#!/usr/bin/env python
"""Documentation health checks (DESIGN.md §8; run by the CI docs job).

Three checks, all fatal on failure:

1. **README doctest** — EVERY ```python fenced block in README.md (the
   code quickstart, the object-store quickstart, ...) is extracted and
   executed in its own subprocess with ``PYTHONPATH=src``, so no
   documented snippet can rot.
2. **Section anchors** — every ``§N`` / ``§N.M`` cross-reference in the
   source tree, tests, benchmarks and markdown must resolve to a real
   ``## §N`` / ``### §N.M`` heading in DESIGN.md (catches stale refs
   after renumberings).
3. **Relative links** — every relative markdown link target in README.md
   and DESIGN.md must exist on disk.

Usage:  python tools/check_docs.py  [--skip-doctest]
"""
from __future__ import annotations

import argparse
import os
import pathlib
import re
import subprocess
import sys
import tempfile

REPO = pathlib.Path(__file__).resolve().parent.parent
SCAN_GLOBS = ["src/**/*.py", "tests/**/*.py", "benchmarks/**/*.py",
              "examples/**/*.py", "*.md"]
MD_WITH_LINKS = ["README.md", "DESIGN.md"]


def extract_python_blocks(readme: pathlib.Path) -> list[str]:
    """All ```python fenced blocks — every one is doctested."""
    blocks = re.findall(r"```python\n(.*?)```", readme.read_text(),
                        re.DOTALL)
    if not blocks:
        raise SystemExit("README.md has no ```python quickstart block")
    return blocks


def run_readme_doctest() -> list[str]:
    errors = []
    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO / "src") + os.pathsep + \
        env.get("PYTHONPATH", "")
    for i, code in enumerate(extract_python_blocks(REPO / "README.md"), 1):
        with tempfile.TemporaryDirectory() as d:
            path = pathlib.Path(d) / f"readme_block_{i}.py"
            path.write_text(code)
            proc = subprocess.run([sys.executable, str(path)], env=env,
                                  capture_output=True, text=True,
                                  timeout=600)
        if proc.returncode != 0:
            errors.append(f"README python block #{i} failed "
                          f"(exit {proc.returncode}):\n"
                          f"{proc.stderr.strip()}")
    return errors


def design_headings() -> set[str]:
    """All §N / §N.M anchors declared as DESIGN.md headings."""
    out = set()
    for line in (REPO / "DESIGN.md").read_text().splitlines():
        if line.startswith("#"):
            for ref in re.findall(r"§(\d+(?:\.\d+)?)", line):
                out.add(ref)
    return out


def check_section_refs() -> list[str]:
    known = design_headings()
    errors = []
    for pattern in SCAN_GLOBS:
        for path in sorted(REPO.glob(pattern)):
            rel = path.relative_to(REPO)
            for ln, line in enumerate(path.read_text().splitlines(), 1):
                for ref in re.findall(r"§(\d+(?:\.\d+)?)", line):
                    # "§Paper" style names and bare "§" never match; only
                    # numeric refs are checked.  DESIGN's own headings are
                    # declarations, not references.
                    if str(rel) == "DESIGN.md" and line.startswith("#"):
                        continue
                    if ref not in known:
                        errors.append(f"{rel}:{ln}: stale reference §{ref} "
                                      f"(DESIGN.md has {sorted(known)})")
    return errors


def check_relative_links() -> list[str]:
    errors = []
    for name in MD_WITH_LINKS:
        path = REPO / name
        for ln, line in enumerate(path.read_text().splitlines(), 1):
            for target in re.findall(r"\]\(([^)]+)\)", line):
                if target.startswith(("http://", "https://", "#", "mailto:")):
                    continue
                if not (REPO / target.split("#")[0]).exists():
                    errors.append(f"{name}:{ln}: dead link -> {target}")
    return errors


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--skip-doctest", action="store_true",
                    help="only run the static anchor/link checks")
    args = ap.parse_args()

    errors = check_section_refs() + check_relative_links()
    for e in errors:
        print(f"FAIL {e}")
    print(f"anchors: {len(design_headings())} DESIGN.md headings; "
          f"links: checked {MD_WITH_LINKS}")
    if not args.skip_doctest:
        doc_errors = run_readme_doctest()
        for e in doc_errors:
            print(f"FAIL {e}")
        errors += doc_errors
        if not doc_errors:
            n = len(extract_python_blocks(REPO / "README.md"))
            print(f"README doctest: {n} python block(s) ran clean")
    if errors:
        print(f"{len(errors)} documentation error(s)")
        return 1
    print("docs OK")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
