"""End-to-end fault-tolerant training driver (deliverable b).

Trains an LM on the synthetic pipeline with MSR-coded checkpointing and an
injected node crash mid-run; verifies the post-repair run is bit-exact with
an uninterrupted one.

    PYTHONPATH=src python examples/train_tiny_lm.py --preset tiny   # CPU, ~1 min
    PYTHONPATH=src python examples/train_tiny_lm.py --preset 100m   # ~100M params
    PYTHONPATH=src python examples/train_tiny_lm.py --arch qwen3-4b --reduced

The 100m preset is the "train a ~100M model for a few hundred steps" driver;
on this CPU container it is compute-heavy — tiny is the smoke default.
"""
import argparse
import os
import sys
import tempfile

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

from repro.checkpoint.msr_checkpoint import MSRCheckpointer
from repro.configs import get_config
from repro.core.circulant import CodeSpec
from repro.optim import adamw
from repro.train.fault_tolerance import FailureEvent, FailureInjector
from repro.train.loop import TrainConfig, train

PRESETS = {
    "tiny": dict(model=dict(n_layers=2, d_model=128, n_heads=4, n_kv_heads=2,
                            head_dim=32, d_ff=512, vocab_size=512,
                            loss_chunk=64),
                 steps=120, batch=8, seq=64),
    "100m": dict(model=dict(n_layers=12, d_model=768, n_heads=12, n_kv_heads=4,
                            head_dim=64, d_ff=2048, vocab_size=8192,
                            loss_chunk=128),
                 steps=300, batch=8, seq=256),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", choices=sorted(PRESETS), default="tiny")
    ap.add_argument("--arch", default="paper-tiny-lm")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=None)
    ap.add_argument("--k", type=int, default=4, help="MSR code dimension")
    ap.add_argument("--crash-step", type=int, default=None)
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()

    preset = PRESETS[args.preset]
    cfg = get_config(args.arch)
    if args.reduced or args.arch == "paper-tiny-lm":
        cfg = cfg.reduced(**preset["model"])
    steps = args.steps or preset["steps"]
    tcfg = TrainConfig(n_steps=steps, global_batch=preset["batch"],
                       seq_len=preset["seq"], ckpt_every=max(steps // 6, 5),
                       log_every=max(steps // 10, 1), seed=0)
    crash = args.crash_step or (steps * 2 // 3)

    ckpt_dir = args.ckpt_dir or tempfile.mkdtemp(prefix="msr_ckpt_")
    spec = CodeSpec.make(args.k, 257)
    from repro.launch.steps import count_params
    from repro.models import Model
    n_params = count_params(jax.eval_shape(
        lambda: Model(cfg).init(jax.random.PRNGKey(0))))
    print(f"arch={cfg.name}  params={n_params/1e6:.1f}M  steps={steps}  "
          f"MSR code [{spec.n},{spec.k}] over GF({spec.p})  ckpt={ckpt_dir}")
    ckpt = MSRCheckpointer(ckpt_dir, spec)
    injector = FailureInjector(spec.n, schedule=[FailureEvent(step=crash, node=2)])

    opt = adamw.AdamWConfig(lr=3e-3, warmup_steps=max(steps // 20, 1),
                            total_steps=steps)
    print(f"\n-- training with a node-2 crash injected at step {crash} --")
    state, log = train(cfg, tcfg, opt, checkpointer=ckpt, injector=injector)
    repairs = [e for e in log if e["event"] == "repair"]
    steps_logged = [e for e in log if e["event"] == "step"]
    print(f"completed: {len(steps_logged)} step executions, "
          f"{len(repairs)} repair event(s)")
    for r in repairs:
        print(f"  crash@{r['step']}: restored from ckpt@{r['ckpt_step']} via "
              f"'{r['restore_path']}', repair read {r['repair_bytes']/2**20:.2f} MiB")
    losses = [e["loss"] for e in steps_logged]
    print(f"loss: first={losses[0]:.4f}  last={losses[-1]:.4f}")
    assert losses[-1] < losses[0], "training must make progress"

    print("\n-- verifying bit-exact equivalence with an uninterrupted run --")
    with tempfile.TemporaryDirectory() as d2:
        ckpt2 = MSRCheckpointer(d2, spec)
        state_clean, _ = train(cfg, tcfg, opt, checkpointer=ckpt2)
    for a, b in zip(jax.tree_util.tree_leaves(state),
                    jax.tree_util.tree_leaves(state_clean)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    print("final states are BIT-EXACT equal: crash + MSR repair is invisible.")


if __name__ == "__main__":
    main()
