"""Coded object store under fire, end to end (DESIGN.md §10).

A [2k, k] MSR object store on a physical ring larger than the code —
put-heavy traffic, then read-heavy traffic, then a whole rack dies while
a store-backed checkpoint is live.  Reads keep serving bit-exactly
through the outage (systematic fast path where shares survive, ONE
cached-inverse decode matmul per failure pattern for the rest), the
background scheduler queues every affected stripe with priority =
remaining redundancy, a second failure mid-drain makes the newly
at-risk stripes jump the queue, and the bandwidth-throttled drain
rebuilds everything for a fraction of the classical-RS re-download
baseline.

    PYTHONPATH=src python examples/store_demo.py [--k 4] [--objects 6]
"""
import argparse
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.checkpoint.msr_checkpoint import MSRCheckpointer
from repro.core.circulant import CodeSpec
from repro.store import CodedObjectStore, DrainReport, RepairScheduler


def check_reads(store, objs, label):
    t0 = time.perf_counter()
    degraded = 0
    for key, ref in objs.items():
        res = store.get_ext(key)
        assert res.obj == ref, f"get({key}) not bit-exact"
        degraded += res.degraded_stripes
    dt = time.perf_counter() - t0
    mb = sum(len(v) for v in objs.values()) / 2**20
    print(f"[{label}] {len(objs)} objects BIT-EXACT in {dt:.3f}s "
          f"({mb/dt:.1f} MB/s, {degraded} degraded stripe reads)")
    return degraded


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--k", type=int, default=4, help="MSR code dimension")
    ap.add_argument("--objects", type=int, default=6)
    ap.add_argument("--object-kb", type=int, default=96)
    ap.add_argument("--stripe-symbols", type=int, default=1 << 10)
    ap.add_argument("--extra-nodes", type=int, default=4)
    ap.add_argument("--budget-stripes", type=int, default=2,
                    help="repair budget per tick, in full-decode stripes")
    args = ap.parse_args()

    spec = CodeSpec.make(args.k, 257)
    n_nodes = spec.n + args.extra_nodes
    store = CodedObjectStore(spec, n_nodes=n_nodes, n_racks=4,
                             stripe_symbols=args.stripe_symbols)
    sched = RepairScheduler(store)
    store.subscribe(sched.on_event)     # failures feed the repair queue
    print(f"[{spec.n},{spec.k}] MSR store over GF({spec.p}): "
          f"{n_nodes} nodes / {store.layout.n_racks} racks, "
          f"S={store.S} symbols, backend={store.code.backend_name}")

    # ---- put-heavy phase: odd sizes, multi-stripe objects, a pytree
    rng = np.random.default_rng(0)
    objs = {}
    t0 = time.perf_counter()
    for i in range(args.objects):
        size = args.object_kb * 1024 + 131 * i + (i % 3)   # never round
        key = f"obj{i:02d}"
        objs[key] = rng.integers(0, 256, size, dtype=np.uint8).tobytes()
        store.put(key, objs[key])
    put_dt = time.perf_counter() - t0
    total_mb = sum(len(v) for v in objs.values()) / 2**20
    stripes = sum(store.stat(k).n_stripes for k in objs)
    print(f"[put] {len(objs)} objects, {total_mb:.2f} MB in {stripes} "
          f"stripes: {total_mb/put_dt:.1f} MB/s")

    # a live store-backed checkpoint rides on the same ring (§10.4)
    state = {"w": np.arange(4096, dtype=np.float32).reshape(64, 64),
             "step": np.int32(7)}
    ck = MSRCheckpointer(None, store=store, leaf_group_bytes=8192)
    ck.save(7, state)

    # ---- read-heavy phase (healthy: all systematic)
    check_reads(store, objs, "read")
    assert store.metrics.reads_degraded == 0

    # ---- a whole rack dies
    victims = store.layout.nodes_in(0)
    for v in victims:
        store.fail_node(v)
    order = sched.peek_order()
    rems = [rem for _, _, rem in order]
    print(f"[failure] rack 0 ({list(victims)}) lost; repair queue: "
          f"{sched.pending()} stripes, remaining-redundancy "
          f"{min(rems)}..{max(rems)}")

    deg = check_reads(store, objs, "degraded")
    assert deg > 0, "rack loss must force degraded stripe reads"
    restored, rep = ck.restore(state)
    assert np.array_equal(restored["w"], state["w"])
    print(f"[checkpoint] store-backed restore BIT-EXACT through the "
          f"outage ({rep.bytes_read} bytes read)")

    # ---- drain under a bandwidth budget; a second failure mid-drain
    budget = args.budget_stripes * 2 * spec.k * store.S
    first = sched.drain(budget_symbols=budget)
    survivor = next(v for v in store.up_nodes()
                    if store.layout.rack_of(v) != 0)
    store.fail_node(survivor)
    order = sched.peek_order()
    min_rem = min(rem for _, _, rem in order)
    at_risk = [(key, t) for key, t, rem in order if rem == min_rem]
    others = [(key, t) for key, t, rem in order if rem != min_rem]
    # prove the at-risk stripes are REPAIRED first, not just queued
    # first: one throttled tick sized for m at-risk repairs must heal m
    # of them while every lower-priority stripe stays lost
    m = min(args.budget_stripes, len(at_risk))
    tick = sched.drain(budget_symbols=m * 2 * spec.k * store.S)
    healed = [kt for kt in at_risk if not store.lost_code_nodes(*kt)]
    assert len(healed) >= m, "at-risk stripes must be repaired first"
    assert all(store.lost_code_nodes(*kt) for kt in others), \
        "no lower-priority stripe may jump the at-risk set"
    print(f"[failure] node {survivor} died mid-drain: {len(at_risk)} "
          f"stripes dropped to remaining-redundancy {min_rem}; next tick "
          f"healed {len(healed)} of them while {len(others)} safer stripes "
          f"waited — scheduler repairs at-risk stripes first")

    rest = sched.drain_all(budget_symbols=budget)
    total = DrainReport(ticks=2 + rest.ticks)
    for part in (first, tick, rest):
        total.merge(part)
    moved, baseline = total.symbols_moved, total.rs_baseline_symbols
    ratio = moved / baseline
    print(f"[scheduler] drained {total.repaired_stripes} stripe repairs "
          f"in {total.ticks} ticks @ {budget} sym/tick "
          f"({total.batch_calls} coalesced batch + "
          f"{total.decode_calls} decode dispatches, "
          f"{total.drain_time_s:.3f}s simulated)")
    print(f"[scheduler] repair traffic {moved/2**20:.2f} Mi symbols vs "
          f"RS re-download {baseline/2**20:.2f} Mi — ratio {ratio:.3f}")
    assert ratio < 1.0, "MSR repair must beat the RS baseline"
    assert sched.pending() == 0 and total.unrecoverable == 0

    # ---- healed: bit-exact and fully systematic again
    assert store.verify(), "post-repair shares must equal a fresh encode"
    before = store.metrics.reads_degraded
    check_reads(store, objs, "healed")
    assert store.metrics.reads_degraded == before, "healed reads degrade"
    m = store.metrics.summary()
    print(f"[healed] store whole; availability={m['availability']}, "
          f"reads {m['reads']['systematic']} systematic / "
          f"{m['reads']['degraded']} degraded / {m['reads']['failed']} failed")


if __name__ == "__main__":
    main()
