"""Quickstart: the paper end-to-end in 60 lines.

Encode a file with a [2k, k] double circulant MSR code, kill a node,
regenerate it with the embedded d = k+1 protocol, and verify any-k
reconstruction — printing the bandwidth ledger from eq. (7).

    PYTHONPATH=src python examples/quickstart.py [--k 4] [--mb 4]
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np
import jax.numpy as jnp

from repro.core.circulant import CodeSpec
from repro.core.msr import DoubleCirculantMSR, encode_file, reconstruct_file


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--k", type=int, default=4)
    ap.add_argument("--mb", type=float, default=4.0, help="file size in MiB")
    args = ap.parse_args()

    k = args.k
    spec = CodeSpec.make(k, p=257)
    code = DoubleCirculantMSR(spec)
    n, d = spec.n, spec.d
    print(f"[{n},{k}] double circulant MSR code over GF(257), "
          f"c = {spec.c}  (condition (6) verified)")

    payload = np.random.default_rng(0).integers(0, 256, int(args.mb * 2**20),
                                                dtype=np.int64).astype(np.uint8).tobytes()
    enc = encode_file(payload, spec, code)
    b = len(payload)
    s_block = enc.data.shape[1]
    print(f"file B = {b/2**20:.1f} MiB -> {n} data blocks + {n} redundancy "
          f"blocks of {s_block/2**20:.2f} MiB; per-node alpha = {2*s_block/2**20:.2f} MiB "
          f"(= B/k, the MSR point)")

    # ---- kill node 3 and regenerate it through the fused batched engine:
    # the whole newcomer computation is ONE (2, k+1) repair-matrix matmul
    # (DESIGN.md §4), vmapped over however many nodes failed
    victim = 3
    plan = code.repair_plan(victim)
    print(f"\nnode v_{victim} fails.  Embedded repair plan (no coefficient "
          f"search): redundancy from v_{plan.prev_node}, data from "
          f"{['v_%d' % j for j in plan.next_nodes]}")
    r_prevs = jnp.asarray(enc.red[plan.prev_node - 1])[None]      # (1, S)
    nxt = jnp.asarray(enc.data[np.asarray(plan.data_indices)])[None]  # (1,k,S)
    pairs = np.asarray(code.regenerate_batch([victim], r_prevs, nxt))
    assert np.array_equal(pairs[0, 0], enc.data[victim - 1])
    assert np.array_equal(pairs[0, 1], enc.red[victim - 1])
    gamma = d * s_block
    print(f"regenerated BIT-EXACTLY in one fused matmul.  downloaded {d} "
          f"blocks = {gamma/2**20:.2f} MiB = (k+1)B/2k; classical EC would "
          f"read {b/2**20:.1f} MiB  ->  saving {1-gamma/b:.1%}")

    # ---- any-k reconstruction (data collector path); the system inverse
    # is LRU-cached by node subset, so the second call costs no solve
    pick = sorted(np.random.default_rng(1).choice(n, size=k, replace=False) + 1)
    got = reconstruct_file(enc, [int(x) for x in pick], code)
    assert got == payload
    reconstruct_file(enc, [int(x) for x in pick], code)   # cache hit
    info = code.repair.decode_cache.cache_info()
    print(f"\nDC reconstruction from nodes {pick}: OK "
          f"(downloaded 2k blocks = B = {b/2**20:.1f} MiB, the minimum; "
          f"decode-inverse cache: {info.hits} hit / {info.misses} miss)")


if __name__ == "__main__":
    main()
