"""Batched serving demo: prefill + KV-cache decode with continuous batching.

    PYTHONPATH=src python examples/serve_demo.py [--arch gemma3-27b]

Uses the reduced config of the chosen arch (CPU container); the full-size
serving path is exercised by the decode_32k / long_500k dry-run cells.
"""
import argparse
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

from repro.configs import get_config
from repro.models import Model
from repro.serve.engine import Request, ServingEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-4b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    if cfg.embeds_as_input and not cfg.is_encoder_decoder:
        print(f"{args.arch} consumes frontend embeddings; serving demo uses "
              f"its text decode path only")
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = ServingEngine(model, params, batch_size=args.batch, max_len=128,
                        temperature=args.temperature)
    print(f"arch={cfg.name} (reduced: {cfg.n_layers}L d={cfg.d_model} "
          f"pattern={cfg.layer_pattern})")

    rng = np.random.default_rng(0)
    reqs = [Request(uid=i,
                    prompt=rng.integers(1, cfg.vocab_size, size=6 + i).astype(np.int32),
                    max_new_tokens=args.new_tokens)
            for i in range(args.batch * 2 + 1)]
    t0 = time.perf_counter()
    done = eng.serve(reqs, prompt_len=16)
    dt = time.perf_counter() - t0
    total_new = sum(len(r.out_tokens) for r in done)
    print(f"served {len(done)} requests / {total_new} new tokens "
          f"in {dt:.2f}s ({total_new/dt:.1f} tok/s on CPU)")
    for r in done[:3]:
        print(f"  req {r.uid}: prompt[-4:]={r.prompt[-4:].tolist()} -> "
              f"{r.out_tokens[:8]}...")


if __name__ == "__main__":
    main()
