"""Kill-nodes-while-serving, end to end (DESIGN.md §9).

The serving engine's parameters live MSR-coded across a [2k, k] storage
cluster.  Mid-service a rack's worth of nodes is killed: parameter reads
transparently fall back to the one-matmul degraded decode, generation
continues bit-exactly, the fused repair engine rebuilds the lost nodes,
and the bandwidth ledger shows the repair traffic vs the classical-RS
re-download baseline.

    PYTHONPATH=src python examples/serve_demo.py [--arch qwen3-4b] [--k 4]
"""
import argparse
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

from repro.cluster.events import default_layout
from repro.configs import get_config
from repro.core.circulant import CodeSpec
from repro.models import Model
from repro.serve.engine import CodedReadServer, Request, ServingEngine


def make_requests(rng, vocab, batch, new_tokens):
    return [Request(uid=i,
                    prompt=rng.integers(1, vocab, size=6 + i).astype(np.int32),
                    max_new_tokens=new_tokens)
            for i in range(batch * 2 + 1)]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-4b")
    ap.add_argument("--k", type=int, default=4, help="MSR code dimension")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--new-tokens", type=int, default=16)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    # ---- encode the parameters across the cluster
    spec = CodeSpec.make(args.k, 257)
    layout = default_layout(spec.n, spec.k)
    store = CodedReadServer.for_pytree(params, spec, layout=layout)
    s_sym = store.sim.S
    print(f"arch={cfg.name} (reduced: {cfg.n_layers}L d={cfg.d_model}) — "
          f"params stored on a [{spec.n},{spec.k}] MSR cluster over "
          f"GF({spec.p}), {s_sym/2**20:.2f} Mi symbols/block, "
          f"{layout.n_racks} racks")

    eng = ServingEngine.from_coded_store(model, store,
                                         batch_size=args.batch, max_len=128)
    rng = np.random.default_rng(0)
    reqs = make_requests(rng, cfg.vocab_size, args.batch, args.new_tokens)
    baseline = [list(r.prompt) for r in reqs]

    t0 = time.perf_counter()
    done = eng.serve(reqs, prompt_len=16)
    healthy_tokens = [r.out_tokens for r in done]
    print(f"\n[healthy] served {len(done)} requests in "
          f"{time.perf_counter()-t0:.2f}s (all-systematic parameter reads)")

    # ---- kill a rack's worth of nodes while serving continues
    victims = layout.nodes_in(0)[: spec.n - spec.k]
    for v in victims:
        store.sim.fail_node(v)
    print(f"\n[failure] killed nodes {list(victims)} (rack 0); "
          f"{len(store.sim.up_nodes())}/{spec.n} nodes up")

    eng.reload_params(store)            # transparent degraded decode
    reqs2 = [Request(uid=r.uid, prompt=np.asarray(p, np.int32),
                     max_new_tokens=args.new_tokens)
             for r, p in zip(done, baseline)]
    done2 = eng.serve(reqs2, prompt_len=16)
    degraded_tokens = [r.out_tokens for r in done2]
    assert degraded_tokens == healthy_tokens, "degraded decode must be bit-exact"
    print(f"[degraded] re-served all {len(done2)} requests BIT-EXACTLY from "
          f"{len(store.sim.up_nodes())} survivors "
          f"({store.metrics.reads_degraded} degraded block reads)")

    # ---- repair and verify the cluster is whole again
    repaired = store.sim.repair_now()
    if not repaired:
        raise RuntimeError("repair impossible: fewer than k nodes up")
    rep = store.metrics.summary()["repair"]
    assert np.array_equal(store.sim.node_a, store.sim._orig_a)
    print(f"\n[repair] rebuilt {rep['nodes_repaired']} nodes in "
          f"{rep['events']} one-matmul decode(s): moved "
          f"{rep['symbols_moved']/2**20:.2f} Mi symbols vs RS re-download "
          f"{rep['rs_baseline_symbols']/2**20:.2f} Mi "
          f"(ratio {rep['ratio_vs_rs']})")
    eng.reload_params(store)
    done3 = eng.serve([Request(uid=r.uid, prompt=np.asarray(p, np.int32),
                               max_new_tokens=args.new_tokens)
                       for r, p in zip(done, baseline)], prompt_len=16)
    assert [r.out_tokens for r in done3] == healthy_tokens
    m = store.metrics.summary()
    print(f"[healed] cluster whole; availability={m['availability']}, "
          f"reads: {m['reads']['systematic']} systematic / "
          f"{m['reads']['degraded']} degraded / {m['reads']['failed']} failed")
    for r in done3[:3]:
        print(f"  req {r.uid}: prompt[-4:]={r.prompt[-4:].tolist()} -> "
              f"{r.out_tokens[:8]}...")


if __name__ == "__main__":
    main()
