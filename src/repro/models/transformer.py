"""Block assembly + scan-over-superblocks transformer stack.

The layer pattern (cfg.layer_pattern, e.g. ("la","la","la","la","la","ga"))
is cycled to n_layers.  Full cycles are STACKED and run under one lax.scan —
HLO size stays O(cycle), which is what makes 512-device SPMD compiles
tractable; remainder layers are unrolled.

Modes: "train" (no cache), "prefill" (build cache), "decode" (consume cache,
s == 1).  Caches mirror the parameter stacking structure.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.sharding import ctx as shctx

from . import attention as attn
from . import ffn as ffn_mod
from . import moe as moe_mod
from . import rglru as rglru_mod
from . import xlstm as xlstm_mod
from .layers import apply_norm, init_norm, positions_to_angles

Params = Any


@dataclasses.dataclass
class Ctx:
    """Per-call context threaded through blocks."""
    mode: str                       # train | prefill | decode
    cos: Optional[jnp.ndarray]      # rotary angles for current positions
    sin: Optional[jnp.ndarray]
    q_pos: jnp.ndarray              # (b, s) absolute positions of the inputs
    pos: Optional[jnp.ndarray]      # scalar int32: decode write offset
    max_len: int                    # global-attn cache capacity (decode)
    enc_out: Optional[jnp.ndarray] = None   # encoder hidden states (enc-dec)
    q_chunk: Optional[int] = None   # prefill attention chunking


# ------------------------------------------------------------- block: init
def init_block(cfg, key, kind: str, *, decoder: bool = False) -> dict:
    ks = jax.random.split(key, 8)
    d = cfg.d_model
    if kind in ("ga", "la", "gm", "enc"):
        p = {"norm1": init_norm(cfg, d),
             "attn": attn.init_attention(cfg, ks[0]),
             "norm2": init_norm(cfg, d)}
        if kind == "gm":
            p["moe"] = moe_mod.init_moe(cfg, ks[1])
        else:
            p["ffn"] = ffn_mod.init_ffn(cfg, ks[1])
        if decoder and cfg.is_encoder_decoder and kind != "enc":
            p["cross_norm"] = init_norm(cfg, d)
            p["cross"] = attn.init_attention(cfg, ks[2], cross=True)
        return p
    if kind == "rg":
        return {"norm1": init_norm(cfg, d),
                "rglru": rglru_mod.init_rglru_block(cfg, ks[0]),
                "norm2": init_norm(cfg, d),
                "ffn": ffn_mod.init_ffn(cfg, ks[1])}
    if kind == "ml":
        return {"norm1": init_norm(cfg, d),
                "mlstm": xlstm_mod.init_mlstm_block(cfg, ks[0])}
    if kind == "sl":
        return {"norm1": init_norm(cfg, d),
                "slstm": xlstm_mod.init_slstm_block(cfg, ks[0])}
    raise ValueError(f"unknown block kind {kind!r}")


def init_block_cache(cfg, kind: str, batch: int, max_len: int,
                     *, decoder: bool = False) -> dict:
    if kind in ("ga", "gm", "enc"):
        c = attn.init_global_cache(cfg, batch, max_len)
    elif kind == "la":
        c = attn.init_window_cache(cfg, batch)
    elif kind == "rg":
        c = rglru_mod.init_rglru_cache(cfg, batch)
    elif kind == "ml":
        c = xlstm_mod.init_mlstm_cache(cfg, batch)
    elif kind == "sl":
        c = xlstm_mod.init_slstm_cache(cfg, batch)
    else:
        raise ValueError(kind)
    if decoder and cfg.is_encoder_decoder and kind not in ("enc",):
        m, hd = cfg.n_kv_heads, cfg.head_dim
        c = dict(c)
        c["ck"] = jnp.zeros((batch, cfg.encoder_seq, m, hd), jnp.bfloat16)
        c["cv"] = jnp.zeros((batch, cfg.encoder_seq, m, hd), jnp.bfloat16)
    return c


# ------------------------------------------------------------ block: apply
def _self_attention_sublayer(cfg, p, x, kind, ctx: Ctx, cache):
    h = apply_norm(cfg, p["norm1"], x)
    causal = kind != "enc"
    window = cfg.window_size if kind == "la" else None
    q = attn.project_q(cfg, p["attn"], h, ctx.cos, ctx.sin)
    k_new, v_new = attn.project_kv(cfg, p["attn"], h, ctx.cos, ctx.sin)
    new_cache = cache
    if ctx.mode == "decode":
        # Mask against the cache in ABSOLUTE slot coordinates: k_pos below
        # is the cache slot index, so the query side must be the absolute
        # position ctx.pos too.  ctx.q_pos is the ROPE stream position —
        # identical for text archs, but the M-RoPE temporal stream lags the
        # slot index once image tokens share a t, which would wrongly mask
        # the newest slots out of q_pos - k_pos >= 0.
        q_pos = jnp.broadcast_to(
            jnp.asarray(ctx.pos, jnp.int32)[None, None],
            (x.shape[0], x.shape[1]))
        if kind == "la":
            new_cache = {**cache,
                         **attn.window_cache_update(cache, k_new, v_new, ctx.pos)}
            w = cfg.window_size
            slot_pos = attn.window_slot_positions(ctx.pos, w)       # (W,)
            k_pos = jnp.broadcast_to(slot_pos[None], (x.shape[0], w))
            k_valid = (slot_pos >= 0) & (slot_pos <= ctx.pos)
            k_valid = jnp.broadcast_to(k_valid[None], (x.shape[0], w))
        else:
            new_cache = {**cache,
                         **attn.global_cache_update(cache, k_new, v_new, ctx.pos)}
            t = jnp.arange(ctx.max_len, dtype=jnp.int32)
            k_pos = jnp.broadcast_to(t[None], (x.shape[0], ctx.max_len))
            k_valid = jnp.broadcast_to((t <= ctx.pos)[None],
                                       (x.shape[0], ctx.max_len))
        o = attn.attention(cfg, q, new_cache["k"], new_cache["v"],
                           q_pos=q_pos, k_pos=k_pos, causal=causal,
                           window=cfg.window_size if kind == "la" else None,
                           k_valid=k_valid)
    else:
        o = attn.attention(cfg, q, k_new, v_new, q_pos=ctx.q_pos,
                           k_pos=ctx.q_pos, causal=causal, window=window,
                           q_chunk=ctx.q_chunk)
        if ctx.mode == "prefill" and cache is not None:
            if kind == "la":
                ring = attn.prefill_to_window_cache(cfg, k_new, v_new, x.shape[1])
                new_cache = {**cache, **ring}
            else:
                new_cache = {**cache,
                             **attn.global_cache_update(
                                 {"k": cache["k"], "v": cache["v"]},
                                 k_new, v_new, 0)}
    return x + attn.out_proj(p["attn"], o), new_cache


def _cross_attention_sublayer(cfg, p, x, ctx: Ctx, cache):
    h = apply_norm(cfg, p["cross_norm"], x)
    q = attn.project_q(cfg, p["cross"], h, None, None)   # no rope on cross
    new_cache = cache
    if ctx.mode == "decode":
        ck, cv = cache["ck"], cache["cv"]
    else:
        ck, cv = attn.project_kv(cfg, p["cross"], ctx.enc_out, None, None)
        if ctx.mode == "prefill" and cache is not None:
            new_cache = {**cache, "ck": ck.astype(cache["ck"].dtype),
                         "cv": cv.astype(cache["cv"].dtype)}
    t = ck.shape[1]
    k_pos = jnp.broadcast_to(jnp.arange(t, dtype=jnp.int32)[None],
                             (x.shape[0], t))
    o = attn.attention(cfg, q, ck, cv, q_pos=jnp.zeros_like(ctx.q_pos),
                       k_pos=k_pos, causal=False, window=None,
                       q_chunk=ctx.q_chunk)
    return x + attn.out_proj(p["cross"], o), new_cache


def apply_block(cfg, p, kind: str, x, ctx: Ctx, cache=None,
                *, decoder: bool = False):
    """Returns (x, new_cache, aux)."""
    x = shctx.constrain(x, "residual")
    aux = jnp.zeros((), jnp.float32)
    if kind in ("ga", "la", "gm", "enc"):
        x, cache = _self_attention_sublayer(cfg, p, x, kind, ctx, cache)
        if decoder and cfg.is_encoder_decoder and kind != "enc":
            x, cache = _cross_attention_sublayer(cfg, p, x, ctx, cache)
        h = apply_norm(cfg, p["norm2"], x)
        if kind == "gm":
            f, aux = moe_mod.apply_moe(cfg, p["moe"], h)
        else:
            f = ffn_mod.apply_ffn(cfg, p["ffn"], h)
        return x + f, cache, aux
    if kind == "rg":
        h = apply_norm(cfg, p["norm1"], x)
        o, new_rec = rglru_mod.apply_rglru_block(
            cfg, p["rglru"], h,
            cache=None if ctx.mode == "train" and cache is None else cache,
            pos=ctx.pos)
        x = x + o
        h2 = apply_norm(cfg, p["norm2"], x)
        x = x + ffn_mod.apply_ffn(cfg, p["ffn"], h2)
        return x, new_rec, aux
    if kind == "ml":
        h = apply_norm(cfg, p["norm1"], x)
        o, new_state = xlstm_mod.apply_mlstm_block(cfg, p["mlstm"], h,
                                                   cache=cache, pos=ctx.pos)
        return x + o, new_state, aux
    if kind == "sl":
        h = apply_norm(cfg, p["norm1"], x)
        o, new_state = xlstm_mod.apply_slstm_block(cfg, p["slstm"], h,
                                                   cache=cache, pos=ctx.pos)
        return x + o, new_state, aux
    raise ValueError(kind)


# ----------------------------------------------------------- stack: init
def init_stack(cfg, key, *, decoder: bool = False) -> dict:
    n_cycles, rem = cfg.cycles()
    pattern = cfg.layer_pattern
    keys = jax.random.split(key, len(pattern) + rem)
    params: dict = {}
    if n_cycles > 0:
        cyc = []
        for j, kind in enumerate(pattern):
            sub = jax.random.split(keys[j], n_cycles)
            cyc.append(jax.vmap(lambda kk, kind=kind: init_block(
                cfg, kk, kind, decoder=decoder))(sub))
        params["cycles"] = tuple(cyc)
    for r in range(rem):
        kind = pattern[r]
        params[f"rem_{r}"] = init_block(cfg, keys[len(pattern) + r], kind,
                                        decoder=decoder)
    return params


def init_stack_cache(cfg, batch: int, max_len: int, *, decoder: bool = False) -> dict:
    n_cycles, rem = cfg.cycles()
    pattern = cfg.layer_pattern
    cache: dict = {}
    if n_cycles > 0:
        cyc = []
        for kind in pattern:
            one = init_block_cache(cfg, kind, batch, max_len, decoder=decoder)
            cyc.append(jax.tree_util.tree_map(
                lambda x: jnp.zeros((n_cycles,) + x.shape, x.dtype), one))
        cache["cycles"] = tuple(cyc)
    for r in range(rem):
        cache[f"rem_{r}"] = init_block_cache(cfg, pattern[r], batch, max_len,
                                             decoder=decoder)
    return cache


# ---------------------------------------------------------- stack: apply
def apply_stack(cfg, params: dict, x, ctx: Ctx, cache: Optional[dict] = None,
                *, decoder: bool = False, remat: bool = True):
    """Returns (x, new_cache_or_None, aux_sum)."""
    n_cycles, rem = cfg.cycles()
    pattern = cfg.layer_pattern
    aux_total = jnp.zeros((), jnp.float32)
    new_cache: dict = {}

    if n_cycles > 0:
        def cycle_body(carry, xs):
            xc, aux = carry
            layer_params, layer_cache = xs
            new_caches = []
            for j, kind in enumerate(pattern):
                cj = None if layer_cache is None else layer_cache[j]
                xc, cj_new, a = apply_block(cfg, layer_params[j], kind, xc,
                                            ctx, cj, decoder=decoder)
                aux = aux + a
                new_caches.append(cj_new)
            return (xc, aux), tuple(new_caches)

        body = jax.checkpoint(cycle_body) if (remat and ctx.mode == "train") \
            else cycle_body
        cyc_cache = cache["cycles"] if cache is not None else None
        if cyc_cache is None:
            # feed dummy None-cache: use per-kind fresh zeros? train mode:
            # recurrent blocks need an initial state even without a cache.
            dummy = _train_cache_stub(cfg, x.shape[0], n_cycles)
            (x, aux_total), _ = jax.lax.scan(body, (x, aux_total),
                                             (params["cycles"], dummy))
        else:
            (x, aux_total), out_cyc = jax.lax.scan(body, (x, aux_total),
                                                   (params["cycles"], cyc_cache))
            new_cache["cycles"] = out_cyc

    for r in range(rem):
        kind = pattern[r]
        cj = None if cache is None else cache[f"rem_{r}"]
        x, cj_new, a = apply_block(cfg, params[f"rem_{r}"], kind, x, ctx, cj,
                                   decoder=decoder)
        aux_total = aux_total + a
        if cache is not None:
            new_cache[f"rem_{r}"] = cj_new

    return x, (new_cache if cache is not None else None), aux_total


def _train_cache_stub(cfg, batch: int, n_cycles: int):
    """Zero initial recurrent states for train mode (attention kinds get an
    empty dict placeholder: their train path ignores the cache)."""
    stubs = []
    for kind in cfg.layer_pattern:
        if kind == "rg":
            one = rglru_mod.init_rglru_cache(cfg, batch)
        elif kind == "ml":
            one = xlstm_mod.init_mlstm_cache(cfg, batch)
        elif kind == "sl":
            one = xlstm_mod.init_slstm_cache(cfg, batch)
        else:
            one = {}
        stubs.append(jax.tree_util.tree_map(
            lambda x: jnp.zeros((n_cycles,) + x.shape, x.dtype), one))
    return tuple(stubs)
