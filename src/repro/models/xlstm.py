"""xLSTM blocks (arXiv:2405.04517): mLSTM (matrix memory) and sLSTM.

mLSTM — matrix-memory cell with exponential gating:
    C_t = f_t C_{t-1} + i_t v_t k_t^T,   n_t = f_t n_{t-1} + i_t k_t
    h_t = (C_t q_t) / max(|n_t^T q_t|, 1)
Training/prefill uses the stabilized CHUNKWISE form (intra-chunk parallel via
the log-gate decay matrix D, inter-chunk recurrent state — GLA/SSD-style,
O(C^2) score tiles instead of O(S^2)); decode keeps (C, n, m) state.
Block structure: pre-norm -> up-proj (x2) -> [conv? omitted] -> mLSTM heads
-> learnable skip gate -> down-proj (the paper's pre-up-projection block).

sLSTM — scalar memory, new memory mixing, exponential gating with the
stabilizer m_t; realized as a lax.scan over time (only 1/8 of the layers).
Block: pre-norm -> sLSTM -> post up/down MLP (factor 4/3).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .layers import COMPUTE_DTYPE, PARAM_DTYPE, dense_init

NEG_INF = -1e30


# ==================================================================== mLSTM
def init_mlstm_block(cfg, key) -> dict:
    d = cfg.d_model
    di = int(d * cfg.xlstm_proj_factor)        # inner width
    h = cfg.n_heads
    dh = di // h
    ks = jax.random.split(key, 9)
    return {
        "w_up": dense_init(ks[0], (d, di)),
        "w_up_gate": dense_init(ks[1], (d, di)),
        "wq": dense_init(ks[2], (di, h, dh)),
        "wk": dense_init(ks[3], (di, h, dh)),
        "wv": dense_init(ks[4], (di, h, dh)),
        # per-head scalar gates from the inner stream
        "w_i": dense_init(ks[5], (di, h), scale=di ** -0.5),
        "w_f": dense_init(ks[6], (di, h), scale=di ** -0.5),
        "b_i": jnp.zeros((h,), PARAM_DTYPE),
        "b_f": jnp.full((h,), 3.0, PARAM_DTYPE),   # forget-gate bias: remember
        "skip_scale": jnp.ones((di,), PARAM_DTYPE),
        "w_down": dense_init(ks[7], (di, d)),
        "out_norm_scale": jnp.ones((di,), PARAM_DTYPE),
    }


MLSTM_CHUNK = 256


def _mlstm_chunk_step(state, q, k, v, log_i, log_f, dh):
    """One chunk of the stabilized CHUNKWISE mLSTM (paper app. A adapted to
    the GLA/SSD chunkwise scheme — TPU-native: intra-chunk parallel matmuls
    on the MXU, O(C^2) score tiles, inter-chunk O(dk*dv) recurrent state).

    state: {c: (b,h,dk,dv), n: (b,h,dk), m: (b,h)} — stabilized so the true
      state is (c, n) * exp(m).
    q,k,v: (b,C,h,dh) fp32; log_i/log_f: (b,C,h) fp32.
    Returns (new_state, h_out (b,C,h,dh)).
    """
    b, C, h, _ = q.shape
    c0, n0, m0 = state["c"], state["n"], state["m"]
    F = jnp.cumsum(log_f, axis=1)                          # (b,C,h) inclusive
    # intra-chunk decay matrix D[t,u] = F_t - F_u + log_i_u  (u <= t)
    dmat = F[:, :, None, :] - F[:, None, :, :] + log_i[:, None, :, :]
    tri = jnp.tril(jnp.ones((C, C), bool))
    dmat = jnp.where(tri[None, :, :, None], dmat, NEG_INF)
    intra_max = jnp.max(dmat, axis=2)                      # (b,t,h)
    # stabilizer per position: max(cross-chunk carry, intra contributions)
    m_t = jnp.maximum(F + m0[:, None, :], intra_max)       # (b,C,h)
    dexp = jnp.exp(dmat - m_t[:, :, None, :])              # (b,t,u,h)
    scores = jnp.einsum("bthd,buhd->btuh", q, k)           # q pre-scaled by dh^-0.5
    w = scores * dexp                                      # masked by dexp=0
    carry_scale = jnp.exp(F + m0[:, None, :] - m_t)        # (b,C,h)
    num = (jnp.einsum("btuh,buhd->bthd", w, v)
           + carry_scale[..., None] * jnp.einsum("bthk,bhkv->bthv", q, c0))
    den_intra = w.sum(2)                                   # (b,t,h)
    den_carry = jnp.einsum("bthk,bhk->bth", q, n0)
    den = den_intra + carry_scale * den_carry
    h_out = num / jnp.maximum(jnp.abs(den), jnp.exp(-m_t))[..., None]
    # end-of-chunk state update (t = C-1 formulas)
    m_new = m_t[:, -1, :]                                  # (b,h)
    decay_u = jnp.exp(F[:, -1:, :] - F + log_i - m_new[:, None, :])  # (b,u,h)
    kv = jnp.einsum("buh,buhk,buhv->bhkv", decay_u, k, v)
    c_new = jnp.exp(F[:, -1, :] + m0 - m_new)[..., None, None] * c0 + kv
    n_new = (jnp.exp(F[:, -1, :] + m0 - m_new)[..., None] * n0
             + jnp.einsum("buh,buhk->bhk", decay_u, k))
    return {"c": c_new, "n": n_new, "m": m_new}, h_out


def _mlstm_chunkwise(q, k, v, log_i, log_f, state, chunk=MLSTM_CHUNK):
    """Scan chunks of the sequence through _mlstm_chunk_step.
    q,k,v: (b,s,h,dh) any dtype; returns (h_out (b,s,h,dh) fp32, final state)."""
    b, s, h, dh = q.shape
    c = min(chunk, s)
    assert s % c == 0, (s, c)
    nc = s // c
    def split(x):
        return x.reshape(b, nc, c, *x.shape[2:]).swapaxes(0, 1)
    qs, ks, vs = (split(x.astype(jnp.float32)) for x in (q, k, v))
    lis, lfs = split(log_i), split(log_f)

    def body(st, xs):
        qi, ki, vi, li, lf = xs
        st, hi = _mlstm_chunk_step(st, qi, ki, vi, li, lf, dh)
        return st, hi

    state, hs = jax.lax.scan(body, state, (qs, ks, vs, lis, lfs))
    return hs.swapaxes(0, 1).reshape(b, s, h, dh), state


def _mlstm_recurrent_step(state, q, k, v, log_i, log_f):
    """One decode step.  state: dict(c (b,h,dk,dv), n (b,h,dk), m (b,h)).
    q,k,v: (b,h,dh) fp32; log_i/log_f: (b,h)."""
    c, n, m = state["c"], state["n"], state["m"]
    m_new = jnp.maximum(log_f + m, log_i)
    i_sc = jnp.exp(log_i - m_new)
    f_sc = jnp.exp(log_f + m - m_new)
    c_new = f_sc[..., None, None] * c + i_sc[..., None, None] * (
        k[..., :, None] * v[..., None, :])
    n_new = f_sc[..., None] * n + i_sc[..., None] * k
    num = jnp.einsum("bhkv,bhk->bhv", c_new, q)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhk,bhk->bh", n_new, q)),
                      jnp.exp(-m_new))
    h = num / den[..., None]
    return {"c": c_new, "n": n_new, "m": m_new}, h


def apply_mlstm_block(cfg, params, x, *, cache=None, pos=None):
    """x: (b, s, d) -> (out, new_cache)."""
    b, s, d = x.shape
    hh = cfg.n_heads
    up = x @ params["w_up"].astype(x.dtype)                    # (b,s,di)
    gate = jax.nn.silu(x @ params["w_up_gate"].astype(x.dtype))
    di = up.shape[-1]
    dh = di // hh
    q = jnp.einsum("bsd,dhk->bshk", up, params["wq"].astype(x.dtype)) * (dh ** -0.5)
    k = jnp.einsum("bsd,dhk->bshk", up, params["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dhk->bshk", up, params["wv"].astype(x.dtype))
    upf = up.astype(jnp.float32)
    log_i = upf @ params["w_i"].astype(jnp.float32) + params["b_i"]    # (b,s,h)
    log_f = jax.nn.log_sigmoid(upf @ params["w_f"].astype(jnp.float32)
                               + params["b_f"])

    state = cache if cache is not None else init_mlstm_cache(cfg, b)
    if s > 1:   # train / prefill: chunkwise (intra-parallel, inter-recurrent)
        hout, state = _mlstm_chunkwise(q, k, v, log_i, log_f, state)
        hout = hout.astype(x.dtype)
    else:       # decode: single recurrent step
        state, h_t = _mlstm_recurrent_step(
            state, q[:, 0].astype(jnp.float32), k[:, 0].astype(jnp.float32),
            v[:, 0].astype(jnp.float32), log_i[:, 0], log_f[:, 0])
        hout = h_t[:, None].astype(x.dtype)

    hflat = hout.reshape(b, s, di)
    # group-norm-ish output norm per inner dim (RMS)
    hf = hflat.astype(jnp.float32)
    hflat = (hf * jax.lax.rsqrt((hf ** 2).mean(-1, keepdims=True) + 1e-6)
             * params["out_norm_scale"]).astype(x.dtype)
    mixed = hflat * gate + params["skip_scale"].astype(x.dtype) * up
    out = mixed @ params["w_down"].astype(x.dtype)
    return out, {k_: v_ for k_, v_ in state.items()}


def init_mlstm_cache(cfg, batch: int) -> dict:
    di = int(cfg.d_model * cfg.xlstm_proj_factor)
    h = cfg.n_heads
    dh = di // h
    return {"c": jnp.zeros((batch, h, dh, dh), jnp.float32),
            "n": jnp.zeros((batch, h, dh), jnp.float32),
            "m": jnp.full((batch, h), -1e9, jnp.float32)}


# ==================================================================== sLSTM
def init_slstm_block(cfg, key) -> dict:
    d = cfg.d_model
    h = cfg.n_heads
    dh = d // h
    ks = jax.random.split(key, 6)
    ff = int(d * cfg.slstm_mlp_factor)
    return {
        # input projections for (z, i, f, o) gates
        "w_zifo": dense_init(ks[0], (d, 4, h, dh)),
        # recurrent (per-head block-diagonal) weights
        "r_zifo": dense_init(ks[1], (4, h, dh, dh), scale=dh ** -0.5),
        "b_zifo": jnp.zeros((4, h, dh), PARAM_DTYPE),
        "w_mlp_in": dense_init(ks[2], (d, ff)),
        "w_mlp_gate": dense_init(ks[3], (d, ff)),
        "w_mlp_out": dense_init(ks[4], (ff, d)),
        "norm_scale": jnp.ones((d,), PARAM_DTYPE),
    }


def _slstm_step(params, state, zifo_x_t):
    """state: dict(c,n,m,h) each (b, heads, dh); zifo_x_t: (b, 4, h, dh) fp32
    — the PRE-PROJECTED input gates for this timestep.

    Perf note (EXPERIMENTS.md §Perf, xlstm iteration 1): the input projection
    w_zifo is hoisted out of the time scan into one big pre-scan matmul;
    computing it in-step re-reads the full (d, 4, h, dh) weight every
    timestep — 4096 x 67 MB per layer per microbatch of pure HBM traffic
    (the dominant term of the xlstm-1.3b train_4k baseline roofline).
    Only the genuinely sequential h_{t-1} recurrence stays in the scan."""
    c, n, m, h_prev = state["c"], state["n"], state["m"], state["h"]
    zifo_r = jnp.einsum("bhk,ghkl->bghl", h_prev, params["r_zifo"].astype(jnp.float32))
    pre = zifo_x_t + zifo_r + params["b_zifo"].astype(jnp.float32)
    z = jnp.tanh(pre[:, 0])
    i_log = pre[:, 1]                         # exponential input gate (log-dom)
    f_log = jax.nn.log_sigmoid(pre[:, 2])     # sigmoid forget gate in log space
    o = jax.nn.sigmoid(pre[:, 3])
    m_new = jnp.maximum(f_log + m, i_log)
    i_sc = jnp.exp(i_log - m_new)
    f_sc = jnp.exp(f_log + m - m_new)
    c_new = f_sc * c + i_sc * z
    n_new = f_sc * n + i_sc
    h_new = o * (c_new / jnp.maximum(n_new, 1e-6))
    return {"c": c_new, "n": n_new, "m": m_new, "h": h_new}


def apply_slstm_block(cfg, params, x, *, cache=None, pos=None):
    b, s, d = x.shape
    h = cfg.n_heads
    dh = d // h
    state = cache if cache is not None else init_slstm_cache(cfg, b)
    xf = x.astype(jnp.float32)
    # hoisted input projection: ONE matmul for all timesteps (see _slstm_step)
    zifo_x = jnp.einsum("bsd,dghk->sbghk", xf,
                        params["w_zifo"].astype(jnp.float32))

    def body(st, zx_t):
        st = _slstm_step(params, st, zx_t)
        return st, st["h"]

    state, hs = jax.lax.scan(body, state, zifo_x)         # (s, b, h, dh)
    y = hs.transpose(1, 0, 2, 3).reshape(b, s, d).astype(x.dtype)
    yf = y.astype(jnp.float32)
    y = (yf * jax.lax.rsqrt((yf ** 2).mean(-1, keepdims=True) + 1e-6)
         * params["norm_scale"]).astype(x.dtype)
    # post MLP (gated)
    hmid = jax.nn.silu(y @ params["w_mlp_gate"].astype(x.dtype)) * (
        y @ params["w_mlp_in"].astype(x.dtype))
    out = hmid @ params["w_mlp_out"].astype(x.dtype)
    return out, state


def init_slstm_cache(cfg, batch: int) -> dict:
    h = cfg.n_heads
    dh = cfg.d_model // h
    z = jnp.zeros((batch, h, dh), jnp.float32)
    return {"c": z, "n": z, "m": jnp.full((batch, h, dh), -1e9, jnp.float32),
            "h": z}
