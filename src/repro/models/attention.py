"""Attention: GQA/MQA/MHA, global & sliding-window, self & cross, with
KV caches (append cache for global, ring buffer for windowed layers).

Numerics: logits accumulate in fp32, softmax in fp32, values in bf16.
Prefill uses a q-chunked attention (bounded score memory, no O(S^2) buffer);
train uses the plain masked form (remat at the layer level bounds its
footprint at 4k tokens); decode reads the whole cache with one query.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.sharding import ctx as shctx

from .flash import flash_attention
from .layers import COMPUTE_DTYPE, PARAM_DTYPE, apply_rope, dense_init, rms_head_norm

NEG_INF = -1e30

# Train-path attention switches to the flash custom-VJP (models/flash.py)
# above this many score elements: neither fwd nor bwd materializes the
# (sq, sk) buffer, which dominated HBM for the b_local=1 DP-layout train
# cells (yi/gemma3/qwen2-vl — EXPERIMENTS.md §Perf).  Small shapes (all unit
# tests) keep the exact materializing path.
FLASH_MIN_ELEMS = 2 ** 28


# ----------------------------------------------------------------- params
def init_attention(cfg, key, *, cross: bool = False) -> dict:
    d, h, m, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], (d, h, hd)),
        "wk": dense_init(ks[1], (d, m, hd)),
        "wv": dense_init(ks[2], (d, m, hd)),
        "wo": dense_init(ks[3], (h, hd, d), scale=(h * hd) ** -0.5),
    }
    if cfg.qk_norm and not cross:
        p["q_scale"] = jnp.ones((hd,), PARAM_DTYPE)
        p["k_scale"] = jnp.ones((hd,), PARAM_DTYPE)
    return p


# -------------------------------------------------------------- projections
def project_q(cfg, params, x, cos, sin):
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"].astype(x.dtype))
    if "q_scale" in params:
        q = rms_head_norm(q, params["q_scale"], cfg.norm_eps)
    if cos is not None:
        q = apply_rope(q, cos, sin)
    return q


def project_kv(cfg, params, x, cos, sin):
    k = jnp.einsum("bsd,dmk->bsmk", x, params["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dmk->bsmk", x, params["wv"].astype(x.dtype))
    if "k_scale" in params:
        k = rms_head_norm(k, params["k_scale"], cfg.norm_eps)
    if cos is not None:
        k = apply_rope(k, cos, sin)
    return k, v


def out_proj(params, o):
    return jnp.einsum("bshk,hkd->bsd", o, params["wo"].astype(o.dtype))


# ---------------------------------------------------------------- core math
def _mask_bias(q_pos, k_pos, *, causal: bool, window: Optional[int],
               k_valid=None) -> jnp.ndarray:
    """(b, sq, sk) additive bias from absolute positions."""
    ok = jnp.ones(q_pos.shape[:1] + (q_pos.shape[1], k_pos.shape[1]), bool)
    d = q_pos[:, :, None] - k_pos[:, None, :]
    if causal:
        ok &= d >= 0
    if window is not None:
        ok &= d < window
    if k_valid is not None:
        ok &= k_valid[:, None, :]
    return jnp.where(ok, 0.0, NEG_INF).astype(jnp.float32)


def _sdpa(q, k, v, bias):
    """q: (b,sq,h,hd)  k/v: (b,sk,m,hd)  bias: (b,sq,sk) -> (b,sq,h,hd).

    GQA via repeat-kv: k/v are broadcast from m to h heads so every einsum
    keeps the cleanly-sharded `h` axis (no (m, g) reshape across the model
    axis — that reshape forces involuntary resharding under GSPMD).  XLA
    fuses the broadcast into the dots, so no real memory is spent.
    """
    b, sq, h, hd = q.shape
    m = k.shape[2]
    if m != h:
        g = h // m
        k = jnp.repeat(k, g, axis=2)
        v = jnp.repeat(v, g, axis=2)
    q = shctx.constrain(q, "attn_q")          # seq-parallel hint (policy-driven)
    # NOTE (§Perf, refuted hypothesis): storing scores/probs in bf16 was
    # tried to cut the f32 buffers; the manual-softmax backward materialized
    # MORE intermediates under the HBM proxy (yi M: 14.5 -> 15.4 s) and was
    # reverted.  The real lever is a flash-style custom-vjp (never
    # materialize (s, t) buffers) — see attention "flash" path.
    logits = jnp.einsum("bshk,bthk->bhst", q, k).astype(jnp.float32)
    logits = shctx.constrain(logits, "attn_scores")
    logits = logits * (hd ** -0.5) + bias[:, None, :, :]
    probs = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
    o = jnp.einsum("bhst,bthk->bshk", probs, v)
    return shctx.constrain(o, "attn_out")


def attention(cfg, q, k, v, *, q_pos, k_pos, causal=True, window=None,
              k_valid=None, q_chunk: Optional[int] = None):
    """Masked GQA attention.  If q_chunk is set, scan over query chunks
    (prefill path: bounds live score memory to (b, h, q_chunk, sk))."""
    b, sq, h, hd = q.shape
    sk = k.shape[1]
    if (k_valid is None and q_chunk is None
            and b * h * sq * sk >= FLASH_MIN_ELEMS and sq > 1):
        m = k.shape[2]
        if m != h:
            k = jnp.repeat(k, h // m, axis=2)
            v = jnp.repeat(v, h // m, axis=2)
        return flash_attention(q, k, v, q_pos, k_pos, causal, window, 1024)
    if q_chunk is None or q.shape[1] <= q_chunk:
        return _sdpa(q, k, v, _mask_bias(q_pos, k_pos, causal=causal,
                                         window=window, k_valid=k_valid))
    b, sq, h, hd = q.shape
    assert sq % q_chunk == 0, (sq, q_chunk)
    nc = sq // q_chunk
    qc = q.reshape(b, nc, q_chunk, h, hd).transpose(1, 0, 2, 3, 4)
    pc = q_pos.reshape(b, nc, q_chunk).transpose(1, 0, 2)

    def one(args):
        qi, pi = args
        bias = _mask_bias(pi, k_pos, causal=causal, window=window, k_valid=k_valid)
        return _sdpa(qi, k, v, bias)

    oc = jax.lax.map(one, (qc, pc))
    return oc.transpose(1, 0, 2, 3, 4).reshape(b, sq, h, hd)


# -------------------------------------------------------------------- caches
def init_global_cache(cfg, batch: int, max_len: int) -> dict:
    m, hd = cfg.n_kv_heads, cfg.head_dim
    return {
        "k": jnp.zeros((batch, max_len, m, hd), COMPUTE_DTYPE),
        "v": jnp.zeros((batch, max_len, m, hd), COMPUTE_DTYPE),
    }


def init_window_cache(cfg, batch: int) -> dict:
    m, hd, w = cfg.n_kv_heads, cfg.head_dim, cfg.window_size
    return {
        "k": jnp.zeros((batch, w, m, hd), COMPUTE_DTYPE),
        "v": jnp.zeros((batch, w, m, hd), COMPUTE_DTYPE),
    }


def global_cache_update(cache: dict, k_new, v_new, pos) -> dict:
    """Write s_new entries at [pos, pos+s_new) (scalar traced pos)."""
    k = jax.lax.dynamic_update_slice(cache["k"], k_new.astype(cache["k"].dtype),
                                     (0, pos, 0, 0))
    v = jax.lax.dynamic_update_slice(cache["v"], v_new.astype(cache["v"].dtype),
                                     (0, pos, 0, 0))
    return {"k": k, "v": v}


def window_cache_update(cache: dict, k_new, v_new, pos) -> dict:
    """Ring-buffer write of ONE token at slot pos % W (decode path)."""
    w = cache["k"].shape[1]
    slot = jax.lax.rem(pos, w)
    k = jax.lax.dynamic_update_slice(cache["k"], k_new.astype(cache["k"].dtype),
                                     (0, slot, 0, 0))
    v = jax.lax.dynamic_update_slice(cache["v"], v_new.astype(cache["v"].dtype),
                                     (0, slot, 0, 0))
    return {"k": k, "v": v}


def window_slot_positions(pos, w: int) -> jnp.ndarray:
    """Absolute position of the latest write in each ring slot, given that the
    token at `pos` has just been written: slot s holds position
    pos - ((pos - s) mod W); slots never written are masked by the caller
    via position > pos or < 0 checks."""
    s = jnp.arange(w, dtype=jnp.int32)
    return pos - jnp.mod(pos - s, w)   # jnp.mod is non-negative for w > 0


def prefill_to_window_cache(cfg, k_full, v_full, seq_len: int) -> dict:
    """Convert full-length prefill K/V into the ring buffer holding the last W
    positions, laid out so slot s holds absolute position p with p % W == s."""
    w = cfg.window_size
    b, s, m, hd = k_full.shape
    if s < w:
        pad = w - s
        k = jnp.concatenate([k_full, jnp.zeros((b, pad, m, hd), k_full.dtype)], 1)
        v = jnp.concatenate([v_full, jnp.zeros((b, pad, m, hd), v_full.dtype)], 1)
        return {"k": k, "v": v}
    last_k = k_full[:, s - w:, :, :]
    last_v = v_full[:, s - w:, :, :]
    # absolute positions s-w .. s-1 ; slot of position p is p % W
    roll = (s - w) % w
    return {"k": jnp.roll(last_k, roll, axis=1), "v": jnp.roll(last_v, roll, axis=1)}
