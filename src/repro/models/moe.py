"""Mixture-of-Experts FFN: top-k token-choice routing with per-chunk capacity
(GShard-style einsum dispatch), expert-parallel over the `model` mesh axis,
optional parallel dense-residual branch (Arctic).

Memory note: the dispatch one-hot is (b, chunk, E, C); chunking the sequence
bounds it to tens of MB at production shapes while keeping the einsum
formulation GSPMD-friendly (experts shard on `model`, tokens on `data`;
no explicit all-to-all is needed because activations are replicated across
the model axis under our TP layout).

Load-balancing aux loss follows Switch (mean fraction * mean prob per expert).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .ffn import init_ffn, apply_ffn, is_gated
from .layers import dense_init


def init_moe(cfg, key) -> dict:
    d, e, ff = cfg.d_model, cfg.n_experts, cfg.moe_dff
    ks = jax.random.split(key, 5)
    p = {
        "router": dense_init(ks[0], (d, e), scale=d ** -0.5),
        "w_in": dense_init(ks[1], (e, d, ff)),
        "w_out": dense_init(ks[2], (e, ff, d)),
    }
    if is_gated(cfg.act):
        p["w_gate"] = dense_init(ks[3], (e, d, ff))
    if cfg.dense_residual:
        p["residual"] = init_ffn(cfg, ks[4], d_ff=cfg.d_ff)
    return p


def _capacity(chunk: int, cfg) -> int:
    c = int(chunk * cfg.n_experts_per_token / cfg.n_experts * cfg.capacity_factor)
    return max(1, min(chunk, c))


def _moe_chunk(cfg, params, x):
    """x: (b, t, d) one sequence chunk -> (out, aux_loss_terms)."""
    b, t, d = x.shape
    e, topk = cfg.n_experts, cfg.n_experts_per_token
    cap = _capacity(t, cfg)

    logits = (x @ params["router"].astype(x.dtype)).astype(jnp.float32)  # (b,t,e)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, topk)                     # (b,t,k)
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)

    # position of each (token, choice) within its expert queue
    onehot = jax.nn.one_hot(gate_idx, e, dtype=jnp.float32)              # (b,t,k,e)
    flat = onehot.reshape(b, t * topk, e)
    pos_in_expert = (jnp.cumsum(flat, axis=1) - flat).reshape(b, t, topk, e)
    pos_in_expert = (pos_in_expert * onehot).sum(-1)                     # (b,t,k)
    keep = pos_in_expert < cap
    # dispatch (b,t,e,cap) / combine weights via capacity-slot one-hot
    slot_oh = jax.nn.one_hot(pos_in_expert.astype(jnp.int32), cap,
                             dtype=jnp.float32)                          # (b,t,k,cap)
    disp = jnp.einsum("btke,btkc,btk->btec", onehot, slot_oh,
                      keep.astype(jnp.float32))                          # (b,t,e,cap)
    comb = jnp.einsum("btec,btke,btk->btec", disp, onehot,
                      gate_vals * keep.astype(jnp.float32))

    xe = jnp.einsum("btec,btd->becd", disp.astype(x.dtype), x)           # (b,e,cap,d)
    h = jnp.einsum("becd,edf->becf", xe, params["w_in"].astype(x.dtype))
    if "w_gate" in params:
        g = jnp.einsum("becd,edf->becf", xe, params["w_gate"].astype(x.dtype))
        act = jax.nn.silu if cfg.act == "silu" else jax.nn.gelu
        h = act(g) * h
    else:
        h = jax.nn.gelu(h)
    ye = jnp.einsum("becf,efd->becd", h, params["w_out"].astype(x.dtype))
    out = jnp.einsum("btec,becd->btd", comb.astype(x.dtype), ye)

    # Switch aux loss terms for this chunk
    me = jnp.mean(onehot.sum(2), axis=(0, 1))        # fraction routed per expert
    ce = jnp.mean(probs, axis=(0, 1))                # mean router prob per expert
    aux = jnp.sum(me * ce) * e / topk
    return out, aux


def apply_moe(cfg, params, x):
    """x: (b, s, d) -> (out, aux_loss).  Sequence is chunked for dispatch
    memory; capacity is enforced per chunk."""
    b, s, d = x.shape
    chunk = min(cfg.moe_chunk, s)
    if s % chunk == 0 and s // chunk > 1:
        nc = s // chunk
        xs = x.reshape(b, nc, chunk, d).transpose(1, 0, 2, 3)

        def body(carry, xi):
            o, a = _moe_chunk(cfg, params, xi)
            return carry + a, o

        aux_sum, os_ = jax.lax.scan(body, jnp.zeros((), jnp.float32), xs)
        out = os_.transpose(1, 0, 2, 3).reshape(b, s, d)
        aux = aux_sum / (s // chunk)
    else:
        out, aux = _moe_chunk(cfg, params, x)
    if "residual" in params:
        out = out + apply_ffn(cfg, params["residual"], x)
    return out, aux
