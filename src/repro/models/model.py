"""Model: embeddings + stack(s) + chunked-loss head.  Public API:

    model = Model(cfg)
    params = model.init(key)                       (eval_shape-able)
    loss, aux = model.loss(params, batch)
    logits, cache = model.prefill(params, batch)
    logits, cache = model.decode_step(params, cache, tokens, pos)

Batch dict keys (see repro.data.pipeline / repro.launch.dryrun.input_specs):
    tokens (b, s) int32          — or inputs_embeds (b, s, d) for [audio]/[vlm]
    labels (b, s) int32          — train only
    positions (b, s) int32       — or (3, b, s) for M-RoPE
    enc_embeds (b, enc_seq, d)   — encoder-decoder only (stub frontend output)
"""
from __future__ import annotations

import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp

from . import transformer as tfm
from .layers import COMPUTE_DTYPE, PARAM_DTYPE, apply_norm, embed_init, init_norm, positions_to_angles

Params = Any


class Model:
    def __init__(self, cfg):
        self.cfg = cfg

    # ------------------------------------------------------------- params
    def init(self, key) -> Params:
        cfg = self.cfg
        ks = jax.random.split(key, 5)
        params: dict = {
            "embed": embed_init(ks[0], (cfg.vocab_size, cfg.d_model)),
            "final_norm": init_norm(cfg, cfg.d_model),
            "stack": tfm.init_stack(cfg, ks[1], decoder=cfg.is_encoder_decoder),
        }
        if not cfg.tie_embeddings:
            params["lm_head"] = embed_init(ks[2], (cfg.d_model, cfg.vocab_size))
        if cfg.is_encoder_decoder:
            enc_cfg = self._encoder_cfg()
            params["encoder"] = {
                "stack": tfm.init_stack(enc_cfg, ks[3]),
                "final_norm": init_norm(enc_cfg, enc_cfg.d_model),
            }
        if cfg.param_dtype != "float32":
            dt = jnp.dtype(cfg.param_dtype)
            params = jax.tree_util.tree_map(lambda x: x.astype(dt), params)
        return params

    def _encoder_cfg(self):
        import dataclasses
        cfg = self.cfg
        return dataclasses.replace(
            cfg, n_layers=cfg.encoder_layers, layer_pattern=("enc",),
            is_encoder_decoder=False)

    def head(self, params):
        if self.cfg.tie_embeddings:
            return params["embed"].T
        return params["lm_head"]

    # -------------------------------------------------------------- embed
    def _embed_inputs(self, params, batch) -> jnp.ndarray:
        if "inputs_embeds" in batch:
            return batch["inputs_embeds"].astype(COMPUTE_DTYPE)
        tok = batch["tokens"]
        return params["embed"].astype(COMPUTE_DTYPE)[tok]

    def _encode(self, params, batch) -> Optional[jnp.ndarray]:
        if not self.cfg.is_encoder_decoder:
            return None
        enc_cfg = self._encoder_cfg()
        x = batch["enc_embeds"].astype(COMPUTE_DTYPE)
        b, s, _ = x.shape
        pos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
        cos, sin = positions_to_angles(enc_cfg, pos)
        ctx = tfm.Ctx(mode="train", cos=cos, sin=sin, q_pos=pos, pos=None,
                      max_len=s)
        x, _, _ = tfm.apply_stack(enc_cfg, params["encoder"]["stack"], x, ctx,
                                  None, remat=False)
        return apply_norm(enc_cfg, params["encoder"]["final_norm"], x)

    # ------------------------------------------------------------ forward
    def _positions(self, batch) -> jnp.ndarray:
        if "positions" in batch:
            return batch["positions"]
        if "inputs_embeds" in batch:
            b, s, _ = batch["inputs_embeds"].shape
        else:
            b, s = batch["tokens"].shape
        return jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))

    def forward(self, params, batch, mode: str, cache=None, *, pos=None,
                max_len: int = 0, q_chunk: Optional[int] = None,
                remat: bool = True):
        cfg = self.cfg
        x = self._embed_inputs(params, batch)
        positions = self._positions(batch)
        # masks use the temporal stream when M-RoPE supplies (t, h, w) streams
        rope_pos = positions[0] if positions.ndim == 3 else positions   # (b,s)
        cos, sin = positions_to_angles(cfg, positions)
        enc_out = (self._encode(params, batch)
                   if cfg.is_encoder_decoder and mode != "decode" else None)
        ctx = tfm.Ctx(mode=mode, cos=cos, sin=sin, q_pos=rope_pos, pos=pos,
                      max_len=max_len, enc_out=enc_out, q_chunk=q_chunk)
        x, cache, aux = tfm.apply_stack(cfg, params["stack"], x, ctx, cache,
                                        decoder=cfg.is_encoder_decoder,
                                        remat=remat)
        x = apply_norm(cfg, params["final_norm"], x)
        return x, cache, aux

    # --------------------------------------------------------------- loss
    def loss(self, params, batch, *, remat: bool = True):
        """Mean next-token cross entropy, vocab-sharded chunked over seq."""
        cfg = self.cfg
        h, _, aux = self.forward(params, batch, "train", remat=remat)
        labels = batch["labels"]
        head = self.head(params).astype(COMPUTE_DTYPE)
        b, s, d = h.shape
        chunk = min(cfg.loss_chunk, s)
        if s % chunk:
            chunk = s
        nc = s // chunk
        hs = h.reshape(b, nc, chunk, d).swapaxes(0, 1)
        ys = labels.reshape(b, nc, chunk).swapaxes(0, 1)

        def body(tot, xs):
            hi, yi = xs
            logits = (hi @ head).astype(jnp.float32)
            if cfg.logit_softcap:
                logits = cfg.logit_softcap * jnp.tanh(logits / cfg.logit_softcap)
            lse = jax.nn.logsumexp(logits, axis=-1)
            ll = jnp.take_along_axis(logits, yi[..., None], axis=-1)[..., 0]
            return tot + jnp.sum(lse - ll), None

        total, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32), (hs, ys))
        loss = total / (b * s)
        return loss + 0.01 * aux, {"xent": loss, "aux": aux}

    # ------------------------------------------------------------ serving
    def init_cache(self, batch: int, max_len: int):
        return tfm.init_stack_cache(self.cfg, batch, max_len,
                                    decoder=self.cfg.is_encoder_decoder)

    def prefill(self, params, batch, *, max_len: int = 0,
                q_chunk: Optional[int] = 1024):
        """Run the prompt, return (last-position logits, filled cache)."""
        cfg = self.cfg
        if "inputs_embeds" in batch:
            b, s = batch["inputs_embeds"].shape[:2]
        else:
            b, s = batch["tokens"].shape
        max_len = max(max_len, s)
        cache = self.init_cache(b, max_len)
        h, cache, _ = self.forward(params, batch, "prefill", cache,
                                   max_len=max_len, q_chunk=q_chunk,
                                   remat=False)
        logits = (h[:, -1:] @ self.head(params).astype(h.dtype)).astype(jnp.float32)
        return logits, cache

    def decode_step(self, params, cache, tokens, pos, *, max_len: int):
        """tokens: (b, 1) int32; pos: scalar int32 — absolute position of the
        incoming token.  Returns (logits (b,1,V), new cache)."""
        b = tokens.shape[0]
        positions = jnp.broadcast_to(
            jnp.asarray(pos, jnp.int32)[None, None], (b, 1))
        batch = {"tokens": tokens, "positions": positions}
        h, cache, _ = self.forward(params, batch, "decode", cache, pos=pos,
                                   max_len=max_len, remat=False)
        logits = (h @ self.head(params).astype(h.dtype)).astype(jnp.float32)
        return logits, cache
