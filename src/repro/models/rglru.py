"""Griffin recurrent block (RecurrentGemma): temporal conv1d + RG-LRU.

RG-LRU (arXiv:2402.19427 eq. 1-4):
    r_t = sigmoid(W_a x_t)                   (recurrence gate)
    i_t = sigmoid(W_x x_t)                   (input gate)
    a_t = exp(-c * softplus(Lambda) * r_t)   (c = 8)
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

Training/prefill uses an associative scan over the sequence (log-depth on
TPU); decode carries (conv_state, h) in the cache.  The block wraps the LRU
with the Griffin gated-linear-unit structure:  out = W_out( GELU(W_gate x) *
LRU(conv1d(W_branch x)) ).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .layers import COMPUTE_DTYPE, PARAM_DTYPE, dense_init

_C = 8.0


def init_rglru_block(cfg, key) -> dict:
    d, w = cfg.d_model, cfg.rnn_width
    ks = jax.random.split(key, 7)
    # Lambda init so that a^c in [0.9, 0.999] (griffin appendix)
    u = jax.random.uniform(ks[0], (w,), PARAM_DTYPE, 0.9, 0.999)
    lam = jnp.log(jnp.expm1(-jnp.log(u) / _C))     # softplus^-1(-log(u)/c)
    return {
        "w_branch": dense_init(ks[1], (d, w)),
        "w_gate": dense_init(ks[2], (d, w)),
        "conv_w": dense_init(ks[3], (cfg.conv_width, w), scale=cfg.conv_width ** -0.5),
        "conv_b": jnp.zeros((w,), PARAM_DTYPE),
        "wa": dense_init(ks[4], (w, w)),
        "wx": dense_init(ks[5], (w, w)),
        "lam": lam,
        "w_out": dense_init(ks[6], (w, d)),
    }


def _causal_conv(params, x, state=None):
    """Depthwise causal conv1d, width cw.  x: (b, s, w).
    state: (b, cw-1, w) prior context (decode) or None (train: zero pad)."""
    cw = params["conv_w"].shape[0]
    wt = params["conv_w"].astype(x.dtype)
    if state is None:
        pad = jnp.zeros((x.shape[0], cw - 1, x.shape[2]), x.dtype)
    else:
        pad = state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)              # (b, s+cw-1, w)
    out = sum(xp[:, i:i + x.shape[1], :] * wt[i] for i in range(cw))
    new_state = xp[:, xp.shape[1] - (cw - 1):, :]
    return out + params["conv_b"].astype(x.dtype), new_state


def _rg_lru_gates(params, x):
    xf = x.astype(jnp.float32)
    r = jax.nn.sigmoid(xf @ params["wa"].astype(jnp.float32))
    i = jax.nn.sigmoid(xf @ params["wx"].astype(jnp.float32))
    log_a = -_C * jax.nn.softplus(params["lam"]) * r          # (b, s, w)
    a = jnp.exp(log_a)
    gated_x = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * (i * xf)
    return a, gated_x


def _lru_scan(a, gx, h0=None):
    """h_t = a_t h_{t-1} + gx_t via associative scan over the seq axis.
    a, gx: (b, s, w) fp32; h0: (b, w) initial state or None."""
    if h0 is not None:
        gx = gx.at[:, 0, :].add(a[:, 0, :] * h0)

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, a2 * b1 + b2

    _, h = jax.lax.associative_scan(combine, (a, gx), axis=1)
    return h                                              # (b, s, w)


def apply_rglru_block(cfg, params, x, *, cache=None, pos=None):
    """x: (b, s, d).  Returns (out, new_cache).

    Train/prefill: cache=None -> associative scan from zero state; the
    returned cache carries (conv_state, h_last) for decode handoff.
    Decode: cache={"conv": (b,cw-1,w), "h": (b,w)}; s may be 1.
    """
    gate = jax.nn.gelu(x @ params["w_gate"].astype(x.dtype))
    branch = x @ params["w_branch"].astype(x.dtype)
    conv_state = None if cache is None else cache["conv"]
    branch, new_conv = _causal_conv(params, branch, conv_state)
    a, gx = _rg_lru_gates(params, branch)
    h0 = None if cache is None else cache["h"].astype(jnp.float32)
    h = _lru_scan(a, gx, h0)
    new_cache = {"conv": new_conv.astype(COMPUTE_DTYPE),
                 "h": h[:, -1, :].astype(jnp.float32)}
    out = (gate * h.astype(x.dtype)) @ params["w_out"].astype(x.dtype)
    return out, new_cache


def init_rglru_cache(cfg, batch: int) -> dict:
    w = cfg.rnn_width
    return {"conv": jnp.zeros((batch, cfg.conv_width - 1, w), COMPUTE_DTYPE),
            "h": jnp.zeros((batch, w), jnp.float32)}
