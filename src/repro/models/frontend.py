"""Modality frontend STUBS (assignment: "[audio]/[vlm] entries specify the
transformer BACKBONE only; the modality frontend is a STUB — input_specs()
provides precomputed frame/patch embeddings").

These helpers define the stand-in embedding shapes and a deterministic
synthetic generator for smoke tests / examples.  A real deployment would
replace them with the conv feature extractor (whisper) or the dynamic-
resolution ViT (qwen2-vl).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def audio_frame_embeddings_shape(cfg, batch: int) -> tuple[int, int, int]:
    """Whisper: 30 s of audio -> cfg.encoder_seq log-mel frame embeddings."""
    return (batch, cfg.encoder_seq, cfg.d_model)


def vision_patch_embeddings_shape(cfg, batch: int, seq: int) -> tuple[int, int, int]:
    """Qwen2-VL: dynamic-resolution patches + text, already merged to one
    stream of `seq` embeddings."""
    return (batch, seq, cfg.d_model)


def synth_embeddings(key, shape, dtype=jnp.bfloat16) -> jnp.ndarray:
    return jax.random.normal(key, shape, jnp.float32).astype(dtype) * 0.02


def mrope_positions(batch: int, seq: int, *, image_tokens: int = 0,
                    grid_hw: tuple[int, int] = (0, 0)) -> np.ndarray:
    """Qwen2-VL M-RoPE position streams (3, b, s): vision tokens get (t, h, w)
    grid coordinates, text tokens advance all three streams together."""
    t = np.zeros((3, seq), dtype=np.int32)
    if image_tokens:
        gh, gw = grid_hw
        assert gh * gw == image_tokens
        hh, ww = np.meshgrid(np.arange(gh), np.arange(gw), indexing="ij")
        t[0, :image_tokens] = 0
        t[1, :image_tokens] = hh.reshape(-1)
        t[2, :image_tokens] = ww.reshape(-1)
        base = max(gh, gw)
    else:
        base = 0
    text = np.arange(seq - image_tokens, dtype=np.int32) + base
    t[:, image_tokens:] = text[None]
    return np.broadcast_to(t[:, None, :], (3, batch, seq)).copy()
