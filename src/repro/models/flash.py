"""Flash-style attention with a custom VJP (pure jnp, TPU-fusable).

Neither forward nor backward ever materializes the (sq, sk) score matrix:
the forward streams KV chunks with an online softmax saving only (o, lse);
the backward recomputes per-chunk probabilities from (q, k, lse) and
accumulates dq/dk/dv chunkwise.  This is the documented §Perf lever for the
train cells whose f32 score buffers exceeded HBM (yi-34b/gemma3/qwen2-vl at
b_local = 1).

Masking is positional (causal and/or sliding window + validity), matching
attention._mask_bias semantics.  GQA is handled by the caller (repeat-kv).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def _chunk_bias(q_pos, k_pos, causal, window):
    """(b, sq_c, sk_c) additive f32 bias from absolute positions."""
    d = q_pos[:, :, None] - k_pos[:, None, :]
    ok = jnp.ones(d.shape, bool)
    if causal:
        ok &= d >= 0
    if window is not None:
        ok &= d < window
    return jnp.where(ok, 0.0, NEG_INF)


def _split(x, nc, axis=1):
    """(b, s, ...) -> (nc, b, s/nc, ...) chunk-major."""
    b = x.shape[0]
    s = x.shape[axis]
    shape = x.shape[:axis] + (nc, s // nc) + x.shape[axis + 1:]
    return jnp.moveaxis(x.reshape(shape), axis, 0)


@functools.partial(jax.custom_vjp, nondiff_argnums=(5, 6, 7))
def flash_attention(q, k, v, q_pos, k_pos, causal=True, window=None,
                    kv_chunk=1024):
    """q: (b,sq,h,hd), k/v: (b,sk,h,hd) (same head count — repeat-kv before),
    q_pos: (b,sq), k_pos: (b,sk).  Returns (b,sq,h,hd) in q.dtype."""
    o, _ = _flash_fwd_inner(q, k, v, q_pos, k_pos, causal, window, kv_chunk)
    return o


def _flash_fwd_inner(q, k, v, q_pos, k_pos, causal, window, kv_chunk):
    b, sq, h, hd = q.shape
    sk = k.shape[1]
    nc = max(1, sk // min(kv_chunk, sk))
    assert sk % nc == 0, (sk, nc)
    scale = hd ** -0.5
    ks_, vs_ = _split(k, nc), _split(v, nc)
    kps = _split(k_pos, nc)

    qf = q.astype(jnp.float32)

    def body(carry, xs):
        m, l, acc = carry                       # (b,h,sq), (b,h,sq), (b,h,sq,hd)
        kc, vc, kpc = xs
        s = jnp.einsum("bqhd,bkhd->bhqk", qf, kc.astype(jnp.float32)) * scale
        s = s + _chunk_bias(q_pos, kpc, causal, window)[:, None, :, :]
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=-1)
        acc = (acc * corr[..., None]
               + jnp.einsum("bhqk,bkhd->bhqd", p, vc.astype(jnp.float32)))
        return (m_new, l_new, acc), None

    m0 = jnp.full((b, h, sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, h, sq), jnp.float32)
    a0 = jnp.zeros((b, h, sq, hd), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0), (ks_, vs_, kps))
    l_safe = jnp.maximum(l, 1e-30)
    o = (acc / l_safe[..., None]).swapaxes(1, 2)          # (b,sq,h,hd)
    lse = m + jnp.log(l_safe)                             # (b,h,sq)
    return o.astype(q.dtype), lse


def _fwd(q, k, v, q_pos, k_pos, causal, window, kv_chunk):
    o, lse = _flash_fwd_inner(q, k, v, q_pos, k_pos, causal, window, kv_chunk)
    return o, (q, k, v, q_pos, k_pos, o, lse)


def _bwd(causal, window, kv_chunk, res, do):
    q, k, v, q_pos, k_pos, o, lse = res
    b, sq, h, hd = q.shape
    sk = k.shape[1]
    nc = max(1, sk // min(kv_chunk, sk))
    scale = hd ** -0.5
    qf = q.astype(jnp.float32)
    dof = do.astype(jnp.float32)
    of = o.astype(jnp.float32)
    # delta_q = rowsum(do * o): (b,h,sq)
    delta = jnp.einsum("bqhd,bqhd->bhq", dof, of)
    ks_, vs_ = _split(k, nc), _split(v, nc)
    kps = _split(k_pos, nc)

    def body(dq_acc, xs):
        kc, vc, kpc = xs
        s = jnp.einsum("bqhd,bkhd->bhqk", qf, kc.astype(jnp.float32)) * scale
        s = s + _chunk_bias(q_pos, kpc, causal, window)[:, None, :, :]
        p = jnp.exp(s - lse[..., None])                    # (b,h,sq,kc)
        dv_c = jnp.einsum("bhqk,bqhd->bkhd", p, dof)
        dp = jnp.einsum("bqhd,bkhd->bhqk", dof, vc.astype(jnp.float32))
        ds = p * (dp - delta[..., None]) * scale
        dq_acc = dq_acc + jnp.einsum("bhqk,bkhd->bqhd", ds,
                                     kc.astype(jnp.float32))
        dk_c = jnp.einsum("bhqk,bqhd->bkhd", ds, qf)
        return dq_acc, (dk_c, dv_c)

    dq0 = jnp.zeros((b, sq, h, hd), jnp.float32)
    dq, (dks, dvs) = jax.lax.scan(body, dq0, (ks_, vs_, kps))
    dk = jnp.moveaxis(dks, 0, 1).reshape(b, sk, h, hd)
    dv = jnp.moveaxis(dvs, 0, 1).reshape(b, sk, h, hd)
    return (dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype),
            None, None)


flash_attention.defvjp(_fwd, _bwd)
