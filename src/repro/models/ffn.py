"""Dense FFN: SwiGLU (silu), GeGLU (geglu) or plain-GELU MLP (gelu)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .layers import dense_init


def is_gated(act: str) -> bool:
    return act in ("silu", "geglu")


def init_ffn(cfg, key, d_ff: int | None = None) -> dict:
    d = cfg.d_model
    ff = d_ff if d_ff is not None else cfg.d_ff
    ks = jax.random.split(key, 3)
    p = {"w_in": dense_init(ks[0], (d, ff)),
         "w_out": dense_init(ks[1], (ff, d))}
    if is_gated(cfg.act):
        p["w_gate"] = dense_init(ks[2], (d, ff))
    return p


def apply_ffn(cfg, params, x: jnp.ndarray) -> jnp.ndarray:
    h = x @ params["w_in"].astype(x.dtype)
    if is_gated(cfg.act):
        g = x @ params["w_gate"].astype(x.dtype)
        act = jax.nn.silu if cfg.act == "silu" else jax.nn.gelu
        h = act(g) * h
    else:
        h = jax.nn.gelu(h)
    return h @ params["w_out"].astype(x.dtype)
