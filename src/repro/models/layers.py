"""Shared layer primitives: norms, initializers, RoPE / M-RoPE, embeddings.

Compute dtype is bf16, parameters are stored fp32 (cast at use); all shapes
are chosen to shard cleanly under repro.sharding.policy.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

COMPUTE_DTYPE = jnp.bfloat16
PARAM_DTYPE = jnp.float32


def dense_init(key, shape, scale: float | None = None) -> jnp.ndarray:
    fan_in = shape[0] if len(shape) >= 2 else 1
    scale = scale if scale is not None else fan_in ** -0.5
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, PARAM_DTYPE) * scale)


def embed_init(key, shape) -> jnp.ndarray:
    # d_model^-0.5 keeps (tied-)head logits O(1) at init; d_model is the
    # smaller dim for both (vocab, d) embeddings and (d, vocab) heads
    scale = min(shape) ** -0.5 if len(shape) >= 2 else 0.02
    return jax.random.truncated_normal(key, -2.0, 2.0, shape, PARAM_DTYPE) * scale


# --------------------------------------------------------------------- norms
def init_norm(cfg, dim: int) -> dict:
    p = {"scale": jnp.ones((dim,), PARAM_DTYPE)}
    if cfg.norm == "layer":
        p["bias"] = jnp.zeros((dim,), PARAM_DTYPE)
    return p


def apply_norm(cfg, params: dict, x: jnp.ndarray) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    if cfg.norm == "layer":
        mu = xf.mean(-1, keepdims=True)
        var = ((xf - mu) ** 2).mean(-1, keepdims=True)
        out = (xf - mu) * jax.lax.rsqrt(var + cfg.norm_eps)
        out = out * params["scale"] + params["bias"]
    else:  # rmsnorm
        var = (xf ** 2).mean(-1, keepdims=True)
        out = xf * jax.lax.rsqrt(var + cfg.norm_eps) * params["scale"]
    return out.astype(x.dtype)


def rms_head_norm(x: jnp.ndarray, scale: jnp.ndarray, eps: float) -> jnp.ndarray:
    """qk-norm: RMS over the head dim."""
    xf = x.astype(jnp.float32)
    out = xf * jax.lax.rsqrt((xf ** 2).mean(-1, keepdims=True) + eps) * scale
    return out.astype(x.dtype)


# ---------------------------------------------------------------------- RoPE
def rope_angles(positions: jnp.ndarray, head_dim: int, theta: float) -> tuple[jnp.ndarray, jnp.ndarray]:
    """positions: (..., s) int32 -> cos/sin of shape (..., s, head_dim//2), fp32."""
    half = head_dim // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * freq
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jnp.ndarray, cos: jnp.ndarray, sin: jnp.ndarray) -> jnp.ndarray:
    """x: (b, s, h, hd); cos/sin: (b, s, hd//2) or (s, hd//2)."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    if cos.ndim == 2:
        cos, sin = cos[None, :, None, :], sin[None, :, None, :]
    else:
        cos, sin = cos[:, :, None, :], sin[:, :, None, :]
    cos = cos.astype(x.dtype)
    sin = sin.astype(x.dtype)
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)


def mrope_angles(positions: jnp.ndarray, head_dim: int, theta: float,
                 sections: tuple[int, int, int]) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Qwen2-VL M-RoPE: positions (3, b, s) for (t, h, w) streams; the rotary
    half-dim is split into `sections` (sum = head_dim//2), each section using
    its own position stream."""
    half = head_dim // 2
    assert sum(sections) == half, (sections, half)
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    # section id per frequency slot
    cos_parts, sin_parts = [], []
    start = 0
    for sec_id, width in enumerate(sections):
        f = freq[start:start + width]
        ang = positions[sec_id].astype(jnp.float32)[..., None] * f   # (b, s, width)
        cos_parts.append(jnp.cos(ang))
        sin_parts.append(jnp.sin(ang))
        start += width
    return jnp.concatenate(cos_parts, -1), jnp.concatenate(sin_parts, -1)


def positions_to_angles(cfg, positions: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """positions: (b, s) — or (3, b, s) when cfg.mrope_sections is set."""
    if cfg.mrope_sections is not None:
        if positions.ndim == 2:   # text-only stream: all three sections aligned
            positions = jnp.broadcast_to(positions[None], (3,) + positions.shape)
        return mrope_angles(positions, cfg.head_dim, cfg.rope_theta, cfg.mrope_sections)
    return rope_angles(positions, cfg.head_dim, cfg.rope_theta)


# ---------------------------------------------------------------- activations
def act_fn(name: str):
    return {"silu": jax.nn.silu, "gelu": jax.nn.gelu, "geglu": jax.nn.gelu}[name]
