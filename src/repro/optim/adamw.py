"""AdamW, pure-pytree (no optax dependency): init / update, bf16-safe.

Master weights and moments are fp32 regardless of compute dtype.  The update
is written as a single tree_map so GSPMD shards optimizer math exactly like
the parameters (ZeRO-free baseline; the sharding policy may additionally
shard moments over `data` — see repro.sharding.policy.zero1_specs).
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1
    moment_dtype: str = "float32"     # bf16 halves optimizer HBM (giants)


class OptState(NamedTuple):
    mu: Any
    nu: Any
    step: jnp.ndarray


def init(params, cfg: AdamWConfig | None = None) -> OptState:
    dt = jnp.dtype(cfg.moment_dtype) if cfg else jnp.float32
    zeros = lambda p: jnp.zeros(p.shape, dt)
    return OptState(mu=jax.tree_util.tree_map(zeros, params),
                    nu=jax.tree_util.tree_map(zeros, params),
                    step=jnp.zeros((), jnp.int32))


def schedule(cfg: AdamWConfig, step) -> jnp.ndarray:
    """Linear warmup + cosine decay to min_lr_ratio."""
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / max(cfg.warmup_steps, 1), 1.0)
    frac = jnp.clip((step - cfg.warmup_steps)
                    / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * frac))
    return cfg.lr * warm * (cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos)


def global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree_util.tree_leaves(tree)))


def update(cfg: AdamWConfig, grads, state: OptState, params):
    """Returns (new_params, new_state, metrics)."""
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
    step = state.step + 1
    lr = schedule(cfg, step)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    mdt = jnp.dtype(cfg.moment_dtype)

    def upd(g, m, v, p):
        g = g.astype(jnp.float32) * clip
        m = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * g
        v = cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * g * g
        mhat = m / b1c
        vhat = v / b2c
        step_ = mhat / (jnp.sqrt(vhat) + cfg.eps)
        p32 = p.astype(jnp.float32)
        p_new = p32 - lr * (step_ + cfg.weight_decay * p32)
        return p_new.astype(p.dtype), m.astype(mdt), v.astype(mdt)

    flat_p, tdef = jax.tree_util.tree_flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_m = tdef.flatten_up_to(state.mu)
    flat_v = tdef.flatten_up_to(state.nu)
    out = [upd(g, m, v, p) for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_m = tdef.unflatten([o[1] for o in out])
    new_v = tdef.unflatten([o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_p, OptState(mu=new_m, nu=new_v, step=step), metrics
