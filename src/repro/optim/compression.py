"""Gradient compression: int8 error-feedback quantization with a ring
reduce-scatter/all-gather over the data axis (shard_map).

Wire cost per gradient sync drops 4x (f32 -> int8 + one f32 scale per
tensor); the quantization error is carried in an error-feedback accumulator
so the *expected* update is unbiased (1-bit Adam / EF-SGD lineage).

Usage (train loop, optional):
    comp = Int8ErrorFeedback(params)
    grads, comp_state = comp.compress_sync(grads, comp_state, mesh, axis="data")
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

try:                                    # JAX >= 0.4.35 exports it at top level
    from jax import shard_map
except ImportError:                     # older JAX: experimental namespace
    from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P


def init_error_state(params: Any) -> Any:
    return jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)


def quantize(x: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Symmetric per-tensor int8: returns (q, scale)."""
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def ef_compress(g: jnp.ndarray, err: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Error-feedback step: quantize (g + err), carry the residual."""
    target = g.astype(jnp.float32) + err
    q, scale = quantize(target)
    new_err = target - dequantize(q, scale)
    return q, scale, new_err


def compress_tree(grads: Any, err_state: Any) -> tuple[Any, Any, Any]:
    flat_g, tdef = jax.tree_util.tree_flatten(grads)
    flat_e = tdef.flatten_up_to(err_state)
    qs, scales, errs = [], [], []
    for g, e in zip(flat_g, flat_e):
        q, s, ne = ef_compress(g, e)
        qs.append(q)
        scales.append(s)
        errs.append(ne)
    return (tdef.unflatten(qs), tdef.unflatten(scales), tdef.unflatten(errs))


def decompress_tree(qs: Any, scales: Any) -> Any:
    return jax.tree_util.tree_map(dequantize, qs, scales)


# -------------------------------------------------- int8 ring mean (shard_map)
def int8_ring_mean(x: jnp.ndarray, mesh: Mesh, axis: str) -> jnp.ndarray:
    """Mean of per-device gradients with int8 on the wire.

    x: (n, ...) — row i is device i's local gradient (sharded over `axis`).
    Ring reduce-scatter in int8 (each hop re-quantizes its partial sum — the
    standard ring-compression compromise) + int8 all-gather of the finished
    chunks.  Wire bytes: 2 * |x| * 1B vs 2 * |x| * 4B uncompressed.
    Returns (n, ...) with every row = the mean.

    Ring algebra: acc_i^(0) = x_i[chunk i]; each hop sends acc rightward and
    adds the receiver's own chunk (idx - t - 1); after n-1 hops device i holds
    the FULL sum of chunk (i+1) mod n, so gathered chunk c sits at device
    (c - 1) mod n.
    """
    n = mesh.shape[axis]
    if x.shape[0] != n:
        raise ValueError(f"leading dim {x.shape[0]} != axis {axis}={n}")

    def body(xl):
        xi = jnp.reshape(xl[0], (-1,))
        pad = (-xi.size) % n
        xi = jnp.pad(xi, (0, pad))
        chunks = xi.reshape(n, -1)
        idx = jax.lax.axis_index(axis)
        perm = [(j, (j + 1) % n) for j in range(n)]

        def hop(t, acc):
            q, s = quantize(acc)
            q = jax.lax.ppermute(q, axis, perm)
            s = jax.lax.ppermute(s, axis, perm)
            return dequantize(q, s) + chunks[jnp.mod(idx - t - 1, n)]

        acc = chunks[idx]
        if n > 1:
            acc = jax.lax.fori_loop(0, n - 1, hop, acc)
        own = acc / n                           # full mean of chunk (idx+1)%n
        q, s = quantize(own)
        qg = jax.lax.all_gather(q, axis)        # (n, chunk)
        sg = jax.lax.all_gather(s, axis)        # (n,)
        full = dequantize(qg, sg[:, None])
        order = jnp.mod(jnp.arange(n) - 1, n)   # chunk c at device (c-1)%n
        flat = jnp.reshape(full[order], (-1,))
        return jnp.reshape(flat[: xl[0].size], xl.shape)

    fn = shard_map(body, mesh=mesh, in_specs=P(axis), out_specs=P(axis))
    return fn(x)
