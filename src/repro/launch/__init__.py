# NOTE: dryrun is intentionally NOT imported here — importing it sets
# XLA_FLAGS for 512 host devices, which must only happen in the dry-run
# entry point itself.
from . import mesh, steps  # noqa: F401
