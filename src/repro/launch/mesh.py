"""Production meshes.  Defined as FUNCTIONS so importing this module never
touches jax device state (dry-run sets the 512-device flag first).

Single pod: 16 x 16 = 256 chips, axes (data, model).
Multi-pod:  2 x 16 x 16 = 512 chips, axes (pod, data, model); `pod` is an
outer data axis (DCN between pods, ICI within).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_storage_mesh(n_nodes: int):
    """1-D ring mesh for the MSR storage layer (circulant encode/repair runs
    neighbour-wise over this axis — DESIGN.md §2)."""
    return jax.make_mesh((n_nodes,), ("storage",))


def make_host_mesh():
    """Whatever this host offers (tests/examples): 1-D data mesh."""
    n = len(jax.devices())
    return jax.make_mesh((n,), ("data",))
