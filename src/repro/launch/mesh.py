"""Production meshes.  Defined as FUNCTIONS so importing this module never
touches jax device state (dry-run sets the 512-device flag first).

Single pod: 16 x 16 = 256 chips, axes (data, model).
Multi-pod:  2 x 16 x 16 = 512 chips, axes (pod, data, model); `pod` is an
outer data axis (DCN between pods, ICI within).

All constructors validate through :func:`checked_mesh` and raise the
typed ``repro.sharding.mesh.MeshConfigError`` (a ValueError) on bad
axis sizes or device-count mismatches, with a message naming the fix —
instead of whatever jax.make_mesh happens to throw.  The MSR storage
layer's 1-D stream mesh lives in ``repro.sharding.mesh.StreamMesh``
(DESIGN.md §14); these are the LM-launch meshes.
"""
from __future__ import annotations

import math

import jax

from repro.sharding.mesh import MeshConfigError


def checked_mesh(shape: tuple[int, ...], axes: tuple[str, ...]):
    """jax.make_mesh with typed validation: every axis size a positive
    int, one name per axis, and the total device product available."""
    if len(shape) != len(axes):
        raise MeshConfigError(
            f"mesh shape {shape} has {len(shape)} axes but {len(axes)} "
            f"names {axes}")
    if len(set(axes)) != len(axes):
        raise MeshConfigError(f"duplicate mesh axis names: {axes}")
    for size, name in zip(shape, axes):
        if isinstance(size, bool) or not isinstance(size, int) or size < 1:
            raise MeshConfigError(
                f"mesh axis {name!r} must have a positive int size, "
                f"got {size!r}")
    want = math.prod(shape)
    have = len(jax.devices())
    if want > have:
        raise MeshConfigError(
            f"mesh {dict(zip(axes, shape))} needs {want} devices but only "
            f"{have} are available; on CPU set XLA_FLAGS="
            f"--xla_force_host_platform_device_count={want} BEFORE the "
            f"first jax import")
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return checked_mesh(shape, axes)


def make_storage_mesh(n_nodes: int):
    """1-D ring mesh for the MSR storage layer (circulant encode/repair runs
    neighbour-wise over this axis — DESIGN.md §2)."""
    return checked_mesh((n_nodes,), ("storage",))


def make_host_mesh():
    """Whatever this host offers (tests/examples): 1-D data mesh."""
    n = len(jax.devices())
    return checked_mesh((n,), ("data",))
