import os
_N_DRYRUN_DEV = int(os.environ.get("REPRO_DRYRUN_DEVICES", "512"))
os.environ["XLA_FLAGS"] = (
    os.environ.get("XLA_FLAGS", "") +
    f" --xla_force_host_platform_device_count={_N_DRYRUN_DEV}").strip()
# ^ MUST precede any jax import: jax locks the device count on first init.
#   Set ONLY here — tests/benches see the host's single device.  The
#   REPRO_DRYRUN_DEVICES override exists for the tier-1 smoke cell
#   (tests/test_hlo_stats.py), which dry-runs a reduced config on a
#   small forced-device mesh instead of the 512-chip production mesh.

"""Multi-pod dry-run (deliverable e): for every (arch x shape x mesh) cell,
lower + compile the step function against ShapeDtypeStruct inputs on the
production mesh, record memory_analysis / cost_analysis / collective bytes.

    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-4b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--force]

Artifacts: benchmarks/dryrun_results/<arch>__<shape>__<mesh>.json (incremental:
already-computed cells are skipped unless --force).
"""
import argparse
import gzip
import json
import pathlib
import re
import time
import traceback

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import SHAPES, get_config
from repro.configs.registry import cells, skipped_cells
from repro.launch import hlo_stats
from repro.launch import steps as steps_mod
from repro.launch.mesh import make_production_mesh
from repro.models import Model
from repro.optim import adamw
from repro.sharding import ctx as shctx
from repro.sharding import policy

RESULTS_DIR = pathlib.Path(__file__).resolve().parents[3] / "benchmarks" / "dryrun_results"

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")


# ------------------------------------------------------- collective parser
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(\(?[a-z0-9\[\],{}/* ]+\)?)\s+[a-z][\w\-]*\(")


def _type_bytes(type_str: str) -> int:
    """Bytes of an HLO type string, incl. tuple types `(f32[2], bf16[4])`."""
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.groups()
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _build_symbol_table(hlo_text: str) -> dict[str, int]:
    """instruction name -> result bytes (operands print as bare %name)."""
    table: dict[str, int] = {}
    for line in hlo_text.splitlines():
        m = _DEF_RE.match(line)
        if m:
            table[m.group(1)] = _type_bytes(m.group(2))
    return table


def parse_collectives(hlo_text: str) -> dict:
    """Collective op counts + operand bytes from POST-SPMD optimized HLO.

    Collective bytes are per-program (per-device) operand sizes — the traffic
    each chip's links carry up to the collective's algorithmic factor, which
    roofline.py applies per op type.  Operands are resolved via a symbol
    table because optimized HLO prints them as bare `%name`.
    """
    table = _build_symbol_table(hlo_text)
    out = {c: {"count": 0, "bytes": 0, "result_bytes": 0} for c in _COLLECTIVES}
    for line in hlo_text.splitlines():
        for c in _COLLECTIVES:
            m = re.search(rf"=\s*([^=]+?)\s+{c}(?:-start)?\(([^)]*)\)", line)
            if not m:
                continue
            result_t, operands = m.groups()
            ob = sum(table.get(name, 0)
                     for name in re.findall(r"%([\w.\-]+)", operands))
            if ob == 0:  # operands with inline types (older printers)
                ob = _type_bytes(operands)
            out[c]["count"] += 1
            out[c]["bytes"] += ob
            out[c]["result_bytes"] += _type_bytes(result_t)
            break
    out["total_bytes"] = sum(v["bytes"] for v in out.values()
                             if isinstance(v, dict))
    out["total_count"] = sum(v["count"] for v in out.values()
                             if isinstance(v, dict))
    return out


# --------------------------------------------------------------- lowering
def lower_cell(arch: str, shape_name: str, *, multi_pod: bool = False,
               policy_overrides: dict | None = None,
               save_hlo: pathlib.Path | None = None,
               cfg=None, shape=None, mesh=None,
               mesh_name: str | None = None) -> dict:
    """One (arch x shape x mesh) cell.  The cfg/shape/mesh overrides let
    the tier-1 smoke test lower a REDUCED config on a small forced-device
    mesh end-to-end (same artifact schema, same invariants) without the
    256/512-chip production mesh."""
    cfg = cfg if cfg is not None else get_config(arch)
    shape = shape if shape is not None else SHAPES[shape_name]
    mesh = mesh if mesh is not None else make_production_mesh(
        multi_pod=multi_pod)
    n_devices = int(np.prod(list(mesh.shape.values())))
    if mesh_name is None:
        mesh_name = ("pod2x16x16" if multi_pod else "pod16x16") \
            if n_devices in (256, 512) else \
            "mesh" + "x".join(str(mesh.shape[a]) for a in mesh.shape)
    model = Model(cfg)
    t0 = time.time()

    params_shapes = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
    layout = policy.choose_layout(cfg, mesh, shape)
    pspecs = policy.param_specs(params_shapes, mesh, layout=layout)
    n_params_total = steps_mod.count_params(params_shapes)
    # >100B: bf16 moments (fp32 AdamW state would exceed pod HBM — DESIGN.md)
    opt_cfg = adamw.AdamWConfig(
        moment_dtype="bfloat16" if n_params_total > 100e9 else "float32")
    act_rules = policy.activation_rules(cfg, mesh, shape.kind, layout=layout)

    with mesh, shctx.rules(mesh, act_rules):
        if shape.kind == "train":
            state_shapes = {"params": params_shapes,
                            "opt": jax.eval_shape(lambda: adamw.init(params_shapes, opt_cfg))}
            state_sh = {"params": pspecs,
                        "opt": adamw.OptState(mu=pspecs, nu=pspecs,
                                              step=policy.P())}
            batch_shapes = steps_mod.input_specs(cfg, shape)
            bspecs = policy.batch_spec(batch_shapes, mesh,
                                       global_batch=shape.global_batch,
                                       layout=layout)
            n_dev = n_devices
            model_ax = mesh.shape.get("model", 1)
            n_shards = n_dev if layout == "dp" else n_dev // model_ax
            n_micro = steps_mod.pick_microbatches(shape, n_shards)
            fn = steps_mod.make_train_step(model, opt_cfg, n_micro)
            lowered = jax.jit(
                fn,
                in_shardings=(policy.named(state_sh, mesh),
                              policy.named(bspecs, mesh)),
                donate_argnums=(0,),
            ).lower(state_shapes, batch_shapes)
        elif shape.kind == "prefill":
            batch_shapes = steps_mod.input_specs(cfg, shape, labels=False)
            bspecs = policy.batch_spec(batch_shapes, mesh,
                                       global_batch=shape.global_batch)
            fn = steps_mod.make_prefill_step(model, max_len=shape.seq_len)
            lowered = jax.jit(
                fn,
                in_shardings=(policy.named(pspecs, mesh),
                              policy.named(bspecs, mesh)),
            ).lower(params_shapes, batch_shapes)
        else:  # decode
            cache_shapes, tok, pos = steps_mod.decode_input_specs(cfg, shape, model)
            cspecs = policy.cache_spec(cache_shapes, mesh,
                                       batch=shape.global_batch,
                                       seq_shard=shape.global_batch == 1)
            tspec = policy.batch_spec({"tokens": tok}, mesh,
                                      global_batch=shape.global_batch)["tokens"]
            fn = steps_mod.make_decode_step(model, max_len=shape.seq_len)
            lowered = jax.jit(
                fn,
                in_shardings=(policy.named(pspecs, mesh),
                              policy.named(cspecs, mesh),
                              policy.named(tspec, mesh),
                              policy.named(policy.P(), mesh)),
                donate_argnums=(1,),
            ).lower(params_shapes, cache_shapes, tok, pos)

        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):     # older jax: one dict per device
        cost = cost[0] if cost else {}
    hlo_text = compiled.as_text()
    if save_hlo is not None:
        save_hlo.parent.mkdir(parents=True, exist_ok=True)
        with gzip.open(save_hlo, "wt") as fh:
            fh.write(hlo_text)
    coll = parse_collectives(hlo_text)
    dyn = hlo_stats.analyze(hlo_text)   # trip-count-aware (see hlo_stats.py)

    n_params = steps_mod.count_params(params_shapes)
    n_active = steps_mod.count_active_params(cfg, params_shapes)
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode" else 1)

    result = {
        "arch": arch, "shape": shape_name,
        "mesh": mesh_name,
        "n_devices": n_devices,
        "kind": shape.kind,
        "seq_len": shape.seq_len, "global_batch": shape.global_batch,
        "n_params": n_params, "n_active_params": n_active,
        "tokens_per_step": tokens,
        "memory": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", 0),
            "output_bytes": getattr(mem, "output_size_in_bytes", 0),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", 0),
            "generated_code_bytes": getattr(mem, "generated_code_size_in_bytes", 0),
        },
        "cost": {
            "flops": cost.get("flops", 0.0),
            "bytes_accessed": cost.get("bytes accessed", 0.0),
            "transcendentals": cost.get("transcendentals", 0.0),
        },
        "collectives": coll,
        "dynamic": {                     # while-loop trip counts applied
            "flops": dyn["flops"],
            "hbm_bytes": dyn["hbm_bytes"],
            "collectives": dyn["collectives"],
        },
        "n_microbatches": (n_micro if shape.kind == "train" else None),
        "layout": layout,
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
    }
    return result


def cell_path(arch: str, shape_name: str, multi_pod: bool) -> pathlib.Path:
    mesh = "pod2x16x16" if multi_pod else "pod16x16"
    return RESULTS_DIR / f"{arch}__{shape_name}__{mesh}.json"


def run_cell(arch: str, shape_name: str, *, multi_pod: bool, force: bool = False,
             verbose: bool = True) -> dict | None:
    out = cell_path(arch, shape_name, multi_pod)
    if out.exists() and not force:
        if verbose:
            print(f"[skip] {out.name} (cached)")
        return json.loads(out.read_text())
    try:
        res = lower_cell(arch, shape_name, multi_pod=multi_pod,
                         save_hlo=out.with_suffix(".hlo.gz"))
    except Exception as e:  # noqa: BLE001 — record the failure artifact
        res = {"arch": arch, "shape": shape_name,
               "mesh": "pod2x16x16" if multi_pod else "pod16x16",
               "error": f"{type(e).__name__}: {e}",
               "traceback": traceback.format_exc()[-4000:]}
        RESULTS_DIR.mkdir(parents=True, exist_ok=True)
        out.with_suffix(".error.json").write_text(json.dumps(res, indent=2))
        print(f"[FAIL] {arch} x {shape_name}: {res['error']}")
        return None
    RESULTS_DIR.mkdir(parents=True, exist_ok=True)
    out.write_text(json.dumps(res, indent=2))
    if verbose:
        gb = res["memory"]["argument_bytes"] / 2**30
        print(f"[ok] {arch} x {shape_name} x {res['mesh']}: "
              f"flops/dev={res['cost']['flops']:.3e} args/dev={gb:.2f}GiB "
              f"coll={res['collectives']['total_bytes']/2**30:.3f}GiB "
              f"compile={res['compile_s']}s")
    return res


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    todo = cells() if args.all else [(args.arch, args.shape)]
    ok = fail = 0
    for mp in meshes:
        for arch, shape_name in todo:
            r = run_cell(arch, shape_name, multi_pod=mp, force=args.force)
            ok, fail = (ok + 1, fail) if r is not None else (ok, fail + 1)
    for arch, shape_name, why in skipped_cells():
        print(f"[skipped-by-design] {arch} x {shape_name}: {why}")
    print(f"done: {ok} ok, {fail} failed")
    if fail:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
