"""Step functions (train / prefill / decode) and ShapeDtypeStruct input specs
for every (arch x shape) cell — shared by dryrun, train driver and benches.
"""
from __future__ import annotations

import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs import ModelConfig, ShapeConfig
from repro.models import Model
from repro.optim import adamw


# ------------------------------------------------------------------ steps
def make_train_step(model: Model, opt_cfg: adamw.AdamWConfig,
                    n_microbatches: int = 1):
    """Gradient-accumulated train step.  Microbatching bounds the live
    activation footprint (the layer scan saves one residual-stream carry per
    layer per microbatch: O(L * b_micro * s * d) instead of O(L * b * s * d))
    and is the unit of compute/comm overlap: XLA overlaps microbatch k's
    gradient reduce with k+1's compute."""

    def grads_of(params, batch):
        def loss_fn(p):
            # §Perf lever (all cells): cast weights to bf16 BEFORE use — the
            # model consumes them in bf16 anyway, so the FSDP all-gather
            # inside the layer scan moves half the bytes, and the backward
            # cotangents (hence the data-axis gradient reduce-scatters) are
            # bf16 too.  The f32 master copy and the f32 grad ACCUMULATOR
            # keep the update exact-ish (error < 1 bf16 ulp per microbatch).
            pc = jax.tree_util.tree_map(
                lambda x: x.astype(jnp.bfloat16)
                if x.dtype == jnp.float32 and x.ndim >= 2 else x, p)
            return model.loss(pc, batch)
        return jax.value_and_grad(loss_fn, has_aux=True)(params)

    def train_step(state, batch):
        params = state["params"]
        if n_microbatches == 1:
            (loss, metrics), grads = grads_of(params, batch)
        else:
            def split(x):
                b = x.shape[0]
                assert b % n_microbatches == 0, (b, n_microbatches)
                return x.reshape(n_microbatches, b // n_microbatches,
                                 *x.shape[1:])

            micro = {k: split(v) for k, v in batch.items()
                     if not (k == "positions" and v.ndim == 3)}
            if "positions" in batch and batch["positions"].ndim == 3:
                # M-RoPE positions (3, b, s): batch is dim 1
                pos = batch["positions"]
                micro["positions"] = pos.reshape(
                    3, n_microbatches, pos.shape[1] // n_microbatches,
                    pos.shape[2]).swapaxes(0, 1)

            zero_grads = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)

            def body(acc, mb):
                g_acc, loss_acc, aux_acc = acc
                (loss, metrics), g = grads_of(params, mb)
                g_acc = jax.tree_util.tree_map(
                    lambda a, b_: a + b_.astype(jnp.float32), g_acc, g)
                return (g_acc, loss_acc + loss, aux_acc + metrics["aux"]), None

            (grads, loss_sum, aux_sum), _ = jax.lax.scan(
                body, (zero_grads, jnp.zeros((), jnp.float32),
                       jnp.zeros((), jnp.float32)), micro)
            grads = jax.tree_util.tree_map(lambda g: g / n_microbatches, grads)
            loss = loss_sum / n_microbatches
            metrics = {"xent": loss, "aux": aux_sum / n_microbatches}

        new_params, new_opt, opt_metrics = adamw.update(
            opt_cfg, grads, state["opt"], state["params"])
        out = {"params": new_params, "opt": new_opt}
        return out, {"loss": loss, **metrics, **opt_metrics}

    return train_step


def pick_microbatches(shape: ShapeConfig, n_batch_shards: int,
                      target_dev_tokens: int = 16384) -> int:
    # 16k tokens/device/microbatch: fewer microbatches halve the per-step
    # FSDP gather + gradient reduce traffic (both scale with n_micro) at the
    # cost of ~2x live activations — still inside the 16 GiB HBM envelope
    # (EXPERIMENTS.md §Perf, lever 2).
    """Largest microbatch count that divides the per-shard batch while
    pushing per-device live tokens down to ~target_dev_tokens."""
    local = shape.global_batch // max(n_batch_shards, 1)
    if local <= 0:
        return 1
    want = max(1, (local * shape.seq_len) // target_dev_tokens)
    n = min(local, want)
    while local % n:
        n -= 1
    return max(1, n)


def make_prefill_step(model: Model, *, max_len: int, q_chunk: int = 1024):
    def prefill_step(params, batch):
        return model.prefill(params, batch, max_len=max_len, q_chunk=q_chunk)

    return prefill_step


def make_decode_step(model: Model, *, max_len: int):
    def serve_step(params, cache, tokens, pos):
        return model.decode_step(params, cache, tokens, pos, max_len=max_len)

    return serve_step


# ------------------------------------------------------------ input specs
def f(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def input_specs(cfg: ModelConfig, shape: ShapeConfig, *, labels: bool = True) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of a train/prefill
    batch (weak-type-correct, shardable, no device allocation).

    [audio]/[vlm]: the frontend is a stub — specs carry precomputed
    frame/patch embeddings instead of raw modalities (assignment note)."""
    b, s = shape.global_batch, shape.seq_len
    out: dict = {}
    if cfg.embeds_as_input and not cfg.is_encoder_decoder:
        out["inputs_embeds"] = f((b, s, cfg.d_model), jnp.bfloat16)
    else:
        out["tokens"] = f((b, s), jnp.int32)
    if cfg.is_encoder_decoder:
        out["enc_embeds"] = f((b, cfg.encoder_seq, cfg.d_model), jnp.bfloat16)
    if cfg.mrope_sections:
        out["positions"] = f((3, b, s), jnp.int32)
    if labels and shape.kind == "train":
        out["labels"] = f((b, s), jnp.int32)
    return out


def decode_input_specs(cfg: ModelConfig, shape: ShapeConfig, model: Model):
    """(cache, tokens, pos) stand-ins for serve_step at this cell: one new
    token against a KV cache of seq_len."""
    b, s = shape.global_batch, shape.seq_len
    cache = jax.eval_shape(lambda: model.init_cache(b, s))
    tokens = f((b, 1), jnp.int32)
    pos = f((), jnp.int32)
    return cache, tokens, pos


def state_specs(model: Model):
    params = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
    opt = jax.eval_shape(lambda: adamw.init(params))
    return {"params": params, "opt": opt}


def count_params(params_shapes) -> int:
    import math
    return sum(math.prod(x.shape) if x.shape else 1
               for x in jax.tree_util.tree_leaves(params_shapes))


def count_active_params(cfg: ModelConfig, params_shapes) -> int:
    """MoE: experts beyond top-k don't contribute to per-token compute."""
    total = count_params(params_shapes)
    if not cfg.n_experts:
        return total
    # expert tensors are the w_in/w_gate/w_out leaves under "moe" (they carry
    # an E axis, possibly behind the stacked n_cycles axis)
    import math
    expert = 0
    def visit(path, leaf):
        nonlocal expert
        names = [str(getattr(e, "key", getattr(e, "idx", e))) for e in path]
        if "moe" in names and names[-1] in ("w_in", "w_gate", "w_out"):
            expert += math.prod(leaf.shape)
        return leaf
    jax.tree_util.tree_map_with_path(visit, params_shapes)
    frac = cfg.n_experts_per_token / cfg.n_experts
    return int(total - expert * (1 - frac))
