"""Trip-count-aware HLO statistics.

`compiled.cost_analysis()` counts each while-loop BODY once, but a layer
scan executes its body n_cycles times (and grad-accumulation / loss-chunk /
q-chunk scans likewise) — so FLOPs, bytes and collective traffic are
undercounted by the trip counts.  This module re-derives the three roofline
inputs from the optimized HLO text with loop multipliers applied:

  * flops            — dot ops exactly (2 * numel(result) * K), elementwise 1/elem
  * hbm_bytes        — operand+result bytes at fusion boundaries (a standard
                       proxy for HBM traffic, same convention as XLA's
                       bytes_accessed)
  * collective_bytes — per collective type, operand bytes x trip counts

Parsing strategy: split the module into computations; compute per-
computation totals; walk the call graph from ENTRY with multipliers
(while bodies x trip count, conditionals x 1, fusion-called computations are
EXCLUDED from the walk — their cost is folded into the fusion instruction).
Trip counts come from the loop-condition's compare-against-constant.
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

_ELEMENTWISE = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "abs",
    "negate", "exponential", "log", "tanh", "rsqrt", "sqrt", "power",
    "select", "compare", "and", "or", "xor", "convert", "floor", "ceil",
    "sign", "cosine", "sine", "logistic", "exponential-minus-one",
    "log-plus-one", "atan2", "remainder", "clamp",
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
# type = lazy run up to the first "opcode(" token; tuple types may contain
# /*index=N*/ comments and layout braces, so a charset match is infeasible
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*"            # name
    r"(.*?)\s+"                                        # type (lazy)
    r"([a-z][\w\-]*)\("                                # opcode
)
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s+\([^)]*\)\s*->")


def _type_numel_bytes(type_str: str) -> tuple[int, int]:
    numel_total, bytes_total = 0, 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.groups()
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        numel_total += n
        bytes_total += n * _DTYPE_BYTES[dt]
    return numel_total, bytes_total


def _shape_dims(type_str: str) -> list[int]:
    m = _SHAPE_RE.search(type_str)
    if not m or not m.group(2):
        return []
    return [int(d) for d in m.group(2).split(",")]


@dataclasses.dataclass
class Instr:
    name: str
    type_str: str
    opcode: str
    operands: list[str]
    opseg: str           # raw operand segment (holds literal constants)
    attrs: str


@dataclasses.dataclass
class Computation:
    name: str
    instrs: list[Instr]
    symbols: dict        # name -> type_str


_HEADER_RE = re.compile(r"^(?:ENTRY\s+)?%([\w.\-]+)\s*\(")


def parse_module(hlo_text: str) -> tuple[dict[str, Computation], str | None]:
    comps: dict[str, Computation] = {}
    entry: str | None = None
    cur: Computation | None = None
    for line in hlo_text.splitlines():
        # computation headers start at column 0: "%name (...) -> ... {"
        # (ENTRY lines may contain /*index=N*/ comments and layout braces)
        if not line.startswith(" ") and line.rstrip().endswith("{"):
            m = _HEADER_RE.match(line)
            if m:
                cur = Computation(m.group(1), [], {})
                comps[cur.name] = cur
                if line.startswith("ENTRY"):
                    entry = cur.name
                continue
        if cur is None:
            continue
        if line.strip() == "}":
            cur = None
            continue
        m = _INSTR_RE.match(line)
        if not m:
            # parameters: "%p = f32[..] parameter(0)" matches; constants too
            continue
        name, type_str, opcode = m.groups()
        rest = line[m.end():]
        close = rest.find(")")
        opseg = rest[:close if close >= 0 else len(rest)]
        operands = re.findall(r"%([\w.\-]+)", opseg)
        cur.instrs.append(Instr(name, type_str, opcode, operands, opseg,
                                rest[close + 1:] if close >= 0 else ""))
        cur.symbols[name] = type_str
    return comps, entry


def _call_refs(instr: Instr) -> dict[str, list[str]]:
    """attr kind -> called computation names."""
    out = defaultdict(list)
    for kind, pat in (("fused", r"calls=%?([\w.\-]+)"),
                      ("body", r"body=%?([\w.\-]+)"),
                      ("cond", r"condition=%?([\w.\-]+)"),
                      ("apply", r"to_apply=%?([\w.\-]+)"),
                      ("branch", r"branch_computations=\{([^}]*)\}")):
        for m in re.finditer(pat, instr.attrs):
            if kind == "branch":
                out[kind].extend(x.strip().lstrip("%")
                                 for x in m.group(1).split(","))
            else:
                out[kind].append(m.group(1))
    return out


def _trip_count(cond: Computation, body_sym: dict) -> int:
    """Loop condition: compare(%iv, %const), direction=LT — the constant is
    the trip count for scan-lowered loops (iv starts at 0)."""
    consts: list[int] = []
    for instr in cond.instrs:
        if instr.opcode == "constant":
            m = re.fullmatch(r"\s*(\d+)\s*", instr.opseg)
            if m:
                consts.append(int(m.group(1)))
    return max(consts) if consts else 1


def _instr_flops(instr: Instr, symbols: dict) -> float:
    numel, _ = _type_numel_bytes(instr.type_str)
    if instr.opcode == "dot":
        k = 1
        m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", instr.attrs)
        if m and instr.operands:
            lhs_t = symbols.get(instr.operands[0], "")
            dims = _shape_dims(lhs_t)
            for idx in (int(x) for x in m.group(1).split(",") if x):
                if idx < len(dims):
                    k *= dims[idx]
        return 2.0 * numel * k
    if instr.opcode in _ELEMENTWISE:
        return float(numel)
    if instr.opcode in ("reduce", "reduce-window"):
        # ~1 op per input element
        tot = 0
        for op in instr.operands[: max(1, len(instr.operands) // 2)]:
            n, _ = _type_numel_bytes(symbols.get(op, ""))
            tot += n
        return float(tot or numel)
    return 0.0


_SLICING_OPS = {"fusion", "dynamic-slice", "dynamic-update-slice", "gather",
                "scatter", "slice"}


def _instr_bytes(instr: Instr, symbols: dict, loop_trip: int = 1) -> float:
    """HBM-traffic proxy: result + operand bytes.

    Two scan-body corrections (without them, layer/time-scan traffic is
    overcounted by the trip count):
      * a slicing op whose RESULT is the loop-carried stacked buffer
        (leading dim == trip count, e.g. dynamic-update-slice into the xs/ys
        stack) truly writes size/trip per iteration;
      * operands larger than the (corrected) result are capped at it — a
        dynamic-slice reads one slice of the stacked buffer, not all of it.
    Genuine high-K contractions are top-level `dot` ops and keep their true
    operand sizes.
    """
    _, rb = _type_numel_bytes(instr.type_str)
    cap = instr.opcode in _SLICING_OPS
    if cap and loop_trip > 1:
        dims = _shape_dims(instr.type_str)
        if dims and dims[0] == loop_trip:
            rb = rb / loop_trip
    ob = 0
    for op in instr.operands:
        _, b = _type_numel_bytes(symbols.get(op, ""))
        if cap and b > rb:
            b = rb
        ob += b
    return float(rb + ob)


_SKIP_BYTES_OPS = {"parameter", "constant", "get-tuple-element", "tuple",
                   "bitcast", "after-all", "partition-id", "replica-id",
                   "while", "conditional", "call", "custom-call"}


def analyze(hlo_text: str, entry: str | None = None) -> dict:
    comps, parsed_entry = parse_module(hlo_text)
    if not comps:
        return {"flops": 0.0, "hbm_bytes": 0.0, "collectives": {}}
    entry_name = entry or parsed_entry
    if entry_name is None:  # fallback: a computation nobody calls
        called: set[str] = set()
        for c in comps.values():
            for instr in c.instrs:
                for names in _call_refs(instr).values():
                    called.update(names)
        entries = [c for c in comps if c not in called]
        entry_name = entries[0] if entries else next(iter(comps))

    fused: set[str] = set()
    for c in comps.values():
        for instr in c.instrs:
            refs = _call_refs(instr)
            fused.update(refs.get("fused", []))
            fused.update(refs.get("apply", []))

    coll = {c: {"count": 0.0, "bytes": 0.0} for c in _COLLECTIVES}
    totals = {"flops": 0.0, "hbm_bytes": 0.0, "transcendentals": 0.0}

    seen_stack: set[str] = set()

    def walk(comp_name: str, mult: float, loop_trip: int = 1):
        if comp_name not in comps or comp_name in seen_stack:
            return
        seen_stack.add(comp_name)
        comp = comps[comp_name]
        for instr in comp.instrs:
            refs = _call_refs(instr)
            # cost of fused computations folds into this instruction
            own_flops = _instr_flops(instr, comp.symbols)
            for fname in refs.get("fused", []):
                if fname in comps:
                    fc = comps[fname]
                    own_flops += sum(_instr_flops(i, fc.symbols)
                                     for i in fc.instrs)
            totals["flops"] += mult * own_flops
            if instr.opcode not in _SKIP_BYTES_OPS:
                totals["hbm_bytes"] += mult * _instr_bytes(instr, comp.symbols,
                                                           loop_trip)
            base = instr.opcode.removesuffix("-start")
            if base in _COLLECTIVES:
                ob = sum(_type_numel_bytes(comp.symbols.get(op, ""))[1]
                         for op in instr.operands)
                if ob == 0:
                    ob = _type_numel_bytes(instr.type_str)[1]
                coll[base]["count"] += mult
                coll[base]["bytes"] += mult * ob
            # control flow
            if instr.opcode == "while":
                body = refs.get("body", [None])[0]
                cond = refs.get("cond", [None])[0]
                trips = _trip_count(comps[cond], comp.symbols) if cond in comps else 1
                if body:
                    walk(body, mult * trips, trips)
                if cond:
                    walk(cond, mult * trips, trips)
            elif instr.opcode == "conditional":
                for b in refs.get("branch", []):
                    walk(b, mult, loop_trip)   # upper bound: all branches
            elif instr.opcode in ("call", "async-start"):
                for b in refs.get("apply", []):
                    if b not in fused:
                        walk(b, mult, loop_trip)
        seen_stack.discard(comp_name)

    walk(entry_name, 1.0)
    coll_out: dict = {k: {"count": int(v["count"]), "bytes": float(v["bytes"])}
                      for k, v in coll.items()}
    coll_out["total_bytes"] = sum(v["bytes"] for v in coll.values())
    coll_out["total_count"] = int(sum(v["count"] for v in coll.values()))
    return {"flops": totals["flops"], "hbm_bytes": totals["hbm_bytes"],
            "collectives": coll_out, "entry": entry_name,
            "n_computations": len(comps)}
