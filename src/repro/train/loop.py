"""Training loop: jit'd gradient-accumulated steps + MSR checkpointing +
failure supervision.  Used by examples/train_tiny_lm.py and the system tests;
the same step function lowers on the production mesh via launch/dryrun.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.configs import ModelConfig
from repro.data import pipeline
from repro.models import Model
from repro.optim import adamw
from repro.launch.steps import make_train_step

from .fault_tolerance import FailureInjector, Supervisor


@dataclasses.dataclass
class TrainConfig:
    n_steps: int = 100
    global_batch: int = 8
    seq_len: int = 64
    n_microbatches: int = 1
    ckpt_every: int = 20
    log_every: int = 10
    seed: int = 0
    write_behind: bool = False   # zero-stall checkpointing (DESIGN.md §12.5)


def init_state(model: Model, opt_cfg: adamw.AdamWConfig, seed: int = 0) -> dict:
    params = model.init(jax.random.PRNGKey(seed))
    return {"params": params, "opt": adamw.init(params, opt_cfg)}


def train(cfg: ModelConfig, tcfg: TrainConfig,
          opt_cfg: Optional[adamw.AdamWConfig] = None, *,
          checkpointer=None, injector: Optional[FailureInjector] = None,
          state: Optional[dict] = None, start_step: int = 0,
          log: Callable = print) -> tuple[dict, list[dict]]:
    """Returns (final_state, history).  Deterministic given seeds."""
    opt_cfg = opt_cfg or adamw.AdamWConfig(
        lr=1e-3, warmup_steps=max(tcfg.n_steps // 20, 1),
        total_steps=tcfg.n_steps)
    model = Model(cfg)
    dcfg = pipeline.DataConfig(vocab_size=cfg.vocab_size, seq_len=tcfg.seq_len,
                               global_batch=tcfg.global_batch, seed=tcfg.seed)
    step_fn = jax.jit(make_train_step(model, opt_cfg, tcfg.n_microbatches),
                      donate_argnums=(0,))
    if state is None:
        state = init_state(model, opt_cfg, tcfg.seed)

    history: list[dict] = []

    def data_fn(step: int) -> dict:
        b = pipeline.batch_at(dcfg, step)
        return {k: jnp.asarray(v) for k, v in b.items()}

    if checkpointer is not None:
        sup = Supervisor(checkpointer, injector, ckpt_every=tcfg.ckpt_every,
                         write_behind=tcfg.write_behind)
        state = sup.run(state, step_fn, data_fn, tcfg.n_steps,
                        start_step=start_step)
        history = sup.log
        return state, history

    t0 = time.time()
    for step in range(start_step, start_step + tcfg.n_steps):
        state, metrics = step_fn(state, data_fn(step))
        if step % tcfg.log_every == 0 or step == start_step + tcfg.n_steps - 1:
            rec = {"step": step, "loss": float(metrics["loss"]),
                   "grad_norm": float(metrics["grad_norm"]),
                   "t": round(time.time() - t0, 2)}
            history.append(rec)
            log(f"step {rec['step']:5d}  loss {rec['loss']:.4f}  "
                f"gnorm {rec['grad_norm']:.3f}  {rec['t']}s")
    return state, history
