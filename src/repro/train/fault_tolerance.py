"""Fault-tolerance runtime: failure injection, heartbeat/straggler detection,
elastic re-meshing — the control plane around the MSR storage layer.

On real hardware these hook the cluster manager; here the same logic runs
against a simulated clock so every policy is unit-testable.  The decisions
(who repairs, from whom, at what bandwidth) are delegated to the paper's
embedded property: helpers are DETERMINED (prev + next-k ring neighbours),
so the control plane never solves coefficient/helper-selection problems —
the paper's central operational claim (paper §IV).

The training loop and the cluster simulator (DESIGN.md §9) share one
failure timeline: `ClusterScheduleInjector` replays a `repro.cluster`
scenario's fail events as training-step crashes, and the Supervisor can
account its checkpoint-repair traffic into the same `MetricsLog` the
serving scenarios report against.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Iterable, Optional, Sequence

import numpy as np


# ------------------------------------------------------------ failure model
@dataclasses.dataclass(frozen=True)
class FailureEvent:
    step: int
    node: int                    # 1-indexed storage node / host
    kind: str = "crash"          # crash | straggler


class FailureInjector:
    """Deterministic or Poisson failure schedule over training steps."""

    def __init__(self, n_nodes: int, *, schedule: Sequence[FailureEvent] = (),
                 rate_per_step: float = 0.0, seed: int = 0):
        self.n_nodes = n_nodes
        self._fixed = sorted(schedule, key=lambda e: e.step)
        self._rate = rate_per_step
        self._rng = np.random.default_rng(seed)

    def at(self, step: int) -> list[FailureEvent]:
        out = [e for e in self._fixed if e.step == step]
        if self._rate > 0:
            n = self._rng.poisson(self._rate)
            for _ in range(min(n, self.n_nodes - 1)):
                out.append(FailureEvent(step=step,
                                        node=int(self._rng.integers(1, self.n_nodes + 1))))
        return out


class ClusterScheduleInjector(FailureInjector):
    """A `repro.cluster` scenario viewed as a training-step failure
    schedule (DESIGN.md §9).

    The simulator and the training loop share one failure timeline: every
    ``fail`` event in the scenario becomes a crash of the same node at
    step ``round(t * steps_per_time)``, so the exact cluster dynamics a
    scenario benchmarks are what the Supervisor's checkpoint-repair path
    recovers from.

    Parameters
    ----------
    n_nodes : int
        Storage nodes (the code's n).
    scenario : repro.cluster.events.Scenario
        Event stream; only ``fail`` events are injected (down/up events
        are storage-availability concerns the checkpointer's restore path
        handles internally).
    steps_per_time : float
        Training steps per unit of simulated time.
    """

    def __init__(self, n_nodes: int, scenario, *, steps_per_time: float = 1.0):
        schedule = [FailureEvent(step=int(round(e.t * steps_per_time)),
                                 node=e.node)
                    for e in scenario.events if e.kind == "fail"]
        super().__init__(n_nodes, schedule=schedule)


# ---------------------------------------------------------------- heartbeats
class HeartbeatMonitor:
    """Progress-based straggler detection: a node whose reported step lags
    the median by > `lag_threshold` steps, or whose last heartbeat is older
    than `timeout_s`, is flagged.  Mitigation at the caller: re-dispatch the
    laggard's microbatch to a spare (backup-task / speculative execution).

    Nodes the control plane declared dead (`declare_dead`) stay in the
    ``dead()`` set regardless of clock math until they heartbeat again —
    a beat from a removed node is a *rejoin* (recorded in ``rejoined()``),
    the elastic re-admission path a restarted host takes.

    ``straggler_s`` (optional) adds a wall-clock straggler criterion: a
    node whose last beat is older than ``straggler_s`` (but within
    ``timeout_s``) is flagged even if its reported progress looks fine —
    the hung-but-not-dead shape.  Must be strictly less than
    ``timeout_s``; thresholds are validated at construction so a
    misconfigured monitor fails loudly instead of silently never firing.
    """

    def __init__(self, n_nodes: int, *, timeout_s: float = 60.0,
                 lag_threshold: int = 2,
                 straggler_s: Optional[float] = None):
        if n_nodes < 1:
            raise ValueError("n_nodes must be >= 1")
        if timeout_s <= 0:
            raise ValueError(f"timeout_s must be positive, got {timeout_s}")
        if lag_threshold < 0:
            raise ValueError(f"lag_threshold must be >= 0, "
                             f"got {lag_threshold}")
        if straggler_s is not None and not 0 < straggler_s < timeout_s:
            raise ValueError(
                f"straggler_s must be in (0, timeout_s={timeout_s}), got "
                f"{straggler_s} — a straggler window at or past the death "
                f"timeout can never fire")
        self.n_nodes = n_nodes
        self.timeout_s = timeout_s
        self.lag_threshold = lag_threshold
        self.straggler_s = straggler_s
        self._last_beat = {i: 0.0 for i in range(1, n_nodes + 1)}
        self._progress = {i: 0 for i in range(1, n_nodes + 1)}
        self._removed: set[int] = set()
        self._rejoined: list[int] = []

    def beat(self, node: int, step: int, now: float):
        if node not in self._last_beat:
            raise ValueError(f"unknown node {node} (1..{self.n_nodes})")
        if node in self._removed:           # rejoin: re-admit the host
            self._removed.discard(node)
            self._rejoined.append(node)
        self._last_beat[node] = now
        self._progress[node] = max(self._progress[node], step)

    def declare_dead(self, node: int) -> None:
        """Control-plane removal: the node stays dead until it beats again
        (crash recovery marks the crashed host here; a later beat is the
        rejoin)."""
        if node not in self._last_beat:
            raise ValueError(f"unknown node {node} (1..{self.n_nodes})")
        self._removed.add(node)

    def rejoined(self) -> list[int]:
        """Nodes that heartbeat after being declared dead, in rejoin order."""
        return list(self._rejoined)

    def dead(self, now: float) -> list[int]:
        return sorted(set(self._removed) |
                      {i for i, t in self._last_beat.items()
                       if now - t > self.timeout_s})

    def stragglers(self, now: float) -> list[int]:
        dead = set(self.dead(now))
        alive = [i for i in self._last_beat if i not in dead]
        if not alive:
            return []
        med = float(np.median([self._progress[i] for i in alive]))
        out = {i for i in alive if med - self._progress[i] > self.lag_threshold}
        if self.straggler_s is not None:
            out |= {i for i in alive
                    if now - self._last_beat[i] > self.straggler_s}
        return sorted(out)

    def suspects(self, now: float) -> dict[str, list[int]]:
        """The heartbeat→helper-selection feed (DESIGN.md §13.3): nodes a
        read front end should route around — ``dead`` (declared or past
        ``timeout_s``) and ``stragglers`` (progress lag or the
        wall-clock ``straggler_s`` criterion).  The serving layer
        demotes both to last-resort helpers, so a straggler is avoided
        BEFORE any hedge timer fires rather than merely raced."""
        return {"dead": self.dead(now), "stragglers": self.stragglers(now)}


# ------------------------------------------------------------------ elastic
@dataclasses.dataclass(frozen=True)
class ElasticPlan:
    n_alive: int
    data_parallel: int           # new data-axis extent
    dropped_nodes: tuple[int, ...]
    microbatch_scale: float      # factor to keep the global batch constant

    @property
    def changed(self) -> bool:
        return bool(self.dropped_nodes)


def plan_elastic(n_nodes: int, dead: Iterable[int], *,
                 keep_global_batch: bool = True) -> ElasticPlan:
    """Shrink the data-parallel extent to the largest power-of-two <= alive
    hosts (mesh axes must stay regular); surviving hosts absorb the dropped
    ranks' share via more grad-accumulation microbatches."""
    dead = tuple(sorted(set(dead)))
    alive = n_nodes - len(dead)
    if alive < 1:
        raise RuntimeError("no hosts left")
    dp = 2 ** int(math.log2(alive))
    scale = (n_nodes / dp) if keep_global_batch else 1.0
    return ElasticPlan(n_alive=alive, data_parallel=dp, dropped_nodes=dead,
                       microbatch_scale=scale)


# --------------------------------------------------------------- supervisor
class Supervisor:
    """Drives train-step execution with failure handling:

    on crash events at step t:
      1. flag the node dead; if a checkpoint exists, REPAIR its shard via the
         MSR newcomer protocol (gamma = (k+1)B/2k reads, not B);
      2. restore the training state (systematic path for survivors);
      3. re-plan the mesh if the node stays gone (elastic), else resume.

    The loop is synchronous-SPMD, so a crash loses at most the steps since
    the last checkpoint; the MSR layer's job is to make the *storage* repair
    cheap and deterministic.

    **Write-behind mode** (``write_behind=True``, DESIGN.md §12.5): save
    points call ``checkpointer.save_async`` — the state is snapshotted on
    device and encoded/written on a background thread while training
    continues ("zero-stall" checkpointing).  At most one save is in
    flight; the supervisor fences (``barrier``) before any crash-recovery
    restore and before returning, so recovery never races a write and the
    returned state is always durably backed.  A background save that
    FAILS surfaces at the barrier: ``on_save_error="raise"`` re-raises
    (strict durability), ``"log"`` records a ``ckpt_failed`` event and
    continues — the previous committed generation still bounds the loss.
    """

    def __init__(self, checkpointer, injector: Optional[FailureInjector] = None,
                 *, ckpt_every: int = 10, metrics=None,
                 write_behind: bool = False, on_save_error: str = "raise"):
        """``metrics``: optional `repro.cluster.MetricsLog` — repair
        traffic from crash recovery is accounted there against the RS
        re-download baseline, alongside any serving-scenario traffic."""
        if on_save_error not in ("raise", "log"):
            raise ValueError(f"on_save_error must be 'raise' or 'log', "
                             f"got {on_save_error!r}")
        if write_behind and not hasattr(checkpointer, "save_async"):
            raise ValueError("write_behind=True needs a checkpointer with "
                             "save_async/barrier (MSRCheckpointer)")
        self.ckpt = checkpointer
        self.injector = injector
        self.ckpt_every = ckpt_every
        self.metrics = metrics
        self.write_behind = write_behind
        self.on_save_error = on_save_error
        self.log: list[dict] = []

    def _barrier(self, step: int) -> None:
        """Fence the in-flight background save (no-op when none).  A save
        failure surfaces HERE — logged, then re-raised unless
        ``on_save_error="log"``."""
        if not hasattr(self.ckpt, "barrier"):
            return
        try:
            self.ckpt.barrier()
        except Exception as e:
            self.log.append({"step": step, "event": "ckpt_failed",
                             "error": repr(e)})
            if self.on_save_error == "raise":
                raise

    def run(self, state, step_fn: Callable, data_fn: Callable, n_steps: int,
            start_step: int = 0):
        """data_fn: step -> batch (stateless indexing — after a rollback the
        exact stream replays, no loss/duplication: repro.data.pipeline)."""
        step = start_step
        consumed: set[tuple[int, int]] = set()
        while step < start_step + n_steps:
            events = self.injector.at(step) if self.injector else []
            crashes = [e for e in events if e.kind == "crash"
                       and (e.step, e.node) not in consumed]
            consumed.update((e.step, e.node) for e in crashes)
            if crashes:
                # recovery must see a settled checkpoint directory: fence
                # the in-flight write-behind save BEFORE listing steps()
                self._barrier(step)
            if crashes and self.ckpt.steps():
                last = self.ckpt.steps()[-1]
                failed = [e.node for e in crashes]
                repaired_bytes = 0
                if len(failed) == 1:
                    repaired_bytes = self.ckpt.repair_node(last, failed[0])
                    state, report = self.ckpt.restore(state, last)
                else:
                    state, report = self.ckpt.restore(state, last,
                                                      failed_nodes=failed)
                self.log.append({
                    "step": step, "event": "repair", "failed": failed,
                    "ckpt_step": last, "restore_path": report.path,
                    "repair_bytes": repaired_bytes or report.bytes_read,
                })
                if self.metrics is not None:
                    from repro.core.baselines import rs_scenario_repair_symbols
                    spec = self.ckpt.spec
                    block_symbols = report.bytes_total_stored // (2 * spec.n)
                    self.metrics.record_repair(
                        len(failed), repaired_bytes or report.bytes_read,
                        rs_scenario_repair_symbols(spec.k, block_symbols,
                                                   len(failed)))
                step = last          # roll back to the checkpoint
                continue
            batch = data_fn(step)
            state, metrics = step_fn(state, batch)
            self.log.append({"step": step, "event": "step",
                             "loss": float(metrics["loss"])})
            step += 1
            if step % self.ckpt_every == 0:
                if self.write_behind:
                    # fence (with policy) BEFORE submitting: save_async's
                    # own internal barrier would re-raise a previous
                    # failure past the on_save_error="log" handling
                    self._barrier(step)
                    self.ckpt.save_async(step, state)
                    self.log.append({"step": step, "event": "ckpt_async"})
                else:
                    self.ckpt.save(step, state)
                    self.log.append({"step": step, "event": "ckpt"})
        # the state handed back must be durably backed: fence the last
        # background save before returning
        self._barrier(step)
        return state
