from . import ctx, policy  # noqa: F401
