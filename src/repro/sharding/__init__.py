from . import ctx, mesh, policy  # noqa: F401
