"""Sharding policy: PartitionSpec rules per (param path x shape) and per
batch/cache kind, for the production meshes (DESIGN.md §6).

Philosophy: sharding never changes semantics under GSPMD — only layout and
collective traffic — so every rule has a divisibility-checked preference
list with a safe fallback, letting one policy serve all 10 architectures:

  * embeddings / lm_head:       vocab -> model
  * attention q/o projections:  heads -> model, else head_dim, else d_model
  * attention k/v projections:  kv_heads -> model, else head_dim, else d
  * dense FFN:                  hidden  -> model
  * MoE experts:                expert  -> model (expert parallelism)
  * RG-LRU / xLSTM inner dims:  width   -> model
  * norms / biases / gates:     replicated
  * batch:                      (pod, data); long-context decode shards the
                                KV-cache sequence dim on data instead
  * optimizer moments:          mirror the parameter specs (zero1_specs adds
                                a data-axis shard on the largest dim — ZeRO-1)

Stacked (scan) parameters carry a leading n_cycles axis: specs are computed
on shape[1:] and prefixed with None (detected via the "cycles" path entry).
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


# --------------------------------------------------------------- helpers
def _axis_size(mesh: Mesh, name: str) -> int:
    return mesh.shape[name] if name in mesh.shape else 1


def _fits(dim: int, mesh: Mesh, axis: str) -> bool:
    n = _axis_size(mesh, axis)
    return n > 1 and dim % n == 0


def spec_fits(spec: P, shape: tuple[int, ...], mesh: Mesh, *,
              require_multi: bool = False) -> bool:
    """Divisibility check for a PartitionSpec against a concrete shape:
    every sharded dim must divide its mesh-axis product.  With
    ``require_multi`` a spec naming any size-1 axis is rejected too
    (used by the param rules, which want a REAL shard or a clean
    fallback).  Shared by the param policy and the activation-hint
    context (`ctx.constrain`), so the two can never disagree on what
    "fits" means."""
    for dim, ax in zip(shape, tuple(spec) + (None,) * len(shape)):
        if ax is None:
            continue
        axes = ax if isinstance(ax, tuple) else (ax,)
        n = int(np.prod([_axis_size(mesh, a) for a in axes]))
        if n > 1 and dim % n != 0:
            return False
        if require_multi and any(_axis_size(mesh, a) == 1 for a in axes):
            return False
    return True


def _path_names(path) -> list[str]:
    out = []
    for e in path:
        if hasattr(e, "key"):
            out.append(str(e.key))
        elif hasattr(e, "idx"):
            out.append(f"[{e.idx}]")
        else:
            out.append(str(e))
    return out


# ------------------------------------------------------------- layouts
def choose_layout(cfg, mesh: Mesh, shape_cfg) -> str:
    """"hybrid" (TP over `model` + DP/FSDP over `data`) vs "dp" (the model
    axis JOINS data parallelism: pure FSDP over every chip, no per-layer
    activation all-reduces — EXPERIMENTS.md §Perf lever 3).

    dp is chosen when (a) the global batch divides the full chip count,
    (b) sharded optimizer state is comfortably small, and (c) a single
    sample's attention scores fit next to the activations (plain-attention
    training at b_local=1).
    """
    n_dev = int(np.prod(list(mesh.shape.values())))
    if shape_cfg.kind != "train" or shape_cfg.global_batch % n_dev:
        return "hybrid"
    state_bytes = _rough_param_bytes(cfg) * 3       # fp32 params + mu + nu
    if state_bytes / n_dev > 2 * 2**30:
        return "hybrid"
    score_bytes = cfg.n_heads * shape_cfg.seq_len ** 2 * 4
    if score_bytes > 4 * 2**30:
        return "hybrid"
    return "dp"


def _rough_param_bytes(cfg) -> float:
    d, L = cfg.d_model, cfg.n_layers
    per_layer = 4 * d * cfg.n_heads * (cfg.head_dim or d // cfg.n_heads)
    per_layer += 3 * d * max(cfg.d_ff, int(d * cfg.xlstm_proj_factor))
    per_layer += 3 * cfg.n_experts * d * cfg.moe_dff
    total = L * per_layer + 2 * cfg.vocab_size * d
    return total * 4.0


# ---------------------------------------------------------- param rules
def param_spec(path_names: list[str], shape: tuple[int, ...], mesh: Mesh) -> P:
    stacked = any("cycles" in n for n in path_names)
    eff = shape[1:] if stacked and len(shape) >= 2 else shape
    name = path_names[-1] if path_names else ""
    spec = _param_spec_inner(name, path_names, eff, mesh)
    if stacked and len(shape) >= 2:
        spec = P(None, *spec)
    return spec


def _param_spec_inner(name: str, path: list[str], shape: tuple[int, ...],
                      mesh: Mesh) -> P:
    nd = len(shape)
    if nd <= 1:
        return P()                                           # norms, biases
    if name == "embed":
        return P("model", None) if _fits(shape[0], mesh, "model") else P()
    if name == "lm_head":
        return P(None, "model") if _fits(shape[1], mesh, "model") else P()
    in_moe = any(n in ("moe",) for n in path)
    if in_moe and name in ("w_in", "w_out", "w_gate") and nd == 3:
        # (E, d, ff) / (E, ff, d): expert parallelism first
        for cand in (P("model", None, None),
                     P(None, None, "model") if name != "w_out" else P(None, "model", None),
                     P(None, "model", None) if name != "w_out" else P(None, None, "model")):
            if _spec_fits(cand, shape, mesh):
                return cand
        return P()
    if name in ("wq", "wk", "wv") and nd == 3:               # (d, heads, hd)
        # heads -> model when divisible; otherwise REPLICATE over model (FSDP
        # still shards over data).  Never shard the contraction/input dims:
        # GSPMD defers the partial-sum into the attention einsums and emits
        # full-batch score all-reduces (32 GiB/op observed — see EXPERIMENTS).
        cand = P(None, "model", None)
        return cand if _spec_fits(cand, shape, mesh) else P()
    if name == "wo" and nd == 3:                             # (h, hd, d)
        cand = P("model", None, None)                        # Megatron row-par
        return cand if _spec_fits(cand, shape, mesh) else P()
    if name == "w_zifo" and nd == 4:                         # (d, 4, h, dh)
        for cand in (P(None, None, "model", None), P(None, None, None, "model"),
                     P("model", None, None, None)):
            if _spec_fits(cand, shape, mesh):
                return cand
        return P()
    if name == "r_zifo" and nd == 4:                         # (4, h, dh, dh)
        for cand in (P(None, "model", None, None), P(None, None, "model", None)):
            if _spec_fits(cand, shape, mesh):
                return cand
        return P()
    if nd == 2:
        # generic matmul weight (d_in, d_out): prefer output dim ("column
        # parallel"), except *_out / w_down / wo which prefer input dim
        prefer_in = name in ("w_out", "w_down", "w_mlp_out")
        cands = ([P("model", None), P(None, "model")] if prefer_in
                 else [P(None, "model"), P("model", None)])
        for cand in cands:
            if _spec_fits(cand, shape, mesh):
                return cand
        return P()
    if name == "conv_w":                                     # (cw, width)
        return P(None, "model") if _fits(shape[1], mesh, "model") else P()
    if nd == 3:
        for cand in (P(None, None, "model"), P(None, "model", None)):
            if _spec_fits(cand, shape, mesh):
                return cand
    return P()


def _spec_fits(spec: P, shape: tuple[int, ...], mesh: Mesh) -> bool:
    return spec_fits(spec, shape, mesh, require_multi=True)


def _add_fsdp(spec: P, shape: tuple[int, ...], mesh: Mesh,
              min_size: int = 2**20, axes: tuple[str, ...] = ("data",)) -> P:
    """Add an FSDP shard over `axes` on the first free, divisible dim
    (ZeRO-3 style).  Parameters and optimizer moments then occupy
    bytes / prod(axes x existing) per device; GSPMD all-gathers each layer's
    weight slice inside the scan.  Tiny leaves (norms, biases) stay
    replicated."""
    if int(np.prod(shape)) < min_size:
        return spec
    used = tuple(spec) + (None,) * (len(shape) - len(tuple(spec)))
    n = int(np.prod([_axis_size(mesh, a) for a in axes]))
    if n <= 1:
        return spec
    # prefer the largest free dim
    order = sorted((i for i, ax in enumerate(used) if ax is None),
                   key=lambda i: -shape[i])
    for i in order:
        if shape[i] % n == 0:
            new = list(used)
            new[i] = axes if len(axes) > 1 else axes[0]
            return P(*new)
    # split axes across two free dims if one dim cannot take the product
    if len(axes) == 2 and len(order) >= 2:
        a0, a1 = axes
        for i in order:
            if shape[i] % _axis_size(mesh, a0) == 0:
                for j in order:
                    if j != i and shape[j] % _axis_size(mesh, a1) == 0:
                        new = list(used)
                        new[i], new[j] = a0, a1
                        return P(*new)
    return spec


# weights consumed INSIDE a per-timestep scan: FSDP-sharding them makes
# GSPMD emit a gather/all-reduce EVERY timestep (observed: 24.6k ARs /
# 400 GiB per step on xlstm).  They are small — keep them replicated.
_SCAN_RESIDENT = ("r_zifo", "b_zifo")


def param_specs(params_shapes: Any, mesh: Mesh, *, fsdp: bool = True,
                layout: str = "hybrid") -> Any:
    """Pytree of PartitionSpec matching a pytree of ShapeDtypeStruct/arrays.

    layout="hybrid": TP rules + FSDP over `data` on stack weights.
    layout="dp":     no TP — everything FSDP over ("data", "model").

    FSDP is applied ONLY to layer-stack weights: sharding the embedding's
    d_model over `data` collides with batch-data sharding at the first
    gather and makes GSPMD replicate the global batch through the entire
    model (observed: 32 GiB full-batch score buffers)."""
    def one(path, leaf):
        names = _path_names(path)
        if layout == "dp":
            # EVERYTHING is FSDP over (data, model) — including embeddings:
            # replicated embed + moments cost ~9 GiB/dev on 150k vocabs.
            if names[-1] in _SCAN_RESIDENT:
                return P()
            stacked = any("cycles" in n for n in names)
            eff = tuple(leaf.shape[1:]) if stacked else tuple(leaf.shape)
            spec = _add_fsdp(P(), eff, mesh, axes=("data", "model"))
            if stacked:
                spec = P(None, *spec)
            return spec
        spec = param_spec(names, tuple(leaf.shape), mesh)
        if (fsdp and "stack" in names
                and names[-1] not in ("embed", "lm_head") + _SCAN_RESIDENT):
            spec = _add_fsdp(spec, tuple(leaf.shape), mesh)
        return spec
    return jax.tree_util.tree_map_with_path(one, params_shapes)


def activation_rules(cfg, mesh: Mesh, kind: str,
                     layout: str = "hybrid") -> dict[str, P]:
    """Activation sharding hints (DESIGN.md §6).

    * residual: pin the residual stream to batch-over-(pod,data) at every
      block boundary.  REQUIRED with FSDP: without it GSPMD lets the
      data-axis weight shards override batch sharding and replicates the
      global batch through the model (observed 32 GiB score buffers).
    * seq-parallel attention for head counts that do not divide the model
      axis: shard the query-seq dim of q/scores/attn-out over `model`.
    """
    if layout == "dp":
        all_axes = tuple(a for a in ("pod", "data", "model") if a in mesh.shape)
        return {"residual": P(all_axes, None, None)}
    baxes = batch_axes(mesh)
    rules: dict[str, P] = {}
    if baxes:
        rules["residual"] = P(baxes, None, None)        # (b, s, d)
    n_model = _axis_size(mesh, "model")
    if n_model > 1 and cfg.n_heads % n_model != 0:
        rules["attn_q"] = P(baxes, "model", None, None)        # (b, s, h, hd)
        rules["attn_scores"] = P(baxes, None, "model", None)   # (b, h, s, t)
        rules["attn_out"] = P(baxes, "model", None, None)      # (b, s, h, hd)
    return rules


# ----------------------------------------------------------- batch rules
def batch_axes(mesh: Mesh) -> tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.shape)


def batch_spec(batch_shapes: Any, mesh: Mesh, *, global_batch: int,
               layout: str = "hybrid") -> Any:
    """tokens/labels (b, s) -> (pod,data) on b; embeds (b, s, d) likewise;
    mrope positions (3, b, s) on axis 1.  Falls back to replication when the
    batch does not divide the data axes (e.g. long_500k's batch=1)."""
    if layout == "dp":
        baxes = tuple(a for a in ("pod", "data", "model") if a in mesh.shape)
    else:
        baxes = batch_axes(mesh)
    bsize = int(np.prod([_axis_size(mesh, a) for a in baxes]))
    shard_batch = global_batch % bsize == 0 and bsize > 1

    def one(path, leaf):
        names = _path_names(path)
        name = names[-1] if names else ""
        nd = len(leaf.shape)
        if not shard_batch:
            return P()
        if name == "positions" and nd == 3:
            return P(None, baxes, None)
        if name == "enc_embeds" or name == "inputs_embeds":
            return P(baxes, None, None)
        if nd >= 1 and leaf.shape[0] == global_batch:
            return P(baxes, *([None] * (nd - 1)))
        return P()

    return jax.tree_util.tree_map_with_path(one, batch_shapes)


# ----------------------------------------------------------- cache rules
def cache_spec(cache_shapes: Any, mesh: Mesh, *, batch: int,
               seq_shard: bool = False) -> Any:
    """KV caches (b, len, m, hd) & recurrent states.

    seq_shard=True (long_500k, batch=1): shard the cache length dim over
    `data` and recurrent widths over `model`; otherwise batch over
    (pod, data) and KV length replicated."""
    baxes = batch_axes(mesh)
    bsize = int(np.prod([_axis_size(mesh, a) for a in baxes]))

    def one(path, leaf):
        shape = leaf.shape
        nd = len(shape)
        stacked = any("cycles" in n for n in _path_names(path))
        eff = shape[1:] if stacked else shape
        pre = (None,) if stacked else ()
        if len(eff) == 0:
            return P()
        if not seq_shard and batch % bsize == 0 and bsize > 1 and eff[0] == batch:
            spec = [baxes] + [None] * (len(eff) - 1)
            # KV caches (b, L, m, hd): also shard kv-heads (else head_dim)
            # over model — a 32k cache replicated over the model axis costs
            # 16x the HBM (observed 24 GiB/dev on gemma3 decode_32k).
            if len(eff) == 4:
                if _fits(eff[2], mesh, "model"):
                    spec[2] = "model"
                elif _fits(eff[3], mesh, "model"):
                    spec[3] = "model"
            elif len(eff) >= 2 and _fits(eff[-1], mesh, "model"):
                spec[-1] = "model"      # recurrent state width
            return P(*pre, *spec)
        if seq_shard:
            # (b, L, m, hd): L -> data when divisible; recurrent (b, w): w -> model
            if len(eff) == 4 and _fits(eff[1], mesh, "data"):
                spec = [None, "data", None, None]
                if _fits(eff[2], mesh, "model"):
                    spec[2] = "model"
                elif _fits(eff[3], mesh, "model"):
                    spec[3] = "model"
                return P(*pre, *spec)
            if len(eff) >= 2 and _fits(eff[-1], mesh, "model"):
                return P(*pre, *([None] * (len(eff) - 1)), "model")
        return P(*pre, *([None] * len(eff)))

    return jax.tree_util.tree_map_with_path(one, cache_shapes)


# ------------------------------------------------------------- optimizer
def opt_specs(pspecs: Any) -> Any:
    """Moments mirror parameter specs; the scalar step is replicated."""
    from repro.optim.adamw import OptState
    return OptState(mu=pspecs, nu=pspecs, step=P())


def named(tree_specs: Any, mesh: Mesh) -> Any:
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s),
        tree_specs, is_leaf=lambda x: isinstance(x, P))
