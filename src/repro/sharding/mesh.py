"""Stream-axis device mesh + declarative sharding rules (DESIGN.md §14).

Every hot-path GF op — circulant encode, the decode-side matmul, fused
regenerate, batched regenerate — has one large *stream* axis (symbol
columns) and the paper's double-circulant structure makes every op
column-local over it: shard the stream, replicate the tiny static
operands (coefficient vectors, repair/decode matrices), and each device
computes its slice with ZERO cross-device GF arithmetic.  The mesh
layer states that once, declaratively:

* :class:`StreamMesh` — a validated 1-D ``jax.sharding.Mesh`` over the
  ``"stream"`` axis (typed :class:`MeshConfigError` on bad sizes or
  device-count mismatches);
* :class:`ShardingRule` + :func:`register_rule` / :func:`get_rule` — a
  registry mapping op name -> per-operand ``PartitionSpec``s, in the
  declarative spirit of scalax's ``MeshShardingHelper``: the exec
  planner looks the rule up by op name instead of hand-writing specs at
  every call site;
* :func:`shard_body` — wraps a dispatch-layer kernel in
  ``jax.shard_map`` under the rule's specs (``check_rep=False``: the
  bodies are pure per-shard maps, there is no replication to verify);
* :func:`use_mesh` / :func:`current_mesh` — ambient-mesh context so
  stores / checkpointers / codes built inside a ``use_mesh(...)`` block
  inherit the mesh without threading a kwarg through every layer.

CPU multi-device testing recipe (DESIGN.md §14.4): set
``XLA_FLAGS=--xla_force_host_platform_device_count=N`` *before* the
first jax import and any ``StreamMesh(m)`` with ``m <= N`` works on a
plain CPU host — the parity harness in ``tests/test_sharding.py`` and
``benchmarks/bench_shard.py`` both run that way.
"""
from __future__ import annotations

import contextlib
import contextvars
import dataclasses
from typing import Callable, Union

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

try:                                    # jax >= 0.4.35 top-level export
    from jax import shard_map as _shard_map
except ImportError:                     # pragma: no cover - older jax
    from jax.experimental.shard_map import shard_map as _shard_map

STREAM_AXIS = "stream"


class MeshConfigError(ValueError):
    """Invalid mesh construction: non-integer / non-positive axis size,
    or more shards requested than devices exist."""


class StreamMesh:
    """A validated 1-D device mesh over the ``"stream"`` axis.

    Parameters
    ----------
    n_shards : int, optional
        Mesh size (devices along the stream axis).  ``None`` uses every
        available device.
    devices : sequence of jax devices, optional
        Device pool to draw from (default ``jax.devices()``); the mesh
        takes the first ``n_shards`` of them.

    Raises
    ------
    MeshConfigError
        If ``n_shards`` is not a positive integer or exceeds the number
        of available devices.
    """

    def __init__(self, n_shards: int | None = None, *, devices=None):
        pool = list(jax.devices() if devices is None else devices)
        if n_shards is None:
            n_shards = len(pool)
        if isinstance(n_shards, bool) or not isinstance(n_shards, int):
            raise MeshConfigError(
                f"mesh axis '{STREAM_AXIS}' size must be an int, got "
                f"{n_shards!r} ({type(n_shards).__name__})")
        if n_shards < 1:
            raise MeshConfigError(
                f"mesh axis '{STREAM_AXIS}' size must be >= 1, got "
                f"{n_shards}")
        if n_shards > len(pool):
            raise MeshConfigError(
                f"mesh axis '{STREAM_AXIS}' wants {n_shards} devices but "
                f"only {len(pool)} are available; on CPU set XLA_FLAGS="
                f"--xla_force_host_platform_device_count={n_shards} "
                f"BEFORE the first jax import")
        self.size = n_shards
        self.devices = tuple(pool[:n_shards])
        self.mesh = Mesh(np.array(self.devices), (STREAM_AXIS,))

    # ------------------------------------------------------------- identity
    @property
    def is_trivial(self) -> bool:
        """1-device meshes carry no sharding — callers fall back to the
        plain dispatch path (satellite: REPRO_GF_BACKEND x device-count
        interaction stays recompile-free)."""
        return self.size == 1

    def key(self) -> tuple:
        """Registry identity: two StreamMesh objects over the same
        devices share planners (and therefore AOT executables)."""
        return (STREAM_AXIS, tuple(d.id for d in self.devices))

    # ------------------------------------------------------------ shardings
    def sharding(self, spec: P) -> NamedSharding:
        return NamedSharding(self.mesh, spec)

    def shardings(self, specs) -> tuple:
        return tuple(self.sharding(s) for s in specs)

    def shard_extent(self, s: int) -> int:
        """Per-shard stream extent before bucketing: ceil(s / size)."""
        return -(-int(s) // self.size)

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return f"StreamMesh(size={self.size})"


MeshLike = Union[StreamMesh, int, None]


def as_stream_mesh(mesh: MeshLike) -> StreamMesh | None:
    """Coerce user input: None passes through, an int builds a
    StreamMesh of that size, anything else must already be one."""
    if mesh is None or isinstance(mesh, StreamMesh):
        return mesh
    if isinstance(mesh, bool):
        raise MeshConfigError(f"mesh must be a StreamMesh, int or None, "
                              f"got {mesh!r}")
    if isinstance(mesh, int):
        return StreamMesh(mesh)
    raise MeshConfigError(f"mesh must be a StreamMesh, int or None, got "
                          f"{type(mesh).__name__}")


# ------------------------------------------------------------ rule registry
@dataclasses.dataclass(frozen=True)
class ShardingRule:
    """Declarative per-op layout: how each operand and the output split
    over the stream axis.  ``in_specs[i]`` matches positional operand i
    of the planned op; replicated operands use ``P()``."""
    op: str
    in_specs: tuple
    out_specs: P
    doc: str = ""


_RULES: dict[str, ShardingRule] = {}


def register_rule(rule: ShardingRule, *, override: bool = False) -> None:
    if rule.op in _RULES and not override:
        raise ValueError(f"sharding rule for op {rule.op!r} already "
                         f"registered (pass override=True to replace)")
    _RULES[rule.op] = rule


def get_rule(op: str) -> ShardingRule:
    try:
        return _RULES[op]
    except KeyError:
        raise KeyError(f"no sharding rule registered for op {op!r}; "
                       f"known ops: {sorted(_RULES)}") from None


def known_rules() -> tuple[str, ...]:
    return tuple(sorted(_RULES))


# The four planned GF ops (exec/plan.py).  All are column-local over the
# stream (last) axis, so the rules are pure data-parallel splits:
# zero collectives appear in the lowered HLO (asserted by the parity
# harness via steady-state compile counts + bit-exactness).
register_rule(ShardingRule(
    "matmul",
    in_specs=(P(), P(None, STREAM_AXIS)),
    out_specs=P(None, STREAM_AXIS),
    doc="decode-side (mat @ blocks) mod p: small mat replicated, the "
        "(rows, S) block operand and product split over S"))
register_rule(ShardingRule(
    "circulant_encode",
    in_specs=(P(None, STREAM_AXIS),),
    out_specs=P(None, STREAM_AXIS),
    doc="eq. (2) encode: (n, S) data split over S; coefficients are "
        "static in the kernel"))
register_rule(ShardingRule(
    "regenerate",
    in_specs=(P(), P(STREAM_AXIS), P(None, STREAM_AXIS)),
    out_specs=P(None, STREAM_AXIS),
    doc="fused newcomer kernel: (2, k+1) repair matrix replicated, "
        "r_prev (S,) and helper data (k, S) split over S"))
register_rule(ShardingRule(
    "regenerate_batch",
    in_specs=(P(), P(None, STREAM_AXIS), P(None, None, STREAM_AXIS)),
    out_specs=P(None, None, STREAM_AXIS),
    doc="vmapped fused regeneration: batch (F) axis replicated per "
        "device, stream split over S"))
register_rule(ShardingRule(
    "matmul_batch",
    in_specs=(P(), P(None, None, STREAM_AXIS)),
    out_specs=P(None, None, STREAM_AXIS),
    doc="per-element batched matmul (product-matrix batched regen, "
        "DESIGN.md §16.5): the (F, q, d) matrix stack is replicated, "
        "the (F, d, S) sends and (F, q, S) product split over S"))


def shard_body(fn: Callable, op: str, mesh: StreamMesh) -> Callable:
    """Wrap a dispatch-layer kernel body in ``shard_map`` under the
    registered rule for ``op``.  ``check_rep=False``: the bodies are
    per-shard maps with no collectives, so there is no replication
    invariant to verify (and skipping the check keeps tracing cheap)."""
    rule = get_rule(op)
    return _shard_map(fn, mesh=mesh.mesh, in_specs=rule.in_specs,
                      out_specs=rule.out_specs, check_rep=False)


# ------------------------------------------------------------ ambient mesh
_ACTIVE: contextvars.ContextVar[StreamMesh | None] = \
    contextvars.ContextVar("stream_mesh", default=None)


@contextlib.contextmanager
def use_mesh(mesh: MeshLike):
    """Ambient-mesh scope: codes / stores / checkpointers constructed
    inside inherit ``mesh`` (coerced via :func:`as_stream_mesh`)
    without explicit kwargs.  ``use_mesh(None)`` explicitly disables an
    outer ambient mesh for the scope."""
    token = _ACTIVE.set(as_stream_mesh(mesh))
    try:
        yield
    finally:
        _ACTIVE.reset(token)


def current_mesh() -> StreamMesh | None:
    return _ACTIVE.get()


__all__ = [
    "STREAM_AXIS", "MeshConfigError", "StreamMesh", "as_stream_mesh",
    "ShardingRule", "register_rule", "get_rule", "known_rules",
    "shard_body", "use_mesh", "current_mesh",
]
