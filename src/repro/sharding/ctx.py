"""Activation-sharding hint context.

GSPMD propagates parameter shardings to most intermediates, but some layouts
(notably sequence-parallel attention for head counts that do not divide the
model axis) must be stated explicitly.  Model code calls `constrain(x, kind)`
at the few relevant points; outside a `rules(...)` context (unit tests,
single-device runs) it is a no-op.  Constraints that do not divide the
tensor's dimensions are skipped silently — one policy serves every arch.
"""
from __future__ import annotations

import contextlib
import contextvars

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_CTX = contextvars.ContextVar("activation_sharding", default=None)


@contextlib.contextmanager
def rules(mesh: Mesh, table: dict[str, P]):
    tok = _CTX.set((mesh, dict(table)))
    try:
        yield
    finally:
        _CTX.reset(tok)


def _fits(shape, spec, mesh) -> bool:
    for dim, ax in zip(shape, tuple(spec)):
        if ax is None:
            continue
        axes = ax if isinstance(ax, tuple) else (ax,)
        n = int(np.prod([mesh.shape.get(a, 1) for a in axes]))
        if n > 1 and dim % n != 0:
            return False
    return True


def constrain(x, kind: str):
    ctx = _CTX.get()
    if ctx is None:
        return x
    mesh, table = ctx
    spec = table.get(kind)
    if spec is None:
        return x
    if len(tuple(spec)) > x.ndim or not _fits(x.shape, spec, mesh):
        return x
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def active() -> bool:
    return _CTX.get() is not None
