"""Activation-sharding hint context.

GSPMD propagates parameter shardings to most intermediates, but some layouts
(notably sequence-parallel attention for head counts that do not divide the
model axis) must be stated explicitly.  Model code calls `constrain(x, kind)`
at the few relevant points; outside a `rules(...)` context (unit tests,
single-device runs) it is a no-op.  Constraints that do not divide the
tensor's dimensions are skipped silently — one policy serves every arch.
"""
from __future__ import annotations

import contextlib
import contextvars

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.sharding.policy import spec_fits

_CTX = contextvars.ContextVar("activation_sharding", default=None)


@contextlib.contextmanager
def rules(mesh: Mesh, table: dict[str, P]):
    tok = _CTX.set((mesh, dict(table)))
    try:
        yield
    finally:
        _CTX.reset(tok)


def constrain(x, kind: str):
    ctx = _CTX.get()
    if ctx is None:
        return x
    mesh, table = ctx
    spec = table.get(kind)
    if spec is None:
        return x
    if len(tuple(spec)) > x.ndim or not spec_fits(spec, x.shape, mesh):
        return x
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def active() -> bool:
    return _CTX.get() is not None
