"""granite-moe-1b-a400m [moe]: 32 experts top-8, expert d_ff=512.
24L d=1024 16H kv=8 vocab=49155.  [hf:ibm-granite/granite-3.0-1b-a400m-base; hf]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="granite-moe-1b-a400m",
    family="moe",
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=8,
    d_ff=0,                      # all-MoE FFN
    vocab_size=49_155,
    layer_pattern=("gm",),
    n_experts=32,
    n_experts_per_token=8,
    moe_dff=512,
    tie_embeddings=True,
)
