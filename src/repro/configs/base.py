"""Model configuration dataclass + the shape suite assigned to this paper.

Block kinds (layer_pattern entries, cycled to n_layers):
  "ga" — global attention + dense FFN
  "la" — local (sliding-window) attention + dense FFN
  "gm" — global attention + MoE FFN (optionally + parallel dense residual FFN)
  "rg" — Griffin RG-LRU recurrent block + dense FFN
  "ml" — xLSTM mLSTM block (internal up/down projection, no separate FFN)
  "sl" — xLSTM sLSTM block (+ post MLP)
Encoder-decoder models add an encoder stack of "enc" (bidirectional attn+FFN)
blocks; decoder blocks get a cross-attention sublayer automatically.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                       # dense | moe | hybrid | ssm | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int | None = None       # default d_model // n_heads
    layer_pattern: tuple[str, ...] = ("ga",)
    window_size: int = 1024           # for "la" blocks
    # attention details
    qk_norm: bool = False
    rope_theta: float = 10_000.0
    mrope_sections: tuple[int, int, int] | None = None   # qwen2-vl M-RoPE
    # MoE
    n_experts: int = 0
    n_experts_per_token: int = 0
    moe_dff: int = 0
    dense_residual: bool = False      # arctic: parallel dense FFN next to MoE
    capacity_factor: float = 1.25
    moe_chunk: int = 1024             # sequence chunking for dispatch memory
    # recurrent (Griffin / RG-LRU)
    rnn_width: int | None = None      # default d_model
    conv_width: int = 4
    # xLSTM
    xlstm_proj_factor: float = 2.0    # mLSTM up-projection factor
    slstm_mlp_factor: float = 1.3334  # sLSTM post-MLP factor
    # encoder-decoder
    is_encoder_decoder: bool = False
    encoder_layers: int = 0
    encoder_seq: int = 1500           # whisper: 30 s of audio frames (stub)
    # modality frontend stub: model consumes precomputed embeddings
    embeds_as_input: bool = False
    # misc
    act: str = "silu"                 # dense FFN: silu => SwiGLU, gelu => GELU-MLP
    norm: str = "rms"                 # rms | layer
    tie_embeddings: bool = False
    norm_eps: float = 1e-6
    logit_softcap: float | None = None
    param_dtype: str = "float32"      # bfloat16 for memory-bound giants (arctic)
    # training
    loss_chunk: int = 512             # sequence chunking of the xent loss

    def __post_init__(self):
        if self.head_dim is None:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)
        if self.rnn_width is None:
            object.__setattr__(self, "rnn_width", self.d_model)
        if self.n_heads % max(self.n_kv_heads, 1):
            raise ValueError("n_heads must be a multiple of n_kv_heads")

    # ---- layer pattern expansion -------------------------------------
    def expanded_pattern(self) -> tuple[str, ...]:
        pat = self.layer_pattern
        reps = -(-self.n_layers // len(pat))
        return (pat * reps)[: self.n_layers]

    def cycles(self) -> tuple[int, int]:
        """(n_full_cycles, n_remainder_blocks) for scan-over-superblocks."""
        cl = len(self.layer_pattern)
        return self.n_layers // cl, self.n_layers % cl

    def is_subquadratic(self) -> bool:
        """Eligible for long_500k (DESIGN.md §5): no block attends globally,
        or global blocks are a small minority of a local/recurrent design."""
        kinds = set(self.expanded_pattern())
        if kinds <= {"la", "rg", "ml", "sl"}:
            return True
        n_global = sum(1 for k in self.expanded_pattern() if k in ("ga", "gm"))
        return n_global * 6 <= self.n_layers   # e.g. gemma3's 5:1 local:global

    def reduced(self, **overrides) -> "ModelConfig":
        """A tiny same-family config for CPU smoke tests."""
        cl = len(self.layer_pattern)
        small = dict(
            n_layers=max(2 * cl, cl),          # >= two cycles when possible
            d_model=64,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2),
            head_dim=16,
            d_ff=128 if self.d_ff else 0,
            vocab_size=512,
            window_size=min(self.window_size, 32),
            encoder_seq=32 if self.is_encoder_decoder else self.encoder_seq,
            encoder_layers=min(self.encoder_layers, 2),
            n_experts=min(self.n_experts, 4),
            n_experts_per_token=min(self.n_experts_per_token, 2),
            moe_dff=32 if self.moe_dff else 0,
            moe_chunk=16,
            loss_chunk=32,
            rnn_width=64,
        )
        if self.mrope_sections is not None and "mrope_sections" not in overrides:
            # rescale the M-RoPE sections to the reduced head_dim
            hd = overrides.get("head_dim", small["head_dim"])
            half = hd // 2
            tot = sum(self.mrope_sections)
            secs = [max(1, s * half // tot) for s in self.mrope_sections]
            secs[-1] += half - sum(secs)
            small["mrope_sections"] = tuple(secs)
        small.update(overrides)
        return dataclasses.replace(self, **small)


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                         # train | prefill | decode


# the assigned LM shape suite (4 shapes x 10 archs = 40 cells)
SHAPES: dict[str, ShapeConfig] = {
    "train_4k":    ShapeConfig("train_4k",    4_096,   256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768,  32,  "prefill"),
    "decode_32k":  ShapeConfig("decode_32k",  32_768,  128, "decode"),
    "long_500k":   ShapeConfig("long_500k",   524_288, 1,   "decode"),
}
