from .base import ModelConfig, ShapeConfig, SHAPES  # noqa: F401
from .registry import ARCH_IDS, get_config, get_shape, cells, skipped_cells  # noqa: F401
