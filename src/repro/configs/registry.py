"""Architecture registry: --arch <id> -> ModelConfig."""
from __future__ import annotations

import importlib

from .base import ModelConfig, SHAPES, ShapeConfig

_ARCH_MODULES = {
    "whisper-medium": "whisper_medium",
    "recurrentgemma-2b": "recurrentgemma_2b",
    "qwen3-4b": "qwen3_4b",
    "yi-34b": "yi_34b",
    "starcoder2-7b": "starcoder2_7b",
    "gemma3-27b": "gemma3_27b",
    "granite-moe-1b-a400m": "granite_moe_1b_a400m",
    "arctic-480b": "arctic_480b",
    "qwen2-vl-72b": "qwen2_vl_72b",
    "xlstm-1.3b": "xlstm_1_3b",
    "paper-tiny-lm": "paper_msr",
}

ARCH_IDS = tuple(k for k in _ARCH_MODULES if k != "paper-tiny-lm")


def get_config(arch: str) -> ModelConfig:
    if arch not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(_ARCH_MODULES)}")
    mod = importlib.import_module(f"repro.configs.{_ARCH_MODULES[arch]}")
    return mod.CONFIG


def get_shape(name: str) -> ShapeConfig:
    return SHAPES[name]


def cells() -> list[tuple[str, str]]:
    """All assigned (arch x shape) dry-run cells, with skip rules applied.

    Skips (recorded in DESIGN.md §5): long_500k for pure-full-attention archs.
    Whisper has a decoder, so decode shapes run (backbone exercise).
    """
    out = []
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        for shape in SHAPES.values():
            if shape.name == "long_500k" and not cfg.is_subquadratic():
                continue
            out.append((arch, shape.name))
    return out


def skipped_cells() -> list[tuple[str, str, str]]:
    out = []
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        if not cfg.is_subquadratic():
            out.append((arch, "long_500k",
                        "pure full attention — sub-quadratic required (DESIGN.md §5)"))
    return out
