"""The paper\'s own configuration surface: Double Circulant MSR code presets
(paper §III-D examples + production-scale defaults) and the tiny LM used by
the end-to-end fault-tolerance examples."""
from repro.core.circulant import CodeSpec

from .base import ModelConfig

# paper worked examples
CODE_4_2_F257 = CodeSpec.make(2, p=257, c=[1, 1])      # Fig. 3 (any field)
CODE_6_3_F5 = CodeSpec.make(3, p=5, c=[1, 1, 2])       # Fig. 4 (F_5)
# production default: 16-node storage groups over GF(257)
CODE_16_8_F257 = CodeSpec.make(8, p=257)

CONFIG = ModelConfig(
    name="paper-tiny-lm",
    family="dense",
    n_layers=4,
    d_model=256,
    n_heads=8,
    n_kv_heads=4,
    d_ff=1024,
    vocab_size=4096,
    tie_embeddings=True,
)
