"""xlstm-1.3b [ssm]: 7:1 mLSTM:sLSTM interleave.  48L d=2048 4 heads vocab=50304,
d_ff=0 (mLSTM blocks carry their own up/down projection).  [arXiv:2405.04517;
unverified]  mLSTM in stabilized parallel form for train/prefill; matrix-memory
recurrence for decode."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-1.3b",
    family="ssm",
    n_layers=48,
    d_model=2048,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab_size=50_304,
    layer_pattern=("ml",) * 7 + ("sl",),
    xlstm_proj_factor=2.0,
)
