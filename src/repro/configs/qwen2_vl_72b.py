"""qwen2-vl-72b [vlm]: M-RoPE (sections 16/24/24 over t/h/w), dynamic-resolution
vision frontend stubbed — input_specs() supplies patch+text embeddings.
80L d=8192 64H kv=8 d_ff=29568 vocab=152064.  [arXiv:2409.12191; hf]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-72b",
    family="vlm",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=29_568,
    vocab_size=152_064,
    mrope_sections=(16, 24, 24),
    embeds_as_input=True,
    rope_theta=1_000_000.0,
)
