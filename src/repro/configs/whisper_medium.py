"""whisper-medium [audio]: enc-dec transformer backbone, conv frontend stubbed.

24 enc + 24 dec layers, d_model=1024, 16 heads (MHA), d_ff=4096, vocab=51865.
[arXiv:2212.04356; unverified]  Frontend: input_specs() supplies precomputed
log-mel frame embeddings (b, 1500, d_model); see repro/models/frontend.py.
Positional scheme unified to RoPE across the framework (backbone exercise).
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-medium",
    family="audio",
    n_layers=24,                 # decoder stack
    encoder_layers=24,
    is_encoder_decoder=True,
    embeds_as_input=True,        # encoder side consumes frame embeddings
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=4096,
    vocab_size=51865,
    act="gelu",
    norm="layer",
    tie_embeddings=True,
    encoder_seq=1500,
)
