"""gemma3-27b [dense]: 5:1 local:global interleave, 128k context, GeGLU, qk-norm.
62L d=5376 32H kv=16 head_dim=128 d_ff=21504 vocab=262144.
[hf:google/gemma-3-1b-pt; unverified]  Window 1024 on local layers."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-27b",
    family="dense",
    n_layers=62,
    d_model=5376,
    n_heads=32,
    n_kv_heads=16,
    head_dim=128,
    d_ff=21_504,
    vocab_size=262_144,
    layer_pattern=("la", "la", "la", "la", "la", "ga"),
    window_size=1024,
    qk_norm=True,
    act="geglu",
    rope_theta=1_000_000.0,
    tie_embeddings=True,
)
