"""recurrentgemma-2b [hybrid]: Griffin — RG-LRU recurrent blocks + local attn, 1:2.

26L, d_model=2560, 10 heads (MQA kv=1, head_dim 256), d_ff=7680, vocab=256000.
[arXiv:2402.19427; hf]  Pattern (rg, rg, la) cycled; window 2048.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    n_layers=26,
    d_model=2560,
    n_heads=10,
    n_kv_heads=1,
    head_dim=256,
    d_ff=7680,
    vocab_size=256_000,
    layer_pattern=("rg", "rg", "la"),
    window_size=2048,
    rnn_width=2560,
    conv_width=4,
    act="geglu",
)
