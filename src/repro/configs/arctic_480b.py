"""arctic-480b [moe]: 128 experts top-2 PLUS parallel dense residual FFN.
35L d=7168 56H kv=8 expert d_ff=4864 vocab=32000.
[hf:Snowflake/snowflake-arctic-base; hf]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="arctic-480b",
    family="moe",
    n_layers=35,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    d_ff=4864,                   # dense residual branch width
    vocab_size=32_000,
    layer_pattern=("gm",),
    n_experts=128,
    n_experts_per_token=2,
    moe_dff=4864,
    dense_residual=True,
    # 480B fp32 params + fp32 moments = 5.76 TB > a 256-chip v5e pod's 4 TB
    # HBM: store params (and, via dryrun policy, moments) in bf16.  See
    # EXPERIMENTS.md §Dry-run for the memory ledger.
    param_dtype="bfloat16",
)
