"""starcoder2-7b [dense]: GQA kv=4, RoPE, plain-GELU MLP, LayerNorm.
32L d=4608 36H d_ff=18432 vocab=49152.  [arXiv:2402.19173; hf]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-7b",
    family="dense",
    n_layers=32,
    d_model=4608,
    n_heads=36,
    n_kv_heads=4,
    d_ff=18_432,
    vocab_size=49_152,
    act="gelu",
    norm="layer",
    rope_theta=1_000_000.0,
)
