"""Pure-jnp oracles for the GF(p) kernels (exact integer semantics)."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from .envelope import int32_lazy_terms, require_int32_envelope


def gf_matmul_ref(a: jnp.ndarray, b: jnp.ndarray, p: int) -> jnp.ndarray:
    """(a @ b) mod p with exact integer accumulation.

    a: (m, k) int32 in [0, p); b: (k, s) int32 in [0, p).  Oracle uses
    float64-free int32 chunked accumulation (chunks keep partial sums within
    int32), matching repro.core.gf.matmul semantics.
    """
    require_int32_envelope(p)
    a = jnp.asarray(a, jnp.int32) % p
    b = jnp.asarray(b, jnp.int32) % p
    k = a.shape[-1]
    chunk = int32_lazy_terms(p)
    out = None
    for s0 in range(0, k, chunk):
        part = (a[:, s0:s0 + chunk] @ b[s0:s0 + chunk, :]) % p
        out = part if out is None else (out + part) % p
    return out


def circulant_encode_ref(data: jnp.ndarray, c, p: int) -> jnp.ndarray:
    """Redundancy blocks r[i] = sum_{u=1..k} c_u * data[(i - k - u) mod n] mod p.

    data: (n, s) int32; c: (k,) with n = 2k.  This is the paper's eq. (2) in
    circulant closed form — the oracle realizes it with explicit rolls.
    """
    require_int32_envelope(p)
    data = jnp.asarray(data, jnp.int32) % p
    c = np.asarray(c, dtype=np.int64) % p
    k = c.shape[0]
    n = data.shape[0]
    assert n == 2 * k, (n, k)
    # lazy mod-folding: each term is <= (p-1)^2, so int32 headroom admits
    # int32_lazy_terms(p) un-folded terms (32767 for p = 257) — one fold
    # for any realistic k instead of one per term.
    chunk = int32_lazy_terms(p)
    out = jnp.zeros_like(data)
    pending = 0
    for u in range(1, k + 1):
        # row j holds r_{j+1} (nodes are 1-indexed in the paper):
        # r_{j+1} = sum_u c_u data[(j+1-k-u) mod n]  =>  roll by k+u-1
        rolled = jnp.roll(data, shift=k + u - 1, axis=0)
        out = out + int(c[u - 1]) * rolled
        pending += 1
        if pending == chunk:
            out = out % p
            pending = 0
    return out % p


def gf_axpy_ref(y: jnp.ndarray, alpha: int, x: jnp.ndarray, p: int) -> jnp.ndarray:
    """(y + alpha * x) mod p — the regenerate-path primitive."""
    require_int32_envelope(p)
    return (jnp.asarray(y, jnp.int32) + (int(alpha) % p) * (jnp.asarray(x, jnp.int32) % p)) % p
