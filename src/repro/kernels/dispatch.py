"""GF(p) backend dispatch: a registry of exact compute implementations
(DESIGN.md §3).

Every hot path of the MSR layer reduces to three primitives over GF(p):

    matmul(a, b, p)              (m, k) @ (k, s) mod p
    circulant_encode(data, c, p) the paper's eq. (2), k MACs/symbol
    axpy(y, alpha, x, p)         the regenerate-path scale+accumulate

Each registered backend implements all three with *bit-exact* integer
semantics; they differ only in how the arithmetic is scheduled:

  * ``jnp-int32``         jit'd integer lanes with lazy mod-folding — a chunk
                          of ~(2^31-1)/(p-1)^2 contraction terms (32767 for
                          p = 257, envelope.int32_lazy_terms) accumulates in
                          int32 before a single fold.  The fast exact path
                          on CPU/GPU.
  * ``jnp-f32``           einsum at HIGHEST precision (MXU-exact on TPU):
                          fp32 chunk partials < 2^24, accumulated lazily in
                          int32 (127 chunks per fold — see DESIGN.md §3.2).
                          Falls back to integer lanes when (p-1)^2 > 2^24-1
                          (no fp32 schedule is exact there).
  * ``pallas``            native Pallas TPU kernels (VMEM-tiled, MXU dots).
  * ``pallas-interpret``  the same kernels in interpret mode — validation
                          only, never auto-selected (it is the slowest
                          possible execution mode).

Selection is automatic from ``(jax.default_backend(), p, k)`` via
:func:`select`, overridable with the ``REPRO_GF_BACKEND`` environment
variable or :func:`set_default_backend`.
"""
from __future__ import annotations

import dataclasses
import functools
import os
from typing import Callable, Optional, Sequence

import jax
import jax.numpy as jnp

from .envelope import (LAZY_F32_CHUNKS, MXU_FOLD_CAP, f32_exact_terms,
                       int32_lazy_terms, require_int32_envelope)

ENV_VAR = "REPRO_GF_BACKEND"

# canonical name used throughout the docs/benchmarks
int32_headroom_terms = int32_lazy_terms


def fold_count(backend_name: str, p: int, k: int) -> int:
    """Number of ``% p`` folds a k-term contraction costs on a backend.

    The dispatch layer's headline saving: the int32 lazy path folds
    ceil(k / 32767) times where the eager fp32 path folds ceil(k / 128).
    Mirrors the implementations: `jnp-f32` falls back to integer lanes
    when no fp32 schedule is exact; the Pallas kernels reject such p."""
    if backend_name == "jnp-int32":
        require_int32_envelope(p)
        return -(-k // int32_lazy_terms(p))
    if backend_name in ("jnp-f32", "pallas", "pallas-interpret"):
        depth = f32_exact_terms(p)
        if depth < 1:
            if backend_name == "jnp-f32":               # int32 fallback
                require_int32_envelope(p)
                return -(-k // int32_lazy_terms(p))
            raise ValueError(f"(p-1)^2 > 2^24-1: no exact fp32 schedule "
                             f"for p={p} on {backend_name}")
        if backend_name != "jnp-f32":      # the Pallas kernel caps at 128
            depth = min(depth, MXU_FOLD_CAP)
        chunks = -(-k // depth)
        return -(-chunks // LAZY_F32_CHUNKS)
    raise KeyError(backend_name)


# ---------------------------------------------------------------------------
# jnp-int32: integer lanes, lazy folding by int32 headroom
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("p",))
def _matmul_i32(a, b, p: int):
    require_int32_envelope(p)
    a = jnp.asarray(a, jnp.int32) % p
    b = jnp.asarray(b, jnp.int32) % p
    k = a.shape[-1]
    chunk = int32_lazy_terms(p)
    if k <= chunk:
        return jnp.einsum("...mk,...kn->...mn", a, b) % p
    # fold the running sum every chunk: for p near the int32 ceiling the
    # chunk count itself can be large, so unfolded < p partials could wrap
    out = None
    for s in range(0, k, chunk):
        part = jnp.einsum("...mk,...kn->...mn",
                          a[..., s:s + chunk], b[..., s:s + chunk, :]) % p
        out = part if out is None else (out + part) % p
    return out


@functools.partial(jax.jit, static_argnames=("c", "p"))
def _circulant_i32(data, c: tuple[int, ...], p: int):
    require_int32_envelope(p)
    data = jnp.asarray(data, jnp.int32) % p
    k = len(c)
    chunk = int32_lazy_terms(p)    # accumulates onto a post-fold residual
    acc = jnp.zeros_like(data)
    pending = 0
    for u in range(1, k + 1):
        acc = acc + c[u - 1] * jnp.roll(data, shift=k + u - 1, axis=0)
        pending += 1
        if pending == chunk:
            acc = acc % p
            pending = 0
    return acc % p


@functools.partial(jax.jit, static_argnames=("alpha", "p"))
def _axpy_i32(y, alpha: int, x, p: int):
    require_int32_envelope(p)             # guarantees (p-1) + (p-1)^2 < 2^31
    y = jnp.asarray(y, jnp.int32) % p
    x = jnp.asarray(x, jnp.int32) % p
    return (y + (alpha % p) * x) % p


# ---------------------------------------------------------------------------
# jnp-f32: HIGHEST-precision einsum (MXU-exact), lazy int32 accumulation
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("p",))
def _matmul_f32(a, b, p: int):
    depth = f32_exact_terms(p)
    if depth < 1:                  # a single product already rounds in fp32
        return _matmul_i32(a, b, p)
    a = jnp.asarray(a, jnp.int32) % p
    b = jnp.asarray(b, jnp.int32) % p
    k = a.shape[-1]
    af, bf = a.astype(jnp.float32), b.astype(jnp.float32)
    acc, pending = None, 0
    for s in range(0, k, depth):
        prod = jnp.einsum("...mk,...kn->...mn",
                          af[..., s:s + depth], bf[..., s:s + depth, :],
                          precision=jax.lax.Precision.HIGHEST)
        part = prod.astype(jnp.int32)       # each partial < 2^24: exact
        acc = part if acc is None else acc + part
        pending += 1
        if pending == LAZY_F32_CHUNKS:      # int32 headroom exhausted: fold
            acc = acc % p
            pending = 0
    return acc % p


def _circulant_f32(data, c: tuple[int, ...], p: int):
    # term magnitudes match the int32 analysis; reuse the integer scheduler
    return _circulant_i32(data, c, p)


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class GFBackend:
    """One exact implementation of the three GF primitives."""
    name: str
    matmul: Callable      # (a, b, p) -> (m, s) int32
    circulant_encode: Callable  # (data, c: tuple, p) -> (n, s) int32
    axpy: Callable        # (y, alpha, x, p) -> int32
    selectable: bool = True     # False: validation-only, never auto-picked

    def msr_matmul(self):
        """Adapter for DoubleCirculantMSR(..., matmul=...)."""
        return lambda a, b, p: self.matmul(a, b, p)

    def planner(self, p: int, **plan_kwargs):
        """The shared execution planner for this backend at modulus p
        (DESIGN.md §11): shape-bucketed AOT executables over this
        backend's primitives.  Lazy import — the exec layer sits above
        kernels and plain kernel users never pay for it."""
        from repro.exec.plan import get_planner
        return get_planner(self, p, **plan_kwargs)


_REGISTRY: dict[str, GFBackend] = {}
_default_override: Optional[str] = None


def register(backend: GFBackend) -> GFBackend:
    _REGISTRY[backend.name] = backend
    return backend


def get(name: str) -> GFBackend:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(f"unknown GF backend {name!r}; "
                       f"registered: {sorted(_REGISTRY)}") from None


def get_backend(name: Optional[str] = None, *, p: int = 257,
                k: Optional[int] = None) -> GFBackend:
    """Resolve a GF backend: by name, or auto-selected for this host.

    The one-stop entry point the README documents.  With ``name`` it is
    a registry lookup (including validation-only backends like
    ``pallas-interpret``); without, it defers to :func:`select`, which
    applies the ``REPRO_GF_BACKEND`` env var, any
    :func:`set_default_backend` override, and finally the platform rule.

    Parameters
    ----------
    name : str, optional
        Registered backend name (``jnp-int32``, ``jnp-f32``, ``pallas``,
        ``pallas-interpret``).  None auto-selects.
    p : int
        Field modulus; bounds which backends are exact (see
        `kernels/envelope.py`: fp32 schedules need p <= 4097, everything
        needs p <= 46341).
    k : int, optional
        Contraction depth hint for the platform rule.

    Returns
    -------
    GFBackend
        The resolved backend; its ``matmul`` / ``circulant_encode`` /
        ``axpy`` are bit-exact over GF(p).

    Raises
    ------
    KeyError
        Unknown ``name``.
    ValueError
        ``p`` outside every exact envelope (p > 46341).

    Examples
    --------
    >>> get_backend("jnp-int32").name
    'jnp-int32'
    >>> get_backend(p=257).name in registered_backends()
    True
    """
    return get(name) if name else select(p, k)


def registered_backends() -> list[str]:
    return sorted(_REGISTRY)


def set_default_backend(name: Optional[str]) -> None:
    """Process-wide override (None restores automatic selection)."""
    global _default_override
    if name is not None:
        get(name)
    _default_override = name


def select(p: int = 257, k: Optional[int] = None) -> GFBackend:
    """Pick the fastest exact backend for this host from
    ``(jax.default_backend(), p, k)``.

    Priority: ``REPRO_GF_BACKEND`` env var > :func:`set_default_backend` >
    platform rule.  Explicit pins may name validation-only backends; the
    automatic rule only ever returns ``selectable`` ones.  Raises for p
    outside every exact envelope (p > envelope.INT32_MAX_P).
    """
    env = os.environ.get(ENV_VAR)
    if env:
        if env not in _REGISTRY:
            raise ValueError(
                f"{ENV_VAR}={env!r} is not a registered GF backend; "
                f"valid values: {', '.join(sorted(_REGISTRY))}")
        return get(env)
    if _default_override:
        return get(_default_override)
    require_int32_envelope(p)      # int32 lanes are the widest exact path
    platform = jax.default_backend()
    if platform == "tpu" and f32_exact_terms(p) >= 8 and (k is None or k >= 2):
        # MXU territory: the native kernel wins while fp32 chunks are deep
        # enough to amortize the fold and the contraction is a real matmul
        # (k == 1 degenerates to a scale — not worth an MXU pass); shallow
        # or out-of-envelope fp32 depth falls back to integer lanes.
        name = "pallas"
    else:
        name = "jnp-int32"
    chosen = get(name)
    assert chosen.selectable, name     # registry invariant for auto-picks
    return chosen


# ---------------------------------------------------------------------------
# Backend instances.  The Pallas kernel modules (and jax.experimental.pallas)
# are imported inside the call wrappers, on FIRST USE — CPU-only consumers
# that stay on the jnp backends never pay the pallas import.
# ---------------------------------------------------------------------------

def _pallas(interpret: bool):
    def matmul(a, b, p):
        from .gf_matmul import gf_matmul as pk_matmul
        return pk_matmul(a, b, p, interpret=interpret)

    def circ(data, c, p):
        from .circulant_encode import circulant_encode as pk_circ
        return pk_circ(data, tuple(int(x) for x in c), p, interpret=interpret)

    def axpy(y, alpha, x, p):
        from .ref import gf_axpy_ref
        return gf_axpy_ref(y, int(alpha), x, p)

    return matmul, circ, axpy


def _norm_c(fn):
    @functools.wraps(fn)
    def wrapped(data, c, p):
        return fn(data, tuple(int(x) % p for x in c), p)
    return wrapped


register(GFBackend(
    name="jnp-int32",
    matmul=_matmul_i32,
    circulant_encode=_norm_c(_circulant_i32),
    axpy=lambda y, alpha, x, p: _axpy_i32(y, int(alpha), x, p),
))

register(GFBackend(
    name="jnp-f32",
    matmul=_matmul_f32,
    circulant_encode=_norm_c(_circulant_f32),
    axpy=lambda y, alpha, x, p: _axpy_i32(y, int(alpha), x, p),
))

_pm, _pc, _pa = _pallas(interpret=False)
register(GFBackend(name="pallas", matmul=_pm, circulant_encode=_pc, axpy=_pa))

_im, _ic, _ia = _pallas(interpret=True)
register(GFBackend(name="pallas-interpret", matmul=_im, circulant_encode=_ic,
                   axpy=_ia, selectable=False))


__all__ = [
    "GFBackend", "register", "get", "get_backend", "select",
    "registered_backends",
    "set_default_backend", "int32_headroom_terms", "int32_lazy_terms",
    "f32_exact_terms", "fold_count", "LAZY_F32_CHUNKS", "ENV_VAR",
]
