"""Pallas TPU kernel: GF(p) matrix multiply  (A @ B) mod p.

The encode/reconstruct hot path of the MSR layer: A is the tiny (<= 512 wide)
code matrix (M^T, a solve inverse, or a coefficient row), B is the symbol
stream — gigabytes of checkpoint state cut into (k, S) blocks.

TPU-native trick (DESIGN.md §2): for p = 257, symbols 0..256 are exact in
bf16 and a <=128-term dot stays < 2^24, exact in the MXU's fp32 accumulator.
The kernel therefore:
  * tiles BOTH the output-row axis and the stream axis through VMEM
    ((BM, k) x (k, BS) per grid step), so n = 512 reconstructs stay inside
    the ~16 MB VMEM budget instead of holding a (512, BS) fp32 tile set,
  * contracts on the MXU via jnp.dot(..., preferred_element_type=f32),
  * accumulates fp32 chunk partials (< 2^24 each) LAZILY in int32: the VPU
    folds `mod p` only every 127 chunks — up to ~127x fewer folds than the
    eager per-chunk schedule (DESIGN.md §3.2),
emitting exact int32 symbols.  The fp32 chunk depth adapts as
(2^24-1)/(p-1)^2 (255 for p = 257, clamped to the MXU-friendly 128); p with
(p-1)^2 > 2^24-1 (p > 4097) is REJECTED — a single product already rounds
in fp32, so no MXU schedule is exact and dispatch routes such p to the
integer-lane backends instead.

Validated on CPU via interpret=True against ref.gf_matmul_ref; dispatched as
the `pallas` / `pallas-interpret` backends (repro.kernels.dispatch).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .envelope import LAZY_F32_CHUNKS as LAZY_CHUNKS
from .envelope import MXU_FOLD_CAP as FOLD
from .envelope import f32_exact_terms


def _fold_depth(p: int) -> int:
    """Largest chunk depth whose worst-case partial dot stays < 2^24.

    Raises for p outside the fp32 envelope: when (p-1)^2 > 2^24-1 even a
    single product rounds, so this kernel cannot be exact at all."""
    d = f32_exact_terms(p)
    if d < 1:
        raise ValueError(f"(p-1)^2 > 2^24-1: no exact fp32 MXU schedule for "
                         f"p={p}; use the jnp-int32 dispatch backend")
    return min(FOLD, d)


def _gf_matmul_kernel(a_ref, b_ref, o_ref, *, p: int):
    """One grid step: o[BM, BS] = (a[BM, k] @ b[k, BS]) mod p, exact."""
    a = a_ref[...].astype(jnp.float32)
    b = b_ref[...].astype(jnp.float32)
    k = a.shape[1]
    depth = _fold_depth(p)
    acc = jnp.zeros((a.shape[0], b.shape[1]), jnp.int32)
    pending = 0
    # static unroll over fold chunks: k is small (code dimension n <= 512)
    for s in range(0, k, depth):
        prod = jnp.dot(a[:, s:s + depth], b[s:s + depth, :],
                       preferred_element_type=jnp.float32)
        acc = acc + prod.astype(jnp.int32)    # lazy: partial < 2^24, no fold
        pending += 1
        if pending == LAZY_CHUNKS:            # int32 headroom exhausted
            acc = acc % p
            pending = 0
    o_ref[...] = acc % p


@functools.partial(jax.jit,
                   static_argnames=("p", "block_m", "block_s", "interpret"))
def gf_matmul(a: jnp.ndarray, b: jnp.ndarray, p: int = 257, *,
              block_m: int = 128, block_s: int = 512,
              interpret: bool = True) -> jnp.ndarray:
    """(a @ b) mod p via Pallas.  a: (m, k) int32, b: (k, s) int32.

    2-D grid: output rows tiled by block_m, the symbol stream axis by
    block_s (zero padding is mod-p neutral under matmul).
    """
    a = jnp.asarray(a, jnp.int32) % p
    b = jnp.asarray(b, jnp.int32) % p
    m, k = a.shape
    k2, s = b.shape
    if k != k2:
        raise ValueError(f"contraction mismatch: {a.shape} @ {b.shape}")
    block_m = min(block_m, m) or 1
    pad_m = (-m) % block_m
    if pad_m:
        a = jnp.pad(a, ((0, pad_m), (0, 0)))
    pad_s = (-s) % block_s
    if pad_s:
        b = jnp.pad(b, ((0, 0), (0, pad_s)))
    m_pad, s_pad = m + pad_m, s + pad_s
    grid = (m_pad // block_m, s_pad // block_s)
    out = pl.pallas_call(
        functools.partial(_gf_matmul_kernel, p=p),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, k), lambda i, j: (i, 0)),  # code-matrix rows
            pl.BlockSpec((k, block_s), lambda i, j: (0, j)),  # stream tile
        ],
        out_specs=pl.BlockSpec((block_m, block_s), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m_pad, s_pad), jnp.int32),
        interpret=interpret,
    )(a, b)
    return out[:m, :s]
