"""Pallas TPU kernel: GF(p) matrix multiply  (A @ B) mod p.

The encode/reconstruct hot path of the MSR layer: A is the tiny (<= 512 wide)
code matrix (M^T, a solve inverse, or a coefficient row), B is the symbol
stream — gigabytes of checkpoint state cut into (k, S) blocks.

TPU-native trick (DESIGN.md §2): for p = 257, symbols 0..256 are exact in
bf16 and a <=128-term dot stays < 2^24, exact in the MXU's fp32 accumulator.
The kernel therefore:
  * streams B through VMEM in (k, BS)-shaped tiles (BS 128-aligned),
  * contracts on the MXU via jnp.dot(..., preferred_element_type=f32),
  * folds `mod p` on the VPU every FOLD=128 contraction terms,
emitting exact int32 symbols.  Works for any p with (p-1)^2 * 128 < 2^24
... i.e. p <= 257 single-fold; larger p uses more folds of smaller depth.

Validated on CPU via interpret=True against ref.gf_matmul_ref.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

FOLD = 128  # max exact contraction depth for p=257 in fp32


def _fold_depth(p: int) -> int:
    """Largest chunk depth whose worst-case partial dot stays < 2^24."""
    d = (2**24 - 1) // max((p - 1) ** 2, 1)
    return max(1, min(FOLD, d))


def _gf_matmul_kernel(a_ref, b_ref, o_ref, *, p: int):
    """One grid step: o[m, BS] = (a[m, k] @ b[k, BS]) mod p, exact."""
    a = a_ref[...].astype(jnp.float32)
    b = b_ref[...].astype(jnp.float32)
    k = a.shape[1]
    depth = _fold_depth(p)
    acc = jnp.zeros((a.shape[0], b.shape[1]), jnp.int32)
    # static unroll over fold chunks: k is small (code dimension n <= 512)
    for s in range(0, k, depth):
        prod = jnp.dot(a[:, s:s + depth], b[s:s + depth, :],
                       preferred_element_type=jnp.float32)
        acc = (acc + prod.astype(jnp.int32)) % p
    o_ref[...] = acc


@functools.partial(jax.jit, static_argnames=("p", "block_s", "interpret"))
def gf_matmul(a: jnp.ndarray, b: jnp.ndarray, p: int = 257, *,
              block_s: int = 512, interpret: bool = True) -> jnp.ndarray:
    """(a @ b) mod p via Pallas.  a: (m, k) int32, b: (k, s) int32.

    The symbol stream axis s is padded to a multiple of block_s (zero symbols
    are mod-p neutral under matmul) and tiled through VMEM.
    """
    a = jnp.asarray(a, jnp.int32) % p
    b = jnp.asarray(b, jnp.int32) % p
    m, k = a.shape
    k2, s = b.shape
    if k != k2:
        raise ValueError(f"contraction mismatch: {a.shape} @ {b.shape}")
    pad = (-s) % block_s
    if pad:
        b = jnp.pad(b, ((0, 0), (0, pad)))
    s_pad = s + pad
    grid = (s_pad // block_s,)
    out = pl.pallas_call(
        functools.partial(_gf_matmul_kernel, p=p),
        grid=grid,
        in_specs=[
            pl.BlockSpec((m, k), lambda i: (0, 0)),        # code matrix: resident
            pl.BlockSpec((k, block_s), lambda i: (0, i)),  # stream tile
        ],
        out_specs=pl.BlockSpec((m, block_s), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((m, s_pad), jnp.int32),
        interpret=interpret,
    )(a, b)
    return out[:, :s]
