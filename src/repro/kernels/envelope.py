"""The lazy mod-folding exactness envelope (DESIGN.md §3.2) — single source
of truth for every chunk/fold bound in the GF compute layer.

Two accumulation regimes:

* integer lanes: every term is <= (p-1)^2; a partial sum of
  ``int32_lazy_terms(p)`` terms stays inside int32 even when it lands on a
  post-fold residual (< p), so one `% p` per chunk suffices.
* fp32 chunk sums: a dot of ``f32_exact_terms(p)`` terms is exact in fp32
  (< 2^24).  Cast to int32, ``LAZY_F32_CHUNKS`` such partials accumulate
  before a fold — the post-fold residual (< p <= 2^24) costs one chunk of
  headroom, so (LAZY + 1) * (2^24 - 1) <= 2^31 - 1  =>  LAZY = 127.

Both term helpers return 0 when a SINGLE product already exceeds the
range ((p-1)^2 > 2^24 - 1 for fp32, > 2^31 - 1 for int32): no schedule in
that regime is exact, and callers must reject (``require_int32_envelope``)
or fall back.
"""
from __future__ import annotations

LAZY_F32_CHUNKS = (2**31 - 1) // (2**24 - 1) - 1      # = 127

# the Pallas matmul kernel caps its fp32 chunk depth at the MXU-native 128
# even when f32_exact_terms(p) allows deeper chunks
MXU_FOLD_CAP = 128

# largest p whose single product (p-1)^2 fits int32: int32 lanes are the
# widest exact path this layer has, so this bounds the whole compute layer
INT32_MAX_P = 46341


def int32_lazy_terms(p: int) -> int:
    """Max un-folded terms per int32 chunk: residual (< p) + chunk * (p-1)^2
    must stay <= 2^31 - 1.  32767 terms for p = 257; 0 when even one
    product overflows int32 (p > 46341)."""
    return (2**31 - 1 - (p - 1)) // max((p - 1) ** 2, 1)


def require_int32_envelope(p: int) -> None:
    if int32_lazy_terms(p) < 1:
        raise ValueError(f"(p-1)^2 > 2^31-1: int32 lanes cannot be exact for "
                         f"p={p} (largest supported p is {INT32_MAX_P})")


def f32_exact_terms(p: int) -> int:
    """Max contraction terms exact in a single fp32 accumulation:
    terms * (p-1)^2 <= 2^24 - 1.  255 for p = 257; 0 when even one
    product is inexact ((p-1)^2 > 2^24 - 1, i.e. p > 4097)."""
    return (2**24 - 1) // max((p - 1) ** 2, 1)


__all__ = ["LAZY_F32_CHUNKS", "INT32_MAX_P", "int32_lazy_terms",
           "f32_exact_terms", "require_int32_envelope"]
