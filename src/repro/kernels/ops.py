"""Public jit'd wrappers around the Pallas GF kernels.

`interpret` defaults to True off-TPU (this container is CPU-only; the kernels
target TPU VMEM/MXU and are validated in interpret mode per DESIGN.md).
On a TPU backend the same calls compile natively (interpret=False).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import ref
from .circulant_encode import circulant_encode as _circulant_encode
from .gf_matmul import gf_matmul as _gf_matmul


@functools.cache
def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def gf_matmul(a, b, p: int = 257, *, block_s: int = 512,
              interpret: bool | None = None) -> jnp.ndarray:
    """Exact (a @ b) mod p — kernel-backed."""
    if interpret is None:
        interpret = _default_interpret()
    return _gf_matmul(a, b, p, block_s=block_s, interpret=interpret)


def circulant_encode(data, c, p: int = 257, *, block_s: int = 512,
                     interpret: bool | None = None) -> jnp.ndarray:
    """MSR redundancy blocks from data blocks — kernel-backed, coefficients
    compile-time-specialized (embedded property)."""
    if interpret is None:
        interpret = _default_interpret()
    return _circulant_encode(data, tuple(int(x) for x in c), p,
                             block_s=block_s, interpret=interpret)


def msr_matmul_backend(p: int = 257, *, block_s: int = 512,
                       interpret: bool | None = None):
    """A drop-in `matmul(a, b, p)` for DoubleCirculantMSR(..., matmul=...)."""
    def matmul(a, b, p_inner=p):
        return gf_matmul(a, b, p_inner, block_s=block_s, interpret=interpret)
    return matmul


# re-export oracles for test convenience
gf_matmul_ref = ref.gf_matmul_ref
circulant_encode_ref = ref.circulant_encode_ref

__all__ = ["gf_matmul", "circulant_encode", "msr_matmul_backend",
           "gf_matmul_ref", "circulant_encode_ref"]
