"""Public GF(p) compute entry points, backend-dispatched (DESIGN.md §3).

Every call routes through the :mod:`repro.kernels.dispatch` registry: the
fastest exact implementation for this host is chosen automatically from
``(jax.default_backend(), p, k)`` — jit'd int32 lanes on CPU/GPU, native
Pallas kernels on TPU — and can be pinned per call (``backend=``), per
process (:func:`dispatch.set_default_backend`), or via the
``REPRO_GF_BACKEND`` environment variable.

``pallas-interpret`` (the seed repo's only execution mode on CPU, and the
slowest possible one) remains registered for kernel validation but is never
auto-selected.
"""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp

from . import dispatch, ref


def _resolve(backend: Optional[str], p: int, k: Optional[int]) -> dispatch.GFBackend:
    if backend is None:
        return dispatch.select(p, k)
    return dispatch.get(backend)


def gf_matmul(a, b, p: int = 257, *, backend: Optional[str] = None) -> jnp.ndarray:
    """Exact (a @ b) mod p — dispatched to the fastest exact backend."""
    a = jnp.asarray(a)
    return _resolve(backend, p, a.shape[-1]).matmul(a, b, p)


def circulant_encode(data, c, p: int = 257, *,
                     backend: Optional[str] = None) -> jnp.ndarray:
    """MSR redundancy blocks from data blocks (paper eq. (2)) — dispatched;
    coefficients are compile-time-specialized (embedded property)."""
    c = tuple(int(x) for x in c)
    if any(x % p == 0 for x in c):
        raise ValueError("coefficients must be nonzero (paper §III-A)")
    return _resolve(backend, p, len(c)).circulant_encode(data, c, p)


def gf_axpy(y, alpha: int, x, p: int = 257, *,
            backend: Optional[str] = None) -> jnp.ndarray:
    """(y + alpha * x) mod p — the regenerate-path primitive, dispatched."""
    return _resolve(backend, p, None).axpy(y, alpha, x, p)


def msr_matmul_backend(p: int = 257, *, backend: Optional[str] = None):
    """A drop-in `matmul(a, b, p)` for DoubleCirculantMSR(..., matmul=...)."""
    def matmul(a, b, p_inner=p):
        return gf_matmul(a, b, p_inner, backend=backend)
    return matmul


# re-export oracles for test convenience
gf_matmul_ref = ref.gf_matmul_ref
circulant_encode_ref = ref.circulant_encode_ref
gf_axpy_ref = ref.gf_axpy_ref

__all__ = ["gf_matmul", "circulant_encode", "gf_axpy", "msr_matmul_backend",
           "gf_matmul_ref", "circulant_encode_ref", "gf_axpy_ref", "dispatch"]
