"""Pallas TPU kernel: double circulant MSR encode (paper eq. (2)).

Computes the n redundancy blocks  r[i] = sum_{u=1..k} c_u * a[(i-k-u) mod n]
without materializing the n x n matrix M: the circulant structure is realized
as k static *rolls* of the resident data tile — each roll lowers to two
contiguous VMEM slices (no gathers), and the coefficients are baked into the
kernel as compile-time constants (the paper's *embedded property*: the code
is precalculated, so the kernel is specialized per CodeSpec).

Arithmetic-intensity note: dense (M^T @ a) does n MACs per output symbol;
this kernel does k = n/2 — half the work and half the VMEM traffic for the
same result, which is exactly the structural win the paper's construction
buys over a generic MDS encode.

Exactness (lazy folding, DESIGN.md §3.2): every accumulated term is
c_u * a_j <= (p-1)^2, so int32 holds ~(2^31-1)/(p-1)^2 terms — 32767 for
p = 257 (envelope.int32_lazy_terms) — before a `mod p` fold is due.  The old schedule folded every
128 terms (the fp32 dot envelope), which this elementwise accumulation
never needed; for realistic k the kernel now folds exactly once.
Validated on CPU via interpret=True against ref.circulant_encode_ref.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from .envelope import int32_lazy_terms, require_int32_envelope


def _circulant_encode_kernel(a_ref, o_ref, *, c: tuple[int, ...], p: int):
    k = len(c)
    n = 2 * k
    a = a_ref[...]                                    # (n, BS) int32
    chunk = int32_lazy_terms(p)
    acc = jnp.zeros_like(a)
    pending = 0
    for u in range(1, k + 1):
        # output row j holds r_{j+1} (1-indexed nodes):
        # roll(a, k+u-1)[j] = a[(j+1 - k - u) mod n]  — static shift: two slices
        shift = (k + u - 1) % n
        rolled = jnp.concatenate([a[n - shift:], a[:n - shift]], axis=0) if shift else a
        acc = acc + c[u - 1] * rolled
        pending += 1
        if pending == chunk:                           # int32 headroom spent
            acc = acc % p
            pending = 0
    o_ref[...] = acc % p


@functools.partial(jax.jit, static_argnames=("c", "p", "block_s", "interpret"))
def circulant_encode(data: jnp.ndarray, c: tuple[int, ...], p: int = 257, *,
                     block_s: int = 512, interpret: bool = True) -> jnp.ndarray:
    """data: (n, s) int32 data blocks -> (n, s) redundancy blocks.

    c must be a static tuple (it parameterizes the compiled kernel).
    """
    require_int32_envelope(p)
    c = tuple(int(x) % p for x in c)
    if any(x == 0 for x in c):
        raise ValueError("coefficients must be nonzero (paper §III-A)")
    data = jnp.asarray(data, jnp.int32) % p
    n, s = data.shape
    if n != 2 * len(c):
        raise ValueError(f"n={n} != 2k={2 * len(c)}")
    pad = (-s) % block_s
    if pad:
        data = jnp.pad(data, ((0, 0), (0, pad)))
    s_pad = s + pad
    grid = (s_pad // block_s,)
    out = pl.pallas_call(
        functools.partial(_circulant_encode_kernel, c=c, p=p),
        grid=grid,
        in_specs=[pl.BlockSpec((n, block_s), lambda i: (0, i))],
        out_specs=pl.BlockSpec((n, block_s), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((n, s_pad), jnp.int32),
        interpret=interpret,
    )(data)
    return out[:, :s]
