"""Deterministic, shardable synthetic token pipeline.

Design goals (the properties a real pipeline must have for fault tolerance):
  * STATELESS indexing: batch(i) is a pure function of (seed, step) — restart
    from a checkpointed step reproduces the exact stream, no data loss or
    duplication after failover;
  * per-host sharding: each data-parallel host materializes only its slice;
  * structure, not noise: sequences follow a mixture of integer-sequence
    "tasks" (arithmetic progressions, repeats, copy patterns) so a small LM's
    loss actually decreases — used by examples/train_tiny_lm.py.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    n_hosts: int = 1
    host_id: int = 0

    @property
    def local_batch(self) -> int:
        assert self.global_batch % self.n_hosts == 0
        return self.global_batch // self.n_hosts


def _sequence(rng: np.random.Generator, seq_len: int, vocab: int) -> np.ndarray:
    """One synthetic sequence from a task mixture."""
    task = rng.integers(0, 4)
    v = vocab - 1
    if task == 0:    # arithmetic progression mod vocab
        start, step = rng.integers(1, v), rng.integers(1, 7)
        return (start + step * np.arange(seq_len)) % v
    if task == 1:    # repeated motif
        m = rng.integers(2, 9)
        motif = rng.integers(1, v, size=m)
        return np.tile(motif, seq_len // m + 1)[:seq_len]
    if task == 2:    # copy: first half random, second half copies
        half = (seq_len + 1) // 2
        head = rng.integers(1, v, size=half)
        return np.concatenate([head, head])[:seq_len]
    # noise with a sticky state (markov-ish)
    out = np.empty(seq_len, dtype=np.int64)
    cur = rng.integers(1, v)
    for i in range(seq_len):
        if rng.random() < 0.2:
            cur = rng.integers(1, v)
        out[i] = cur
    return out


def batch_at(cfg: DataConfig, step: int) -> dict:
    """The canonical access path: (seed, step, host) -> local batch."""
    out_tokens = np.empty((cfg.local_batch, cfg.seq_len + 1), dtype=np.int64)
    for i in range(cfg.local_batch):
        global_row = step * cfg.global_batch + cfg.host_id * cfg.local_batch + i
        rng = np.random.default_rng((cfg.seed, global_row))
        out_tokens[i] = _sequence(rng, cfg.seq_len + 1, cfg.vocab_size)
    tokens = out_tokens[:, :-1].astype(np.int32)
    labels = out_tokens[:, 1:].astype(np.int32)
    return {"tokens": tokens, "labels": labels}


def iterate(cfg: DataConfig, start_step: int = 0) -> Iterator[dict]:
    step = start_step
    while True:
        yield batch_at(cfg, step)
        step += 1
