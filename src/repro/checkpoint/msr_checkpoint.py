"""MSR-coded distributed checkpointing — the paper's technique as the
framework's fault-tolerance layer (DESIGN.md §2).

Layout on disk (one directory per step, one file pair per storage node —
in a real cluster each host writes only its own pair):

    step_000042/
      manifest.json            code spec, tree metadata, byte accounting
      node_01.a.npy            a_0   (raw systematic block: uncoded bytes)
      node_01.r.npy            r_1   (circulant redundancy block)
      ...
      node_NN.{a,r}.npy

Restore paths (all byte-metered, verified by benchmarks):
  * happy path (all nodes up): read ONLY the n data blocks — systematic, so
    restore costs B bytes and ZERO field operations;
  * single failure: the paper's d = k+1 regeneration — read r_{i-1} from the
    previous node + k data blocks from the next k nodes:
    gamma = (k+1) * B / (2k)  (eq. 7) and rebuild node i bit-exactly;
  * <= k failures ... as long as k nodes survive: any-k reconstruction
    (2 blocks from each of k nodes = B bytes + a GF solve);
  * > n-k failures: unrecoverable (raises).
"""
from __future__ import annotations

import dataclasses
import json
import pathlib
import shutil
from typing import Any, Optional, Sequence

import jax
import numpy as np

from repro.core import gf, placement
from repro.core.circulant import CodeSpec
from repro.core.msr import DoubleCirculantMSR


@dataclasses.dataclass
class RestoreReport:
    step: int
    path: str                    # systematic | regenerate | reconstruct
    failed_nodes: tuple[int, ...]
    bytes_read: int
    bytes_total_stored: int
    repaired_nodes: tuple[int, ...] = ()


class MSRCheckpointer:
    def __init__(self, directory, spec: CodeSpec, *, matmul=None,
                 keep_last: int = 3):
        self.dir = pathlib.Path(directory)
        self.spec = spec
        self.code = DoubleCirculantMSR(spec, matmul=matmul)
        self.keep_last = keep_last
        self.dir.mkdir(parents=True, exist_ok=True)

    # ------------------------------------------------------------------ paths
    def _step_dir(self, step: int) -> pathlib.Path:
        return self.dir / f"step_{step:06d}"

    def _node_files(self, step: int, i: int) -> tuple[pathlib.Path, pathlib.Path]:
        d = self._step_dir(step)
        return d / f"node_{i:02d}.a.npy", d / f"node_{i:02d}.r.npy.npz"

    def steps(self) -> list[int]:
        return sorted(int(p.name.split("_")[1]) for p in self.dir.glob("step_*"))

    # ------------------------------------------------------------------- save
    def save(self, step: int, state: Any) -> dict:
        n = self.spec.n
        blocks, treedef, tspec = placement.pytree_to_blocks(state, n, self.spec.p)
        red = np.asarray(self.code.encode(blocks))
        d = self._step_dir(step)
        tmp = d.with_suffix(".tmp")
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        for i in range(1, n + 1):
            # systematic block: raw bytes; redundancy: packed GF(257)
            np.save(tmp / f"node_{i:02d}.a.npy",
                    blocks[i - 1].astype(np.uint8))
            low, hi = gf.pack257(red[i - 1])
            np.savez(str(tmp / f"node_{i:02d}.r.npy"), low=low, hi=hi)
        manifest = {
            "step": step, "k": self.spec.k, "p": self.spec.p,
            "c": list(self.spec.c), "tree": tspec.to_json(),
        }
        (tmp / "manifest.json").write_text(json.dumps(manifest))
        if d.exists():
            shutil.rmtree(d)
        tmp.rename(d)                       # atomic-ish publish
        self._gc()
        return manifest

    def _gc(self):
        steps = self.steps()
        for s in steps[: -self.keep_last]:
            shutil.rmtree(self._step_dir(s), ignore_errors=True)

    # ---------------------------------------------------------------- restore
    def restore(self, template: Any, step: Optional[int] = None,
                failed_nodes: Sequence[int] = (), *, repair: bool = True,
                ) -> tuple[Any, RestoreReport]:
        """Rebuild the pytree.  `failed_nodes` simulates dead hosts (their
        files are treated as unreadable; with repair=True the missing pair is
        rebuilt and re-written — the newcomer protocol)."""
        if step is None:
            step = self.steps()[-1]
        d = self._step_dir(step)
        manifest = json.loads((d / "manifest.json").read_text())
        tspec = placement.TreeSpec.from_json(manifest["tree"])
        n, k = self.spec.n, self.spec.k
        failed = sorted(set(failed_nodes))
        alive = [i for i in range(1, n + 1) if i not in failed]
        if len(alive) < k:
            raise RuntimeError(f"unrecoverable: only {len(alive)} of n={n} "
                               f"nodes alive, need k={k}")
        bytes_read = 0
        repaired: list[int] = []

        def read(path: pathlib.Path) -> np.ndarray:
            nonlocal bytes_read
            if path.suffix == ".npz":                 # packed redundancy
                z = np.load(path)
                low, hi = z["low"], z["hi"]
                bytes_read += low.nbytes + hi.nbytes
                return gf.unpack257(low, hi)
            arr = np.load(path)
            bytes_read += arr.nbytes
            return arr.astype(np.int32)

        if not failed:
            data = np.stack([read(self._node_files(step, i)[0])
                             for i in range(1, n + 1)])
            path = "systematic"
        elif len(failed) == 1 and repair:
            f = failed[0]
            plan = self.code.repair_plan(f)
            r_prev = read(self._node_files(step, plan.prev_node)[1])
            next_data = np.stack([read(self._node_files(step, j)[0])
                                  for j in plan.next_nodes])
            a_new, r_new = self.code.regenerate(f, r_prev, next_data)
            a_new, r_new = np.asarray(a_new), np.asarray(r_new)
            af, rf = self._node_files(step, f)
            np.save(af, a_new.astype(np.uint8))
            low, hi = gf.pack257(r_new)
            np.savez(rf.with_suffix(""), low=low, hi=hi)
            repaired.append(f)
            # assemble full data: the k helpers' blocks are already in hand
            data = np.zeros((n, tspec.block_symbols), np.int32)
            have = dict(zip(plan.data_indices, next_data))
            have[f - 1] = a_new
            for i in range(1, n + 1):
                idx = i - 1
                if idx in have:
                    data[idx] = have[idx]
                else:
                    data[idx] = read(self._node_files(step, i)[0])
            path = "regenerate"
        else:
            use = alive[:k]
            data_blocks = np.stack([read(self._node_files(step, i)[0]) for i in use])
            red_blocks = np.stack([read(self._node_files(step, i)[1]) for i in use])
            data = np.asarray(self.code.reconstruct(use, data_blocks, red_blocks))
            if repair:
                red_all = np.asarray(self.code.encode(data))
                for f in failed:
                    af, rf = self._node_files(step, f)
                    np.save(af, data[f - 1].astype(np.uint8))
                    low, hi = gf.pack257(red_all[f - 1])
                    np.savez(rf.with_suffix(""), low=low, hi=hi)
                    repaired.append(f)
            path = "reconstruct"

        treedef = jax.tree_util.tree_structure(template)
        state = placement.blocks_to_pytree(data.astype(np.int32), treedef, tspec)
        total = 2 * n * tspec.block_symbols          # ~bytes (packed storage)
        report = RestoreReport(step=step, path=path,
                               failed_nodes=tuple(failed),
                               bytes_read=bytes_read,
                               bytes_total_stored=total,
                               repaired_nodes=tuple(repaired))
        return state, report

    # -------------------------------------------------------------- accounting
    def gamma_bytes(self, tspec_block_symbols: int, *, mode: str) -> int:
        """Ideal byte counts (packed symbols ~ 1 byte each) for the three
        restore paths — eq. (7) and §III-B of the paper."""
        s = tspec_block_symbols
        if mode == "regenerate":
            return (self.spec.k + 1) * s
        if mode == "reconstruct":
            return 2 * self.spec.k * s
        if mode == "systematic":
            return self.spec.n * s
        raise ValueError(mode)

    def repair_node(self, step: int, node: int) -> int:
        """The newcomer protocol in isolation: rebuild node's (a, r) pair
        from d = k+1 reads.  Returns bytes read (the measured gamma)."""
        plan = self.code.repair_plan(node)
        bytes_read = 0

        def read(path):
            nonlocal bytes_read
            if path.suffix == ".npz":
                z = np.load(path)
                bytes_read += z["low"].nbytes + z["hi"].nbytes
                return gf.unpack257(z["low"], z["hi"])
            arr = np.load(path)
            bytes_read += arr.nbytes
            return arr.astype(np.int32)

        r_prev = read(self._node_files(step, plan.prev_node)[1])
        next_data = np.stack([read(self._node_files(step, j)[0])
                              for j in plan.next_nodes])
        a_new, r_new = self.code.regenerate(node, r_prev, next_data)
        af, rf = self._node_files(step, node)
        np.save(af, np.asarray(a_new).astype(np.uint8))
        low, hi = gf.pack257(np.asarray(r_new))
        np.savez(rf.with_suffix(""), low=low, hi=hi)
        return bytes_read
