"""MSR-coded distributed checkpointing — the paper's technique as the
framework's fault-tolerance layer (DESIGN.md §2).

Layout on disk (one directory per step, one file pair per storage node —
in a real cluster each host writes only its own pair):

    step_000042/
      manifest.json            code spec, tree metadata, byte accounting
      node_01.a.npy            a_0   (raw systematic block: uncoded bytes)
      node_01.r.npz            r_1   (circulant redundancy block, packed)
      ...
      node_NN.a.npy / node_NN.r.npz

Restore paths (all byte-metered, verified by benchmarks):
  * happy path (all nodes up): read ONLY the n data blocks — systematic, so
    restore costs B bytes and ZERO field operations;
  * single failure: the paper's d = k+1 regeneration — read r_{i-1} from the
    previous node + k data blocks from the next k nodes:
    gamma = (k+1) * B / (2k)  (eq. 7) and rebuild node i bit-exactly;
  * <= k failures ... as long as k nodes survive: any-k reconstruction
    (2 blocks from each of k nodes = B bytes + a GF solve);
  * > n-k failures: unrecoverable (raises).
"""
from __future__ import annotations

import dataclasses
import json
import pathlib
import shutil
from concurrent.futures import Future, ThreadPoolExecutor
from typing import Any, Optional, Sequence

import jax
import numpy as np

from repro.core import gf, placement
from repro.core.circulant import CodeSpec
from repro.core.msr import DoubleCirculantMSR

# Stream-axis tile (symbols) for the streaming encode: bounds the int32
# intermediates on device and lets host file writes overlap device compute.
SAVE_TILE_SYMBOLS = 1 << 20


@dataclasses.dataclass
class RestoreReport:
    step: int
    path: str                    # systematic | regenerate | reconstruct
    failed_nodes: tuple[int, ...]
    bytes_read: int
    bytes_total_stored: int
    repaired_nodes: tuple[int, ...] = ()


class MSRCheckpointer:
    def __init__(self, directory, spec: CodeSpec, *, matmul=None,
                 backend: Optional[str] = None, keep_last: int = 3,
                 save_tile_symbols: int = SAVE_TILE_SYMBOLS,
                 io_workers: int = 4):
        self.dir = pathlib.Path(directory)
        self.spec = spec
        self.code = DoubleCirculantMSR(spec, matmul=matmul, backend=backend)
        self.keep_last = keep_last
        self.save_tile_symbols = max(1, save_tile_symbols)
        self.io_workers = max(1, io_workers)
        self.dir.mkdir(parents=True, exist_ok=True)

    # ------------------------------------------------------------------ paths
    def _step_dir(self, step: int) -> pathlib.Path:
        return self.dir / f"step_{step:06d}"

    def _node_files(self, step: int, i: int) -> tuple[pathlib.Path, pathlib.Path]:
        """(data_path, redundancy_path) for node v_i at `step`.

        The redundancy file is a plain ``node_XX.r.npz`` archive; np.savez
        is always handed the full path (it only appends ``.npz`` when the
        suffix is missing, which it never is here).
        """
        d = self._step_dir(step)
        return d / f"node_{i:02d}.a.npy", d / f"node_{i:02d}.r.npz"

    def _write_node_pair(self, a_path: pathlib.Path, r_path: pathlib.Path,
                         a_block: np.ndarray, r_low: np.ndarray,
                         r_hi: np.ndarray) -> None:
        np.save(a_path, a_block.astype(np.uint8))
        np.savez(r_path, low=r_low, hi=r_hi)

    def steps(self) -> list[int]:
        return sorted(int(p.name.split("_")[1]) for p in self.dir.glob("step_*"))

    # ------------------------------------------------------------------- save
    def save(self, step: int, state: Any) -> dict:
        """Streaming checkpoint save (DESIGN.md §3.3).

        The redundancy encode runs as a depth-2 stream-tile pipeline: tile
        t+1 is dispatched to the device while tile t's result lands in a
        single preallocated host buffer (at most two tiles live on device,
        no concatenate copy).  Every node file write goes through a thread
        pool, so the n systematic np.save calls overlap the encode instead
        of the seed's serial per-node loop; the packed redundancy writes
        follow as soon as the last tile resolves.
        """
        n = self.spec.n
        blocks, treedef, tspec = placement.pytree_to_blocks(state, n, self.spec.p)
        d = self._step_dir(step)
        tmp = d.with_suffix(".tmp")
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        s_total = blocks.shape[1]
        tile = self.save_tile_symbols
        with ThreadPoolExecutor(max_workers=self.io_workers) as ex:
            writes: list[Future] = []
            # systematic blocks are raw bytes — no compute, write immediately
            for i in range(1, n + 1):
                writes.append(ex.submit(
                    np.save, tmp / f"node_{i:02d}.a.npy",
                    blocks[i - 1].astype(np.uint8)))
            # depth-2 pipeline: force tile t only after dispatching t+1
            red = np.empty((n, s_total), np.int32)
            pending = None                  # (host slice, device tile)
            for s0 in range(0, s_total, tile):
                part = self.code.encode(blocks[:, s0:s0 + tile])
                if pending is not None:
                    red[:, pending[0]] = np.asarray(pending[1])
                pending = (slice(s0, min(s0 + tile, s_total)), part)
            if pending is not None:
                red[:, pending[0]] = np.asarray(pending[1])
            # vectorized pack over all nodes at once (no per-node loop)
            low, his = gf.pack257_rows(red)
            for i in range(1, n + 1):
                writes.append(ex.submit(
                    np.savez, tmp / f"node_{i:02d}.r.npz",
                    low=low[i - 1], hi=his[i - 1]))
            for w in writes:
                w.result()                  # surface any I/O error
        manifest = {
            "step": step, "k": self.spec.k, "p": self.spec.p,
            "c": list(self.spec.c), "tree": tspec.to_json(),
        }
        (tmp / "manifest.json").write_text(json.dumps(manifest))
        if d.exists():
            shutil.rmtree(d)
        tmp.rename(d)                       # atomic-ish publish
        self._gc()
        return manifest

    def _gc(self):
        steps = self.steps()
        for s in steps[: -self.keep_last]:
            shutil.rmtree(self._step_dir(s), ignore_errors=True)

    # ---------------------------------------------------------------- restore
    def restore(self, template: Any, step: Optional[int] = None,
                failed_nodes: Sequence[int] = (), *, repair: bool = True,
                ) -> tuple[Any, RestoreReport]:
        """Rebuild the pytree.  `failed_nodes` simulates dead hosts (their
        files are treated as unreadable; with repair=True the missing pair is
        rebuilt and re-written — the newcomer protocol)."""
        if step is None:
            step = self.steps()[-1]
        d = self._step_dir(step)
        manifest = json.loads((d / "manifest.json").read_text())
        tspec = placement.TreeSpec.from_json(manifest["tree"])
        n, k = self.spec.n, self.spec.k
        failed = sorted(set(failed_nodes))
        alive = [i for i in range(1, n + 1) if i not in failed]
        if len(alive) < k:
            raise RuntimeError(f"unrecoverable: only {len(alive)} of n={n} "
                               f"nodes alive, need k={k}")
        bytes_read = 0
        repaired: list[int] = []

        def read(path: pathlib.Path) -> np.ndarray:
            nonlocal bytes_read
            if path.suffix == ".npz":                 # packed redundancy
                z = np.load(path)
                low, hi = z["low"], z["hi"]
                bytes_read += low.nbytes + hi.nbytes
                return gf.unpack257(low, hi)
            arr = np.load(path)
            bytes_read += arr.nbytes
            return arr.astype(np.int32)

        if not failed:
            data = np.stack([read(self._node_files(step, i)[0])
                             for i in range(1, n + 1)])
            path = "systematic"
        elif len(failed) == 1 and repair:
            f = failed[0]
            plan = self.code.repair_plan(f)
            r_prev = read(self._node_files(step, plan.prev_node)[1])
            next_data = np.stack([read(self._node_files(step, j)[0])
                                  for j in plan.next_nodes])
            a_new, r_new = self.code.regenerate(f, r_prev, next_data)
            a_new, r_new = np.asarray(a_new), np.asarray(r_new)
            af, rf = self._node_files(step, f)
            low, hi = gf.pack257(r_new)
            self._write_node_pair(af, rf, a_new, low, hi)
            repaired.append(f)
            # assemble full data: the k helpers' blocks are already in hand
            data = np.zeros((n, tspec.block_symbols), np.int32)
            have = dict(zip(plan.data_indices, next_data))
            have[f - 1] = a_new
            for i in range(1, n + 1):
                idx = i - 1
                if idx in have:
                    data[idx] = have[idx]
                else:
                    data[idx] = read(self._node_files(step, i)[0])
            path = "regenerate"
        else:
            use = alive[:k]
            data_blocks = np.stack([read(self._node_files(step, i)[0]) for i in use])
            red_blocks = np.stack([read(self._node_files(step, i)[1]) for i in use])
            data = np.asarray(self.code.reconstruct(use, data_blocks, red_blocks))
            if repair:
                red_all = np.asarray(self.code.encode(data))
                for f in failed:
                    af, rf = self._node_files(step, f)
                    low, hi = gf.pack257(red_all[f - 1])
                    self._write_node_pair(af, rf, data[f - 1], low, hi)
                    repaired.append(f)
            path = "reconstruct"

        treedef = jax.tree_util.tree_structure(template)
        state = placement.blocks_to_pytree(data.astype(np.int32), treedef, tspec)
        total = 2 * n * tspec.block_symbols          # ~bytes (packed storage)
        report = RestoreReport(step=step, path=path,
                               failed_nodes=tuple(failed),
                               bytes_read=bytes_read,
                               bytes_total_stored=total,
                               repaired_nodes=tuple(repaired))
        return state, report

    # -------------------------------------------------------------- accounting
    def gamma_bytes(self, tspec_block_symbols: int, *, mode: str) -> int:
        """Ideal byte counts (packed symbols ~ 1 byte each) for the three
        restore paths — eq. (7) and §III-B of the paper."""
        s = tspec_block_symbols
        if mode == "regenerate":
            return (self.spec.k + 1) * s
        if mode == "reconstruct":
            return 2 * self.spec.k * s
        if mode == "systematic":
            return self.spec.n * s
        raise ValueError(mode)

    def repair_node(self, step: int, node: int) -> int:
        """The newcomer protocol in isolation: rebuild node's (a, r) pair
        from d = k+1 reads.  Returns bytes read (the measured gamma)."""
        plan = self.code.repair_plan(node)
        bytes_read = 0

        def read(path):
            nonlocal bytes_read
            if path.suffix == ".npz":
                z = np.load(path)
                bytes_read += z["low"].nbytes + z["hi"].nbytes
                return gf.unpack257(z["low"], z["hi"])
            arr = np.load(path)
            bytes_read += arr.nbytes
            return arr.astype(np.int32)

        r_prev = read(self._node_files(step, plan.prev_node)[1])
        next_data = np.stack([read(self._node_files(step, j)[0])
                              for j in plan.next_nodes])
        a_new, r_new = self.code.regenerate(node, r_prev, next_data)
        af, rf = self._node_files(step, node)
        low, hi = gf.pack257(np.asarray(r_new))
        self._write_node_pair(af, rf, np.asarray(a_new), low, hi)
        return bytes_read
