"""MSR-coded distributed checkpointing — the paper's technique as the
framework's fault-tolerance layer (DESIGN.md §2).

Layout on disk (one directory per step, one file pair per storage node —
in a real cluster each host writes only its own pair):

    step_000042/
      manifest.json            code spec, tree metadata, byte accounting
      node_01.a.npy            a_0   (raw systematic block: uncoded bytes)
      node_01.r.npz            r_1   (circulant redundancy block, packed)
      ...
      node_NN.a.npy / node_NN.r.npz

Restore paths (all byte-metered, verified by benchmarks):
  * happy path (all nodes up): read ONLY the n data blocks — systematic, so
    restore costs B bytes and ZERO field operations;
  * single failure: the paper's d = k+1 regeneration — read r_{i-1} from the
    previous node + k data blocks from the next k nodes:
    gamma = (k+1) * B / (2k)  (eq. 7) and rebuild node i bit-exactly;
  * <= k failures ... as long as k nodes survive: any-k reconstruction
    (2 blocks from each of k nodes = B bytes + a GF solve);
  * > n-k failures: unrecoverable (raises).

Restore is symmetric with the streaming save (DESIGN.md §4): node reads go
through a thread pool, the regenerate/reconstruct decode runs as a depth-2
stream-tile pipeline (tiles bounded by ``save_tile_symbols``) through the
fused repair engine, multi-failure repair produces all lost pairs from one
decode matmul, and ``scrub(step)`` is a degraded-read pass that re-derives
every node pair through the batched engine and flags inconsistencies.

All four streaming paths (save, restore, repair_node, scrub) run on ONE
engine — `repro.exec.Pipeline` (DESIGN.md §11.3) — and all GF compute
dispatches through the shape-bucketed execution-plan cache (§11.1), so a
steady-state save/restore loop over arbitrarily mixed state sizes
performs zero XLA recompiles after warm-up.  ``pipeline_depth=1`` turns
the overlap off (the benchmark's serial baseline).

Store-backed mode (``MSRCheckpointer(None, store=...)``, DESIGN.md §10.4):
redundancy is delegated to a coded object store — one object per pytree
leaf group plus a manifest — and restores ride the store's transparent
degraded reads; all byte metering funnels through ONE ``_read_block``
accounting path shared with directory mode.

Crash consistency (DESIGN.md §12): every byte goes through a
`repro.io.BlobBackend` wrapped in a `repro.io.RetryPolicy` (bounded
retries, exponential backoff + deterministic jitter, typed
`GiveUpError`), and a save is *atomic*: files land in ``step_X.tmp``
(fsync'd), the manifest — carrying per-block content CRCs — is written
last, and one directory rename publishes the generation.  ``steps()``
and ``restore`` only ever see committed generations; ``recover()``
(run at construction) garbage-collects orphaned temp dirs and
manifest-less step dirs from crashed writers.  ``save_async`` is the
zero-stall write-behind mode: the state is snapshotted on device
(donation-safe copies) and encoded + committed on a background writer
— at most ONE checkpoint in flight, ``barrier()`` is the completion
fence — so training continues while the previous step's bytes drain.
"""
from __future__ import annotations

import dataclasses
import io as _pyio
import json
import pathlib
import re
import zlib
from concurrent.futures import Future, ThreadPoolExecutor
from typing import Any, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import gf, placement
from repro.core.circulant import CodeSpec
from repro.core.msr import DoubleCirculantMSR
from repro.exec.pipeline import Pipeline
from repro.exec.plan import planning_enabled
from repro.io.blob import BlobBackend, LocalBlob
from repro.io.retry import RetryPolicy, RetryStats

# Stream-axis tile (symbols) for the streaming encode: bounds the int32
# intermediates on device and lets host file writes overlap device compute.
SAVE_TILE_SYMBOLS = 1 << 20

_STEP_DIR_RE = re.compile(r"step_(\d+)$")


def _npy_bytes(arr: np.ndarray) -> bytes:
    buf = _pyio.BytesIO()
    np.save(buf, arr)
    return buf.getvalue()


def _npz_bytes(**arrs: np.ndarray) -> bytes:
    buf = _pyio.BytesIO()
    np.savez(buf, **arrs)
    return buf.getvalue()


def _crc_data(block: np.ndarray) -> int:
    """Content CRC of a systematic block (over its stored uint8 bytes)."""
    return zlib.crc32(np.ascontiguousarray(block, np.uint8).tobytes())


def _crc_red(low: np.ndarray, hi: np.ndarray) -> int:
    """Content CRC of a packed redundancy block — over the logical
    (low, hi) payload, NOT the .npz container bytes, so a bit-exact
    repair rewrite keeps the manifest CRC valid without a manifest
    rewrite."""
    c = zlib.crc32(np.ascontiguousarray(low, np.uint8).tobytes())
    return zlib.crc32(np.ascontiguousarray(hi, np.int64).tobytes(), c)


def _snapshot_leaf(x):
    """Donation-safe snapshot of one pytree leaf: device arrays get a
    device-side copy (dispatched before the caller's next donating step,
    so program order protects it), host arrays a host copy."""
    if isinstance(x, jax.Array):
        return jnp.copy(x)
    if isinstance(x, np.ndarray):
        return np.copy(x)
    return x


@dataclasses.dataclass
class RestoreReport:
    step: int
    path: str                    # systematic | regenerate | reconstruct
    failed_nodes: tuple[int, ...]
    bytes_read: int
    bytes_total_stored: int
    repaired_nodes: tuple[int, ...] = ()


@dataclasses.dataclass
class ScrubReport:
    """Result of a degraded-read verification pass (DESIGN.md §4).

    A node appears in ``mismatched_nodes`` when its re-derived pair
    (regenerated from r_{i-1} + the next k data blocks through the batched
    repair engine) disagrees with the stored pair.  A single corrupt block
    flags its own node and can flag the neighbours whose regeneration
    consumed it — the flagged set localizes, not convicts.
    """
    step: int
    nodes_checked: int
    mismatched_nodes: tuple[int, ...]
    bytes_read: int

    @property
    def clean(self) -> bool:
        return not self.mismatched_nodes


class _MeteredReader:
    """The single byte-accounting funnel for checkpoint reads.

    Every node-file and store-object read — restore, repair_node, scrub,
    directory- or store-backed — submits through here and lands through
    :meth:`take`, so there is exactly ONE place bytes_read accumulates
    (the meters can't drift apart across the three read paths, which is
    how the pre-PR-4 duplication bug class arose).
    """

    def __init__(self, ckpt: "MSRCheckpointer", pipe: Pipeline):
        self._ckpt = ckpt
        self._pipe = pipe
        self.bytes_read = 0

    def submit(self, ref) -> Future:
        """Async read of a node file path or a store object key."""
        return self._pipe.submit(self._ckpt._read_block, ref)

    def submit_packed(self, ref) -> Future:
        """Async read of a packed ``.npz`` redundancy block WITHOUT
        unpacking: lands ``(low, hi)`` so row-batched callers (scrub,
        the reconstruct download set) unpack every row in one
        vectorized `gf.unpack257_rows` instead of per-pair loops."""
        return self._pipe.submit(self._ckpt._read_packed, ref)

    def take(self, fut: Future):
        """Land one read: returns the payload, meters its bytes."""
        arr, nbytes = fut.result()
        self.bytes_read += nbytes
        return arr


class MSRCheckpointer:
    """MSR-coded checkpointing, directory- or store-backed.

    Directory mode (default): one file pair per storage node per step,
    encode/repair done here (module docstring above).

    Store mode (``store=`` given, DESIGN.md §10.4): redundancy is
    delegated to the coded object store — ``save`` puts one object per
    pytree *leaf group* (consecutive leaves greedily packed up to
    ``leaf_group_bytes``) plus a manifest object, and ``restore`` gets
    them back through the store's transparent degraded-read path, so a
    checkpoint stays restorable through node failures without the
    checkpointer knowing which nodes died.  ``repair_node``/``scrub``
    are directory-mode-only (the store's scheduler owns repair).
    """

    def __init__(self, directory, spec: Optional[CodeSpec] = None, *,
                 matmul=None,
                 backend: Optional[str] = None, keep_last: int = 3,
                 save_tile_symbols: int = SAVE_TILE_SYMBOLS,
                 io_workers: int = 4, pipeline_depth: int = 2, store=None,
                 object_prefix: str = "ckpt",
                 leaf_group_bytes: int = 1 << 20,
                 io_backend: Optional[BlobBackend] = None,
                 retry: Optional[RetryPolicy] = None,
                 mesh=None):
        self._store = store
        self._prefix = object_prefix.rstrip("/")
        self.leaf_group_bytes = max(1, leaf_group_bytes)
        self.iob = io_backend or LocalBlob()
        self.retry = retry or RetryPolicy()
        self.retry_stats = RetryStats()
        self._writer_ex: Optional[ThreadPoolExecutor] = None
        self._inflight: Optional[Future] = None
        if store is not None:
            if directory is not None:
                raise ValueError(
                    "pass a directory OR a store, not both: store-backed "
                    "checkpoints live entirely in the object store")
            spec = spec or store.spec
            if spec is not store.spec and spec != store.spec:
                raise ValueError("spec disagrees with the store's code spec")
        elif spec is None:
            raise ValueError("directory mode needs an explicit CodeSpec")
        self.spec = spec
        # stream-axis mesh (DESIGN.md §14): the stream-tile save/restore
        # pipeline inherits it through the code's planner; store-backed
        # mode uses the store's (already mesh-aware) code
        self.code = store.code if store is not None else \
            DoubleCirculantMSR(spec, matmul=matmul, backend=backend,
                               mesh=mesh)
        self.keep_last = keep_last
        self.save_tile_symbols = max(1, save_tile_symbols)
        self.io_workers = max(1, io_workers)
        self.pipeline_depth = max(1, pipeline_depth)
        self.dir = None
        if directory is not None:
            self.dir = pathlib.Path(directory)
            self.iob.mkdir(self.dir)
        elif store is None:
            raise ValueError("need a directory (or a store=)")
        # startup recovery: a crashed writer's orphans must not survive
        # into this process's view of the generation sequence
        self.recover()

    def _pipe(self, io_workers: Optional[int] = None) -> Pipeline:
        """One streaming engine per operation (DESIGN.md §11.3): pooled
        host I/O + depth-bounded compute/consume overlap."""
        return Pipeline(io_workers=io_workers or self.io_workers,
                        depth=self.pipeline_depth)

    def _staging_pool(self):
        """The planner's host staging pool (DESIGN.md §16.1), or None
        when the planner path is off — save/restore/scrub stage their
        big landing / pack / download buffers there so steady-state
        checkpoint loops allocate nothing per step."""
        planner = getattr(self.code, "planner", None)
        if planner is None or not planning_enabled():
            return None
        return planner.staging

    # ------------------------------------------------------------------ paths
    def _step_dir(self, step: int) -> pathlib.Path:
        return self.dir / f"step_{step:06d}"

    def _okey(self, step: int, name: str) -> str:
        """Store-object key for one piece of a checkpoint step."""
        return f"{self._prefix}/step_{step:06d}/{name}"

    def _node_files(self, step: int, i: int) -> tuple[pathlib.Path, pathlib.Path]:
        """(data_path, redundancy_path) for node v_i at `step`.

        The redundancy file is a plain ``node_XX.r.npz`` archive; np.savez
        is always handed the full path (it only appends ``.npz`` when the
        suffix is missing, which it never is here).
        """
        d = self._step_dir(step)
        return d / f"node_{i:02d}.a.npy", d / f"node_{i:02d}.r.npz"

    # ------------------------------------------------------ retried blob I/O
    def _write_blob(self, path: pathlib.Path, data: bytes, *,
                    atomic: bool = False) -> None:
        """Retry-wrapped backend write.  ``atomic=True`` uses the
        single-file tmp+rename protocol — required for any write into an
        already-committed generation (repair/restore rewrites), where a
        torn write would corrupt a good checkpoint."""
        if atomic:
            tmp = path.parent / (path.name + ".tmp")
            self.retry.call(lambda: self.iob.write(tmp, data),
                            op=f"write:{path.name}", stats=self.retry_stats)
            self.retry.call(lambda: self.iob.rename(tmp, path),
                            op=f"rename:{path.name}", stats=self.retry_stats)
        else:
            self.retry.call(lambda: self.iob.write(path, data),
                            op=f"write:{path.name}", stats=self.retry_stats)

    def _read_bytes(self, path: pathlib.Path) -> bytes:
        return self.retry.call(lambda: self.iob.read(path),
                               op=f"read:{path.name}",
                               stats=self.retry_stats)

    def _load(self, path: pathlib.Path):
        """np.load through the retried backend (npy and npz payloads)."""
        return np.load(_pyio.BytesIO(self._read_bytes(path)))

    def _write_node_pair(self, a_path: pathlib.Path, r_path: pathlib.Path,
                         a_block: np.ndarray, r_low: np.ndarray,
                         r_hi: np.ndarray) -> None:
        # repair writes land in committed generations: atomic per file
        self._write_blob(a_path, _npy_bytes(a_block.astype(np.uint8)),
                         atomic=True)
        self._write_blob(r_path, _npz_bytes(low=r_low, hi=r_hi), atomic=True)

    def steps(self) -> list[int]:
        """Committed generations only: a step counts iff its manifest
        exists — uncommitted ``*.tmp`` staging dirs and torn generations
        from crashed writers are invisible (and recover() removes them).
        """
        if self._store is not None:
            pre = f"{self._prefix}/step_"
            return sorted(int(key[len(pre):].split("/")[0])
                          for key in self._store.keys()
                          if key.startswith(pre)
                          and key.endswith("/manifest"))
        out = []
        for name in self.iob.listdir(self.dir):
            m = _STEP_DIR_RE.fullmatch(name)
            if m and self.iob.exists(self.dir / name / "manifest.json"):
                out.append(int(m.group(1)))
        return sorted(out)

    # --------------------------------------------------------------- recovery
    def recover(self) -> list[str]:
        """Garbage-collect orphans a crashed writer left behind; returns
        what was removed.  Three orphan classes: ``*.tmp`` staging dirs
        and files (save or atomic rewrite died before its rename),
        ``step_*`` dirs without a manifest (pre-protocol torn saves),
        and — store-backed — leaf-group objects of a step whose manifest
        never committed."""
        removed: list[str] = []
        if self._store is not None:
            committed = {f"{self._prefix}/step_{s:06d}/" for s in self.steps()}
            pre = f"{self._prefix}/step_"
            for key in list(self._store.keys()):
                if not key.startswith(pre):
                    continue
                gen = key.rsplit("/", 1)[0] + "/"
                if gen not in committed:
                    self._store.delete(key)
                    removed.append(key)
            return removed
        for name in self.iob.listdir(self.dir):
            p = self.dir / name
            if name.endswith(".tmp"):
                self.iob.rmtree(p) if self.iob.isdir(p) else self.iob.remove(p)
                removed.append(name)
            elif _STEP_DIR_RE.fullmatch(name) and self.iob.isdir(p):
                if not self.iob.exists(p / "manifest.json"):
                    self.iob.rmtree(p)
                    removed.append(name)
                else:
                    for f in self.iob.listdir(p):
                        if f.endswith(".tmp"):    # torn atomic rewrite
                            self.iob.remove(p / f)
                            removed.append(f"{name}/{f}")
        return removed

    # --------------------------------------------------- write-behind (async)
    def save_async(self, step: int, state: Any) -> Future:
        """Zero-stall save: snapshot ``state`` (device-side, donation-safe
        copies) and encode + commit on a background writer thread while
        the caller keeps training.  At most ONE checkpoint is in flight:
        a second call first waits out (and surfaces) the previous one.
        The returned future resolves to the manifest; :meth:`barrier` is
        the completion fence."""
        self.barrier()
        snap = jax.tree_util.tree_map(_snapshot_leaf, state)
        if self._writer_ex is None:
            self._writer_ex = ThreadPoolExecutor(
                max_workers=1, thread_name_prefix="ckpt-writer")
        fut = self._writer_ex.submit(self.save, step, snap)
        self._inflight = fut
        return fut

    def barrier(self) -> Optional[dict]:
        """Wait for the in-flight write-behind save (if any); returns its
        manifest or re-raises its failure (typed `GiveUpError` for I/O
        give-ups).  Idempotent."""
        fut, self._inflight = self._inflight, None
        if fut is not None:
            return fut.result()
        return None

    def close(self) -> None:
        """Fence and shut down the write-behind writer thread."""
        try:
            self.barrier()
        finally:
            if self._writer_ex is not None:
                self._writer_ex.shutdown(wait=True)
                self._writer_ex = None

    # ------------------------------------------------------- store-backed save
    def _leaf_groups(self, metas: list[dict]) -> list[tuple[int, int]]:
        """Greedy (start_byte, end_byte) spans: consecutive leaves packed
        until ``leaf_group_bytes`` (one oversized leaf still gets its own
        group) — one store object per span."""
        groups: list[tuple[int, int]] = []
        start = off = 0
        size = 0
        for m in metas:
            if size and size + m["nbytes"] > self.leaf_group_bytes:
                groups.append((start, off))
                start, size = off, 0
            off += m["nbytes"]
            size += m["nbytes"]
        groups.append((start, off))
        return groups

    def _save_store(self, step: int, state: Any) -> dict:
        payload, treedef, metas = placement.pytree_to_bytes(state)
        tspec = placement.TreeSpec(treedef_repr=str(treedef), leaves=metas,
                                   total_bytes=len(payload),
                                   n_blocks=self.spec.n, block_symbols=0)
        groups = self._leaf_groups(metas)
        for gi, (lo, hi) in enumerate(groups):
            self._store.put(self._okey(step, f"g{gi:04d}"), payload[lo:hi])
        manifest = {
            "step": step, "k": self.spec.k, "p": self.spec.p,
            "c": list(self.spec.c), "tree": tspec.to_json(),
            "n_groups": len(groups),
        }
        self._store.put(self._okey(step, "manifest"),
                        json.dumps(manifest).encode())
        self._gc()
        return manifest

    # ------------------------------------------------------------------- save
    def save(self, step: int, state: Any) -> dict:
        """Streaming checkpoint save (DESIGN.md §3.3).

        The redundancy encode runs as a depth-2 stream-tile pipeline: tile
        t+1 is dispatched to the device while tile t's result lands in a
        single preallocated host buffer (at most two tiles live on device,
        no concatenate copy).  Every node file write goes through a thread
        pool, so the n systematic np.save calls overlap the encode instead
        of the seed's serial per-node loop; the packed redundancy writes
        follow as soon as the last tile resolves.

        Parameters
        ----------
        step : int
            Checkpoint step id; the on-disk directory is ``step_{step:06d}``
            (staged as ``.tmp`` and renamed only after all writes land).
        state : pytree
            Arbitrary JAX/numpy pytree; serialized via
            `placement.pytree_to_blocks`.

        Returns
        -------
        dict
            The manifest written alongside the node files (code spec +
            tree metadata).
        """
        if self._store is not None:
            return self._save_store(step, state)
        n = self.spec.n
        blocks, treedef, tspec = placement.pytree_to_blocks(state, n, self.spec.p)
        d = self._step_dir(step)
        tmp = d.parent / (d.name + ".tmp")
        if self.iob.exists(tmp):
            self.iob.rmtree(tmp)
        self.iob.mkdir(tmp)
        s_total = blocks.shape[1]
        tile = self.save_tile_symbols
        crcs: dict[str, int] = {}
        pool = self._staging_pool()
        stage_bufs: list[np.ndarray] = []
        try:
            with self._pipe() as pipe:
                # systematic blocks are raw bytes — no compute, write
                # immediately (retried, fsync'd, content CRC recorded)
                for i in range(1, n + 1):
                    pipe.submit(self._save_data_block, tmp, i,
                                blocks[i - 1], crcs)
                # depth-bounded pipeline over PLANNED encode tiles: tile t+1
                # is dispatched (AOT executable, bucketed shape — zero
                # recompiles at steady state) before tile t lands in the
                # host buffer — a pooled one (DESIGN.md §16.1), so a
                # steady-state save loop allocates no fresh (n, S) arrays
                if pool is not None:
                    red = pool.acquire((n, s_total), np.int32)
                    low_buf = pool.acquire((n, s_total), np.uint8)
                    stage_bufs += [red, low_buf]
                else:
                    red = np.empty((n, s_total), np.int32)
                    low_buf = None
                pipe.stream_tiles(
                    s_total, tile,
                    lambda sl: self.code.encode_planned(blocks[:, sl]),
                    lambda sl, res: red.__setitem__(
                        (slice(None), sl), res.host()))
                # vectorized pack over all nodes at once (no per-node loop)
                low, his = gf.pack257_rows(red, out=low_buf)
                for i in range(1, n + 1):
                    pipe.submit(self._save_red_block, tmp, i,
                                low[i - 1], his[i - 1], crcs)
                # context exit joins every write and surfaces any I/O error
            # the manifest commits LAST: a generation without one is, by
            # definition, torn — steps()/restore never see it and
            # recover() deletes it
            manifest = {
                "step": step, "k": self.spec.k, "p": self.spec.p,
                "c": list(self.spec.c), "tree": tspec.to_json(),
                "crc": dict(sorted(crcs.items())),
            }
            self._write_blob(tmp / "manifest.json",
                             json.dumps(manifest).encode())
            self._commit_dir(tmp, d)
        except Exception:
            # best-effort immediate GC; a hard crash leaves the orphan
            # for recover() instead
            try:
                if self.iob.exists(tmp):
                    self.iob.rmtree(tmp)
            except OSError:
                pass
            raise
        finally:
            # the pipe context exit joined every write, so the staged
            # buffers are quiescent — safe to recycle (DESIGN.md §16.2)
            if pool is not None:
                for b in stage_bufs:
                    pool.release(b)
        self._gc()
        return manifest

    def _save_data_block(self, tmp: pathlib.Path, i: int,
                         block: np.ndarray, crcs: dict) -> None:
        raw = block.astype(np.uint8)
        crcs[f"node_{i:02d}.a"] = _crc_data(raw)
        self._write_blob(tmp / f"node_{i:02d}.a.npy", _npy_bytes(raw))

    def _save_red_block(self, tmp: pathlib.Path, i: int, low: np.ndarray,
                        hi: np.ndarray, crcs: dict) -> None:
        crcs[f"node_{i:02d}.r"] = _crc_red(low, hi)
        self._write_blob(tmp / f"node_{i:02d}.r.npz",
                         _npz_bytes(low=low, hi=hi))

    def _commit_dir(self, tmp: pathlib.Path, final: pathlib.Path) -> None:
        """Publish a fully-written staging dir with one rename (retried;
        an existing generation is parked under ``*.old.tmp`` first so a
        crash at any point leaves either the old or the new generation
        committed, never a mix — the park/GC windows leave only
        tmp-suffixed orphans recover() sweeps)."""
        old = None
        if self.iob.exists(final):
            old = final.parent / (final.name + ".old.tmp")
            if self.iob.exists(old):
                self.iob.rmtree(old)
            self.retry.call(lambda: self.iob.rename(final, old),
                            op=f"park:{final.name}", stats=self.retry_stats)
        self.retry.call(lambda: self.iob.rename(tmp, final),
                        op=f"commit:{final.name}", stats=self.retry_stats)
        if old is not None:
            self.iob.rmtree(old)
        self.iob.fsync_dir(final.parent)

    def _gc(self):
        steps = self.steps()
        for s in steps[: -self.keep_last]:
            if self._store is not None:
                pre = self._okey(s, "")
                for key in self._store.keys():
                    if key.startswith(pre):
                        self._store.delete(key)
            else:
                try:
                    self.iob.rmtree(self._step_dir(s))
                except OSError:
                    pass

    # ------------------------------------------------------------- block I/O
    def _read_block(self, ref) -> tuple[np.ndarray, int]:
        """One read -> (array, bytes read) — BOTH backends.

        ``ref`` is a node-file path (directory mode: ``.npz`` is a packed
        redundancy block via ``gf.pack257``, anything else a raw
        systematic byte block) or a store-object key string (store mode:
        the object's payload bytes, metered by the store's transfer
        receipt — systematic or degraded, whatever the store served).
        Every checkpoint read path funnels through here via
        :class:`_MeteredReader` so the byte meters can't drift apart.
        """
        if isinstance(ref, str):
            res = self._store.get_ext(ref)
            return np.frombuffer(res.obj, np.uint8), res.bytes_read
        if ref.suffix == ".npz":
            z = self._load(ref)
            low, hi = z["low"], z["hi"]
            return gf.unpack257(low, hi), low.nbytes + hi.nbytes
        arr = self._load(ref)
        return arr.astype(np.int32), arr.nbytes

    def _read_packed(self, ref) -> tuple[tuple[np.ndarray, np.ndarray], int]:
        """One packed redundancy read -> ((low, hi), bytes) — the raw
        pack257 parts, NOT unpacked: row-batched callers collect n of
        these and expand them in one `gf.unpack257_rows` pass."""
        z = self._load(ref)
        low, hi = z["low"], z["hi"]
        return (low, hi), low.nbytes + hi.nbytes

    # ---------------------------------------------------- tiled decode stages
    def _regenerate_tiled(self, pipe: Pipeline, node: int,
                          r_prev: np.ndarray,
                          next_data: np.ndarray) -> np.ndarray:
        """Depth-bounded stream-tile pipeline over the PLANNED fused
        regenerate: tile t+1 is dispatched while tile t's (2, T) result
        lands in the preallocated host pair buffer (mirrors the
        streaming save)."""
        out = np.empty((2, r_prev.shape[-1]), np.int32)
        pipe.stream_tiles(
            r_prev.shape[-1], self.save_tile_symbols,
            lambda sl: self.code.repair.regenerate_planned(
                node, r_prev[sl], next_data[:, sl]),
            lambda sl, res: out.__setitem__((slice(None), sl), res.host()))
        return out

    def _decode_tiled(self, pipe: Pipeline, mat: np.ndarray,
                      downloads: np.ndarray) -> np.ndarray:
        """Depth-bounded stream-tile pipeline for (mat @ downloads) mod p
        — the any-k decode (and, with repair rows stacked, the lost-pair
        re-encode) through the planned dispatch."""
        out = np.empty((mat.shape[0], downloads.shape[-1]), np.int32)
        pipe.stream_tiles(
            downloads.shape[-1], self.save_tile_symbols,
            lambda sl: self.code.repair.apply_planned(mat, downloads[:, sl]),
            lambda sl, res: out.__setitem__((slice(None), sl), res.host()))
        return out

    # ---------------------------------------------------------------- restore
    def restore(self, template: Any, step: Optional[int] = None,
                failed_nodes: Sequence[int] = (), *, repair: bool = True,
                ) -> tuple[Any, RestoreReport]:
        """Rebuild the pytree, repairing failed nodes along the way.

        Symmetric with the streaming save: node reads overlap through the
        thread pool, and the regenerate/reconstruct compute runs as a
        depth-2 stream-tile pipeline through the fused repair engine.

        Parameters
        ----------
        template : pytree
            Any pytree with the stored tree structure (values unused).
        step : int, optional
            Checkpoint step; None restores the latest.
        failed_nodes : sequence of int
            1-indexed dead hosts — their files are treated as unreadable.
        repair : bool
            When True the missing pairs are rebuilt bit-exactly and
            re-written to disk (the newcomer protocol); False only
            reconstructs the data in memory.

        Returns
        -------
        (state, report) : (pytree, RestoreReport)
            The rebuilt pytree and the byte-metered restore path taken
            (``systematic`` | ``regenerate`` | ``reconstruct``).

        Raises
        ------
        RuntimeError
            Fewer than k of the n nodes survive (> n - k failures).
        """
        if step is None:
            step = self.steps()[-1]
        if self._store is not None:
            return self._restore_store(template, step, failed_nodes)
        d = self._step_dir(step)
        manifest = json.loads(self._read_bytes(d / "manifest.json"))
        tspec = placement.TreeSpec.from_json(manifest["tree"])
        n, k = self.spec.n, self.spec.k
        failed = sorted(set(failed_nodes))
        alive = [i for i in range(1, n + 1) if i not in failed]
        if len(alive) < k:
            raise RuntimeError(f"unrecoverable: only {len(alive)} of n={n} "
                               f"nodes alive, need k={k}")
        repaired: list[int] = []

        with self._pipe() as pipe:
            reader = _MeteredReader(self, pipe)
            read_async, result = reader.submit, reader.take

            if not failed:
                futs = [read_async(self._node_files(step, i)[0])
                        for i in range(1, n + 1)]
                data = np.stack([result(f) for f in futs])
                path = "systematic"
            elif len(failed) == 1 and repair:
                f = failed[0]
                plan = self.code.repair_plan(f)
                fut_prev = read_async(self._node_files(step, plan.prev_node)[1])
                futs_help = [read_async(self._node_files(step, j)[0])
                             for j in plan.next_nodes]
                # the non-helper blocks are needed for the full restore
                # anyway — their reads overlap the regenerate compute
                rest = [i for i in range(1, n + 1)
                        if i != f and (i - 1) not in plan.data_indices]
                futs_rest = {i: read_async(self._node_files(step, i)[0])
                             for i in rest}
                r_prev = result(fut_prev)
                next_data = np.stack([result(x) for x in futs_help])
                pair = self._regenerate_tiled(pipe, f, r_prev, next_data)
                a_new, r_new = pair[0], pair[1]
                af, rf = self._node_files(step, f)
                low, hi = gf.pack257(r_new)
                pipe.submit(self._write_node_pair, af, rf, a_new, low, hi)
                repaired.append(f)
                data = np.zeros((n, tspec.block_symbols), np.int32)
                have = dict(zip(plan.data_indices, next_data))
                have[f - 1] = a_new
                for i in range(1, n + 1):
                    idx = i - 1
                    data[idx] = have[idx] if idx in have else result(futs_rest[i])
                path = "regenerate"
            else:
                use = alive[:k]                      # sorted by construction
                futs = [read_async(self._node_files(step, i)[0]) for i in use]
                futs_r = [reader.submit_packed(self._node_files(step, i)[1])
                          for i in use]
                # the (2k, S) download matrix stages in a pooled buffer
                # (DESIGN.md §16.1): data rows land in the top half as
                # the reads resolve, the redundancy rows expand into the
                # bottom half in one vectorized unpack — no stack or
                # concatenate copy on the restore path
                pool = self._staging_pool()
                s_sym = tspec.block_symbols
                downloads = (pool.acquire((2 * k, s_sym), np.int32)
                             if pool is not None
                             else np.empty((2 * k, s_sym), np.int32))
                for j, x in enumerate(futs):
                    downloads[j] = result(x)
                packed = [result(x) for x in futs_r]
                gf.unpack257_rows(np.stack([lo for lo, _ in packed]),
                                  [hi for _, hi in packed],
                                  out=downloads[k:])
                if repair and failed:
                    # one decode matmul yields the data AND every lost pair
                    mat = self.code.repair.decode_repair_matrix(
                        tuple(use), failed)
                    data, red_f = self.code.repair.split_decode_output(
                        self._decode_tiled(pipe, mat, downloads))
                    # one vectorized pack for all lost redundancy rows
                    low_f, his_f = gf.pack257_rows(red_f)
                    for j, fl in enumerate(failed):
                        af, rf = self._node_files(step, fl)
                        pipe.submit(self._write_node_pair, af, rf,
                                    data[fl - 1], low_f[j], his_f[j])
                        repaired.append(fl)
                else:
                    mat = self.code.repair.decode_matrix(tuple(use))
                    data = self._decode_tiled(pipe, mat, downloads)
                if pool is not None:
                    # every decode tile has materialized — quiescent
                    pool.release(downloads)
                path = "reconstruct"
            # context exit joins the repaired-pair writes

        treedef = jax.tree_util.tree_structure(template)
        state = placement.blocks_to_pytree(data.astype(np.int32), treedef, tspec)
        total = 2 * n * tspec.block_symbols          # ~bytes (packed storage)
        report = RestoreReport(step=step, path=path,
                               failed_nodes=tuple(failed),
                               bytes_read=reader.bytes_read,
                               bytes_total_stored=total,
                               repaired_nodes=tuple(repaired))
        return state, report

    def _restore_store(self, template: Any, step: int,
                       failed_nodes: Sequence[int]) -> tuple[Any, RestoreReport]:
        """Store-backed restore: get the leaf-group objects back through
        the store's transparent read path (systematic when healthy, the
        batched cached-inverse decode otherwise) and reassemble.

        ``failed_nodes`` must be empty — which *store* nodes are dead is
        the store's internal state, and repair is its scheduler's job,
        not the checkpointer's.
        """
        if failed_nodes:
            raise ValueError(
                "store-backed restore takes no failed_nodes: the store "
                "serves degraded reads transparently and its scheduler "
                "owns repair (DESIGN.md §10.4)")
        manifest_raw, mbytes = self._read_block(self._okey(step, "manifest"))
        manifest = json.loads(bytes(manifest_raw))
        tspec = placement.TreeSpec.from_json(manifest["tree"])
        # store objects are in-memory: serial reads through the shared
        # metering funnel (no I/O latency to hide with a pool)
        with self._pipe(io_workers=1) as pipe:
            reader = _MeteredReader(self, pipe)
            reader.bytes_read += mbytes
            futs = [reader.submit(self._okey(step, f"g{gi:04d}"))
                    for gi in range(manifest["n_groups"])]
            payload = b"".join(reader.take(f).tobytes() for f in futs)
        leaves = placement.bytes_to_leaves(payload, tspec.leaves)
        treedef = jax.tree_util.tree_structure(template)
        state = jax.tree_util.tree_unflatten(treedef, leaves)
        total = sum(
            2 * self._store.n * st.n_stripes * st.stripe_symbols
            for key in self._store.keys()
            if key.startswith(self._okey(step, ""))
            for st in (self._store.stat(key),))
        report = RestoreReport(step=step, path="store", failed_nodes=(),
                               bytes_read=reader.bytes_read,
                               bytes_total_stored=total)
        return state, report

    # -------------------------------------------------------------- accounting
    def gamma_bytes(self, tspec_block_symbols: int, *, mode: str) -> int:
        """Ideal byte counts (packed symbols ~ 1 byte each) for the three
        restore paths — eq. (7) and §III-B of the paper."""
        s = tspec_block_symbols
        if mode == "regenerate":
            return (self.spec.k + 1) * s
        if mode == "reconstruct":
            return 2 * self.spec.k * s
        if mode == "systematic":
            return self.spec.n * s
        raise ValueError(mode)

    def repair_node(self, step: int, node: int) -> int:
        """The newcomer protocol in isolation: rebuild node's (a, r) pair
        from d = k+1 reads (thread-pooled, fused tiled regenerate).
        Returns bytes read (the measured gamma).  Directory mode only —
        a store-backed checkpoint's nodes belong to the store's repair
        scheduler."""
        self._require_directory("repair_node")
        plan = self.code.repair_plan(node)
        with self._pipe() as pipe:
            reader = _MeteredReader(self, pipe)
            fut_prev = reader.submit(self._node_files(step, plan.prev_node)[1])
            futs = [reader.submit(self._node_files(step, j)[0])
                    for j in plan.next_nodes]
            r_prev = reader.take(fut_prev)
            helpers = [reader.take(f) for f in futs]
            pair = self._regenerate_tiled(pipe, node, r_prev,
                                          np.stack(helpers))
            af, rf = self._node_files(step, node)
            low, hi = gf.pack257(pair[1])
            pipe.submit(self._write_node_pair, af, rf, pair[0], low, hi)
        return reader.bytes_read

    def _require_directory(self, op: str) -> None:
        if self._store is not None:
            raise RuntimeError(
                f"{op} is directory-mode only: store-backed checkpoints "
                f"delegate node repair/verification to the store's "
                f"scheduler (DESIGN.md §10.4)")

    # ------------------------------------------------------------------ scrub
    def scrub(self, step: int) -> ScrubReport:
        """Degraded-read verification pass over one checkpoint step.

        Reads EVERY node pair and re-derives each one from its d = k+1
        helpers through the batched fused engine (stream-tiled), comparing
        bit-exactly against what is stored.  Run it after suspected partial
        writes or on cold archives before trusting a restore — a clean
        scrub certifies that every single-node repair of this step would
        succeed bit-exactly.  Cost: 2B bytes read + n fused tile matmuls;
        see DESIGN.md §4 for when to schedule it.

        Parameters
        ----------
        step : int
            Checkpoint step to verify (must exist on disk).

        Returns
        -------
        ScrubReport
            ``mismatched_nodes`` localizes damage (a corrupt block flags
            its own node and possibly neighbours whose regeneration
            consumed it); ``clean`` is True when every pair verified.
        """
        self._require_directory("scrub")
        n, k = self.spec.n, self.spec.k
        manifest = json.loads(
            self._read_bytes(self._step_dir(step) / "manifest.json"))
        crcs = manifest.get("crc") or {}
        with self._pipe() as pipe:
            reader = _MeteredReader(self, pipe)
            futs_a = [reader.submit(self._node_files(step, i)[0])
                      for i in range(1, n + 1)]
            futs_r = [reader.submit_packed(self._node_files(step, i)[1])
                      for i in range(1, n + 1)]
            rows_a = [reader.take(f) for f in futs_a]
            packed = [reader.take(f) for f in futs_r]
            data = np.stack(rows_a)
            # manifest content CRCs convict a damaged block exactly (the
            # algebraic pass below only localizes); checked when present
            mismatched: set[int] = set()
            for i in range(1, n + 1):
                ca = crcs.get(f"node_{i:02d}.a")
                cr = crcs.get(f"node_{i:02d}.r")
                if ca is not None and _crc_data(rows_a[i - 1]) != ca:
                    mismatched.add(i)
                if cr is not None and _crc_red(*packed[i - 1]) != cr:
                    mismatched.add(i)
            # all n redundancy rows expanded in ONE vectorized unpack —
            # into a pooled staging buffer, recycled after the last tile
            pool = self._staging_pool()
            low_all = np.stack([lo for lo, _ in packed])
            red_buf = (pool.acquire(low_all.shape, np.int32)
                       if pool is not None else None)
            red = gf.unpack257_rows(low_all, [hi for _, hi in packed],
                                    out=red_buf)
            nodes = list(range(1, n + 1))
            prev = np.asarray([self.code.repair_plan(i).prev_node - 1
                               for i in nodes])
            helper_idx = np.asarray([self.code.repair_plan(i).data_indices
                                     for i in nodes])              # (n, k)

            def flag(sl: slice, res) -> None:
                out = res.host()
                bad = ((out[:, 0] != data[:, sl]).any(axis=1)
                       | (out[:, 1] != red[:, sl]).any(axis=1))
                mismatched.update(int(x) + 1 for x in np.nonzero(bad)[0])

            # depth-bounded: compare tile t while t+1 computes, through the
            # planned batched engine (F = n is a fixed batch bucket)
            pipe.stream_tiles(
                data.shape[1], self.save_tile_symbols,
                lambda sl: self.code.repair.regenerate_batch_planned(
                    nodes, red[:, sl][prev], data[:, sl][helper_idx]),
                flag)
            if pool is not None:
                pool.release(red)       # last tile flagged — quiescent
        return ScrubReport(step=step, nodes_checked=n,
                           mismatched_nodes=tuple(sorted(mismatched)),
                           bytes_read=reader.bytes_read)
