"""Shape-bucketed execution-plan cache (DESIGN.md §11.1-§11.2).

Every hot-path GF operation has one large *stream* axis (symbols) whose
extent varies per object/leaf/stripe, and a handful of tiny static axes
(the code dimensions n, k, a batch count F).  `jax.jit` keyed on raw
shapes retraces and recompiles once per distinct stream extent — a
mixed-size workload with thousands of object sizes pays thousands of
XLA compiles for what is the same program at different paddings.

The :class:`PlanCache` removes that cost structurally:

* the stream axis is padded **up** to a small geometric ladder of shape
  buckets (:func:`bucket_symbols`) — log-many buckets cover any size
  range, and padding is bit-exact because every planned op is
  column-local over the stream axis (zero columns in, zero columns out,
  sliced off host-side before anyone looks);
* variable *batch* axes (the F failed-node axis of ``regenerate_batch``)
  are bucketed the same way, so a drain of 3 stripes and a drain of 5
  share one executable;
* each ``(op, static dims, bucket)`` key is lowered ONCE to an
  ahead-of-time compiled executable (``jax.jit(...).lower(...)
  .compile()``) with the stream operand **donated** on device backends
  whenever an output can actually alias it (encode's (n, S) -> (n, S),
  the square any-k decode) — the padded staging buffer is dead after
  the call, so XLA reuses it instead of allocating;
* :func:`plan_stats` exposes lifetime hits / misses / compiles across
  every live planner, which is how the recompile-regression test and
  ``benchmarks/bench_pipeline.py`` assert the steady-state guarantee:
  after warm-up, a mixed-size put/get/restore workload performs ZERO
  new compiles.

Planners are shared process-wide per ``(backend, p, ladder, donation,
mesh)`` via :func:`get_planner` so every code instance on the same
backend hits one executable cache.  :func:`planning_disabled` restores
the raw jit-per-shape dispatch (the pre-plan behavior) for A/B
measurement.

Mesh-sharded plans (DESIGN.md §14): pass ``mesh=`` (a
``repro.sharding.mesh.StreamMesh``, an int shard count, or None) and
every executable is lowered as ``jit(shard_map(op))`` over the stream
axis under the declarative rule registry.  The bucket ladder then runs
*per shard*: the stream extent is split ceil(s / m) per device, THAT is
bucketed, and the global operand pads to ``m * shard_bucket`` — so each
shape bucket compiles once per-shard shape, stream lengths not
divisible by the mesh just pad (still bit-exact: column-local ops),
and a 1-device mesh normalizes to the plain unsharded planner (same
object, same executables — no spurious recompiles when the device
count collapses to one).
"""
from __future__ import annotations

import contextlib
import math
import threading
from time import perf_counter
from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from .staging import StagingPool, record_stage

# Ladder defaults: buckets 4096, 8192, 16384, ... — stream extents below
# the floor all share the smallest executable, and a ratio-2 ladder
# bounds padded compute at 2x while keeping the executable count
# logarithmic in the size range.  Ratio 2 also makes every power-of-two
# tile (the checkpointer's stream tiles, the store's full put windows)
# an EXACT bucket hit, so the tiled hot loops never pad at all — only
# odd tails and whole small objects pay the padding tax.
BUCKET_MIN = 1 << 12
BUCKET_RATIO = 2.0

# Batch axes (regenerate_batch's F) are tiny; a finer floor avoids
# padding a single-failure repair up to a 4096-wide batch.
BATCH_BUCKET_MIN = 4

_ENABLED = True
_LOCK = threading.Lock()
_REGISTRY: dict[tuple, "PlanCache"] = {}


def bucket_symbols(s: int, *, bucket_min: int = BUCKET_MIN,
                   ratio: float = BUCKET_RATIO) -> int:
    """Smallest ladder bucket >= ``s``: bucket_min * ratio^j, j >= 0.

    >>> bucket_symbols(1000)
    4096
    >>> bucket_symbols(4097)
    8192
    """
    if s <= 0:
        raise ValueError(f"stream extent must be positive, got {s}")
    if ratio <= 1.0:
        raise ValueError(f"ladder ratio must be > 1, got {ratio}")
    if s <= bucket_min:
        return bucket_min
    # ceil in log space, then walk down float error
    j = max(0, math.ceil(math.log(s / bucket_min) / math.log(ratio)))
    b = int(math.ceil(bucket_min * ratio ** j))
    while b < s:                                   # float round-down guard
        j += 1
        b = int(math.ceil(bucket_min * ratio ** j))
    while j > 0 and int(math.ceil(bucket_min * ratio ** (j - 1))) >= s:
        j -= 1
        b = int(math.ceil(bucket_min * ratio ** j))
    return b


def set_planning(enabled: bool) -> None:
    """Process-wide switch: False restores raw jit-per-shape dispatch."""
    global _ENABLED
    _ENABLED = bool(enabled)


def planning_enabled() -> bool:
    return _ENABLED


@contextlib.contextmanager
def planning_disabled():
    """Temporarily bypass every plan cache (the benchmark's "before")."""
    prev = _ENABLED
    set_planning(False)
    try:
        yield
    finally:
        set_planning(prev)


def make_regen_fn(mm: Callable, p: int) -> Callable:
    """THE fused newcomer kernel — the single definition both execution
    modes trace (planned AOT executables here, the per-shape jit paths
    in `core/repair.py`), so the two can never desync.

    Algebraically R @ [r_prev; next_data]; the r_prev column is peeled
    out of the dispatched matmul into a row-0 scale-accumulate epilogue
    (R[1, 0] is 0, so only the decode row touches r_prev).  Exactness:
    the matmul output is < p and the epilogue term is <= (p-1)^2, so the
    sum stays inside the int32 envelope (kernels/envelope.py guarantees
    (p-1) + (p-1)^2 < 2^31) before the single fold.
    """
    def fn(rmat, r_prev, next_data):
        part = mm(rmat[:, 1:], next_data, p)
        return part.at[0].set((part[0] + rmat[0, 0] * r_prev) % p)

    return fn


class PlanStats(NamedTuple):
    """Executable-cache accounting: ``misses`` trigger ``compiles``
    (they differ only if a lowering raises), ``hits`` run an existing
    executable with zero trace/compile work."""
    hits: int
    misses: int
    compiles: int


class PlanResult:
    """A planned op's asynchronous result: the (possibly padded) device
    value plus the true stream extent.

    Dispatch is async — holding a PlanResult does NOT block on the
    device.  :meth:`host` blocks, materializes, and slices the padding
    off with a host-side numpy view (deliberately NOT a device slice:
    a ``lax.slice`` per distinct extent would reintroduce the very
    per-shape compiles the plan cache exists to remove).
    """

    __slots__ = ("raw", "symbols", "batch", "_release")

    def __init__(self, raw, symbols: int, batch: Optional[int] = None,
                 release: Optional[Callable] = None):
        self.raw = raw
        self.symbols = int(symbols)
        self.batch = None if batch is None else int(batch)
        self._release = release

    def host(self) -> np.ndarray:
        """Block and return the exact (unpadded) result as numpy —
        stream padding sliced off the last axis, batch padding (when the
        op bucketed a leading batch axis) off the first.

        Materializing is also the staging release point: any pooled pad
        buffers the dispatch read are recycled here, AFTER the blocking
        conversion proves the compute consumed them (DESIGN.md §16.2).
        A PlanResult dropped without ``host()`` simply strands its
        buffers — the pool never reissues an unreleased buffer, so that
        is safe, just not free."""
        out = np.asarray(self.raw)
        if self._release is not None:
            rel, self._release = self._release, None
            rel()
        if out.shape[-1] != self.symbols:
            out = out[..., : self.symbols]
        if self.batch is not None and out.shape[0] != self.batch:
            out = out[: self.batch]
        return out

    def __array__(self, dtype=None):
        out = self.host()
        return out if dtype is None else out.astype(dtype)


def _pad_last(arr: np.ndarray, bucket: int,
              pool: Optional["StagingPool"] = None,
              bufs: Optional[list] = None) -> np.ndarray:
    """Zero-pad the stream (last) axis up to ``bucket``.

    JAX reads host operands asynchronously (after dispatch returns), so
    a scratch buffer may not be reused while an in-flight compute still
    reads it.  With ``pool`` set, the pad stages into a pooled buffer
    appended to ``bufs`` — the caller attaches the buffers to the
    PlanResult, whose ``host()`` (the dispatch-completion proof)
    releases them back to the pool (DESIGN.md §16.2).  Without a pool
    the historical always-fresh buffer keeps the same safety the hard
    way.
    """
    arr = np.asarray(arr, np.int32)
    s = arr.shape[-1]
    if s == bucket:
        return arr
    t0 = perf_counter()
    if pool is None:
        out = np.zeros(arr.shape[:-1] + (bucket,), np.int32)
        out[..., :s] = arr
    else:
        out = pool.acquire(arr.shape[:-1] + (bucket,), np.int32)
        out[..., :s] = arr
        out[..., s:] = 0            # reused buffer: tail must be re-zeroed
        bufs.append(out)
    record_stage("pad", perf_counter() - t0)
    return out


def _pad_both(arr: np.ndarray, f_bucket: int, s_bucket: int,
              pool: Optional["StagingPool"] = None,
              bufs: Optional[list] = None) -> np.ndarray:
    """Pad axis 0 to ``f_bucket`` and the last axis to ``s_bucket`` in
    one copy (the batched-regenerate operands); pooled like
    :func:`_pad_last` when ``pool`` is set."""
    arr = np.asarray(arr, np.int32)
    f, s = arr.shape[0], arr.shape[-1]
    if f == f_bucket and s == s_bucket:
        return arr
    t0 = perf_counter()
    shape = (f_bucket,) + arr.shape[1:-1] + (s_bucket,)
    if pool is None:
        out = np.zeros(shape, np.int32)
        out[:f, ..., :s] = arr
    else:
        out = pool.acquire(shape, np.int32)
        out[...] = 0
        out[:f, ..., :s] = arr
        bufs.append(out)
    record_stage("pad", perf_counter() - t0)
    return out


class PlanCache:
    """AOT-compiled, shape-bucketed executables for one (backend, p).

    Parameters
    ----------
    backend : repro.kernels.dispatch.GFBackend
        The exact GF implementation the plans lower through; its matmul
        / circulant_encode primitives are traced INSIDE each plan, so a
        plan is exactly the dispatched op at a fixed padded shape.
    p : int
        Field modulus (static in every executable).
    bucket_min, bucket_ratio :
        The stream-axis ladder (:func:`bucket_symbols`).
    donate : bool, optional
        Donate the stream operand to XLA where an output can alias it.
        Default: True on device backends (gpu/tpu — operands live in
        device buffers the planner's host copy populated), False on CPU,
        where XLA may read the HOST numpy buffer in place: donating an
        exact-bucket-fit caller array there could let the output
        overwrite caller memory.  Donation is disabled on sharded plans
        (the padded staging buffer is host-side and gets scattered to
        per-device shards; there is no whole-buffer alias to reuse).
    mesh : StreamMesh | int | None, optional
        Shard every plan over this stream-axis mesh (DESIGN.md §14).
        A 1-device mesh is normalized to None — the plain dispatch
        fallback.

    Notes
    -----
    All planned ops are column-local over the stream axis, which is the
    bit-exactness argument for bucketing: a zero symbol column maps to a
    zero output column through matmul, circulant encode and the fused
    regenerate epilogue alike, and :meth:`PlanResult.host` slices those
    columns off before any caller sees them.
    """

    def __init__(self, backend, p: int, *, bucket_min: int = BUCKET_MIN,
                 bucket_ratio: float = BUCKET_RATIO,
                 donate: Optional[bool] = None, mesh=None):
        from repro.sharding.mesh import as_stream_mesh
        self.backend = backend
        self.backend_name = getattr(backend, "name", "custom")
        self.p = int(p)
        self.bucket_min = int(bucket_min)
        self.bucket_ratio = float(bucket_ratio)
        mesh = as_stream_mesh(mesh)
        if mesh is not None and mesh.is_trivial:
            mesh = None                 # single-device: plain dispatch
        self.mesh = mesh
        if donate is None:
            donate = jax.default_backend() not in ("cpu",)
        if mesh is not None:
            donate = False              # see class docstring
        self.donate = bool(donate)
        # pooled zero-copy pad staging (DESIGN.md §16): pad buffers are
        # acquired here and released by PlanResult.host() once the
        # dispatch that read them has provably completed
        self.staging = StagingPool()
        self._plans: dict[tuple, Callable] = {}
        self._lock = threading.Lock()
        self.hits = 0
        self.misses = 0
        self.compiles = 0
        # per code-family accounting (DESIGN.md §15.4): ops dispatched
        # with a `tag` (the family identity string) count under that
        # tag; untagged ops — the pre-existing double-circulant paths —
        # under "default".  Tagged ops also mix the tag into the plan
        # key, so families with overlapping shapes never share (or
        # fight over) an executable slot.
        self.family_stats: dict[str, list[int]] = {}

    # ------------------------------------------------------------- plumbing
    def bucket(self, s: int) -> int:
        return bucket_symbols(s, bucket_min=self.bucket_min,
                              ratio=self.bucket_ratio)

    def batch_bucket(self, f: int) -> int:
        return bucket_symbols(f, bucket_min=BATCH_BUCKET_MIN,
                              ratio=self.bucket_ratio)

    def stream_pad(self, s: int) -> tuple[int, int]:
        """(plan-key bucket, padded stream extent) for a true extent s.

        Unsharded: both are the ladder bucket.  Sharded: the ladder runs
        per shard — bucket ceil(s / m), pad the global operand to
        m * shard_bucket so every device sees the same bucketed shard
        shape (one compile per-shard shape; lengths not divisible by the
        mesh just pad, still bit-exact because the ops are column-local).
        """
        if self.mesh is None:
            b = self.bucket(s)
            return b, b
        sb = self.bucket(self.mesh.shard_extent(s))
        return sb, sb * self.mesh.size

    def _i32(self, *shapes):
        return [jax.ShapeDtypeStruct(s, jnp.int32) for s in shapes]

    def _compile(self, op: str, fn: Callable, shapes, donate=()):
        """Lower + AOT-compile ``fn`` at ``shapes``: plain jit when
        unsharded, ``jit(shard_map(fn))`` under the op's registered
        sharding rule when meshed (inputs/outputs pinned to the rule's
        NamedShardings, so host numpy operands are scattered straight to
        their per-device shards at call time)."""
        if self.mesh is None:
            jf = jax.jit(fn, donate_argnums=donate)
        else:
            from repro.sharding.mesh import get_rule, shard_body
            rule = get_rule(op)
            jf = jax.jit(shard_body(fn, op, self.mesh),
                         in_shardings=self.mesh.shardings(rule.in_specs),
                         out_shardings=self.mesh.sharding(rule.out_specs))
        return jf.lower(*self._i32(*shapes)).compile()

    def _exe(self, key: tuple, build: Callable[[], Callable],
             tag: Optional[str] = None) -> Callable:
        fam = tag or "default"
        with self._lock:
            row = self.family_stats.setdefault(fam, [0, 0, 0])
            exe = self._plans.get(key)
            if exe is not None:
                self.hits += 1
                row[0] += 1
                return exe
            self.misses += 1
            row[1] += 1
            exe = build()
            self.compiles += 1
            row[2] += 1
            self._plans[key] = exe
            return exe

    def _releaser(self, bufs: list) -> Optional[Callable]:
        """A PlanResult release hook recycling ``bufs`` (pooled pad
        staging) — None when nothing was staged."""
        if not bufs:
            return None
        pool = self.staging

        def rel():
            for b in bufs:
                pool.release(b)

        return rel

    @staticmethod
    def _tagged(key: tuple, tag: Optional[str]) -> tuple:
        """Mix a family tag into a plan key.  ``None`` (every
        pre-existing caller) leaves the key byte-identical — no
        recompiles ride along with the tagging feature."""
        return key if tag is None else key + (tag,)

    def plan_stats(self) -> PlanStats:
        return PlanStats(self.hits, self.misses, self.compiles)

    def plan_stats_by_family(self) -> dict[str, PlanStats]:
        """Per-family hit/miss/compile counters (ops dispatched without
        a tag land under ``"default"``)."""
        with self._lock:
            return {fam: PlanStats(*row)
                    for fam, row in sorted(self.family_stats.items())}

    def reset_stats(self) -> None:
        self.hits = self.misses = self.compiles = 0
        self.family_stats = {}

    def clear(self) -> None:
        with self._lock:
            self._plans.clear()
        self.reset_stats()

    def __len__(self) -> int:
        return len(self._plans)

    # ------------------------------------------------------------------ ops
    def matmul(self, mat, blocks, *, tag: Optional[str] = None) -> PlanResult:
        """(mat @ blocks) mod p — the decode-side workhorse.

        ``mat`` is a small runtime operand (cached decode inverses, the
        combined decode+re-encode matrix, row subsets for degraded
        reads); its shape is part of the plan key, its VALUES are not.
        Only ``blocks`` (the stream operand) is padded and donated.
        ``tag`` is the dispatching code family's identity — mixed into
        the plan key and the per-family stats (DESIGN.md §15.4).
        """
        mat = np.asarray(mat, np.int32)
        blocks = np.asarray(blocks, np.int32)
        s = blocks.shape[-1]
        if not _ENABLED:
            return PlanResult(self.backend.matmul(mat, blocks, self.p), s)
        b, pad = self.stream_pad(s)
        key = self._tagged(("matmul", mat.shape, blocks.shape[:-1], b), tag)
        # donation is only usable when an output can alias the donated
        # buffer, i.e. the product has the stream operand's exact shape
        # (square decode matrices: the (n, n) any-k inverse) — donating
        # anything else just trips XLA's unusable-donation warning
        donate = (1,) if self.donate and mat.shape[0] == blocks.shape[0] \
            else ()

        def build():
            fn = lambda a, x: self.backend.matmul(a, x, self.p)
            return self._compile("matmul", fn,
                                 (mat.shape, blocks.shape[:-1] + (pad,)),
                                 donate)

        bufs: list = []
        padded = _pad_last(blocks, pad, self.staging, bufs)
        return PlanResult(self._exe(key, build, tag)(mat, padded), s,
                          release=self._releaser(bufs))

    def circulant_encode(self, data, c, *, tag: Optional[str] = None,
                         ) -> PlanResult:
        """The paper's eq. (2) encode at a bucketed stream extent.

        The coefficient tuple ``c`` is static in the underlying kernels,
        so it is part of the plan key — one executable per code, not per
        call.
        """
        data = np.asarray(data, np.int32)
        c = tuple(int(x) for x in c)
        s = data.shape[-1]
        if not _ENABLED:
            return PlanResult(self.backend.circulant_encode(data, c, self.p),
                              s)
        b, pad = self.stream_pad(s)
        key = self._tagged(("circ", data.shape[0], c, b), tag)

        def build():
            fn = lambda d: self.backend.circulant_encode(d, c, self.p)
            return self._compile("circulant_encode", fn,
                                 ((data.shape[0], pad),),
                                 (0,) if self.donate else ())

        bufs: list = []
        padded = _pad_last(data, pad, self.staging, bufs)
        return PlanResult(self._exe(key, build, tag)(padded), s,
                          release=self._releaser(bufs))

    def regenerate(self, rmat, r_prev, next_data) -> PlanResult:
        """The fused (2, k+1) repair-matrix application (DESIGN.md §4):
        backend matmul over the k helper blocks + the row-0 axpy
        epilogue on r_prev, one executable per (k, bucket)."""
        rmat = np.asarray(rmat, np.int32)
        r_prev = np.asarray(r_prev, np.int32)
        next_data = np.asarray(next_data, np.int32)
        s = r_prev.shape[-1]
        if not _ENABLED:
            return PlanResult(
                self._regen_fn()(rmat, r_prev, next_data), s)
        b, pad = self.stream_pad(s)
        k = next_data.shape[0]
        key = ("regen", k, b)

        def build():
            # the (2, S) pair can alias next_data only at k == 2
            donate = (2,) if self.donate and k == 2 else ()
            return self._compile("regenerate", self._regen_fn(),
                                 (rmat.shape, (pad,), (k, pad)), donate)

        bufs: list = []
        return PlanResult(self._exe(key, build)(
            rmat, _pad_last(r_prev, pad, self.staging, bufs),
            _pad_last(next_data, pad, self.staging, bufs)), s,
            release=self._releaser(bufs))

    def regenerate_batch(self, rmat, r_prevs, next_data) -> PlanResult:
        """Vmapped fused regeneration with BOTH variable axes bucketed:
        the stream axis on the symbol ladder, the failed-node axis F on
        the batch ladder (zero-padded tasks regenerate zeros).

        Returns a PlanResult whose raw value is (F_bucket, 2, S_bucket);
        ``host()`` trims both paddings back to (F, 2, S).
        """
        rmat = np.asarray(rmat, np.int32)
        r_prevs = np.asarray(r_prevs, np.int32)
        next_data = np.asarray(next_data, np.int32)
        s = r_prevs.shape[-1]
        f, k = next_data.shape[0], next_data.shape[1]
        if not _ENABLED:
            one = self._regen_fn()
            return PlanResult(jax.vmap(lambda rp, nd: one(rmat, rp, nd))(
                r_prevs, next_data), s, batch=f)
        b, pad = self.stream_pad(s)
        fb = self.batch_bucket(f)
        key = ("regen_batch", fb, k, b)

        def build():
            one = self._regen_fn()

            def fn(rm, rps, nds):
                return jax.vmap(lambda rp, nd: one(rm, rp, nd))(rps, nds)

            # the (F, 2, S) output can alias next_data only at k == 2
            donate = (2,) if self.donate and k == 2 else ()
            return self._compile("regenerate_batch", fn,
                                 (rmat.shape, (fb, pad), (fb, k, pad)),
                                 donate)

        bufs: list = []
        return PlanResult(self._exe(key, build)(
            rmat, _pad_both(r_prevs, fb, pad, self.staging, bufs),
            _pad_both(next_data, fb, pad, self.staging, bufs)), s, batch=f,
            release=self._releaser(bufs))

    def matmul_batch(self, mats, blocks, *,
                     tag: Optional[str] = None) -> PlanResult:
        """Per-element batched (q, d) @ (d, S) mod p — the coalesced
        regeneration dispatch for families WITHOUT a node-invariant
        repair matrix (product-matrix MSR: the newcomer matrix differs
        per (node, helpers), so ``regenerate_batch``'s shared-matrix
        vmap does not apply).

        mats: (F, q, d) int — one newcomer matrix per batch element.
        blocks: (F, d, S) — the stacked helper sends per element.
        Returns (F, q, S) via ``host()``; both the batch axis and the
        stream axis are bucketed (zero-padded elements multiply zeros).
        """
        mats = np.asarray(mats, np.int32)
        blocks = np.asarray(blocks, np.int32)
        if mats.ndim != 3 or blocks.ndim != 3 or \
                mats.shape[0] != blocks.shape[0] or \
                mats.shape[2] != blocks.shape[1]:
            raise ValueError(f"matmul_batch needs (F, q, d) mats and "
                             f"(F, d, S) blocks, got {mats.shape} / "
                             f"{blocks.shape}")
        f, s = blocks.shape[0], blocks.shape[-1]
        if not _ENABLED:
            out = ((mats.astype(np.int64) @ blocks.astype(np.int64))
                   % self.p).astype(np.int32)
            return PlanResult(out, s, batch=f)
        b, pad = self.stream_pad(s)
        fb = self.batch_bucket(f)
        key = self._tagged(("matmul_batch", mats.shape[1:], fb, b), tag)

        def build():
            def fn(ms, xs):
                return jax.vmap(
                    lambda m, x: self.backend.matmul(m, x, self.p))(ms, xs)

            return self._compile(
                "matmul_batch", fn,
                ((fb,) + mats.shape[1:], (fb, blocks.shape[1], pad)))

        if mats.shape[0] != fb:     # tiny (F, q, d) stack: plain pad
            pm = np.zeros((fb,) + mats.shape[1:], np.int32)
            pm[:f] = mats
            mats = pm
        bufs: list = []
        return PlanResult(self._exe(key, build, tag)(
            mats, _pad_both(blocks, fb, pad, self.staging, bufs)),
            s, batch=f, release=self._releaser(bufs))

    def _regen_fn(self):
        return make_regen_fn(self.backend.matmul, self.p)


# --------------------------------------------------------------- registry
def get_planner(backend, p: int, *, bucket_min: int = BUCKET_MIN,
                bucket_ratio: float = BUCKET_RATIO,
                donate: Optional[bool] = None, mesh=None) -> PlanCache:
    """The shared PlanCache for (backend, p, ladder, donation, mesh) —
    every code/engine on the same backend and mesh shares one executable
    cache.  A 1-device mesh normalizes to the UNSHARDED planner (the
    very same object), so collapsing the device count to one changes
    neither results nor compile counts."""
    from repro.sharding.mesh import as_stream_mesh
    mesh = as_stream_mesh(mesh)
    if mesh is not None and mesh.is_trivial:
        mesh = None
    if donate is None:
        donate = jax.default_backend() not in ("cpu",)
    if mesh is not None:
        donate = False                  # matches PlanCache normalization
    key = (getattr(backend, "name", id(backend)), int(p), int(bucket_min),
           float(bucket_ratio), bool(donate),
           None if mesh is None else mesh.key())
    with _LOCK:
        pc = _REGISTRY.get(key)
        if pc is None:
            pc = PlanCache(backend, p, bucket_min=bucket_min,
                           bucket_ratio=bucket_ratio, donate=donate,
                           mesh=mesh)
            _REGISTRY[key] = pc
        return pc


def plan_stats() -> PlanStats:
    """Aggregate hits/misses/compiles over every live planner — the
    number tests and ``bench_pipeline`` watch for steady-state zeros."""
    h = m = c = 0
    with _LOCK:
        planners = list(_REGISTRY.values())
    for pc in planners:
        st = pc.plan_stats()
        h += st.hits
        m += st.misses
        c += st.compiles
    return PlanStats(h, m, c)


def plan_stats_by_family() -> dict[str, PlanStats]:
    """Per-family hit/miss/compile counters aggregated over every live
    planner (DESIGN.md §15.4) — untagged double-circulant traffic lands
    under ``"default"``, each other family under its identity string."""
    agg: dict[str, list[int]] = {}
    with _LOCK:
        planners = list(_REGISTRY.values())
    for pc in planners:
        for fam, st in pc.plan_stats_by_family().items():
            row = agg.setdefault(fam, [0, 0, 0])
            row[0] += st.hits
            row[1] += st.misses
            row[2] += st.compiles
    return {fam: PlanStats(*row) for fam, row in sorted(agg.items())}


def reset_plan_stats() -> None:
    with _LOCK:
        planners = list(_REGISTRY.values())
    for pc in planners:
        pc.reset_stats()


def clear_planners() -> None:
    """Drop every cached executable AND registry entry (tests only)."""
    with _LOCK:
        for pc in _REGISTRY.values():
            pc.clear()
        _REGISTRY.clear()


__all__ = [
    "BUCKET_MIN", "BUCKET_RATIO", "BATCH_BUCKET_MIN",
    "bucket_symbols", "make_regen_fn",
    "PlanCache", "PlanResult", "PlanStats",
    "get_planner", "plan_stats", "plan_stats_by_family",
    "reset_plan_stats", "clear_planners",
    "set_planning", "planning_enabled", "planning_disabled",
]
