"""Unified overlapped I/O⇄compute pipeline (DESIGN.md §11.3).

One stage engine for every hot path that used to hand-roll its own
streaming loop (checkpoint save/restore/repair/scrub) or run serially
(store put/get, scheduler drain batches):

    read (thread pool)  →  compute (async device dispatch)  →  consume

The engine is *depth-bounded*: compute for item t+1..t+depth-1 is
dispatched before item t's result is consumed, so at most ``depth``
device results are in flight (depth 2 = classic double buffering;
depth 1 = serial, the benchmark's no-overlap baseline).  Reads prefetch
``depth`` items ahead through the pool, and consume callbacks may
:meth:`Pipeline.submit` host writes onto the same pool — joined, with
errors surfaced, at :meth:`barrier`/exit.

JAX dispatch is asynchronous, so ``compute`` returning a device value
(or a `repro.exec.plan.PlanResult`) costs near-zero wall time; the
blocking materialization happens inside ``consume`` (``.host()`` /
``np.asarray``) — by which point the NEXT item's compute is already
running on the device threads while the pool moves bytes.

Two lifecycles:

* context-managed (checkpointer paths): ``with Pipeline(...) as p:`` —
  exit joins every submitted future and surfaces the first error;
* persistent (the object store keeps one pipeline for its lifetime):
  each :meth:`map`/:meth:`stream_tiles` call barriers its own work, the
  pool thread(s) are reused across calls, :meth:`close` shuts down.
"""
from __future__ import annotations

import threading
from collections import deque
from concurrent.futures import Future, ThreadPoolExecutor
from time import perf_counter
from typing import Callable, Iterable, Optional, Sequence

from . import staging

DEFAULT_DEPTH = 2


class Pipeline:
    """Depth-bounded read → compute → consume engine with a shared
    host-I/O pool.

    Parameters
    ----------
    io_workers : int
        Pool threads for reads and submitted writes.
    depth : int
        Max device results in flight (1 = serial; 2 = double-buffered).

    Notes
    -----
    A pipeline instance is not re-entrant: one ``map``/``stream_tiles``
    runs at a time (the store and checkpointer each own theirs).
    """

    def __init__(self, *, io_workers: int = 4, depth: int = DEFAULT_DEPTH):
        self.io_workers = max(1, int(io_workers))
        self.depth = max(1, int(depth))
        self._ex: Optional[ThreadPoolExecutor] = None
        self._futs: list[Future] = []
        self._stage_lock = threading.Lock()
        self._stage: dict = {}
        self.reset_stage_stats()

    # ------------------------------------------------------ stage accounting
    def reset_stage_stats(self) -> None:
        """Zero this pipeline's stage timers and rebase the process-wide
        pack/pad clocks (DESIGN.md §16.3)."""
        with self._stage_lock:
            self._stage = {"t_stage_read": 0.0, "t_dispatch": 0.0,
                           "t_consume": 0.0}
            self._stage_base = staging.stage_times()

    def _acct(self, name: str, dt: float) -> None:
        with self._stage_lock:
            self._stage[name] += dt

    def stage_stats(self) -> dict:
        """Cumulative wall seconds per pipeline stage since the last
        :meth:`reset_stage_stats`.

        ``t_stage_read`` / ``t_dispatch`` / ``t_consume`` are timed
        around this pipeline's read/compute/consume callbacks (read time
        is pool-thread time, so at depth >= 2 it largely overlaps the
        other two).  ``t_pack`` (flatten / pack257 staging writes) and
        ``t_pad`` (planner bucket padding) are deltas of the
        process-wide stage clock in `repro.exec.staging` — the staging
        work those callbacks triggered, wherever it ran.
        """
        g = staging.stage_times()
        with self._stage_lock:
            out = dict(self._stage)
            base = self._stage_base
        out["t_pack"] = g.get("pack", 0.0) - base.get("pack", 0.0)
        out["t_pad"] = g.get("pad", 0.0) - base.get("pad", 0.0)
        return out

    # ------------------------------------------------------------ lifecycle
    def _pool(self) -> ThreadPoolExecutor:
        if self._ex is None:
            self._ex = ThreadPoolExecutor(max_workers=self.io_workers)
        return self._ex

    def __enter__(self) -> "Pipeline":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        if exc_type is None:
            self.close()
        else:                      # don't mask the in-flight exception,
            self.close(wait=True, surface=False)   # but never leak threads
        return None

    def close(self, *, wait: bool = True, surface: bool = True) -> None:
        """Join tracked futures (surfacing the first error) and shut the
        pool down; the pipeline may be reused afterwards (a fresh pool
        is created lazily)."""
        try:
            if surface:
                self.barrier()
        finally:
            if self._ex is not None:
                self._ex.shutdown(wait=wait)
                self._ex = None
                self._futs = []

    # ----------------------------------------------------------- host pool
    def submit(self, fn: Callable, *args, **kwargs) -> Future:
        """Schedule a host I/O task (file write, share placement, read)
        on the pool; tracked until the next :meth:`barrier`."""
        fut = self._pool().submit(fn, *args, **kwargs)
        self._futs.append(fut)
        return fut

    def barrier(self) -> None:
        """Wait for every tracked future; re-raise the first failure."""
        futs, self._futs = self._futs, []
        for f in futs:
            f.result()

    # -------------------------------------------------------------- stages
    def stream_tiles(self, s_total: int, tile: int,
                     compute: Callable, consume: Callable) -> None:
        """Depth-bounded tile loop over one stream axis (the engine the
        checkpointer's save/restore/scrub share).

        ``compute(sl)`` dispatches stream slice ``sl`` to the device and
        returns without blocking; ``consume(sl, result)`` lands the
        result host-side.  With depth d, tile t is consumed only after
        tiles t+1..t+d-1 have been dispatched.
        """
        tile = max(1, int(tile))
        self.map([slice(s0, min(s0 + tile, s_total))
                  for s0 in range(0, s_total, tile)], compute, consume)

    def map(self, items: Iterable, compute: Callable, consume: Callable, *,
            read: Optional[Callable] = None) -> None:
        """Run ``items`` through read → compute → consume, depth-bounded.

        Parameters
        ----------
        items : iterable
            Work descriptors, processed (and consumed) in order.
        compute : callable
            ``compute(item)`` — or ``compute(item, read_result)`` when
            ``read`` is given.  Should dispatch asynchronously (device
            work / PlanResult); its return value is handed to consume.
        consume : callable
            ``consume(item, compute_result)`` — the blocking stage; may
            :meth:`submit` further host writes.
        read : callable, optional
            ``read(item)`` runs on the pool, prefetched ``depth`` items
            ahead of compute.
        """
        items = list(items)
        if not items:
            return

        timed_read = None
        if read is not None:
            def timed_read(it):
                t0 = perf_counter()
                data = read(it)
                self._acct("t_stage_read", perf_counter() - t0)
                return data

        # depth 1 is the true serial baseline: no prefetch, reads run
        # inline — stage overlap exists only at depth >= 2
        ahead = self.depth if self.depth > 1 else 0
        read_futs: dict[int, Future] = {}
        if read is not None:
            for j in range(min(ahead, len(items))):
                read_futs[j] = self._pool().submit(timed_read, items[j])

        def _consume(it0, out0):
            t0 = perf_counter()
            consume(it0, out0)
            self._acct("t_consume", perf_counter() - t0)

        pending: deque = deque()
        try:
            for i, item in enumerate(items):
                if read is not None:
                    if i in read_futs:
                        data = read_futs.pop(i).result()
                    else:
                        data = timed_read(items[i])
                    nxt = i + ahead
                    if ahead and nxt < len(items):
                        read_futs[nxt] = self._pool().submit(
                            timed_read, items[nxt])
                    t0 = perf_counter()
                    out = compute(item, data)
                else:
                    t0 = perf_counter()
                    out = compute(item)
                self._acct("t_dispatch", perf_counter() - t0)
                pending.append((item, out))
                while len(pending) >= self.depth:
                    it0, out0 = pending.popleft()
                    _consume(it0, out0)
            while pending:
                it0, out0 = pending.popleft()
                _consume(it0, out0)
        finally:
            for f in read_futs.values():     # error path: drain prefetches
                f.cancel()
        self.barrier()


__all__ = ["Pipeline", "DEFAULT_DEPTH"]
