"""Pooled zero-copy host staging + per-stage wall-time accounting
(DESIGN.md §16).

Every overlapped hot path (store put/get, checkpoint save/restore/
scrub, scheduler repair) used to pay one fresh host allocation per
window: the flatten transpose, the bucket-ladder zero-pad, and the
pack257 byte split each materialized a new ndarray per dispatch.  At
depth-2 pipelining those allocations (plus their first-touch page
faults) sat squarely on the critical thread and ate the overlap win —
BENCH_pipeline showed the depth-2 put *slower* than serial.

:class:`StagingPool` replaces them with a reusable ring of preallocated,
bucket-ladder-sized host buffers:

* ``acquire(shape, dtype)`` returns a view into a pooled buffer whose
  backing allocation is rounded up the same geometric ladder the plan
  cache buckets on — so the window sizes a steady-state stream touches
  map to a handful of distinct pool slots that are reused forever.
* Buffers are **page-touched at allocation** (``prefault=True``): after
  the first use every reuse hits resident pages with a stable address,
  which is what XLA's host-to-device transfer path wants from a staging
  buffer (on device backends the planner additionally donates the
  staged operand — see ``PlanCache.donate``).
* **Aliasing rule**: a buffer handed out by ``acquire`` is never handed
  out again until ``release`` is called on it.  The release points are
  exactly the dispatch-completion points — ``PlanResult.host()`` for
  planner-internal pad staging, and the pipeline consume stage (which
  has just blocked in ``host()``) for caller-owned flatten staging — so
  a reused buffer can never be scribbled while an in-flight compute
  still reads it.  Because the pool grows on demand, its depth is
  always >= the pipeline depth: ``stats().in_use`` is the live count
  tests assert against.
* Dropping a buffer without releasing it is safe (it is simply retired
  from the pool, never reissued), so error paths need no bookkeeping.

The module also owns the process-wide **stage clock**: `record_stage` /
`stage_times` accumulate wall time per named stage ("pack" for
flatten/pack257 staging writes, "pad" for planner bucket padding), and
``Pipeline.stage_stats()`` merges them with its own read/dispatch/
consume timers into the ``t_stage_read / t_pack / t_pad / t_dispatch /
t_consume`` accounting BENCH_pipeline reports.
"""
from __future__ import annotations

import threading
from collections import defaultdict
from contextlib import contextmanager
from time import perf_counter
from typing import NamedTuple, Optional

import numpy as np

# Pool buckets ride their own power-of-two ladder from this floor; it
# deliberately matches the plan cache's BUCKET_MIN so a planner pad of a
# bucketed stream extent is an exact-size pool hit.
POOL_BUCKET_MIN = 1 << 12

# Stage names surfaced by Pipeline.stage_stats() (DESIGN.md §16.3).
STAGE_NAMES = ("t_stage_read", "t_pack", "t_pad", "t_dispatch",
               "t_consume")

# ------------------------------------------------------------ stage clock
_TLOCK = threading.Lock()
_TIMES: dict = defaultdict(float)
_CALLS: dict = defaultdict(int)


def record_stage(name: str, seconds: float) -> None:
    """Accumulate ``seconds`` of wall time under stage ``name``
    (thread-safe; called from pool workers and the dispatch thread)."""
    with _TLOCK:
        _TIMES[name] += float(seconds)
        _CALLS[name] += 1


def stage_times() -> dict:
    """Cumulative process-wide seconds per stage since the last reset."""
    with _TLOCK:
        return dict(_TIMES)


def stage_calls() -> dict:
    with _TLOCK:
        return dict(_CALLS)


def reset_stage_times() -> None:
    with _TLOCK:
        _TIMES.clear()
        _CALLS.clear()


@contextmanager
def staged(name: str):
    """Time a block under stage ``name``."""
    t0 = perf_counter()
    try:
        yield
    finally:
        record_stage(name, perf_counter() - t0)


# ------------------------------------------------------------------- pool
class StagingStats(NamedTuple):
    """Pool accounting: ``hits`` reused a pooled buffer, ``misses``
    allocated a fresh one, ``in_use`` are acquired-but-unreleased
    buffers (the pipeline-depth invariant tests watch), ``pooled_bytes``
    is the resident free-list footprint."""
    hits: int
    misses: int
    released: int
    in_use: int
    pooled_bytes: int


def _bucket_elems(elems: int) -> int:
    """Smallest power-of-two ladder size >= elems (floor
    POOL_BUCKET_MIN) — the pool's allocation granularity."""
    b = POOL_BUCKET_MIN
    while b < elems:
        b <<= 1
    return b


class StagingPool:
    """A reusable ring of bucket-ladder-sized host staging buffers.

    Parameters
    ----------
    max_pooled : int
        Cap on retained free buffers per (dtype, bucket) slot; releases
        beyond it simply drop the buffer (steady-state streams need at
        most pipeline-depth + in-flight buffers per slot).
    prefault : bool
        Touch every page at allocation so reuses never fault and the
        buffer keeps a stable resident address across dispatches (the
        pinned-host staging property device transfer engines want).

    Notes
    -----
    ``acquire`` may return a reshaped *view* of the pooled base buffer;
    ``release`` accepts the view (it walks ``.base``).  Releasing an
    array the pool never issued is a safe no-op, and double-release is
    idempotent.
    """

    def __init__(self, max_pooled: int = 8, prefault: bool = True):
        self.max_pooled = int(max_pooled)
        self.prefault = bool(prefault)
        self._lock = threading.Lock()
        self._free: dict = defaultdict(list)   # (dtype.str, bucket) -> bufs
        self._in_use: dict = {}                # id(base) -> (key, base)
        self.hits = 0
        self.misses = 0
        self.released = 0

    def acquire(self, shape, dtype=np.int32) -> np.ndarray:
        """A ``shape``-shaped view into a pooled host buffer.  Contents
        are UNDEFINED (callers overwrite every element or zero the tail
        themselves — that is the zero-copy point)."""
        shape = tuple(int(x) for x in shape)
        dt = np.dtype(dtype)
        elems = 1
        for x in shape:
            elems *= x
        key = (dt.str, _bucket_elems(max(elems, 1)))
        with self._lock:
            free = self._free.get(key)
            if free:
                base = free.pop()
                self.hits += 1
            else:
                base = None
                self.misses += 1
        if base is None:
            base = np.empty(key[1], dt)
            if self.prefault:
                base.fill(0)            # touch every page once
        with self._lock:
            self._in_use[id(base)] = (key, base)
        return base[:elems].reshape(shape)

    @staticmethod
    def _base_of(arr: np.ndarray) -> np.ndarray:
        while arr.base is not None and isinstance(arr.base, np.ndarray):
            arr = arr.base
        return arr

    def release(self, arr) -> None:
        """Return ``arr``'s backing buffer to the pool.  Only call once
        the consuming dispatch has completed (``PlanResult.host()`` has
        returned) — that is the aliasing rule (DESIGN.md §16.2)."""
        if not isinstance(arr, np.ndarray):
            return
        base = self._base_of(arr)
        with self._lock:
            entry = self._in_use.pop(id(base), None)
            if entry is None:
                return                  # foreign array / double release
            key, buf = entry
            self.released += 1
            if len(self._free[key]) < self.max_pooled:
                self._free[key].append(buf)

    def stats(self) -> StagingStats:
        with self._lock:
            pooled = sum(b.nbytes for bufs in self._free.values()
                         for b in bufs)
            return StagingStats(self.hits, self.misses, self.released,
                                len(self._in_use), pooled)

    def clear(self) -> None:
        """Drop every retained buffer (tests / memory pressure)."""
        with self._lock:
            self._free.clear()
            self._in_use.clear()
            self.hits = self.misses = self.released = 0


__all__ = ["StagingPool", "StagingStats", "POOL_BUCKET_MIN", "STAGE_NAMES",
           "record_stage", "stage_times", "stage_calls",
           "reset_stage_times", "staged"]
