"""Execution-plan layer (DESIGN.md §11): the shape-bucketed AOT plan
cache (`repro.exec.plan`) and the unified overlapped I/O⇄compute
pipeline (`repro.exec.pipeline`) every hot path routes through.
"""
from .pipeline import Pipeline
from .plan import (PlanCache, PlanResult, PlanStats, bucket_symbols,
                   clear_planners, get_planner, plan_stats,
                   planning_disabled, planning_enabled, reset_plan_stats,
                   set_planning)

__all__ = [
    "Pipeline", "PlanCache", "PlanResult", "PlanStats", "bucket_symbols",
    "get_planner", "plan_stats", "reset_plan_stats", "clear_planners",
    "set_planning", "planning_enabled", "planning_disabled",
]
