"""GF(2^8) backend — byte-native portability fallback (DESIGN.md §2).

The primary field is GF(257) (MXU-exact fp32 matmuls); GF(2^8) trades the
MXU for VMEM-resident log/exp table gathers but is *closed over bytes*
(no 256-value packing, XOR addition).  Field: AES polynomial x^8+x^4+x^3+x+1
(0x11B), generator 0x03.

Useful when the deployment target lacks fast fp32 accumulation or when
storage must be strictly byte-in/byte-out with zero packing overhead.
Provided: elementwise ops, matmul, Gauss-Jordan inverse — enough to run a
Vandermonde/Cauchy MDS code or a double circulant construction over GF(256)
(condition (6) checked with the same circulant machinery generalized over a
field object).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

_POLY = 0x11B
_GEN = 0x03


@functools.cache
def _tables() -> tuple[np.ndarray, np.ndarray]:
    """exp[i] = g^i (510 entries for wraparound), log[x] for x in 1..255."""
    exp = np.zeros(510, np.int32)
    log = np.zeros(256, np.int32)
    x = 1
    for i in range(255):
        exp[i] = x
        log[x] = i
        hi = x << 1                  # times generator 0x03 = (x << 1) ^ x
        if hi & 0x100:
            hi ^= _POLY
        x = hi ^ x
    exp[255:510] = exp[0:255]
    return exp, log


def add(x, y):
    """Addition in GF(2^8) is XOR."""
    return jnp.bitwise_xor(jnp.asarray(x, jnp.int32), jnp.asarray(y, jnp.int32))


sub = add  # characteristic 2


def mul(x, y):
    exp, log = _tables()
    exp_t, log_t = jnp.asarray(exp), jnp.asarray(log)
    x = jnp.asarray(x, jnp.int32)
    y = jnp.asarray(y, jnp.int32)
    prod = exp_t[log_t[x] + log_t[y]]
    return jnp.where((x == 0) | (y == 0), 0, prod)


def inv(x):
    exp, log = _tables()
    exp_t, log_t = jnp.asarray(exp), jnp.asarray(log)
    x = jnp.asarray(x, jnp.int32)
    return jnp.where(x == 0, 0, exp_t[255 - log_t[x]])


def matmul(a, b):
    """(a @ b) over GF(2^8): gather-multiply + XOR-reduce.

    a: (m, k), b: (k, n) int32 bytes.  TPU mapping: the log/exp tables are
    VMEM-resident (766 x 4 B); each output element is a k-deep XOR tree —
    VPU work, no MXU (the price of the byte-native field)."""
    a = jnp.asarray(a, jnp.int32)
    b = jnp.asarray(b, jnp.int32)
    prods = mul(a[:, :, None], b[None, :, :])       # (m, k, n)
    return jax.lax.reduce(prods, np.int32(0),
                          lambda x, y: jnp.bitwise_xor(x, y), (1,))


def gauss_inverse(mat: np.ndarray) -> np.ndarray:
    """Inverse over GF(2^8), host-side numpy."""
    exp, log = _tables()

    def m_(x, y):
        if x == 0 or y == 0:
            return 0
        return int(exp[log[x] + log[y]])

    def inv_(x):
        return int(exp[255 - log[x]]) if x else 0

    mat = np.asarray(mat, np.int32) % 256
    n = mat.shape[0]
    aug = np.concatenate([mat, np.eye(n, dtype=np.int32)], axis=1)
    for col in range(n):
        piv = next((r for r in range(col, n) if aug[r, col]), None)
        if piv is None:
            raise ValueError("singular over GF(256)")
        if piv != col:
            aug[[col, piv]] = aug[[piv, col]]
        pinv = inv_(int(aug[col, col]))
        aug[col] = [m_(int(v), pinv) for v in aug[col]]
        for r in range(n):
            if r != col and aug[r, col]:
                f = int(aug[r, col])
                aug[r] = [int(v) ^ m_(f, int(w))
                          for v, w in zip(aug[r], aug[col])]
    return aug[:, n:].astype(np.int32)


__all__ = ["add", "sub", "mul", "inv", "matmul", "gauss_inverse"]
