"""Pytree <-> MSR block placement: serialize training state into the code's
n = 2k data blocks and back (DESIGN.md §2 — MSR-coded checkpointing).

The mapping is deliberately dumb and auditable:
  pytree -> flat list of (path, dtype, shape, raw bytes) -> one byte stream
         -> GF(p) symbols -> pad to a multiple of n -> reshape (n, S).

Systematic property: restoring WITHOUT failures reads only the raw data
blocks — `blocks_to_pytree(data_blocks)` never touches field arithmetic.

Physical placement (DESIGN.md §9): `RackLayout` assigns the n storage
nodes to failure domains (racks) so the cluster simulator can model
*correlated* failures — losing a whole rack must not exceed the code's
n - k erasure budget, which `RackLayout.survives_rack_loss` checks.
"""
from __future__ import annotations

import json
from dataclasses import dataclass
from typing import Any, Sequence

import jax
import numpy as np

from . import gf


@dataclass
class TreeSpec:
    """Static metadata needed to rebuild the pytree from bytes."""
    treedef_repr: str
    leaves: list[dict]       # [{dtype, shape, nbytes}]
    total_bytes: int
    n_blocks: int
    block_symbols: int

    def to_json(self) -> str:
        return json.dumps({
            "treedef_repr": self.treedef_repr,
            "leaves": self.leaves,
            "total_bytes": self.total_bytes,
            "n_blocks": self.n_blocks,
            "block_symbols": self.block_symbols,
        })

    @staticmethod
    def from_json(s: str) -> "TreeSpec":
        d = json.loads(s)
        return TreeSpec(**d)


@dataclass(frozen=True)
class RackLayout:
    """Node -> failure-domain (rack) assignment for correlated failures.

    Parameters
    ----------
    n_nodes : int
        Number of storage nodes (the code's n = 2k).
    racks : tuple of int
        ``racks[i]`` is the rack id of node ``v_{i+1}`` (0-based rack ids).

    Notes
    -----
    Build one with :func:`rack_layout`, which round-robins nodes across
    racks so rack sizes differ by at most one — the placement that
    maximizes the number of racks that may fail together while staying
    inside the code's n - k erasure budget.
    """
    n_nodes: int
    racks: tuple[int, ...]

    def __post_init__(self):
        if len(self.racks) != self.n_nodes:
            raise ValueError(f"need one rack id per node: "
                             f"{len(self.racks)} != {self.n_nodes}")

    @property
    def n_racks(self) -> int:
        return len(set(self.racks))

    def rack_of(self, node: int) -> int:
        """Rack id of node ``v_node`` (1-indexed)."""
        if not 1 <= node <= self.n_nodes:
            raise ValueError(f"node {node} out of range 1..{self.n_nodes}")
        return self.racks[node - 1]

    def nodes_in(self, rack: int) -> tuple[int, ...]:
        """All (1-indexed) nodes assigned to ``rack``."""
        return tuple(i + 1 for i, r in enumerate(self.racks) if r == rack)

    @property
    def max_rack_size(self) -> int:
        return max(len(self.nodes_in(r)) for r in set(self.racks))

    def survives_rack_loss(self, k: int) -> bool:
        """True if losing ANY single rack leaves >= k nodes alive — i.e.
        every rack holds at most n - k nodes, so a correlated rack
        failure stays inside the code's erasure budget."""
        return self.max_rack_size <= self.n_nodes - k


def rack_layout(n_nodes: int, n_racks: int) -> RackLayout:
    """Round-robin the n nodes across ``n_racks`` failure domains.

    Rack sizes differ by at most one; with ``n_racks >= n / (n - k)`` the
    resulting layout survives any single-rack loss (``survives_rack_loss``).
    """
    if n_racks < 1:
        raise ValueError("need at least one rack")
    return RackLayout(n_nodes=n_nodes,
                      racks=tuple(i % n_racks for i in range(n_nodes)))


def rotate_placement(layout: RackLayout, n_shares: int,
                     stripe: int) -> tuple[int, ...]:
    """Physical nodes (1-indexed) holding a stripe's ``n_shares`` shares.

    Share j of stripe t lands on node ``(t + j) mod n_nodes + 1``: stripes
    rotate around the node ring so load (and, after a node failure, the
    per-stripe loss count) spreads evenly, and because ``rack_layout``
    round-robins rack ids, any window of consecutive nodes also spreads
    across racks — roughly ``ceil(n_shares / n_racks)`` shares of one
    stripe per failure domain, up to one more when the window wraps a
    ring whose size is not a multiple of ``n_racks``.  The binding
    invariant is the one the stripe manager CHECKS at construction:
    ``max_shares_per_rack`` stays within the code's n - k erasure budget
    for every rotation phase (DESIGN.md §10).
    """
    if n_shares > layout.n_nodes:
        raise ValueError(f"cannot place {n_shares} distinct shares on "
                         f"{layout.n_nodes} nodes")
    return tuple((stripe + j) % layout.n_nodes + 1 for j in range(n_shares))


def max_shares_per_rack(layout: RackLayout,
                        placement: Sequence[int]) -> int:
    """Largest number of a stripe's shares co-located in one rack — a
    correlated rack loss erases exactly this many shares of the stripe,
    so the store requires it to stay within the code's n - k budget."""
    counts: dict[int, int] = {}
    for node in placement:
        r = layout.rack_of(node)
        counts[r] = counts.get(r, 0) + 1
    return max(counts.values()) if counts else 0


def pytree_to_bytes(tree: Any) -> tuple[bytes, jax.tree_util.PyTreeDef, list[dict]]:
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    metas, chunks = [], []
    for leaf in leaves:
        arr = np.asarray(leaf)
        raw = arr.tobytes()
        metas.append({"dtype": str(arr.dtype), "shape": list(arr.shape),
                      "nbytes": len(raw)})
        chunks.append(raw)
    return b"".join(chunks), treedef, metas


def bytes_to_leaves(payload: bytes, metas: list[dict]) -> list[np.ndarray]:
    leaves, off = [], 0
    for m in metas:
        raw = payload[off: off + m["nbytes"]]
        off += m["nbytes"]
        leaves.append(np.frombuffer(raw, dtype=np.dtype(m["dtype"])).reshape(m["shape"]).copy())
    return leaves


def pytree_to_blocks(tree: Any, n: int, p: int = gf.DEFAULT_P,
                     ) -> tuple[np.ndarray, jax.tree_util.PyTreeDef, TreeSpec]:
    """Serialize a pytree into (n, S) GF(p) data blocks a_0..a_{n-1}."""
    payload, treedef, metas = pytree_to_bytes(tree)
    sym = gf.bytes_to_symbols(payload, p)
    pad = (-len(sym)) % n
    sym = np.pad(sym, (0, pad))
    blocks = sym.reshape(n, -1).astype(np.int32)
    spec = TreeSpec(treedef_repr=str(treedef), leaves=metas,
                    total_bytes=len(payload), n_blocks=n,
                    block_symbols=blocks.shape[1])
    return blocks, treedef, spec


def blocks_to_pytree(blocks: np.ndarray, treedef: jax.tree_util.PyTreeDef,
                     spec: TreeSpec) -> Any:
    """Inverse of pytree_to_blocks.  Pure byte reads for systematic blocks."""
    sym = np.asarray(blocks).reshape(-1)
    payload = gf.symbols_to_bytes(sym)[: spec.total_bytes]
    leaves = bytes_to_leaves(payload, spec.leaves)
    return jax.tree_util.tree_unflatten(treedef, leaves)


__all__ = ["TreeSpec", "RackLayout", "rack_layout", "rotate_placement",
           "max_shares_per_rack", "pytree_to_bytes", "bytes_to_leaves",
           "pytree_to_blocks", "blocks_to_pytree"]
