"""ICI-ring-native MSR encode (DESIGN.md §2, adaptation 2).

The circulant structure of M means every redundancy block is a combination
of the NEXT k data blocks:  node i (0-indexed) computes

    r_{i+1} = sum_{t=1..k} c_{k+1-t} * a_{(i+t) mod n}

so encode = k rounds of *neighbour shift + scale + accumulate*: each round
every node forwards one block to its LEFT neighbour (j -> j-1), i.e. blocks
flow rightward exactly one hop per round — the TPU ICI torus's native
pattern.  Total traffic: k blocks per link, all neighbour-wise; no gather,
no all-to-all.  Implemented with shard_map + jax.lax.ppermute over a 1-D
`storage` mesh axis.

Repair, by contrast, is point-to-point (d = k+1 direct fetches) and lives at
the host/checkpoint layer (repro.checkpoint) where its byte count is the
paper's gamma (eq. 7).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

try:                                    # JAX >= 0.4.35 exports it at top level
    from jax import shard_map
except ImportError:                     # older JAX: experimental namespace
    from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from .circulant import CodeSpec


def _ring_encode_local(a_local: jnp.ndarray, *, c: tuple[int, ...], p: int,
                       axis: str, wire_dtype) -> jnp.ndarray:
    """Per-device body: a_local is this node's (1, S) data block.

    §Perf (ring iteration 2): only DATA blocks travel the ring, and
    systematic data blocks are raw bytes by construction — so the permute
    payload is uint8, 4x less wire than int32 symbols.  Accumulation stays
    int32-local.
    """
    k = len(c)
    n = 2 * k
    perm = [(j, (j - 1) % n) for j in range(n)]     # send to LEFT neighbour
    buf = a_local.astype(wire_dtype)
    acc = jnp.zeros(a_local.shape, jnp.int32)
    for t in range(1, k + 1):
        buf = jax.lax.ppermute(buf, axis, perm)      # buf now holds a_{i+t}
        acc = (acc + c[k - t] * buf.astype(jnp.int32)) % p  # coeff c_{k+1-t}
    return acc


def ring_encode(data: jnp.ndarray, spec: CodeSpec, mesh: Mesh,
                axis: str = "storage", byte_wire: bool | None = None) -> jnp.ndarray:
    """data: (n, S) int32, row i on storage-node i -> redundancy (n, S),
    row i = r_{i+1} resident on node i.  Neighbour-only communication.

    byte_wire: permute uint8 payloads (4x less wire — §Perf ring iteration
    2).  Valid when every data symbol < 256: automatic for p <= 256; for
    p = 257 the caller opts in when the blocks are systematic raw BYTES
    (always true for the checkpoint layer's data blocks)."""
    n = spec.n
    if mesh.shape[axis] != n:
        raise ValueError(f"mesh axis {axis}={mesh.shape[axis]} != n={n}")
    if byte_wire is None:
        byte_wire = spec.p <= 256
    wire_dtype = jnp.uint8 if byte_wire else jnp.int32
    fn = shard_map(
        functools.partial(_ring_encode_local, c=tuple(spec.c), p=spec.p,
                          axis=axis, wire_dtype=wire_dtype),
        mesh=mesh,
        in_specs=P(axis, None),
        out_specs=P(axis, None),
    )
    return fn(jnp.asarray(data, jnp.int32) % spec.p)


def ring_encode_reference(data: jnp.ndarray, spec: CodeSpec) -> jnp.ndarray:
    """Oracle: the dense-M encode from the core layer."""
    from .msr import DoubleCirculantMSR
    return DoubleCirculantMSR(spec).encode(data)


def ring_link_traffic_blocks(spec: CodeSpec) -> int:
    """Blocks crossing each ring link during encode: k (one per round)."""
    return spec.k
