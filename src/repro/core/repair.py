"""Fused batched repair engine (DESIGN.md §4).

The decode-side counterpart of the encode dispatch layer: everything a
repairing / reconstructing reader does is reduced to **one GF matmul per
request** through the dispatched backend, with all tiny host-side linear
algebra precomputed (repair matrices) or cached (reconstruction inverses).

Regeneration (paper §III-C).  The reference path solves the newcomer's
scalar equation in three device rounds: a (1, k-1) matmul for the partial
sum, an elementwise ``(r_prev - partial) * c_k^{-1} mod p`` correction, and
a second (1, k) matmul for the re-encoded redundancy.  But the whole
newcomer computation is *linear* in the d = k+1 downloaded helper blocks,
so it folds into a single (2, k+1) **repair matrix** R applied to the
stacked helper matrix H = [r_{i-1}; a_{i+1}; ...; a_{i+k}]:

    [a_lost; r_new] = R @ H  mod p,          R =
      row 0 (decode):    [c_k^{-1},  -c_k^{-1} c_{k-1}, ..., -c_k^{-1} c_1, 0]
      row 1 (re-encode): [0,          c_k,  c_{k-1},     ...,          c_1]

Because the construction is circulant, R is the SAME for every node v_i —
helper blocks are always indexed relative to i (the embedded property made
compute-static: no per-node matrices, no coefficient discovery, one fused
matmul reusing the backend's lazy mod-folding envelope).

Reconstruction (paper §III-B).  The 2k x 2k system matrix depends only on
WHICH k nodes are read, not the read order, so inverses are cached in an
LRU keyed by the sorted node subset — there are only C(2k, k) of them and
restore loops / scrubs hit the same subsets over and over.  Multi-failure
repair stacks the re-encode rows of the failed nodes under the inverse so
full data AND every lost redundancy block come out of one decode matmul.
"""
from __future__ import annotations

import functools
import threading
import weakref
from collections import OrderedDict
from typing import Callable, NamedTuple, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.exec.plan import PlanResult, make_regen_fn, planning_enabled

from . import gf
from .circulant import CodeSpec

MatmulFn = Callable[..., jnp.ndarray]  # (A, B, p) -> (A @ B) mod p


def build_repair_matrix(spec: CodeSpec) -> np.ndarray:
    """The (2, k+1) fused repair matrix R (one per code, see module doc).

    Column 0 multiplies r_{i-1}; column 1+j multiplies the j-th helper data
    block a_{(i+j) mod n} (plan order, j = 0..k-1).  Row 0 recovers the
    lost data block a_{i-1}, row 1 re-encodes the lost redundancy r_i.
    ``repair_matrix(i)`` below returns this same R for every i: the
    circulant structure makes the repair matrix node-invariant.
    """
    k, p = spec.k, spec.p
    c = np.asarray(spec.c, dtype=np.int64) % p
    ck_inv = pow(int(c[-1]), p - 2, p)
    r = np.zeros((2, k + 1), dtype=np.int64)
    # r_{i-1} = c_k a_{i-1} + sum_{u=1..k-1} c_u a_{(i-1+k-u) mod n}; the
    # u-th term is helper column 1 + (k-u-1), so
    #   a_{i-1} = c_k^{-1} r_{i-1} - sum_u c_k^{-1} c_u a_{(i-1+k-u)}.
    r[0, 0] = ck_inv
    for j in range(k - 1):                      # j = k-u-1  <->  u = k-1-j
        r[0, 1 + j] = (-ck_inv * c[k - 2 - j]) % p
    # r_i = sum_{u=1..k} c_u a_{(i-1+k+1-u) mod n}: helper column 1 + (k-u).
    for j in range(k):                          # j = k-u    <->  u = k-j
        r[1, 1 + j] = c[k - 1 - j]
    return (r % p).astype(np.int32)


# Module-level jitted kernels with the backend matmul as a *static* argument:
# backend matmuls are module-level singletons, so the jit cache is shared
# across every engine instance (no per-code recompilation).
#
# The kernel body itself (matmul + row-0 axpy epilogue, chosen because
# XLA's CPU int32 einsum degrades badly at tiny odd contraction depths
# and an in-jit stack of the (k+1, S) helper matrix costs a full extra
# memory pass; exactness argument alongside it) is defined ONCE in
# `exec.plan.make_regen_fn` — the planned AOT executables trace the same
# function, so the two execution modes cannot desync.

@functools.partial(jax.jit, static_argnames=("mm", "p"))
def _fused_regenerate(mm, rmat, r_prev, next_data, p: int):
    return make_regen_fn(mm, p)(rmat, r_prev, next_data)


@functools.partial(jax.jit, static_argnames=("mm", "p"))
def _fused_regenerate_vmapped(mm, rmat, r_prevs, next_data, p: int):
    one = make_regen_fn(mm, p)
    return jax.vmap(lambda rp, nd: one(rmat, rp, nd))(
        r_prevs, next_data)                              # (F, 2, S)


class DecodeCacheInfo(NamedTuple):
    hits: int
    misses: int
    size: int
    maxsize: int


# Every live DecodeInverseCache, for the per-family stats surface
# (DESIGN.md §15.4): the process-wide planner registry already exposes
# plan_stats(); decode_cache_stats() is its decode-side counterpart.
_CACHE_LOCK = threading.Lock()
_LIVE_CACHES: "weakref.WeakSet[DecodeInverseCache]" = weakref.WeakSet()


def decode_cache_stats() -> dict[str, DecodeCacheInfo]:
    """Aggregate decode-inverse cache counters per code-family identity
    — one :class:`DecodeCacheInfo` per distinct family key across every
    live cache (two families with overlapping (k, p) report separately;
    that is the point of family-keyed entries)."""
    agg: dict[str, list[int]] = {}
    with _CACHE_LOCK:
        caches = list(_LIVE_CACHES)
    for c in caches:
        row = agg.setdefault(c.family, [0, 0, 0, 0])
        info = c.cache_info()
        row[0] += info.hits
        row[1] += info.misses
        row[2] += info.size
        row[3] += info.maxsize
    return {fam: DecodeCacheInfo(*row) for fam, row in sorted(agg.items())}


class DecodeInverseCache:
    """LRU of reconstruction inverses keyed by (code family, sorted
    k-node subset).

    The any-k system matrix [I^s | M^s]^T is determined by the *set* of
    nodes read; there are only C(2k, k) subsets (12870 at k = 8) and real
    restore/scrub traffic reuses a handful, so the O(n^3) host-side
    ``gf.gauss_inverse`` runs once per subset instead of once per call.

    Entry keys carry the owning code's **family identity** — not just
    the subset — so two code families with overlapping (k, p) can never
    alias an inverse (DESIGN.md §15.4), and :func:`decode_cache_stats`
    can report hit rates per family.

    Parameters
    ----------
    spec : CodeSpec, optional
        The double-circulant code whose system matrices are inverted.
        Omitted by non-circulant families, which pass ``matrix_fn``.
    maxsize : int
        LRU capacity; least-recently-used subsets are evicted beyond it.
    family : str, optional
        Family identity string baked into every entry key; defaults to
        the double-circulant identity derived from ``spec``.
    matrix_fn : callable, optional
        ``subset -> (square ndarray)`` system-matrix builder for
        generator-matrix families (e.g. product-matrix MSR); mutually
        exclusive with ``spec``.
    k, p : int, optional
        Subset size / field modulus when ``matrix_fn`` is used.

    Attributes
    ----------
    hits, misses : int
        Lifetime counters (see :meth:`cache_info`).

    See Also
    --------
    RepairEngine.reconstruct : canonicalizes caller orderings so every
        permutation of the same k nodes shares one entry.
    """

    def __init__(self, spec: Optional[CodeSpec] = None, maxsize: int = 128,
                 *, family: Optional[str] = None,
                 matrix_fn: Optional[Callable] = None,
                 k: Optional[int] = None, p: Optional[int] = None):
        self.spec = spec
        if spec is not None:
            if matrix_fn is not None:
                raise ValueError("pass spec or matrix_fn, not both")
            self.k, self.n, self.p = spec.k, spec.n, spec.p
            self._m = spec.matrix_m()           # (n, n)
            self._matrix_fn = None
            family = family or (f"double-circulant[n{spec.n},k{spec.k},"
                                f"p{spec.p}]")
        else:
            if matrix_fn is None or k is None or p is None:
                raise ValueError("matrix_fn caches need matrix_fn, k and p")
            self.k, self.p = int(k), int(p)
            self.n = None
            self._matrix_fn = matrix_fn
            family = family or "generator-matrix"
        self.family = str(family)
        self.maxsize = max(1, maxsize)
        self._entries: OrderedDict[tuple, np.ndarray] = OrderedDict()
        self.hits = 0
        self.misses = 0
        with _CACHE_LOCK:
            _LIVE_CACHES.add(self)

    def system_matrix(self, subset: tuple[int, ...]) -> np.ndarray:
        """The square decode system for the (sorted) subset: the
        circulant [I columns | M columns]^T (2k, n), or the family's
        ``matrix_fn`` rows for generator-matrix codes."""
        if self._matrix_fn is not None:
            return np.asarray(self._matrix_fn(subset), np.int64) % self.p
        cols = [i - 1 for i in subset]
        return np.concatenate(
            [np.eye(self.n, dtype=np.int64)[:, cols], self._m[:, cols]],
            axis=1,
        ).T % self.p

    def inverse(self, subset: Sequence[int]) -> np.ndarray:
        """Cached inverse of the subset's system matrix — (n, n) for the
        circulant family, (k*q, k*q) for generator-matrix families."""
        key = tuple(subset)
        if sorted(set(key)) != list(key) or len(key) != self.k:
            raise ValueError(f"need a sorted set of k={self.k} distinct "
                             f"nodes, got {key}")
        entry_key = (self.family,) + key       # family identity in the key
        hit = self._entries.get(entry_key)
        if hit is not None:
            self.hits += 1
            self._entries.move_to_end(entry_key)
            return hit
        self.misses += 1
        inv = gf.gauss_inverse(self.system_matrix(key), self.p)
        self._entries[entry_key] = inv
        if len(self._entries) > self.maxsize:
            self._entries.popitem(last=False)
        return inv

    def cache_info(self) -> DecodeCacheInfo:
        return DecodeCacheInfo(self.hits, self.misses, len(self._entries),
                               self.maxsize)

    def clear(self) -> None:
        self._entries.clear()
        self.hits = 0
        self.misses = 0


class RepairEngine:
    """Fused decode-side compute for one code: all repair/reconstruct
    requests reduce to a single dispatched GF matmul (DESIGN.md §4).

    Parameters
    ----------
    spec : CodeSpec
        The code being repaired.
    matmul : callable
        Backend ``(a, b, p) -> (a @ b) mod p`` primitive; module-level
        dispatch singletons share one jit cache across engines.
    jittable : bool
        False for custom injected matmuls: keeps every field op routed
        through the injected function and skips the jit fusion — the
        helper stack is built eagerly and the single matmul still
        applies.
    inverse_cache_size : int
        Capacity of :attr:`decode_cache`.
    planner : repro.exec.plan.PlanCache, optional
        Shape-bucketed AOT plan cache (DESIGN.md §11).  When set, the
        ``*_planned`` methods run through pre-compiled bucketed
        executables — zero recompiles at steady state — and fall back
        to the per-shape jit paths when absent or globally disabled.

    Attributes
    ----------
    decode_cache : DecodeInverseCache
        Any-k reconstruction inverses, LRU-keyed by sorted node subset.

    Notes
    -----
    The (2, k+1) repair matrix (:func:`build_repair_matrix`) is
    node-invariant by the circulant structure, so one engine serves
    every node's regeneration with zero per-node precompute.
    """

    def __init__(self, spec: CodeSpec, matmul: MatmulFn, *,
                 jittable: bool = True, inverse_cache_size: int = 128,
                 planner=None):
        self.spec = spec
        self.k, self.n, self.p = spec.k, spec.n, spec.p
        self._mm = matmul
        self._jittable = jittable
        self._mt = np.ascontiguousarray(spec.matrix_m().T)   # (n, n)
        self._rmat_np = build_repair_matrix(spec)
        self._rmat = jnp.asarray(self._rmat_np)
        self.decode_cache = DecodeInverseCache(spec, maxsize=inverse_cache_size)
        self._batch_vmap_ok = jittable
        self.planner = planner

    def _planned(self) -> bool:
        return self.planner is not None and planning_enabled()

    # ------------------------------------------------------------ regenerate
    def repair_matrix(self, i: int | None = None) -> np.ndarray:
        """R for node v_i — identical for every i (circulant invariance)."""
        if i is not None and not 1 <= i <= self.n:
            raise ValueError(f"node {i} out of range 1..{self.n}")
        return self._rmat_np

    def apply(self, mat, blocks) -> jnp.ndarray:
        """(mat @ blocks) mod p through the dispatched backend."""
        return self._mm(jnp.asarray(mat, jnp.int32),
                        jnp.asarray(blocks, jnp.int32), self.p)

    def apply_planned(self, mat, blocks) -> PlanResult:
        """Planned (mat @ blocks) mod p (DESIGN.md §11): dispatched
        through the shape-bucketed AOT executable cache — async; call
        ``.host()`` on the result to block and get exact numpy.  Falls
        back to :meth:`apply` (per-shape jit) without a planner."""
        if self._planned():
            return self.planner.matmul(mat, blocks)
        blocks = np.asarray(blocks, np.int32)
        return PlanResult(self.apply(mat, blocks), blocks.shape[-1])

    def regenerate_stacked(self, i: int, r_prev, next_data) -> jnp.ndarray:
        """Fused newcomer compute: one (2, k+1) repair-matrix application
        in a single jitted dispatch (matmul + axpy-epilogue, see the
        kernel comment above; custom matmuls get the literal stacked
        (2, k+1) @ (k+1, S) product).

        Returns the (2, S) stack [a_{i-1}; r_i] — bit-exactly the lost
        node's pair (row 0 = data block, row 1 = redundancy block).
        """
        r_prev = jnp.asarray(r_prev, jnp.int32)
        next_data = jnp.asarray(next_data, jnp.int32)
        if next_data.shape[0] != self.k:
            raise ValueError(f"expected {self.k} helper data blocks, "
                             f"got {next_data.shape[0]}")
        if self._jittable:
            return _fused_regenerate(self._mm, self._rmat, r_prev,
                                     next_data, self.p)
        helpers = jnp.concatenate([r_prev[None, :], next_data], axis=0)
        return self._mm(self._rmat, helpers, self.p)

    def regenerate(self, i: int, r_prev, next_data) -> tuple[jnp.ndarray, jnp.ndarray]:
        out = self.regenerate_stacked(i, r_prev, next_data)
        return out[0], out[1]

    def regenerate_planned(self, i: int, r_prev, next_data) -> PlanResult:
        """Planned fused newcomer compute: the (2, k+1) repair-matrix
        application through one bucketed AOT executable per (k, bucket).
        Same contract as :meth:`regenerate_stacked`, asynchronous."""
        next_data = np.asarray(next_data, np.int32)
        if next_data.shape[0] != self.k:
            raise ValueError(f"expected {self.k} helper data blocks, "
                             f"got {next_data.shape[0]}")
        if self._planned():
            return self.planner.regenerate(self._rmat_np, r_prev, next_data)
        r_prev = np.asarray(r_prev, np.int32)
        return PlanResult(self.regenerate_stacked(i, r_prev, next_data),
                          r_prev.shape[-1])

    def regenerate_batch_planned(self, nodes: Sequence[int], r_prevs,
                                 next_data) -> PlanResult:
        """Planned batched fused regeneration: BOTH the stream axis and
        the failed-node axis F are bucketed (a 3-stripe and a 5-stripe
        drain share one executable); ``.host()`` returns the exact
        (F, 2, S) stack.  Falls back to :meth:`regenerate_batch`."""
        r_prevs = np.asarray(r_prevs, np.int32)
        next_data = np.asarray(next_data, np.int32)
        f = len(nodes)
        if r_prevs.shape[0] != f or next_data.shape[:2] != (f, self.k):
            raise ValueError(f"helper shapes {r_prevs.shape}/{next_data.shape}"
                             f" do not match {f} nodes, k={self.k}")
        if self._planned():
            return self.planner.regenerate_batch(self._rmat_np, r_prevs,
                                                 next_data)
        return PlanResult(self.regenerate_batch(nodes, r_prevs, next_data),
                          r_prevs.shape[-1], batch=f)

    def regenerate_batch(self, nodes: Sequence[int], r_prevs, next_data, *,
                         tile_symbols: int | None = None) -> jnp.ndarray:
        """Batched fused regeneration, vmapped over failed nodes.

        r_prevs: (F, S) — r_{i-1} per failed node, plan order.
        next_data: (F, k, S) — the k helper data blocks per failed node.
        Returns (F, 2, S): [a_lost; r_new] per node.

        The stream axis is processed in ``tile_symbols`` tiles (bounds the
        device working set; XLA pipelines the per-tile dispatches).  The
        node axis is vmapped through the backend matmul; backends whose
        kernels don't trace under vmap fall back to per-node dispatch.
        """
        r_prevs = jnp.asarray(r_prevs, jnp.int32)
        next_data = jnp.asarray(next_data, jnp.int32)
        f = len(nodes)
        if r_prevs.shape[0] != f or next_data.shape[:2] != (f, self.k):
            raise ValueError(f"helper shapes {r_prevs.shape}/{next_data.shape}"
                             f" do not match {f} nodes, k={self.k}")
        s = r_prevs.shape[-1]
        tile = s if tile_symbols is None else max(1, tile_symbols)
        parts = []
        for s0 in range(0, s, tile):
            parts.append(self._regen_tile_batch(
                nodes, r_prevs[:, s0:s0 + tile],
                next_data[:, :, s0:s0 + tile]))
        return parts[0] if len(parts) == 1 else jnp.concatenate(parts, axis=-1)

    def _regen_tile_batch(self, nodes, r_prevs, next_data) -> jnp.ndarray:
        if self._batch_vmap_ok:
            try:
                return _fused_regenerate_vmapped(self._mm, self._rmat,
                                                 r_prevs, next_data, self.p)
            except NotImplementedError:   # trace-time: a primitive in the
                self._batch_vmap_ok = False   # backend has no batching rule
        return jnp.stack([self.regenerate_stacked(i, r_prevs[f], next_data[f])
                          for f, i in enumerate(nodes)])

    # ----------------------------------------------------------- reconstruct
    def decode_matrix(self, subset: Sequence[int]) -> np.ndarray:
        """Cached (n, n) any-k decode matrix for a sorted node subset."""
        return self.decode_cache.inverse(tuple(subset))

    def decode_repair_matrix(self, subset: Sequence[int],
                             failed: Sequence[int]) -> np.ndarray:
        """(n + F, n) combined decode + re-encode matrix.

        Rows 0..n-1 recover the full data matrix; row n + j re-encodes the
        redundancy block of ``failed[j]`` (r_f = M^T[f-1] @ data), so a
        multi-failure repair produces ALL lost pairs from one matmul with
        the downloads.  The tiny (F, n) @ (n, n) host product rides on the
        cached inverse.
        """
        inv = self.decode_cache.inverse(tuple(subset))
        rows = np.asarray([self._mt[f - 1] for f in failed], dtype=np.int64)
        red_rows = (rows @ inv.astype(np.int64)) % self.p
        return np.concatenate([inv.astype(np.int64), red_rows],
                              axis=0).astype(np.int32)

    def split_decode_output(self, out):
        """Split a ``decode_repair_matrix`` product into
        (data (n, S), failed_red (F, S)) — the single source of truth for
        the combined matrix's row layout (callers that tile the product
        themselves must not hand-roll this split)."""
        return out[: self.n], out[self.n:]

    def reconstruct(self, node_ids: Sequence[int], data_blocks,
                    red_blocks) -> jnp.ndarray:
        """Any-k reconstruction via the cached inverse (paper §III-B).

        ``node_ids`` may arrive in any order: rows are permuted to the
        sorted subset so every ordering of the same k nodes shares one
        cache entry (and one ``gf.gauss_inverse``).
        """
        ids = [int(x) for x in node_ids]
        if len(set(ids)) != self.k:
            raise ValueError(f"need k={self.k} distinct nodes, got {ids}")
        order = sorted(range(self.k), key=lambda j: ids[j])
        subset = tuple(ids[j] for j in order)
        data_blocks = jnp.asarray(data_blocks, jnp.int32)
        red_blocks = jnp.asarray(red_blocks, jnp.int32)
        if order != list(range(self.k)):
            sel = jnp.asarray(order)
            data_blocks, red_blocks = data_blocks[sel], red_blocks[sel]
        downloads = jnp.concatenate([data_blocks, red_blocks], axis=0)
        return self.apply(self.decode_matrix(subset), downloads)

    def reconstruct_with_repair(self, node_ids: Sequence[int], data_blocks,
                                red_blocks, failed: Sequence[int],
                                ) -> tuple[jnp.ndarray, jnp.ndarray]:
        """One-matmul multi-failure repair: full data AND the failed nodes'
        redundancy blocks from a single decode matmul.

        Returns (data (n, S), failed_red (F, S)) with failed_red rows in
        ``failed`` order.  ``node_ids`` must be sorted (restore reads the
        surviving nodes in id order).
        """
        subset = tuple(int(x) for x in node_ids)
        downloads = jnp.concatenate([jnp.asarray(data_blocks, jnp.int32),
                                     jnp.asarray(red_blocks, jnp.int32)],
                                    axis=0)
        mat = self.decode_repair_matrix(subset, failed)
        return self.split_decode_output(self.apply(mat, downloads))


__all__ = ["RepairEngine", "DecodeInverseCache", "DecodeCacheInfo",
           "build_repair_matrix", "decode_cache_stats"]
