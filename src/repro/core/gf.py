"""Prime-field GF(p) arithmetic, vectorized for JAX.

The paper (Gastón & Pujol 2010) works over an arbitrary finite field F_m.
We default to p = 257: the smallest prime > 2**8, so every data *byte* is a
field element.  Key TPU-native property (see DESIGN.md §2):

  * integers 0..256 are exactly representable in bf16 (8-bit significand),
  * products <= 256**2 = 2**16 are exact in the MXU's fp32 accumulator,
  * a k-term dot product with k <= 128 stays < 2**24, i.e. exact in fp32.

Hence GF(257) matmuls lower to a single native bf16xbf16->fp32 MXU pass plus
a cheap `mod p` fold — no lookup tables, no integer matmul units.  On CPU
(this container) the same code paths run in fp32/int32 and remain exact.

Everything here is pure JAX (jit/vmap/shard_map friendly).  Host-side helpers
(`inv_table`, `gauss_inverse`) use numpy for tiny O(n^3) matrices.
"""
from __future__ import annotations

from time import perf_counter
from typing import Sequence

import jax.numpy as jnp
import numpy as np

DEFAULT_P = 257


def record_stage(name: str, seconds: float) -> None:
    # lazy import: the stage clock lives in repro.exec.staging and core
    # carries no module-level edge into exec (same pattern as the
    # envelope import below)
    from repro.exec.staging import record_stage as rec
    rec(name, seconds)

# Max number of accumulation terms an int32 lane can hold before a `mod p`
# fold is due: 32767 terms for p = 257 (the lazy mod-folding envelope,
# DESIGN.md §3.2).  The bound lives in repro.kernels.envelope — the single
# source of truth — imported lazily so core carries no module-level edge
# into kernels.  The old fp32-dot bound (128 terms) lives in
# repro.kernels.gf_matmul where the MXU path actually needs it.
def _i32_chunk(p: int) -> int:
    from repro.kernels.envelope import int32_lazy_terms, require_int32_envelope
    require_int32_envelope(p)
    return int32_lazy_terms(p)


def _check_prime(p: int) -> None:
    if p < 2 or any(p % q == 0 for q in range(2, int(p**0.5) + 1)):
        raise ValueError(f"p={p} is not prime")


# ---------------------------------------------------------------------------
# Elementwise ops (int32 lanes; exact)
# ---------------------------------------------------------------------------

def add(x, y, p: int = DEFAULT_P):
    return (jnp.asarray(x, jnp.int32) + jnp.asarray(y, jnp.int32)) % p


def sub(x, y, p: int = DEFAULT_P):
    return (jnp.asarray(x, jnp.int32) - jnp.asarray(y, jnp.int32)) % p


def mul(x, y, p: int = DEFAULT_P):
    return (jnp.asarray(x, jnp.int32) * jnp.asarray(y, jnp.int32)) % p


def neg(x, p: int = DEFAULT_P):
    return (-jnp.asarray(x, jnp.int32)) % p


def pow_(x, e: int, p: int = DEFAULT_P):
    """x**e mod p by square-and-multiply (e is a static python int >= 0)."""
    x = jnp.asarray(x, jnp.int32) % p
    acc = jnp.ones_like(x)
    while e:
        if e & 1:
            acc = (acc * x) % p
        x = (x * x) % p
        e >>= 1
    return acc


def inv(x, p: int = DEFAULT_P):
    """Multiplicative inverse by Fermat's little theorem: x**(p-2) mod p."""
    return pow_(x, p - 2, p)


# ---------------------------------------------------------------------------
# Matmul over GF(p)
# ---------------------------------------------------------------------------

def matmul(a, b, p: int = DEFAULT_P, *, precision=None):
    """(a @ b) mod p, exact — portable int32 lanes with lazy mod-folding.

    a: (..., m, k) int32 symbols in [0, p)
    b: (..., k, n) int32 symbols in [0, p)

    Chunks the contraction by int32 headroom (~(2^31-1)/(p-1)^2 terms, 32767
    for p = 257) instead of the fp32 bound (128 terms): for any realistic k
    that is a single einsum and ONE `mod p` fold.  The MXU fp32 path lives
    in repro.kernels (dispatch backend `jnp-f32` / `pallas`).
    """
    del precision  # kept for API compat; the int32 path has no fp rounding
    a = jnp.asarray(a, jnp.int32) % p
    b = jnp.asarray(b, jnp.int32) % p
    k = a.shape[-1]
    chunk = _i32_chunk(p)
    if k <= chunk:
        return jnp.einsum("...mk,...kn->...mn", a, b) % p
    # fold the running sum every chunk: for p near the int32 ceiling the
    # chunk count itself can be large, so unfolded < p partials could wrap
    out = None
    for s in range(0, k, chunk):
        part = jnp.einsum("...mk,...kn->...mn",
                          a[..., s : s + chunk], b[..., s : s + chunk, :]) % p
        out = part if out is None else (out + part) % p
    return out


def matvec(m, v, p: int = DEFAULT_P):
    return matmul(m, v[..., None], p)[..., 0]


# ---------------------------------------------------------------------------
# Host-side dense linear algebra (tiny matrices: code dimension n <= 512)
# ---------------------------------------------------------------------------

def gauss_inverse(mat: np.ndarray, p: int = DEFAULT_P) -> np.ndarray:
    """Inverse of a square matrix over GF(p) by Gauss-Jordan (numpy, host).

    Raises ValueError if the matrix is singular over GF(p).
    """
    mat = np.asarray(mat, dtype=np.int64) % p
    n = mat.shape[0]
    if mat.shape != (n, n):
        raise ValueError(f"square matrix required, got {mat.shape}")
    aug = np.concatenate([mat, np.eye(n, dtype=np.int64)], axis=1)
    for col in range(n):
        piv = None
        for r in range(col, n):
            if aug[r, col] % p != 0:
                piv = r
                break
        if piv is None:
            raise ValueError("matrix is singular over GF(%d)" % p)
        if piv != col:
            aug[[col, piv]] = aug[[piv, col]]
        pinv = pow(int(aug[col, col]), p - 2, p)
        aug[col] = (aug[col] * pinv) % p
        for r in range(n):
            if r != col and aug[r, col] % p:
                aug[r] = (aug[r] - aug[r, col] * aug[col]) % p
    return (aug[:, n:] % p).astype(np.int32)


def gauss_det(mat: np.ndarray, p: int = DEFAULT_P) -> int:
    """Determinant over GF(p) (numpy, host)."""
    mat = np.asarray(mat, dtype=np.int64).copy() % p
    n = mat.shape[0]
    det = 1
    for col in range(n):
        piv = None
        for r in range(col, n):
            if mat[r, col] % p != 0:
                piv = r
                break
        if piv is None:
            return 0
        if piv != col:
            mat[[col, piv]] = mat[[piv, col]]
            det = (-det) % p
        det = (det * int(mat[col, col])) % p
        pinv = pow(int(mat[col, col]), p - 2, p)
        mat[col] = (mat[col] * pinv) % p
        for r in range(col + 1, n):
            if mat[r, col] % p:
                mat[r] = (mat[r] - mat[r, col] * mat[col]) % p
    return int(det % p)


def nullspace(mat: np.ndarray, p: int = DEFAULT_P) -> np.ndarray:
    """Basis of the right null space of ``mat`` over GF(p) (numpy, host).

    Returns an (n_cols, nullity) matrix N with ``mat @ N == 0 (mod p)``
    whose columns are the canonical RREF basis vectors (free column j
    gets a 1, pivot rows carry the negated reduced entries).  Used by the
    product-matrix code family to shorten the parent (n', k', d') code:
    the admissible messages are exactly the null space of the deleted
    nodes' share map (DESIGN.md §15.2).
    """
    a = np.asarray(mat, dtype=np.int64) % p
    if a.ndim != 2:
        raise ValueError(f"matrix required, got shape {a.shape}")
    rows, cols = a.shape
    a = a.copy()
    pivots: list[int] = []
    r = 0
    for c in range(cols):
        if r == rows:
            break
        piv = None
        for i in range(r, rows):
            if a[i, c] % p:
                piv = i
                break
        if piv is None:
            continue
        if piv != r:
            a[[r, piv]] = a[[piv, r]]
        a[r] = (a[r] * pow(int(a[r, c]), p - 2, p)) % p
        for i in range(rows):
            if i != r and a[i, c] % p:
                a[i] = (a[i] - a[i, c] * a[r]) % p
        pivots.append(c)
        r += 1
    free = [c for c in range(cols) if c not in pivots]
    basis = np.zeros((cols, len(free)), dtype=np.int64)
    for j, fc in enumerate(free):
        basis[fc, j] = 1
        for i, pc in enumerate(pivots):
            basis[pc, j] = (-a[i, fc]) % p
    return (basis % p).astype(np.int32)


def solve(mat: np.ndarray, rhs: np.ndarray, p: int = DEFAULT_P) -> np.ndarray:
    """Solve mat @ x = rhs over GF(p).  rhs may be a matrix of columns.

    Host-side numpy for the tiny system matrix; the big-block application is
    done with `matmul` on device by the callers.
    """
    inv_m = gauss_inverse(mat, p)
    return (inv_m.astype(np.int64) @ (np.asarray(rhs, np.int64) % p)) % p


# ---------------------------------------------------------------------------
# Byte <-> symbol packing
# ---------------------------------------------------------------------------

def bytes_to_symbols(data: bytes | np.ndarray, p: int = DEFAULT_P) -> np.ndarray:
    """Lossless embedding of a byte stream into GF(p) symbols (p > 256)."""
    if p <= 256:
        raise ValueError("byte embedding requires p > 256")
    arr = np.frombuffer(data, dtype=np.uint8) if isinstance(data, (bytes, bytearray)) else np.asarray(data, np.uint8)
    return arr.astype(np.int32)


def bytes_to_symbols_into(data: bytes | np.ndarray, out: np.ndarray,
                          p: int = DEFAULT_P) -> np.ndarray:
    """One-pass byte embedding into a preallocated int32 symbol buffer
    (zero-copy staging, DESIGN.md §16.1): the uint8 -> int32 cast and
    the stripe zero-padding land in a single strided write over ``out``
    instead of the legacy astype -> pad -> astype copy chain.  ``out``
    must be a flat int32 array at least ``len(data)`` long; the tail
    past the payload is zeroed.  Counts toward the "pack" stage clock.
    """
    if p <= 256:
        raise ValueError("byte embedding requires p > 256")
    arr = np.frombuffer(data, dtype=np.uint8) \
        if isinstance(data, (bytes, bytearray)) else np.asarray(data, np.uint8)
    if out.dtype != np.int32 or out.ndim != 1 or out.size < arr.size:
        raise ValueError(f"need flat int32 out of >= {arr.size} symbols, "
                         f"got {out.dtype} {out.shape}")
    from time import perf_counter
    t0 = perf_counter()
    out[:arr.size] = arr
    out[arr.size:] = 0
    record_stage("pack", perf_counter() - t0)
    return out


def symbols_to_bytes(sym: np.ndarray) -> bytes:
    sym = np.asarray(sym)
    if sym.max(initial=0) > 255 or sym.min(initial=0) < 0:
        raise ValueError("symbols out of byte range; not a systematic data block")
    return sym.astype(np.uint8).tobytes()


def pack257(sym: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """Pack GF(257) symbols (values 0..256) into (low_bytes uint8, idx256).

    The value 256 occurs with probability ~1/257 in redundancy blocks; we
    store its positions explicitly, so storage is S * (1 + 4/257) bytes
    instead of 2-4 bytes/symbol — the redundancy blocks stay byte-priced.
    """
    sym = np.asarray(sym)
    if sym.min(initial=0) < 0 or sym.max(initial=0) > 256:
        raise ValueError("symbols out of GF(257) range")
    hi = np.nonzero(sym.reshape(-1) == 256)[0].astype(np.int64)
    low = (sym.reshape(-1) % 256).astype(np.uint8)
    return low, hi


def unpack257(low: np.ndarray, hi: np.ndarray, shape=None) -> np.ndarray:
    out = low.astype(np.int32)
    out[hi] = 256
    return out.reshape(shape) if shape is not None else out


def pack257_rows(sym: np.ndarray, *, out: np.ndarray | None = None,
                 ) -> tuple[np.ndarray, list[np.ndarray]]:
    """Vectorized per-row pack257 for a (n, S) block matrix.

    One pass over the whole matrix (no per-node Python loop): returns the
    uint8 low bytes (n, S) and a list of n per-row index-of-256 arrays.

    ``out`` (uint8, same shape) receives the low bytes in place — the
    zero-copy staging path (DESIGN.md §16): callers pass a pooled
    buffer so a checkpoint save stages no fresh (n, S) allocation.  The
    int32 -> uint8 truncating store IS the ``& 0xFF`` (values are
    0..256, so only 256 wraps — to 0, as before).
    """
    sym = np.asarray(sym)
    if sym.ndim != 2:
        raise ValueError(f"expected (n, S) block matrix, got {sym.shape}")
    if sym.min(initial=0) < 0 or sym.max(initial=0) > 256:
        raise ValueError("symbols out of GF(257) range")
    t0 = perf_counter()
    if out is None:
        low = (sym & 0xFF).astype(np.uint8)   # 256 -> 0, others unchanged
    else:
        if out.shape != sym.shape or out.dtype != np.uint8:
            raise ValueError(f"out must be uint8 {sym.shape}, got "
                             f"{out.dtype} {out.shape}")
        np.copyto(out, sym, casting="unsafe")
        low = out
    rows, cols = np.nonzero(sym == 256)
    splits = np.searchsorted(rows, np.arange(1, sym.shape[0]))
    his = np.split(cols.astype(np.int64), splits)
    record_stage("pack", perf_counter() - t0)
    return low, his


def unpack257_rows(low: np.ndarray, his: Sequence[np.ndarray], *,
                   out: np.ndarray | None = None) -> np.ndarray:
    """Inverse of pack257_rows.  ``out`` (int32, same shape) receives
    the expansion in place — pooled-buffer staging for restore/scrub."""
    t0 = perf_counter()
    if out is None:
        out = np.asarray(low).astype(np.int32)
    else:
        low = np.asarray(low)
        if out.shape != low.shape or out.dtype != np.int32:
            raise ValueError(f"out must be int32 {low.shape}, got "
                             f"{out.dtype} {out.shape}")
        np.copyto(out, low)
    for i, hi in enumerate(his):
        out[i, hi] = 256
    record_stage("pack", perf_counter() - t0)
    return out


def packed_nbytes(sym: np.ndarray) -> int:
    low, hi = pack257(sym)
    return low.nbytes + hi.nbytes


__all__ = [
    "DEFAULT_P", "add", "sub", "mul", "neg", "pow_", "inv", "matmul",
    "matvec", "gauss_inverse", "gauss_det", "nullspace", "solve",
    "bytes_to_symbols", "symbols_to_bytes",
    "pack257", "unpack257", "pack257_rows", "unpack257_rows", "packed_nbytes",
]
