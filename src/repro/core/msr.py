"""Double Circulant MSR code: encode / reconstruct / regenerate (paper §III).

Block convention: the file is cut into n = 2k data blocks; `data[j]` is block
a_j, a row of S symbols (int32 in [0, p)).  Node v_i (1-indexed) stores the
pair (a_{i-1}, r_i) with

    r_i = sum_{u=1..k} c_u * a_{(i - k - u) mod n}   over GF(p).

Storage per node alpha = 2 * S = B/k symbols (MSR point, q = 2).

The three phases of the paper:
  * encode       — construction phase (eq. (2) via M circulant);
  * reconstruct  — data-reconstruction condition: ANY k nodes -> full file;
  * regenerate   — node regeneration with d = k+1 determined helpers and the
                   *embedded property*: no coefficient discovery, helpers send
                   raw stored blocks, the newcomer solves one scalar inverse.

Repair bandwidth: gamma = d * S = (k+1) * B / (2k)  — eq. (7).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Sequence

import jax.numpy as jnp
import numpy as np

from . import gf
from .circulant import CodeSpec, redundancy_support
from .repair import RepairEngine

MatmulFn = Callable[..., jnp.ndarray]  # (A, B, p) -> (A @ B) mod p


@dataclass
class RepairPlan:
    """The embedded property, reified: everything a newcomer for node v_i
    must do, known statically from (i, spec) — no coefficient search."""
    node: int                  # v_i being regenerated (1-indexed)
    prev_node: int             # serves its redundancy block r_{prev}
    next_nodes: tuple[int, ...]  # k nodes serving their data blocks (in order)
    data_indices: tuple[int, ...]  # 0-based a-indices downloaded (a_{i..i+k-1} mod n)
    blocks_downloaded: int     # d = k + 1

    @property
    def d(self) -> int:
        return self.blocks_downloaded


class DoubleCirculantMSR:
    """The paper's [n = 2k, k] code over GF(p), vectorized over symbols.

    Node v_i (1-indexed) stores the pair (a_{i-1}, r_i); all three phases
    — encode (eq. (2)), any-k reconstruct (§III-B) and d = k+1
    regenerate (§III-C) — run as dispatched GF matmuls through the fused
    repair engine (DESIGN.md §3-§4).

    Parameters
    ----------
    spec : CodeSpec
        Validated code specification (k, p, coefficient vector c
        satisfying condition (6)).
    matmul : callable, optional
        Fully custom ``(a, b, p) -> (a @ b) mod p`` kernel.  Injecting
        one disables the structure-exploiting circulant encode and the
        jit fusion so EVERY field operation flows through it.
    backend : str, optional
        Pin a registered dispatch backend by name (``jnp-int32``,
        ``jnp-f32``, ``pallas``, ``pallas-interpret``); None auto-selects
        from (platform, p, k), overridable with ``REPRO_GF_BACKEND``.
    inverse_cache_size : int
        LRU capacity of the decode-inverse cache (entries are keyed by
        the sorted k-node subset; there are C(2k, k) possible).
    mesh : StreamMesh | int | None
        Shard every planned op over this stream-axis device mesh
        (DESIGN.md §14).  ``None`` inherits the ambient
        ``repro.sharding.mesh.use_mesh(...)`` scope (or no mesh at
        all); a 1-device mesh falls back to the plain dispatch planner.
        Ignored for custom-matmul codes (nothing is lowered).

    Attributes
    ----------
    repair : RepairEngine
        The decode-side engine: fused regeneration, cached any-k
        inverses, one-matmul multi-failure repair.
    backend_name : str
        Resolved backend (``"custom"`` when ``matmul`` was injected).

    Examples
    --------
    >>> spec = CodeSpec.make(2, 257)
    >>> code = DoubleCirculantMSR(spec)
    >>> import numpy as np
    >>> red = code.encode(np.zeros((4, 8), np.int32))
    >>> red.shape
    (4, 8)
    """

    def __init__(self, spec: CodeSpec, matmul: MatmulFn | None = None,
                 backend: str | None = None,
                 inverse_cache_size: int = 128, mesh=None):
        self.spec = spec
        self.k, self.n, self.p = spec.k, spec.n, spec.p
        self.c = np.asarray(spec.c, dtype=np.int32)
        self._custom_matmul = matmul is not None
        if matmul is None:
            from repro.kernels import dispatch
            from repro.sharding import mesh as mesh_mod
            be = dispatch.get(backend) if backend else dispatch.select(
                self.p, self.k)
            self.backend_name = be.name
            self._matmul = be.msr_matmul()
            self._circulant = be.circulant_encode
            engine_mm = be.matmul            # module-level singleton: the
                                             # engine's jit cache is shared
            self.mesh = (mesh_mod.as_stream_mesh(mesh) if mesh is not None
                         else mesh_mod.current_mesh())
            # shared per (backend, p, mesh): every code on this backend +
            # mesh hits one AOT executable cache (DESIGN.md §11, §14)
            self.planner = be.planner(self.p, mesh=self.mesh)
        else:
            self.backend_name = "custom"
            self._matmul = matmul
            self._circulant = None
            engine_mm = matmul
            self.mesh = None
            self.planner = None              # custom kernels are not lowered
        self._m = spec.matrix_m()            # (n, n) M[j, i] = coef of a_j in r_{i+1}
        self._mt = np.ascontiguousarray(self._m.T)  # (n, n): r = M^T @ a
        # fused decode-side engine (DESIGN.md §4): repair matrix precomputed
        # here, reconstruction inverses LRU-cached across calls
        self.repair = RepairEngine(spec, engine_mm,
                                   jittable=not self._custom_matmul,
                                   inverse_cache_size=inverse_cache_size,
                                   planner=self.planner)

    # ---------------------------------------------------------------- encode
    def encode(self, data: jnp.ndarray) -> jnp.ndarray:
        """data: (n, S) data blocks -> (n, S) redundancy blocks.

        r[i] = (M^T @ a)[i]; M^T row i has exactly k nonzeros (the circulant
        support), so the dispatched circulant kernel does k MACs/symbol where
        the dense matmul does n — the paper's 2x "computer efficiency" win.
        A custom-matmul code falls back to the dense form.
        """
        data = jnp.asarray(data, jnp.int32)
        if data.shape[0] != self.n:
            raise ValueError(f"expected {self.n} data blocks, got {data.shape[0]}")
        if self._circulant is not None:
            return self._circulant(data, tuple(int(x) for x in self.spec.c),
                                   self.p)
        return self._matmul(jnp.asarray(self._mt), data, self.p)

    def encode_planned(self, data) -> "PlanResult":
        """Planned encode (DESIGN.md §11): the circulant kernel at a
        bucketed stream extent through the shared AOT executable cache.

        Asynchronous — returns a `repro.exec.plan.PlanResult`; call
        ``.host()`` to block and get the exact (n, S) numpy redundancy
        matrix.  Bit-exact vs :meth:`encode` (padding is column-local),
        with zero trace/compile work at steady state.  Custom-matmul
        codes fall back to the eager :meth:`encode`.
        """
        from repro.exec.plan import PlanResult
        data = np.asarray(data, np.int32)
        if data.shape[0] != self.n:
            raise ValueError(f"expected {self.n} data blocks, "
                             f"got {data.shape[0]}")
        if self.planner is not None:
            return self.planner.circulant_encode(
                data, tuple(int(x) for x in self.spec.c))
        return PlanResult(self.encode(data), data.shape[-1])

    def node_storage(self, data: jnp.ndarray) -> list[tuple[jnp.ndarray, jnp.ndarray]]:
        """[(a_{i-1}, r_i)] for node v_i, i = 1..n."""
        red = self.encode(data)
        return [(data[i - 1], red[i - 1]) for i in range(1, self.n + 1)]

    # ----------------------------------------------------------- reconstruct
    def reconstruct(self, node_ids: Sequence[int], data_blocks: jnp.ndarray,
                    red_blocks: jnp.ndarray) -> jnp.ndarray:
        """Any-k reconstruction (paper §III-B).

        node_ids: k distinct 1-indexed nodes the DC connected to.
        data_blocks/red_blocks: (k, S) — the (a_{i-1}, r_i) each node served.
        Returns the full (n, S) data block matrix.

        Downloads 2k blocks of S symbols = B symbols total: gamma = B.

        The system inverse is LRU-cached by the sorted node subset
        (``self.repair.decode_cache``): repeated reconstructions — restore
        loops, scrubs — cost one ``gf.gauss_inverse`` per subset, not per
        call, and any ordering of the same k nodes shares the entry.
        """
        return self.repair.reconstruct(node_ids, data_blocks, red_blocks)

    def reconstruct_with_repair(self, node_ids: Sequence[int], data_blocks,
                                red_blocks, failed: Sequence[int],
                                ) -> tuple[jnp.ndarray, jnp.ndarray]:
        """Multi-failure repair: full data AND every failed node's
        redundancy block from ONE decode matmul (DESIGN.md §4).
        ``node_ids`` must be sorted."""
        return self.repair.reconstruct_with_repair(node_ids, data_blocks,
                                                   red_blocks, failed)

    def systematic_read(self, data: jnp.ndarray) -> jnp.ndarray:
        """Systematic reconstruction (paper §III-B): connect to all n nodes,
        download only the first (data) block from each — n blocks of S symbols
        = B total, all uncoded.  Zero field operations."""
        return jnp.asarray(data, jnp.int32)

    # ------------------------------------------------------------ regenerate
    def repair_plan(self, i: int) -> RepairPlan:
        """Determined helper set for node v_i — the embedded property."""
        if not 1 <= i <= self.n:
            raise ValueError(f"node {i} out of range 1..{self.n}")
        prev_node = (i - 2) % self.n + 1
        next_nodes = tuple((i - 1 + t) % self.n + 1 for t in range(1, self.k + 1))
        data_indices = tuple((i - 1 + t) % self.n for t in range(1, self.k + 1))
        return RepairPlan(node=i, prev_node=prev_node, next_nodes=next_nodes,
                          data_indices=data_indices, blocks_downloaded=self.k + 1)

    def regenerate(self, i: int, r_prev: jnp.ndarray,
                   next_data: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
        """Systematic (exact) regeneration of node v_i (paper §III-C).

        r_prev: (S,) — r_{i-1} downloaded from the previous node.
        next_data: (k, S) — a_{(i-1+t) mod n}, t = 1..k, downloaded from the
          next k nodes in plan order.
        Returns (a_{i-1}, r_i) — bit-exactly the lost node's pair.

        Download = (k+1) * S symbols = (k+1) B / (2k): eq. (7), the MSR
        minimum for d = k+1.

        Fused path (DESIGN.md §4): the scalar solve, the correction and the
        re-encode fold into ONE (2, k+1) repair-matrix matmul over the
        stacked helpers — ``regenerate_reference`` keeps the unfused
        three-round schedule as the bit-exactness oracle.
        """
        return self.repair.regenerate(i, r_prev, next_data)

    def regenerate_batch(self, nodes: Sequence[int], r_prevs, next_data, *,
                         tile_symbols: int | None = None) -> jnp.ndarray:
        """Batched fused regeneration (vmapped over failed nodes, stream
        axis tiled): (F, S) r_prevs + (F, k, S) helpers -> (F, 2, S)
        [a_lost; r_new] stacks.  See RepairEngine.regenerate_batch."""
        return self.repair.regenerate_batch(nodes, r_prevs, next_data,
                                            tile_symbols=tile_symbols)

    def regenerate_reference(self, i: int, r_prev: jnp.ndarray,
                             next_data: jnp.ndarray,
                             ) -> tuple[jnp.ndarray, jnp.ndarray]:
        """The unfused pre-engine newcomer schedule: two small matmuls plus
        host-side elementwise correction.  Kept as the reference the fused
        single-matmul path is verified (and benchmarked) against."""
        k, n, p = self.k, self.n, self.p
        r_prev = jnp.asarray(r_prev, jnp.int32)
        next_data = jnp.asarray(next_data, jnp.int32)
        if next_data.shape[0] != k:
            raise ValueError(f"expected {k} helper data blocks, got {next_data.shape[0]}")

        # r_{i-1} = c_k a_{i-1} + sum_{u=1..k-1} c_u a_{(i-1+k-u) mod n}
        # the u-th term's block is next_data[k-u-1]  (t = k-u).
        c = self.c.astype(np.int64)
        if k > 1:
            coefs = jnp.asarray(c[:-1], jnp.int32)            # c_1..c_{k-1}
            # t = k-u for u=1..k-1  ->  rows k-2, k-3, ..., 0 of next_data
            rows = next_data[jnp.arange(k - 2, -1, -1)]       # (k-1, S)
            partial = self._matmul(coefs[None, :], rows, p)[0]
        else:
            partial = jnp.zeros_like(r_prev)
        ck_inv = int(pow(int(c[-1]), p - 2, p))
        a_lost = ((r_prev - partial) * ck_inv) % p

        # r_i = sum_{u=1..k} c_u a_{(i-k-u) mod n}; term u uses t = k+1-u,
        # i.e. next_data[k-u]  (t-1 = k-u).
        coefs_all = jnp.asarray(c, jnp.int32)
        rows_all = next_data[jnp.arange(k - 1, -1, -1)]       # u=1..k -> t-1 = k-1..0
        r_new = self._matmul(coefs_all[None, :], rows_all, p)[0]
        return a_lost, r_new

    # ------------------------------------------------------------- accounting
    def gamma_regenerate_symbols(self, block_symbols: int) -> int:
        """Repair bandwidth in symbols: d * S = (k+1) * B / (2k)."""
        return (self.k + 1) * block_symbols

    def gamma_reconstruct_symbols(self, block_symbols: int) -> int:
        """Classical-EC-style repair (full reconstruction): 2k * S = B."""
        return 2 * self.k * block_symbols

    def alpha_symbols(self, block_symbols: int) -> int:
        """Per-node storage: 2 * S = B / k (MSR point)."""
        return 2 * block_symbols

    # sanity helper used by property tests
    def verify_support(self) -> bool:
        for i in range(1, self.n + 1):
            sup = redundancy_support(i, self.n)
            col = self._m[:, i - 1]
            nz = [j for j in range(self.n) if col[j] != 0]
            if sorted(sup) != sorted(nz):
                return False
        return True


# ---------------------------------------------------------------- file-level
@dataclass
class EncodedFile:
    """A file encoded across n nodes (host-side container for tests/examples)."""
    spec: CodeSpec
    data: np.ndarray          # (n, S) data blocks
    red: np.ndarray           # (n, S) redundancy blocks
    orig_len: int             # original byte length (before padding)

    def node(self, i: int) -> tuple[np.ndarray, np.ndarray]:
        return self.data[i - 1], self.red[i - 1]


def encode_file(payload: bytes, spec: CodeSpec,
                code: DoubleCirculantMSR | None = None) -> EncodedFile:
    code = code or DoubleCirculantMSR(spec)
    sym = gf.bytes_to_symbols(payload, spec.p)
    n = spec.n
    pad = (-len(sym)) % n
    sym = np.pad(sym, (0, pad))
    blocks = sym.reshape(n, -1)
    red = np.asarray(code.encode(jnp.asarray(blocks)))
    return EncodedFile(spec=spec, data=blocks.astype(np.int32), red=red,
                       orig_len=len(payload))


def reconstruct_file(enc: EncodedFile, node_ids: Sequence[int],
                     code: DoubleCirculantMSR | None = None) -> bytes:
    code = code or DoubleCirculantMSR(enc.spec)
    d = jnp.asarray(enc.data[[i - 1 for i in node_ids]])
    r = jnp.asarray(enc.red[[i - 1 for i in node_ids]])
    blocks = np.asarray(code.reconstruct(node_ids, d, r))
    return gf.symbols_to_bytes(blocks.reshape(-1)[: enc.orig_len])


__all__ = ["DoubleCirculantMSR", "RepairPlan", "EncodedFile",
           "encode_file", "reconstruct_file"]
