"""Core: the paper's contribution — Double Circulant MSR codes.

Gastón & Pujol (2010): systematic [n=2k, k] Minimum Storage Regenerating
codes with d = k+1 determined helpers and precalculated (embedded)
coefficients, built from a double circulant generator A = (I | M).
"""
from . import gf, circulant, msr, baselines, placement, repair  # noqa: F401
from .circulant import CodeSpec, check_condition6, find_coefficients, min_field_size  # noqa: F401
from .msr import DoubleCirculantMSR, RepairPlan, encode_file, reconstruct_file  # noqa: F401
from .repair import DecodeInverseCache, RepairEngine, build_repair_matrix  # noqa: F401
