"""Baselines the paper compares against (§II, §IV).

1. Replication  — per-node storage alpha = B, repair gamma = B (download a
   full replica); storage overhead = replicas x.
2. Classical MDS erasure coding (systematic Reed–Solomon via Vandermonde over
   GF(p)) — alpha = B/k, but repair of ONE node requires downloading the
   whole file: gamma = B (the paper's central drawback, §II).
3. Solve-based MSR repair (Rashmi/Cullina-style, modelled): optimal gamma but
   the newcomer must (a) pick d helpers, (b) discover/solve for coefficients
   — an O(k^3) field solve per repair plus per-helper inner products.  We
   model it as full any-k reconstruction + re-encode with an added coefficient
   solve, and count field operations so benchmarks can compare complexity
   (paper §IV "the algorithm for node regeneration is trivial").
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Sequence

import jax.numpy as jnp
import numpy as np

from . import gf


# ----------------------------------------------------------------- replication
@dataclass(frozen=True)
class ReplicationScheme:
    replicas: int

    def storage_per_node_symbols(self, file_symbols: int) -> int:
        return file_symbols

    def total_storage_symbols(self, file_symbols: int) -> int:
        return self.replicas * file_symbols

    def repair_symbols(self, file_symbols: int) -> int:
        return file_symbols  # download one replica

    def max_failures(self) -> int:
        return self.replicas - 1


# ------------------------------------------------------------------ classical RS
class RSCode:
    """Systematic [n, k] Reed–Solomon (Vandermonde) over GF(p).

    Node v_i stores ONE block of B/k symbols (classical EC view, Fig. 1).
    Repairing any single node = reconstruct from k nodes = download B symbols.
    """

    def __init__(self, n: int, k: int, p: int = gf.DEFAULT_P):
        if n >= p:
            raise ValueError(f"RS over GF({p}) needs n < p, got n={n}")
        self.n, self.k, self.p = n, k, p
        # generator: G = [I | V] with V[j, i] = x_i^j (k x (n-k)); any k columns
        # of [I | V] invertible for distinct evaluation points (Cauchy/Vandermonde
        # systematicization): we build G by interpolation to guarantee MDS.
        x = np.arange(1, n + 1, dtype=np.int64) % p          # n distinct points
        vand_k = np.vstack([pow_col(x[:k], j, p) for j in range(k)])   # (k, k)
        inv = gf.gauss_inverse(vand_k.T % p, p)               # interpolation
        vand_n = np.vstack([pow_col(x, j, p) for j in range(k)]).T % p  # (n, k)
        self.g = (vand_n.astype(np.int64) @ inv.astype(np.int64)) % p  # (n, k)
        # rows 0..k-1 of g form I_k => systematic
        assert np.array_equal(self.g[:k] % p, np.eye(k, dtype=np.int64) % p)
        self.g = self.g.astype(np.int32)

    def encode(self, data: jnp.ndarray) -> jnp.ndarray:
        """data: (k, S) -> codeword blocks (n, S); first k rows are the data."""
        return gf.matmul(jnp.asarray(self.g), jnp.asarray(data, jnp.int32), self.p)

    def reconstruct(self, node_ids: Sequence[int], blocks: jnp.ndarray) -> jnp.ndarray:
        """Any k node blocks -> original (k, S) data."""
        rows = [i - 1 for i in node_ids]
        sub = self.g[rows]                                   # (k, k)
        inv = gf.gauss_inverse(sub, self.p)
        return gf.matmul(jnp.asarray(inv), jnp.asarray(blocks, jnp.int32), self.p)

    def repair_symbols(self, file_symbols: int) -> int:
        return file_symbols                                   # gamma = B

    def storage_per_node_symbols(self, file_symbols: int) -> int:
        return file_symbols // self.k                         # alpha = B/k

    def total_storage_symbols(self, file_symbols: int) -> int:
        return self.n * self.storage_per_node_symbols(file_symbols)


def pow_col(x: np.ndarray, j: int, p: int) -> np.ndarray:
    out = np.ones_like(x)
    for _ in range(j):
        out = (out * x) % p
    return out


# ----------------------------------------------------- solve-based MSR (modelled)
@dataclass
class SolveBasedRepairCost:
    """Field-operation counts for one repair, for complexity comparison."""
    coefficient_solve_ops: int    # discovering/solving combination coefficients
    helper_combine_ops: int       # helpers' internal linear combinations
    newcomer_solve_ops: int       # newcomer's linear system solve
    stream_ops: int               # per-symbol multiply-accumulate work
    downloads_symbols: int


def solve_based_msr_repair_cost(k: int, block_symbols: int) -> SolveBasedRepairCost:
    """Rashmi et al. (d = k+1) style repair, modelled per §IV: helpers combine
    their q=2 blocks, the newcomer solves a (k+1)-dim system, and coefficients
    must be discovered per failure (O(k^3) solve over the field)."""
    d = k + 1
    return SolveBasedRepairCost(
        coefficient_solve_ops=2 * k**3,          # Gaussian elimination scale
        helper_combine_ops=d * 2 * block_symbols,  # each helper combines q=2 blocks
        newcomer_solve_ops=2 * d**3,
        stream_ops=d * d * block_symbols,        # applying the solved system
        downloads_symbols=d * block_symbols,
    )


def embedded_repair_cost(k: int, block_symbols: int) -> SolveBasedRepairCost:
    """The paper's embedded repair: zero coefficient discovery, zero helper-side
    combinations; the newcomer does 2k multiply-accumulates per symbol
    (k-1 MACs + 1 inverse-scale for a_{i-1}; k MACs for r_i)."""
    d = k + 1
    return SolveBasedRepairCost(
        coefficient_solve_ops=0,
        helper_combine_ops=0,
        newcomer_solve_ops=0,
        stream_ops=2 * k * block_symbols,
        downloads_symbols=d * block_symbols,
    )


# ------------------------------------------------- scenario-level accounting
def rs_scenario_repair_symbols(k: int, block_symbols: int,
                               n_failures: int) -> int:
    """RS re-download baseline for a failure scenario (DESIGN.md §9).

    Classical [n, k] erasure coding repairs EACH failed node by
    re-downloading the whole file: gamma = B = 2k * S symbols per failure
    (the paper's central drawback, §II).  The cluster simulator divides
    its measured repair traffic by this number to report the per-scenario
    bandwidth ratio.

    Parameters
    ----------
    k : int
        Code dimension (n = 2k).
    block_symbols : int
        Symbols per block (S); the file is B = 2k * S symbols.
    n_failures : int
        Number of failed nodes repaired in the scenario.

    Returns
    -------
    int
        Total symbols an RS cluster would move: ``n_failures * 2k * S``.
    """
    return n_failures * 2 * k * block_symbols


__all__ = ["ReplicationScheme", "RSCode", "SolveBasedRepairCost",
           "solve_based_msr_repair_cost", "embedded_repair_cost",
           "rs_scenario_repair_symbols"]
