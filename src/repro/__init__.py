"""repro: Double Circulant MSR codes as the fault-tolerance substrate of a
multi-pod JAX training/inference framework (see DESIGN.md)."""

__version__ = "0.1.0"
