"""Serving layer (DESIGN.md §9, §13).

* `frontend.ReadFrontEnd` — the robust store-serving front end:
  deadlines + hedged reads, end-to-end share CRCs with corrupt-share
  quarantine, and a bounded admission queue with typed ``Overloaded``
  shedding;
* `engine.CodedReadServer` / `engine.ServingEngine` — degraded-read
  block serving over the cluster simulator and the batched LLM
  inference engine it can feed (imported from `repro.serve.engine`
  directly; kept out of this namespace so importing the front end does
  not pull the model stack).
"""
from .frontend import (FrontEndMetrics, NodeHealth, Overloaded,
                       ReadFrontEnd, ReadReceipt, ReadTicket)

__all__ = ["ReadFrontEnd", "ReadTicket", "ReadReceipt", "NodeHealth",
           "FrontEndMetrics", "Overloaded"]
