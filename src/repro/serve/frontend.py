"""Robust serving front end over the coded object store (DESIGN.md §13).

:class:`ReadFrontEnd` sits in front of :class:`CodedObjectStore` and
makes the read path survive what the drill harness throws at it, by
treating the code's redundancy as a *serving* resource — tail-latency
insurance and integrity armor, not just durability:

* **deadlines + hedged reads** (§13.1) — every request carries a
  deadline budget that propagates into each share fetch (capping the
  retry policy's wall clock via ``budget_s``).  A fetch that exceeds
  the hedge threshold is abandoned: the stripe decodes around the
  laggard through the one-matmul degraded path instead of waiting.
  Per-node EWMA fetch latencies plus :class:`HeartbeatMonitor`
  straggler signals demote known-slow nodes to last-resort helpers
  BEFORE any hedge timer fires.
* **end-to-end read integrity** (§13.2) — every fetched share is
  CRC-verified against the put-time ledger (:func:`share_crc`, PR 6's
  logical-CRC convention).  A mismatch is treated as an erasure: the
  stripe decodes around it, the node's suspicion rises, and — when the
  STORED copy is also bad (storage rot, not a transient read-path
  flip) — the share is dropped and the stripe enqueued with the
  repair scheduler.  A corrupt payload never reaches a caller.
* **quarantine** (§13.3) — a suspicion ledger (CRC failures weigh
  most, retry give-ups next, hedged-past fetches least) evicts nodes
  from helper selection at ``quarantine_threshold``; re-admission
  requires a clean targeted scrub (:meth:`CodedObjectStore.scrub_node`)
  — a dirty scrub drops the rotten shares, queues their repairs, and
  keeps the node out until a later scrub comes back clean.
* **admission control + load shedding** (§13.4) — a bounded priority
  queue; concurrent gets coalesce per key, and degraded stripes
  coalesce ACROSS requests by failure pattern into one planned decode
  dispatch each (the PR 5 plan cache).  When the queue is full the
  lowest-priority request in sight is shed with a typed
  :class:`Overloaded` — never a hang, never a silent drop.  Background
  repair drains share the same :class:`LinkModel` budget via
  :meth:`tick`.

The front end is single-dispatcher: one thread calls ``submit``/
``pump``/``tick``; only share fetches fan out to the internal pool.
"""
from __future__ import annotations

import dataclasses
import math
import time
from concurrent.futures import ThreadPoolExecutor
from concurrent.futures import TimeoutError as _FutureTimeout
from typing import Any, Callable, Optional

import numpy as np

from repro.io.retry import GiveUpError
from repro.store.object_store import (CodedObjectStore, ObjectStat,
                                      share_crc)

_MIN_PATIENCE_S = 1e-3      # never poll a future with a zero timeout
_CRC_REREADS = 2            # re-fetches after a transient CRC mismatch


class Overloaded(RuntimeError):
    """Typed load-shed error (DESIGN.md §13.4): the admission queue was
    full and this request was the lowest-priority one in sight.  The
    shed ticket resolves immediately with this error — callers always
    get an answer, never a hang or a silent drop."""

    def __init__(self, key: str, priority: int, queue_depth: int):
        super().__init__(f"overloaded: shed read of {key!r} (priority "
                         f"{priority}) at queue depth {queue_depth}")
        self.key = key
        self.priority = priority
        self.queue_depth = queue_depth


@dataclasses.dataclass
class ReadReceipt:
    """What serving one request cost (attached to its ticket)."""
    key: str
    wall_latency_s: float = 0.0
    deadline_s: float = 0.0
    deadline_met: bool = True
    degraded_stripes: int = 0
    hedged_fetches: int = 0
    crc_rejected: int = 0
    coalesced: int = 1            # tickets served by this key's one read
    decode_dispatches: int = 0    # failure patterns this key's read joined
    avoided_nodes: tuple = ()


@dataclasses.dataclass
class ReadTicket:
    """One admitted (or shed) request.  ``result()`` returns the object
    or raises the typed error; it never blocks — ``pump()`` resolves
    tickets synchronously."""
    uid: int
    key: str
    priority: int
    deadline_s: float
    submitted_t: float
    done: bool = False
    obj: Any = None
    error: Optional[BaseException] = None
    receipt: Optional[ReadReceipt] = None

    def result(self) -> Any:
        if not self.done:
            raise RuntimeError(f"request {self.uid} ({self.key!r}) not "
                               f"served yet — pump() the front end")
        if self.error is not None:
            raise self.error
        return self.obj


@dataclasses.dataclass
class NodeHealth:
    """Per-physical-node suspicion ledger + learned fetch latency."""
    suspicion: float = 0.0
    quarantined: bool = False
    crc_failures: int = 0
    timeouts: int = 0             # fetches hedged past
    giveups: int = 0
    scrubs: int = 0
    readmissions: int = 0
    ewma_read_s: Optional[float] = None

    def observe(self, dt: float, alpha: float = 0.3) -> None:
        self.ewma_read_s = dt if self.ewma_read_s is None \
            else (1.0 - alpha) * self.ewma_read_s + alpha * dt


def _percentile(sorted_vals: list, p: float) -> float:
    if not sorted_vals:
        return 0.0
    idx = max(0, min(len(sorted_vals) - 1,
                     math.ceil(p / 100.0 * len(sorted_vals)) - 1))
    return sorted_vals[idx]


class FrontEndMetrics:
    """Front-end accounting: request outcomes, wall-latency tail, and
    every robustness mechanism's fire count."""

    def __init__(self):
        self.requests = 0
        self.served = 0
        self.failed = 0
        self.shed = 0
        self.coalesced_requests = 0   # tickets beyond the first per key
        self.deadline_misses = 0
        self.hedged_fetches = 0
        self.crc_rejected = 0
        self.quarantines = 0
        self.readmissions = 0
        self.decode_dispatches = 0
        self.degraded_stripes = 0
        self.wall_latencies: list[float] = []

    def latency_percentiles(self) -> dict:
        lat = sorted(self.wall_latencies)
        return {"p50_s": _percentile(lat, 50.0),
                "p99_s": _percentile(lat, 99.0),
                "p999_s": _percentile(lat, 99.9),
                "max_s": lat[-1] if lat else 0.0}

    def summary(self) -> dict:
        return {"requests": self.requests, "served": self.served,
                "failed": self.failed, "shed": self.shed,
                "coalesced_requests": self.coalesced_requests,
                "deadline_misses": self.deadline_misses,
                "hedged_fetches": self.hedged_fetches,
                "crc_rejected": self.crc_rejected,
                "quarantines": self.quarantines,
                "readmissions": self.readmissions,
                "decode_dispatches": self.decode_dispatches,
                "degraded_stripes": self.degraded_stripes,
                "latency": {k: round(v, 6) for k, v in
                            self.latency_percentiles().items()}}


class ReadFrontEnd:
    """Deadline-aware, hedged, integrity-checking read front end.

    Parameters
    ----------
    store : CodedObjectStore
        The store being served.  Its fault injector (if any) drives the
        hedging/quarantine machinery deterministically in tests.
    scheduler : RepairScheduler, optional
        Where CRC-dropped shares get their stripes re-protected, and
        whose drains :meth:`tick` interleaves with foreground serving
        under the shared link budget.
    heartbeat : HeartbeatMonitor, optional
        Its :meth:`suspects` feed (dead + wall-clock/progress
        stragglers) demotes nodes in helper selection before any hedge
        fires.  ``heartbeat_clock`` supplies the monitor's time domain
        (often simulated); defaults to the front end's clock.
    default_deadline_s : float
        Deadline for requests that don't carry one.
    hedge_after_s : float or None
        Per-fetch patience before abandoning a share and decoding
        around it.  ``None`` disables hedging AND latency-based
        avoidance (the unhedged baseline the benchmark A/Bs against).
    max_queue : int
        Admission bound; beyond it the lowest-priority request is shed.
    quarantine_threshold : float
        Suspicion level at which a node is evicted from helper
        selection until a clean scrub re-admits it.
    crc_weight, giveup_weight, hedge_weight : float
        Suspicion increments per signal — integrity failures weigh
        most, being slow weighs least.
    fetch_workers : int
        Pool width for hedged share fetches.
    clock : callable
        Injectable wall clock (tests pin it).
    """

    def __init__(self, store: CodedObjectStore, *,
                 scheduler=None, heartbeat=None,
                 heartbeat_clock: Optional[Callable[[], float]] = None,
                 default_deadline_s: float = 0.25,
                 hedge_after_s: Optional[float] = 0.02,
                 max_queue: int = 64,
                 quarantine_threshold: float = 3.0,
                 crc_weight: float = 2.0,
                 giveup_weight: float = 1.0,
                 hedge_weight: float = 0.5,
                 fetch_workers: int = 8,
                 clock: Callable[[], float] = time.monotonic):
        self.store = store
        self.scheduler = scheduler
        self.heartbeat = heartbeat
        self.clock = clock
        self.heartbeat_clock = heartbeat_clock or clock
        self.default_deadline_s = float(default_deadline_s)
        self.hedge_after_s = hedge_after_s
        self.max_queue = int(max_queue)
        self.quarantine_threshold = float(quarantine_threshold)
        self.crc_weight = float(crc_weight)
        self.giveup_weight = float(giveup_weight)
        self.hedge_weight = float(hedge_weight)
        self.fetch_workers = int(fetch_workers)
        self.metrics = FrontEndMetrics()
        self.events: list[dict] = []      # quarantine state transitions
        self._health: dict[int, NodeHealth] = {}
        self._queue: list[ReadTicket] = []
        self._uid = 0
        self._pool_obj: Optional[ThreadPoolExecutor] = None

    # ------------------------------------------------------------- lifecycle
    @property
    def _pool(self) -> ThreadPoolExecutor:
        if self._pool_obj is None:
            self._pool_obj = ThreadPoolExecutor(
                max_workers=self.fetch_workers,
                thread_name_prefix="serve-fetch")
        return self._pool_obj

    def close(self) -> None:
        if self._pool_obj is not None:
            self._pool_obj.shutdown(wait=True)
            self._pool_obj = None

    def __enter__(self) -> "ReadFrontEnd":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        self.close()

    # ----------------------------------------------------- health machinery
    def health(self, phys: int) -> NodeHealth:
        if phys not in self._health:
            self._health[phys] = NodeHealth()
        return self._health[phys]

    def quarantined_nodes(self) -> list[int]:
        return sorted(p for p, h in self._health.items() if h.quarantined)

    def _log(self, what: str, **fields) -> None:
        self.events.append({"seq": len(self.events), "what": what, **fields})

    def _suspect(self, phys: int, weight: float, reason: str) -> None:
        h = self.health(phys)
        h.suspicion += weight
        if not h.quarantined and h.suspicion >= self.quarantine_threshold:
            h.quarantined = True
            self.metrics.quarantines += 1
            self._log("quarantine", node=phys, reason=reason,
                      suspicion=round(h.suspicion, 3))

    def _avoid_reasons(self) -> dict[int, str]:
        """Physical nodes helper selection demotes, worst reason wins:
        quarantined (integrity) > heartbeat dead/straggler > learned-slow
        (EWMA above the hedge threshold).  Demoted nodes are still used
        as a LAST resort when fewer than k preferred shares are
        readable — graceful degradation beats refusal."""
        avoid: dict[int, str] = {}
        if self.heartbeat is not None:
            sus = self.heartbeat.suspects(self.heartbeat_clock())
            for phys in sus["dead"]:
                if 1 <= phys <= self.store.n_nodes:
                    avoid[phys] = "dead-heartbeat"
            for phys in sus["stragglers"]:
                if 1 <= phys <= self.store.n_nodes:
                    avoid.setdefault(phys, "straggler")
        for phys, h in self._health.items():
            if h.quarantined:
                avoid[phys] = "quarantined"
            elif self.hedge_after_s is not None \
                    and h.ewma_read_s is not None \
                    and h.ewma_read_s > self.hedge_after_s:
                avoid.setdefault(phys, "slow")
        return avoid

    def scrub_quarantined(self) -> list[dict]:
        """Targeted scrub of every quarantined node whose slot is up: a
        clean scrub re-admits (suspicion reset); a dirty one drops the
        rotten shares as erasures, queues their repairs, and keeps the
        node quarantined until a later scrub comes back clean
        (DESIGN.md §13.3)."""
        out = []
        for phys in sorted(self._health):
            h = self._health[phys]
            if not h.quarantined or not self.store.is_up(phys):
                continue
            bad = self.store.scrub_node(phys)
            h.scrubs += 1
            if bad:
                for key, t in bad:
                    self.store.drop_share(phys, key, t)
                    if self.scheduler is not None:
                        self.scheduler.enqueue_stripe(key, t)
                self._log("scrub_dirty", node=phys, dropped=len(bad))
            else:
                h.quarantined = False
                h.suspicion = 0.0
                h.readmissions += 1
                self.metrics.readmissions += 1
                self._log("readmit", node=phys)
            out.append({"node": phys, "bad_shares": len(bad),
                        "readmitted": not h.quarantined})
        return out

    # ------------------------------------------------------------ admission
    def submit(self, key: str, *, priority: int = 0,
               deadline_s: Optional[float] = None) -> ReadTicket:
        """Admit a read (or shed the lowest-priority request in sight if
        the queue is full).  Returns the ticket; a shed ticket is
        already ``done`` with a typed :class:`Overloaded` error."""
        self._uid += 1
        tk = ReadTicket(uid=self._uid, key=key, priority=int(priority),
                        deadline_s=self.default_deadline_s
                        if deadline_s is None else float(deadline_s),
                        submitted_t=self.clock())
        self.metrics.requests += 1
        if len(self._queue) < self.max_queue:
            self._queue.append(tk)
            return tk
        # full: shed the lowest-priority request (newest loses ties, so
        # an incoming request never bumps an equal-priority queued one)
        victim = min(self._queue, key=lambda r: (r.priority, -r.uid))
        if (tk.priority, -tk.uid) <= (victim.priority, -victim.uid):
            victim = tk
        else:
            self._queue.remove(victim)
            self._queue.append(tk)
        victim.done = True
        victim.error = Overloaded(victim.key, victim.priority,
                                  len(self._queue))
        victim.receipt = ReadReceipt(key=victim.key,
                                     deadline_s=victim.deadline_s,
                                     deadline_met=False)
        self.metrics.shed += 1
        self._log("shed", key=victim.key, priority=victim.priority)
        return tk

    def read(self, key: str, *, priority: int = 0,
             deadline_s: Optional[float] = None) -> Any:
        """Convenience: submit + pump + result (raises the typed error
        on shed or data loss)."""
        return self.read_ext(key, priority=priority,
                             deadline_s=deadline_s).result()

    def read_ext(self, key: str, *, priority: int = 0,
                 deadline_s: Optional[float] = None) -> ReadTicket:
        tk = self.submit(key, priority=priority, deadline_s=deadline_s)
        if not tk.done:
            self.pump()
        return tk

    # ----------------------------------------------------------- serve loop
    def pump(self) -> list[ReadTicket]:
        """Serve everything admitted so far: coalesce tickets per key,
        read each key once, coalesce degraded stripes across ALL keys
        by failure pattern into one planned decode dispatch each, then
        resolve every ticket.  Returns the batch."""
        batch, self._queue = self._queue, []
        if not batch:
            return []
        batch.sort(key=lambda r: (-r.priority, r.uid))
        by_key: dict[str, list[ReadTicket]] = {}
        for tk in batch:
            by_key.setdefault(tk.key, []).append(tk)
        self._serve(by_key)
        return batch

    def _serve(self, by_key: dict[str, list[ReadTicket]]) -> None:
        store = self.store
        avoid = self._avoid_reasons()
        plans: dict[str, dict] = {}
        groups: dict[tuple, list[tuple[str, int]]] = {}
        downloads: dict[tuple[str, int], np.ndarray] = {}
        for key, tickets in by_key.items():
            try:
                stat = store.stat(key)
            except KeyError as e:           # includes UnknownKeyError
                self._fail_tickets(tickets, e)
                continue
            cc = getattr(stat, "code_class", None)
            if cc is not None and cc != store.default_class:
                # non-default code family (DESIGN.md §15.1): the hedged /
                # cross-key-coalesced machinery below is specific to the
                # default class's share geometry — serve through the
                # store's family-generic degraded read path instead
                self._serve_generic(key, tickets)
                continue
            plan = {"stat": stat, "tickets": tickets,
                    "deadline_end": max(tk.submitted_t + tk.deadline_s
                                        for tk in tickets),
                    "blocks": np.zeros((stat.n_stripes, store.n, store.S),
                                       np.int32),
                    "degraded": 0, "hedged": 0, "crc_rejected": 0,
                    "patterns": 0, "avoided": set()}
            try:
                for t in range(stat.n_stripes):
                    pattern, dl = self._read_stripe(key, t, plan, avoid)
                    if pattern is not None:
                        groups.setdefault(pattern, []).append((key, t))
                        downloads[(key, t)] = dl
                        plan["degraded"] += 1
            except RuntimeError as e:       # < k readable shares
                store.metrics.record_read("failed", 0.0, 0)
                self._fail_tickets(tickets, e)
                continue
            plans[key] = plan

        if groups:
            S = store.S

            def gather(item):
                _pattern, refs = item
                return np.concatenate([downloads[r] for r in refs], axis=1)

            def decode(item, dl):
                (helpers, missing), _refs = item
                mat = store.code.repair.decode_matrix(helpers)
                return store.code.repair.apply_planned(mat[list(missing)], dl)

            def scatter(item, res) -> None:
                (_helpers, missing), refs = item
                dec = res.host()
                for g, (key, t) in enumerate(refs):
                    plans[key]["blocks"][t, list(missing)] = \
                        dec[:, g * S:(g + 1) * S]

            store.pipeline.map(list(groups.items()), decode, scatter,
                               read=gather)
            self.metrics.decode_dispatches += len(groups)
            for _pattern, refs in groups.items():
                for key in {k for k, _t in refs}:
                    plans[key]["patterns"] += 1

        for key, plan in plans.items():
            self._resolve_key(key, plan)

    def _read_stripe(self, key: str, t: int, plan: dict,
                     avoid: dict[int, str]):
        """Fetch stripe (key, t): preferred (non-demoted) nodes first
        under the hedge/deadline budget, demoted nodes as a blocking
        last resort only while fewer than k shares are readable.
        Fills the systematic blocks; returns the ((helpers, missing)
        pattern, (2k, S) downloads) when a decode is needed, else
        (None, None)."""
        store = self.store
        pl = store.placement_of(key, t)
        present = sorted(store.present_code_nodes(key, t))
        pref = [j for j in present if pl[j - 1] not in avoid]
        fall = [j for j in present if pl[j - 1] in avoid]
        # soft-demoted (slow/straggler) nodes outrank quarantined ones
        fall.sort(key=lambda j: (avoid[pl[j - 1]] == "quarantined", j))
        plan["avoided"].update(pl[j - 1] for j in fall)
        fetched: dict[int, list] = {}
        for j in pref:
            share = self._fetch_checked(pl[j - 1], key, t, plan)
            if share is not None:
                fetched[j] = share
        for j in fall:
            if len(fetched) >= store.k:
                break
            share = self._fetch_checked(pl[j - 1], key, t, plan, must=True)
            if share is not None:
                fetched[j] = share
        if len(fetched) < store.k:
            raise RuntimeError(
                f"data loss: stripe {t} of {key!r} has only "
                f"{len(fetched)} readable of k={store.k} shares")
        for j, share in fetched.items():
            plan["blocks"][t, j - 1] = share[1]
        missing = tuple(j for j in range(store.n) if j + 1 not in fetched)
        if not missing:
            lat = store.link.fetch_s(store.S)
            store.metrics.record_read("systematic", lat, store.n * store.S)
            return None, None
        helpers = tuple(sorted(fetched)[: store.k])
        dl = np.concatenate(
            [np.stack([fetched[j][1] for j in helpers]),
             np.stack([fetched[j][2] for j in helpers])], axis=0)
        lat = store.link.degraded_read_s(2 * store.S, [1.0] * store.k)
        store.metrics.record_read("degraded", lat, 2 * store.k * store.S)
        return (helpers, missing), dl

    def _fetch_checked(self, phys: int, key: str, t: int, plan: dict,
                      must: bool = False) -> Optional[list]:
        """One share fetch + end-to-end CRC check.  Returns the share or
        None (absent, hedged past, gave up, or failed its CRC — in
        which case the caller decodes around it).  A mismatch whose
        STORED copy is intact is a read-path flip: the fetch is retried
        up to ``_CRC_REREADS`` times before giving the share up.
        ``must`` fetches (last-resort helpers) ignore the hedge and
        deadline: serving late beats refusing."""
        store = self.store
        h = self.health(phys)
        for _attempt in range(1 + _CRC_REREADS):
            share = self._fetch_once(phys, key, t, plan, must)
            if share is None:
                return None
            if self._crc_ok(plan["stat"], t, share):
                return share
            # integrity failure: erasure candidate, suspicion always;
            # drop + enqueue repair only when the STORED copy is rotten
            h.crc_failures += 1
            plan["crc_rejected"] += 1
            self.metrics.crc_rejected += 1
            self._suspect(phys, self.crc_weight, "crc mismatch")
            if store.share_intact(phys, key, t) is False:
                store.drop_share(phys, key, t)
                if self.scheduler is not None:
                    self.scheduler.enqueue_stripe(key, t)
                self._log("crc_drop", node=phys, key=key, stripe=t)
                return None
            self._log("crc_transient", node=phys, key=key, stripe=t)
        return None

    def _fetch_once(self, phys: int, key: str, t: int, plan: dict,
                    must: bool) -> Optional[list]:
        """One raw share fetch under the hedge/deadline machinery (no
        CRC): the share, or None when absent, hedged past, or the retry
        policy gave up."""
        store = self.store
        h = self.health(phys)
        t0 = self.clock()
        budget = None if must \
            else max(0.0, plan["deadline_end"] - t0)
        if store.faults is None:
            # nothing can stall an in-memory read: fetch inline
            try:
                share = store.read_share(phys, key, t)
            except KeyError:
                return None
            h.observe(self.clock() - t0)
            return share
        timeout = None if must else self.hedge_after_s
        if timeout is not None:
            timeout = min(timeout, max(budget, _MIN_PATIENCE_S))
        fut = self._pool.submit(store.read_share, phys, key, t,
                                budget_s=budget)
        try:
            share = fut.result(timeout=timeout)
        except _FutureTimeout:
            h.timeouts += 1
            plan["hedged"] += 1
            self.metrics.hedged_fetches += 1
            self._suspect(phys, self.hedge_weight, "hedged past")
            fut.add_done_callback(
                lambda f, p=phys, s=t0: self._observe_late(p, s, f))
            return None
        except GiveUpError:
            h.giveups += 1
            self._suspect(phys, self.giveup_weight, "retry give-up")
            return None
        except KeyError:
            return None
        h.observe(self.clock() - t0)
        return share

    def _observe_late(self, phys: int, t0: float, fut) -> None:
        # a hedged-past fetch that eventually lands still teaches the
        # latency model how slow the node really is
        if fut.exception() is None:
            self.health(phys).observe(self.clock() - t0)

    @staticmethod
    def _crc_ok(stat: ObjectStat, t: int, share: list) -> bool:
        if stat.share_crcs is None:
            return True
        return share_crc(share[1], share[2]) == \
            stat.share_crcs[t][share[0] - 1]

    def _serve_generic(self, key: str, tickets: list) -> None:
        """Serve a non-default-code-class key through the store's
        family-generic read path (systematic reuse + grouped decode),
        resolving tickets with a receipt built from the GetResult."""
        try:
            res = self.store.get_ext(key)
        except (KeyError, RuntimeError) as e:
            self.store.metrics.record_read("failed", 0.0, 0)
            self._fail_tickets(tickets, e)
            return
        for tk in tickets:
            wall = self.clock() - tk.submitted_t
            met = wall <= tk.deadline_s
            tk.obj = res.obj
            tk.receipt = ReadReceipt(
                key=key, wall_latency_s=wall, deadline_s=tk.deadline_s,
                deadline_met=met, degraded_stripes=res.degraded_stripes,
                coalesced=len(tickets))
            tk.done = True
            self.metrics.served += 1
            self.metrics.wall_latencies.append(wall)
            if not met:
                self.metrics.deadline_misses += 1
        self.metrics.coalesced_requests += len(tickets) - 1
        self.metrics.degraded_stripes += res.degraded_stripes

    def _resolve_key(self, key: str, plan: dict) -> None:
        obj = self.store.materialize(plan["stat"], plan["blocks"])
        tickets = plan["tickets"]
        for tk in tickets:
            wall = self.clock() - tk.submitted_t
            met = wall <= tk.deadline_s
            tk.obj = obj
            tk.receipt = ReadReceipt(
                key=key, wall_latency_s=wall, deadline_s=tk.deadline_s,
                deadline_met=met, degraded_stripes=plan["degraded"],
                hedged_fetches=plan["hedged"],
                crc_rejected=plan["crc_rejected"],
                coalesced=len(tickets),
                decode_dispatches=plan["patterns"],
                avoided_nodes=tuple(sorted(plan["avoided"])))
            tk.done = True
            self.metrics.served += 1
            self.metrics.wall_latencies.append(wall)
            if not met:
                self.metrics.deadline_misses += 1
        self.metrics.coalesced_requests += len(tickets) - 1
        self.metrics.degraded_stripes += plan["degraded"]

    def _fail_tickets(self, tickets: list[ReadTicket],
                      err: BaseException) -> None:
        for tk in tickets:
            tk.error = err
            tk.done = True
            tk.receipt = ReadReceipt(key=tk.key,
                                     wall_latency_s=self.clock()
                                     - tk.submitted_t,
                                     deadline_s=tk.deadline_s,
                                     deadline_met=False)
            self.metrics.failed += 1

    # ------------------------------------------------------------ tick loop
    def tick(self, repair_budget_symbols: Optional[int] = None) -> dict:
        """One serving tick: pump admitted requests, scrub/re-admit
        quarantined nodes, then let the repair scheduler drain one
        bandwidth-throttled tick — foreground serving and background
        repair contend under the same :class:`LinkModel` budget (the
        scheduler's ``repair_bandwidth_fraction`` is repair's slice)."""
        served = self.pump()
        scrubs = self.scrub_quarantined()
        repaired = remaining = 0
        if self.scheduler is not None and self.scheduler.pending():
            rep = self.scheduler.drain(repair_budget_symbols)
            repaired, remaining = rep.repaired_stripes, rep.remaining
        return {"served": len(served), "scrubbed": len(scrubs),
                "repaired_stripes": repaired,
                "repair_remaining": remaining}


__all__ = ["ReadFrontEnd", "ReadTicket", "ReadReceipt", "NodeHealth",
           "FrontEndMetrics", "Overloaded"]
