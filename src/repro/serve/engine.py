"""Serving layer: coded storage reads + batched LLM inference
(DESIGN.md §9).

Two engines live here, layered:

* :class:`CodedReadServer` — degraded-read block serving over an MSR
  cluster.  Every read goes to the block's assigned node when it is up
  (systematic: raw bytes, zero field operations) and *transparently*
  falls back to a one-matmul any-k decode through the fused repair
  engine's cached inverses when assigned nodes are down, slow, or lost.
  The node state, latency model and byte accounting come from
  `repro.cluster.ClusterSimulator`, so a serving workload and a failure
  scenario compose directly (see ``examples/serve_demo.py``).

* :class:`ServingEngine` — prefill + KV-cache decode with a simple
  continuous-batching request queue (admit-on-slot-free).  The decode
  step is the same `serve_step` the dry-run lowers at production shapes;
  here it runs jit'd at host scale for the examples/tests.  Its
  parameters can be materialized straight out of a :class:`CodedReadServer`
  (:meth:`ServingEngine.from_coded_store`) — the kill-nodes-while-serving
  path the demo exercises end to end.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import Model


# --------------------------------------------------------------- coded reads
class CodedReadServer:
    """Degraded-read serving facade over a cluster simulator.

    Parameters
    ----------
    sim : repro.cluster.ClusterSimulator
        Owns node state, the encoded bytes, the latency model and the
        metrics log.  Reads issued here and scenario events run through
        ``sim.run`` share one accounting stream.
    treedef, tspec : optional
        When the stored object is a pytree (`placement.pytree_to_blocks`),
        these let :meth:`read_state` rebuild it.

    Notes
    -----
    The degraded path is exactly the paper's any-k data-collector decode,
    but served one *row* at a time: block a_j is ``inv[j] @ downloads``
    with the (n, n) inverse LRU-cached per node subset, so an outage's
    worth of degraded reads costs one `gf.gauss_inverse` total.

    Every degraded decode dispatches through the execution-plan layer
    (DESIGN.md §11): shape-bucketed AOT executables, so a serving fleet
    reading objects of arbitrarily mixed sizes performs zero XLA
    recompiles at steady state — :meth:`plan_stats` is the live counter
    an operator watches for that guarantee.
    """

    def __init__(self, sim, treedef=None, tspec=None):
        self.sim = sim
        self.treedef = treedef
        self.tspec = tspec
        self._clock = 0.0

    def plan_stats(self):
        """Hits/misses/compiles of the code's execution-plan cache —
        steady-state serving must show a frozen ``compiles`` count."""
        from repro.exec.plan import PlanStats
        planner = self.sim.code.planner
        if planner is None:
            return PlanStats(0, 0, 0)
        return planner.plan_stats()

    @classmethod
    def for_pytree(cls, state: Any, spec, **sim_kwargs) -> "CodedReadServer":
        """Encode a pytree across the cluster and serve reads of it.

        Serializes ``state`` into the code's n data blocks
        (`placement.pytree_to_blocks`), builds a fresh
        `ClusterSimulator` holding the encoded bytes, and returns the
        server wired for :meth:`read_state`.
        """
        from repro.cluster.simulator import ClusterSimulator
        from repro.core import placement
        blocks, treedef, tspec = placement.pytree_to_blocks(
            state, spec.n, spec.p)
        sim = ClusterSimulator(spec, blocks, **sim_kwargs)
        return cls(sim, treedef=treedef, tspec=tspec)

    def _tick(self) -> float:
        self._clock += 1.0
        return self._clock

    def read_block(self, block: int) -> Optional[np.ndarray]:
        """One data block, systematic or transparently degraded;
        None only when fewer than k nodes are up."""
        return self.sim.read_block(block, self._tick())

    def read_blocks(self) -> Optional[np.ndarray]:
        """The full (n, S) data matrix — systematic rows where owners are
        up, ONE decode matmul for everything else."""
        return self.sim.read_all(self._tick())

    def read_state(self) -> Any:
        """Rebuild the stored pytree (requires ``for_pytree``), whatever
        the current node state — raises only below k survivors."""
        if self.treedef is None or self.tspec is None:
            raise RuntimeError("server was not built with for_pytree()")
        blocks = self.read_blocks()
        if blocks is None:
            raise RuntimeError(
                f"unrecoverable: fewer than k={self.sim.k} nodes up")
        from repro.core import placement
        return placement.blocks_to_pytree(blocks, self.treedef, self.tspec)

    @property
    def metrics(self):
        return self.sim.metrics


def _read_coded_params(store, key: Optional[str]):
    """One param-materialization path for both storage layers: a coded
    object store (``key`` names the pytree object) or a CodedReadServer
    (``key=None``, the single-stripe cluster read)."""
    if key is not None:
        return store.get_pytree(key)
    return store.read_state()


# ------------------------------------------------------------- LLM serving
@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray            # (s,) int32
    max_new_tokens: int
    out_tokens: list = dataclasses.field(default_factory=list)
    done: bool = False


class ServingEngine:
    """Batched prefill/decode engine with continuous batching.

    Parameters
    ----------
    model : Model
        The architecture to serve.
    params : pytree
        Model parameters (materialize them from coded storage with
        :meth:`from_coded_store`).
    batch_size : int
        Concurrent decode slots.
    max_len : int
        KV-cache capacity; prompts + new tokens must fit.
    temperature : float
        0 = greedy argmax; otherwise categorical sampling.
    """

    def __init__(self, model: Model, params, *, batch_size: int, max_len: int,
                 temperature: float = 0.0, seed: int = 0):
        self.model = model
        self.params = params
        self.batch_size = batch_size
        self.max_len = max_len
        self.temperature = temperature
        self.key = jax.random.PRNGKey(seed)
        self._decode = jax.jit(
            lambda p, c, t, pos: model.decode_step(p, c, t, pos,
                                                   max_len=max_len))
        self._prefill = jax.jit(
            lambda p, b: model.prefill(p, b, max_len=max_len, q_chunk=None))

    @classmethod
    def from_coded_store(cls, model: Model, store, *, key: Optional[str] = None,
                         **engine_kwargs) -> "ServingEngine":
        """Materialize parameters out of MSR-coded storage and serve.

        ``store`` is either a :class:`CodedReadServer` (single-stripe
        cluster; ``key`` omitted) or a `repro.store.CodedObjectStore`
        holding the parameters as a pytree object under ``key``
        (``put_pytree``, DESIGN.md §10.4).  Either way the read is
        systematic when the storage is healthy and falls back to the
        one-matmul degraded decode for whatever is missing — the engine
        itself cannot tell the difference (bit-exact either way)."""
        return cls(model, _read_coded_params(store, key), **engine_kwargs)

    def reload_params(self, store, *, key: Optional[str] = None) -> None:
        """Re-read parameters from coded storage in place (e.g. after the
        cluster repaired a failed node, or to pick up a new checkpoint).
        Accepts the same ``store``/``key`` pairs as
        :meth:`from_coded_store`."""
        self.params = _read_coded_params(store, key)

    # ----------------------------------------------------------- one batch
    def generate(self, prompts: np.ndarray, max_new_tokens: int,
                 stop_token: Optional[int] = None) -> np.ndarray:
        """prompts: (b, s) int32, same length (padded upstream).
        Returns (b, max_new_tokens) int32."""
        b, s = prompts.shape
        assert s + max_new_tokens <= self.max_len, "exceeds cache capacity"
        logits, cache = self._prefill(self.params, {"tokens": jnp.asarray(prompts)})
        out = np.zeros((b, max_new_tokens), np.int32)
        tok = self._sample(logits)
        for t in range(max_new_tokens):
            out[:, t] = np.asarray(tok[:, 0])
            logits, cache = self._decode(self.params, cache, tok,
                                         jnp.asarray(s + t, jnp.int32))
            tok = self._sample(logits)
        return out

    def _sample(self, logits: jnp.ndarray) -> jnp.ndarray:
        logits = logits[:, -1, :]
        if self.temperature <= 0.0:
            return jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        self.key, sub = jax.random.split(self.key)
        return jax.random.categorical(
            sub, logits / self.temperature)[:, None].astype(jnp.int32)

    # ------------------------------------------------- continuous batching
    def serve(self, requests: list[Request], prompt_len: int) -> list[Request]:
        """Round-based continuous batching: up to `batch_size` active slots;
        a finished request's slot is refilled from the queue at the next
        prefill round.  Prompts are right-aligned/padded to prompt_len."""
        queue = list(requests)
        done: list[Request] = []
        while queue:
            active = queue[: self.batch_size]
            queue = queue[self.batch_size:]
            prompts = np.zeros((len(active), prompt_len), np.int32)
            for i, r in enumerate(active):
                p = r.prompt[-prompt_len:]
                prompts[i, prompt_len - len(p):] = p
            steps = max(r.max_new_tokens for r in active)
            outs = self.generate(prompts, steps)
            for i, r in enumerate(active):
                r.out_tokens = outs[i, : r.max_new_tokens].tolist()
                r.done = True
                done.append(r)
        return done


__all__ = ["CodedReadServer", "Request", "ServingEngine"]
