"""Batched serving engine: prefill + decode with KV caches and a simple
continuous-batching request queue (admit-on-slot-free).

The decode step is the same `serve_step` the dry-run lowers at production
shapes; here it runs jit'd at host scale for the examples/tests.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import Model


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray            # (s,) int32
    max_new_tokens: int
    out_tokens: list = dataclasses.field(default_factory=list)
    done: bool = False


class ServingEngine:
    def __init__(self, model: Model, params, *, batch_size: int, max_len: int,
                 temperature: float = 0.0, seed: int = 0):
        self.model = model
        self.params = params
        self.batch_size = batch_size
        self.max_len = max_len
        self.temperature = temperature
        self.key = jax.random.PRNGKey(seed)
        self._decode = jax.jit(
            lambda p, c, t, pos: model.decode_step(p, c, t, pos,
                                                   max_len=max_len))
        self._prefill = jax.jit(
            lambda p, b: model.prefill(p, b, max_len=max_len, q_chunk=None))

    # ----------------------------------------------------------- one batch
    def generate(self, prompts: np.ndarray, max_new_tokens: int,
                 stop_token: Optional[int] = None) -> np.ndarray:
        """prompts: (b, s) int32, same length (padded upstream).
        Returns (b, max_new_tokens) int32."""
        b, s = prompts.shape
        assert s + max_new_tokens <= self.max_len, "exceeds cache capacity"
        logits, cache = self._prefill(self.params, {"tokens": jnp.asarray(prompts)})
        out = np.zeros((b, max_new_tokens), np.int32)
        tok = self._sample(logits)
        for t in range(max_new_tokens):
            out[:, t] = np.asarray(tok[:, 0])
            logits, cache = self._decode(self.params, cache, tok,
                                         jnp.asarray(s + t, jnp.int32))
            tok = self._sample(logits)
        return out

    def _sample(self, logits: jnp.ndarray) -> jnp.ndarray:
        logits = logits[:, -1, :]
        if self.temperature <= 0.0:
            return jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        self.key, sub = jax.random.split(self.key)
        return jax.random.categorical(
            sub, logits / self.temperature)[:, None].astype(jnp.int32)

    # ------------------------------------------------- continuous batching
    def serve(self, requests: list[Request], prompt_len: int) -> list[Request]:
        """Round-based continuous batching: up to `batch_size` active slots;
        a finished request's slot is refilled from the queue at the next
        prefill round.  Prompts are right-aligned/padded to prompt_len."""
        queue = list(requests)
        done: list[Request] = []
        while queue:
            active = queue[: self.batch_size]
            queue = queue[self.batch_size:]
            prompts = np.zeros((len(active), prompt_len), np.int32)
            for i, r in enumerate(active):
                p = r.prompt[-prompt_len:]
                prompts[i, prompt_len - len(p):] = p
            steps = max(r.max_new_tokens for r in active)
            outs = self.generate(prompts, steps)
            for i, r in enumerate(active):
                r.out_tokens = outs[i, : r.max_new_tokens].tolist()
                r.done = True
                done.append(r)
        return done
