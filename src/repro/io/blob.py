"""Blob backend: the byte-level I/O seam beneath the durability layer
(DESIGN.md §12.1).

Every file the checkpointer touches goes through a :class:`BlobBackend`
— writes, reads, renames, directory listings.  The seam exists so the
drill harness can wrap it (`repro.io.faults.FaultyBlob`) and inject
torn writes, corrupt or partial reads, transient ``OSError``s and
per-node latency WITHOUT monkeypatching numpy or the filesystem; the
production implementation (:class:`LocalBlob`) is a thin, fsync-honest
local-filesystem backend.

Durability contract of :class:`LocalBlob`:

* :meth:`write` is *full-or-raise at the API level* but NOT atomic on
  disk — a crash (or an injected torn write) can leave a prefix.  The
  commit protocols one layer up (`MSRCheckpointer.save`'s
  stage-directory rename, the ``*.tmp`` + :meth:`rename` single-file
  protocol) are what make torn bytes unreachable;
* every write is fsync'd before returning, so a completed ``rename``
  publishes bytes that are actually on the platter;
* :meth:`fsync_dir` flushes directory entries (the rename itself).
"""
from __future__ import annotations

import os
import pathlib
import shutil
from typing import Union

PathLike = Union[str, os.PathLike]


class BlobBackend:
    """Abstract byte-level storage backend (the fault-injection seam)."""

    def write(self, path: PathLike, data: bytes) -> None:
        raise NotImplementedError

    def read(self, path: PathLike) -> bytes:
        raise NotImplementedError

    def exists(self, path: PathLike) -> bool:
        raise NotImplementedError

    def isdir(self, path: PathLike) -> bool:
        raise NotImplementedError

    def listdir(self, path: PathLike) -> list[str]:
        raise NotImplementedError

    def mkdir(self, path: PathLike) -> None:
        raise NotImplementedError

    def rename(self, src: PathLike, dst: PathLike) -> None:
        raise NotImplementedError

    def remove(self, path: PathLike) -> None:
        raise NotImplementedError

    def rmtree(self, path: PathLike) -> None:
        raise NotImplementedError

    def fsync_dir(self, path: PathLike) -> None:
        raise NotImplementedError


class LocalBlob(BlobBackend):
    """Local filesystem backend with fsync'd writes.

    Parameters
    ----------
    fsync : bool
        Flush file contents to stable storage on every :meth:`write`
        (and directory entries on :meth:`fsync_dir`).  Default True —
        the commit protocol's rename barrier is only meaningful if the
        bytes it publishes are durable.  Turn off for throwaway test
        dirs where wall time matters more than crash safety.
    """

    def __init__(self, *, fsync: bool = True):
        self.fsync = fsync

    def write(self, path: PathLike, data: bytes) -> None:
        with open(path, "wb") as f:
            f.write(data)
            if self.fsync:
                f.flush()
                os.fsync(f.fileno())

    def read(self, path: PathLike) -> bytes:
        with open(path, "rb") as f:
            return f.read()

    def exists(self, path: PathLike) -> bool:
        return os.path.exists(path)

    def isdir(self, path: PathLike) -> bool:
        return os.path.isdir(path)

    def listdir(self, path: PathLike) -> list[str]:
        return sorted(os.listdir(path))

    def mkdir(self, path: PathLike) -> None:
        os.makedirs(path, exist_ok=True)

    def rename(self, src: PathLike, dst: PathLike) -> None:
        os.rename(src, dst)

    def remove(self, path: PathLike) -> None:
        os.remove(path)

    def rmtree(self, path: PathLike) -> None:
        shutil.rmtree(path)

    def fsync_dir(self, path: PathLike) -> None:
        if not self.fsync:
            return
        fd = os.open(path, os.O_RDONLY)
        try:
            os.fsync(fd)
        finally:
            os.close(fd)


def count_tmp_orphans(root: PathLike) -> int:
    """Uncommitted ``*.tmp`` entries under ``root`` (one level deep plus
    inside committed step directories) — the drill harness's
    zero-orphans assertion after recovery."""
    root = pathlib.Path(root)
    if not root.exists():
        return 0
    n = 0
    for entry in root.iterdir():
        if entry.name.endswith(".tmp"):
            n += 1
        elif entry.is_dir():
            n += sum(1 for f in entry.iterdir() if f.name.endswith(".tmp"))
    return n


__all__ = ["BlobBackend", "LocalBlob", "count_tmp_orphans", "PathLike"]
