"""Bounded-retry policy with exponential backoff and deterministic
jitter (DESIGN.md §12.3).

Every share read/write the durability layer performs is wrapped in a
:class:`RetryPolicy` call: transient failures (``OSError`` and friends
— the class a flaky disk, NFS hiccup or injected fault raises) are
retried up to ``max_attempts`` times under a per-op wall-clock budget
``op_timeout_s``; persistent failures surface as ONE typed
:class:`GiveUpError` carrying the op name, attempt count and the last
underlying exception as ``__cause__`` — callers never see a raw
``OSError`` escape a retried path without the policy having given up
on it first.

Jitter is *deterministic*: the delay for attempt ``a`` of op ``o`` is
``base * multiplier**a`` scaled by a factor in ``[1-jitter, 1+jitter]``
derived from ``crc32(f"{o}|{a}")`` — two runs with the same fault seed
take identical backoff paths, which is what makes the drill harness's
retry-amplification numbers reproducible.

:class:`RetryStats` is the shared accounting object: ops, attempts,
retries, give-ups, and the headline ``amplification`` ratio
(attempts / ops) `BENCH_drills.json` reports per injected fault rate.
"""
from __future__ import annotations

import dataclasses
import threading
import time
import zlib
from typing import Callable, Optional

# Error classes a retry may heal: I/O-flavoured and timing-flavoured.
# Everything else (ValueError from a corrupt decode, KeyError, ...) is a
# logic error and propagates on the first attempt.
TRANSIENT_ERRORS: tuple[type, ...] = (OSError, TimeoutError)


class GiveUpError(RuntimeError):
    """A retried op exhausted its attempt/time budget (typed give-up).

    ``__cause__`` is the last underlying exception; ``op``/``attempts``/
    ``elapsed_s`` say what was tried and for how long.  Deliberately NOT
    an ``OSError`` subclass, so an outer retry layer never re-retries a
    give-up.
    """

    def __init__(self, op: str, attempts: int, elapsed_s: float,
                 last: BaseException):
        super().__init__(f"gave up on {op!r} after {attempts} attempt(s) "
                         f"in {elapsed_s:.3f}s: {last!r}")
        self.op = op
        self.attempts = attempts
        self.elapsed_s = elapsed_s


class RetryStats:
    """Thread-safe retry accounting shared across a component's ops."""

    def __init__(self):
        self._lock = threading.Lock()
        self.ops = 0          # logical operations (calls to RetryPolicy.call)
        self.attempts = 0     # total attempts including retries
        self.retries = 0      # attempts beyond each op's first
        self.giveups = 0

    def record(self, attempts: int, gave_up: bool) -> None:
        with self._lock:
            self.ops += 1
            self.attempts += attempts
            self.retries += attempts - 1
            self.giveups += int(gave_up)

    @property
    def amplification(self) -> float:
        """attempts / ops — 1.0 means no retry ever fired."""
        return self.attempts / self.ops if self.ops else 1.0

    def summary(self) -> dict:
        return {"ops": self.ops, "attempts": self.attempts,
                "retries": self.retries, "giveups": self.giveups,
                "amplification": round(self.amplification, 4)}


@dataclasses.dataclass
class RetryPolicy:
    """Bounded retries + exponential backoff + deterministic jitter.

    Parameters
    ----------
    max_attempts : int
        Total tries per op (1 = no retry).
    base_delay_s, multiplier, max_delay_s : float
        Backoff curve: attempt ``a`` waits
        ``min(base * multiplier**a, max_delay)`` (jittered) before
        retrying.
    jitter : float
        Fractional jitter width; the deterministic factor lands in
        ``[1-jitter, 1+jitter]``.
    op_timeout_s : float
        Wall-clock budget per op ACROSS attempts: when elapsed time plus
        the next backoff would exceed it, the policy gives up early.
    retryable : tuple of exception types
        What counts as transient (default :data:`TRANSIENT_ERRORS`).
    sleep, clock : callables
        Injectable for tests and drills (``sleep=lambda s: None`` makes
        backoff schedules free to simulate).
    """

    max_attempts: int = 4
    base_delay_s: float = 0.01
    multiplier: float = 2.0
    max_delay_s: float = 1.0
    jitter: float = 0.5
    op_timeout_s: float = 30.0
    retryable: tuple = TRANSIENT_ERRORS
    sleep: Callable[[float], None] = time.sleep
    clock: Callable[[], float] = time.monotonic

    def __post_init__(self):
        if self.max_attempts < 1:
            raise ValueError("max_attempts must be >= 1")
        if not 0.0 <= self.jitter <= 1.0:
            raise ValueError("jitter must be in [0, 1]")
        if self.op_timeout_s <= 0:
            raise ValueError("op_timeout_s must be positive")

    def delay_s(self, op: str, attempt: int) -> float:
        """The (deterministic) backoff before retry number ``attempt``."""
        d = min(self.base_delay_s * self.multiplier ** attempt,
                self.max_delay_s)
        h = zlib.crc32(f"{op}|{attempt}".encode()) / 0xFFFFFFFF
        return d * (1.0 - self.jitter + 2.0 * self.jitter * h)

    def call(self, fn: Callable[[], object], *, op: str = "io",
             stats: Optional[RetryStats] = None,
             budget_s: Optional[float] = None):
        """Run ``fn()`` under the policy; returns its value or raises
        :class:`GiveUpError` once the attempt/time budget is spent.

        ``budget_s`` caps the wall-clock budget below ``op_timeout_s``
        for this one call — deadline propagation (DESIGN.md §13.1): a
        serving request's remaining deadline bounds how long any of its
        share fetches may keep retrying.  The first attempt always
        runs, even on an exhausted budget, so a zero budget degrades to
        try-once rather than fail-without-trying."""
        limit = self.op_timeout_s if budget_s is None \
            else min(self.op_timeout_s, max(0.0, budget_s))
        t0 = self.clock()
        last: Optional[BaseException] = None
        attempts = 0
        while True:
            attempts += 1
            try:
                out = fn()
            except self.retryable as e:
                last = e
            else:
                if stats is not None:
                    stats.record(attempts, gave_up=False)
                return out
            elapsed = self.clock() - t0
            if attempts >= self.max_attempts or elapsed >= limit:
                break
            d = self.delay_s(op, attempts - 1)
            if elapsed + d > limit:
                break
            self.sleep(d)
        if stats is not None:
            stats.record(attempts, gave_up=True)
        raise GiveUpError(op, attempts, self.clock() - t0, last) from last


def fast_retry(**overrides) -> RetryPolicy:
    """A RetryPolicy whose backoff sleeps are no-ops — drills and tests
    exercise the full retry/give-up logic without wall-clock cost."""
    kw = dict(max_attempts=4, base_delay_s=0.001, sleep=lambda _s: None)
    kw.update(overrides)
    return RetryPolicy(**kw)


__all__ = ["RetryPolicy", "RetryStats", "GiveUpError", "TRANSIENT_ERRORS",
           "fast_retry"]
