"""Fault-injectable I/O substrate: blob backends, retry policy, fault
injection (DESIGN.md §12).

The durability seam beneath `repro.checkpoint.MSRCheckpointer` and
`repro.store.CodedObjectStore`: every byte they persist flows through a
:class:`BlobBackend` (or the store's share-op guard) wrapped in a
:class:`RetryPolicy`, so the drill harness (`repro.cluster.drills`) can
inject torn writes, corrupt/partial reads, transient ``OSError``s and
per-node latency and assert the system recovers bit-exactly.
"""
from .blob import BlobBackend, LocalBlob, count_tmp_orphans
from .faults import FaultInjector, FaultSpec, FaultyBlob
from .retry import (TRANSIENT_ERRORS, GiveUpError, RetryPolicy, RetryStats,
                    fast_retry)

__all__ = [
    "BlobBackend", "LocalBlob", "count_tmp_orphans",
    "FaultInjector", "FaultSpec", "FaultyBlob",
    "RetryPolicy", "RetryStats", "GiveUpError", "TRANSIENT_ERRORS",
    "fast_retry",
]
