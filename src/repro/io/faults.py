"""Fault injection for the I/O substrate (DESIGN.md §12.4).

A :class:`FaultInjector` holds an ordered list of :class:`FaultSpec`
rules and a seeded RNG; seams consult it per operation:

* :class:`FaultyBlob` wraps any `repro.io.blob.BlobBackend` and maps
  matched rules onto byte-level damage — **torn** writes (a prefix
  lands, then ``OSError``), **corrupt** reads/writes (a flipped byte),
  **transient** ``OSError``s, and per-path **latency**;
* the coded object store consults :meth:`FaultInjector.apply` around
  its share reads/writes with refs like ``node:03``, so per-node
  transient failures and latency inject without a filesystem in the
  loop.

Rules fire deterministically given the seed: probability draws consume
the injector RNG only for rules that are otherwise eligible, and
``times`` caps how often a rule fires — ``times=1`` is "exactly one
torn write, then the disk behaves", the retry-heals-a-torn-write drill.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Callable, Optional

import numpy as np

from .blob import BlobBackend, PathLike


@dataclasses.dataclass
class FaultSpec:
    """One injection rule.

    op : "write" | "read" | "rename" | "*"
    match : substring of the operation ref (a path string or a store
        ``node:NN`` ref); "" matches everything.
    kind : "transient" (raise OSError) | "torn" (write/read a prefix)
         | "corrupt" (flip a byte) | "latency" (sleep, then proceed).
    times : fire at most this many times (None = unlimited).
    prob : per-eligible-op firing probability (seeded, deterministic).
    latency_s, torn_fraction : kind parameters.
    """
    op: str = "*"
    match: str = ""
    kind: str = "transient"
    times: Optional[int] = None
    prob: float = 1.0
    latency_s: float = 0.0
    torn_fraction: float = 0.5
    fired: int = 0

    KINDS = ("transient", "torn", "corrupt", "latency")

    def __post_init__(self):
        if self.kind not in self.KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}; "
                             f"one of {self.KINDS}")


class FaultInjector:
    """Seeded, thread-safe rule set the I/O seams consult per op."""

    def __init__(self, seed: int = 0, *,
                 sleep: Callable[[float], None] = time.sleep):
        self._rng = np.random.default_rng(seed)
        self._sleep = sleep
        self._lock = threading.Lock()
        self.specs: list[FaultSpec] = []
        self.fired_total = 0

    def add(self, **kw) -> FaultSpec:
        spec = FaultSpec(**kw)
        self.specs.append(spec)
        return spec

    def clear(self) -> None:
        self.specs = []

    def match(self, op: str, ref: PathLike) -> Optional[FaultSpec]:
        """First eligible rule that fires for (op, ref), or None.  The
        probability draw is consumed only for eligible rules, so a run's
        fault sequence depends only on the seed and the op stream."""
        ref = str(ref)
        with self._lock:
            for spec in self.specs:
                if spec.op not in ("*", op):
                    continue
                if spec.match and spec.match not in ref:
                    continue
                if spec.times is not None and spec.fired >= spec.times:
                    continue
                if spec.prob < 1.0 and self._rng.random() >= spec.prob:
                    continue
                spec.fired += 1
                self.fired_total += 1
                return spec
        return None

    def apply(self, op: str, ref: PathLike) -> None:
        """Payload-free seam (store share ops): latency sleeps, anything
        else raises a transient ``OSError`` — torn/corrupt need a byte
        payload and only exist on the blob seam."""
        spec = self.match(op, ref)
        if spec is None:
            return
        if spec.kind == "latency":
            self._sleep(spec.latency_s)
            return
        raise OSError(f"injected {spec.kind} fault: {op} {ref}")

    def apply_share(self, op: str, ref: PathLike, share: list) -> list:
        """Share-payload seam (the store's read path, DESIGN.md §13.2):
        ``latency`` sleeps then returns the share untouched, ``corrupt``
        returns a DAMAGED COPY — one data symbol xor-flipped, backing
        storage intact — the read-path bit-rot an end-to-end checksum
        must catch, and anything else raises a transient ``OSError``.
        The caller's stored share list is never mutated."""
        spec = self.match(op, ref)
        if spec is None:
            return share
        if spec.kind == "latency":
            self._sleep(spec.latency_s)
            return share
        if spec.kind == "corrupt":
            node, a, r = share
            a = np.array(a, dtype=np.int32, copy=True)
            if a.size:
                with self._lock:
                    i = int(self._rng.integers(a.size))
                a[i] ^= 0x55        # stays < 256: still a valid data symbol
            return [node, a, r]
        raise OSError(f"injected {spec.kind} fault: {op} {ref}")


def _flip_byte(data: bytes, rng: np.random.Generator) -> bytes:
    if not data:
        return data
    i = int(rng.integers(len(data)))
    out = bytearray(data)
    out[i] ^= 0xFF
    return bytes(out)


class FaultyBlob(BlobBackend):
    """A BlobBackend wrapper that injects the matched damage.

    Write kinds: ``transient`` raises before any byte lands; ``torn``
    writes ``torn_fraction`` of the payload through the inner backend
    and THEN raises (the crash-mid-write shape the commit protocol must
    mask); ``corrupt`` silently writes a flipped byte; ``latency``
    sleeps then proceeds.  Read kinds mirror: torn returns a prefix,
    corrupt flips a byte in what was read.  ``rename``/``remove``/
    ``rmtree``/``mkdir`` support transient + latency via
    :meth:`FaultInjector.apply` (ref = destination path), so drills can
    kill the commit rename itself.
    """

    def __init__(self, inner: BlobBackend, faults: FaultInjector):
        self.inner = inner
        self.faults = faults
        self._rng = np.random.default_rng(0xC0FFEE)

    # ------------------------------------------------------------- payload ops
    def write(self, path: PathLike, data: bytes) -> None:
        spec = self.faults.match("write", path)
        if spec is not None:
            if spec.kind == "latency":
                self.faults._sleep(spec.latency_s)
            elif spec.kind == "transient":
                raise OSError(f"injected transient write fault: {path}")
            elif spec.kind == "torn":
                cut = int(len(data) * spec.torn_fraction)
                self.inner.write(path, data[:cut])
                raise OSError(f"injected torn write ({cut}/{len(data)} "
                              f"bytes): {path}")
            elif spec.kind == "corrupt":
                data = _flip_byte(data, self._rng)
        self.inner.write(path, data)

    def read(self, path: PathLike) -> bytes:
        spec = self.faults.match("read", path)
        if spec is not None:
            if spec.kind == "latency":
                self.faults._sleep(spec.latency_s)
            elif spec.kind == "transient":
                raise OSError(f"injected transient read fault: {path}")
            elif spec.kind == "torn":
                data = self.inner.read(path)
                return data[: int(len(data) * spec.torn_fraction)]
            elif spec.kind == "corrupt":
                return _flip_byte(self.inner.read(path), self._rng)
        return self.inner.read(path)

    # ---------------------------------------------------------- metadata ops
    def exists(self, path: PathLike) -> bool:
        return self.inner.exists(path)

    def isdir(self, path: PathLike) -> bool:
        return self.inner.isdir(path)

    def listdir(self, path: PathLike) -> list[str]:
        return self.inner.listdir(path)

    def mkdir(self, path: PathLike) -> None:
        self.faults.apply("mkdir", path)
        self.inner.mkdir(path)

    def rename(self, src: PathLike, dst: PathLike) -> None:
        self.faults.apply("rename", dst)
        self.inner.rename(src, dst)

    def remove(self, path: PathLike) -> None:
        self.faults.apply("remove", path)
        self.inner.remove(path)

    def rmtree(self, path: PathLike) -> None:
        self.faults.apply("rmtree", path)
        self.inner.rmtree(path)

    def fsync_dir(self, path: PathLike) -> None:
        self.inner.fsync_dir(path)


__all__ = ["FaultSpec", "FaultInjector", "FaultyBlob"]
