"""The paper's double-circulant MSR code behind the generic
:class:`~repro.codes.base.ErasureCode` interface (DESIGN.md §15.1).

A thin adapter over the existing `core.msr.DoubleCirculantMSR` /
`core.repair.RepairEngine` pair — every operation delegates to the
same planned kernels, cached inverses and node-invariant repair matrix
the pre-registry store used, with the SAME plan keys (untagged) and the
same ``[node, a, r]`` share layout, so adopting the interface changes
neither bytes on "disk" nor compile counts:

* q = 2 blocks per share (a_{j-1}, r_j); D = n payload blocks;
* ``helper_block_ids`` keeps the historical block-major download
  stacking [all data rows; all redundancy rows], so ``decode_rows``
  rides the RepairEngine's family-keyed inverse cache unchanged;
* the repair plan is the embedded property: d = k+1 determined helpers
  (prev sends its redundancy block, next k send their data blocks —
  one-hot send matrices, zero helper-side field ops), and the newcomer
  matrix is the node-invariant (2, k+1) fused repair matrix, which is
  what makes this the only family with ``supports_batched_regen()``.
"""
from __future__ import annotations

from typing import Optional, Sequence

import numpy as np

from repro.core.circulant import CodeSpec
from repro.core.msr import DoubleCirculantMSR
from repro.exec.plan import PlanResult

from .base import CodeClass, CodeRepairPlan, ErasureCode
from .registry import FAMILY_DOUBLE_CIRCULANT, register_family


@register_family(FAMILY_DOUBLE_CIRCULANT)
class DoubleCirculantCode(ErasureCode):
    """ErasureCode adapter for the [n = 2k, k], d = k+1 paper code.

    Parameters
    ----------
    code_class : CodeClass
        Must satisfy n = 2k, d = k+1 (the family's only shape).
    inner : DoubleCirculantMSR, optional
        Reuse an existing code instance — the store wraps its live
        ``store.code`` so the adapter shares its planner, decode-inverse
        cache and backend selection.
    """

    def __init__(self, code_class: CodeClass, *, backend: Optional[str] = None,
                 mesh=None, inner: Optional[DoubleCirculantMSR] = None):
        if code_class.family != self.family:
            raise ValueError(f"wrong family {code_class.family!r}")
        if code_class.n != 2 * code_class.k or code_class.d != code_class.k + 1:
            raise ValueError(
                f"double-circulant requires n = 2k and d = k+1, got "
                f"n={code_class.n}, k={code_class.k}, d={code_class.d}")
        self.code_class = code_class
        self.n, self.k, self.d, self.p = (code_class.n, code_class.k,
                                          code_class.d, code_class.p)
        if inner is not None and (inner.k, inner.p) != (self.k, self.p):
            raise ValueError(f"inner code (k={inner.k}, p={inner.p}) does "
                             f"not match class {code_class.key()}")
        self.spec = inner.spec if inner is not None else \
            CodeSpec.make(self.k, self.p)
        self.inner = inner if inner is not None else \
            DoubleCirculantMSR(self.spec, backend=backend, mesh=mesh)
        self.backend_name = self.inner.backend_name
        self.mesh = self.inner.mesh
        self.planner = self.inner.planner

    # ------------------------------------------------------------- geometry
    @property
    def share_blocks(self) -> int:
        return 2

    @property
    def data_blocks(self) -> int:
        return self.n

    @property
    def derived_rows(self) -> int:
        return self.n                    # the (n, S) redundancy matrix

    def data_location(self, m: int) -> tuple[int, int]:
        return m + 1, 0                  # node v_{m+1} stores a_m as block 0

    # --------------------------------------------------------------- encode
    def encode_derived_planned(self, flat: np.ndarray) -> PlanResult:
        return self.inner.encode_planned(flat)

    def stripe_share_blocks(self, data: np.ndarray, derived: np.ndarray,
                            node: int) -> list:
        return [data[node - 1], derived[node - 1]]

    # --------------------------------------------------------------- decode
    def helper_block_ids(self, subset: Sequence[int],
                         ) -> list[tuple[int, int]]:
        # historical block-major stacking [a rows; r rows]: the cached
        # RepairEngine inverses expect exactly this download layout
        return [(j, 0) for j in subset] + [(j, 1) for j in subset]

    def decode_rows(self, subset: Sequence[int],
                    rows_needed: Sequence[int]) -> np.ndarray:
        return self.inner.repair.decode_matrix(tuple(subset))[
            list(rows_needed)]

    def share_rows(self, subset: Sequence[int],
                   lost_nodes: Sequence[int]) -> np.ndarray:
        lost = [int(f) for f in lost_nodes]
        mat = self.inner.repair.decode_repair_matrix(tuple(subset), lost)
        rows = []
        for j, f in enumerate(lost):
            rows.append(mat[f - 1])          # data block a_{f-1}
            rows.append(mat[self.n + j])     # re-encoded redundancy r_f
        return np.stack(rows)

    # ----------------------------------------------------------- regenerate
    def repair_plan(self, node: int,
                    available: Optional[Sequence[int]] = None,
                    ) -> Optional[CodeRepairPlan]:
        plan = self.inner.repair_plan(node)
        helpers = (plan.prev_node,) + plan.next_nodes
        if available is not None:
            avail = set(available)
            if any(h not in avail for h in helpers):
                return None              # embedded helpers are DETERMINED
        send_red = np.array([[0, 1]], np.int32)    # prev sends r_{prev}
        send_data = np.array([[1, 0]], np.int32)   # next k send a_{j-1}
        return CodeRepairPlan(
            node=node, helpers=helpers,
            send_matrices=(send_red,) + (send_data,) * self.k,
            blocks_downloaded=self.k + 1)

    def newcomer_matrix(self, plan: CodeRepairPlan) -> np.ndarray:
        # node-invariant (2, k+1) fused repair matrix — valid only for
        # the embedded helper order the plan encodes
        expected = self.repair_plan(plan.node)
        if plan.helpers != expected.helpers:
            raise ValueError(f"double-circulant repair needs the embedded "
                             f"helper order {expected.helpers}, got "
                             f"{plan.helpers}")
        return self.inner.repair.repair_matrix(plan.node)

    def supports_batched_regen(self) -> bool:
        return True

    # ------------------------------------------------------------- dispatch
    def apply_planned(self, mat, blocks) -> PlanResult:
        # untagged: byte-identical plan keys to the pre-registry store
        return self.inner.repair.apply_planned(mat, blocks)

    # ------------------------------------------------------------ integrity
    def share_crc_blocks(self, blocks: Sequence[np.ndarray]) -> int:
        from repro.store.object_store import share_crc  # lazy: no cycle
        return share_crc(blocks[0], blocks[1])


__all__ = ["DoubleCirculantCode"]
