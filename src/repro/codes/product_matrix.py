"""Product-matrix MSR code family (DESIGN.md §15.2).

The Rashmi–Shah–Kumar product-matrix construction (arXiv:1005.4178)
gives an exact-repair MSR code for every ``d >= 2k - 2``; shortening the
parent code supports ``d < n - 1`` (repair from ANY d helpers, not a
fixed embedded set).  Construction, worked symbolically once per class
at build time:

* alpha = d - k + 1 blocks per node, B = k * alpha payload blocks.
* Parent code: shorten by i = d - 2k + 2 symbols — n' = n + i virtual
  nodes, d' = d + i = 2 * alpha.  Node j's share is
  ``w_j^T = psi_j^T M'`` with ``psi_j = (1, g_j, ..., g_j^{2a-1})``
  Vandermonde and ``M' = [[S1], [S2]]`` stacked symmetric alpha x alpha
  matrices (B' = alpha (alpha + 1) free entries).
* Shortening: the i virtual nodes' shares are constrained to zero;
  the admissible messages are ``vec(M') = Sym @ N @ theta`` where N is
  the GF null-space basis of the deleted share map, dim B' - i*alpha
  = k*alpha = B exactly.
* Systematic form: with A the first k nodes' share map restricted to
  the null space, ``G = P_real @ Sym @ N @ A^{-1}`` is the (n*alpha,
  k*alpha) generator whose top k*alpha rows are the identity — nodes
  1..k store the payload verbatim (systematic fast reads + conversion
  share reuse).
* Repair of node f from any d helpers H: each helper sends its share
  projected on ``phi_f`` (the first alpha Vandermonde components of
  psi_f) — a real (1, alpha) helper-side product, unlike the
  double-circulant one-hot sends.  Stacking the d sends with the i
  identically-zero virtual shares yields the invertible (2a, 2a)
  Vandermonde system ``Psi_sys x = [sends; 0]`` for
  ``x = M' phi_f``; by S1/S2 symmetry the lost share is
  ``w_f = [I | lambda_f I] x`` with ``lambda_f = g_f^alpha``, so the
  cached newcomer matrix is ``([I | lambda_f I] Psi_sys^{-1})[:, :d]``.
  gamma = d * S symbols — the MSR cut-set point.
"""
from __future__ import annotations

from typing import Optional, Sequence

import numpy as np

from repro.core import gf
from repro.core.repair import DecodeInverseCache
from repro.exec.plan import PlanResult

from .base import CodeClass, CodeRepairPlan, ErasureCode
from .registry import FAMILY_PRODUCT_MATRIX, register_family


def _pick_generators(count: int, alpha: int, p: int) -> np.ndarray:
    """Greedily pick ``count`` elements g of GF(p)* that are pairwise
    distinct AND have pairwise distinct lambda = g^alpha (alpha-th
    powers collide for composite p - 1, so sequential choice fails;
    greedy scan is exact)."""
    gens: list[int] = []
    lams: set[int] = set()
    for g in range(1, p):
        lam = pow(g, alpha, p)
        if lam in lams:
            continue
        gens.append(g)
        lams.add(lam)
        if len(gens) == count:
            return np.array(gens, dtype=np.int64)
    raise ValueError(f"field GF({p}) too small for {count} product-matrix "
                     f"nodes with distinct {alpha}-th powers")


def _sym_embedding(alpha: int) -> np.ndarray:
    """(2*alpha^2, B') 0/1 map from the B' = alpha*(alpha+1) free
    entries of two symmetric alpha x alpha matrices to the row-major
    flattening of M' = [[S1], [S2]]."""
    b_prime = alpha * (alpha + 1)
    sym = np.zeros((2 * alpha * alpha, b_prime), dtype=np.int64)
    col = 0
    for s in range(2):
        for u in range(alpha):
            for v in range(u, alpha):
                sym[(s * alpha + u) * alpha + v, col] = 1
                if u != v:
                    sym[(s * alpha + v) * alpha + u, col] = 1
                col += 1
    assert col == b_prime
    return sym


@register_family(FAMILY_PRODUCT_MATRIX)
class ProductMatrixMSR(ErasureCode):
    """Product-matrix MSR [n, k, d] over GF(p), 2k - 2 <= d <= n - 1.

    Unlike the double-circulant family this repairs from *any* d
    helpers, trading helper-side field ops and a denser generator for
    placement freedom and the full (n, k, d) grid.
    """

    def __init__(self, code_class: CodeClass, *, backend: Optional[str] = None,
                 mesh=None):
        if code_class.k < 2:
            raise ValueError("product-matrix MSR needs k >= 2")
        if code_class.d < 2 * code_class.k - 2:
            raise ValueError(
                f"product-matrix MSR needs d >= 2k-2, got d={code_class.d} "
                f"for k={code_class.k}")
        super().__init__(code_class, backend=backend, mesh=mesh)
        n, k, d, p = self.n, self.k, self.d, self.p
        self.alpha = alpha = d - k + 1
        shortening = d - 2 * k + 2          # i >= 0
        n_parent = n + shortening
        self.B = k * alpha

        self.gens = _pick_generators(n_parent, alpha, p)
        self.lams = np.array([pow(int(g), alpha, p) for g in self.gens],
                             dtype=np.int64)
        # Psi' rows (1, g, ..., g^{2a-1}); Phi' = first alpha columns
        exps = np.arange(2 * alpha, dtype=np.int64)
        self.psi = np.stack([[pow(int(g), int(e), p) for e in exps]
                             for g in self.gens]).astype(np.int64)

        sym = _sym_embedding(alpha)
        # P: share map from vec(M') to the n' * alpha stacked share rows
        pmat = np.zeros((n_parent * alpha, 2 * alpha * alpha), dtype=np.int64)
        for j in range(n_parent):
            for t in range(alpha):
                for r in range(2 * alpha):
                    pmat[j * alpha + t, r * alpha + t] = self.psi[j, r]
        # shorten: deleted (virtual) nodes' shares must vanish
        constraints = (pmat[n * alpha:] @ sym) % p
        nsp = gf.nullspace(constraints, p).astype(np.int64)
        if nsp.shape[1] != self.B:
            raise AssertionError(
                f"shortening null space has dim {nsp.shape[1]}, "
                f"expected B = {self.B}")
        embed = (sym @ nsp) % p             # vec(M') = embed @ theta
        shares_of_theta = (pmat[:n * alpha] @ embed) % p
        a_mat = shares_of_theta[:self.B]    # first k nodes' shares
        a_inv = gf.gauss_inverse(a_mat, p).astype(np.int64)
        self.G = ((shares_of_theta @ a_inv) % p).astype(np.int64)
        if not np.array_equal(self.G[:self.B],
                              np.eye(self.B, dtype=np.int64)):
            raise AssertionError("generator is not systematic")
        self._g_parity = np.ascontiguousarray(self.G[self.B:])

        self._inverse_cache = DecodeInverseCache(
            maxsize=128, family=self.family_key(),
            matrix_fn=self._subset_matrix, k=k, p=p)
        self._newcomer_cache: dict[tuple, np.ndarray] = {}

    def _subset_matrix(self, subset: tuple[int, ...]) -> np.ndarray:
        """Node-major G rows of a k-subset — square (B, B), invertible
        by the RSK reconstruction theorem."""
        rows = [(j - 1) * self.alpha + t for j in subset
                for t in range(self.alpha)]
        return self.G[rows]

    # ------------------------------------------------------------- geometry
    @property
    def share_blocks(self) -> int:
        return self.alpha

    @property
    def data_blocks(self) -> int:
        return self.B

    @property
    def derived_rows(self) -> int:
        return (self.n - self.k) * self.alpha

    def data_location(self, m: int) -> tuple[int, int]:
        return m // self.alpha + 1, m % self.alpha

    # --------------------------------------------------------------- encode
    def encode_derived_planned(self, flat: np.ndarray) -> PlanResult:
        return self.apply_planned(self._g_parity, flat)

    def stripe_share_blocks(self, data: np.ndarray, derived: np.ndarray,
                            node: int) -> list:
        a = self.alpha
        src = data if node <= self.k else derived
        base = (node - 1) * a if node <= self.k else (node - 1 - self.k) * a
        return [src[base + t] for t in range(a)]

    # --------------------------------------------------------------- decode
    def decode_rows(self, subset: Sequence[int],
                    rows_needed: Sequence[int]) -> np.ndarray:
        inv = self._inverse_cache.inverse(tuple(subset))
        return inv[list(rows_needed)]

    def share_rows(self, subset: Sequence[int],
                   lost_nodes: Sequence[int]) -> np.ndarray:
        inv = self._inverse_cache.inverse(tuple(subset))
        a = self.alpha
        g_rows = np.concatenate([self.G[(f - 1) * a:f * a]
                                 for f in lost_nodes])
        return ((g_rows @ inv.astype(np.int64)) % self.p)

    # ----------------------------------------------------------- regenerate
    def repair_plan(self, node: int,
                    available: Optional[Sequence[int]] = None,
                    ) -> Optional[CodeRepairPlan]:
        pool = (sorted(set(available)) if available is not None
                else [j for j in range(1, self.n + 1) if j != node])
        helpers = tuple(j for j in pool if j != node)[:self.d]
        if len(helpers) < self.d:
            return None                      # any d helpers, but all d
        phi_f = self.psi[node - 1, :self.alpha].reshape(1, -1) % self.p
        send = np.ascontiguousarray(phi_f.astype(np.int32))
        return CodeRepairPlan(node=node, helpers=helpers,
                              send_matrices=(send,) * self.d,
                              blocks_downloaded=self.d)

    def newcomer_matrix(self, plan: CodeRepairPlan) -> np.ndarray:
        key = (plan.node,) + plan.helpers
        hit = self._newcomer_cache.get(key)
        if hit is not None:
            return hit
        if len(set(plan.helpers)) != self.d or plan.node in plan.helpers:
            raise ValueError(f"need {self.d} distinct helpers != node "
                             f"{plan.node}, got {plan.helpers}")
        a, p = self.alpha, self.p
        # d helper rows + i virtual zero-share rows: (2a, 2a) Vandermonde
        rows_idx = [h - 1 for h in plan.helpers] + \
            list(range(self.n, len(self.gens)))
        psi_sys = self.psi[rows_idx] % p
        psi_inv = gf.gauss_inverse(psi_sys, p).astype(np.int64)
        lam_f = int(self.lams[plan.node - 1])
        lift = np.concatenate([np.eye(a, dtype=np.int64),
                               lam_f * np.eye(a, dtype=np.int64)], axis=1)
        r_full = ((lift @ psi_inv) % p)
        mat = np.ascontiguousarray(r_full[:, :self.d]).astype(np.int64)
        if len(self._newcomer_cache) >= 256:
            self._newcomer_cache.clear()
        self._newcomer_cache[key] = mat
        return mat

    def supports_batched_regen(self) -> bool:
        """The newcomer matrix varies per (node, helpers), so the
        shared-matrix ``regenerate_batch`` vmap does not apply — but
        every plan shares the (alpha, d) geometry, so the store
        coalesces PM repairs through the per-element batched
        ``regenerate_many_planned`` dispatch instead (DESIGN.md §16.5).
        """
        return True


__all__ = ["ProductMatrixMSR"]
