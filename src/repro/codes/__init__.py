"""Pluggable code-family subsystem (DESIGN.md §15): the abstract
:class:`ErasureCode` interface, the serializable :class:`CodeClass`
descriptor, and the family registry mapping descriptors to live codes.
"""
from .base import (CodeClass, CodeRepairPlan, ErasureCode,
                   generic_share_crc, is_one_hot)
from .registry import (FAMILY_DOUBLE_CIRCULANT, FAMILY_PRODUCT_MATRIX,
                       default_code_class, families, make_code,
                       register_family)

__all__ = [
    "CodeClass", "CodeRepairPlan", "ErasureCode", "generic_share_crc",
    "is_one_hot", "FAMILY_DOUBLE_CIRCULANT", "FAMILY_PRODUCT_MATRIX",
    "default_code_class", "families", "make_code", "register_family",
]
