"""Code-family registry (DESIGN.md §15.1): family name -> constructor.

``make_code`` turns a serializable :class:`~repro.codes.base.CodeClass`
back into a live :class:`~repro.codes.base.ErasureCode` on a chosen
backend/mesh — the store does this lazily per object, the conversion
path for its target class, and tests for the whole (n, k, d) grid.
"""
from __future__ import annotations

from typing import Callable, Optional

from repro.core.circulant import CodeSpec

from .base import CodeClass, ErasureCode

FAMILY_DOUBLE_CIRCULANT = "double-circulant"
FAMILY_PRODUCT_MATRIX = "product-matrix"

_FAMILIES: dict[str, Callable[..., ErasureCode]] = {}


def register_family(name: str):
    """Class decorator: register an ErasureCode subclass under ``name``
    (re-registration replaces — the test-override seam)."""
    def deco(cls):
        _FAMILIES[name] = cls
        cls.family = name
        return cls
    return deco


def families() -> list[str]:
    """Registered family names, sorted."""
    _load_builtins()
    return sorted(_FAMILIES)


def make_code(code_class: CodeClass, *, backend: Optional[str] = None,
              mesh=None, **kwargs) -> ErasureCode:
    """Build the live code for a descriptor.  Raises ``KeyError`` with
    the known families listed when the family is unregistered."""
    _load_builtins()
    try:
        factory = _FAMILIES[code_class.family]
    except KeyError:
        raise KeyError(f"unknown code family {code_class.family!r}; "
                       f"registered: {sorted(_FAMILIES)}") from None
    return factory(code_class, backend=backend, mesh=mesh, **kwargs)


def default_code_class(spec: CodeSpec) -> CodeClass:
    """The double-circulant class of a legacy CodeSpec — what every
    object stored before per-object classes implicitly used."""
    return CodeClass(family=FAMILY_DOUBLE_CIRCULANT, n=spec.n, k=spec.k,
                     d=spec.k + 1, p=spec.p)


def _load_builtins() -> None:
    """Import the built-in families exactly once (they self-register);
    deferred so ``base``/``registry`` stay import-cycle-free."""
    from . import double_circulant, product_matrix  # noqa: F401


__all__ = ["FAMILY_DOUBLE_CIRCULANT", "FAMILY_PRODUCT_MATRIX",
           "register_family", "families", "make_code", "default_code_class"]
