"""Pluggable code-family subsystem: the abstract ``ErasureCode``
interface every family implements (DESIGN.md §15.1).

A *code class* is the serializable descriptor ``(family, n, k, d, p)``
an object's manifest records; a *code* is the live implementation the
registry (`repro.codes.registry`) builds from it — encode, any-k
reconstruct, bandwidth-optimal regenerate, and the repair-matrix
surface, all running through the same GF dispatch backends, shared
`PlanCache` buckets and `StreamMesh` sharding the double-circulant code
already uses (families inherit AOT plans and multi-device execution for
free).

Share model (DESIGN.md §15.1): node ``v_j`` (1-indexed) stores
``share_blocks`` = q blocks of S symbols each; a stored share is the
list ``[code_node, blk_0, ..., blk_{q-1}]``.  The double-circulant
family keeps its historical ``[node, a, r]`` layout as the q = 2 case.
The object payload is cut into ``data_blocks`` = D systematic blocks
per stripe; ``data_location(m)`` says which share block carries payload
block m, which is what makes systematic fast reads — and conversion's
systematic share reuse — family-generic.

Every family here sits at the MSR point: q = d - k + 1 blocks per node,
D = k * q payload blocks, helpers send beta = 1 block (S symbols) per
repair, so gamma = d * S = d * B / (k (d - k + 1)) symbols — the
cut-set bound the property suite asserts for every registered family.
"""
from __future__ import annotations

import abc
import dataclasses
import zlib
from typing import Optional, Sequence

import numpy as np

from repro.core import gf
from repro.exec.plan import PlanResult, planning_enabled


@dataclasses.dataclass(frozen=True)
class CodeClass:
    """Serializable code-family descriptor: what an object's manifest
    records so every read/repair/convert dispatches through the right
    family (DESIGN.md §15.1).

    >>> cc = CodeClass("double-circulant", n=4, k=2, d=3)
    >>> CodeClass.from_meta(cc.to_meta()) == cc
    True
    """
    family: str
    n: int
    k: int
    d: int
    p: int = gf.DEFAULT_P

    def __post_init__(self):
        if not (1 <= self.k < self.n):
            raise ValueError(f"need 1 <= k < n, got k={self.k}, n={self.n}")
        if not (self.k <= self.d <= self.n - 1):
            raise ValueError(f"need k <= d <= n-1, got d={self.d} "
                             f"(k={self.k}, n={self.n})")

    def key(self) -> str:
        """The family identity string plan tags and decode-cache entries
        are keyed by — distinct for any two inequivalent classes."""
        return f"{self.family}[n{self.n},k{self.k},d{self.d},p{self.p}]"

    def to_meta(self) -> dict:
        return {"family": self.family, "n": self.n, "k": self.k,
                "d": self.d, "p": self.p}

    @classmethod
    def from_meta(cls, meta: dict) -> "CodeClass":
        return cls(family=str(meta["family"]), n=int(meta["n"]),
                   k=int(meta["k"]), d=int(meta["d"]), p=int(meta["p"]))


@dataclasses.dataclass(frozen=True)
class CodeRepairPlan:
    """One node regeneration, reified: which d helpers participate and
    what each sends.

    ``send_matrices[i]`` is the (1, q) GF matrix helper ``helpers[i]``
    applies to its own q stored blocks — the helper-side compute of the
    repair.  One-hot rows mean "send a stored block raw" (the
    double-circulant embedded property); dense rows mean a real
    helper-side projection (product-matrix's Phi_f).  The newcomer
    multiplies the stacked (d, S) sends by the family's
    ``newcomer_matrix`` to rebuild all q lost blocks.
    """
    node: int
    helpers: tuple[int, ...]
    send_matrices: tuple
    blocks_downloaded: int          # d

    @property
    def d(self) -> int:
        return self.blocks_downloaded


def generic_share_crc(blocks: Sequence[np.ndarray]) -> int:
    """CRC32 of one share's logical payload for q-block families: every
    block's ``pack257`` halves chained (any block of a non-systematic
    node can carry the symbol 256, so no raw-uint8 shortcut)."""
    c = 0
    for blk in blocks:
        low, hi = gf.pack257(np.asarray(blk, np.int32))
        c = zlib.crc32(np.ascontiguousarray(low, np.uint8).tobytes(), c)
        c = zlib.crc32(np.ascontiguousarray(hi, np.int64).tobytes(), c)
    return c


def is_one_hot(row: np.ndarray) -> Optional[int]:
    """Index of the single 1 in a (1, q) selector row, or None if the
    row is a real projection — lets the store serve one-hot helper
    sends straight from storage with zero field ops."""
    row = np.asarray(row).reshape(-1)
    nz = np.nonzero(row)[0]
    if len(nz) == 1 and row[nz[0]] == 1:
        return int(nz[0])
    return None


class ErasureCode(abc.ABC):
    """Abstract regenerating code: the encode / reconstruct / regenerate
    / repair-matrix surface the store, scheduler and serving layers
    dispatch through (DESIGN.md §15.1).

    Subclasses are built by the registry from a :class:`CodeClass` and
    must define the share geometry (``share_blocks``, ``data_blocks``,
    ``derived_rows``), the systematic map (``data_location``,
    ``stripe_share_blocks``), the encode kernel
    (``encode_derived_planned``), the any-k decode surface
    (``decode_rows`` / ``share_rows`` with ``helper_block_ids`` fixing
    the download stacking order), and the regeneration surface
    (``repair_plan`` / ``newcomer_matrix``).
    """

    family: str = "abstract"

    def __init__(self, code_class: CodeClass, *, backend: Optional[str] = None,
                 mesh=None):
        if code_class.family != self.family:
            raise ValueError(f"{type(self).__name__} builds family "
                             f"{self.family!r}, got {code_class.family!r}")
        self.code_class = code_class
        self.n, self.k, self.d, self.p = (code_class.n, code_class.k,
                                          code_class.d, code_class.p)
        from repro.kernels import dispatch
        from repro.sharding import mesh as mesh_mod
        be = dispatch.get(backend) if backend else dispatch.select(self.p,
                                                                   self.k)
        self.backend_name = be.name
        self._backend = be
        self.mesh = (mesh_mod.as_stream_mesh(mesh) if mesh is not None
                     else mesh_mod.current_mesh())
        # shared per (backend, p, mesh) — same AOT executable cache the
        # double-circulant code hits (DESIGN.md §11, §14); family tags
        # keep per-family plan keys and stats separable (§15.4)
        self.planner = be.planner(self.p, mesh=self.mesh)

    # ------------------------------------------------------------- identity
    def family_key(self) -> str:
        """Identity string for plan tags / decode-cache keys."""
        return self.code_class.key()

    # ------------------------------------------------------------- geometry
    @property
    @abc.abstractmethod
    def share_blocks(self) -> int:
        """q: stored blocks per node (alpha = q * S symbols)."""

    @property
    @abc.abstractmethod
    def data_blocks(self) -> int:
        """D: systematic payload blocks per stripe (B = D * S symbols)."""

    @property
    @abc.abstractmethod
    def derived_rows(self) -> int:
        """Rows of the planned encode product — the non-systematic block
        rows ``encode_derived_planned`` computes per stripe."""

    @abc.abstractmethod
    def data_location(self, m: int) -> tuple[int, int]:
        """Payload block m (0-based) lives at (code node 1-indexed,
        share block index) — the systematic map."""

    # --------------------------------------------------------------- encode
    @abc.abstractmethod
    def encode_derived_planned(self, flat: np.ndarray) -> PlanResult:
        """(D, T*S) flattened payload blocks -> planned
        (derived_rows, T*S) non-systematic rows, through the shared
        bucketed AOT plan cache (async; ``.host()`` for exact numpy)."""

    @abc.abstractmethod
    def stripe_share_blocks(self, data: np.ndarray, derived: np.ndarray,
                            node: int) -> list:
        """The q blocks node ``node`` stores for one stripe, assembled
        from the (D, S) payload rows and the (derived_rows, S) encode
        product.  Views are acceptable; the store copies on install."""

    def encode_shares(self, data: np.ndarray) -> np.ndarray:
        """(D, S) payload blocks -> (n, q, S) node shares (the
        convenience/verify path; the store streams through
        ``encode_derived_planned`` + ``stripe_share_blocks``)."""
        data = np.asarray(data, np.int32)
        if data.shape[0] != self.data_blocks:
            raise ValueError(f"expected {self.data_blocks} payload blocks, "
                             f"got {data.shape[0]}")
        derived = self.encode_derived_planned(data).host()
        return np.stack([np.stack([np.asarray(b, np.int32) for b in
                                   self.stripe_share_blocks(data, derived, j)])
                         for j in range(1, self.n + 1)])

    # --------------------------------------------------------------- decode
    def helper_block_ids(self, subset: Sequence[int],
                         ) -> list[tuple[int, int]]:
        """Stacking order of the (k*q, S) decode download matrix:
        (code node, share block) per row.  Node-major by default; the
        double-circulant family overrides to its historical block-major
        [all data rows; all redundancy rows] order so the pre-existing
        cached inverses and plan keys are reused bit-identically."""
        return [(j, b) for j in subset for b in range(self.share_blocks)]

    @abc.abstractmethod
    def decode_rows(self, subset: Sequence[int],
                    rows_needed: Sequence[int]) -> np.ndarray:
        """(len(rows_needed), k*q) GF matrix taking the stacked helper
        downloads (``helper_block_ids`` order) to the requested payload
        block rows — rides on the family's cached subset inverse."""

    @abc.abstractmethod
    def share_rows(self, subset: Sequence[int],
                   lost_nodes: Sequence[int]) -> np.ndarray:
        """(len(lost_nodes)*q, k*q) matrix rebuilding EVERY block of
        each lost node from the stacked downloads (multi-loss repair:
        one matmul per stripe, node-major rows)."""

    def reconstruct(self, subset: Sequence[int],
                    downloads: np.ndarray) -> np.ndarray:
        """Any-k reconstruction: (k*q, S) stacked downloads (in
        ``helper_block_ids`` order) -> (D, S) payload blocks."""
        mat = self.decode_rows(tuple(subset), list(range(self.data_blocks)))
        return self.apply_planned(mat, downloads).host()

    # ----------------------------------------------------------- regenerate
    @abc.abstractmethod
    def repair_plan(self, node: int,
                    available: Optional[Sequence[int]] = None,
                    ) -> Optional[CodeRepairPlan]:
        """A d-helper regeneration plan for ``node`` drawn from
        ``available`` (default: all other nodes), or None when the
        family cannot build one from what is available — the caller
        falls back to the k-subset full decode."""

    @abc.abstractmethod
    def newcomer_matrix(self, plan: CodeRepairPlan) -> np.ndarray:
        """(q, d) matrix taking the stacked (d, S) helper sends to the
        lost node's q blocks (cached per (node, helpers) where the
        family is not helper-invariant)."""

    def helper_send(self, send_matrix, blocks: Sequence[np.ndarray],
                    ) -> np.ndarray:
        """One helper's (S,) contribution: its (1, q) send matrix
        applied to its q stored blocks.  One-hot selectors are served
        raw (zero field ops — the embedded property's case)."""
        idx = is_one_hot(send_matrix)
        if idx is not None:
            return np.asarray(blocks[idx], np.int32)
        stack = np.stack([np.asarray(b, np.int64) for b in blocks])
        return ((np.asarray(send_matrix, np.int64) @ stack) % self.p
                ).astype(np.int32)[0]

    def regenerate(self, plan: CodeRepairPlan,
                   sends: np.ndarray) -> np.ndarray:
        """(d, S) stacked helper sends -> the lost node's (q, S) blocks."""
        return self.apply_planned(self.newcomer_matrix(plan), sends).host()

    def regenerate_many_planned(self, plans: Sequence[CodeRepairPlan],
                                sends: np.ndarray) -> PlanResult:
        """F independent single-loss regenerations in ONE batched
        dispatch: the per-plan (q, d) newcomer matrices stack to
        (F, q, d), the (F, d, S) helper sends ride ``matmul_batch``'s
        per-element vmapped matmul (DESIGN.md §16.5).  This is the
        coalescing path for families whose newcomer matrix varies per
        (node, helpers) — ``supports_batched_regen()`` families that
        cannot use the store's shared-matrix ``regenerate_batch``.
        ``host()`` yields (F, q, S) rebuilt shares."""
        sends = np.asarray(sends, np.int32)
        if sends.ndim != 3 or sends.shape[0] != len(plans):
            raise ValueError(f"expected ({len(plans)}, d, S) sends, got "
                             f"{sends.shape}")
        mats = np.stack([np.asarray(self.newcomer_matrix(p), np.int32)
                         for p in plans])
        if self.planner is not None and planning_enabled():
            return self.planner.matmul_batch(mats, sends,
                                             tag=self.family_key())
        out = ((mats.astype(np.int64) @ sends.astype(np.int64))
               % self.p).astype(np.int32)
        return PlanResult(out, sends.shape[-1], batch=len(plans))

    # ------------------------------------------------------------- dispatch
    def apply_planned(self, mat, blocks) -> PlanResult:
        """Family-tagged planned (mat @ blocks) mod p through the shared
        bucketed executable cache; exact eager fallback when planning is
        disabled."""
        if self.planner is not None and planning_enabled():
            return self.planner.matmul(mat, blocks, tag=self.family_key())
        blocks = np.asarray(blocks, np.int32)
        out = ((np.asarray(mat, np.int64) @ blocks.astype(np.int64))
               % self.p).astype(np.int32)
        return PlanResult(out, blocks.shape[-1])

    # ------------------------------------------------------------ integrity
    def share_crc_blocks(self, blocks: Sequence[np.ndarray]) -> int:
        """Put-time CRC of one share's q blocks (the store's integrity
        ledger entry).  Generic pack257 chaining; the double-circulant
        family overrides to its historical (data-uint8, pack257(red))
        formula so existing ledgers stay byte-identical."""
        return generic_share_crc(blocks)

    # ----------------------------------------------------------- accounting
    def alpha_symbols(self, block_symbols: int) -> int:
        """Per-node storage: q * S symbols."""
        return self.share_blocks * block_symbols

    def gamma_regenerate_symbols(self, block_symbols: int) -> int:
        """Repair bandwidth: d * S = d * B / (k (d - k + 1)) — the MSR
        cut-set point every family here sits at."""
        return self.d * block_symbols

    def gamma_reconstruct_symbols(self, block_symbols: int) -> int:
        """Classical-EC-style repair (full k-subset decode): k*q*S = B."""
        return self.k * self.share_blocks * block_symbols

    def storage_overhead(self) -> float:
        """Stored symbols per payload symbol: n*q / D (= n/k at MSR)."""
        return self.n * self.share_blocks / self.data_blocks

    def supports_batched_regen(self) -> bool:
        """True when the store may coalesce this family's single-loss
        repairs into vmapped ``regenerate_batch`` dispatches (the
        node-invariant repair-matrix case)."""
        return False


__all__ = ["CodeClass", "CodeRepairPlan", "ErasureCode",
           "generic_share_crc", "is_one_hot"]
