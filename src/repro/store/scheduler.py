"""Prioritized background repair scheduler (DESIGN.md §10.3).

The scheduler sits between a failure feed and the store's repair
primitives:

* **subscribe** — it consumes the same typed ``Event`` stream the
  cluster simulator publishes (``store.subscribe(sched.on_event)`` or
  ``ClusterSimulator.subscribe(sched.on_event)``); every ``fail`` event
  enqueues the stripes that placed a share on the dead node;
* **prioritize** — the queue key is *remaining redundancy*
  ``(n - k) - lost_shares``: a stripe one failure away from data loss
  (remaining 0) drains before stripes that can still absorb losses.
  Priorities are recomputed at pop time, so a stripe that lost another
  share while queued jumps the line and a stripe repaired out of band
  is dropped;
* **coalesce** — all single-loss stripes whose embedded d = k+1 helpers
  are present fold into coalesced ``regenerate_batch`` dispatches (one
  per ``repair_tile_tasks`` window — a single dispatch for typical
  drains; the repair matrix is node-invariant, so stripes that lost
  different code nodes still share the vmapped call, and the window's
  helper gathering / share writes overlap the neighbouring window's
  planned compute through the store pipeline, DESIGN.md §11.3);
  multi-loss stripes fall back to the one-matmul full decode per
  stripe;
* **throttle** — each ``drain`` tick moves at most
  ``budget_symbols_per_tick`` repair symbols, derived from the link
  model's bandwidth and the configurable ``repair_bandwidth_fraction``
  (repair must not starve foreground traffic); ``drain_all`` reports
  how many ticks (and simulated seconds) emptying the queue took.

Byte accounting lands in ``store.metrics`` with the classical-RS
re-download baseline (`CodedObjectStore.rs_baseline_symbols`), so a
scenario's repair-traffic ratio is read off exactly like the cluster
simulator's.
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Optional

from repro.cluster.events import Event
from repro.cluster.metrics import LinkModel

from .object_store import CodedObjectStore, ShareIntegrityError


@dataclasses.dataclass
class DrainReport:
    """What one ``drain`` tick (or a full ``drain_all``) accomplished."""
    repaired_stripes: int = 0
    repaired_shares: int = 0
    symbols_moved: int = 0
    rs_baseline_symbols: int = 0
    batch_calls: int = 0          # coalesced regenerate_batch dispatches
    decode_calls: int = 0         # full-decode (multi-loss) dispatches
    unrecoverable: int = 0        # dropped: < k shares left (needs re-put)
    remaining: int = 0            # queue depth after the tick
    ticks: int = 1
    drain_time_s: float = 0.0     # simulated: max(transfer + overheads,
                                  # budget throttle at tick_s per budget)
    converted_objects: int = 0    # online code conversions completed
    convert_symbols: int = 0      # read-side symbols those conversions moved

    @property
    def ratio_vs_rs(self) -> Optional[float]:
        if self.rs_baseline_symbols == 0:
            return None
        return self.symbols_moved / self.rs_baseline_symbols

    def merge(self, other: "DrainReport") -> None:
        self.repaired_stripes += other.repaired_stripes
        self.repaired_shares += other.repaired_shares
        self.symbols_moved += other.symbols_moved
        self.rs_baseline_symbols += other.rs_baseline_symbols
        self.batch_calls += other.batch_calls
        self.decode_calls += other.decode_calls
        self.unrecoverable += other.unrecoverable
        self.remaining = other.remaining
        self.drain_time_s += other.drain_time_s
        self.converted_objects += other.converted_objects
        self.convert_symbols += other.convert_symbols


class RepairScheduler:
    """Background repair queue for a :class:`CodedObjectStore`.

    Parameters
    ----------
    store : CodedObjectStore
        The store whose stripes are repaired.
    link : LinkModel, optional
        Service-time model; defaults to the store's.
    repair_bandwidth_fraction : float
        Fraction of one node's link budgeted for repair per tick.
    tick_s : float
        Simulated tick length; the per-tick symbol budget is
        ``bandwidth_bps * tick_s * fraction`` (symbols ~ bytes over
        GF(257) systematic storage).

    Examples
    --------
    >>> from repro.core.circulant import CodeSpec
    >>> store = CodedObjectStore(CodeSpec.make(2, 257), stripe_symbols=8)
    >>> sched = RepairScheduler(store)
    >>> store.subscribe(sched.on_event)
    >>> _ = store.put("x", bytes(range(64)))
    >>> store.fail_node(1)
    >>> rep = sched.drain_all()
    >>> (sched.pending(), store.get("x") == bytes(range(64)))
    (0, True)
    """

    def __init__(self, store: CodedObjectStore, *,
                 link: Optional[LinkModel] = None,
                 repair_bandwidth_fraction: float = 0.1,
                 tick_s: float = 1.0):
        self.store = store
        self.link = link or store.link
        self.repair_bandwidth_fraction = float(repair_bandwidth_fraction)
        self.tick_s = float(tick_s)
        self._heap: list[tuple[int, int, str, int]] = []
        self._queued: set[tuple[str, int]] = set()
        self._seq = 0
        self._converts: list[tuple[str, object]] = []

    # --------------------------------------------------------------- intake
    def on_event(self, event: Event) -> None:
        """Failure-feed subscriber (store or cluster-simulator events).

        ``fail`` enqueues the dead node's stripes; ``up`` enqueues a
        replaced slot's still-lost stripes — that is how shares lost at
        birth (put while the node was down) get re-protected once a
        newcomer takes the slot; ``delete`` purges the key's queued
        tasks, so deleted objects stop costing pop-time revalidation."""
        if event.kind in ("fail", "up"):
            self.enqueue_node(event.node)
        elif event.kind == "delete":
            self.purge_key(event.key)

    def enqueue_node(self, node: int) -> int:
        """Queue every stripe that placed a share on ``node``; returns how
        many were newly enqueued."""
        added = 0
        for key, t in self.store.stripes_on(node):
            added += self.enqueue_stripe(key, t)
        return added

    def enqueue_scan(self) -> int:
        """Full-store scan: queue every stripe with ANY lost share —
        restart recovery (DESIGN.md §12.5).  A scheduler created after a
        crash has no memory of the failure events that preceded it; one
        scan rebuilds the queue from the store's ground truth (a
        restart-mid-drain drill is ``enqueue_scan()`` + ``drain_all()``).
        Returns how many stripes were newly enqueued."""
        added = 0
        for key, t in list(self.store.stripe_refs()):
            added += self.enqueue_stripe(key, t)
        return added

    def enqueue_stripe(self, key: str, t: int) -> int:
        lost = self.store.lost_code_nodes(key, t)
        if not lost:
            return 0
        if (key, t) in self._queued:
            # already queued at an older (higher) priority: push a second
            # entry at the current loss count — the lower-remaining copy
            # pops first, stale copies are discarded at pop time
            self._push(key, t, len(lost))
            return 0
        self._push(key, t, len(lost))
        return 1

    def _push(self, key: str, t: int, n_lost: int) -> None:
        # priority = remaining redundancy under the OBJECT'S code class
        # (DESIGN.md §15.1); 0 (one failure from loss) first
        n_code, k_code, _d = self._code_params(key)
        remaining = (n_code - k_code) - n_lost
        self._seq += 1
        heapq.heappush(self._heap, (remaining, self._seq, key, t))
        self._queued.add((key, t))

    def _code_params(self, key: str) -> tuple[int, int, int]:
        """(n, k, d) of the key's code class; the store's defaults when
        the key vanished (stale queue entries revalidate at pop time)."""
        try:
            cc = self.store.class_of(key)
        except KeyError:
            return self.store.n, self.store.k, self.store.k + 1
        return cc.n, cc.k, cc.d

    # ------------------------------------------------------- code conversion
    def enqueue_convert(self, key: str, target_class) -> None:
        """Queue an online code conversion (DESIGN.md §15.3); ``drain``
        runs conversions with whatever budget repairs leave — protection
        first, re-encoding second."""
        self._converts.append((key, target_class))

    def pending_converts(self) -> int:
        return len(self._converts)

    def purge_key(self, key: str) -> int:
        """Drop every queued task for ``key`` (the store's ``delete``
        notification): membership leaves ``_queued`` now, and the stale
        heap entries are discarded lazily at pop time like any other
        duplicate.  Returns how many tasks were dropped."""
        dropped = {kt for kt in self._queued if kt[0] == key}
        self._queued -= dropped
        return len(dropped)

    def pending(self) -> int:
        return len(self._queued)

    def peek_order(self) -> list[tuple[str, int, int]]:
        """Queue snapshot as (key, stripe, remaining_redundancy), in drain
        order — for tests and dashboards; does not consume the queue.
        Duplicate entries (priority updates) collapse to the most urgent."""
        seen: set[tuple[str, int]] = set()
        out = []
        for rem, _, key, t in sorted(self._heap):
            if (key, t) in self._queued and (key, t) not in seen:
                seen.add((key, t))
                out.append((key, t, rem))
        return out

    # ---------------------------------------------------------------- drain
    def budget_symbols_per_tick(self) -> int:
        """The throttle: symbols/tick from the link bandwidth budget."""
        return max(1, int(self.link.bandwidth_bps * self.tick_s
                          * self.repair_bandwidth_fraction))

    def drain(self, budget_symbols: Optional[int] = None) -> DrainReport:
        """One throttled tick: pop stripes in priority order until the
        symbol budget is spent, coalesce, dispatch, account.

        Stale queue entries are re-validated at pop time: a stripe whose
        loss count changed is re-queued at its current priority; one
        with nothing lost any more is dropped.
        """
        budget = self.budget_symbols_per_tick() \
            if budget_symbols is None else max(1, int(budget_symbols))
        store = self.store
        s = store.S
        report = DrainReport()
        embedded: list[tuple[str, int, int]] = []   # coalesced single-loss
        full: list[tuple[str, int, tuple[int, ...]]] = []
        selected: set[tuple[str, int]] = set()
        spent = 0
        while self._heap:
            rem, _, key, t = self._heap[0]
            if (key, t) not in self._queued or (key, t) in selected:
                heapq.heappop(self._heap)           # stale dup entry
                continue
            try:
                lost = store.lost_code_nodes(key, t)
            except KeyError:                        # object deleted
                heapq.heappop(self._heap)
                self._queued.discard((key, t))
                continue
            if not lost:
                heapq.heappop(self._heap)
                self._queued.discard((key, t))
                continue
            n_code, k_code, d_code = self._code_params(key)
            if len(lost) > n_code - k_code:         # data loss: fewer than
                heapq.heappop(self._heap)           # k shares left — only a
                self._queued.discard((key, t))      # re-put can help, so it
                report.unrecoverable += 1           # must not wedge the queue
                continue
            now_rem = (n_code - k_code) - len(lost)
            if now_rem != rem:                      # priority drifted
                heapq.heappop(self._heap)
                self._push(key, t, len(lost))       # requeue at current prio
                continue
            # bandwidth-optimal regeneration (d * S, eq. (7)) when the
            # object's family has a plan from the present shares; full
            # decode (B = k * q * S) otherwise — per-key code geometry
            regen_ok = (len(lost) == 1
                        and store.embedded_helpers_present(key, t, lost[0]))
            cost = d_code * s if regen_ok \
                else k_code * (d_code - k_code + 1) * s
            if spent + cost > budget and spent > 0:
                break                               # budget exhausted
            heapq.heappop(self._heap)
            selected.add((key, t))
            spent += cost
            if regen_ok:
                embedded.append((key, t, lost[0]))
            else:
                full.append((key, t, lost))
        # provision newcomers for every slot we are about to write — their
        # `up` events may enqueue OTHER still-lost stripes on the slot
        # (lost-at-birth re-protection); the selected set stays in
        # _queued until its repairs land so those events cannot double-
        # enqueue the work in flight.  The finally block keeps queue state
        # and byte accounting consistent with whatever repairs actually
        # landed, even if one raises mid-tick.
        completed: set[tuple[str, int]] = set()
        try:
            self._replace_target_nodes(embedded, full)
            if embedded:
                # a rotten helper (persistent CRC failure) must not be
                # decoded FROM: skip the batch, requeue via the finally
                # block, and let a scrub drop the bad share first
                try:
                    moved, dispatches = \
                        store.repair_stripes_embedded(embedded)
                except ShareIntegrityError:
                    pass
                else:
                    report.symbols_moved += moved
                    report.batch_calls += dispatches
                    report.repaired_stripes += len(embedded)
                    report.repaired_shares += len(embedded)
                    # per-key RS baseline: each task rebuilt one share of
                    # ITS object's code class (identical to the legacy
                    # store-wide formula when everything is default-class)
                    report.rs_baseline_symbols += sum(
                        store.rs_baseline_symbols_for(key, 1)
                        for key, _t, _n in embedded)
                    completed.update((key, t) for key, t, _ in embedded)
            for key, t, lost in full:
                try:
                    report.symbols_moved += \
                        store.repair_stripe_full(key, t, lost)
                except ShareIntegrityError:
                    continue
                report.decode_calls += 1
                report.repaired_stripes += 1
                report.repaired_shares += len(lost)
                report.rs_baseline_symbols += \
                    store.rs_baseline_symbols_for(key, len(lost))
                completed.add((key, t))
        finally:
            for kt in selected:
                self._queued.discard(kt)
            for key, t in selected - completed:     # repair raised: requeue
                self.enqueue_stripe(key, t)         # at the current priority
            if report.repaired_shares:
                store.metrics.record_repair(report.repaired_shares,
                                            report.symbols_moved,
                                            report.rs_baseline_symbols)
        # online conversions run on whatever budget repairs left this
        # tick (protection first, re-encoding second); each conversion's
        # read-side traffic is charged against the same symbol budget
        while self._converts and spent < budget:
            key, target = self._converts.pop(0)
            try:
                receipt = store.convert(key, target)
            except KeyError:
                continue                            # deleted while queued
            report.converted_objects += 1
            report.convert_symbols += receipt.bytes_read
            spent += max(1, receipt.bytes_read)
        report.remaining = self.pending()
        n_tasks = len(embedded) + len(full)
        # simulated tick duration: the raw transfer + per-task overheads,
        # floored by the THROTTLE — the budget grants at most `budget`
        # symbols per tick_s of simulated time, so a tick that spends its
        # whole budget costs tick_s however fast the link could move it
        # (this is what makes drain_time_s a function of the budget)
        moved = report.symbols_moved + report.convert_symbols
        raw_s = (moved / self.link.bandwidth_bps
                 + n_tasks * self.link.request_overhead_s
                 + report.decode_calls * self.link.decode_overhead_s)
        throttle_s = moved / budget * self.tick_s
        report.drain_time_s = max(raw_s, throttle_s)
        return report

    def _replace_target_nodes(self, embedded, full) -> None:
        targets: set[int] = set()
        for key, t, node in embedded:
            targets.add(self.store.placement_of(key, t)[node - 1])
        for key, t, lost in full:
            pl = self.store.placement_of(key, t)
            targets.update(pl[i - 1] for i in lost)
        for phys in targets:
            if not self.store.is_up(phys):
                self.store.replace_node(phys)

    def drain_all(self, budget_symbols: Optional[int] = None,
                  max_ticks: int = 100_000) -> DrainReport:
        """Tick until the queue is empty; the merged report's ``ticks``
        and ``drain_time_s`` are the queue-drain-time-vs-budget numbers
        ``BENCH_store.json`` tracks."""
        total = DrainReport(ticks=0)
        while self.pending() or self._converts:
            if total.ticks >= max_ticks:
                raise RuntimeError(f"repair queue not drained after "
                                   f"{max_ticks} ticks")
            rep = self.drain(budget_symbols)
            total.merge(rep)
            total.ticks += 1
            if rep.repaired_stripes == 0 and rep.converted_objects == 0 \
                    and (rep.remaining or self._converts):
                raise RuntimeError(
                    "repair stalled: pending stripes cannot be repaired "
                    "(fewer than k shares present?)")
        return total


__all__ = ["RepairScheduler", "DrainReport"]
