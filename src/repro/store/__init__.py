"""Coded object store: multi-stripe MSR storage with put/get/delete/stat,
transparent degraded reads, and a prioritized background repair
scheduler (DESIGN.md §10).

The layer that turns the single-stripe engines (encode dispatch, fused
repair, decode-inverse cache) into a multi-object storage subsystem:

* `stripes.StripeManager` — chunk arbitrary objects into fixed stripes,
  encode through one planned circulant dispatch per window, place
  shares rack-aware on a physical node ring;
* `object_store.CodedObjectStore` — the front-end: systematic fast-path
  reads, one cached-inverse decode matmul per failure pattern for
  everything missing; put/get/repair all run through the store's
  overlapped I/O⇄compute pipeline and the shape-bucketed execution-plan
  cache (DESIGN.md §11) — zero recompiles at steady state;
* `scheduler.RepairScheduler` — failure-event-driven repair queue,
  priority = remaining redundancy, single-loss stripes coalesced into
  windowed `regenerate_batch` dispatches, throttled by a link-bandwidth
  budget.
"""
from .object_store import (FAILED, UP, CodedObjectStore, ConvertReceipt,
                           GetResult, ObjectStat, ShareIntegrityError,
                           StoreAudit, StoreMetrics, UnknownKeyError,
                           share_crc)
from .scheduler import DrainReport, RepairScheduler
from .stripes import StripeCodec, StripeManager, StripeMap

__all__ = ["CodedObjectStore", "ObjectStat", "GetResult", "ConvertReceipt",
           "StoreAudit", "StoreMetrics", "UnknownKeyError",
           "ShareIntegrityError", "share_crc",
           "RepairScheduler", "DrainReport", "StripeManager", "StripeCodec",
           "StripeMap", "UP", "FAILED"]
