"""Stripe manager: arbitrary-size objects <-> fixed MSR stripes
(DESIGN.md §10.1).

An object (bytes, or any numpy array) is serialized to a byte payload,
converted to GF(p) symbols, zero-padded to a whole number of stripes and
cut into (T, n, S) data blocks: T stripes of the code's n = 2k blocks,
S = ``stripe_symbols`` symbols each.  The original byte length is
recorded in the :class:`StripeMap` so padding strips off bit-exactly on
reassembly.

Encoding exploits that the circulant encode is independent per symbol
column: ALL T stripes of an object are folded into ONE dispatched
(n, T*S) encode call instead of T small ones — the multi-stripe
counterpart of the PR 1 streaming save.

Physical placement rides on `core.placement`: share j of stripe t lands
on node ``rotate_placement(layout, n, t)[j]``, rotating stripes around
the node ring so load spreads and a node failure costs each stripe at
most one share, while the round-robin rack layout keeps any stripe's
rack-correlated loss within the code's n - k erasure budget
(`max_shares_per_rack`).
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from repro.core import gf, placement
from repro.core.circulant import CodeSpec
from repro.core.msr import DoubleCirculantMSR


def _flatten_into(blocks: np.ndarray, axes: tuple, out_shape: tuple,
                  out: np.ndarray | None) -> np.ndarray:
    """Transpose ``blocks`` by ``axes`` into ``out_shape``, writing into
    ``out`` in place when given (the zero-copy staging path, DESIGN.md
    §16) or materializing a fresh contiguous array otherwise."""
    if out is None:
        return np.ascontiguousarray(
            np.transpose(blocks, axes)).reshape(out_shape)
    if out.shape != out_shape or out.dtype != np.int32:
        raise ValueError(f"staging out must be int32 {out_shape}, got "
                         f"{out.dtype} {out.shape}")
    from time import perf_counter
    t0 = perf_counter()
    # 3-D view of the destination so the strided transpose writes land
    # directly in the pooled buffer (one pass, no intermediate copy)
    np.copyto(out.reshape(tuple(blocks.shape[a] for a in axes)),
              np.transpose(blocks, axes))
    gf.record_stage("pack", perf_counter() - t0)
    return out


@dataclasses.dataclass(frozen=True)
class StripeMap:
    """Geometry of one striped object (everything needed to reassemble).

    Parameters
    ----------
    orig_bytes : int
        Payload length before symbol conversion and padding.
    n_stripes : int
        Number of stripes the object spans (>= 1 even for empty objects,
        so every object owns storable shares and a repairable footprint).
    stripe_symbols : int
        Symbols per data block (the code's S) — each stripe carries
        ``n * stripe_symbols`` payload symbols.
    """
    orig_bytes: int
    n_stripes: int
    stripe_symbols: int

    def payload_symbols(self, n: int) -> int:
        """Padded symbol capacity across all stripes."""
        return self.n_stripes * n * self.stripe_symbols


class StripeManager:
    """Chunk + encode + place: the store's codec for one code spec.

    Parameters
    ----------
    spec : CodeSpec
        The [n = 2k, k] double circulant code every stripe uses.
    layout : placement.RackLayout
        Physical node ring (may be larger than n) with rack assignment.
    stripe_symbols : int
        Data-block size S; small objects still occupy one full stripe
        (padded), so pick S against the expected object size.
    code : DoubleCirculantMSR, optional
        Share an existing code instance (and its decode-inverse cache).
    backend : str, optional
        Pin a dispatch backend by name (forwarded to the code).
    mesh : StreamMesh | int | None, optional
        Stream-axis device mesh forwarded to the code (DESIGN.md §14);
        ignored when ``code`` is given (the code owns its planner).
    """

    def __init__(self, spec: CodeSpec, layout: placement.RackLayout, *,
                 stripe_symbols: int = 1 << 12,
                 code: DoubleCirculantMSR | None = None,
                 backend: str | None = None, mesh=None):
        self.spec = spec
        self.k, self.n, self.p = spec.k, spec.n, spec.p
        self.layout = layout
        self.stripe_symbols = int(stripe_symbols)
        if self.stripe_symbols < 1:
            raise ValueError("stripe_symbols must be >= 1")
        self.code = code or DoubleCirculantMSR(spec, backend=backend,
                                               mesh=mesh)
        worst = max(placement.max_shares_per_rack(
            layout, self.placement(t)) for t in range(layout.n_nodes))
        if worst > self.n - self.k:
            raise ValueError(
                f"layout unsafe: some stripe puts {worst} shares in one "
                f"rack > n-k = {self.n - self.k}; add racks or nodes")

    # ------------------------------------------------------------- placement
    def placement(self, stripe: int) -> tuple[int, ...]:
        """Physical node (1-indexed) of each code node's share for stripe
        ``stripe`` — entry j holds code node v_{j+1}'s pair."""
        return placement.rotate_placement(self.layout, self.n, stripe)

    # ----------------------------------------------------------------- chunk
    def chunk(self, payload: bytes,
              one_pass: bool = True) -> tuple[np.ndarray, StripeMap]:
        """payload -> ((T, n, S) int32 data blocks, StripeMap).

        ``one_pass`` (the zero-copy staging default, DESIGN.md §16.1)
        writes the byte payload straight into the freshly allocated
        block array — cast and stripe padding fused into one strided
        write.  ``one_pass=False`` keeps the legacy astype -> pad ->
        astype copy chain as the measurable A/B baseline; both produce
        bit-identical blocks."""
        per_stripe = self.n * self.stripe_symbols
        t = max(1, -(-len(payload) // per_stripe))
        if one_pass:
            blocks = np.empty((t, self.n, self.stripe_symbols), np.int32)
            gf.bytes_to_symbols_into(payload, blocks.reshape(-1), self.p)
        else:
            sym = gf.bytes_to_symbols(payload, self.p)
            sym = np.pad(sym, (0, t * per_stripe - len(sym)))
            blocks = sym.reshape(t, self.n,
                                 self.stripe_symbols).astype(np.int32)
        return blocks, StripeMap(orig_bytes=len(payload), n_stripes=t,
                                 stripe_symbols=self.stripe_symbols)

    def assemble(self, blocks: np.ndarray, smap: StripeMap) -> bytes:
        """Inverse of :meth:`chunk`: (T, n, S) data blocks -> payload."""
        sym = np.asarray(blocks, np.int32).reshape(-1)
        return gf.symbols_to_bytes(sym)[: smap.orig_bytes]

    # ---------------------------------------------------------------- encode
    def flatten(self, blocks: np.ndarray,
                out: np.ndarray | None = None) -> np.ndarray:
        """(T, n, S) data blocks -> the (n, T*S) stream view the encode
        dispatches over (the stripe axis folds into the symbol axis —
        the circulant encode is independent per symbol column).

        ``out`` (int32, exactly (n, T*S)) receives the transpose in
        place — the zero-copy staging path (DESIGN.md §16): the put
        pipeline passes a view into a pooled, bucket-padded buffer so
        flatten + pad collapse into one strided write."""
        t, n, s = blocks.shape
        if n != self.n:
            raise ValueError(f"expected {self.n} blocks per stripe, got {n}")
        return _flatten_into(blocks, (1, 0, 2), (n, t * s), out)

    def unflatten(self, flat: np.ndarray, t: int) -> np.ndarray:
        """Inverse of :meth:`flatten`: (n, T*S) -> (T, n, S)."""
        return np.ascontiguousarray(np.transpose(
            np.asarray(flat, np.int32).reshape(self.n, t, -1), (1, 0, 2)))

    def encode(self, blocks: np.ndarray) -> np.ndarray:
        """(T, n, S) data blocks -> (T, n, S) redundancy blocks.

        One dispatched circulant matmul for the whole object: the stripe
        axis is folded into the symbol axis ((n, T*S) view), encoded
        once, and unfolded — encode cost is independent of how many
        stripes the object spans.  (The store's put path tiles the same
        flatten/encode/unflatten over stripe windows so share placement
        overlaps the next window's encode — DESIGN.md §11.3.)
        """
        flat = self.flatten(blocks)
        red = np.asarray(self.code.encode(jnp.asarray(flat)), np.int32)
        return self.unflatten(red, blocks.shape[0])


class StripeCodec:
    """Family-generic stripe codec: chunk + encode + place for any
    registered :class:`~repro.codes.base.ErasureCode` (DESIGN.md §15.3).

    The generic counterpart of :class:`StripeManager` — one stripe
    carries ``D = code.data_blocks`` payload blocks of S symbols, each
    node stores ``q = code.share_blocks`` blocks, and the whole object's
    non-systematic rows are produced by ONE folded
    ``encode_derived_planned`` dispatch over the (D, T*S) stream view.
    """

    def __init__(self, code, layout: placement.RackLayout, *,
                 stripe_symbols: int):
        self.code = code
        self.layout = layout
        self.n, self.k, self.d, self.p = code.n, code.k, code.d, code.p
        self.stripe_symbols = int(stripe_symbols)
        if self.stripe_symbols < 1:
            raise ValueError("stripe_symbols must be >= 1")
        worst = max(placement.max_shares_per_rack(
            layout, self.placement(t)) for t in range(layout.n_nodes))
        if worst > self.n - self.k:
            raise ValueError(
                f"layout unsafe for {code.family_key()}: some stripe puts "
                f"{worst} shares in one rack > n-k = {self.n - self.k}")

    # ------------------------------------------------------------- placement
    def placement(self, stripe: int) -> tuple[int, ...]:
        """Physical node (1-indexed) of each code node's share."""
        return placement.rotate_placement(self.layout, self.n, stripe)

    # ----------------------------------------------------------------- chunk
    def chunk(self, payload: bytes,
              one_pass: bool = True) -> tuple[np.ndarray, StripeMap]:
        """payload -> ((T, D, S) int32 payload blocks, StripeMap).
        ``one_pass`` stages the bytes in one fused write like
        :meth:`StripeManager.chunk`."""
        d_blocks = self.code.data_blocks
        per_stripe = d_blocks * self.stripe_symbols
        t = max(1, -(-len(payload) // per_stripe))
        if one_pass:
            blocks = np.empty((t, d_blocks, self.stripe_symbols), np.int32)
            gf.bytes_to_symbols_into(payload, blocks.reshape(-1), self.p)
        else:
            sym = gf.bytes_to_symbols(payload, self.p)
            sym = np.pad(sym, (0, t * per_stripe - len(sym)))
            blocks = sym.reshape(t, d_blocks,
                                 self.stripe_symbols).astype(np.int32)
        return blocks, StripeMap(orig_bytes=len(payload), n_stripes=t,
                                 stripe_symbols=self.stripe_symbols)

    def assemble(self, blocks: np.ndarray, smap: StripeMap) -> bytes:
        """Inverse of :meth:`chunk`: (T, D, S) payload blocks -> bytes."""
        sym = np.asarray(blocks, np.int32).reshape(-1)
        return gf.symbols_to_bytes(sym)[: smap.orig_bytes]

    # ---------------------------------------------------------------- encode
    def flatten(self, blocks: np.ndarray,
                out: np.ndarray | None = None) -> np.ndarray:
        """(T, D, S) -> (D, T*S) stream view (stripe axis folded into
        the symbol axis; every family's encode is column-independent).
        ``out`` stages in place like ``StripeManager.flatten``."""
        t, d_blocks, s = blocks.shape
        if d_blocks != self.code.data_blocks:
            raise ValueError(f"expected {self.code.data_blocks} payload "
                             f"blocks per stripe, got {d_blocks}")
        return _flatten_into(blocks, (1, 0, 2), (d_blocks, t * s), out)

    def unflatten_rows(self, flat: np.ndarray, rows: int,
                       t: int) -> np.ndarray:
        """(rows, T*S) encode/decode product -> (T, rows, S)."""
        return np.ascontiguousarray(np.transpose(
            np.asarray(flat, np.int32).reshape(rows, t, -1), (1, 0, 2)))

    def encode_window(self, blocks: np.ndarray) -> np.ndarray:
        """(T, D, S) payload blocks -> (T, derived_rows, S) derived rows
        in ONE planned dispatch for the whole window."""
        flat = self.flatten(blocks)
        derived = self.code.encode_derived_planned(flat).host()
        return self.unflatten_rows(derived, self.code.derived_rows,
                                   blocks.shape[0])

    def stripe_shares(self, data: np.ndarray, derived: np.ndarray):
        """One stripe's (D, S) payload + (derived_rows, S) product ->
        per-node block lists, 1-indexed by code node."""
        return {j: self.code.stripe_share_blocks(data, derived, j)
                for j in range(1, self.n + 1)}


__all__ = ["StripeMap", "StripeManager", "StripeCodec"]
