"""Coded object store front-end: put / get / delete / stat over MSR
stripes (DESIGN.md §10).

The store owns a ring of physical nodes (possibly more than the code's
n = 2k) and, per stripe, places the n node shares — pairs
(a_{j-1}, r_j) — via the rotating rack-aware placement of
`store.stripes.StripeManager`.  Every byte it serves is a real field
computation over really-stored symbols, so failures are verifiable
bit-exactly, exactly like the cluster simulator one layer down.

Read paths (DESIGN.md §10.2):

* **systematic fast path** — a stripe whose n data shares are all
  present is served as raw bytes, zero field operations;
* **transparent degraded read** — stripes with missing data blocks are
  grouped by (helper subset, missing set) and ALL missing blocks of a
  group come out of ONE cached-inverse decode matmul: the per-stripe
  (2k, S) downloads concatenate along the symbol axis, so a get that
  spans a thousand stripes after a node failure still costs one
  `gf.gauss_inverse` (LRU-cached) and one dispatched matmul per
  failure pattern.

Failures: ``fail_node`` wipes a node's shares and notifies subscribers
(the background `RepairScheduler` enqueues affected stripes);
``replace_node`` brings up an empty newcomer the scheduler rebuilds
shares onto.  A get never blocks on repair — it degrades while the
queue drains.
"""
from __future__ import annotations

import dataclasses
import os
import zlib
from typing import Any, Callable, Iterator, Optional, Sequence

import jax
import numpy as np

from repro.codes import CodeClass, default_code_class, make_code
from repro.codes.double_circulant import DoubleCirculantCode
from repro.core import baselines, gf, placement
from repro.core.circulant import CodeSpec
from repro.core.msr import DoubleCirculantMSR
from repro.cluster.events import Event
from repro.cluster.metrics import LinkModel, MetricsLog
from repro.exec.pipeline import Pipeline
from repro.exec.plan import planning_enabled
from repro.io.faults import FaultInjector
from repro.io.retry import RetryPolicy, RetryStats

from .stripes import StripeCodec, StripeManager, StripeMap

UP, FAILED = "up", "failed"


class UnknownKeyError(KeyError):
    """``get``/``stat``/``delete`` on a key the store has never committed
    (or has deleted).  A ``KeyError`` subclass, so generic key-miss
    handling — the repair scheduler's pop-time revalidation, ``except
    KeyError`` call sites — keeps working unchanged."""

    def __init__(self, key: str):
        super().__init__(f"unknown key {key!r}")
        self.key = key


class ShareIntegrityError(OSError):
    """A helper share repeatedly failed its put-time CRC on the read
    path feeding a repair or degraded decode (DESIGN.md §13.2).  Raised
    only after re-reads rule out a transient read-path flip — the
    stored copy is rotten; the caller should scrub/drop it (the repair
    scheduler requeues the stripe instead of installing a share rebuilt
    from garbage)."""

    def __init__(self, phys: int, key: str, t: int, attempts: int):
        super().__init__(
            f"share (key={key!r}, stripe={t}) on node {phys} failed its "
            f"CRC {attempts} times — storage rot, not a read-path flip")
        self.phys = phys
        self.key = key
        self.stripe = t


def share_crc(a: np.ndarray, r: np.ndarray, *, zero_copy: bool = True) -> int:
    """CRC32 of one node share's LOGICAL payload — PR 6's checkpoint
    manifest convention (DESIGN.md §12.2) applied per share: the data
    block as raw uint8 bytes chained with the redundancy block's
    ``pack257`` halves (low bytes, then int64 indexes of 256).  Repairs
    are bit-exact, so a rebuilt share matches its put-time CRC without
    any ledger rewrite.

    Hot on the put/repair install path: the default feeds zlib the
    array buffers directly (no ``.tobytes()`` heap copies) and folds
    ``pack257`` inline — the truncating uint8 cast IS ``% 256`` for
    symbols in [0, 256].  ``zero_copy=False`` keeps the legacy
    three-copy chain as the measurable A/B baseline (DESIGN.md §16.3);
    both produce the SAME CRC for every GF(257) share."""
    if not zero_copy:
        c = zlib.crc32(np.ascontiguousarray(a, np.uint8).tobytes())
        low, hi = gf.pack257(np.asarray(r, np.int32))
        c = zlib.crc32(np.ascontiguousarray(low, np.uint8).tobytes(), c)
        return zlib.crc32(np.ascontiguousarray(hi, np.int64).tobytes(), c)
    c = zlib.crc32(np.ascontiguousarray(a, np.uint8))
    sym = np.ascontiguousarray(r, np.int32).reshape(-1)
    c = zlib.crc32(sym.astype(np.uint8), c)
    return zlib.crc32(
        np.ascontiguousarray(np.nonzero(sym == 256)[0].astype(np.int64)), c)


class StoreMetrics(MetricsLog):
    """Cluster-layer accounting plus the store's write-side counters."""

    def __init__(self):
        super().__init__()
        self.puts_total = 0
        self.put_symbols = 0          # payload symbols accepted
        self.put_stored_symbols = 0   # share symbols written (2x payload)

    def record_put(self, payload_symbols: int, stored_symbols: int) -> None:
        self.puts_total += 1
        self.put_symbols += payload_symbols
        self.put_stored_symbols += stored_symbols

    def summary(self) -> dict:
        out = super().summary()
        out["puts"] = {"total": self.puts_total,
                       "payload_symbols": self.put_symbols,
                       "stored_symbols": self.put_stored_symbols}
        return out


@dataclasses.dataclass
class ObjectStat:
    """Metadata for one stored object (``stat`` result).

    ``dtype``/``shape`` are set for array objects so ``get`` returns the
    original array type; ``meta`` carries caller extras (e.g. the
    checkpointer's tree spec).  ``share_crcs[t][j]`` is the put-time
    :func:`share_crc` of stripe ``t``'s code-node ``j+1`` share — the
    ground truth end-to-end read integrity (DESIGN.md §13.2) verifies
    against; ``None`` only for stats built by callers that predate it.
    """
    key: str
    size_bytes: int
    n_stripes: int
    stripe_symbols: int
    dtype: Optional[str] = None
    shape: Optional[tuple[int, ...]] = None
    meta: dict = dataclasses.field(default_factory=dict)
    share_crcs: Optional[list] = None
    # the object's code class (DESIGN.md §15.1); None means the store's
    # default double-circulant class (stats that predate per-object
    # classes keep working)
    code_class: Optional[CodeClass] = None


@dataclasses.dataclass
class GetResult:
    """``get_ext`` receipt: the object plus what serving it cost."""
    obj: Any
    bytes_read: int
    degraded_stripes: int
    latency_s: float


@dataclasses.dataclass
class ConvertReceipt:
    """:meth:`CodedObjectStore.convert` receipt (DESIGN.md §15.3).

    ``degraded_source_stripes`` counts source stripes that needed a
    decode during the read-out — every other stripe's payload was
    reused straight from systematic shares (the structure-aware fast
    path).  ``bytes_read`` is the read-side traffic; the write side is
    a normal put (accounted in ``store.metrics``).
    """
    key: str
    source: CodeClass
    target: CodeClass
    payload_bytes: int
    source_stripes: int
    target_stripes: int
    degraded_source_stripes: int
    bytes_read: int
    latency_s: float

    @property
    def converted(self) -> bool:
        return self.source != self.target


@dataclasses.dataclass
class StoreAudit:
    """:meth:`CodedObjectStore.audit` receipt (DESIGN.md §12.2).

    ``orphan_shares`` are (phys_node, key, stripe, reason) tuples for
    shares that no committed object accounts for — the residue a crash
    between share placement and the ``_stats`` commit would leave if
    ``put`` were not commit-last, or that direct state corruption
    leaves.  ``stat``/``get`` never see orphans (they walk ``_stats``);
    the audit exists so :meth:`CodedObjectStore.gc_orphans` and the
    drill harness can prove there are none.
    """
    orphan_shares: list = dataclasses.field(default_factory=list)
    shares_checked: int = 0

    @property
    def clean(self) -> bool:
        return not self.orphan_shares


class CodedObjectStore:
    """Multi-object MSR storage over a physical node ring.

    Parameters
    ----------
    spec : CodeSpec
        The double circulant code every stripe is encoded with.
    n_nodes : int, optional
        Physical ring size (default the code's n = 2k; larger rings
        spread stripes so one node failure touches only a fraction of
        them — that is what makes repair *priorities* meaningful).
    n_racks : int, optional
        Failure domains; default the fewest racks keeping any stripe's
        single-rack loss within n - k (`events.default_layout` formula).
    stripe_symbols : int
        Data-block size S per stripe.
    link : LinkModel, optional
        Deterministic service-time model for read/repair latencies.
    backend : str, optional
        Pin a GF dispatch backend for encode/decode.
    mesh : StreamMesh | int | None, optional
        Stream-axis device mesh for every planned GF dispatch — put
        encodes, degraded-read decodes, coalesced repair
        (DESIGN.md §14).  ``None`` inherits an ambient
        ``repro.sharding.mesh.use_mesh(...)`` scope.
    io_workers, pipeline_depth : int
        The store's overlapped I/O⇄compute engine (DESIGN.md §11.3):
        share placement / download gathering runs on ``io_workers`` pool
        threads while the next window's planned GF dispatch computes;
        ``pipeline_depth=1`` disables the overlap (serial baseline) and
        ``pipeline_depth=None`` (default) auto-sizes to the machine —
        depth 2 with >= 2 CPUs, the serial schedule on a single-core
        host where overlap cannot win (DESIGN.md §16.4).
    put_tile_stripes : int
        Stripes per encode window on the put path — each window is one
        planned circulant dispatch whose share placement overlaps the
        next window's encode.
    repair_tile_tasks : int
        Repair tasks per coalesced ``regenerate_batch`` dispatch in
        :meth:`repair_stripes_embedded` (the batch axis is bucketed, so
        variable task counts share executables).
    faults : FaultInjector, optional
        Fault-injection seam (DESIGN.md §12.4): every share read/write
        consults ``faults.apply(op, "node:NN")`` so drills inject
        per-node transient failures and latency.  ``None`` (production)
        short-circuits the guard entirely.
    retry : RetryPolicy, optional
        How guarded share ops retry transient faults (DESIGN.md §12.3);
        give-ups surface as typed ``GiveUpError``.  Accounting lands in
        ``self.retry_stats``.

    Examples
    --------
    >>> from repro.core.circulant import CodeSpec
    >>> store = CodedObjectStore(CodeSpec.make(2, 257), stripe_symbols=16)
    >>> _ = store.put("hello", b"payload bytes")
    >>> store.get("hello")
    b'payload bytes'
    """

    def __init__(self, spec: CodeSpec, *, n_nodes: Optional[int] = None,
                 n_racks: Optional[int] = None, stripe_symbols: int = 1 << 12,
                 link: Optional[LinkModel] = None,
                 backend: Optional[str] = None,
                 code: Optional[DoubleCirculantMSR] = None,
                 io_workers: int = 4, pipeline_depth: Optional[int] = None,
                 put_tile_stripes: int = 64,
                 repair_tile_tasks: int = 64,
                 faults: Optional[FaultInjector] = None,
                 retry: Optional[RetryPolicy] = None,
                 mesh=None):
        self.spec = spec
        self.k, self.n, self.p = spec.k, spec.n, spec.p
        self.n_nodes = int(n_nodes if n_nodes is not None else spec.n)
        if self.n_nodes < spec.n:
            raise ValueError(f"need >= n = {spec.n} physical nodes, "
                             f"got {self.n_nodes}")
        if n_racks is None:
            n_racks = self._default_racks(spec, self.n_nodes)
        self.layout = placement.rack_layout(self.n_nodes, n_racks)
        self.stripes = StripeManager(spec, self.layout,
                                     stripe_symbols=stripe_symbols,
                                     code=code, backend=backend, mesh=mesh)
        self.code = self.stripes.code
        self.S = self.stripes.stripe_symbols
        self.link = link or LinkModel()
        self.state = [UP] * self.n_nodes
        # _shares[phys-1][(key, stripe)] = [code_node, a_block, r_block]
        self._shares: list[dict[tuple[str, int], list]] = \
            [dict() for _ in range(self.n_nodes)]
        self._stats: dict[str, ObjectStat] = {}
        self._next_stripe = 0          # rotation phase for the next put
        self.metrics = StoreMetrics()
        self._subscribers: list[Callable[[Event], None]] = []
        self.put_tile_stripes = max(1, int(put_tile_stripes))
        self.repair_tile_tasks = max(1, int(repair_tile_tasks))
        # zero-copy staging (DESIGN.md §16): pooled buffers on every hot
        # path; False restores the legacy copying path (the A/B baseline
        # the staging tests and BENCH_pipeline measure against)
        self.staging_enabled = True
        # fault-injection seam (DESIGN.md §12): every share read/write is
        # guarded by faults.apply("read"/"write", "node:NN") under the
        # retry policy; faults=None short-circuits to zero overhead
        self.faults = faults
        self.retry = retry or RetryPolicy()
        self.retry_stats = RetryStats()
        # persistent overlapped I/O⇄compute engine (DESIGN.md §11.3):
        # pool threads are reused across put/get/repair calls.  Depth
        # auto-sizes to the machine (DESIGN.md §16.4): on a single-core
        # host the host/compute overlap cannot win — read-ahead and
        # install offload just add thread switching — so the default
        # degenerates to the serial depth-1 schedule there.
        if pipeline_depth is None:
            pipeline_depth = 2 if (os.cpu_count() or 1) >= 2 else 1
        self.pipeline = Pipeline(io_workers=io_workers, depth=pipeline_depth)
        # per-object code classes (DESIGN.md §15): objects default to the
        # store's double-circulant class and take the battle-tested legacy
        # paths; other classes dispatch through their family's codec
        self.default_class = default_code_class(spec)
        self._codecs: dict[str, StripeCodec] = {}

    @staticmethod
    def _default_racks(spec: CodeSpec, n_nodes: int) -> int:
        """Fewest racks (>= 2) whose rotating share windows stay within
        the n - k budget on THIS ring.  The `events.default_layout`
        formula ceil(n / (n-k)) is only exact when the window never
        wraps (n_nodes a multiple of the rack count); wrapping can put
        one extra share in a rack, so candidates are checked against
        every rotation phase and bumped until safe — n_nodes racks
        (one node per rack) always terminates the search."""
        budget = spec.n - spec.k
        for cand in range(max(2, -(-spec.n // max(1, budget))),
                          n_nodes + 1):
            layout = placement.rack_layout(n_nodes, cand)
            worst = max(placement.max_shares_per_rack(
                layout, placement.rotate_placement(layout, spec.n, t))
                for t in range(n_nodes))
            if worst <= budget:
                return cand
        return n_nodes

    # ------------------------------------------------------------- lifecycle
    def close(self) -> None:
        """Shut the store's pipeline pool down (its threads are
        non-daemon; long-lived processes that churn store instances
        should close them — or use the store as a context manager).
        The store remains usable afterwards: the pool respawns lazily.
        """
        self.pipeline.close()

    def __enter__(self) -> "CodedObjectStore":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        self.close()

    # ---------------------------------------------------------- staging pool
    def _stage_into(self, planner, rows: int, s: int):
        """A pooled (rows, padded_extent) int32 staging buffer for a
        stream operand of true extent ``s`` — or None when the planner
        path is off (custom matmul backends, planning disabled).

        Callers write the payload into ``buf[:, :s]``, zero the tail,
        and hand the WHOLE buffer to the planned op: the extent is
        exactly the plan cache's bucketed pad, so the planner's pad
        stage sees an exact fit and dispatches the buffer as-is — the
        flatten/gather copy and the bucket pad collapse into one write
        (DESIGN.md §16.1).  Release the buffer only after the consuming
        ``PlanResult.host()`` returned (§16.2).  ``staging_enabled =
        False`` forces the legacy copying path everywhere — the A/B
        baseline the staging tests and BENCH_pipeline compare against."""
        if planner is None or not planning_enabled() \
                or not self.staging_enabled:
            return None
        _, pad = planner.stream_pad(s)
        return planner.staging.acquire((rows, pad), np.int32)

    def _install(self, work) -> None:
        """Run share-install work (CRC + staging copies) on the pipeline
        pool only when it can genuinely overlap the next window's
        dispatch (depth > 1).  A depth-1 store runs it inline: its pool
        has one worker, so offloading would just move the same wall
        time behind the trailing barrier AND let installs overlap the
        main thread — a depth-1 store must stay a true serial baseline
        for the overlap benchmark (DESIGN.md §16.3)."""
        if self.pipeline.depth > 1:
            self.pipeline.submit(work)
        else:
            work()

    # ------------------------------------------------------------ node state
    def subscribe(self, fn: Callable[[Event], None]) -> None:
        """Register a callback for store events (``fail`` on node loss) —
        the repair scheduler's feed, same Event type the cluster
        simulator publishes."""
        self._subscribers.append(fn)

    def _notify(self, event: Event) -> None:
        for fn in self._subscribers:
            fn(event)

    def is_up(self, node: int) -> bool:
        return self.state[node - 1] == UP

    def up_nodes(self) -> list[int]:
        return [i + 1 for i in range(self.n_nodes) if self.state[i] == UP]

    def fail_node(self, node: int, t: float = 0.0) -> None:
        """Node crash: every share it held is lost; subscribers (the
        repair scheduler) are notified with a ``fail`` event."""
        self._check_node(node)
        self.state[node - 1] = FAILED
        self._shares[node - 1].clear()
        self._notify(Event(t=t, kind="fail", node=node))

    def replace_node(self, node: int, t: float = 0.0) -> None:
        """An empty newcomer takes the failed node's slot: UP, no shares.
        Subscribers see an ``up`` event so the scheduler re-protects any
        share the slot should hold but doesn't — including shares that
        were *lost at birth* (``put`` while the node was FAILED), whose
        loss never produced a ``fail`` event."""
        self._check_node(node)
        self.state[node - 1] = UP
        self._notify(Event(t=t, kind="up", node=node))

    def _check_node(self, node: int) -> int:
        if not 1 <= node <= self.n_nodes:
            raise ValueError(f"node {node} out of range 1..{self.n_nodes}")
        return node

    # --------------------------------------------------------- fault seam
    def _guard(self, op: str, phys: int) -> None:
        """Route a share operation on physical node ``phys`` through the
        fault seam under the retry policy.  No injector → no overhead;
        persistent injected faults surface as ``GiveUpError``."""
        if self.faults is None:
            return
        ref = f"node:{phys:02d}"
        self.retry.call(lambda: self.faults.apply(op, ref),
                        op=f"{op}:{ref}", stats=self.retry_stats)

    def read_share(self, phys: int, key: str, t: int, *,
                   budget_s: Optional[float] = None) -> list:
        """The (code_node, a_block, r_block) share of stripe (key, t) on
        ``phys`` — every read path funnels through here so drills can
        inject per-node read faults.  Matched ``corrupt`` rules return a
        damaged COPY (backing storage intact — the read-path bit-rot a
        CRC-checking caller must catch); ``latency`` sleeps; transient
        kinds retry under the policy, capped by ``budget_s`` when a
        serving deadline bounds the fetch (DESIGN.md §13.1).  Raises
        ``KeyError`` when the share is absent, ``GiveUpError`` when the
        retry budget is spent."""
        if self.faults is None:
            return self._shares[phys - 1][(key, t)]
        ref = f"node:{phys:02d}"
        return self.retry.call(
            lambda: self.faults.apply_share(
                "read", ref, self._shares[phys - 1][(key, t)]),
            op=f"read:{ref}", stats=self.retry_stats, budget_s=budget_s)

    def _read_share(self, phys: int, key: str, t: int) -> list:
        return self.read_share(phys, key, t)

    def _read_share_verified(self, phys: int, key: str, t: int,
                             attempts: int = 3) -> list:
        """A share fetch CRC-gated against the put-time ledger — the
        read path feeding repairs and degraded decodes, where one
        corrupt helper silently poisons every rebuilt block.  A
        mismatch is re-read (transient read-path flip); persistent
        mismatch raises :class:`ShareIntegrityError` (storage rot —
        decode around it, don't decode FROM it).  Objects without a
        ledger pass through unchecked."""
        stat = self._stats.get(key)
        for _ in range(attempts):
            share = self.read_share(phys, key, t)
            if stat is None or stat.share_crcs is None \
                    or self._share_crc_of(stat, share) == \
                    stat.share_crcs[t][share[0] - 1]:
                return share
        raise ShareIntegrityError(phys, key, t, attempts)

    # ------------------------------------------------------- code classes
    def class_of(self, key: str) -> CodeClass:
        """The code class ``key`` was stored under (DESIGN.md §15.1)."""
        return self._stat_class(self.stat(key))

    def _stat_class(self, stat: ObjectStat) -> CodeClass:
        return stat.code_class if stat.code_class is not None \
            else self.default_class

    def _is_default(self, cc: CodeClass) -> bool:
        return cc == self.default_class

    def _codec_for(self, cc: CodeClass) -> StripeCodec:
        """The (cached) stripe codec of a code class.  The default class
        wraps the store's live code instance, so its planner, decode
        inverses and plan keys are shared with the legacy paths; other
        classes build their family from the registry on the same layout
        and mesh (raises if the layout cannot place them rack-safely)."""
        codec = self._codecs.get(cc.key())
        if codec is None:
            if self._is_default(cc):
                code = DoubleCirculantCode(cc, inner=self.code)
            else:
                code = make_code(cc, mesh=self.code.mesh)
            codec = StripeCodec(code, self.layout, stripe_symbols=self.S)
            self._codecs[cc.key()] = codec
        return codec

    def codec_of(self, key: str) -> StripeCodec:
        """The stripe codec serving ``key`` (placement, geometry, and the
        live :class:`~repro.codes.base.ErasureCode`)."""
        return self._codec_for(self.class_of(key))

    def _share_crc_of(self, stat: ObjectStat, share: list) -> int:
        """Put-time CRC formula of a share under the object's family."""
        cc = self._stat_class(stat)
        if self._is_default(cc):
            return share_crc(share[1], share[2])
        return self._codec_for(cc).code.share_crc_blocks(share[1:])

    # -------------------------------------------------------------- put path
    def put(self, key: str, obj: Any, *, meta: Optional[dict] = None,
            code_class: Optional[CodeClass] = None) -> ObjectStat:
        """Store ``obj`` (bytes or numpy array) under ``key``.

        The object is striped and encoded in ``put_tile_stripes``-wide
        windows, each ONE planned circulant dispatch (shape-bucketed
        AOT executable — no recompiles at steady state), with window
        t's share placement overlapping window t+1's encode through the
        store pipeline (DESIGN.md §11.3).  Shares whose placed node is
        FAILED are simply absent (lost-at-birth) — a later ``get``
        degrades around them and the scheduler can rebuild them once
        the slot is replaced.  Re-putting an existing key overwrites it.

        **Atomicity** (DESIGN.md §12.2): shares are *staged* while the
        windows stream and only installed — ``_stats`` entry last —
        after every share write succeeded.  A put that dies mid-flight
        (injected ``GiveUpError``, encode error) leaves the store
        exactly as it was: no partial shares, and on overwrite the old
        object still fully readable.

        ``code_class`` selects the erasure-code family the object is
        encoded with (DESIGN.md §15.1); ``None`` (and the store's
        default class) keeps the double-circulant fast paths.
        """
        dtype = shape = None
        if isinstance(obj, np.ndarray):
            dtype, shape = str(obj.dtype), tuple(obj.shape)
            payload = obj.tobytes()
        elif isinstance(obj, (bytes, bytearray, memoryview)):
            payload = bytes(obj)
        else:
            raise TypeError(f"store objects are bytes or numpy arrays, "
                            f"got {type(obj).__name__}")
        cc = code_class if code_class is not None else self.default_class
        if not self._is_default(cc):
            return self._put_generic(key, payload, dtype, shape, meta, cc)
        blocks, smap = self.stripes.chunk(payload,
                                          one_pass=self.staging_enabled)
        base = self._next_stripe
        self._next_stripe += smap.n_stripes
        tile = self.put_tile_stripes
        # staged installs keep views into the per-put block/redundancy
        # arrays (each share aliases a disjoint slice, so scrub and
        # fault drills behave identically); the legacy baseline copies
        copy_shares = not self.staging_enabled

        planner = getattr(self.code, "planner", None)

        def flatten_window(t0: int):
            # host transpose on the pool — overlaps the previous window's
            # encode and the one before's share placement.  With the
            # planner on, the transpose lands directly in a pooled,
            # bucket-padded staging buffer (zero-copy path, DESIGN.md
            # §16.1): the planner's pad stage sees an exact fit.
            tb = blocks[t0: t0 + tile]
            tt = tb.shape[0]
            buf = self._stage_into(planner, self.n, tt * self.S)
            if buf is None:
                return tt, self.stripes.flatten(tb)
            self.stripes.flatten(tb, out=buf[:, :tt * self.S])
            buf[:, tt * self.S:] = 0
            return tt, buf

        def encode_window(t0: int, flat):
            tt, view = flat
            return tt, self.code.encode_planned(view), view

        staged: list[tuple[int, int, list]] = []    # (phys, t, share)
        # put-time integrity ledger: share_crcs[t][j] covers EVERY share,
        # including lost-at-birth ones a repair rebuilds later bit-exactly
        crcs: list[list[int]] = [[0] * self.n for _ in range(smap.n_stripes)]

        def place_window(t0: int, res) -> None:
            tt, planned, view = res
            raw = planned.host()        # dispatch done: staging reusable
            if planner is not None:
                planner.staging.release(view)

            def install() -> None:
                # CRC + share copies off the critical thread: the pool
                # installs window t while window t+1's encode dispatches
                red = self.stripes.unflatten(raw[:, :tt * self.S], tt)
                for t in range(t0, t0 + tt):
                    pl = self.stripes.placement(base + t)
                    for j, phys in enumerate(pl):
                        a_blk, r_blk = blocks[t, j], red[t - t0, j]
                        crcs[t][j] = share_crc(a_blk, r_blk,
                                               zero_copy=not copy_shares)
                        if self.is_up(phys):
                            self._guard("write", phys)
                            if copy_shares:
                                a_blk, r_blk = a_blk.copy(), r_blk.copy()
                            staged.append((phys, t, [j + 1, a_blk, r_blk]))

            self._install(install)

        self.pipeline.map(range(0, smap.n_stripes, tile),
                          encode_window, place_window, read=flatten_window)
        # commit point: every share write succeeded.  Retire the old
        # generation (overwrite case), install the staged shares, and
        # only THEN publish the key — a crash or give-up before this
        # line leaves no observable trace of the new put.
        if key in self._stats:
            self.delete(key)
        for phys, t, share in staged:
            if self.is_up(phys):        # node may have died mid-put
                self._shares[phys - 1][(key, t)] = share
        stat = ObjectStat(key=key, size_bytes=smap.orig_bytes,
                          n_stripes=smap.n_stripes, stripe_symbols=self.S,
                          dtype=dtype, shape=shape, meta=dict(meta or {}),
                          share_crcs=crcs, code_class=self.default_class)
        stat.meta["_base_stripe"] = base
        self._stats[key] = stat
        self.metrics.record_put(smap.n_stripes * self.n * self.S,
                                2 * smap.n_stripes * self.n * self.S)
        return stat

    def _put_generic(self, key: str, payload: bytes, dtype, shape,
                     meta: Optional[dict], cc: CodeClass) -> ObjectStat:
        """Family-generic put (DESIGN.md §15.1): same windowed
        encode-overlaps-placement pipeline and the same commit-last
        atomicity as the default path, dispatched through the object's
        codec.  Shares are ``[code_node, blk_0, ..., blk_{q-1}]``."""
        codec = self._codec_for(cc)
        code = codec.code
        n, q, d_blocks = codec.n, code.share_blocks, code.data_blocks
        blocks, smap = codec.chunk(payload, one_pass=self.staging_enabled)
        copy_shares = not self.staging_enabled
        base = self._next_stripe
        self._next_stripe += smap.n_stripes
        tile = self.put_tile_stripes

        planner = getattr(code, "planner", None)

        def flatten_window(t0: int):
            tb = blocks[t0: t0 + tile]
            tt = tb.shape[0]
            buf = self._stage_into(planner, d_blocks, tt * self.S)
            if buf is None:
                return tt, codec.flatten(tb)
            codec.flatten(tb, out=buf[:, :tt * self.S])
            buf[:, tt * self.S:] = 0
            return tt, buf

        def encode_window(t0: int, flat):
            tt, view = flat
            return tt, code.encode_derived_planned(view), view

        staged: list[tuple[int, int, list]] = []    # (phys, t, share)
        crcs: list[list[int]] = [[0] * n for _ in range(smap.n_stripes)]

        def place_window(t0: int, res) -> None:
            tt, planned, view = res
            raw = planned.host()
            if planner is not None:
                planner.staging.release(view)

            def install() -> None:
                derived = codec.unflatten_rows(raw[:, :tt * self.S],
                                               code.derived_rows, tt)
                for t in range(t0, t0 + tt):
                    pl = codec.placement(base + t)
                    for j, phys in enumerate(pl):
                        blks = code.stripe_share_blocks(
                            blocks[t], derived[t - t0], j + 1)
                        crcs[t][j] = code.share_crc_blocks(blks)
                        if self.is_up(phys):
                            self._guard("write", phys)
                            arrs = [np.asarray(b, np.int32) for b in blks]
                            if copy_shares:
                                arrs = [b.copy() for b in arrs]
                            staged.append((phys, t, [j + 1] + arrs))

            self._install(install)

        self.pipeline.map(range(0, smap.n_stripes, tile),
                          encode_window, place_window, read=flatten_window)
        # commit point — identical semantics to the default path: retire
        # the old generation, install, publish the stat entry LAST
        if key in self._stats:
            self.delete(key)
        for phys, t, share in staged:
            if self.is_up(phys):
                self._shares[phys - 1][(key, t)] = share
        stat = ObjectStat(key=key, size_bytes=smap.orig_bytes,
                          n_stripes=smap.n_stripes, stripe_symbols=self.S,
                          dtype=dtype, shape=shape, meta=dict(meta or {}),
                          share_crcs=crcs, code_class=cc)
        stat.meta["_base_stripe"] = base
        self._stats[key] = stat
        self.metrics.record_put(smap.n_stripes * d_blocks * self.S,
                                smap.n_stripes * n * q * self.S)
        return stat

    # -------------------------------------------------------------- get path
    def get(self, key: str) -> Any:
        """The stored object, bit-exact, systematic when healthy and
        transparently degraded otherwise (see :meth:`get_ext`)."""
        return self.get_ext(key).obj

    def get_ext(self, key: str) -> GetResult:
        """Read with a receipt (bytes read, degraded stripes, latency).

        All missing data blocks of the request are batched: stripes are
        grouped by (helper subset, missing set) and each group is decoded
        in ONE cached-inverse matmul over the symbol-axis-concatenated
        downloads (DESIGN.md §10.2).  Groups run through the store
        pipeline — download gathering on the pool, the planned decode
        dispatch overlapped with the previous group's scatter
        (DESIGN.md §11.3).

        Raises
        ------
        UnknownKeyError
            Key never committed (a ``KeyError`` subclass).
        RuntimeError
            Some stripe has fewer than k shares left (data loss).
        """
        stat = self.stat(key)
        if not self._is_default(self._stat_class(stat)):
            return self._get_generic(stat)
        base = stat.meta["_base_stripe"]
        blocks = np.zeros((stat.n_stripes, self.n, self.S), np.int32)
        # group degraded stripes by failure pattern
        groups: dict[tuple, list[int]] = {}
        latency = 0.0
        bytes_read = 0
        for t in range(stat.n_stripes):
            pl = self.stripes.placement(base + t)
            present = self._present_code_nodes(key, t, pl)
            missing = tuple(j for j in range(self.n)
                            if j + 1 not in present)
            if not missing:
                for j in range(self.n):
                    blocks[t, j] = self._read_share(pl[j], key, t)[1]
                lat = self.link.fetch_s(self.S)
                self.metrics.record_read("systematic", lat, self.n * self.S)
                latency = max(latency, lat)
                bytes_read += self.n * self.S
                continue
            if len(present) < self.k:
                self.metrics.record_read("failed", 0.0, 0)
                raise RuntimeError(
                    f"data loss: stripe {t} of {key!r} has only "
                    f"{len(present)} of k={self.k} shares")
            helpers = tuple(sorted(present)[: self.k])
            # present data blocks are still served systematically — and
            # billed as such, one record per block, matching the cluster
            # simulator's read_all convention (the 2kS degraded billing
            # below covers only the decode download set)
            sys_lat = self.link.fetch_s(self.S)
            for j in range(self.n):
                if j + 1 in present:
                    blocks[t, j] = self._read_share(pl[j], key, t)[1]
                    self.metrics.record_read("systematic", sys_lat, self.S)
                    bytes_read += self.S
            latency = max(latency, sys_lat)
            groups.setdefault((helpers, missing), []).append(t)
        acct = {"bytes": 0, "latency": 0.0}
        planner = getattr(self.code, "planner", None)

        def gather(item):
            (helpers, _missing), ts = item
            # pooled, bucket-padded gather staging (DESIGN.md §16.1):
            # the per-stripe downloads land directly in the buffer the
            # decode dispatches over — no concatenate copy, no pad copy
            buf = self._stage_into(planner, 2 * self.k, len(ts) * self.S)
            if buf is None:
                return np.concatenate([self._downloads(key, t, helpers)
                                       for t in ts], axis=1)    # (2k, G*S)
            for g, t in enumerate(ts):
                buf[:, g * self.S:(g + 1) * self.S] = \
                    self._downloads(key, t, helpers)
            buf[:, len(ts) * self.S:] = 0
            return buf

        def decode(item, downloads):
            (helpers, missing), _ts = item
            mat = self.code.repair.decode_matrix(helpers)
            return self.code.repair.apply_planned(mat[list(missing)],
                                                  downloads), downloads

        def scatter(item, res) -> None:
            (_helpers, missing), ts = item
            planned, downloads = res
            decoded = planned.host()
            if planner is not None:
                planner.staging.release(downloads)
            for g, t in enumerate(ts):
                blocks[t, list(missing)] = \
                    decoded[:, g * self.S:(g + 1) * self.S]
            lat = self.link.degraded_read_s(2 * self.S, [1.0] * self.k)
            # one download set per stripe in the group
            for _ in ts:
                self.metrics.record_read("degraded", lat, 2 * self.k * self.S)
            acct["latency"] = max(acct["latency"], lat)
            acct["bytes"] += 2 * self.k * self.S * len(ts)

        self.pipeline.map(groups.items(), decode, scatter, read=gather)
        latency = max(latency, acct["latency"])
        bytes_read += acct["bytes"]
        return GetResult(obj=self.materialize(stat, blocks),
                         bytes_read=bytes_read,
                         degraded_stripes=sum(len(v) for v in groups.values()),
                         latency_s=latency)

    def _get_generic(self, stat: ObjectStat) -> GetResult:
        """Family-generic read (DESIGN.md §15.1): systematic payload
        rows served raw, missing rows decoded through the object's
        family — grouped by failure pattern, one cached-inverse matmul
        per group over symbol-axis-concatenated downloads, exactly the
        default path's shape."""
        key = stat.key
        cc = self._stat_class(stat)
        codec = self._codec_for(cc)
        code = codec.code
        n, k, q = codec.n, codec.k, code.share_blocks
        d_blocks = code.data_blocks
        base = stat.meta["_base_stripe"]
        locs = [code.data_location(m) for m in range(d_blocks)]
        blocks = np.zeros((stat.n_stripes, d_blocks, self.S), np.int32)
        groups: dict[tuple, list[int]] = {}
        latency = 0.0
        bytes_read = 0
        for t in range(stat.n_stripes):
            pl = codec.placement(base + t)
            present = self._present_code_nodes(key, t, pl)
            missing_rows = tuple(m for m, (j, _b) in enumerate(locs)
                                 if j not in present)
            if not missing_rows:
                for m, (j, b) in enumerate(locs):
                    blocks[t, m] = self._read_share(pl[j - 1], key, t)[1 + b]
                lat = self.link.fetch_s(q * self.S)
                self.metrics.record_read("systematic", lat, d_blocks * self.S)
                latency = max(latency, lat)
                bytes_read += d_blocks * self.S
                continue
            if len(present) < k:
                self.metrics.record_read("failed", 0.0, 0)
                raise RuntimeError(
                    f"data loss: stripe {t} of {key!r} has only "
                    f"{len(present)} of k={k} shares")
            helpers = tuple(sorted(present)[:k])
            sys_lat = self.link.fetch_s(q * self.S)
            for m, (j, b) in enumerate(locs):
                if j in present:
                    blocks[t, m] = self._read_share(pl[j - 1], key, t)[1 + b]
                    self.metrics.record_read("systematic", sys_lat, self.S)
                    bytes_read += self.S
            latency = max(latency, sys_lat)
            groups.setdefault((helpers, missing_rows), []).append(t)
        acct = {"bytes": 0, "latency": 0.0}
        planner = getattr(code, "planner", None)

        def gather(item):
            (helpers, _missing), ts = item
            buf = self._stage_into(planner, k * q, len(ts) * self.S)
            if buf is None:
                return np.concatenate(
                    [self._downloads_generic(key, t, helpers, codec)
                     for t in ts], axis=1)                # (k*q, G*S)
            for g, t in enumerate(ts):
                buf[:, g * self.S:(g + 1) * self.S] = \
                    self._downloads_generic(key, t, helpers, codec)
            buf[:, len(ts) * self.S:] = 0
            return buf

        def decode(item, downloads):
            (helpers, missing), _ts = item
            return code.apply_planned(
                code.decode_rows(helpers, list(missing)), downloads), \
                downloads

        def scatter(item, res) -> None:
            (_helpers, missing), ts = item
            planned, downloads = res
            decoded = planned.host()
            if planner is not None:
                planner.staging.release(downloads)
            for g, t in enumerate(ts):
                blocks[t, list(missing)] = \
                    decoded[:, g * self.S:(g + 1) * self.S]
            lat = self.link.degraded_read_s(q * self.S, [1.0] * k)
            for _ in ts:
                self.metrics.record_read("degraded", lat, k * q * self.S)
            acct["latency"] = max(acct["latency"], lat)
            acct["bytes"] += k * q * self.S * len(ts)

        self.pipeline.map(groups.items(), decode, scatter, read=gather)
        latency = max(latency, acct["latency"])
        bytes_read += acct["bytes"]
        return GetResult(obj=self.materialize(stat, blocks),
                         bytes_read=bytes_read,
                         degraded_stripes=sum(len(v) for v in groups.values()),
                         latency_s=latency)

    def _downloads_generic(self, key: str, t: int, helpers: Sequence[int],
                           codec: StripeCodec) -> np.ndarray:
        """(k*q, S) stacked helper blocks in the family's
        ``helper_block_ids`` order — CRC-verified like the default
        path's ``_downloads``."""
        base = self.stat(key).meta["_base_stripe"]
        pl = codec.placement(base + t)
        shares = {j: self._read_share_verified(pl[j - 1], key, t)
                  for j in helpers}
        return np.stack([np.asarray(shares[j][1 + b], np.int32)
                         for j, b in codec.code.helper_block_ids(helpers)])

    def materialize(self, stat: ObjectStat, blocks: np.ndarray) -> Any:
        """(n_stripes, D, S) data blocks -> the stored object (bytes or
        the original array type) — the shared tail of every read path
        (``get_ext`` and the serving front end's coalesced decodes).
        D is the object's family payload width (n for the default
        double-circulant class)."""
        cc = self._stat_class(stat)
        if self._is_default(cc):
            payload = self.stripes.assemble(
                blocks, StripeMap(stat.size_bytes, stat.n_stripes, self.S))
        else:
            payload = self._codec_for(cc).assemble(
                blocks, StripeMap(stat.size_bytes, stat.n_stripes, self.S))
        if stat.dtype is None:
            return payload
        return np.frombuffer(payload, dtype=np.dtype(stat.dtype)) \
            .reshape(stat.shape).copy()

    def _present_code_nodes(self, key: str, t: int,
                            pl: Sequence[int]) -> set[int]:
        return {j + 1 for j, phys in enumerate(pl)
                if (key, t) in self._shares[phys - 1]}

    def placement_of(self, key: str, t: int) -> tuple[int, ...]:
        """Physical nodes hosting stripe ``t`` of ``key``, by code node
        (index j holds code node j+1) — the front end's placement seam.
        Length is the object's family n (the default class's n for
        legacy objects)."""
        stat = self.stat(key)
        base = stat.meta["_base_stripe"]
        cc = self._stat_class(stat)
        if self._is_default(cc):
            return self.stripes.placement(base + t)
        return self._codec_for(cc).placement(base + t)

    def present_code_nodes(self, key: str, t: int) -> set[int]:
        """Code nodes (1-indexed) of stripe (key, t) whose share is
        physically present."""
        return self._present_code_nodes(key, t, self.placement_of(key, t))

    def _downloads(self, key: str, t: int,
                   helpers: Sequence[int]) -> np.ndarray:
        """(2k, S) stacked [data; red] blocks of the helper code nodes —
        CRC-verified: a decode matmul multiplies every helper into every
        output, so one rotten input corrupts the whole stripe."""
        pl = self.stripes.placement(self.stat(key).meta["_base_stripe"] + t)
        shares = [self._read_share_verified(pl[i - 1], key, t)
                  for i in helpers]
        return np.concatenate([np.stack([s[1] for s in shares]),
                               np.stack([s[2] for s in shares])], axis=0)

    # ----------------------------------------------------------- delete/stat
    def delete(self, key: str) -> None:
        """Drop the object and notify subscribers with a ``delete`` event
        so the repair scheduler purges its queued tasks instead of
        re-validating them forever.  Raises :class:`UnknownKeyError`."""
        stat = self.stat(key)
        for t in range(stat.n_stripes):
            for shares in self._shares:
                shares.pop((key, t), None)
        del self._stats[key]
        self._notify(Event(t=0.0, kind="delete", key=key))

    def stat(self, key: str) -> ObjectStat:
        try:
            return self._stats[key]
        except KeyError:
            raise UnknownKeyError(key) from None

    def keys(self) -> list[str]:
        return sorted(self._stats)

    # -------------------------------------------------------- pytree objects
    def put_pytree(self, key: str, tree: Any) -> ObjectStat:
        """Store a JAX/numpy pytree as one object (serving integration:
        `ServingEngine.from_coded_store(model, store, key=...)`)."""
        payload, treedef, metas = placement.pytree_to_bytes(tree)
        return self.put(key, payload,
                        meta={"treedef": treedef, "leaves": metas})

    def get_pytree(self, key: str) -> Any:
        stat = self.stat(key)
        if "treedef" not in stat.meta:
            raise TypeError(f"{key!r} was not stored with put_pytree")
        payload = self.get(key)
        leaves = placement.bytes_to_leaves(payload, stat.meta["leaves"])
        return jax.tree_util.tree_unflatten(stat.meta["treedef"], leaves)

    # ------------------------------------------------------- code conversion
    def convert(self, key: str,
                target_class: CodeClass) -> ConvertReceipt:
        """Re-encode ``key`` under ``target_class``, online and atomic
        (DESIGN.md §15.3).

        The object is read through the normal (possibly degraded) read
        path — systematic source shares are reused raw, only missing
        payload rows are decoded — and re-put under the target family.
        The put's commit-last protocol makes the switch atomic: shares
        are staged first, the old generation is retired and the manifest
        republished only after every target share write succeeded.  A
        crash mid-convert (injected ``GiveUpError``, encode failure)
        leaves the source object fully readable and nothing but staged
        garbage ``gc_orphans`` collects — reads are served throughout.

        Converting to the class the object already has is a no-op.
        """
        stat = self.stat(key)
        source = self._stat_class(stat)
        if target_class == source:
            return ConvertReceipt(
                key=key, source=source, target=target_class,
                payload_bytes=stat.size_bytes,
                source_stripes=stat.n_stripes,
                target_stripes=stat.n_stripes,
                degraded_source_stripes=0, bytes_read=0, latency_s=0.0)
        if not self._is_default(target_class):
            # fail fast (unknown family, unsafe layout) BEFORE reading
            self._codec_for(target_class)
        res = self.get_ext(key)
        meta = {mk: mv for mk, mv in stat.meta.items()
                if mk != "_base_stripe"}
        new_stat = self.put(key, res.obj, meta=meta,
                            code_class=target_class)
        return ConvertReceipt(
            key=key, source=source, target=target_class,
            payload_bytes=stat.size_bytes,
            source_stripes=stat.n_stripes,
            target_stripes=new_stat.n_stripes,
            degraded_source_stripes=res.degraded_stripes,
            bytes_read=res.bytes_read, latency_s=res.latency_s)

    # ------------------------------------------------------- repair surface
    def stripe_refs(self) -> Iterator[tuple[str, int]]:
        """All (key, stripe) pairs currently stored."""
        for key, stat in self._stats.items():
            for t in range(stat.n_stripes):
                yield key, t

    def stripes_on(self, node: int) -> list[tuple[str, int]]:
        """Stripes that PLACE a share on ``node`` (present or lost) —
        what a failure of ``node`` puts at risk."""
        self._check_node(node)
        out = []
        for key, t in self.stripe_refs():
            if node in self.placement_of(key, t):
                out.append((key, t))
        return out

    def lost_code_nodes(self, key: str, t: int) -> tuple[int, ...]:
        """Code nodes (1-indexed) of stripe (key, t) whose share is absent
        — lost to failures, or never written (placed on a dead node)."""
        pl = self.placement_of(key, t)
        present = self._present_code_nodes(key, t, pl)
        return tuple(i for i in range(1, len(pl) + 1) if i not in present)

    def embedded_helpers_present(self, key: str, t: int,
                                 code_node: int) -> bool:
        """True when a d-helper regeneration plan for ``code_node`` is
        available from the shares actually present — for the default
        double-circulant class that means its d = k+1 DETERMINED helpers
        all hold their shares (the cheap (k+1)S regeneration); other
        families consult their own ``repair_plan`` (product-matrix
        accepts ANY d present helpers)."""
        stat = self.stat(key)
        if self._is_default(self._stat_class(stat)):
            base = stat.meta["_base_stripe"]
            pl = self.stripes.placement(base + t)
            plan = self.code.repair_plan(code_node)
            shares = self._shares
            needed = (plan.prev_node,) + plan.next_nodes
            return all((key, t) in shares[pl[i - 1] - 1] for i in needed)
        return self.regen_plan_for(key, t, code_node) is not None

    def regen_plan_for(self, key: str, t: int, code_node: int):
        """The object's family :class:`~repro.codes.base.CodeRepairPlan`
        for regenerating ``code_node`` from the shares present, or None
        when the family cannot build one (fall back to full decode)."""
        codec = self.codec_of(key)
        pl = self.placement_of(key, t)
        present = sorted(self._present_code_nodes(key, t, pl))
        return codec.code.repair_plan(code_node, available=present)

    def repair_stripes_embedded(self, tasks: Sequence[tuple[str, int, int]],
                                ) -> tuple[int, int]:
        """Regenerate one lost share per task through coalesced
        ``regenerate_batch`` dispatches (the scheduler's path,
        DESIGN.md §10.3), pipelined in ``repair_tile_tasks``-wide
        windows: window t's helper gathering runs on the pool and its
        share writes overlap window t+1's planned vmapped dispatch
        (§11.3).  The batch axis is bucketed, so drains of different
        sizes share one executable.

        tasks: (key, stripe, lost_code_node) triples, each single-loss
        with a regeneration plan available (caller-checked).  The
        default class's repair matrix is node-invariant, so stripes that
        lost DIFFERENT code nodes still share a vmapped dispatch; tasks
        of other code classes regenerate through their family's plan
        (``d * S`` symbols each, one dispatch per task — only families
        with ``supports_batched_regen()`` coalesce).  Returns (symbols
        moved, dispatch count).
        """
        if not tasks:
            return 0, 0
        legacy, generic = [], []
        for task in tasks:
            (legacy if self._is_default(self.class_of(task[0]))
             else generic).append(task)
        if generic:
            symbols, dispatches = self._repair_generic(generic)
            if legacy:
                s2, d2 = self.repair_stripes_embedded(legacy)
                symbols, dispatches = symbols + s2, dispatches + d2
            return symbols, dispatches
        tasks = legacy
        tile = self.repair_tile_tasks
        windows = [tasks[i: i + tile] for i in range(0, len(tasks), tile)]

        def gather(window):
            r_prevs, helper_data, placements = [], [], []
            for key, t, node in window:
                base = self.stat(key).meta["_base_stripe"]
                pl = self.stripes.placement(base + t)
                plan = self.code.repair_plan(node)
                r_prevs.append(self._read_share_verified(
                    pl[plan.prev_node - 1], key, t)[2])
                helper_data.append(np.stack(
                    [self._read_share_verified(pl[i - 1], key, t)[1]
                     for i in plan.next_nodes]))
                placements.append(pl)
            return np.stack(r_prevs), np.stack(helper_data), placements

        def regen(window, gathered):
            r_prevs, helper_data, placements = gathered
            res = self.code.repair.regenerate_batch_planned(
                [node for _, _, node in window], r_prevs, helper_data)
            return res, placements

        def land(window, out) -> None:
            res, placements = out
            pairs = res.host()

            def install() -> None:
                # share copies off the critical thread (DESIGN.md §16.3)
                for (key, t, node), pl, pair in zip(window, placements,
                                                    pairs):
                    phys = pl[node - 1]
                    if not self.is_up(phys):
                        raise RuntimeError(f"replace node {phys} before "
                                           f"repairing onto it")
                    self._guard("write", phys)
                    blks = ([pair[0], pair[1]] if self.staging_enabled
                            else [pair[0].copy(), pair[1].copy()])
                    self._shares[phys - 1][(key, t)] = [node] + blks

            self._install(install)

        self.pipeline.map(windows, regen, land, read=gather)
        return len(tasks) * (self.k + 1) * self.S, len(windows)

    def _repair_generic(self, tasks: Sequence[tuple[str, int, int]],
                        ) -> tuple[int, int]:
        """Family-generic single-loss repairs.  Families whose
        ``supports_batched_regen()`` is True coalesce into windowed
        per-element batched dispatches (``matmul_batch`` — one dispatch
        per ``repair_tile_tasks`` window even though the newcomer
        matrices differ per task, DESIGN.md §16.5); the rest keep the
        one-dispatch-per-task plan path.  Returns (symbols moved,
        dispatch count)."""
        symbols = dispatches = 0
        by_codec: dict[tuple, tuple[StripeCodec, list]] = {}
        for task in tasks:
            codec = self.codec_of(task[0])
            by_codec.setdefault(self.class_of(task[0]).key(),
                                (codec, []))[1].append(task)
        for codec, group in by_codec.values():
            if not codec.code.supports_batched_regen():
                for key, t, node in group:
                    symbols += self._repair_stripe_regen(key, t, node)
                    dispatches += 1
                continue
            s2, d2 = self._repair_generic_batched(codec, group)
            symbols, dispatches = symbols + s2, dispatches + d2
        return symbols, dispatches

    def _repair_generic_batched(self, codec: StripeCodec,
                                tasks: Sequence[tuple[str, int, int]],
                                ) -> tuple[int, int]:
        """Coalesced single-loss regeneration for one non-default
        family: window t's helper sends gather on the pool, each window
        is ONE ``regenerate_many_planned`` dispatch (the (F, q, d)
        newcomer-matrix stack rides the batched per-element matmul),
        and installs overlap the next window's dispatch."""
        code = codec.code
        tile = self.repair_tile_tasks
        windows = [tasks[i: i + tile] for i in range(0, len(tasks), tile)]
        moved = [0]

        def gather(window):
            plans, sends, placements = [], [], []
            for key, t, node in window:
                pl = self.placement_of(key, t)
                present = sorted(self._present_code_nodes(key, t, pl))
                plan = code.repair_plan(node, available=present)
                if plan is None:
                    raise RuntimeError(f"no regeneration plan for code "
                                       f"node {node} of stripe {t} of "
                                       f"{key!r}")
                sends.append(np.stack([
                    code.helper_send(
                        sm, self._read_share_verified(pl[h - 1], key, t)[1:])
                    for sm, h in zip(plan.send_matrices, plan.helpers)]))
                plans.append(plan)
                placements.append(pl)
            return plans, np.stack(sends), placements    # sends (F, d, S)

        def regen(window, gathered):
            plans, sends, placements = gathered
            return (code.regenerate_many_planned(plans, sends),
                    plans, placements)

        def land(window, out) -> None:
            res, plans, placements = out
            rebuilt = res.host()                         # (F, q, S)
            for (key, t, node), plan, pl, blks in zip(window, plans,
                                                      placements, rebuilt):
                phys = pl[node - 1]
                if not self.is_up(phys):
                    raise RuntimeError(f"replace node {phys} before "
                                       f"repairing onto it")
                self._guard("write", phys)
                self._shares[phys - 1][(key, t)] = \
                    [node] + (list(blks) if self.staging_enabled
                              else [b.copy() for b in blks])
                moved[0] += plan.d * self.S

        self.pipeline.map(windows, regen, land, read=gather)
        return moved[0], len(windows)

    def _repair_stripe_regen(self, key: str, t: int, node: int) -> int:
        """Bandwidth-optimal single-share regeneration through the
        object's family plan (the generic counterpart of the coalesced
        embedded path): helpers apply their (1, q) send matrices, the
        newcomer one (q, d) matmul.  Returns symbols moved: d * S."""
        codec = self.codec_of(key)
        code = codec.code
        pl = self.placement_of(key, t)
        present = sorted(self._present_code_nodes(key, t, pl))
        plan = code.repair_plan(node, available=present)
        if plan is None:
            raise RuntimeError(f"no regeneration plan for code node "
                               f"{node} of stripe {t} of {key!r}")
        sends = np.stack([
            code.helper_send(sm,
                             self._read_share_verified(pl[h - 1], key, t)[1:])
            for sm, h in zip(plan.send_matrices, plan.helpers)])
        rebuilt = code.regenerate(plan, sends)          # (q, S)
        phys = pl[node - 1]
        if not self.is_up(phys):
            raise RuntimeError(f"replace node {phys} before repairing "
                               f"onto it")
        self._guard("write", phys)
        self._shares[phys - 1][(key, t)] = \
            [node] + [np.asarray(b, np.int32).copy() for b in rebuilt]
        return plan.d * self.S

    def repair_stripe_full(self, key: str, t: int,
                           lost: Sequence[int]) -> int:
        """Multi-loss repair: ONE decode matmul rebuilds the stripe's data
        and every lost redundancy block (`reconstruct_with_repair`).
        Returns symbols moved: k * q * S total (2k * S for the default
        class), however many shares come back (ratio 1/F vs the RS
        baseline).
        """
        stat = self.stat(key)
        if not self._is_default(self._stat_class(stat)):
            return self._repair_stripe_full_generic(key, t, lost)
        base = stat.meta["_base_stripe"]
        pl = self.stripes.placement(base + t)
        present = sorted(self._present_code_nodes(key, t, pl))
        if len(present) < self.k:
            raise RuntimeError(f"stripe {t} of {key!r} unrecoverable")
        use = tuple(present[: self.k])
        downloads = self._downloads(key, t, use)
        # planned one-matmul decode + re-encode (combined matrix rides on
        # the cached inverse; same math as reconstruct_with_repair)
        mat = self.code.repair.decode_repair_matrix(use, list(lost))
        data, red_f = self.code.repair.split_decode_output(
            self.code.repair.apply_planned(mat, downloads).host())
        for j, node in enumerate(lost):
            phys = pl[node - 1]
            if not self.is_up(phys):
                raise RuntimeError(f"replace node {phys} before repairing "
                                   f"onto it")
            self._guard("write", phys)
            self._shares[phys - 1][(key, t)] = \
                [node, data[node - 1].copy(), red_f[j].copy()]
        return 2 * self.k * self.S

    def _repair_stripe_full_generic(self, key: str, t: int,
                                    lost: Sequence[int]) -> int:
        """Family-generic multi-loss repair: one ``share_rows`` matmul
        rebuilds every block of every lost node from a k-subset."""
        codec = self.codec_of(key)
        code = codec.code
        q = code.share_blocks
        pl = self.placement_of(key, t)
        present = sorted(self._present_code_nodes(key, t, pl))
        if len(present) < codec.k:
            raise RuntimeError(f"stripe {t} of {key!r} unrecoverable")
        use = tuple(present[: codec.k])
        downloads = self._downloads_generic(key, t, use, codec)
        mat = code.share_rows(use, list(lost))
        out = code.apply_planned(mat, downloads).host()
        for j, node in enumerate(lost):
            phys = pl[node - 1]
            if not self.is_up(phys):
                raise RuntimeError(f"replace node {phys} before repairing "
                                   f"onto it")
            self._guard("write", phys)
            self._shares[phys - 1][(key, t)] = \
                [node] + [out[j * q + b].copy() for b in range(q)]
        return codec.k * q * self.S

    def rs_baseline_symbols(self, n_shares: int) -> int:
        """What a classical [n, k] RS store would download to rebuild
        ``n_shares`` lost shares: the whole file per share (§II)."""
        return baselines.rs_scenario_repair_symbols(self.k, self.S, n_shares)

    def rs_baseline_symbols_for(self, key: str, n_shares: int) -> int:
        """Per-object RS re-download baseline: the object's family file
        size B = k * q * S per rebuilt share (equals the store-wide
        :meth:`rs_baseline_symbols` for default-class objects)."""
        cc = self.class_of(key)
        if self._is_default(cc):
            return self.rs_baseline_symbols(n_shares)
        code = self._codec_for(cc).code
        return n_shares * code.gamma_reconstruct_symbols(self.S)

    # ------------------------------------------------------ share integrity
    def share_intact(self, phys: int, key: str, t: int) -> Optional[bool]:
        """CRC-verify the STORED share directly (no fault seam): the
        front end's arbiter between storage bit-rot and a transient
        read-path flip after a fetched share fails its CRC
        (DESIGN.md §13.2).  ``None`` when the share is absent or the
        object predates CRC recording."""
        self._check_node(phys)
        share = self._shares[phys - 1].get((key, t))
        stat = self._stats.get(key)
        if share is None or stat is None or stat.share_crcs is None:
            return None
        return self._share_crc_of(stat, share) == \
            stat.share_crcs[t][share[0] - 1]

    def drop_share(self, phys: int, key: str, t: int) -> bool:
        """Erase one stored share (the quarantine path: a share whose
        storage failed its CRC is an erasure — reads decode around it
        and the scheduler rebuilds it).  True if a share was dropped."""
        self._check_node(phys)
        return self._shares[phys - 1].pop((key, t), None) is not None

    def scrub_node(self, phys: int) -> list[tuple[str, int]]:
        """Targeted integrity scrub of one node: CRC-verify every stored
        share on ``phys`` against its put-time ledger, bypassing the
        fault seam (re-admission gate of the quarantine state machine,
        DESIGN.md §13.3).  Returns the (key, stripe) mismatches; shares
        without a ledger entry are skipped, not flagged."""
        self._check_node(phys)
        bad = []
        for (key, t), share in self._shares[phys - 1].items():
            stat = self._stats.get(key)
            if stat is None or stat.share_crcs is None \
                    or t >= stat.n_stripes:
                continue
            if self._share_crc_of(stat, share) != \
                    stat.share_crcs[t][share[0] - 1]:
                bad.append((key, t))
        return sorted(bad)

    # ------------------------------------------------------------ inspection
    def audit(self) -> StoreAudit:
        """Walk every physically-held share and flag orphans — shares no
        committed object accounts for (DESIGN.md §12.2): unknown key,
        stripe index past the object's extent, a share sitting on a
        node its stripe's placement never assigned it to, or (new
        orphan class, DESIGN.md §13.2) a share whose content fails its
        put-time CRC — silent bit-rot ``gc_orphans`` converts into an
        honest erasure the scheduler can repair."""
        report = StoreAudit()
        for node0, shares in enumerate(self._shares):
            for (key, t), share in shares.items():
                report.shares_checked += 1
                stat = self._stats.get(key)
                if stat is None:
                    report.orphan_shares.append(
                        (node0 + 1, key, t, "unknown key"))
                elif t >= stat.n_stripes:
                    report.orphan_shares.append(
                        (node0 + 1, key, t, "stripe out of range"))
                else:
                    pl = self.placement_of(key, t)
                    if pl[share[0] - 1] != node0 + 1:
                        report.orphan_shares.append(
                            (node0 + 1, key, t, "placement mismatch"))
                    elif stat.share_crcs is not None and \
                            self._share_crc_of(stat, share) != \
                            stat.share_crcs[t][share[0] - 1]:
                        report.orphan_shares.append(
                            (node0 + 1, key, t, "crc mismatch"))
        return report

    def gc_orphans(self) -> int:
        """Drop every orphan share :meth:`audit` flags; returns how many
        were collected (startup-recovery hygiene, DESIGN.md §12.2)."""
        orphans = self.audit().orphan_shares
        for phys, key, t, _reason in orphans:
            self._shares[phys - 1].pop((key, t), None)
        return len(orphans)

    def verify(self) -> bool:
        """Ground-truth audit: no orphan shares, and every present share
        equals a fresh encode of its object (the simulator's
        ``bit_exact`` check, store-wide)."""
        if not self.audit().clean:
            return False
        for key, stat in self._stats.items():
            base = stat.meta["_base_stripe"]
            obj = self.get(key)
            payload = obj.tobytes() if isinstance(obj, np.ndarray) else obj
            cc = self._stat_class(stat)
            if not self._is_default(cc):
                if not self._verify_generic(key, stat, payload, cc):
                    return False
                continue
            blocks, smap = self.stripes.chunk(payload)
            red = self.stripes.encode(blocks)
            for t in range(stat.n_stripes):
                pl = self.stripes.placement(base + t)
                for j, phys in enumerate(pl):
                    share = self._shares[phys - 1].get((key, t))
                    if share is None:
                        continue
                    if not (np.array_equal(share[1], blocks[t, j])
                            and np.array_equal(share[2], red[t, j])):
                        return False
        return True

    def _verify_generic(self, key: str, stat: ObjectStat, payload: bytes,
                        cc: CodeClass) -> bool:
        """Ground-truth re-encode comparison for a non-default-class
        object: every present share block equals a fresh encode."""
        codec = self._codec_for(cc)
        code = codec.code
        blocks, _smap = codec.chunk(payload)
        derived = codec.encode_window(blocks)
        for t in range(stat.n_stripes):
            pl = codec.placement(stat.meta["_base_stripe"] + t)
            for j, phys in enumerate(pl):
                share = self._shares[phys - 1].get((key, t))
                if share is None:
                    continue
                expect = code.stripe_share_blocks(blocks[t], derived[t],
                                                  j + 1)
                if not all(np.array_equal(share[1 + b],
                                          np.asarray(expect[b], np.int32))
                           for b in range(code.share_blocks)):
                    return False
        return True

    def total_lost_shares(self) -> int:
        return sum(len(self.lost_code_nodes(key, t))
                   for key, t in self.stripe_refs())


__all__ = ["CodedObjectStore", "ObjectStat", "GetResult", "ConvertReceipt",
           "StoreAudit", "StoreMetrics", "UnknownKeyError",
           "ShareIntegrityError", "share_crc", "UP", "FAILED"]
