"""Cluster event model and scenario builders (DESIGN.md §9).

An event stream is the simulator's only input: a time-ordered sequence of
``Event`` records describing what the cluster experiences — node failures
(data lost), transient down/up (data intact, e.g. a rolling restart),
latent sector corruption, scrub passes, straggler onset/recovery, and
client block reads.  The builders at the bottom compose the streams the
paper's operational story cares about; each returns a :class:`Scenario`
the simulator (and ``benchmarks/bench_cluster.py``) can run unchanged.

Event kinds
-----------
``fail``     node crashes and loses its (a, r) pair — triggers repair
``down``     node unavailable but data intact (restart, network partition)
``up``       a ``down`` node rejoins with its data
``corrupt``  silent sector corruption of stored symbols (latent until scrub)
``scrub``    degraded-read verification pass; flagged nodes are repaired
``slow``     node becomes a straggler (service time x ``factor``)
``read``     client read of one data block (the serving workload)
``delete``   a store object was deleted (key in ``key``) — queue purge feed
"""
from __future__ import annotations

import dataclasses
from typing import Iterable, Sequence

from repro.core.placement import RackLayout

KINDS = ("fail", "down", "up", "corrupt", "scrub", "slow", "read", "delete")


@dataclasses.dataclass(frozen=True)
class Event:
    """One cluster event.

    Parameters
    ----------
    t : float
        Simulated time the event occurs at.
    kind : str
        One of :data:`KINDS`.
    node : int
        1-indexed node the event targets (0 for cluster-wide kinds).
    block : int
        For ``read``: 0-based data-block index to read.
    factor : float
        For ``slow``: service-time multiplier (1.0 restores full speed).
    where : str
        For ``corrupt``: which stored block to damage, ``"a"`` or ``"r"``.
    positions : tuple of int
        For ``corrupt``: symbol offsets to damage (empty = offset 0).
    key : str
        For ``delete``: the deleted object's store key (the repair
        scheduler drops that key's queued tasks on this event).
    """
    t: float
    kind: str
    node: int = 0
    block: int = 0
    factor: float = 1.0
    where: str = "a"
    positions: tuple[int, ...] = ()
    key: str = ""

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(f"unknown event kind {self.kind!r}; "
                             f"expected one of {KINDS}")
        if self.kind == "delete" and not self.key:
            raise ValueError("delete events carry the deleted store key")
        if self.where not in ("a", "r"):
            raise ValueError(f"corrupt target must be 'a' or 'r', "
                             f"got {self.where!r}")
        if self.kind in ("fail", "down", "up", "corrupt", "slow") \
                and self.node < 1:
            raise ValueError(f"{self.kind} events target a 1-indexed node, "
                             f"got node={self.node}")


# tiny constructors — keep scenario code readable
def fail(t: float, node: int) -> Event:
    return Event(t=t, kind="fail", node=node)


def down(t: float, node: int) -> Event:
    return Event(t=t, kind="down", node=node)


def up(t: float, node: int) -> Event:
    return Event(t=t, kind="up", node=node)


def corrupt(t: float, node: int, where: str = "a",
            positions: Sequence[int] = (0,)) -> Event:
    return Event(t=t, kind="corrupt", node=node, where=where,
                 positions=tuple(int(x) for x in positions))


def scrub(t: float) -> Event:
    return Event(t=t, kind="scrub")


def slow(t: float, node: int, factor: float) -> Event:
    return Event(t=t, kind="slow", node=node, factor=factor)


def read(t: float, block: int) -> Event:
    return Event(t=t, kind="read", block=block)


def delete(t: float, key: str) -> Event:
    return Event(t=t, kind="delete", key=key)


@dataclasses.dataclass(frozen=True)
class Scenario:
    """A named, time-ordered event stream plus its description."""
    name: str
    events: tuple[Event, ...]
    description: str = ""

    def __post_init__(self):
        object.__setattr__(self, "events",
                           tuple(sorted(self.events, key=lambda e: e.t)))

    @property
    def duration(self) -> float:
        return self.events[-1].t if self.events else 0.0


def read_traffic(n_blocks: int, *, t0: float = 0.0, t1: float = 10.0,
                 reads: int = 50, seed: int = 0) -> list[Event]:
    """A uniform client read workload: ``reads`` block reads spread evenly
    over [t0, t1), block indices cycling deterministically from ``seed``
    (no RNG — scenarios must replay identically across runs).  The unit
    stride guarantees every block is visited once ``reads >= n_blocks``,
    whatever ``n_blocks``."""
    if reads <= 0:
        return []
    dt = (t1 - t0) / reads
    return [read(t0 + i * dt, (seed + i) % n_blocks)
            for i in range(reads)]


# --------------------------------------------------------------- scenarios
def victim_reads(victims: Sequence[int], at: float, *,
                 burst: int = 4, window: float = 0.2) -> list[Event]:
    """Reads targeting the failed nodes' blocks right after ``at`` — the
    requests that must be served degraded while repair is in flight."""
    return [read(at + window * (i + 1) / (burst + 1), v - 1)
            for v in victims for i in range(burst)]


def single_node_loss(n: int, *, node: int = 3, at: float = 2.0,
                     horizon: float = 10.0, reads: int = 40,
                     seed: int = 0) -> Scenario:
    """One node crashes mid-traffic; the embedded d = k+1 repair rebuilds
    it while reads of its block transparently degrade."""
    ev = read_traffic(n, t1=horizon, reads=reads, seed=seed)
    ev.append(fail(at, node))
    ev += victim_reads([node], at)
    return Scenario("single_node_loss", tuple(ev),
                    f"node v_{node} fails at t={at} under read traffic")


def multi_node_loss(n: int, k: int, *, failures: int | None = None,
                    at: float = 2.0, horizon: float = 10.0,
                    reads: int = 40, seed: int = 1) -> Scenario:
    """``failures`` nodes (default the full n - k erasure budget) crash at
    the same instant — repaired together by the one-matmul multi-failure
    decode."""
    f = failures if failures is not None else n - k
    if not 1 <= f <= n - k:
        raise ValueError(f"failures must be in 1..{n - k}, got {f}")
    victims = [(2 + 3 * j) % n + 1 for j in range(f)]
    if len(set(victims)) < f:                      # tiny n: fall back dense
        victims = list(range(1, f + 1))
    ev = read_traffic(n, t1=horizon, reads=reads, seed=seed)
    ev += [fail(at, v) for v in victims]
    ev += victim_reads(victims, at, burst=2)
    return Scenario("multi_node_loss", tuple(ev),
                    f"{f} simultaneous failures ({victims}) at t={at}")


def latent_corruption(n: int, *, node: int = 2, at: float = 1.0,
                      scrub_at: float = 5.0, horizon: float = 10.0,
                      reads: int = 30, seed: int = 2) -> Scenario:
    """Silent sector corruption sits latent until a scrub pass re-derives
    every pair through the batched engine, flags the node and repairs it."""
    ev = read_traffic(n, t1=horizon, reads=reads, seed=seed)
    ev.append(corrupt(at, node, "a", positions=(0, 7)))
    ev.append(scrub(scrub_at))
    return Scenario("latent_corruption", tuple(ev),
                    f"v_{node} silently corrupted at t={at}, "
                    f"scrub at t={scrub_at}")


def straggler(n: int, *, node: int = 1, factor: float = 20.0,
              at: float = 1.0, until: float = 6.0, horizon: float = 10.0,
              reads: int = 40, seed: int = 3) -> Scenario:
    """A node slows by ``factor``; reads of its block route around it via
    the degraded path whenever that is faster (straggler mitigation)."""
    ev = read_traffic(n, t1=horizon, reads=reads, seed=seed)
    ev.append(slow(at, node, factor))
    ev.append(slow(until, node, 1.0))
    return Scenario("straggler", tuple(ev),
                    f"v_{node} runs {factor}x slow on [{at}, {until})")


def rack_failure(layout: RackLayout, k: int, *, rack: int = 0,
                 at: float = 2.0, horizon: float = 10.0, reads: int = 40,
                 seed: int = 4) -> Scenario:
    """A whole failure domain (rack) crashes at once — the correlated
    failure the placement layer must keep inside the n - k budget."""
    victims = layout.nodes_in(rack)
    if len(victims) > layout.n_nodes - k:
        raise ValueError(
            f"rack {rack} holds {len(victims)} nodes > n-k = "
            f"{layout.n_nodes - k}: layout cannot survive its loss")
    ev = read_traffic(layout.n_nodes, t1=horizon, reads=reads, seed=seed)
    ev += [fail(at, v) for v in victims]
    ev += victim_reads(victims, at, burst=2)
    return Scenario("rack_failure", tuple(ev),
                    f"rack {rack} ({victims}) lost at t={at}")


def rolling_restart(n: int, *, start: float = 1.0, dwell: float = 0.5,
                    reads_per_node: int = 6, seed: int = 5) -> Scenario:
    """Nodes restart one at a time (down -> up with data intact); reads of
    the restarting node's block degrade, zero repair traffic is moved."""
    ev: list[Event] = []
    t = start
    for node in range(1, n + 1):
        ev.append(down(t, node))
        ev.append(up(t + dwell, node))
        ev += read_traffic(n, t0=t, t1=t + dwell, reads=reads_per_node,
                           seed=seed + node)
        ev.append(read(t + dwell / 2, node - 1))    # the restarting node's
        t += dwell                                  # block: must degrade
    return Scenario("rolling_restart", tuple(ev),
                    f"sequential restart of all {n} nodes, dwell={dwell}")


def standard_scenarios(n: int, k: int, layout: RackLayout | None = None,
                       ) -> list[Scenario]:
    """The benchmark/test battery: every scenario class the tentpole names."""
    layout = layout or default_layout(n, k)
    return [
        single_node_loss(n),
        multi_node_loss(n, k),
        latent_corruption(n),
        straggler(n),
        rack_failure(layout, k),
        rolling_restart(n),
    ]


def default_layout(n: int, k: int) -> RackLayout:
    """The fewest racks (>= 2) whose max rack size fits the n - k erasure
    budget — the one rack-count formula the battery, the benchmark and
    the serving demo all share."""
    from repro.core.placement import rack_layout
    n_racks = max(2, -(-n // max(1, n - k)))
    return rack_layout(n, n_racks)


__all__ = ["Event", "Scenario", "KINDS", "fail", "down", "up", "corrupt",
           "scrub", "slow", "read", "delete", "read_traffic",
           "single_node_loss",
           "multi_node_loss", "latent_corruption", "straggler",
           "rack_failure", "rolling_restart", "standard_scenarios",
           "default_layout"]
