"""Event-driven cluster failure simulator (DESIGN.md §9).

Drives the PR 2 fused repair engine through realistic cluster dynamics:
the simulator owns the *actual* encoded bytes of every node (so repair
and degraded reads are real field computations, verifiable bit-exactly
against the original encode), a node-state machine (UP / DOWN / FAILED),
a deterministic latency model, and the repair policy:

* single failure with its embedded helpers up -> the fused (2, k+1)
  repair-matrix regeneration, gamma = (k+1) * S symbols moved;
* anything else (multi-failure, rack loss, helpers down) -> the one-matmul
  multi-failure decode (`reconstruct_with_repair`): 2k * S symbols moved
  TOTAL regardless of how many nodes come back;
* silent corruption -> latent until a ``scrub`` event re-derives every
  pair through the batched engine and repairs the flagged set.

Client reads are part of the event stream: a read of block a_j is served
systematically from its owner when that is the fastest available path,
and otherwise *transparently degrades* to a one-row cached-inverse decode
from the k fastest up nodes — the serving layer (`repro.serve.engine`)
builds directly on :meth:`ClusterSimulator.read_block`.
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Optional, Sequence

import numpy as np

from repro.core import baselines
from repro.core.circulant import CodeSpec
from repro.core.msr import DoubleCirculantMSR
from repro.core.placement import RackLayout

from .events import Event, Scenario
from .metrics import LinkModel, MetricsLog

UP, DOWN, FAILED = "up", "down", "failed"


@dataclasses.dataclass
class ScenarioReport:
    """Outcome of one scenario run.

    ``bit_exact`` is the simulator's ground-truth check: after the run,
    every node is UP and its stored (a, r) pair equals the original
    encode symbol-for-symbol.
    """
    name: str
    description: str
    metrics: dict
    bit_exact: bool
    final_states: tuple[str, ...]
    unserved_events: int = 0

    def to_json(self) -> dict:
        return {
            "scenario": self.name,
            "description": self.description,
            "bit_exact": self.bit_exact,
            "final_states": list(self.final_states),
            **self.metrics,
        }


class ClusterSimulator:
    """A [n = 2k, k] MSR storage cluster under an event stream.

    Parameters
    ----------
    spec : CodeSpec
        The validated double circulant code.
    data : ndarray, shape (n, S)
        Original data blocks; the simulator encodes redundancy itself so
        node contents are bit-exact ground truth.
    code : DoubleCirculantMSR, optional
        Share an existing code instance (and its decode-inverse cache).
    layout : RackLayout, optional
        Failure-domain map (for reporting; rack scenarios come from
        `events.rack_failure`).
    link : LinkModel, optional
        Latency model for simulated read/repair timing.
    repair_delay : float
        Simulated seconds between a failure and its repair completing;
        reads in that window run degraded.
    straggler_mitigation : bool
        When True, a read whose owner is slow is served degraded if the
        k-helper path is faster.
    """

    def __init__(self, spec: CodeSpec, data: np.ndarray, *,
                 code: Optional[DoubleCirculantMSR] = None,
                 layout: Optional[RackLayout] = None,
                 link: Optional[LinkModel] = None,
                 repair_delay: float = 0.25,
                 straggler_mitigation: bool = True):
        self.spec = spec
        self.k, self.n, self.p = spec.k, spec.n, spec.p
        data = np.asarray(data, np.int32) % spec.p
        if data.shape[0] != self.n:
            raise ValueError(f"expected {self.n} data blocks, "
                             f"got {data.shape[0]}")
        self.code = code or DoubleCirculantMSR(spec)
        self.layout = layout
        self.link = link or LinkModel()
        self.repair_delay = repair_delay
        self.straggler_mitigation = straggler_mitigation

        self._orig_a = data.copy()
        self._orig_r = np.asarray(self.code.encode(data), np.int32)
        self.node_a = self._orig_a.copy()
        self.node_r = self._orig_r.copy()
        self.S = data.shape[1]
        self.state = [UP] * self.n            # index 0 = node v_1
        self.slow = [1.0] * self.n
        self.metrics = MetricsLog()
        self.log: list[dict] = []
        self._subscribers: list = []

    # --------------------------------------------------------------- events
    def subscribe(self, fn) -> None:
        """Register ``fn(event)`` to receive every Event the simulator
        processes (scenario events AND interactively-injected failures).
        The store layer's `RepairScheduler.on_event` subscribes here so
        one failure feed can drive both the simulator's own single-stripe
        repair and the object store's repair queue (DESIGN.md §10.3)."""
        self._subscribers.append(fn)

    def _notify(self, event: Event) -> None:
        for fn in self._subscribers:
            fn(event)

    # ------------------------------------------------------------- node view
    def _check_node(self, node: int) -> int:
        if not 1 <= node <= self.n:
            raise ValueError(f"node {node} out of range 1..{self.n} "
                             f"(nodes are 1-indexed)")
        return node

    def is_up(self, node: int) -> bool:
        return self.state[node - 1] == UP

    def up_nodes(self) -> list[int]:
        return [i + 1 for i in range(self.n) if self.state[i] == UP]

    def _fastest_helpers(self, ups: list[int]) -> list[int]:
        """The k up nodes with the smallest service time, id-sorted so the
        subset is canonical for the decode-inverse cache."""
        return sorted(
            sorted(ups, key=lambda i: (self.slow[i - 1], i))[: self.k])

    # ---------------------------------------------------------------- reads
    def read_block(self, block: int, t: float = 0.0) -> Optional[np.ndarray]:
        """Serve data block ``a_block`` (0-based), degrading transparently.

        Returns the (S,) block, or None when fewer than k nodes are up
        (the only unservable case).  Path choice and latency are recorded
        in :attr:`metrics` (non-systematic serves also land in
        :attr:`log` with their time ``t``); silent corruption is served
        as stored (that is what makes it *latent* — only ``scrub``
        events catch it).
        """
        owner = block + 1
        ups = self.up_nodes()
        sys_ok = self.is_up(owner)
        sys_lat = self.link.fetch_s(self.S, self.slow[owner - 1]) \
            if sys_ok else None

        deg_lat = helpers = None
        if len(ups) >= self.k:
            helpers = self._fastest_helpers(ups)
            deg_lat = self.link.degraded_read_s(
                2 * self.S, [self.slow[h - 1] for h in helpers])

        use_degraded = (
            not sys_ok
            or (self.straggler_mitigation and deg_lat is not None
                and deg_lat < sys_lat))
        if not use_degraded and sys_ok:
            out = self.node_a[block]
            self.metrics.record_read(
                "systematic", sys_lat, self.S,
                corrupt=not np.array_equal(out, self._orig_a[block]))
            return out
        if helpers is None:
            self.metrics.record_read("failed", 0.0, 0)
            self.log.append({"t": t, "event": "read_failed", "block": block})
            return None
        out = self._degraded_decode(block, helpers)
        self.metrics.record_read(
            "degraded", deg_lat, 2 * self.k * self.S,
            corrupt=not np.array_equal(out, self._orig_a[block]))
        self.log.append({"t": t, "event": "degraded_read", "block": block,
                         "helpers": helpers})
        return out

    def read_all(self, t: float = 0.0) -> Optional[np.ndarray]:
        """Serve the full (n, S) data matrix — the serving layer's bulk
        read (e.g. re-materializing a model's parameters).

        Blocks whose owner is up are served systematically (raw bytes,
        zero field ops); all missing blocks come out of ONE cached-inverse
        decode matmul.  Returns None when fewer than k nodes are up.
        """
        ups = self.up_nodes()
        missing = [j for j in range(self.n) if not self.is_up(j + 1)]
        if missing and len(ups) < self.k:
            # the bulk read delivers nothing: no block is billed as served
            for b in range(self.n):
                self.metrics.record_read("failed", 0.0, 0)
                self.log.append({"t": t, "event": "read_failed", "block": b})
            return None
        out = np.empty((self.n, self.S), np.int32)
        for j in range(self.n):
            if j not in missing:
                out[j] = self.node_a[j]
                self.metrics.record_read(
                    "systematic", self.link.fetch_s(self.S, self.slow[j]),
                    self.S,
                    corrupt=not np.array_equal(out[j], self._orig_a[j]))
        if not missing:
            return out
        helpers = self._fastest_helpers(ups)
        idx = [h - 1 for h in helpers]
        downloads = np.concatenate([self.node_a[idx], self.node_r[idx]])
        mat = self.code.repair.decode_matrix(tuple(helpers))
        # planned dispatch (DESIGN.md §11): degraded serving stays
        # recompile-free however many distinct stream extents it sees
        decoded = self.code.repair.apply_planned(mat[missing],
                                                 downloads).host()
        lat = self.link.degraded_read_s(
            2 * self.S, [self.slow[h - 1] for h in helpers])
        for row, j in enumerate(missing):
            out[j] = decoded[row]
            # one download set serves every missing block: bill it once
            self.metrics.record_read(
                "degraded", lat, 2 * self.k * self.S if row == 0 else 0,
                corrupt=not np.array_equal(out[j], self._orig_a[j]))
        self.log.append({"t": t, "event": "degraded_read", "block": missing,
                         "helpers": helpers})
        return out

    def fail_node(self, node: int, t: float = 0.0) -> None:
        """Interactive failure injection (the serving demo's kill switch):
        marks the node FAILED and wipes its pair, but does NOT schedule
        the automatic repair — call :meth:`repair_now` when the newcomer
        is provisioned."""
        self._check_node(node)
        self.state[node - 1] = FAILED
        self.node_a[node - 1] = 0
        self.node_r[node - 1] = 0
        self.log.append({"t": t, "event": "fail", "node": node})
        self._notify(Event(t=t, kind="fail", node=node))

    def repair_now(self, t: float = 0.0) -> bool:
        """Repair every FAILED node immediately (see :meth:`_repair_failed`);
        False when fewer than k nodes are up."""
        return self._repair_failed(t)

    def _degraded_decode(self, block: int, helpers: list[int]) -> np.ndarray:
        """One-row cached-inverse decode: a_block = inv[block] @ downloads.

        The (n, n) inverse for the helper subset comes from the engine's
        LRU (`DecodeInverseCache`), so an outage's worth of degraded reads
        costs ONE `gf.gauss_inverse` — each read is a single (1, 2k) x
        (2k, S) dispatched matmul."""
        idx = [h - 1 for h in helpers]
        downloads = np.concatenate([self.node_a[idx], self.node_r[idx]])
        mat = self.code.repair.decode_matrix(tuple(helpers))
        return self.code.repair.apply_planned(mat[block:block + 1],
                                              downloads).host()[0]

    # --------------------------------------------------------------- repair
    def _repair_failed(self, t: float) -> bool:
        """Repair every currently-FAILED node; True if any work was done."""
        failed = [i + 1 for i in range(self.n) if self.state[i] == FAILED]
        if not failed:
            return True
        ups = self.up_nodes()
        if len(ups) < self.k:
            return False                        # postpone: not enough alive
        rs_base = baselines.rs_scenario_repair_symbols(
            self.k, self.S, len(failed))
        if len(failed) == 1 and self._embedded_helpers_up(failed[0]):
            f = failed[0]
            plan = self.code.repair_plan(f)
            pair = self.code.repair.regenerate_planned(
                f, self.node_r[plan.prev_node - 1],
                self.node_a[list(plan.data_indices)]).host()
            self.node_a[f - 1], self.node_r[f - 1] = pair[0], pair[1]
            moved = (self.k + 1) * self.S       # gamma, eq. (7)
            path = "regenerate"
        else:
            use = sorted(ups)[: self.k]
            idx = [u - 1 for u in use]
            data, red_f = self.code.repair.reconstruct_with_repair(
                use, self.node_a[idx], self.node_r[idx], failed)
            data = np.asarray(data, np.int32)
            red_f = np.asarray(red_f, np.int32)
            for j, f in enumerate(failed):
                self.node_a[f - 1] = data[f - 1]
                self.node_r[f - 1] = red_f[j]
            moved = 2 * self.k * self.S         # one decode download set
            path = "reconstruct"
        for f in failed:
            self.state[f - 1] = UP
        self.metrics.record_repair(len(failed), moved, rs_base)
        self.log.append({"t": t, "event": "repair", "path": path,
                         "nodes": failed, "symbols_moved": moved})
        return True

    def _embedded_helpers_up(self, node: int) -> bool:
        plan = self.code.repair_plan(node)
        return (self.is_up(plan.prev_node)
                and all(self.is_up(j) for j in plan.next_nodes))

    # ---------------------------------------------------------------- scrub
    def run_scrub(self, t: float = 0.0) -> tuple[int, ...]:
        """Degraded-read verification pass over the whole cluster.

        Stage 1 (localize): re-derive every node pair from its d = k+1
        helpers through the batched fused engine and compare bit-exactly.
        A corrupt block flags its own node AND every neighbour whose
        regeneration consumed it — the flagged set localizes, it does not
        convict (DESIGN.md §4).

        Stage 2 (convict + repair): decode the full file from a k-subset,
        re-encode, and rewrite every node whose stored pair disagrees.  If
        enough unflagged nodes exist they form the decode subset directly;
        otherwise the n cyclic k-windows are searched for the decode whose
        re-encode disagrees with the fewest nodes (a clean window's
        disagreement set is exactly the corrupt set).

        Requires all nodes up (a real scrubber skips unavailable ones);
        returns the stage-1 flagged set.
        """
        if any(s != UP for s in self.state):
            self.metrics.record_scrub_skipped()
            self.log.append({"t": t, "event": "scrub", "skipped": True})
            return ()
        nodes = list(range(1, self.n + 1))
        prev = np.asarray([self.code.repair_plan(i).prev_node - 1
                           for i in nodes])
        helper_idx = np.asarray([self.code.repair_plan(i).data_indices
                                 for i in nodes])
        derived = self.code.repair.regenerate_batch_planned(
            nodes, self.node_r[prev], self.node_a[helper_idx]).host()
        bad = ((derived[:, 0] != self.node_a).any(axis=1)
               | (derived[:, 1] != self.node_r).any(axis=1))
        flagged = tuple(int(i) + 1 for i in np.nonzero(bad)[0])
        self.metrics.record_scrub(2 * self.n * self.S, len(flagged))
        self.log.append({"t": t, "event": "scrub", "flagged": list(flagged)})
        if flagged:
            corrupt = self._convict(flagged)
            self.log.append({"t": t, "event": "scrub_repair",
                             "nodes": list(corrupt)})
        return flagged

    def _candidate_subsets(self, flagged: tuple[int, ...]):
        clean = [i for i in range(1, self.n + 1) if i not in flagged]
        if len(clean) >= self.k:
            yield tuple(sorted(clean)[: self.k])
            return
        for s0 in range(self.n):                # cyclic k-windows
            yield tuple(sorted((s0 + j) % self.n + 1 for j in range(self.k)))

    def _convict(self, flagged: tuple[int, ...]) -> tuple[int, ...]:
        """Stage-2 scrub resolution: best-consistency decode + rewrite."""
        best = None
        for subset in self._candidate_subsets(flagged):
            idx = [u - 1 for u in subset]
            downloads = np.concatenate([self.node_a[idx], self.node_r[idx]])
            data = np.asarray(self.code.repair.apply(
                self.code.repair.decode_matrix(subset), downloads), np.int32)
            red = np.asarray(self.code.encode(data), np.int32)
            disagree = tuple(
                int(i) + 1 for i in np.nonzero(
                    (data != self.node_a).any(axis=1)
                    | (red != self.node_r).any(axis=1))[0])
            if best is None or len(disagree) < len(best[0]):
                best = (disagree, data, red)
            if not disagree:
                break                 # decode agrees with every node: done
        disagree, data, red = best
        if disagree:
            self.node_a[:] = data
            self.node_r[:] = red
            self.metrics.record_repair(
                len(disagree), 2 * self.k * self.S,
                baselines.rs_scenario_repair_symbols(
                    self.k, self.S, len(disagree)))
        return disagree

    # ------------------------------------------------------------ event loop
    def run(self, scenario: Scenario) -> ScenarioReport:
        """Process the scenario's events in time order and report.

        Failures schedule an internal repair completion ``repair_delay``
        later; reads between failure and repair run degraded.  A repair
        blocked by too few up nodes retries after another delay.
        """
        heap: list[tuple[float, int, Optional[Event]]] = []
        seq = 0
        for e in scenario.events:
            heap.append((e.t, seq, e))
            seq += 1
        heapq.heapify(heap)
        retries = 0                 # CONSECUTIVE postponements; resets on
        while heap:                 # success so long scenarios can't starve
            t, _, e = heapq.heappop(heap)
            if e is None:                       # internal: repair completion
                if self._repair_failed(t):
                    retries = 0
                else:
                    retries += 1
                    if retries > 100:
                        raise RuntimeError(
                            "repair starved: fewer than k nodes up for "
                            f"{retries} consecutive attempts")
                    seq += 1
                    heapq.heappush(heap, (t + self.repair_delay, seq, None))
                continue
            if e.kind in ("fail", "down", "up", "corrupt", "slow"):
                self._check_node(e.node)
            if e.kind == "fail":
                self.state[e.node - 1] = FAILED
                self.node_a[e.node - 1] = 0     # contents are gone
                self.node_r[e.node - 1] = 0
                self.log.append({"t": t, "event": "fail", "node": e.node})
                seq += 1
                heapq.heappush(heap, (t + self.repair_delay, seq, None))
            elif e.kind == "down":
                if self.state[e.node - 1] == UP:
                    self.state[e.node - 1] = DOWN
                self.log.append({"t": t, "event": "down", "node": e.node})
            elif e.kind == "up":
                if self.state[e.node - 1] == DOWN:
                    self.state[e.node - 1] = UP
                self.log.append({"t": t, "event": "up", "node": e.node})
            elif e.kind == "corrupt":
                tgt = self.node_a if e.where == "a" else self.node_r
                pos = list(e.positions) or [0]
                tgt[e.node - 1, pos] = (tgt[e.node - 1, pos] + 1) % self.p
                self.log.append({"t": t, "event": "corrupt", "node": e.node,
                                 "where": e.where})
            elif e.kind == "scrub":
                self.run_scrub(t)
            elif e.kind == "slow":
                self.slow[e.node - 1] = e.factor
            elif e.kind == "read":
                self.read_block(e.block % self.n, t)
            # notify AFTER the event is applied, so subscribers observe
            # the same post-event state whichever injection path (run
            # loop or fail_node) delivered the failure
            self._notify(e)
        return self.report(scenario)

    def report(self, scenario: Scenario) -> ScenarioReport:
        ok = (all(s == UP for s in self.state)
              and np.array_equal(self.node_a, self._orig_a)
              and np.array_equal(self.node_r, self._orig_r))
        return ScenarioReport(name=scenario.name,
                              description=scenario.description,
                              metrics=self.metrics.summary(),
                              bit_exact=bool(ok),
                              final_states=tuple(self.state),
                              unserved_events=self.metrics.reads_failed)


def run_scenario(spec: CodeSpec, data: np.ndarray, scenario: Scenario,
                 **sim_kwargs) -> ScenarioReport:
    """One-shot convenience: fresh simulator, run, report."""
    return ClusterSimulator(spec, data, **sim_kwargs).run(scenario)


__all__ = ["ClusterSimulator", "ScenarioReport", "run_scenario",
           "UP", "DOWN", "FAILED"]
