"""Per-scenario accounting: bytes moved, read latency, availability
(DESIGN.md §9).

Two pieces:

* :class:`LinkModel` — a deterministic service-time model (per-request
  overhead + bytes / bandwidth, scaled by a per-node straggler factor).
  The simulator uses it to *choose* read paths (systematic vs degraded)
  and to report latency distributions without wall-clock noise; the
  benchmark separately measures real wall time for the decode matmuls.
* :class:`MetricsLog` — the accumulator every simulator action reports
  into: read counts by path, simulated latencies, repair/scrub traffic,
  and the RS re-download baseline the repair traffic is ratioed against.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class LinkModel:
    """Deterministic network/service model for simulated latencies.

    Parameters
    ----------
    bandwidth_bps : float
        Per-node sequential read bandwidth, bytes/second.
    request_overhead_s : float
        Fixed per-fetch overhead (connection + seek + RPC).
    decode_overhead_s : float
        Added cost of the degraded-read decode matmul.
    """
    bandwidth_bps: float = 1e9
    request_overhead_s: float = 1e-3
    decode_overhead_s: float = 5e-4

    def fetch_s(self, nbytes: int, slow_factor: float = 1.0) -> float:
        """Time to fetch ``nbytes`` from one node running at
        ``slow_factor`` x nominal service time."""
        return (self.request_overhead_s + nbytes / self.bandwidth_bps) \
            * slow_factor

    def degraded_read_s(self, helper_bytes: int,
                        slow_factors: list[float]) -> float:
        """A degraded read fans out to k helpers in parallel: latency is
        the slowest helper fetch plus the decode epilogue."""
        worst = max(slow_factors) if slow_factors else 1.0
        return self.fetch_s(helper_bytes, worst) + self.decode_overhead_s


class MetricsLog:
    """Accumulator for one scenario run.

    Every count is in symbols for traffic (1 symbol ~ 1 byte for GF(257)
    systematic blocks — the convention `MSRCheckpointer.gamma_bytes`
    uses) and in simulated seconds for latencies.
    """

    def __init__(self):
        self.reads_total = 0
        self.reads_systematic = 0
        self.reads_degraded = 0
        self.reads_failed = 0
        self.reads_corrupt = 0
        self.read_latencies: list[float] = []
        self.read_symbols = 0
        self.repair_events = 0
        self.repaired_nodes = 0
        self.repair_symbols = 0
        self.rs_baseline_symbols = 0
        self.scrub_passes = 0
        self.scrub_skipped = 0
        self.scrub_symbols = 0
        self.scrub_flagged = 0

    # ---------------------------------------------------------------- reads
    def record_read(self, path: str, latency_s: float, symbols: int,
                    *, corrupt: bool = False) -> None:
        """``path``: "systematic" | "degraded" | "failed".  ``corrupt``
        marks a read served from silently-damaged storage (latent until
        a scrub): the simulator knows ground truth, a real client would
        not."""
        self.reads_total += 1
        if path == "systematic":
            self.reads_systematic += 1
        elif path == "degraded":
            self.reads_degraded += 1
        elif path == "failed":
            self.reads_failed += 1
            return                      # no bytes served, no latency sample
        else:
            raise ValueError(path)
        if corrupt:
            self.reads_corrupt += 1
        self.read_latencies.append(latency_s)
        self.read_symbols += symbols

    # --------------------------------------------------------------- repair
    def record_repair(self, n_nodes: int, symbols_moved: int,
                      rs_baseline: int) -> None:
        self.repair_events += 1
        self.repaired_nodes += n_nodes
        self.repair_symbols += symbols_moved
        self.rs_baseline_symbols += rs_baseline

    def record_scrub(self, symbols_read: int, flagged: int) -> None:
        self.scrub_passes += 1
        self.scrub_symbols += symbols_read
        self.scrub_flagged += flagged

    def record_scrub_skipped(self) -> None:
        """A scheduled scrub that could not run (nodes unavailable) —
        counted separately so a skipped pass is never mistaken for a
        clean one."""
        self.scrub_skipped += 1

    # -------------------------------------------------------------- derived
    @property
    def availability(self) -> float:
        """Fraction of client reads that were servable (>= k nodes up)."""
        if self.reads_total == 0:
            return 1.0
        return 1.0 - self.reads_failed / self.reads_total

    @property
    def degraded_fraction(self) -> float:
        served = self.reads_total - self.reads_failed
        return self.reads_degraded / served if served else 0.0

    @property
    def repair_ratio_vs_rs(self) -> float | None:
        """Measured repair traffic over the RS re-download baseline —
        (k+1)/(2k) for a lone embedded repair, 1/F for an F-failure
        one-matmul batch; None when the scenario moved no repair bytes."""
        if self.rs_baseline_symbols == 0:
            return None
        return self.repair_symbols / self.rs_baseline_symbols

    def latency_stats(self) -> dict:
        lat = sorted(self.read_latencies)
        if not lat:
            return {"mean_s": 0.0, "p50_s": 0.0, "p99_s": 0.0, "max_s": 0.0}
        return {
            "mean_s": sum(lat) / len(lat),
            "p50_s": lat[len(lat) // 2],
            "p99_s": lat[min(len(lat) - 1, (99 * len(lat)) // 100)],
            "max_s": lat[-1],
        }

    def summary(self) -> dict:
        """JSON-ready roll-up (the per-scenario record in
        ``BENCH_cluster.json``)."""
        ratio = self.repair_ratio_vs_rs
        return {
            "reads": {
                "total": self.reads_total,
                "systematic": self.reads_systematic,
                "degraded": self.reads_degraded,
                "failed": self.reads_failed,
                "served_corrupt": self.reads_corrupt,
                "degraded_fraction": round(self.degraded_fraction, 4),
                "latency": {k: round(v, 6)
                            for k, v in self.latency_stats().items()},
            },
            "availability": round(self.availability, 4),
            "repair": {
                "events": self.repair_events,
                "nodes_repaired": self.repaired_nodes,
                "symbols_moved": self.repair_symbols,
                "rs_baseline_symbols": self.rs_baseline_symbols,
                "ratio_vs_rs": None if ratio is None else round(ratio, 4),
            },
            "scrub": {
                "passes": self.scrub_passes,
                "skipped": self.scrub_skipped,
                "symbols_read": self.scrub_symbols,
                "nodes_flagged": self.scrub_flagged,
            },
        }


__all__ = ["LinkModel", "MetricsLog"]
