"""Fault-injection drill harness (DESIGN.md §12.5).

Each drill is a scripted failure timeline run against the REAL
durability stack — `MSRCheckpointer` atop a fault-injected
`repro.io.BlobBackend`, `CodedObjectStore` with its per-node fault seam,
the `Supervisor`'s write-behind loop, the `RepairScheduler` — and every
drill's pass criterion is machine-checked:

* **bit-exact resume** — training state restored after the drill equals
  the no-fault reference run, element for element;
* **bounded data loss** — a crash loses at most the steps since the
  last *committed* generation (``data_loss_steps``);
* **zero orphans** — after recovery, no ``*.tmp`` residue on disk
  (`repro.io.count_tmp_orphans`) and a clean store ``audit()``.

The harness is deterministic end to end: the training step is an exact
int32 recurrence, fault rules fire from a seeded
`repro.io.FaultInjector`, and retry backoff jitter is hashed, not drawn
— two runs with the same seed take identical paths.  `run_drills` is
the entry point `benchmarks.bench_drills` (and the CI ``drill-smoke``
job) wraps; each drill returns a :class:`DrillResult`.

Drills double as executable documentation of the crash-consistency
contract: read ``crash_mid_save`` next to DESIGN.md §12.2 and each
assertion is one clause of the commit protocol.
"""
from __future__ import annotations

import dataclasses
import pathlib
import tempfile
import time
from typing import Callable, Optional, Sequence

import numpy as np

from repro.checkpoint.msr_checkpoint import MSRCheckpointer
from repro.core.circulant import CodeSpec
from repro.io import (FaultInjector, FaultyBlob, GiveUpError, LocalBlob,
                      count_tmp_orphans, fast_retry)
from repro.train.fault_tolerance import (FailureEvent, FailureInjector,
                                         Supervisor)


@dataclasses.dataclass
class DrillResult:
    """One drill's verdict — what `BENCH_drills.json` records per drill.

    ``bit_exact`` is the restored-state comparison against the no-fault
    reference; ``orphans`` counts post-recovery ``*.tmp`` residue (must
    be 0); ``data_loss_steps`` is how many steps of progress the crash
    cost (bounded by the checkpoint cadence); ``resumed_from`` is the
    generation recovery restored.  ``passed`` folds in every
    drill-specific assertion, not just the headline two.
    """
    name: str
    passed: bool
    bit_exact: bool
    orphans: int
    resumed_from: Optional[int] = None
    data_loss_steps: Optional[int] = None
    time_to_resume_s: float = 0.0
    detail: str = ""

    def to_json(self) -> dict:
        return dataclasses.asdict(self)


# ----------------------------------------------------- synthetic trainer
# An exact int32 recurrence: w_{t+1} = w_t + (t+1) * iota.  Deterministic,
# overflow-free at drill scale, and cheap — drills exercise the I/O stack,
# not the model.
_STATE_SYMBOLS = 4096


def _init_state() -> dict:
    return {"w": np.zeros(_STATE_SYMBOLS, np.int32),
            "b": np.arange(_STATE_SYMBOLS // 4, dtype=np.int32)}


def _data_fn(step: int) -> dict:
    return {"x": np.full(_STATE_SYMBOLS, step + 1, np.int32)}


def _step_fn(state: dict, batch: dict) -> tuple[dict, dict]:
    w = state["w"] + batch["x"]
    return ({"w": w, "b": state["b"] + 1},
            {"loss": float(batch["x"][0])})


def _run_reference(n_steps: int) -> dict:
    state = _init_state()
    for step in range(n_steps):
        state, _ = _step_fn(state, _data_fn(step))
    return state


def _states_equal(a: dict, b: dict) -> bool:
    return all(np.array_equal(np.asarray(a[k]), np.asarray(b[k]))
               for k in ("w", "b"))


def _spec() -> CodeSpec:
    return CodeSpec.make(3, 257)        # n = 6 nodes, survives 3 losses


def _ckpt(d: pathlib.Path, *, blob=None, faults: Optional[FaultInjector]
          = None) -> MSRCheckpointer:
    iob = blob
    if iob is None and faults is not None:
        iob = FaultyBlob(LocalBlob(fsync=False), faults)
    return MSRCheckpointer(d, _spec(),
                           io_backend=iob or LocalBlob(fsync=False),
                           retry=fast_retry())


# ---------------------------------------------------------------- drills
def crash_mid_save(root: pathlib.Path, seed: int = 0) -> DrillResult:
    """A write-behind save dies mid-write (every write into the step-10
    staging dir fails persistently).  The commit protocol must keep
    generation 5 intact and invisible damage: recovery restores step 5
    bit-exactly, loses exactly the 7 post-checkpoint steps, and leaves
    zero ``*.tmp`` orphans."""
    d = root / "crash_mid_save"
    n_steps, every = 12, 5
    faults = FaultInjector(seed=seed)
    faults.add(op="write", match="step_000010", kind="transient")
    ck = _ckpt(d, faults=faults)
    sup = Supervisor(ck, ckpt_every=every, write_behind=True,
                     on_save_error="log")
    sup.run(_init_state(), _step_fn, _data_fn, n_steps)
    ck.close()
    save_failed = any(e["event"] == "ckpt_failed" for e in sup.log)

    # restart: a fresh process recovers, then resumes from the last
    # committed generation
    t0 = time.perf_counter()
    ck2 = _ckpt(d)                       # clean blob; recover() runs here
    steps = ck2.steps()
    resumed_from = steps[-1] if steps else None
    state, _ = ck2.restore(_init_state(), resumed_from)
    t_resume = time.perf_counter() - t0
    bit_exact = _states_equal(state, _run_reference(resumed_from or 0))
    # resume training to the horizon: the replayed run must converge to
    # the no-fault final state (stateless data_fn => exact replay)
    sup2 = Supervisor(ck2, ckpt_every=every)
    final = sup2.run(state, _step_fn, _data_fn, n_steps - (resumed_from or 0),
                     start_step=resumed_from or 0)
    resumed_exact = _states_equal(final, _run_reference(n_steps))
    ck2.close()
    orphans = count_tmp_orphans(d)
    loss = n_steps - (resumed_from or 0)
    passed = (save_failed and resumed_from == 5 and bit_exact
              and resumed_exact and orphans == 0 and loss <= n_steps - every)
    return DrillResult("crash_mid_save", passed,
                       bit_exact and resumed_exact, orphans,
                       resumed_from=resumed_from, data_loss_steps=loss,
                       time_to_resume_s=t_resume,
                       detail=f"save_failed={save_failed} steps={steps}")


def kill_rack_write_behind(root: pathlib.Path, seed: int = 0) -> DrillResult:
    """Two-phase rack drill.  Phase A: a whole rack's node files become
    unwritable during the write-behind save of step 8 — the save gives
    up, the run continues on generation 4, recovery resumes from it.
    Phase B: after a clean commit, the rack dies AT REST (its node files
    deleted); ``restore(failed_nodes=...)`` must rebuild the pairs
    bit-exactly and a scrub must come back clean."""
    d = root / "kill_rack"
    n_steps, every = 10, 4
    rack = (1, 2)                        # n=6: within the n-k=3 budget
    faults = FaultInjector(seed=seed)
    for node in rack:
        faults.add(op="write", match=f"step_000008.tmp/node_{node:02d}",
                   kind="transient")
    ck = _ckpt(d, faults=faults)
    sup = Supervisor(ck, ckpt_every=every, write_behind=True,
                     on_save_error="log")
    sup.run(_init_state(), _step_fn, _data_fn, n_steps)
    ck.close()
    phase_a_failed = any(e["event"] == "ckpt_failed" for e in sup.log)

    t0 = time.perf_counter()
    ck2 = _ckpt(d)
    steps = ck2.steps()
    resumed_from = steps[-1] if steps else None
    state, _ = ck2.restore(_init_state(), resumed_from)
    t_resume = time.perf_counter() - t0
    phase_a_exact = _states_equal(state, _run_reference(resumed_from or 0))

    # phase B: commit a clean generation, then kill the rack at rest
    ck2.save(n_steps, _run_reference(n_steps))
    for node in rack:
        a, r = ck2._node_files(n_steps, node)
        ck2.iob.remove(a)
        ck2.iob.remove(r)
    state_b, rep = ck2.restore(_init_state(), n_steps,
                               failed_nodes=list(rack))
    phase_b_exact = (_states_equal(state_b, _run_reference(n_steps))
                     and rep.path == "reconstruct"
                     and rep.repaired_nodes == rack)
    scrub_clean = ck2.scrub(n_steps).clean
    ck2.close()
    orphans = count_tmp_orphans(d)
    passed = (phase_a_failed and resumed_from == 4 and phase_a_exact
              and phase_b_exact and scrub_clean and orphans == 0)
    return DrillResult("kill_rack_write_behind", passed,
                       phase_a_exact and phase_b_exact, orphans,
                       resumed_from=resumed_from,
                       data_loss_steps=n_steps - (resumed_from or 0),
                       time_to_resume_s=t_resume,
                       detail=f"phase_a_failed={phase_a_failed} "
                              f"repaired={rep.repaired_nodes} "
                              f"scrub_clean={scrub_clean}")


def _store_classes():
    # deferred: repro.store pulls in repro.cluster.events, so a
    # module-level import here would be circular via the package init
    from repro.store import CodedObjectStore, RepairScheduler
    return CodedObjectStore, RepairScheduler


def crash_mid_put(root: pathlib.Path, seed: int = 0) -> DrillResult:
    """A store ``put`` dies mid-flight (one node's share writes fail
    persistently).  Atomic-put contract: the key must not become
    visible, an overwritten key must keep its old value fully readable,
    and the audit must find zero orphan shares.  A hard-crash orphan
    (poked into node state directly) must be flagged and collected."""
    faults = FaultInjector(seed=seed)
    # n_nodes = n: every stripe places a share on EVERY node, so the
    # node:03 write fault is guaranteed to hit each put
    CodedObjectStore, _ = _store_classes()
    store = CodedObjectStore(_spec(), n_nodes=6, stripe_symbols=64,
                             faults=faults, retry=fast_retry())
    old = bytes(range(256)) * 4
    store.put("obj", old)
    faults.add(op="write", match="node:03", kind="transient")
    gave_up = False
    try:
        store.put("obj", bytes(reversed(old)))      # overwrite dies
    except GiveUpError:
        gave_up = True
    new_key_invisible = True
    try:
        store.put("fresh", b"zz" * 128)             # new key dies too
    except GiveUpError:
        new_key_invisible = "fresh" not in store.keys()
    faults.clear()
    t0 = time.perf_counter()
    old_intact = store.get("obj") == old
    t_resume = time.perf_counter() - t0
    audit_clean = store.audit().clean
    # hard-crash residue: a share no committed object accounts for
    store._shares[0][("ghost", 0)] = [1, np.zeros(64, np.int32),
                                      np.zeros(64, np.int32)]
    flagged = not store.audit().clean and not store.verify()
    collected = store.gc_orphans() == 1 and store.verify()
    store.close()
    passed = (gave_up and new_key_invisible and old_intact and audit_clean
              and flagged and collected)
    return DrillResult("crash_mid_put", passed, old_intact, 0,
                       time_to_resume_s=t_resume,
                       detail=f"gave_up={gave_up} "
                              f"new_key_invisible={new_key_invisible} "
                              f"orphan_flagged={flagged} "
                              f"orphan_collected={collected}")


def corrupt_then_scrub(root: pathlib.Path, seed: int = 0) -> DrillResult:
    """Silent on-disk corruption: a byte of one node's data block flips
    after commit.  The scrub's manifest content CRCs must convict that
    node exactly, ``repair_node`` must rebuild it from its d = k+1
    helpers, and the re-scrub + restore must be clean and bit-exact."""
    d = root / "corrupt_scrub"
    ck = _ckpt(d)
    state = _run_reference(7)
    ck.save(7, state)
    victim = 2
    a_path = ck._node_files(7, victim)[0]
    raw = bytearray(ck.iob.read(a_path))
    raw[-1] ^= 0xFF                      # payload byte, not the npy header
    ck.iob.write(a_path, bytes(raw))
    flagged = victim in ck.scrub(7).mismatched_nodes
    ck.repair_node(7, victim)
    rescrub_clean = ck.scrub(7).clean
    t0 = time.perf_counter()
    restored, _ = ck.restore(_init_state(), 7)
    t_resume = time.perf_counter() - t0
    bit_exact = _states_equal(restored, state)
    ck.close()
    orphans = count_tmp_orphans(d)
    passed = flagged and rescrub_clean and bit_exact and orphans == 0
    return DrillResult("corrupt_then_scrub", passed, bit_exact, orphans,
                       resumed_from=7, time_to_resume_s=t_resume,
                       detail=f"flagged={flagged} "
                              f"rescrub_clean={rescrub_clean}")


def restart_mid_drain(root: pathlib.Path, seed: int = 0) -> DrillResult:
    """The repair scheduler crashes with its queue half-drained.  A new
    scheduler has no memory of the failure events; ``enqueue_scan`` must
    rebuild the queue from store ground truth and ``drain_all`` must
    re-protect every stripe (verify() bit-exact, zero lost shares)."""
    rng = np.random.default_rng(seed)
    CodedObjectStore, RepairScheduler = _store_classes()
    store = CodedObjectStore(_spec(), n_nodes=8, stripe_symbols=64)
    for i in range(3):
        store.put(f"o{i}", rng.integers(0, 256, 2048).astype(np.uint8)
                  .tobytes())
    sched = RepairScheduler(store)
    store.subscribe(sched.on_event)
    store.fail_node(2)
    before = sched.pending()
    sched.drain(budget_symbols=(store.k + 1) * store.S)   # one stripe's worth
    partially_drained = 0 < sched.pending() < before
    del sched                                             # the "crash"

    t0 = time.perf_counter()
    sched2 = RepairScheduler(store)                       # fresh process
    rescanned = sched2.enqueue_scan()
    rep = sched2.drain_all()
    t_resume = time.perf_counter() - t0
    verified = store.verify() and store.total_lost_shares() == 0
    store.close()
    passed = (partially_drained and rescanned > 0 and rep.unrecoverable == 0
              and sched2.pending() == 0 and verified)
    return DrillResult("restart_mid_drain", passed, verified, 0,
                       time_to_resume_s=t_resume,
                       detail=f"queued={before} rescanned={rescanned} "
                              f"repaired={rep.repaired_stripes}")


def transient_fault_storm(root: pathlib.Path, seed: int = 0) -> DrillResult:
    """A storm of ~10%-probability transient faults on every blob read
    and write.  The retry policy must absorb all of it: saves and
    restores succeed, zero give-ups, restored state bit-exact, and the
    retry amplification stays within the policy's attempt budget."""
    d = root / "fault_storm"
    faults = FaultInjector(seed=seed)
    faults.add(op="write", kind="transient", prob=0.1)
    faults.add(op="read", kind="transient", prob=0.1)
    # 6 attempts: at a 10% fault rate the give-up probability per op is
    # 1e-6 — pool-thread scheduling reorders the RNG draws across runs,
    # so the budget must make give-ups negligible for ANY ordering
    ck = MSRCheckpointer(d, _spec(),
                         io_backend=FaultyBlob(LocalBlob(fsync=False),
                                               faults),
                         retry=fast_retry(max_attempts=6))
    state = _run_reference(5)
    ck.save(5, state)
    t0 = time.perf_counter()
    restored, _ = ck.restore(_init_state(), 5)
    t_resume = time.perf_counter() - t0
    bit_exact = _states_equal(restored, state)
    scrub_clean = ck.scrub(5).clean
    stats = ck.retry_stats.summary()
    ck.close()
    orphans = count_tmp_orphans(d)
    passed = (bit_exact and scrub_clean and orphans == 0
              and stats["giveups"] == 0
              and stats["amplification"] < ck.retry.max_attempts)
    return DrillResult("transient_fault_storm", passed, bit_exact, orphans,
                       resumed_from=5, time_to_resume_s=t_resume,
                       detail=f"retry={stats}")


def serve_under_churn(root: pathlib.Path, seed: int = 0) -> DrillResult:
    """Concurrent gets through the serving front end while the cluster
    churns (DESIGN.md §13): a node failure served mid-drain
    (`restart_mid_drain`'s shape), a ~10% transient read-fault storm
    (`transient_fault_storm`'s shape), then storage bit-rot on one node.
    Contract: every response bit-exact, ZERO corrupt payloads reach a
    caller, and the rotten node walks the full quarantine state machine
    — quarantined on the CRC catch, held through a dirty scrub that
    finds the rest of its rot, re-admitted only after repair + a clean
    scrub (the event log proves the ordering)."""
    rng = np.random.default_rng(seed)
    CodedObjectStore, RepairScheduler = _store_classes()
    from repro.serve.frontend import ReadFrontEnd   # deferred like the store
    faults = FaultInjector(seed=seed)
    store = CodedObjectStore(_spec(), n_nodes=8, stripe_symbols=64,
                             faults=faults, retry=fast_retry(max_attempts=6))
    objs = {f"o{i}": rng.integers(0, 256, 2048).astype(np.uint8).tobytes()
            for i in range(3)}
    for key, val in objs.items():
        store.put(key, val)
    sched = RepairScheduler(store)
    store.subscribe(sched.on_event)
    fe = ReadFrontEnd(store, scheduler=sched, quarantine_threshold=2.0,
                      hedge_after_s=0.25, fetch_workers=4)
    corrupt_served = 0

    def serve_all() -> bool:
        nonlocal corrupt_served
        tickets = [fe.submit(key) for key in objs for _ in range(2)]
        fe.pump()
        ok = True
        for tk in tickets:
            if tk.error is not None:
                ok = False
            elif tk.obj != objs[tk.key]:
                corrupt_served += 1
                ok = False
        return ok

    # phase A: node failure served mid-drain (restart_mid_drain shape)
    store.fail_node(2)
    sched.drain(budget_symbols=(store.k + 1) * store.S)  # half-drained queue
    a_ok = serve_all()
    sched.drain_all()

    # phase B: transient read-fault storm (transient_fault_storm shape)
    faults.add(op="read", kind="transient", prob=0.1)
    b_ok = serve_all()
    faults.clear()

    # phase C: storage bit-rot on node 5 — two shares of DIFFERENT keys,
    # only one of which the next reads touch, so re-admission provably
    # requires the dirty scrub to find the second
    victim = 5
    by_key: dict[str, tuple[str, int]] = {}
    for key, t in sorted(store._shares[victim - 1]):
        by_key.setdefault(key, (key, t))
    (k1, t1), (k2, t2) = list(by_key.values())[:2]
    store._shares[victim - 1][(k1, t1)][1][0] ^= 0x55
    store._shares[victim - 1][(k2, t2)][1][0] ^= 0x55
    c_ok = fe.read(k1) == objs[k1]          # CRC catch -> quarantine
    quarantined = victim in fe.quarantined_nodes()
    t0 = time.perf_counter()
    first_scrub = fe.scrub_quarantined()    # dirty: finds (k2, t2)'s rot
    held = victim in fe.quarantined_nodes()
    sched.drain_all()                       # rebuild both dropped shares
    second_scrub = fe.scrub_quarantined()   # clean: re-admit
    t_recover = time.perf_counter() - t0
    readmitted = victim not in fe.quarantined_nodes()
    c_ok = c_ok and serve_all()             # serving clean again
    seqs = {e["what"]: e["seq"] for e in fe.events
            if e.get("node") == victim
            and e["what"] in ("quarantine", "scrub_dirty", "readmit")}
    ordered = (len(seqs) == 3 and
               seqs["quarantine"] < seqs["scrub_dirty"] < seqs["readmit"])
    audit = store.audit()
    verified = store.verify() and store.total_lost_shares() == 0
    fe.close()
    store.close()
    bit_exact = a_ok and b_ok and c_ok
    passed = (bit_exact and corrupt_served == 0 and quarantined and held
              and not first_scrub[0]["readmitted"]
              and second_scrub[0]["readmitted"] and readmitted
              and ordered and audit.clean and verified)
    return DrillResult("serve_under_churn", passed, bit_exact,
                       len(audit.orphan_shares),
                       time_to_resume_s=t_recover,
                       detail=f"corrupt_served={corrupt_served} "
                              f"quarantine_order={ordered} "
                              f"crc_rejected={fe.metrics.crc_rejected} "
                              f"served={fe.metrics.served}")


DRILLS: dict[str, Callable[[pathlib.Path, int], DrillResult]] = {
    "crash_mid_save": crash_mid_save,
    "kill_rack_write_behind": kill_rack_write_behind,
    "crash_mid_put": crash_mid_put,
    "corrupt_then_scrub": corrupt_then_scrub,
    "restart_mid_drain": restart_mid_drain,
    "transient_fault_storm": transient_fault_storm,
    "serve_under_churn": serve_under_churn,
}


def run_drills(root: Optional[pathlib.Path] = None,
               names: Optional[Sequence[str]] = None,
               seed: int = 0) -> list[DrillResult]:
    """Run the selected drills (all by default) under ``root`` (a fresh
    temp dir by default); returns their results in registry order."""
    tmp = None
    if root is None:
        tmp = tempfile.TemporaryDirectory()
        root = pathlib.Path(tmp.name)
    root = pathlib.Path(root)
    try:
        selected = list(DRILLS) if names is None else list(names)
        unknown = [n for n in selected if n not in DRILLS]
        if unknown:
            raise KeyError(f"unknown drill(s) {unknown}; "
                           f"available: {list(DRILLS)}")
        return [DRILLS[n](root, seed) for n in selected]
    finally:
        if tmp is not None:
            tmp.cleanup()


__all__ = ["DrillResult", "DRILLS", "run_drills", "crash_mid_save",
           "kill_rack_write_behind", "crash_mid_put", "corrupt_then_scrub",
           "restart_mid_drain", "transient_fault_storm", "serve_under_churn"]
