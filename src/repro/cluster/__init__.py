"""Cluster failure simulator + degraded-read serving substrate
(DESIGN.md §9).

The event-driven layer that turns the PR 1/PR 2 encode + repair engines
into a *system*: scenarios (node loss, corruption + scrub, stragglers,
correlated rack failures, rolling restarts) drive the fused repair
engine against real encoded bytes, with repair traffic accounted against
the classical-RS re-download baseline and every recovery checked
bit-exactly.

`drills` (DESIGN.md §12.5) is the crash-consistency counterpart: scripted
failure timelines (crash mid-save, rack loss under write-behind, crash
mid-put, corruption + scrub, restart mid-drain, transient-fault storms)
run against the real durability stack and assert bit-exact resume,
bounded data loss, and zero orphans.
"""
from .drills import DRILLS, DrillResult, run_drills
from .events import (Event, Scenario, corrupt, default_layout, down, fail,
                     latent_corruption, multi_node_loss, rack_failure, read,
                     read_traffic, rolling_restart, scrub, single_node_loss,
                     slow, standard_scenarios, straggler, up)
from .metrics import LinkModel, MetricsLog
from .simulator import (DOWN, FAILED, UP, ClusterSimulator, ScenarioReport,
                        run_scenario)

__all__ = [
    "Event", "Scenario", "fail", "down", "up", "corrupt", "scrub", "slow",
    "read", "read_traffic", "single_node_loss", "multi_node_loss",
    "latent_corruption", "straggler", "rack_failure", "rolling_restart",
    "standard_scenarios", "default_layout", "LinkModel", "MetricsLog",
    "ClusterSimulator",
    "ScenarioReport", "run_scenario", "UP", "DOWN", "FAILED",
    "DrillResult", "DRILLS", "run_drills",
]
