"""Code-family frontier benchmark (DESIGN.md §15): the storage-overhead
vs repair-bandwidth tradeoff across registered erasure-code families,
online conversion throughput, and encode-kernel distance-to-roofline.

Per code class on the grid (double-circulant n = 2k / d = k+1 points,
product-matrix MSR points including a d < n-1 repair case):

  * **frontier** — a store is filled under the class, one node is
    killed, and the scheduler drains the queue: measured repair symbols
    vs the classical-RS re-download baseline (the product-matrix rows
    must beat RS — that is the codes-smoke CI gate) next to the class's
    storage overhead n*q/D;
  * **encode roofline** — steady-state ``encode_derived_planned``
    MB/s as a fraction of measured host memcpy bandwidth, the
    streaming roofline every GF kernel is bounded by;
  * **conversion** — every object is converted default -> product-matrix
    and back through :meth:`CodedObjectStore.convert`, timed end to end;
    both directions must be bit-exact with zero orphan shares.

Emits the repo-root perf-trajectory file ``BENCH_codes.json`` (also via
``benchmarks.run``); the CI ``codes-smoke`` job gates on the
``assertions`` block at a fixed seed.
"""
import argparse
import json
import pathlib
import time

import numpy as np

from repro.codes import (CodeClass, FAMILY_DOUBLE_CIRCULANT,
                         FAMILY_PRODUCT_MATRIX, make_code)
from repro.core.circulant import CodeSpec
from repro.store import CodedObjectStore, RepairScheduler

from benchmarks import _timing
from benchmarks._timing import timeit

REPO_ROOT = pathlib.Path(__file__).resolve().parent.parent
OUT = pathlib.Path(__file__).resolve().parent / "results"


def grid(fast: bool) -> list[CodeClass]:
    """The (family, n, k, d) sweep: both families, overlapping (k, p),
    and a product-matrix d < n-1 point (helpers chosen from a strict
    subset of the survivors)."""
    classes = [
        CodeClass(FAMILY_DOUBLE_CIRCULANT, n=4, k=2, d=3),
        CodeClass(FAMILY_PRODUCT_MATRIX, n=5, k=2, d=3),   # d < n-1
        CodeClass(FAMILY_PRODUCT_MATRIX, n=6, k=3, d=4),   # d < n-1
    ]
    if not fast:
        classes += [
            CodeClass(FAMILY_DOUBLE_CIRCULANT, n=8, k=4, d=5),
            CodeClass(FAMILY_PRODUCT_MATRIX, n=6, k=2, d=3),
            CodeClass(FAMILY_PRODUCT_MATRIX, n=8, k=4, d=6),
        ]
    return classes


def memcpy_mbps(mb: int = 32) -> float:
    """Measured host memcpy bandwidth — the streaming roofline the GF
    encode kernels are bounded by on CPU."""
    src = np.zeros(mb << 20, np.uint8)
    dst = np.empty_like(src)
    np.copyto(dst, src)                     # first touch
    best = min(_copy_once(dst, src) for _ in range(3))
    return mb / best


def _copy_once(dst, src) -> float:
    t0 = time.perf_counter()
    np.copyto(dst, src)
    return time.perf_counter() - t0


def encode_mbps(cc: CodeClass, stream_symbols: int) -> float:
    """Steady-state planned encode throughput for one class: (D, T*S)
    payload stream -> derived rows, symbols/s as MB/s (1 B/symbol)."""
    code = make_code(cc)
    rng = _timing.rng(cc.n + cc.d)
    flat = rng.integers(0, cc.p, (code.data_blocks, stream_symbols),
                        dtype=np.int64).astype(np.int32)
    t = timeit(lambda: code.encode_derived_planned(flat).host())
    return flat.size / t / 2**20


def repair_mbps(cc: CodeClass, stream_symbols: int) -> float:
    """Steady-state fused regeneration throughput: F batched single-loss
    repairs through ``regenerate_many_planned`` — the (F, q, d) newcomer
    stack against (F, d, S) helper sends in ONE dispatch (DESIGN.md
    §16.5), MB/s over the helper-send stream (the symbols a repair
    actually moves)."""
    code = make_code(cc)
    rng = _timing.rng(cc.n + 2 * cc.d)
    batch = 4
    s = max(1, stream_symbols // batch)
    plans = [code.repair_plan(1 + (i % cc.n)) for i in range(batch)]
    if any(p is None for p in plans):
        raise RuntimeError(f"{cc.key()}: no regeneration plan with every "
                           f"other node available")
    sends = rng.integers(0, cc.p, (batch, plans[0].d, s),
                         dtype=np.int64).astype(np.int32)
    t = timeit(lambda: code.regenerate_many_planned(plans, sends).host())
    return sends.size / t / 2**20


def decode_mbps(cc: CodeClass, stream_symbols: int) -> float:
    """Steady-state any-k decode throughput: the (B, k*q) subset-inverse
    rows applied to the (k*q, S) stacked downloads in one planned
    dispatch — the degraded-read / reconstruct kernel, MB/s over the
    download stream."""
    code = make_code(cc)
    rng = _timing.rng(3 * cc.n + cc.d)
    subset = tuple(range(2, 2 + cc.k))          # any k survivors, node 1 lost
    mat = code.decode_rows(subset, list(range(code.data_blocks)))
    downloads = rng.integers(0, cc.p, (cc.k * code.share_blocks,
                                       stream_symbols),
                             dtype=np.int64).astype(np.int32)
    t = timeit(lambda: code.apply_planned(mat, downloads).host())
    return downloads.size / t / 2**20


def _fill(store, rng, n_objects, object_bytes, cc=None) -> dict[str, bytes]:
    objs = {}
    for i in range(n_objects):
        key = f"obj{i:03d}"
        objs[key] = rng.integers(0, 256, object_bytes,
                                 dtype=np.uint8).tobytes()
        store.put(key, objs[key], code_class=cc)
    return objs


def frontier_point(cc: CodeClass, *, stripe_symbols: int, n_objects: int,
                   object_bytes: int, stream_symbols: int,
                   copy_mbps: float, seed: int, quiet: bool) -> dict:
    """One class's frontier row: fill a store under the class, kill a
    node, drain the repair queue, and compare moved symbols to the RS
    re-download baseline."""
    if cc.family == FAMILY_DOUBLE_CIRCULANT:
        spec = CodeSpec.make(cc.k, cc.p)
        store = CodedObjectStore(spec, n_nodes=cc.n + 2,
                                 stripe_symbols=stripe_symbols)
        put_class = None                    # the store's default class
    else:
        spec = CodeSpec.make(2, cc.p)
        store = CodedObjectStore(spec, n_nodes=max(cc.n + 2, spec.n),
                                 stripe_symbols=stripe_symbols)
        put_class = cc
    code = make_code(cc)
    with store:
        rng = np.random.default_rng(seed + cc.n * 10 + cc.d)
        objs = _fill(store, rng, n_objects, object_bytes, put_class)
        sched = RepairScheduler(store)
        store.subscribe(sched.on_event)
        store.fail_node(1)
        budget = 4 * cc.k * (cc.d - cc.k + 1) * store.S
        rep = sched.drain_all(budget_symbols=budget)
        bit_exact = all(store.get(key) == ref for key, ref in objs.items())
        ratio = rep.ratio_vs_rs
        row = {
            "family": cc.family, "n": cc.n, "k": cc.k, "d": cc.d,
            "q": code.share_blocks,
            "storage_overhead": round(code.storage_overhead(), 4),
            "gamma_symbols": code.gamma_regenerate_symbols(store.S),
            "repair_symbols": rep.symbols_moved,
            "rs_baseline_symbols": rep.rs_baseline_symbols,
            "repair_ratio_vs_rs": (None if ratio is None
                                   else round(ratio, 4)),
            "repaired_shares": rep.repaired_shares,
            "bit_exact_after_repair": bit_exact,
            "encode_mbps": round(encode_mbps(cc, stream_symbols), 2),
            "repair_mbps": round(repair_mbps(cc, stream_symbols), 2),
            "decode_mbps": round(decode_mbps(cc, stream_symbols), 2),
        }
        row["roofline_frac_of_memcpy"] = round(
            row["encode_mbps"] / copy_mbps, 4)
        row["repair_roofline_frac_of_memcpy"] = round(
            row["repair_mbps"] / copy_mbps, 4)
        row["decode_roofline_frac_of_memcpy"] = round(
            row["decode_mbps"] / copy_mbps, 4)
    if not quiet:
        print(f"[codes] {cc.key():34s} overhead {row['storage_overhead']:.2f} "
              f"repair_vs_rs {row['repair_ratio_vs_rs']} "
              f"encode {row['encode_mbps']} MB/s "
              f"({row['roofline_frac_of_memcpy']:.1%} of memcpy) "
              f"repair {row['repair_mbps']} decode {row['decode_mbps']} MB/s")
    return row


def conversion_section(target: CodeClass, *, stripe_symbols: int,
                       n_objects: int, object_bytes: int, seed: int,
                       quiet: bool) -> dict:
    """Conversion throughput sweep: default -> target -> default for
    every object, bit-exact both ways, zero orphans, plus one
    scheduler-driven conversion (enqueue_convert + drain)."""
    spec = CodeSpec.make(2)
    with CodedObjectStore(spec, n_nodes=max(target.n + 2, 8),
                          stripe_symbols=stripe_symbols) as store:
        rng = np.random.default_rng(seed + 1)
        objs = _fill(store, rng, n_objects, object_bytes)
        total_mb = n_objects * object_bytes / 2**20

        t0 = time.perf_counter()
        receipts = [store.convert(key, target) for key in objs]
        fwd_s = time.perf_counter() - t0
        fwd_exact = all(store.get(key) == ref for key, ref in objs.items())
        classes_ok = all(store.class_of(key) == target for key in objs)

        t0 = time.perf_counter()
        for key in objs:
            store.convert(key, store.default_class)
        back_s = time.perf_counter() - t0
        back_exact = all(store.get(key) == ref for key, ref in objs.items())
        orphans = len(store.audit().orphan_shares)

        # scheduler path: conversions run on leftover drain budget
        sched = RepairScheduler(store)
        store.subscribe(sched.on_event)
        first = next(iter(objs))
        sched.enqueue_convert(first, target)
        rep = sched.drain_all(budget_symbols=1 << 20)
        sched_ok = (rep.converted_objects == 1
                    and store.class_of(first) == target
                    and store.get(first) == objs[first])

        sec = {
            "target": target.key(),
            "objects": n_objects, "payload_mb": round(total_mb, 3),
            "to_target_s": round(fwd_s, 4),
            "to_default_s": round(back_s, 4),
            "mbps": round(2 * total_mb / (fwd_s + back_s), 2),
            "bytes_read": sum(r.bytes_read for r in receipts),
            "degraded_source_stripes": sum(r.degraded_source_stripes
                                           for r in receipts),
            "bit_exact": bool(fwd_exact and back_exact and classes_ok),
            "scheduler_convert_ok": bool(sched_ok),
            "orphans": orphans,
        }
    if not quiet:
        print(f"[codes] convert <-> {target.key()}: {sec['mbps']} MB/s "
              f"bit_exact={sec['bit_exact']} orphans={sec['orphans']} "
              f"scheduler_ok={sec['scheduler_convert_ok']}")
    return sec


def run(fast: bool = False, seed: int = 0, quiet: bool = False) -> dict:
    classes = grid(fast)
    stripe_symbols = 1 << 8 if fast else 1 << 10
    n_objects = 3 if fast else 6
    object_bytes = 1 << 14 if fast else 1 << 17
    stream_symbols = 1 << 12 if fast else 1 << 14
    copy_mbps = memcpy_mbps(8 if fast else 32)

    frontier = [frontier_point(cc, stripe_symbols=stripe_symbols,
                               n_objects=n_objects,
                               object_bytes=object_bytes,
                               stream_symbols=stream_symbols,
                               copy_mbps=copy_mbps, seed=seed, quiet=quiet)
                for cc in classes]
    target = next(cc for cc in classes
                  if cc.family == FAMILY_PRODUCT_MATRIX)
    conversion = conversion_section(target, stripe_symbols=stripe_symbols,
                                    n_objects=n_objects,
                                    object_bytes=object_bytes, seed=seed,
                                    quiet=quiet)
    pm_rows = [r for r in frontier if r["family"] == FAMILY_PRODUCT_MATRIX]
    rec = {
        "seed": seed, "fast": fast,
        "memcpy_mbps": round(copy_mbps, 2),
        "frontier": frontier,
        "conversion": conversion,
        "assertions": {
            "pm_repair_lt_rs": all(r["repair_ratio_vs_rs"] is not None
                                   and r["repair_ratio_vs_rs"] < 1.0
                                   for r in pm_rows),
            "all_repairs_bit_exact": all(r["bit_exact_after_repair"]
                                         for r in frontier),
            "conversion_bit_exact": conversion["bit_exact"],
            "scheduler_convert_ok": conversion["scheduler_convert_ok"],
            "orphans_zero": conversion["orphans"] == 0,
            # every kernel direction reports a distance-to-roofline
            # signal (PR 9 gave encode one; repair/decode ride along)
            "rooflines_reported": all(
                r[f] > 0 for r in frontier
                for f in ("roofline_frac_of_memcpy",
                          "repair_roofline_frac_of_memcpy",
                          "decode_roofline_frac_of_memcpy")),
        },
    }
    rec["all_passed"] = all(rec["assertions"].values())
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true", help="smaller sweeps")
    ap.add_argument("--quiet", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    rec = run(fast=args.fast, seed=args.seed, quiet=args.quiet)
    OUT.mkdir(exist_ok=True)
    (OUT / "codes.json").write_text(json.dumps(rec, indent=1))
    out = REPO_ROOT / "BENCH_codes.json"
    out.write_text(json.dumps(rec, indent=1))
    print(f"wrote {out}  all_passed={rec['all_passed']} "
          f"assertions={rec['assertions']}")


if __name__ == "__main__":
    main()
