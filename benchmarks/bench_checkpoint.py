"""MSR checkpoint pipeline throughput + restore byte accounting.

Measures, for one [n, k] code on a synthetic training state:
  * streaming save throughput (encode in stream tiles + overlapped writes)
  * restore throughput and BYTES READ for each of the three paths —
    systematic (no failures), regenerate (1 failure, the paper's gamma,
    eq. (7)), reconstruct (k alive) — so the bandwidth trajectory of the
    paper's headline claim is tracked per PR in BENCH_checkpoint.json.
"""
import tempfile
import time

import numpy as np

from benchmarks import _timing
from repro.checkpoint.msr_checkpoint import MSRCheckpointer
from repro.core.circulant import CodeSpec


def _make_state(total_bytes: int, seed=0):
    rng = _timing.rng(seed)
    n_f32 = total_bytes // 8
    return {
        "params": {"w": rng.normal(size=(n_f32,)).astype(np.float32)},
        "opt": {"mu": rng.normal(size=(n_f32,)).astype(np.float32)},
    }


def run(ks=(4,), state_mb: float = 2.0, quiet=False):
    rows = []
    total_bytes = int(state_mb * 2**20)
    for k in ks:
        spec = CodeSpec.make(k, 257)
        state = _make_state(total_bytes, seed=k)
        with tempfile.TemporaryDirectory() as d:
            ckpt = MSRCheckpointer(d, spec)
            ckpt.save(0, state)              # warm-up: compile + touch disk
            t0 = time.perf_counter()
            ckpt.save(1, state)
            t_save = time.perf_counter() - t0

            restores = {}
            for mode, failed in (("systematic", []),
                                 ("regenerate", [2]),
                                 ("reconstruct", [1, 3])):
                t0 = time.perf_counter()
                _, rep = ckpt.restore(state, 1, failed_nodes=failed)
                dt = time.perf_counter() - t0
                assert rep.path == mode, (rep.path, mode)
                restores[mode] = {
                    "s": round(dt, 4),
                    "mbps": round(state_mb / dt, 1),
                    "bytes_read": rep.bytes_read,
                    "frac_of_stored": round(
                        rep.bytes_read / rep.bytes_total_stored, 4),
                }
                # restoring rewrites the failed nodes; reset for the next mode
                if failed:
                    ckpt.save(1, state)

            row = {
                "k": k, "n": spec.n, "state_mb": state_mb,
                "backend": ckpt.code.backend_name,
                "save_s": round(t_save, 4),
                "save_mbps": round(state_mb / t_save, 1),
                "restore": restores,
                # ideal symbol counts for reference (paper eq. (7), §III-B)
                "gamma_regenerate_ideal": (k + 1) / (2 * k),
                "gamma_reconstruct_ideal": 1.0,
            }
            rows.append(row)
            if not quiet:
                print(f"[ckpt] k={k:2d} n={spec.n:2d} [{row['backend']}]: "
                      f"save {row['save_mbps']} MB/s; read frac "
                      f"sys={restores['systematic']['frac_of_stored']} "
                      f"regen={restores['regenerate']['frac_of_stored']} "
                      f"recon={restores['reconstruct']['frac_of_stored']}")
    return rows


if __name__ == "__main__":
    run()
