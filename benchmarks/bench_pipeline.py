"""Execution-plan layer + unified pipeline benchmark (DESIGN.md §11.4).

The workload is a seeded STREAM of mixed-size objects — the regime the
exec layer exists for (thousands of distinct object sizes; every new
size is a new XLA shape).  Each measured pass puts/gets objects whose
sizes were never seen before, drawn from one documented distribution
(`_timing.rng`), so the two execution modes show their real steady
states:

* **pre-plan serial** — planning disabled (per-shape ``jax.jit``: >= one
  XLA compile per distinct stream shape, forever, since fresh sizes keep
  arriving) and pipeline depth 1 (no I/O⇄compute overlap): the code
  before this layer;
* **planned overlapped** — shape-bucketed AOT executables + depth-2
  pipelines: after the warm-up pass covers the bucket ladder, ZERO
  compiles ever again (asserted here — the CI bench-smoke job fails on
  any steady-state recompile).

Emits repo-root ``BENCH_pipeline.json``:

* ``recompiles`` — measured XLA compile counts per pass for both modes
  (via ``jax.monitoring``), plus ``plan_stats()`` hits/misses/compiles;
* ``store`` — steady-state mixed-stream put+get MB/s for both modes,
  the speedup, and per-get latency p50/p99 over the planned passes;
* ``restore`` — checkpoint save/restore MB/s over mixed state sizes for
  both modes (restore exercises the reconstruct decode);
* ``overlap`` — the zero-copy staging + pipeline effect at fixed plans
  (DESIGN.md §16.3): the legacy copying serial put (staging disabled,
  depth 1 — the pre-§16 path, kept selectable via
  ``store.staging_enabled``) vs the staged put at the store's
  machine-sized default depth, on identical sizes.  Reports per-stage
  wall times (``Pipeline.stage_stats()``) and an overlap-efficiency
  estimate against the machine-aware lower bound: ``max(t_compute,
  t_host)`` with >= 2 CPUs, ``t_compute + t_host`` on a single-core
  host where host/compute overlap cannot exist.
"""
import contextlib
import json
import os
import pathlib
import tempfile
import time

import jax
import numpy as np

from benchmarks import _timing
from repro.checkpoint.msr_checkpoint import MSRCheckpointer
from repro.core.circulant import CodeSpec
from repro.exec import plan
from repro.store import CodedObjectStore

REPO_ROOT = pathlib.Path(__file__).resolve().parent.parent

# ---------------------------------------------------- XLA compile counter
_COMPILES = {"n": 0, "on": False}


def _listener(event: str, **kw) -> None:
    if _COMPILES["on"] and "compile" in event:
        _COMPILES["n"] += 1


jax.monitoring.register_event_listener(_listener)


@contextlib.contextmanager
def count_compiles(out: dict, key: str):
    """Count real XLA compiles (jit-cache misses AND AOT lowerings)
    inside the block into ``out[key]``."""
    _COMPILES["n"], _COMPILES["on"] = 0, True
    try:
        yield
    finally:
        _COMPILES["on"] = False
        out[key] = _COMPILES["n"]


# ------------------------------------------------------------- workloads
def _draw_sizes(rng, n: int, lo: int, hi: int, seen: set) -> list[int]:
    """n object sizes from the documented distribution, none seen before
    (a fresh-shape stream — every pass is 'new objects arriving')."""
    out = []
    while len(out) < n:
        s = int(rng.integers(lo, hi))
        if s not in seen:
            seen.add(s)
            out.append(s)
    return out


def _payloads(rng, sizes) -> list[bytes]:
    return [rng.integers(0, 256, s, dtype=np.int64).astype(np.uint8)
            .tobytes() for s in sizes]


def _store(spec, *, depth, workers: int, stripe_symbols: int,
           tile: int, staging: bool = True) -> CodedObjectStore:
    st = CodedObjectStore(spec, n_nodes=spec.n + 4,
                          stripe_symbols=stripe_symbols,
                          pipeline_depth=depth, io_workers=workers,
                          put_tile_stripes=tile)
    st.staging_enabled = staging
    return st


def _put_get_pass(store, payloads, tag: str, latencies=None) -> float:
    """One stream pass: put then get every object (bit-exact asserted).
    Returns wall seconds; appends per-get latency when given."""
    t0 = time.perf_counter()
    for i, pl in enumerate(payloads):
        store.put(f"{tag}/{i}", pl)
    for i, pl in enumerate(payloads):
        g0 = time.perf_counter()
        got = store.get(f"{tag}/{i}")
        if latencies is not None:
            latencies.append(time.perf_counter() - g0)
        assert got == pl, "store roundtrip not bit-exact"
    return time.perf_counter() - t0


def _prewarm(code, max_extent: int) -> None:
    """Compile the full executable grid up front — the production
    startup pattern (precompile-at-init, as in async-checkpointing
    runtimes): every ladder bucket up to ``max_extent`` for the three
    planned op shapes the put/get/save/restore paths dispatch (encode
    (n, b); full any-k decode (n, n) @ (n, b); single-row degraded
    decode (1, n) @ (n, b)).  Plan keys depend only on shapes, so this
    covers EVERY workload object size — zero compiles afterwards.
    """
    n = code.n
    row = np.zeros((1, n), np.int32)
    full = np.zeros((n, n), np.int32)
    b = plan.BUCKET_MIN
    while True:
        z = np.zeros((n, b), np.int32)
        code.encode_planned(z).host()
        code.repair.apply_planned(full, z).host()
        code.repair.apply_planned(row, z).host()
        if b >= max_extent:
            break
        b = code.planner.bucket(b + 1)


# ------------------------------------------------------------ store bench
def bench_store(spec, *, sizes_per_pass: int, lo: int, hi: int,
                stripe_symbols: int, tile: int, passes: int,
                quiet: bool) -> dict:
    rng = _timing.rng(1)
    seen: set = set()
    comp: dict = {}
    out: dict = {"sizes_per_pass": sizes_per_pass, "size_range": [lo, hi],
                 "stripe_symbols": stripe_symbols,
                 "put_tile_stripes": tile, "seed": _timing.BENCH_SEED}

    # Two stores, one per execution mode.  Measured passes INTERLEAVE the
    # modes (serial, planned, serial, planned, ...) and each mode's MB/s
    # is its best pass — on throttled/burstable hosts a sequential A-then-B
    # schedule hands whichever mode runs later a slower machine (the same
    # pairing discipline bench_regeneration uses).
    jax.clear_caches()
    st_serial = _store(spec, depth=1, workers=1, staging=False,
                       stripe_symbols=stripe_symbols, tile=tile)
    st_plan = _store(spec, depth=None, workers=2,
                     stripe_symbols=stripe_symbols, tile=tile)
    with plan.planning_disabled():
        warm = _payloads(rng, _draw_sizes(rng, sizes_per_pass, lo, hi, seen))
        _put_get_pass(st_serial, warm, "w")                # warm jit core
        st_serial.fail_node(2)
        _put_get_pass(st_serial, warm, "w")                # degraded warm
    plan.reset_plan_stats()
    per_stripe = spec.n * stripe_symbols
    max_stripes = -(-hi // per_stripe)
    with count_compiles(comp, "planned_warmup"):
        _prewarm(st_plan.code, st_plan.code.planner.bucket(
            max_stripes * stripe_symbols))
        st_plan.fail_node(2)
    stats_warm = plan.plan_stats()

    serial_best, planned_best, per_pass, lat = 0.0, 0.0, [], []
    comp["planned_steady"] = 0
    for p in range(passes):
        pls = _payloads(rng, _draw_sizes(rng, sizes_per_pass, lo, hi, seen))
        mb = 2 * sum(len(x) for x in pls) / 2**20
        with plan.planning_disabled():
            with count_compiles(comp, f"serial_pass{p}"):
                serial_best = max(serial_best,
                                  mb / _put_get_pass(st_serial, pls,
                                                     f"s{p}"))
        per_pass.append(comp[f"serial_pass{p}"])
        pls = _payloads(rng, _draw_sizes(rng, sizes_per_pass, lo, hi, seen))
        mb = 2 * sum(len(x) for x in pls) / 2**20
        with count_compiles(comp, f"planned_pass{p}"):
            planned_best = max(planned_best,
                               mb / _put_get_pass(st_plan, pls, f"p{p}",
                                                  latencies=lat))
        comp["planned_steady"] += comp[f"planned_pass{p}"]
    stats = plan.plan_stats()
    st_serial.close()
    st_plan.close()
    out["serial_mbps"] = round(serial_best, 1)
    out["serial_compiles_per_pass"] = per_pass
    out["planned_mbps"] = round(planned_best, 1)
    out["planned_warmup_compiles"] = comp["planned_warmup"]
    out["planned_steady_compiles"] = comp["planned_steady"]
    out["plan_steady_new_compiles"] = stats.compiles - stats_warm.compiles
    out["plan_stats"] = stats._asdict()
    out["speedup_vs_serial"] = round(out["planned_mbps"]
                                     / out["serial_mbps"], 2)
    out["get_latency_s"] = {k: round(v, 5) for k, v in
                            _timing.percentiles(lat).items()}
    if not quiet:
        print(f"[pipeline] store stream: serial {out['serial_mbps']} MB/s "
              f"({per_pass} compiles/pass) -> planned+overlapped "
              f"{out['planned_mbps']} MB/s ({out['planned_steady_compiles']}"
              f" steady compiles) = {out['speedup_vs_serial']}x; "
              f"get p50 {out['get_latency_s']['p50']*1e3:.1f} ms "
              f"p99 {out['get_latency_s']['p99']*1e3:.1f} ms")
    return out


# ------------------------------------------------------- pipeline overlap
def bench_overlap(spec, *, object_mb: float, n_objects: int,
                  stripe_symbols: int, tile: int, quiet: bool) -> dict:
    """The zero-copy staging + pipeline effect at identical sizes and
    warm plans (DESIGN.md §16.3).

    Three put paths, measured interleaved (throttled-host discipline):

    * **serial** — staging disabled, depth 1: the legacy copying path
      (fresh flatten/pad/chunk copies, ``tobytes`` CRCs, per-share
      install copies) this PR's staging layer replaces;
    * **staged serial** — staging on, depth 1: isolates the host-side
      win; its wall minus the compute-only pass is ``t_host_s``;
    * **overlap** — staging on, the store's machine-sized default
      depth: the shipping configuration (headline MB/s).

    ``overlap_efficiency`` compares the overlap wall against the
    machine-aware lower bound: ``max(t_compute, t_host)`` when the host
    has >= 2 CPUs, ``t_compute + t_host`` on a single-core host (where
    host/compute overlap is physically impossible and the store's
    default depth degenerates to the serial schedule).  Per-stage wall
    times come from ``Pipeline.stage_stats()`` over the best overlap
    pass."""
    rng = _timing.rng(2)
    size = int(object_mb * 2**20)
    pls = _payloads(rng, [size] * n_objects)
    total_mb = n_objects * size / 2**20

    def mk(depth, workers, staging):
        st = _store(spec, depth=depth, workers=workers, staging=staging,
                    stripe_symbols=stripe_symbols, tile=tile)
        for i, pl in enumerate(pls):
            st.put(f"w{i}", pl)                            # warm plans
        return st

    def one_pass(st):
        t0 = time.perf_counter()
        for i, pl in enumerate(pls):
            st.put(f"o{i}", pl)
        return time.perf_counter() - t0

    st_legacy = mk(1, 1, False)
    st_staged = mk(1, 1, True)
    st_over = mk(None, 2, True)
    t_serial = t_staged = t_overlap = float("inf")
    stage_secs: dict = {}
    for _ in range(3):
        t_serial = min(t_serial, one_pass(st_legacy))
        t_staged = min(t_staged, one_pass(st_staged))
        st_over.pipeline.reset_stage_stats()
        t = one_pass(st_over)
        if t < t_overlap:
            t_overlap, stage_secs = t, st_over.pipeline.stage_stats()
    # compute-only: flatten+encode+force, no share placement
    blocks, smap = st_staged.stripes.chunk(pls[0])
    t0 = time.perf_counter()
    for _ in range(n_objects):
        for s0 in range(0, smap.n_stripes, tile):
            st_staged.code.encode_planned(
                st_staged.stripes.flatten(blocks[s0:s0 + tile])).host()
    t_compute = time.perf_counter() - t0
    t_host = max(t_staged - t_compute, 1e-9)
    depth = st_over.pipeline.depth
    for st in (st_legacy, st_staged, st_over):
        st.close()
    cpus = os.cpu_count() or 1
    bound = max(t_compute, t_host) if cpus >= 2 else t_compute + t_host
    out = {
        "object_mb": object_mb, "n_objects": n_objects,
        "host_parallelism": cpus, "overlap_depth": depth,
        "put_serial_mbps": round(total_mb / t_serial, 1),
        "put_staged_serial_mbps": round(total_mb / t_staged, 1),
        "put_overlap_mbps": round(total_mb / t_overlap, 1),
        "overlap_speedup": round(t_serial / t_overlap, 2),
        "t_compute_s": round(t_compute, 4), "t_host_s": round(t_host, 4),
        "serial_lower_bound_s": round(bound, 4),
        "overlap_efficiency": round(bound / t_overlap, 2),
        "stage_seconds": {k: round(v, 4) for k, v in
                          sorted(stage_secs.items())},
    }
    if not quiet:
        print(f"[pipeline] put overlap: legacy serial "
              f"{out['put_serial_mbps']} MB/s -> staged depth-{depth} "
              f"{out['put_overlap_mbps']} MB/s "
              f"({out['overlap_speedup']}x, efficiency "
              f"{out['overlap_efficiency']} of the machine bound on "
              f"{cpus} CPU(s); stages {out['stage_seconds']})")
    return out


# ------------------------------------------------------- checkpoint bench
def bench_restore(spec, *, state_mbs, passes: int, quiet: bool) -> dict:
    """Mixed-size checkpoint save/restore stream, both modes; restore
    takes the reconstruct path (2 failures, repair off) — the decode-
    heavy direction."""
    rng = _timing.rng(3)
    comp: dict = {}

    def mk_state(mb: float, salt: int):
        n_f32 = max(1, int(mb * 2**20) // 8)
        r = _timing.rng(1000 + salt)
        return {"w": r.normal(size=(n_f32,)).astype(np.float32),
                "m": r.normal(size=(n_f32,)).astype(np.float32)}

    def stream(ck, mbs, tag_comp=None):
        t_total, mb_total = 0.0, 0.0
        for i, mb in enumerate(mbs):
            state = mk_state(mb, i)
            t0 = time.perf_counter()
            ck.save(i, state)
            got, rep = ck.restore(state, i, failed_nodes=[1, 3],
                                  repair=False)
            t_total += time.perf_counter() - t0
            np.testing.assert_array_equal(got["w"], state["w"])
            mb_total += 2 * mb                       # save + restore traffic
        return t_total, mb_total

    out = {"state_mbs": list(state_mbs)}
    with tempfile.TemporaryDirectory() as d:
        jax.clear_caches()
        ck_serial = MSRCheckpointer(pathlib.Path(d) / "serial", spec,
                                    pipeline_depth=1, io_workers=1)
        ck_plan = MSRCheckpointer(pathlib.Path(d) / "planned", spec,
                                  pipeline_depth=2, io_workers=2)
        with plan.planning_disabled():
            stream(ck_serial, [state_mbs[0]])            # warm jit core
        # state of M MB serializes to ~M*2^20 payload bytes -> M*2^20/n
        # symbols per block; 1.25 margin covers the size jitter
        max_extent = int(1.25 * max(state_mbs) * 2**20) // spec.n
        with count_compiles(comp, "warmup"):
            _prewarm(ck_plan.code, ck_plan.code.planner.bucket(max_extent))
            stream(ck_plan, [max(state_mbs)])      # warm the non-GF plumbing

        # interleaved rounds, fresh odd sizes per pass, best-of per mode
        # (throttled-host discipline, see bench_store)
        serial_best = planned_best = 0.0
        comp["serial"] = comp["steady"] = 0
        for p in range(passes):
            jit1, jit2 = rng.uniform(0.8, 1.2, len(state_mbs) * 2) \
                .reshape(2, -1)
            with plan.planning_disabled():
                with count_compiles(comp, f"serial{p}"):
                    t, mb = stream(ck_serial,
                                   [m * j for m, j in zip(state_mbs, jit1)])
            serial_best = max(serial_best, mb / t)
            comp["serial"] += comp[f"serial{p}"]
            with count_compiles(comp, f"steady{p}"):
                t, mb = stream(ck_plan,
                               [m * j for m, j in zip(state_mbs, jit2)])
            planned_best = max(planned_best, mb / t)
            comp["steady"] += comp[f"steady{p}"]
        out["serial_mbps"] = round(serial_best, 1)
        out["serial_compiles"] = comp["serial"]
        out["planned_mbps"] = round(planned_best, 1)
        out["planned_warmup_compiles"] = comp["warmup"]
        out["planned_steady_compiles"] = comp["steady"]
        out["speedup_vs_serial"] = round(out["planned_mbps"]
                                         / out["serial_mbps"], 2)
    if not quiet:
        print(f"[pipeline] checkpoint stream: serial {out['serial_mbps']} "
              f"MB/s ({out['serial_compiles']} compiles) -> planned "
              f"{out['planned_mbps']} MB/s "
              f"({out['planned_steady_compiles']} steady compiles) = "
              f"{out['speedup_vs_serial']}x")
    return out


# ------------------------------------------------------------------- run
def run(k: int = 4, *, fast: bool = False, quiet: bool = False) -> dict:
    spec = CodeSpec.make(k, 257)
    if fast:
        store_kw = dict(sizes_per_pass=6, lo=16 << 10, hi=256 << 10,
                        stripe_symbols=1024, tile=8, passes=2)
        overlap_kw = dict(object_mb=1.0, n_objects=2, stripe_symbols=2048,
                          tile=8)
        restore_kw = dict(state_mbs=(0.5, 1.0), passes=1)
    else:
        store_kw = dict(sizes_per_pass=12, lo=16 << 10, hi=2 << 20,
                        stripe_symbols=2048, tile=16, passes=3)
        overlap_kw = dict(object_mb=4.0, n_objects=4, stripe_symbols=4096,
                          tile=8)
        restore_kw = dict(state_mbs=(1.0, 2.0, 4.0), passes=2)
    rec = {
        "k": k, "n": spec.n, "fast": fast, "seed": _timing.BENCH_SEED,
        "store": bench_store(spec, quiet=quiet, **store_kw),
        "overlap": bench_overlap(spec, quiet=quiet, **overlap_kw),
        "restore": bench_restore(spec, quiet=quiet, **restore_kw),
    }
    rec["recompiles"] = {
        "serial_store_compiles_per_pass":
            rec["store"]["serial_compiles_per_pass"],
        "serial_restore_compiles": rec["restore"]["serial_compiles"],
        "planned_warmup_compiles":
            rec["store"]["planned_warmup_compiles"]
            + rec["restore"]["planned_warmup_compiles"],
        "planned_steady_compiles":
            rec["store"]["planned_steady_compiles"]
            + rec["restore"]["planned_steady_compiles"],
    }
    rec["bit_exact"] = True          # every pass asserts roundtrips above
    # THE steady-state guarantee (acceptance + CI gate): after warm-up the
    # planned mode never compiles again, however many fresh sizes arrive
    if rec["recompiles"]["planned_steady_compiles"] != 0:
        raise RuntimeError(
            f"steady-state recompile regression: planned mode compiled "
            f"{rec['recompiles']['planned_steady_compiles']} time(s) after "
            f"warm-up (plan stats: {rec['store']['plan_stats']})")
    (REPO_ROOT / "BENCH_pipeline.json").write_text(json.dumps(rec, indent=1))
    return rec


if __name__ == "__main__":
    import sys
    run(fast="--fast" in sys.argv)
