"""Cluster scenario battery: repair traffic, degraded-read latency,
availability (DESIGN.md §9).

Runs every standard scenario (single/multi node loss, latent corruption +
scrub, straggler, rack-correlated failure, rolling restart) through the
event-driven simulator at a real block size and reports, per scenario:

  * repair MB moved vs the classical-RS re-download baseline (ratio);
  * degraded-read wall latency — the MEASURED time of the one-row
    cached-inverse decode, cold (first read of an outage: includes the
    host `gf.gauss_inverse`) and steady (LRU hit: one dispatched matmul);
  * availability and the degraded-read fraction under the scenario's
    client traffic;
  * the bit-exactness verdict of the post-scenario cluster state.

Emits the repo-root perf-trajectory file ``BENCH_cluster.json`` via
``benchmarks.run``.
"""
import time

import numpy as np

from repro.cluster import ClusterSimulator, standard_scenarios
from repro.cluster.events import default_layout
from repro.core.circulant import CodeSpec

from benchmarks import _timing
from benchmarks._timing import timeit


def _degraded_read_latency(spec, data) -> dict:
    """Wall time of a degraded block read with a node down: cold (inverse
    solve + matmul) vs steady (cached inverse, one matmul)."""
    sim = ClusterSimulator(spec, data)
    sim.fail_node(3)
    sim.code.repair.decode_cache.clear()
    t0 = time.perf_counter()
    sim.read_block(2)
    cold = time.perf_counter() - t0
    steady = timeit(lambda: sim.read_block(2))
    t_sys = timeit(lambda: sim.read_block(4))      # healthy systematic read
    return {"cold_s": cold, "steady_s": steady, "systematic_s": t_sys,
            "amplification_steady": steady / max(t_sys, 1e-12)}


def run(ks=(4, 8), block_symbols: int = 1 << 16, quiet=False) -> list[dict]:
    rows = []
    for k in ks:
        spec = CodeSpec.make(k, 257)
        n = spec.n
        rng = _timing.rng()
        data = rng.integers(0, spec.p, (n, block_symbols),
                            dtype=np.int64).astype(np.int32)
        layout = default_layout(n, k)
        lat = _degraded_read_latency(spec, data)
        scen_rows = []
        for sc in standard_scenarios(n, k, layout):
            sim = ClusterSimulator(spec, data, layout=layout)
            t0 = time.perf_counter()
            rep = sim.run(sc)
            wall = time.perf_counter() - t0
            m = rep.metrics
            scen_rows.append({
                "scenario": rep.name,
                "bit_exact": rep.bit_exact,
                "repair_mb_moved": round(
                    m["repair"]["symbols_moved"] / 2**20, 4),
                "rs_baseline_mb": round(
                    m["repair"]["rs_baseline_symbols"] / 2**20, 4),
                "repair_ratio_vs_rs": m["repair"]["ratio_vs_rs"],
                "reads": m["reads"]["total"],
                "degraded_fraction": m["reads"]["degraded_fraction"],
                "availability": m["availability"],
                "sim_read_latency_ms": round(
                    m["reads"]["latency"]["mean_s"] * 1e3, 4),
                "wall_s": round(wall, 4),
            })
            if not quiet:
                print(f"  [{n},{k}] {rep.name:20s} exact={rep.bit_exact} "
                      f"ratio={m['repair']['ratio_vs_rs']} "
                      f"avail={m['availability']} "
                      f"deg={m['reads']['degraded_fraction']}")
        rows.append({
            "k": k, "n": n, "block_symbols": block_symbols,
            "racks": layout.n_racks,
            "degraded_read_latency": {kk: round(v, 6)
                                      for kk, v in lat.items()},
            "scenarios": scen_rows,
        })
        if not quiet:
            print(f"  [{n},{k}] degraded read: cold {lat['cold_s']*1e3:.2f} ms"
                  f" / steady {lat['steady_s']*1e3:.2f} ms"
                  f" ({lat['amplification_steady']:.1f}x systematic)")
    return rows


if __name__ == "__main__":
    run()
