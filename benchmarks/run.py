"""Benchmark harness: one module per paper table/figure (DESIGN.md §7)
plus the roofline report over the dry-run artifacts.

    PYTHONPATH=src python -m benchmarks.run [--fast] [--quiet]

Emits the repo-root perf-trajectory files BENCH_encode.json,
BENCH_checkpoint.json, BENCH_repair.json, BENCH_cluster.json,
BENCH_store.json, BENCH_codes.json and BENCH_shard.json, and prints
``name,us_per_call,derived`` CSV rows at
the end.  Unknown files under results/ (superseded artifacts, benches
missing from KNOWN_RESULTS) fail the run before any sweep starts.
"""
import argparse
import json
import pathlib
import sys
import time

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent.parent))

from benchmarks import (bench_checkpoint, bench_cluster, bench_codes,
                        bench_drills, bench_encode_throughput,
                        bench_field_size, bench_pipeline,
                        bench_regeneration, bench_repair_bandwidth,
                        bench_serve, bench_shard, bench_store, roofline)

OUT = pathlib.Path(__file__).resolve().parent / "results"
REPO_ROOT = pathlib.Path(__file__).resolve().parent.parent

# Every file benchmarks/ is allowed to leave under results/.  A result
# file not in this set is either a superseded artifact that should have
# been deleted (the field_scaling.json case) or a new bench that forgot
# to register here — both fail the run loudly instead of silently
# shipping stale JSON.
KNOWN_RESULTS = {"checkpoint", "cluster", "codes", "drills",
                 "encode_throughput", "field_size", "pipeline",
                 "regeneration", "repair_bandwidth", "roofline", "serve",
                 "shard", "store"}


def check_results_dir() -> None:
    unknown = sorted(p.name for p in OUT.glob("*.json")
                     if p.stem not in KNOWN_RESULTS)
    if unknown:
        raise SystemExit(
            f"benchmarks/results/ contains unknown result file(s): "
            f"{unknown}.  Delete superseded artifacts or register new "
            f"benches in benchmarks.run.KNOWN_RESULTS.")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true", help="smaller sweeps")
    ap.add_argument("--quiet", action="store_true",
                    help="suppress per-row prints (CI smoke mode)")
    args = ap.parse_args()
    quiet = args.quiet
    OUT.mkdir(exist_ok=True)
    check_results_dir()
    csv_rows = [("name", "us_per_call", "derived")]

    # the regeneration timing section runs FIRST: its fused-vs-unfused
    # ratio is the most contention-sensitive number in the suite (the fused
    # path parallelizes, the unfused path is dispatch-bound), so it gets
    # the freshest CPU budget on throttled/burstable hosts
    print("== paper §IV: regeneration complexity =====================")
    # the 45 s sampling window spreads the paired fused/unfused rounds
    # across shared-host capacity oscillations (see _timeit_pair)
    rows_regen = bench_regeneration.run(
        ks=(2, 4) if args.fast else (2, 4, 8),
        block_symbols=(1 << 14 if args.fast else 1 << 18), quiet=quiet,
        sample_window_s=(0.0 if args.fast else 45.0))
    (OUT / "regeneration.json").write_text(json.dumps(rows_regen, indent=1))
    csv_rows.append(("regeneration",
                     f"{rows_regen[-1]['t_embedded_s']*1e6:.0f}",
                     f"fused_vs_unfused={rows_regen[-1]['speedup_fused_vs_unfused']}x;"
                     f"speedup_vs_solve={rows_regen[-1]['speedup']}"))

    print("== paper §IV eq.(7): repair bandwidth =====================")
    t0 = time.perf_counter()
    rows_bw = bench_repair_bandwidth.run(
        file_bytes=(1 << 18 if args.fast else 1 << 20),
        ks=(2, 3, 4) if args.fast else (2, 3, 4, 8), quiet=quiet)
    (OUT / "repair_bandwidth.json").write_text(json.dumps(rows_bw, indent=1))
    # repair-side perf trajectory, tracked like encode/checkpoint: the
    # fused-engine regeneration rows plus the measured repair bandwidth
    (REPO_ROOT / "BENCH_repair.json").write_text(json.dumps(
        {"regeneration": rows_regen, "repair_bandwidth": rows_bw}, indent=1))
    csv_rows.append(("repair_bandwidth",
                     f"{(time.perf_counter()-t0)*1e6/len(rows_bw):.0f}",
                     f"saving_vs_ec={rows_bw[-1]['saving_vs_ec']:.3f}"))

    print("== paper §IV-A: field size requirement ====================")
    t0 = time.perf_counter()
    rows = bench_field_size.run(ks=(2, 3) if args.fast else (2, 3, 4, 5),
                                quiet=quiet)
    # the scaling-limit sweep lives INSIDE field_size.json (it used to be
    # a separate field_scaling.json, now superseded — KNOWN_RESULTS
    # rejects the old file if it reappears)
    scaling = None if args.fast else bench_field_size.scaling_limit(quiet=quiet)
    (OUT / "field_size.json").write_text(json.dumps(
        {"rows": rows, "scaling_limit": scaling}, indent=1))
    csv_rows.append(("field_size",
                     f"{(time.perf_counter()-t0)*1e6/len(rows):.0f}",
                     f"min_field_k2={rows[0]['min_field']}"))

    print("== paper §IV: encode throughput (dispatch backends) =======")
    t0 = time.perf_counter()
    # stream >= 2^14 symbols: below that, per-call dispatch overhead
    # dominates and the MB/s trajectory numbers are meaningless
    rows = bench_encode_throughput.run(
        ks=(2, 8),
        stream_symbols=(1 << 14 if args.fast else 1 << 16), quiet=quiet)
    (OUT / "encode_throughput.json").write_text(json.dumps(rows, indent=1))
    (REPO_ROOT / "BENCH_encode.json").write_text(json.dumps(rows, indent=1))
    csv_rows.append(("encode_throughput",
                     f"{rows[-1]['circulant_s']*1e6:.0f}",
                     f"circulant_mbps={rows[-1]['circulant_mbps']};"
                     f"vs_interpret={rows[-1].get('speedup_vs_interpret')}x"))

    print("== checkpoint pipeline: save/restore throughput ===========")
    t0 = time.perf_counter()
    rows = bench_checkpoint.run(
        ks=(4,) if args.fast else (4, 8),
        state_mb=(1.0 if args.fast else 4.0), quiet=quiet)
    (OUT / "checkpoint.json").write_text(json.dumps(rows, indent=1))
    (REPO_ROOT / "BENCH_checkpoint.json").write_text(json.dumps(rows, indent=1))
    csv_rows.append(("checkpoint",
                     f"{rows[-1]['save_s']*1e6:.0f}",
                     f"save_mbps={rows[-1]['save_mbps']};regen_frac="
                     f"{rows[-1]['restore']['regenerate']['frac_of_stored']}"))

    print("== cluster scenarios: repair traffic + degraded reads =====")
    t0 = time.perf_counter()
    rows = bench_cluster.run(
        ks=(4,) if args.fast else (4, 8),
        block_symbols=(1 << 13 if args.fast else 1 << 16), quiet=quiet)
    (OUT / "cluster.json").write_text(json.dumps(rows, indent=1))
    (REPO_ROOT / "BENCH_cluster.json").write_text(json.dumps(rows, indent=1))
    worst_ratio = max(
        (s["repair_ratio_vs_rs"] for r in rows for s in r["scenarios"]
         if s["repair_ratio_vs_rs"] is not None), default=None)
    csv_rows.append(("cluster",
                     f"{(time.perf_counter()-t0)*1e6/len(rows):.0f}",
                     f"worst_repair_ratio={worst_ratio};deg_read_ms="
                     f"{rows[-1]['degraded_read_latency']['steady_s']*1e3:.2f}"))

    print("== object store: put/get, degraded reads, repair drain ====")
    t0 = time.perf_counter()
    rows = bench_store.run(
        ks=(4,) if args.fast else (4, 8),
        stripe_symbols=(1 << 10 if args.fast else 1 << 12),
        n_objects=(4 if args.fast else 8),
        object_bytes=(1 << 17 if args.fast else 1 << 20), quiet=quiet)
    (OUT / "store.json").write_text(json.dumps(rows, indent=1))
    (REPO_ROOT / "BENCH_store.json").write_text(json.dumps(rows, indent=1))
    csv_rows.append(("store",
                     f"{(time.perf_counter()-t0)*1e6/len(rows):.0f}",
                     f"put_mbps={rows[-1]['put_mbps']};"
                     f"drain_ratio_vs_rs={rows[-1]['drain'][0]['ratio_vs_rs']}"))

    print("== code families: frontier + conversion + roofline =========")
    t0 = time.perf_counter()
    # the pm-beats-RS / bit-exact-conversion / zero-orphan gates are in
    # rec["assertions"]; codes-smoke re-checks the emitted artifact
    rec = bench_codes.run(fast=args.fast, quiet=quiet)
    (OUT / "codes.json").write_text(json.dumps(rec, indent=1))
    (REPO_ROOT / "BENCH_codes.json").write_text(json.dumps(rec, indent=1))
    assert rec["all_passed"], f"codes assertions failed: {rec['assertions']}"
    best = min(rec["frontier"], key=lambda r: r["repair_ratio_vs_rs"])
    csv_rows.append(("codes",
                     f"{(time.perf_counter()-t0)*1e6:.0f}",
                     f"best_repair_vs_rs={best['repair_ratio_vs_rs']};"
                     f"convert_mbps={rec['conversion']['mbps']};"
                     f"orphans={rec['conversion']['orphans']}"))

    print("== crash consistency: drills + zero-stall checkpointing ===")
    t0 = time.perf_counter()
    rec = bench_drills.run(fast=args.fast, quiet=quiet)
    (OUT / "drills.json").write_text(json.dumps(rec, indent=1))
    (REPO_ROOT / "BENCH_drills.json").write_text(json.dumps(rec, indent=1))
    assert rec["all_bit_exact"] and rec["all_passed"], \
        f"drill failure: {rec['drills']['results']}"
    csv_rows.append(("drills",
                     f"{(time.perf_counter()-t0)*1e6:.0f}",
                     f"all_passed={rec['all_passed']};wb_overhead_ratio="
                     f"{rec['checkpoint_overhead']['wb_vs_stw_overhead_ratio']}"))

    print("== robust serving: hedged reads + quarantine + shedding ===")
    t0 = time.perf_counter()
    # every robustness claim is asserted inside the bench itself
    rec = bench_serve.run(fast=args.fast, quiet=quiet)
    (OUT / "serve.json").write_text(json.dumps(rec, indent=1))
    (REPO_ROOT / "BENCH_serve.json").write_text(json.dumps(rec, indent=1))
    csv_rows.append(("serve",
                     f"{(time.perf_counter()-t0)*1e6:.0f}",
                     f"req_per_s={rec['healthy']['req_per_s']};"
                     f"p99_cut={rec['hedge_ab']['p99_cut']};"
                     f"shed={rec['overload']['shed']}"))

    print("== exec layer: plan cache + overlapped pipeline ===========")
    t0 = time.perf_counter()
    # raises on any steady-state recompile — the bench IS the CI gate
    rec = bench_pipeline.run(fast=args.fast, quiet=quiet)
    (OUT / "pipeline.json").write_text(json.dumps(rec, indent=1))
    csv_rows.append(("pipeline",
                     f"{(time.perf_counter()-t0)*1e6:.0f}",
                     f"ckpt_speedup={rec['restore']['speedup_vs_serial']}x;"
                     f"steady_recompiles="
                     f"{rec['recompiles']['planned_steady_compiles']}"))

    print("== mesh sharding: multi-device encode/repair scaling ======")
    t0 = time.perf_counter()
    # parity, zero steady-state recompiles, and (given >= 4 cores) the
    # 2x 4-device scaling claim are all asserted inside the bench
    rec = bench_shard.run(fast=args.fast, quiet=quiet)
    (OUT / "shard.json").write_text(json.dumps(rec, indent=1))
    (REPO_ROOT / "BENCH_shard.json").write_text(json.dumps(rec, indent=1))
    csv_rows.append(("shard",
                     f"{(time.perf_counter()-t0)*1e6:.0f}",
                     f"enc_speedup_4dev={rec['encode_speedup_4dev']}x;"
                     f"asserted={rec['scaling_asserted']};"
                     f"steady_recompiles={rec['steady_recompiles']}"))

    print("== roofline (dry-run artifacts) ===========================")
    t0 = time.perf_counter()
    rows = roofline.run(quiet=quiet)
    if rows:
        (OUT / "roofline.json").write_text(json.dumps(rows, indent=1))
        worst = min(rows, key=lambda r: r["projected_mfu"])
        csv_rows.append(("roofline",
                         f"{(time.perf_counter()-t0)*1e6/len(rows):.0f}",
                         f"cells={len(rows)};worst_mfu={worst['projected_mfu']:.3f}"))
    else:
        print("  (no dry-run artifacts found — run repro.launch.dryrun --all)")

    print()
    for row in csv_rows:
        print(",".join(str(x) for x in row))


if __name__ == "__main__":
    main()
