"""Shared steady-state timing helper for the benchmark modules.

One warm-up call (excluded: jit compile + first-touch), then best-of-N
mean-of-reps wall time — best-of is robust to host jitter.  Blocks on the
full result pytree so multi-output paths are timed end to end.
"""
import time

import jax


def timeit(fn, *args, reps=3, best_of=3):
    jax.block_until_ready(fn(*args))
    times = []
    for _ in range(best_of):
        t0 = time.perf_counter()
        for _ in range(reps):
            out = fn(*args)
        jax.block_until_ready(out)
        times.append((time.perf_counter() - t0) / reps)
    return min(times)
