"""Shared steady-state timing + seeding helpers for the benchmark modules.

Timing: one warm-up call (excluded: jit compile + first-touch), then
best-of-N mean-of-reps wall time — best-of is robust to host jitter.
Blocks on the full result pytree so multi-output paths are timed end to
end.

Seeding: every bench draws its data through :func:`rng` so the RNG seed
is fixed and documented in ONE place — BENCH_*.json deltas across PRs
then reflect code changes, never data.  A bench that sweeps a parameter
(k, a shard index, ...) passes it as ``offset`` so each sweep point gets
its own deterministic stream.
"""
import time

import jax
import numpy as np

# The single documented benchmark seed.  Change it and EVERY BENCH_*.json
# trajectory number moves together — which is exactly why no bench is
# allowed a private literal seed.
BENCH_SEED = 0


def rng(offset: int = 0) -> np.random.Generator:
    """The benchmark RNG: ``default_rng(BENCH_SEED + offset)``."""
    return np.random.default_rng(BENCH_SEED + offset)


def timeit(fn, *args, reps=3, best_of=3):
    jax.block_until_ready(fn(*args))
    times = []
    for _ in range(best_of):
        t0 = time.perf_counter()
        for _ in range(reps):
            out = fn(*args)
        jax.block_until_ready(out)
        times.append((time.perf_counter() - t0) / reps)
    return min(times)


def percentiles(samples, ps=(50, 99)) -> dict:
    """{"p50": ..., "p99": ...} over a latency sample list (seconds)."""
    arr = np.asarray(sorted(samples), dtype=np.float64)
    return {f"p{p}": float(np.percentile(arr, p)) for p in ps}
