"""Robust serving front end under stress (DESIGN.md §13) — emits the
repo-root ``BENCH_serve.json`` the CI ``serve-smoke`` job gates on.

Six sections, every robustness claim asserted in-bench:

* **healthy** — closed-loop reads against an all-up store: request
  throughput + wall-latency tail (p50/p99/p999); zero failures, every
  payload bit-exact, p99 within the configured deadline;
* **degraded** — n-k physical nodes down: every read decodes around
  the losses bit-exactly with zero failures, and degraded stripes
  coalesce ACROSS concurrent requests by failure pattern (decode
  dispatches < degraded stripes);
* **churn** — serving interleaved with bandwidth-throttled repair
  drains of a failed node's stripes (one :class:`LinkModel` budget for
  both): zero failures while the queue drains to empty;
* **corrupt_storm** — seeded read-path corrupt rules on every node
  plus real storage rot on one: CRC rejects every flip, transient
  flips are re-read, rotten shares are dropped + repaired, quarantined
  nodes re-admitted only after a clean scrub — and not one corrupt
  payload reaches a caller;
* **hedge_ab** — the headline A/B: identical injected stragglers,
  hedged front end vs unhedged baseline; hedging + learned-latency
  avoidance must cut read p99 by >= 30% (``p99_cut_target``);
* **overload** — a bounded admission queue over capacity: excess load
  is shed with typed :class:`Overloaded` (never a hang or silent
  drop), low priority sheds first, and served + shed == submitted.

Run directly (``python -m benchmarks.bench_serve [--fast]``) or via
``benchmarks.run``.
"""
import argparse
import json
import pathlib
import sys
import time

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent.parent))

import numpy as np

from benchmarks import _timing
from repro.core.circulant import CodeSpec
from repro.io import FaultInjector, fast_retry
from repro.serve import Overloaded, ReadFrontEnd
from repro.store import CodedObjectStore, RepairScheduler

REPO_ROOT = pathlib.Path(__file__).resolve().parent.parent

K = 4                    # n = 2k = 8 shares/stripe
N_NODES = 12             # any n-k = 4 physical losses leave >= k shares
STRIPE_SYMBOLS = 128
DEADLINE_S = 0.25
P99_CUT_TARGET = 0.30


def _build(seed: int, *, n_objects: int = 6, obj_bytes: int = 4096,
           faults=None, with_scheduler: bool = False):
    """A populated store (+ optional subscribed scheduler) and the
    seeded payloads reads are checked bit-exactly against."""
    store = CodedObjectStore(
        CodeSpec.make(K, 257), n_nodes=N_NODES,
        stripe_symbols=STRIPE_SYMBOLS, faults=faults,
        retry=fast_retry(max_attempts=6))
    rng = _timing.rng(seed)
    objects = {}
    for i in range(n_objects):
        key = f"obj-{i:02d}"
        objects[key] = rng.integers(0, 256, size=obj_bytes,
                                    dtype=np.uint8).tobytes()
        store.put(key, objects[key])
    sched = None
    if with_scheduler:
        sched = RepairScheduler(store)
        store.subscribe(sched.on_event)
    return store, sched, objects


def _serve_loop(fe: ReadFrontEnd, objects: dict, n_requests: int) -> dict:
    """Closed-loop requests cycling over the keys; returns wall
    throughput + how many payloads came back corrupt/failed."""
    keys = sorted(objects)
    corrupt = failed = 0
    t0 = time.perf_counter()
    for i in range(n_requests):
        tk = fe.read_ext(keys[i % len(keys)], deadline_s=DEADLINE_S)
        if tk.error is not None:
            failed += 1
        elif tk.obj != objects[tk.key]:
            corrupt += 1
    wall = time.perf_counter() - t0
    return {"requests": n_requests, "wall_s": round(wall, 4),
            "req_per_s": round(n_requests / wall, 1),
            "corrupt_served": corrupt, "failed": failed}


def healthy_section(fast: bool, seed: int, quiet: bool) -> dict:
    n_requests = 120 if fast else 400
    store, _, objects = _build(seed)
    with ReadFrontEnd(store, default_deadline_s=DEADLINE_S) as fe:
        loop = _serve_loop(fe, objects, n_requests)
        lat = fe.metrics.latency_percentiles()
        out = {**loop, "latency": {k: round(v, 6) for k, v in lat.items()},
               "deadline_misses": fe.metrics.deadline_misses,
               "p99_within_deadline": lat["p99_s"] <= DEADLINE_S}
    assert out["failed"] == 0 and out["corrupt_served"] == 0, out
    assert out["p99_within_deadline"], out
    if not quiet:
        print(f"[healthy] {out['req_per_s']} req/s  "
              f"p50={lat['p50_s']*1e3:.2f}ms p99={lat['p99_s']*1e3:.2f}ms "
              f"p999={lat['p999_s']*1e3:.2f}ms")
    return out


def degraded_section(fast: bool, seed: int, quiet: bool) -> dict:
    """n-k nodes down; all keys submitted concurrently so degraded
    stripes coalesce across requests by failure pattern."""
    store, _, objects = _build(seed + 1)
    n_lost = store.n - store.k
    for node in range(1, n_lost + 1):
        store.fail_node(node)
    rounds = 4 if fast else 12
    with ReadFrontEnd(store, default_deadline_s=DEADLINE_S) as fe:
        corrupt = failed = 0
        for _ in range(rounds):
            tickets = [fe.submit(key, deadline_s=DEADLINE_S)
                       for key in sorted(objects) for _rep in range(2)]
            fe.pump()
            for tk in tickets:
                if tk.error is not None:
                    failed += 1
                elif tk.obj != objects[tk.key]:
                    corrupt += 1
        m = fe.metrics
        out = {"nodes_failed": n_lost, "requests": m.requests,
               "failed": failed, "corrupt_served": corrupt,
               "degraded_stripes": m.degraded_stripes,
               "decode_dispatches": m.decode_dispatches,
               "coalesced_requests": m.coalesced_requests,
               "latency": {k: round(v, 6)
                           for k, v in m.latency_percentiles().items()}}
    assert out["failed"] == 0 and out["corrupt_served"] == 0, out
    assert out["degraded_stripes"] > 0, out
    # the cross-request coalescer: one planned dispatch per failure
    # pattern, not one per degraded stripe
    assert out["decode_dispatches"] < out["degraded_stripes"], out
    assert out["coalesced_requests"] > 0, out
    if not quiet:
        print(f"[degraded] {n_lost} nodes down: {out['requests']} reads, "
              f"{out['degraded_stripes']} degraded stripes -> "
              f"{out['decode_dispatches']} decode dispatches, 0 failed")
    return out


def churn_section(fast: bool, seed: int, quiet: bool) -> dict:
    """Foreground serving interleaved with throttled repair drains
    after a node failure — the tick loop shares the link budget."""
    store, sched, objects = _build(seed + 2, with_scheduler=True)
    store.fail_node(2)
    pending0 = sched.pending()
    budget = (store.k + 1) * store.S * 2       # ~2 repaired stripes/tick
    keys = sorted(objects)
    corrupt = failed = ticks = 0
    with ReadFrontEnd(store, scheduler=sched,
                      default_deadline_s=DEADLINE_S) as fe:
        i = 0
        while sched.pending() and ticks < 100:
            for _ in range(3):
                fe.submit(keys[i % len(keys)], deadline_s=DEADLINE_S)
                i += 1
            fe.tick(repair_budget_symbols=budget)
            ticks += 1
        served = fe.read_ext  # noqa: F841  (keep fe alive for metrics)
        for key in keys:      # post-drain: every key reads clean
            tk = fe.read_ext(key, deadline_s=DEADLINE_S)
            if tk.error is not None:
                failed += 1
            elif tk.obj != objects[key]:
                corrupt += 1
        m = fe.metrics
        out = {"pending_at_failure": pending0, "ticks": ticks,
               "repair_budget_symbols": budget,
               "requests": m.requests, "served": m.served,
               "failed": m.failed + failed, "corrupt_served": corrupt,
               "degraded_stripes": m.degraded_stripes,
               "pending_after": sched.pending()}
    assert pending0 > 0 and out["pending_after"] == 0, out
    assert out["failed"] == 0 and out["corrupt_served"] == 0, out
    if not quiet:
        print(f"[churn] {pending0} stripes repaired over {ticks} ticks "
              f"while serving {out['served']} reads, 0 failed")
    return out


def corrupt_storm_section(fast: bool, seed: int, quiet: bool) -> dict:
    """Read-path corrupt rules on every node + storage rot on one, with
    n-k nodes ALSO down: CRC catches every flip, nothing corrupt is
    served, the rotten node quarantines and only a clean scrub
    re-admits it.  Unhedged (fetches stay serial) so the seeded fault
    sequence is deterministic."""
    faults = FaultInjector(seed=seed)
    store, sched, objects = _build(seed + 3, faults=faults,
                                   with_scheduler=True)
    keys = sorted(objects)
    n_lost = store.n - store.k
    failed_nodes = set(range(1, n_lost + 1))
    # storage rot: two shares on node 7 (bypasses the fault seam) —
    # chosen on stripes that keep total erasures (rot + the node
    # failures below) within n-k, so no stripe is over-injured
    rotten = []
    for (key, t), share in sorted(store._shares[6].items()):
        if len(rotten) == 2:
            break
        if len(set(store.placement_of(key, t)) & failed_nodes) <= 2:
            share[1][0] ^= 0x55
            rotten.append([key, t])
    for node in sorted(failed_nodes):
        store.fail_node(node)
    faults.add(op="read", kind="corrupt", prob=0.12)
    rounds = 6 if fast else 16
    corrupt = failed = 0
    with ReadFrontEnd(store, scheduler=sched, hedge_after_s=None,
                      quarantine_threshold=3.0,
                      default_deadline_s=DEADLINE_S) as fe:
        for r in range(rounds):
            for key in keys:
                tk = fe.read_ext(key, deadline_s=DEADLINE_S)
                if tk.error is not None:
                    failed += 1
                elif tk.obj != objects[key]:
                    corrupt += 1
            fe.tick(repair_budget_symbols=(store.k + 1) * store.S * 4)
        faults.clear()                      # storm over: drain + scrub
        for _ in range(50):
            if not sched.pending() and not fe.quarantined_nodes():
                break
            fe.tick(repair_budget_symbols=None)
        m = fe.metrics
        out = {"read_corrupt_prob": 0.12, "nodes_failed": n_lost,
               "rotten_shares": rotten, "requests": m.requests,
               "failed": failed, "corrupt_served": corrupt,
               "crc_rejected": m.crc_rejected,
               "quarantines": m.quarantines,
               "readmissions": m.readmissions,
               "crc_drops": sum(1 for e in fe.events
                                if e["what"] == "crc_drop"),
               "quarantined_after": fe.quarantined_nodes(),
               "pending_after": sched.pending()}
    assert out["corrupt_served"] == 0 and out["failed"] == 0, out
    assert out["crc_rejected"] > 0 and out["quarantines"] > 0, out
    assert out["quarantined_after"] == [] and out["pending_after"] == 0, out
    audit = store.audit()
    out["audit_orphans"] = len(audit.orphan_shares)
    assert out["audit_orphans"] == 0, audit.orphan_shares
    if not quiet:
        print(f"[corrupt_storm] {out['crc_rejected']} CRC rejects, "
              f"{out['quarantines']} quarantines, "
              f"{out['readmissions']} readmissions — 0 corrupt served, "
              f"0 failed of {out['requests']}")
    return out


def hedge_section(fast: bool, seed: int, quiet: bool) -> dict:
    """The headline A/B: three straggler nodes (injected 5 ms read
    latency), hedged front end vs unhedged baseline on identical
    stores.  Hedging + learned-latency avoidance must cut p99 by
    >= P99_CUT_TARGET."""
    n_requests = 60 if fast else 150
    straggle_s = 0.005
    rows = {}
    for mode, hedge in (("unhedged", None), ("hedged", 0.001)):
        faults = FaultInjector(seed=seed)
        for node in (5, 7, 9):
            faults.add(op="read", kind="latency", match=f"node:{node:02d}",
                       latency_s=straggle_s)
        store, _, objects = _build(seed + 4, n_objects=4, obj_bytes=1024,
                                   faults=faults)
        with ReadFrontEnd(store, hedge_after_s=hedge,
                          default_deadline_s=DEADLINE_S) as fe:
            loop = _serve_loop(fe, objects, n_requests)
            lat = fe.metrics.latency_percentiles()
            rows[mode] = {**loop,
                          "latency": {k: round(v, 6) for k, v in lat.items()},
                          "hedged_fetches": fe.metrics.hedged_fetches}
        assert loop["failed"] == 0 and loop["corrupt_served"] == 0, loop
    p99_cut = 1.0 - (rows["hedged"]["latency"]["p99_s"]
                     / rows["unhedged"]["latency"]["p99_s"])
    out = {"straggler_nodes": [5, 7, 9], "straggle_s": straggle_s,
           **rows, "p99_cut": round(p99_cut, 4),
           "p99_cut_target": P99_CUT_TARGET,
           "meets_target": p99_cut >= P99_CUT_TARGET}
    assert out["meets_target"], out
    assert rows["hedged"]["hedged_fetches"] > 0, rows
    if not quiet:
        print(f"[hedge_ab] p99 {rows['unhedged']['latency']['p99_s']*1e3:.2f}ms"
              f" unhedged -> {rows['hedged']['latency']['p99_s']*1e3:.2f}ms "
              f"hedged: cut {p99_cut:.0%} (target >= {P99_CUT_TARGET:.0%})")
    return out


def overload_section(fast: bool, seed: int, quiet: bool) -> dict:
    """Admission queue over capacity: low-priority requests shed with
    typed Overloaded, high priority always admitted, every ticket
    resolved — served + shed == submitted."""
    store, _, objects = _build(seed + 5, n_objects=4, obj_bytes=1024)
    keys = sorted(objects)
    max_queue = 8
    with ReadFrontEnd(store, max_queue=max_queue,
                      default_deadline_s=DEADLINE_S) as fe:
        tickets = [fe.submit(keys[i % len(keys)], priority=0)
                   for i in range(max_queue)]
        tickets += [fe.submit(keys[i % len(keys)], priority=2)
                    for i in range(6)]
        tickets += [fe.submit(keys[i % len(keys)], priority=0)
                    for i in range(4)]
        fe.pump()
        shed = [tk for tk in tickets if isinstance(tk.error, Overloaded)]
        served = [tk for tk in tickets if tk.done and tk.error is None]
        unresolved = [tk for tk in tickets if not tk.done]
        out = {"max_queue": max_queue, "submitted": len(tickets),
               "served": len(served), "shed": len(shed),
               "unresolved": len(unresolved),
               "shed_priorities": sorted({tk.priority for tk in shed}),
               "high_priority_served": sum(1 for tk in served
                                           if tk.priority == 2),
               "typed_errors": all(isinstance(tk.error, Overloaded)
                                   for tk in shed),
               "corrupt_served": sum(1 for tk in served
                                     if tk.obj != objects[tk.key])}
    assert out["shed"] > 0 and out["unresolved"] == 0, out
    assert out["served"] + out["shed"] == out["submitted"], out
    assert out["typed_errors"] and out["corrupt_served"] == 0, out
    # low priority sheds first: no high-priority request was shed while
    # priority-0 requests sat in the queue
    assert out["shed_priorities"] == [0], out
    assert out["high_priority_served"] == 6, out
    if not quiet:
        print(f"[overload] {out['submitted']} submitted at queue bound "
              f"{max_queue}: {out['served']} served + {out['shed']} shed "
              f"(typed, low-priority first), 0 unresolved")
    return out


def run(fast: bool = False, seed: int = 0, quiet: bool = False) -> dict:
    rec = {
        "config": {"k": K, "n": 2 * K, "n_nodes": N_NODES,
                   "stripe_symbols": STRIPE_SYMBOLS,
                   "deadline_s": DEADLINE_S,
                   "p99_cut_target": P99_CUT_TARGET, "seed": seed},
        "healthy": healthy_section(fast, seed, quiet),
        "degraded": degraded_section(fast, seed, quiet),
        "churn": churn_section(fast, seed, quiet),
        "corrupt_storm": corrupt_storm_section(fast, seed, quiet),
        "hedge_ab": hedge_section(fast, seed, quiet),
        "overload": overload_section(fast, seed, quiet),
    }
    rec["assertions"] = {
        "healthy_zero_failed": rec["healthy"]["failed"] == 0,
        "healthy_p99_within_deadline":
            rec["healthy"]["p99_within_deadline"],
        "degraded_zero_failed": rec["degraded"]["failed"] == 0,
        "degraded_coalesces_patterns":
            rec["degraded"]["decode_dispatches"]
            < rec["degraded"]["degraded_stripes"],
        "churn_zero_failed": rec["churn"]["failed"] == 0,
        "churn_drained": rec["churn"]["pending_after"] == 0,
        "storm_zero_corrupt_served":
            rec["corrupt_storm"]["corrupt_served"] == 0,
        "storm_zero_failed": rec["corrupt_storm"]["failed"] == 0,
        "storm_quarantine_cycle":
            rec["corrupt_storm"]["quarantines"] > 0
            and rec["corrupt_storm"]["quarantined_after"] == [],
        "hedge_p99_cut_met": rec["hedge_ab"]["meets_target"],
        "overload_typed_sheds": rec["overload"]["typed_errors"]
            and rec["overload"]["shed"] > 0,
        "overload_nothing_unresolved":
            rec["overload"]["unresolved"] == 0,
    }
    rec["all_passed"] = all(rec["assertions"].values())
    assert rec["all_passed"], rec["assertions"]
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true", help="smaller sweeps")
    ap.add_argument("--quiet", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    rec = run(fast=args.fast, seed=args.seed, quiet=args.quiet)
    out = REPO_ROOT / "BENCH_serve.json"
    out.write_text(json.dumps(rec, indent=1))
    print(f"wrote {out}  all_passed={rec['all_passed']} "
          f"p99_cut={rec['hedge_ab']['p99_cut']} "
          f"shed={rec['overload']['shed']}")


if __name__ == "__main__":
    main()
