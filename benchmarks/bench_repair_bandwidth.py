"""Paper §II eq.(1) / §IV eq.(7): repair bandwidth gamma.

Columns per [n=2k, k]:
  * gamma_msr      — MEASURED bytes the newcomer reads (our checkpointer)
  * gamma_eq7      — (k+1) B / (2k), the MSR bound at d = k+1
  * gamma_ec       — classical erasure coding repair: B (full reconstruction)
  * gamma_repl     — replication: B (read one replica ... of the whole file)
  * storage_msr    — per-node alpha = B/k (MSR point) vs replication B
plus MB/s throughput for the save, the repair (steady-state, second call)
and the full-step scrub pass (batched engine, DESIGN.md §4).
Also validates measured ~= bound (the paper's optimality claim).
"""
import json
import pathlib
import tempfile
import time

import numpy as np

from benchmarks import _timing
from repro.core.baselines import ReplicationScheme
from repro.core.circulant import CodeSpec
from repro.checkpoint.msr_checkpoint import MSRCheckpointer


def run(file_bytes: int = 1 << 20, ks=(2, 3, 4, 8), quiet=False):
    # NOTE: k is capped at 8 over GF(257).  Empirically (see
    # bench_field_size and EXPERIMENTS.md §Paper), for k >= 10 a ~1/p
    # fraction of the C(2k, k) reconstruction subsets is singular for ANY
    # random coefficient vector — condition (6) demands field size >> the
    # subset count, so byte-field storage groups top out at n = 16; larger
    # clusters scale out via multiple groups.
    rows = []
    payload = _timing.rng().integers(0, 256, file_bytes, dtype=np.int64)
    state = {"blob": payload.astype(np.int32)}  # 4 B/entry -> B = 4*file_bytes
    for k in ks:
        spec = CodeSpec.make(k, 257)
        with tempfile.TemporaryDirectory() as d:
            ck = MSRCheckpointer(d, spec)
            t0 = time.perf_counter()
            ck.save(0, state)
            t_enc = time.perf_counter() - t0
            measured = ck.repair_node(0, node=1)   # warm-up: compile + touch
            t0 = time.perf_counter()
            measured = ck.repair_node(0, node=1)
            t_rep = time.perf_counter() - t0
            ck.scrub(0)                        # warm-up: compile batch kernel
            t0 = time.perf_counter()
            scrub = ck.scrub(0)
            t_scrub = time.perf_counter() - t0
            assert scrub.clean, scrub
            man = json.loads((pathlib.Path(d) / "step_000000" /
                              "manifest.json").read_text())
            tree = json.loads(man["tree"])
            s_block = tree["block_symbols"]
        b = 2 * k * s_block
        gamma_eq7 = (k + 1) * b // (2 * k)
        repl = ReplicationScheme(replicas=3)
        rows.append({
            "k": k, "n": 2 * k, "B_bytes": b,
            "gamma_msr_measured": measured,
            "gamma_eq7": gamma_eq7,
            "gamma_ratio": round(measured / gamma_eq7, 4),
            "gamma_ec": b,
            "gamma_repl": repl.repair_symbols(b),
            "saving_vs_ec": round(1 - measured / b, 4),
            "alpha_msr": b // k,
            "alpha_repl": b,
            "encode_s": round(t_enc, 4),
            "repair_s": round(t_rep, 4),
            "scrub_s": round(t_scrub, 4),
            "save_mbps": round(b / 2**20 / max(t_enc, 1e-9), 1),
            "repair_mbps": round(measured / 2**20 / max(t_rep, 1e-9), 1),
            "scrub_mbps": round(scrub.bytes_read / 2**20 / max(t_scrub, 1e-9), 1),
        })
        if not quiet:
            r = rows[-1]
            print(f"[repair] k={k:3d} n={2*k:3d}  gamma={r['gamma_msr_measured']:>10d}B "
                  f"bound={r['gamma_eq7']:>10d}B (x{r['gamma_ratio']:.3f})  "
                  f"EC={r['gamma_ec']:>10d}B  saving={r['saving_vs_ec']:.1%}  "
                  f"repair {r['repair_mbps']} MB/s  scrub {r['scrub_mbps']} MB/s")
    return rows


if __name__ == "__main__":
    run()
