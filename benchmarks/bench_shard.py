"""Multi-device sharded encode/repair scaling over the stream mesh
(DESIGN.md §14).

Measures circulant encode and fused batched regeneration throughput at
mesh sizes 1/2/4/8, asserts every sharded result bit-exact against the
unsharded planner BEFORE timing, and asserts zero steady-state
recompiles on every sharded plan.

The headline scaling claim is asserted in-bench where the numbers are
made: with >= 4 host cores (every CI runner), 4-device encode must be
>= 2x single-device.  On a core-starved host (this includes 1-core dev
containers) the XLA CPU client cannot run the shards in parallel, so
real 2x scaling is PHYSICALLY unavailable; the bench then asserts the
weaker invariant that sharding never regresses below single-device
(the per-shard working sets are smaller, which is worth ~1.7x even
serialized) and records ``scaling_asserted: false`` with the reason —
an honest number beats a lucky one.

Ratios use ALTERNATING paired rounds (same rationale as
bench_regeneration._timeit_pair): on burstable hosts, timing one side
to completion and then the other skews the ratio by whichever capacity
window each phase landed in.

The measurement runs in a SUBPROCESS with
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` — the parent
bench process keeps the host's real device topology (jax locks the
device count at first init).
"""
import json
import os
import pathlib
import subprocess
import sys
import time

MESHES = (1, 2, 4, 8)
_INNER_ENV = "_BENCH_SHARD_INNER"


def _timeit_pair(fn_a, fn_b, reps=2, rounds=10):
    """Best-of timing of two alternatives in alternating rounds."""
    import jax
    jax.block_until_ready(fn_a())          # warm-up: compile + first call
    jax.block_until_ready(fn_b())
    best_a = best_b = float("inf")
    for _ in range(rounds):
        for which, fn in ((0, fn_a), (1, fn_b)):
            t0 = time.perf_counter()
            for _ in range(reps):
                out = fn()
            jax.block_until_ready(out)
            t = (time.perf_counter() - t0) / reps
            if which == 0:
                best_a = min(best_a, t)
            else:
                best_b = min(best_b, t)
    return best_a, best_b


def _inner(fast: bool) -> dict:
    import jax
    import numpy as np

    from benchmarks import _timing
    from repro.core.circulant import CodeSpec
    from repro.exec import plan
    from repro.kernels import dispatch

    k = 8
    enc_symbols = 1 << 20       # large enough that shards beat one body
    rep_symbols = 1 << 18
    rounds = 4 if fast else 10
    spec = CodeSpec.make(k, 257)
    n = spec.n
    c = tuple(int(x) for x in spec.c)
    be = dispatch.get("jnp-int32")
    rng = _timing.rng()
    data = rng.integers(0, 257, (n, enc_symbols), dtype=np.int64
                        ).astype(np.int32)
    rmat = rng.integers(0, 257, (2, k + 1), dtype=np.int64).astype(np.int32)
    rprev = rng.integers(0, 257, (2, rep_symbols), dtype=np.int64
                         ).astype(np.int32)
    downs = rng.integers(0, 257, (2, k, rep_symbols), dtype=np.int64
                         ).astype(np.int32)
    enc_mb = n * enc_symbols / 2**20
    rep_mb = 2 * k * rep_symbols / 2**20

    ref = plan.get_planner(be, 257)
    want_enc = ref.circulant_encode(data, c).host()
    want_reg = ref.regenerate_batch(rmat, rprev, downs).host()

    cpus = os.cpu_count() or 1
    rec = {"n_devices": len(jax.devices()), "host_cpus": cpus,
           "k": k, "n": n, "enc_stream_mb": round(enc_mb, 2),
           "backend": be.name, "encode": [], "repair": []}
    for m in MESHES:
        pl = plan.get_planner(be, 257, mesh=m)
        # bit-exact parity gates the timing: a wrong fast number is
        # worse than no number
        np.testing.assert_array_equal(
            pl.circulant_encode(data, c).host(), want_enc,
            err_msg=f"sharded encode diverges at mesh={m}")
        np.testing.assert_array_equal(
            pl.regenerate_batch(rmat, rprev, downs).host(), want_reg,
            err_msg=f"sharded regenerate diverges at mesh={m}")
        pl.reset_stats()
        # .raw is the device array; PlanResult itself is an opaque leaf
        # jax.block_until_ready would silently NOT block on
        t1, tm = _timeit_pair(
            lambda: ref.circulant_encode(data, c).raw,
            lambda: pl.circulant_encode(data, c).raw, rounds=rounds)
        r1, rm = _timeit_pair(
            lambda: ref.regenerate_batch(rmat, rprev, downs).raw,
            lambda: pl.regenerate_batch(rmat, rprev, downs).raw,
            rounds=max(4, rounds // 2))
        st = pl.plan_stats()
        if m > 1:
            assert st.compiles == 0 and st.misses == 0, (m, st)
        rec["encode"].append({"mesh": m, "s": round(tm, 5),
                              "mbps": round(enc_mb / tm, 1),
                              "speedup_vs_1dev": round(t1 / tm, 2)})
        rec["repair"].append({"mesh": m, "s": round(rm, 5),
                              "mbps": round(rep_mb / rm, 1),
                              "speedup_vs_1dev": round(r1 / rm, 2)})
    rec["parity_ok"] = True
    rec["steady_recompiles"] = 0
    speedup4 = next(r["speedup_vs_1dev"] for r in rec["encode"]
                    if r["mesh"] == 4)
    rec["encode_speedup_4dev"] = speedup4
    rec["scaling_asserted"] = cpus >= 4
    if cpus >= 4:
        assert speedup4 >= 2.0, \
            f"4-device encode only {speedup4}x single-device (need >= 2x)"
    else:
        # shards can't run in parallel on < 4 cores; hold the weaker bar
        rec["scaling_skip_reason"] = (
            f"host has {cpus} core(s): 4 shards serialize, 2x parallel "
            f"scaling physically unavailable; asserted no-regression "
            f"instead")
        assert speedup4 >= 1.0, \
            f"4-device encode regressed to {speedup4}x single-device"
    return rec


def run(fast: bool = False, quiet: bool = False) -> dict:
    env = dict(os.environ)
    env[_INNER_ENV] = "fast" if fast else "full"
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    root = pathlib.Path(__file__).resolve().parent.parent
    env["PYTHONPATH"] = os.pathsep.join(
        [str(root / "src"), str(root), env.get("PYTHONPATH", "")])
    res = subprocess.run([sys.executable, "-m", "benchmarks.bench_shard"],
                         capture_output=True, text=True, env=env,
                         cwd=root, timeout=900)
    if res.returncode != 0:
        raise RuntimeError(f"bench_shard subprocess failed:\n{res.stdout}\n"
                           f"{res.stderr}")
    rec = json.loads(res.stdout.splitlines()[-1])
    if not quiet:
        for erow, rrow in zip(rec["encode"], rec["repair"]):
            print(f"  mesh={erow['mesh']}: encode {erow['mbps']} MB/s "
                  f"({erow['speedup_vs_1dev']}x), repair {rrow['mbps']} "
                  f"MB/s ({rrow['speedup_vs_1dev']}x)")
    return rec


if __name__ == "__main__":
    mode = os.environ.get(_INNER_ENV)
    if mode is None:
        print(json.dumps(run(), indent=1))
    else:
        print(json.dumps(_inner(fast=mode == "fast")))
