"""Crash-consistency drill suite + zero-stall checkpoint overhead
(DESIGN.md §12.6) — emits the repo-root ``BENCH_drills.json`` the CI
``drill-smoke`` job gates on.

Four sections:

* **drills** — every `repro.cluster.drills` timeline at a fixed seed;
  the headline aggregates (``all_passed``, ``all_bit_exact``,
  ``orphans_total``) must be True/True/0 or CI fails;
* **checkpoint overhead** — wall-clock cost of checkpointing a training
  loop whose step time is device-bound (a sleep surrogate, so the
  number isolates the I/O stall, not GF throughput): stop-world
  ``save`` vs write-behind ``save_async``, as % of the no-checkpoint
  baseline.  Write-behind must recover most of the stall
  (``wb_vs_stw_overhead_ratio`` well under 1);
* **time-to-resume vs severity** — restore latency against 0..n-k
  failed nodes (systematic -> regenerate -> reconstruct paths);
* **retry amplification** — attempts/op under injected transient-fault
  rates (0%, 5%, 10%); give-ups must stay 0 through 10%.

Run directly (``python -m benchmarks.bench_drills [--fast]``) or via
``benchmarks.run``.
"""
import argparse
import json
import pathlib
import sys
import tempfile
import time

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent.parent))

import numpy as np

from benchmarks import _timing
from repro.checkpoint.msr_checkpoint import MSRCheckpointer
from repro.cluster.drills import run_drills
from repro.core.circulant import CodeSpec
from repro.io import FaultInjector, FaultyBlob, LocalBlob, fast_retry

REPO_ROOT = pathlib.Path(__file__).resolve().parent.parent


def _make_state(total_bytes: int, seed: int = 0) -> dict:
    rng = _timing.rng(seed)
    n_f32 = total_bytes // 8
    return {"params": {"w": rng.normal(size=(n_f32,)).astype(np.float32)},
            "opt": {"mu": rng.normal(size=(n_f32,)).astype(np.float32)}}


def drill_section(seed: int = 0, quiet: bool = False) -> dict:
    results = [r.to_json() for r in run_drills(seed=seed)]
    out = {
        "seed": seed,
        "results": results,
        "all_passed": all(r["passed"] for r in results),
        "all_bit_exact": all(r["bit_exact"] for r in results),
        "orphans_total": sum(r["orphans"] for r in results),
    }
    if not quiet:
        for r in results:
            print(f"[drill] {r['name']:24s} passed={r['passed']} "
                  f"bit_exact={r['bit_exact']} orphans={r['orphans']}")
    return out


def overhead_section(state_mb: float = 2.0, step_s: float = 0.04,
                     n_steps: int = 12, every: int = 4,
                     quiet: bool = False) -> dict:
    """Checkpoint overhead as % of a device-bound step time.

    The 'training step' is a sleep of ``step_s`` — a stand-in for device
    compute the host is free during, which is exactly the window
    write-behind hides the encode+write in.  Stop-world saves add their
    full wall time; write-behind should add only the snapshot cost."""
    spec = CodeSpec.make(4, 257)
    state = _make_state(int(state_mb * 2**20))

    def loop(save_mode: str, ck) -> float:
        nonlocal state
        t0 = time.perf_counter()
        for step in range(1, n_steps + 1):
            time.sleep(step_s)
            if ck is not None and step % every == 0:
                if save_mode == "write_behind":
                    ck.save_async(step, state)
                else:
                    ck.save(step, state)
        if ck is not None:
            ck.barrier()
        return time.perf_counter() - t0

    t_base = loop("none", None)
    rows = {}
    for mode in ("stop_world", "write_behind"):
        with tempfile.TemporaryDirectory() as d:
            ck = MSRCheckpointer(d, spec, io_backend=LocalBlob(fsync=False))
            ck.save(0, state)            # warm-up: compile + first touch
            t = loop(mode, ck)
            ck.close()
        rows[mode] = {"wall_s": round(t, 4),
                      "overhead_pct": round(100 * (t - t_base) / t_base, 2)}
    stw = rows["stop_world"]["overhead_pct"]
    wb = rows["write_behind"]["overhead_pct"]
    ratio = round(wb / stw, 4) if stw > 0 else None
    out = {"state_mb": state_mb, "step_s": step_s, "n_steps": n_steps,
           "ckpt_every": every, "base_wall_s": round(t_base, 4), **rows,
           "wb_vs_stw_overhead_ratio": ratio,
           # write-behind must hide most of the stall; ratio is wall-time
           # noise-prone on shared hosts, so the target is generous
           "meets_target": ratio is not None and ratio < 0.5}
    if not quiet:
        print(f"[overhead] stop-world +{stw}% vs write-behind +{wb}% "
              f"(ratio {ratio}, target < 0.5)")
    return out


def resume_section(state_mb: float = 2.0, quiet: bool = False) -> dict:
    """Time-to-resume vs failure severity: restore wall time with
    0..n-k nodes dead (systematic / regenerate / reconstruct)."""
    spec = CodeSpec.make(4, 257)
    state = _make_state(int(state_mb * 2**20), seed=1)
    rows = []
    with tempfile.TemporaryDirectory() as d:
        ck = MSRCheckpointer(d, spec, io_backend=LocalBlob(fsync=False))
        ck.save(1, state)
        for n_failed in range(spec.n - spec.k + 1):
            failed = list(range(2, 2 + n_failed))
            ck.restore(state, 1, failed_nodes=failed)     # warm-up
            ck.save(1, state)            # reset repaired files
            t0 = time.perf_counter()
            _, rep = ck.restore(state, 1, failed_nodes=failed)
            dt = time.perf_counter() - t0
            rows.append({"n_failed": n_failed, "path": rep.path,
                         "resume_s": round(dt, 4),
                         "bytes_read_frac": round(
                             rep.bytes_read / rep.bytes_total_stored, 4)})
            ck.save(1, state)
            if not quiet:
                print(f"[resume] {n_failed} failed -> {rep.path:12s} "
                      f"{dt*1e3:.1f} ms")
        ck.close()
    return {"state_mb": state_mb, "k": spec.k, "n": spec.n, "rows": rows}


def retry_section(state_mb: float = 0.5, rates=(0.0, 0.05, 0.1),
                  quiet: bool = False) -> dict:
    """Retry amplification (attempts/op) vs injected transient-fault
    rate; the policy must absorb every rate here without a give-up."""
    spec = CodeSpec.make(3, 257)
    state = _make_state(int(state_mb * 2**20), seed=2)
    rows = []
    for rate in rates:
        faults = FaultInjector(seed=_timing.BENCH_SEED)
        if rate > 0:
            faults.add(op="write", kind="transient", prob=rate)
            faults.add(op="read", kind="transient", prob=rate)
        with tempfile.TemporaryDirectory() as d:
            ck = MSRCheckpointer(
                d, spec,
                io_backend=FaultyBlob(LocalBlob(fsync=False), faults),
                retry=fast_retry(max_attempts=6))
            ck.save(1, state)
            ck.restore(state, 1)
            stats = ck.retry_stats.summary()
            ck.close()
        rows.append({"fault_rate": rate, **stats})
        if not quiet:
            print(f"[retry] rate={rate:4.2f} amplification="
                  f"{stats['amplification']} giveups={stats['giveups']}")
    return {"rows": rows,
            "max_amplification": max(r["amplification"] for r in rows),
            "giveups_total": sum(r["giveups"] for r in rows)}


def run(fast: bool = False, seed: int = 0, quiet: bool = False) -> dict:
    rec = {
        "drills": drill_section(seed=seed, quiet=quiet),
        "checkpoint_overhead": overhead_section(
            state_mb=(1.0 if fast else 4.0),
            step_s=(0.02 if fast else 0.04), quiet=quiet),
        "time_to_resume": resume_section(
            state_mb=(1.0 if fast else 4.0), quiet=quiet),
        "retry_amplification": retry_section(quiet=quiet),
    }
    rec["all_passed"] = bool(rec["drills"]["all_passed"]
                             and rec["retry_amplification"]["giveups_total"]
                             == 0)
    rec["all_bit_exact"] = rec["drills"]["all_bit_exact"]
    rec["orphans_total"] = rec["drills"]["orphans_total"]
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true", help="smaller sweeps")
    ap.add_argument("--quiet", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    rec = run(fast=args.fast, seed=args.seed, quiet=args.quiet)
    out = REPO_ROOT / "BENCH_drills.json"
    out.write_text(json.dumps(rec, indent=1))
    print(f"wrote {out}  all_passed={rec['all_passed']} "
          f"all_bit_exact={rec['all_bit_exact']} "
          f"orphans_total={rec['orphans_total']}")


if __name__ == "__main__":
    main()
