"""Roofline analysis (deliverable g): three terms per (arch x shape x mesh)
from the dry-run artifacts in benchmarks/dryrun_results/.

    compute term    = HLO_FLOPs / peak_FLOPs          (per chip, trip-aware)
    memory term     = HLO_bytes / HBM_bw              (per chip, trip-aware)
    collective term = wire_bytes / ICI_bw             (per chip)

Hardware constants (TPU v5e, per chip): 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI.  FLOPs/bytes come from the trip-count-aware HLO walk
(launch/hlo_stats.py) because compiled.cost_analysis() counts while-loop
bodies once; `static_*` columns keep the raw cost_analysis values for
comparison.

Wire-byte model per collective type (operand bytes O, group size n):
  all-reduce          2 * O * (n-1)/n      (ring reduce-scatter + all-gather)
  reduce-scatter      O * (n-1)/n
  all-gather          O * (n-1)            (operand is the local shard)
  all-to-all          O * (n-1)/n
  collective-permute  O
Group sizes are not recoverable per-op from the dynamic walk, so we use the
per-type static result/operand ratio as the effective n for all-gather and
the mesh axis sizes elsewhere (documented approximation; the dominant-term
ranking is insensitive to the (n-1)/n factors).
"""
import json
import pathlib

PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # B/s / chip
ICI_BW = 50e9                # B/s / link (1-link conservative)

RESULTS = pathlib.Path(__file__).resolve().parent / "dryrun_results"


def wire_bytes(rec: dict) -> float:
    dyn = rec["dynamic"]["collectives"]
    stat = rec["collectives"]
    # effective gather width from static result/operand ratio
    ag_ratio = 1.0
    if stat["all-gather"]["bytes"]:
        ag_ratio = max(stat["all-gather"]["result_bytes"]
                       / stat["all-gather"]["bytes"] - 1.0, 0.0)
    total = 0.0
    total += 2.0 * dyn["all-reduce"]["bytes"]
    total += 1.0 * dyn["reduce-scatter"]["bytes"]
    total += ag_ratio * dyn["all-gather"]["bytes"]
    total += 1.0 * dyn["all-to-all"]["bytes"]
    total += 1.0 * dyn["collective-permute"]["bytes"]
    return total


def analyze_record(rec: dict) -> dict:
    n_dev = rec["n_devices"]
    flops = rec["dynamic"]["flops"]
    hbm = rec["dynamic"]["hbm_bytes"]
    wire = wire_bytes(rec)
    t_compute = flops / PEAK_FLOPS
    t_memory = hbm / HBM_BW
    t_coll = wire / ICI_BW
    terms = {"compute": t_compute, "memory": t_memory, "collective": t_coll}
    bottleneck = max(terms, key=terms.get)
    step_time = max(terms.values())
    model_flops = 6.0 * rec["n_active_params"] * rec["tokens_per_step"]
    mfu = (model_flops / n_dev / PEAK_FLOPS) / step_time if step_time > 0 else 0.0
    useful = model_flops / n_dev / max(flops, 1.0)
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "kind": rec["kind"],
        "t_compute_s": t_compute, "t_memory_s": t_memory,
        "t_collective_s": t_coll,
        "bottleneck": bottleneck, "step_time_s": step_time,
        "model_flops_per_chip": model_flops / n_dev,
        "hlo_flops_per_chip": flops,
        "useful_flops_ratio": useful,
        "projected_mfu": mfu,
        "hbm_args_gib": rec["memory"]["argument_bytes"] / 2**30,
        "hbm_temp_gib": rec["memory"]["temp_bytes"] / 2**30,
        "fits_hbm": (rec["memory"]["argument_bytes"]
                     + rec["memory"]["temp_bytes"]) < 16 * 2**30,
        "static_flops": rec["cost"]["flops"],
        "wire_gib": wire / 2**30,
    }


def hint(row: dict) -> str:
    b = row["bottleneck"]
    if b == "collective":
        return ("shrink TP activation all-reduces (bf16 wire, reduce-scatter "
                "+ sequence-sharded residuals)")
    if b == "memory":
        if row["kind"] == "decode":
            return ("decode is weight/cache-read bound: batch more queries "
                    "per step or quantize KV/weights")
        return "raise arithmetic intensity: fuse, cut fp32 score traffic, remat less"
    return "compute-bound: cut non-model FLOPs (remat policy, attention casting)"


def load_all() -> list[dict]:
    rows = []
    for f in sorted(RESULTS.glob("*.json")):
        if f.name.endswith(".error.json"):
            continue
        rec = json.loads(f.read_text())
        if "error" in rec:
            continue
        rows.append(analyze_record(rec))
    return rows


def table(rows: list[dict], mesh: str = "pod16x16") -> str:
    out = ["| arch | shape | compute s | memory s | collective s | bottleneck | useful/HLO | proj. MFU | fits HBM |",
           "|---|---|---|---|---|---|---|---|---|"]
    for r in sorted(rows, key=lambda r: (r["arch"], r["shape"])):
        if r["mesh"] != mesh:
            continue
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['t_compute_s']:.3e} | "
            f"{r['t_memory_s']:.3e} | {r['t_collective_s']:.3e} | "
            f"**{r['bottleneck']}** | {r['useful_flops_ratio']:.2f} | "
            f"{r['projected_mfu']:.1%} | {'yes' if r['fits_hbm'] else 'NO'} |")
    return "\n".join(out)


def run(quiet=False):
    rows = load_all()
    if not quiet:
        for r in sorted(rows, key=lambda r: -r["step_time_s"]):
            if r["mesh"] != "pod16x16":
                continue
            print(f"[roofline] {r['arch']:22s} {r['shape']:12s} "
                  f"C={r['t_compute_s']:.2e} M={r['t_memory_s']:.2e} "
                  f"X={r['t_collective_s']:.2e} -> {r['bottleneck']:10s} "
                  f"MFU~{r['projected_mfu']:.1%}")
    return rows


if __name__ == "__main__":
    run()
