"""Paper §IV: regeneration complexity — embedded vs solve-based repair.

The paper's claim: double circulant MSR regeneration needs NO coefficient
discovery, NO helper-side combining and NO linear-system solve — just 2k
multiply-accumulates per symbol at the newcomer.  We compare:
  * field-operation counts (modelled, both schemes), and
  * measured wall time of our regenerate() vs a solve-based repair
    (full any-k reconstruction of the lost node's blocks).
"""
import time

import jax.numpy as jnp
import numpy as np

from repro.core.baselines import embedded_repair_cost, solve_based_msr_repair_cost
from repro.core.circulant import CodeSpec
from repro.core.msr import DoubleCirculantMSR


def run(ks=(2, 4, 8), block_symbols: int = 1 << 18, quiet=False):
    rows = []
    for k in ks:
        spec = CodeSpec.make(k, 257)
        code = DoubleCirculantMSR(spec)
        n = spec.n
        rng = np.random.default_rng(k)
        data = jnp.asarray(rng.integers(0, 257, (n, block_symbols), dtype=np.int64), jnp.int32)
        red = code.encode(data)
        red.block_until_ready()

        plan = code.repair_plan(1)
        r_prev = red[plan.prev_node - 1]
        nxt = data[jnp.asarray(plan.data_indices)]
        # embedded (paper) path
        t0 = time.perf_counter()
        a_new, r_new = code.regenerate(1, r_prev, nxt)
        a_new.block_until_ready(); r_new.block_until_ready()
        t_emb = time.perf_counter() - t0
        # solve-based path: any-k reconstruction then re-encode lost pair
        use = list(range(2, k + 2))
        idx = jnp.asarray([i - 1 for i in use])
        t0 = time.perf_counter()
        full = code.reconstruct(use, data[idx], red[idx])
        red2 = code.encode(full)
        full.block_until_ready(); red2.block_until_ready()
        t_solve = time.perf_counter() - t0
        np.testing.assert_array_equal(np.asarray(full[0]), np.asarray(data[0]))

        emb = embedded_repair_cost(k, block_symbols)
        slv = solve_based_msr_repair_cost(k, block_symbols)
        rows.append({
            "k": k, "n": n, "block_symbols": block_symbols,
            "t_embedded_s": round(t_emb, 4),
            "t_solve_based_s": round(t_solve, 4),
            "speedup": round(t_solve / max(t_emb, 1e-9), 2),
            "ops_embedded_stream": emb.stream_ops,
            "ops_solve_stream": slv.stream_ops + slv.helper_combine_ops,
            "coeff_solve_ops_embedded": emb.coefficient_solve_ops,
            "coeff_solve_ops_solve_based": slv.coefficient_solve_ops + slv.newcomer_solve_ops,
        })
        if not quiet:
            r = rows[-1]
            print(f"[regen] k={k:3d}: embedded {r['t_embedded_s']}s vs "
                  f"solve-based {r['t_solve_based_s']}s  (x{r['speedup']})  "
                  f"coeff-ops {r['coeff_solve_ops_embedded']} vs "
                  f"{r['coeff_solve_ops_solve_based']}")
    return rows


if __name__ == "__main__":
    run()
