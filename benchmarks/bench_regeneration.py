"""Paper §IV: regeneration complexity — embedded vs solve-based repair.

The paper's claim: double circulant MSR regeneration needs NO coefficient
discovery, NO helper-side combining and NO linear-system solve — just 2k
multiply-accumulates per symbol at the newcomer.  We compare:
  * field-operation counts (modelled, both schemes),
  * measured wall time of the FUSED single-matmul regenerate (repair
    engine, DESIGN.md §4) vs the pre-engine unfused three-round schedule
    (`regenerate_reference`) vs a solve-based repair (full any-k
    reconstruction of the lost node's blocks), and
  * batched regeneration of all n nodes through `regenerate_batch`.

Methodology matches bench_encode_throughput: the first call is excluded
(jit warm-up), timings are best-of over repeated steady-state calls, and
MB/s is reported over the helper download gamma = (k+1) * S bytes.
"""
import functools
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import _timing
from benchmarks._timing import timeit
from repro.core.baselines import embedded_repair_cost, solve_based_msr_repair_cost
from repro.core.circulant import CodeSpec
from repro.core.msr import DoubleCirculantMSR

_timeit = functools.partial(timeit, reps=5, best_of=4)


def _timeit_pair(fn_a, fn_b, reps=3, rounds=16, window_s=0.0, pause_s=1.0):
    """Best-of timing of two alternatives in ALTERNATING rounds.

    The fused-vs-unfused speedup is a ratio of two measurements; on shared
    hosts whose capacity oscillates (burst quotas, noisy neighbours),
    timing one path to completion and then the other skews the ratio by
    whatever window each phase landed in.  Alternating short rounds gives
    both paths a shot at every window, and best-of recovers each path's
    steady-state nominal.  ``window_s > 0`` additionally spreads the
    rounds (with ``pause_s`` cooldowns) across at least that much
    wall-clock, so the samples span multiple capacity windows when the
    oscillation period is longer than the raw sampling loop.
    """
    jax.block_until_ready(fn_a())          # warm-up: compile + first call
    jax.block_until_ready(fn_b())
    best_a = best_b = float("inf")
    t_start = time.perf_counter()
    done = 0
    while done < rounds or (time.perf_counter() - t_start) < window_s:
        for fn, which in ((fn_a, 0), (fn_b, 1)):
            t0 = time.perf_counter()
            for _ in range(reps):
                out = fn()
            jax.block_until_ready(out)
            t = (time.perf_counter() - t0) / reps
            if which == 0:
                best_a = min(best_a, t)
            else:
                best_b = min(best_b, t)
        done += 1
        if (time.perf_counter() - t_start) < window_s:
            time.sleep(pause_s)
    return best_a, best_b


def run(ks=(2, 4, 8), block_symbols: int = 1 << 18, quiet=False,
        sample_window_s: float = 0.0):
    # distance-to-roofline for the repair kernel (PR 9 convention): the
    # fused regenerate streams gamma bytes, so its MB/s is bounded by
    # host memcpy bandwidth like every GF kernel on CPU
    from benchmarks.bench_codes import memcpy_mbps
    copy_mbps = memcpy_mbps(8)
    rows = []
    for k in ks:
        spec = CodeSpec.make(k, 257)
        code = DoubleCirculantMSR(spec)
        n = spec.n
        rng = _timing.rng(k)
        data = jnp.asarray(rng.integers(0, 257, (n, block_symbols),
                                        dtype=np.int64), jnp.int32)
        red = code.encode(data)
        red.block_until_ready()

        plan = code.repair_plan(1)
        r_prev = red[plan.prev_node - 1]
        nxt = data[jnp.asarray(plan.data_indices)]
        gamma_mb = (k + 1) * block_symbols / 2**20   # helper download bytes

        # fused (engine) vs unfused (pre-engine reference) — bit-exact first
        a_f, r_f = code.regenerate(1, r_prev, nxt)
        a_u, r_u = code.regenerate_reference(1, r_prev, nxt)
        np.testing.assert_array_equal(np.asarray(a_f), np.asarray(a_u))
        np.testing.assert_array_equal(np.asarray(r_f), np.asarray(r_u))
        np.testing.assert_array_equal(np.asarray(a_f), np.asarray(data[0]))
        # time the engine's native stacked API — the restore hot path
        # (_regenerate_tiled) consumes the (2, S) stack directly; the
        # tuple-returning `regenerate` adds two per-call row-slice ops
        t_fused, t_unfused = _timeit_pair(
            lambda: code.repair.regenerate_stacked(1, r_prev, nxt),
            lambda: code.regenerate_reference(1, r_prev, nxt),
            window_s=sample_window_s)

        # batched: all n nodes regenerated through the vmapped engine
        r_prevs = red[jnp.asarray([code.repair_plan(i).prev_node - 1
                                   for i in range(1, n + 1)])]
        next_all = jnp.stack([data[jnp.asarray(code.repair_plan(i).data_indices)]
                              for i in range(1, n + 1)])
        batch = code.regenerate_batch(list(range(1, n + 1)), r_prevs, next_all)
        np.testing.assert_array_equal(np.asarray(batch[:, 0]), np.asarray(data))
        t_batch = _timeit(lambda: code.regenerate_batch(
            list(range(1, n + 1)), r_prevs, next_all))

        # solve-based path: any-k reconstruction then re-encode lost pair.
        # steady = decode inverse served from the LRU cache; cold = a fresh
        # subset after the kernels are compiled (measured last, so it prices
        # the per-subset Gaussian inverse, not one-time jit compilation).
        use = list(range(2, k + 2))
        idx = jnp.asarray([i - 1 for i in use])

        def solve_repair():
            full = code.reconstruct(use, data[idx], red[idx])
            return code.encode(full)

        full = code.reconstruct(use, data[idx], red[idx])
        np.testing.assert_array_equal(np.asarray(full), np.asarray(data))
        t_solve = _timeit(solve_repair)
        code.repair.decode_cache.clear()
        t0 = time.perf_counter()
        solve_repair().block_until_ready()
        t_solve_cold = time.perf_counter() - t0

        emb = embedded_repair_cost(k, block_symbols)
        slv = solve_based_msr_repair_cost(k, block_symbols)
        rows.append({
            "k": k, "n": n, "block_symbols": block_symbols,
            "gamma_mb": round(gamma_mb, 2),
            "t_embedded_s": round(t_fused, 4),
            "t_embedded_unfused_s": round(t_unfused, 4),
            "t_batch_all_n_s": round(t_batch, 4),
            "t_solve_based_s": round(t_solve, 4),
            "t_solve_based_cold_s": round(t_solve_cold, 4),
            "embedded_mbps": round(gamma_mb / max(t_fused, 1e-9), 1),
            "embedded_unfused_mbps": round(gamma_mb / max(t_unfused, 1e-9), 1),
            "batch_mbps": round(n * gamma_mb / max(t_batch, 1e-9), 1),
            "roofline_frac_of_memcpy": round(
                gamma_mb / max(t_fused, 1e-9) / copy_mbps, 4),
            "speedup_fused_vs_unfused": round(t_unfused / max(t_fused, 1e-9), 2),
            "speedup": round(t_solve / max(t_fused, 1e-9), 2),
            "ops_embedded_stream": emb.stream_ops,
            "ops_solve_stream": slv.stream_ops + slv.helper_combine_ops,
            "coeff_solve_ops_embedded": emb.coefficient_solve_ops,
            "coeff_solve_ops_solve_based": slv.coefficient_solve_ops + slv.newcomer_solve_ops,
        })
        if not quiet:
            r = rows[-1]
            print(f"[regen] k={k:3d}: fused {r['t_embedded_s']}s "
                  f"({r['embedded_mbps']} MB/s, {r['speedup_fused_vs_unfused']}x "
                  f"vs unfused) batch {r['batch_mbps']} MB/s  "
                  f"solve-based {r['t_solve_based_s']}s (x{r['speedup']})  "
                  f"coeff-ops {r['coeff_solve_ops_embedded']} vs "
                  f"{r['coeff_solve_ops_solve_based']}")
    return rows


if __name__ == "__main__":
    run()
