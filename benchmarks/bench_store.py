"""Coded object store benchmark: put/get throughput, degraded-read
latency, and repair-queue drain time vs. bandwidth budget
(DESIGN.md §10.5).

Per k, a store with a physical ring larger than the code's n is filled
with multi-stripe objects, then:

  * **put / get MB/s** — wall time of the multi-object write workload
    (one dispatched encode per object, whatever its stripe count) and
    the all-systematic read-back;
  * **degraded get** — a rack's worth of nodes is killed and the same
    objects are read back bit-exactly: cold (first read pays the
    cached-inverse solves) vs steady wall latency, plus MB/s;
  * **repair drain** — the scheduler's queue after the rack failure is
    drained under several per-tick symbol budgets: ticks + simulated
    drain seconds per budget, repair symbols moved, and the ratio vs
    the classical-RS re-download baseline (must stay < 1).

Emits the repo-root perf-trajectory file ``BENCH_store.json`` via
``benchmarks.run``.
"""
import time

import numpy as np

from repro.core.circulant import CodeSpec
from repro.store import CodedObjectStore, RepairScheduler

from benchmarks import _timing
from benchmarks._timing import timeit


def _fill(store, rng, n_objects: int, object_bytes: int) -> dict[str, bytes]:
    objs = {}
    for i in range(n_objects):
        key = f"obj{i:03d}"
        objs[key] = rng.integers(0, 256, object_bytes,
                                 dtype=np.uint8).tobytes()
        store.put(key, objs[key])
    return objs


def _make(spec, stripe_symbols: int, extra_nodes: int) -> CodedObjectStore:
    return CodedObjectStore(spec, n_nodes=spec.n + extra_nodes,
                            stripe_symbols=stripe_symbols)


def run(ks=(4, 8), stripe_symbols: int = 1 << 12, n_objects: int = 8,
        object_bytes: int = 1 << 20, extra_nodes: int = 4,
        budgets_stripes=(1, 4, 16), quiet=False) -> list[dict]:
    rows = []
    for k in ks:
        spec = CodeSpec.make(k, 257)
        rng = _timing.rng()
        total_mb = n_objects * object_bytes / 2**20

        store = _make(spec, stripe_symbols, extra_nodes)
        # warm-up: one throwaway put compiles the encode dispatch so the
        # timed loop measures steady-state write throughput
        store.put("_warmup", bytes(object_bytes))
        store.delete("_warmup")
        t0 = time.perf_counter()
        objs = _fill(store, rng, n_objects, object_bytes)
        put_s = time.perf_counter() - t0
        t0 = time.perf_counter()
        for key, ref in objs.items():
            assert store.get(key) == ref
        get_s = time.perf_counter() - t0

        # ---- kill a rack, read everything back degraded
        victims = store.layout.nodes_in(0)
        sched = RepairScheduler(store)
        store.subscribe(sched.on_event)
        for v in victims:
            store.fail_node(v)
        store.code.repair.decode_cache.clear()
        t0 = time.perf_counter()
        for key, ref in objs.items():
            assert store.get(key) == ref
        deg_cold_s = time.perf_counter() - t0
        deg_steady_s = timeit(
            lambda: [store.get(key) for key in objs], reps=1)

        # ---- drain the repair queue under different tick budgets
        queue_symbols = sum(len(store.lost_code_nodes(key, t)) * 2 * store.S
                            for key, t in store.stripe_refs())
        drains = []
        for bs in budgets_stripes:
            st2 = _make(spec, stripe_symbols, extra_nodes)
            _fill(st2, _timing.rng(), n_objects, object_bytes)
            sc2 = RepairScheduler(st2)
            st2.subscribe(sc2.on_event)
            for v in st2.layout.nodes_in(0):
                st2.fail_node(v)
            budget = bs * 2 * spec.k * st2.S      # ~bs full-decode repairs
            t0 = time.perf_counter()
            rep = sc2.drain_all(budget_symbols=budget)
            wall = time.perf_counter() - t0
            assert st2.verify()
            drains.append({
                "budget_symbols_per_tick": budget,
                "ticks": rep.ticks,
                "drain_time_s": round(rep.drain_time_s, 6),
                "wall_s": round(wall, 4),
                "repaired_stripes": rep.repaired_stripes,
                "repaired_shares": rep.repaired_shares,
                "symbols_moved": rep.symbols_moved,
                "rs_baseline_symbols": rep.rs_baseline_symbols,
                "ratio_vs_rs": round(rep.ratio_vs_rs, 4),
                "batch_calls": rep.batch_calls,
                "decode_calls": rep.decode_calls,
            })
        row = {
            "k": k, "n": spec.n, "n_nodes": store.n_nodes,
            "n_racks": store.layout.n_racks,
            "stripe_symbols": store.S,
            "objects": n_objects, "object_mb": object_bytes / 2**20,
            "put_mbps": round(total_mb / put_s, 2),
            "get_mbps": round(total_mb / get_s, 2),
            "degraded_get": {
                "nodes_killed": len(victims),
                "cold_s": round(deg_cold_s, 4),
                "steady_s": round(deg_steady_s, 4),
                "steady_mbps": round(total_mb / deg_steady_s, 2),
            },
            "repair_queue_symbols": queue_symbols,
            "drain": drains,
        }
        rows.append(row)
        if not quiet:
            d0 = drains[0]
            print(f"[store] k={k} n_nodes={store.n_nodes}: "
                  f"put {row['put_mbps']} MB/s, get {row['get_mbps']} MB/s, "
                  f"degraded steady {row['degraded_get']['steady_mbps']} MB/s; "
                  f"drain@{d0['budget_symbols_per_tick']} sym/tick: "
                  f"{d0['ticks']} ticks, ratio_vs_rs={d0['ratio_vs_rs']}")
    return rows


if __name__ == "__main__":
    run()
