"""Paper §III-B / §IV-A: condition (6) and the minimum field size.

Reproduces the paper's two worked results and extends the table:
  * [4,2]: condition (6) = -c1^8 c2^4  => solvable over ANY field (F_2 works)
  * [6,3]: paper's w = circ(0,0,0,1,1,2) over F_5
and reports, per k, the smallest prime field admitting a valid double
circulant MSR code plus the number of coefficient candidates tried.
"""
import itertools
import time

from benchmarks import _timing
from repro.core import circulant


def scaling_limit(quiet=False) -> dict:
    """§IV-A extension: over GF(257), measure the zero-determinant rate of
    random k-subsets for a random coefficient vector.  The rate tracks ~1/p,
    so once C(2k,k) >> p some subset is singular w.h.p. for EVERY c — the
    construction stops admitting codes.  (Empirical boundary: k=8 OK,
    k=10 unobtainable after 8x4000 candidate searches.)"""
    import numpy as np
    from repro.core import gf
    out = {}
    rng = _timing.rng()
    for k in (4, 8, 10, 12):
        p = 257
        c = rng.integers(1, p, size=k).tolist()
        m = circulant.circulant_matrix(c, p)
        n = 2 * k
        full = set(range(n))
        bad = 0
        trials = 1500
        for _ in range(trials):
            s0 = tuple(sorted(rng.choice(n, size=k, replace=False).tolist()))
            sbar = sorted(full - set(s0))
            if gf.gauss_det(m[np.ix_(sbar, list(s0))], p) == 0:
                bad += 1
        out[k] = bad / trials
        if not quiet:
            print(f"[field-scaling] k={k:3d}: singular-subset rate "
                  f"{bad}/{trials} = {bad/trials:.3%} (1/p = {1/p:.3%})")
    return out


def run(ks=(2, 3, 4, 5), primes=(2, 3, 5, 7, 11, 13, 257), quiet=False):
    rows = []
    # paper checks
    assert circulant.check_condition6([1, 1], p=2), "[4,2] must work over F_2"
    assert circulant.check_condition6([1, 1, 2], p=5), "[6,3] paper solution over F_5"
    for k in ks:
        t0 = time.perf_counter()
        best_p, tried = None, 0
        for p in primes:
            space = (p - 1) ** k
            found = False
            if space <= 2000:
                for c in itertools.product(range(1, p), repeat=k):
                    tried += 1
                    if circulant.check_condition6(c, p):
                        found, sol = True, c
                        break
            else:
                try:
                    sol = tuple(int(x) for x in circulant.find_coefficients(k, p, max_trials=500))
                    found = True
                    tried += 1
                except ValueError:
                    found = False
            if found:
                best_p = p
                break
        rows.append({"k": k, "n": 2 * k, "min_field": best_p,
                     "solution_c": list(sol) if best_p else None,
                     "candidates_tried": tried,
                     "search_s": round(time.perf_counter() - t0, 3)})
        if not quiet:
            r = rows[-1]
            print(f"[field] [{2*k},{k}]: min prime field F_{r['min_field']}  "
                  f"c={r['solution_c']}  tried={r['candidates_tried']} "
                  f"({r['search_s']}s)")
    return rows


if __name__ == "__main__":
    run()
