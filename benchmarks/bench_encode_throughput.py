"""Paper §IV "computer efficiency": encode throughput, per dispatch backend.

Compares, per [n, k] at a fixed stream size:
  * core dense encode (M^T matmul through the dispatched backend)
  * every selectable GF backend's circulant_encode (structure-exploiting:
    k MACs/symbol instead of n — the 2x arithmetic saving the construction
    buys over a generic MDS encode)
  * optionally `pallas-interpret` — the seed repo's only CPU execution mode,
    kept as the validation baseline the dispatch layer is measured against
plus fold counts (the lazy mod-folding saving) and the ring-encode
collective's per-link traffic model (k blocks/link).

All paths are asserted bit-exact against each other before timing is
reported.  On CPU the dispatched backend is `jnp-int32`; interpret-mode
MB/s measures kernel *semantics*, not TPU performance (roofline.py covers
the TPU story).
"""
import jax.numpy as jnp
import numpy as np

from benchmarks import _timing
from benchmarks._timing import timeit as _timeit
from repro.core.circulant import CodeSpec
from repro.core.msr import DoubleCirculantMSR
from repro.core.ring import ring_link_traffic_blocks
from repro.kernels import dispatch, ops


def run(ks=(2, 8), stream_symbols: int = 1 << 16, *,
        include_interpret: bool = True, quiet=False):
    rows = []
    for k in ks:
        spec = CodeSpec.make(k, 257)
        code = DoubleCirculantMSR(spec)
        n = spec.n
        rng = _timing.rng()
        data = jnp.asarray(rng.integers(0, 257, (n, stream_symbols),
                                        dtype=np.int64), jnp.int32)
        mt = jnp.asarray(code._mt)
        mb = n * stream_symbols / 2**20

        oracle = np.asarray(code.encode(data))
        np.testing.assert_array_equal(
            np.asarray(ops.gf_matmul(mt, data, 257)), oracle,
            err_msg="dense M^T matmul disagrees with circulant encode")
        row = {
            "k": k, "n": n, "stream_mb": round(mb, 2),
            "dispatch_backend": code.backend_name,
            # dense = generic MDS encode (n MACs/symbol) on the same backend
            "dense_jnp_s": round(
                _timeit(lambda d: ops.gf_matmul(mt, d, 257), data), 4),
            "macs_per_symbol_dense": n,
            "macs_per_symbol_circulant": k,
            "ring_blocks_per_link": ring_link_traffic_blocks(spec),
            "fold_counts": {name: dispatch.fold_count(name, 257, k)
                            for name in ("jnp-int32", "jnp-f32")},
        }
        row["dense_mbps"] = round(mb / row["dense_jnp_s"], 1)

        # always time the auto-selected backend (e.g. `pallas` on TPU) so
        # the headline number is measured, never inferred from a fallback
        backends = list(dict.fromkeys(
            [code.backend_name, "jnp-int32", "jnp-f32"]))
        if include_interpret:
            backends.append("pallas-interpret")
        for name in backends:
            enc = dispatch.get(name).circulant_encode
            np.testing.assert_array_equal(
                np.asarray(enc(data, spec.c, 257)), oracle,
                err_msg=f"backend {name} disagrees with dispatched encode")
            t = _timeit(lambda d, e=enc: e(d, spec.c, 257), data)
            key = name.replace("-", "_")
            row[f"circulant_{key}_s"] = round(t, 4)
            row[f"circulant_{key}_mbps"] = round(mb / t, 1)

        # headline numbers: the dispatched fast path vs the seed baseline
        fast = code.backend_name.replace("-", "_")
        row["circulant_s"] = row[f"circulant_{fast}_s"]
        row["circulant_mbps"] = row[f"circulant_{fast}_mbps"]
        if include_interpret:
            row["speedup_vs_interpret"] = round(
                row["circulant_pallas_interpret_s"] / row["circulant_s"], 1)
        rows.append(row)
        if not quiet:
            extra = (f", {row['speedup_vs_interpret']}x vs interpret"
                     if include_interpret else "")
            print(f"[encode] k={k:3d} n={n:3d}: dense {row['dense_mbps']} MB/s, "
                  f"circulant[{code.backend_name}] {row['circulant_mbps']} MB/s"
                  f"{extra} ({n} vs {k} MAC/sym)")
    return rows


if __name__ == "__main__":
    run()
