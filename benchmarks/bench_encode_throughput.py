"""Paper §IV "computer efficiency": encode throughput.

Compares, per [n, k] at a fixed stream size:
  * core dense encode (M^T matmul, jnp)
  * Pallas gf_matmul kernel (interpret on CPU; MXU path on TPU)
  * Pallas circulant_encode kernel (structure-exploiting: k MACs/symbol
    instead of n — the 2x arithmetic saving the construction buys)
plus the ring-encode collective's per-link traffic model (k blocks/link).

NOTE on CPU: Pallas interpret mode measures the *kernel semantics*, not TPU
performance; the MB/s numbers are relative indicators, the symbol-op counts
are exact.  The roofline story for TPU lives in benchmarks/roofline.py.
"""
import time

import jax.numpy as jnp
import numpy as np

from repro.core.circulant import CodeSpec
from repro.core.msr import DoubleCirculantMSR
from repro.core.ring import ring_link_traffic_blocks
from repro.kernels import ops


def _timeit(fn, *args, reps=3):
    fn(*args).block_until_ready()          # compile
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    out.block_until_ready()
    return (time.perf_counter() - t0) / reps


def run(ks=(2, 8), stream_symbols: int = 1 << 16, quiet=False):
    rows = []
    for k in ks:
        spec = CodeSpec.make(k, 257)
        code = DoubleCirculantMSR(spec)
        n = spec.n
        rng = np.random.default_rng(0)
        data = jnp.asarray(rng.integers(0, 257, (n, stream_symbols), dtype=np.int64), jnp.int32)
        mt = jnp.asarray(code._mt)

        t_dense = _timeit(lambda d: code.encode(d), data)
        t_kmat = _timeit(lambda d: ops.gf_matmul(mt, d, 257), data)
        t_circ = _timeit(lambda d: ops.circulant_encode(d, spec.c, 257), data)
        # exact agreement across all three paths
        np.testing.assert_array_equal(
            np.asarray(code.encode(data)),
            np.asarray(ops.circulant_encode(data, spec.c, 257)))

        mb = n * stream_symbols / 2**20
        rows.append({
            "k": k, "n": n, "stream_mb": round(mb, 2),
            "dense_jnp_s": round(t_dense, 4),
            "pallas_gf_matmul_s": round(t_kmat, 4),
            "pallas_circulant_s": round(t_circ, 4),
            "dense_mbps": round(mb / t_dense, 1),
            "circulant_mbps": round(mb / t_circ, 1),
            "macs_per_symbol_dense": n,
            "macs_per_symbol_circulant": k,
            "ring_blocks_per_link": ring_link_traffic_blocks(spec),
        })
        if not quiet:
            r = rows[-1]
            print(f"[encode] k={k:3d} n={n:3d}: dense {r['dense_mbps']} MB/s, "
                  f"circulant-kernel {r['circulant_mbps']} MB/s "
                  f"({r['macs_per_symbol_dense']} vs {r['macs_per_symbol_circulant']} MAC/sym)")
    return rows


if __name__ == "__main__":
    run()
