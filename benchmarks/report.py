"""Generate the §Dry-run and §Roofline tables of EXPERIMENTS.md from the
dry-run artifacts, and the one-table ``BENCH_*.json`` summary the CI
bench-smoke job prints.  Run after (re-)running repro.launch.dryrun:

    PYTHONPATH=src python -m benchmarks.report > /tmp/tables.md
    PYTHONPATH=src python -m benchmarks.report --bench   # BENCH_* summary
"""
import json
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent.parent))

from benchmarks import roofline

RESULTS = pathlib.Path(__file__).resolve().parent / "dryrun_results"
REPO_ROOT = pathlib.Path(__file__).resolve().parent.parent


def fmt_bytes(b):
    return f"{b/2**30:.2f}"


def dryrun_table(mesh: str) -> str:
    rows = []
    for f in sorted(RESULTS.glob("*.json")):
        rec = json.loads(f.read_text())
        if rec.get("mesh") != mesh or "error" in rec:
            continue
        rows.append(rec)
    out = [f"#### Mesh `{mesh}` ({rows[0]['n_devices'] if rows else '?'} chips)",
           "",
           "| arch | shape | kind | params | args GiB/dev | temp GiB/dev | HLO GFLOP/dev | wire GiB/dev | AR/AG/RS/A2A/CP execs | compile s |",
           "|---|---|---|---|---|---|---|---|---|---|"]
    for r in sorted(rows, key=lambda r: (r["arch"], r["shape"])):
        d = r["dynamic"]["collectives"]
        execs = "/".join(str(d[k]["count"]) for k in
                         ("all-reduce", "all-gather", "reduce-scatter",
                          "all-to-all", "collective-permute"))
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['kind']} | "
            f"{r['n_params']/1e9:.2f}B | {fmt_bytes(r['memory']['argument_bytes'])} | "
            f"{fmt_bytes(r['memory']['temp_bytes'])} | "
            f"{r['dynamic']['flops']/1e9:.0f} | "
            f"{roofline.wire_bytes(r)/2**30:.2f} | {execs} | {r['compile_s']} |")
    return "\n".join(out)


def roofline_table(mesh: str) -> str:
    rows = [r for r in roofline.load_all() if r["mesh"] == mesh]
    out = ["| arch | shape | compute s | memory s | collective s | bottleneck | 6ND/HLO | proj. MFU | next lever |",
           "|---|---|---|---|---|---|---|---|---|"]
    for r in sorted(rows, key=lambda r: (r["arch"], r["shape"])):
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['t_compute_s']:.2e} | "
            f"{r['t_memory_s']:.2e} | {r['t_collective_s']:.2e} | "
            f"**{r['bottleneck']}** | {r['useful_flops_ratio']:.2f} | "
            f"{r['projected_mfu']:.1%} | {roofline.hint(r)} |")
    return "\n".join(out)


# ------------------------------------------------- BENCH_*.json summary
def _bench_headline(stem: str, rec) -> str:
    """One-line headline per trajectory file; unknown shapes degrade to a
    key listing instead of crashing the CI summary."""
    try:
        if stem == "BENCH_encode":
            r = rec[-1]
            return (f"k={r['k']} circulant {r['circulant_mbps']} MB/s "
                    f"({r.get('speedup_vs_interpret', '?')}x vs interpret)")
        if stem == "BENCH_checkpoint":
            r = rec[-1]
            return (f"k={r['k']} save {r['save_mbps']} MB/s, regenerate "
                    f"reads {r['restore']['regenerate']['frac_of_stored']} "
                    f"of stored")
        if stem == "BENCH_repair":
            r = rec["regeneration"][-1]
            bw = rec["repair_bandwidth"][-1]
            return (f"k={r['k']} fused {r['speedup_fused_vs_unfused']}x vs "
                    f"unfused; bandwidth saving vs EC "
                    f"{bw['saving_vs_ec']:.3f}")
        if stem == "BENCH_cluster":
            ratios = [s["repair_ratio_vs_rs"] for r in rec
                      for s in r["scenarios"]
                      if s["repair_ratio_vs_rs"] is not None]
            worst = max(ratios) if ratios else "n/a (no repair bytes)"
            lat = rec[-1]["degraded_read_latency"]["steady_s"]
            return (f"worst repair ratio vs RS {worst}; degraded read "
                    f"{lat * 1e3:.2f} ms steady")
        if stem == "BENCH_pipeline":
            rc = rec["recompiles"]
            return (f"k={rec['k']} mixed-size stream: store "
                    f"{rec['store']['speedup_vs_serial']}x / ckpt "
                    f"{rec['restore']['speedup_vs_serial']}x vs pre-plan "
                    f"serial; steady recompiles "
                    f"{rc['planned_steady_compiles']} (warmup "
                    f"{rc['planned_warmup_compiles']}); get p99 "
                    f"{rec['store']['get_latency_s']['p99']*1e3:.1f} ms")
        if stem == "BENCH_drills":
            oh = rec["checkpoint_overhead"]
            worst = max(r["resume_s"] for r in rec["time_to_resume"]["rows"])
            return (f"{len(rec['drills']['results'])} drills "
                    f"bit_exact={rec['all_bit_exact']} "
                    f"orphans={rec['orphans_total']}; write-behind ckpt "
                    f"+{oh['write_behind']['overhead_pct']}% vs stop-world "
                    f"+{oh['stop_world']['overhead_pct']}%; worst resume "
                    f"{worst*1e3:.0f} ms")
        if stem == "BENCH_serve":
            h = rec["healthy"]
            ab = rec["hedge_ab"]
            return (f"{h['req_per_s']} req/s healthy, p99 "
                    f"{h['latency']['p99_s']*1e3:.2f} ms; hedging cuts "
                    f"straggler p99 {ab['p99_cut']:.0%}; degraded failed="
                    f"{rec['degraded']['failed']}, corrupt served="
                    f"{rec['corrupt_storm']['corrupt_served']}, shed="
                    f"{rec['overload']['shed']} (typed)")
        if stem == "BENCH_shard":
            e4 = next(r for r in rec["encode"] if r["mesh"] == 4)
            bar = ("asserted" if rec["scaling_asserted"]
                   else f"skipped: {rec.get('scaling_skip_reason')}")
            return (f"4-device encode {e4['mbps']} MB/s "
                    f"({e4['speedup_vs_1dev']}x vs 1-device, 2x bar {bar}); "
                    f"parity_ok={rec['parity_ok']}, steady recompiles "
                    f"{rec['steady_recompiles']}")
        if stem == "BENCH_store":
            r = rec[-1]
            d = r["drain"][0]
            return (f"k={r['k']} put {r['put_mbps']} / get {r['get_mbps']} "
                    f"MB/s; drain {d['ticks']} ticks @ "
                    f"{d['budget_symbols_per_tick']} sym/tick, ratio_vs_rs "
                    f"{d['ratio_vs_rs']}")
        if stem == "BENCH_codes":
            fr = rec["frontier"]
            best = min(fr, key=lambda r: r["repair_ratio_vs_rs"])
            cv = rec["conversion"]
            return (f"{len(fr)} classes on frontier, best repair vs RS "
                    f"{best['repair_ratio_vs_rs']:.3f} "
                    f"({best['family']} n{best['n']}k{best['k']}"
                    f"d{best['d']}); convert {cv['mbps']} MB/s "
                    f"bit_exact={cv['bit_exact']} orphans={cv['orphans']}")
    except (KeyError, IndexError, TypeError) as e:
        return f"(unreadable: {type(e).__name__}: {e})"
    keys = list(rec) if isinstance(rec, dict) else f"{len(rec)} rows"
    return f"(unregistered trajectory file: {keys})"


def _bench_gap(stem: str, rec) -> str:
    """The overlap/roofline column (DESIGN.md §16.3): how close each
    hot path runs to its machine bound, so the trajectory of the gap is
    visible across PRs.  Files without the signal show a dash."""
    try:
        if stem == "BENCH_pipeline":
            ov = rec["overlap"]
            return (f"overlap {ov['overlap_speedup']}x, "
                    f"{ov['overlap_efficiency']:.0%} of bound "
                    f"({ov['host_parallelism']} CPU)")
        if stem == "BENCH_codes":
            fr = rec["frontier"]
            enc = max(r["roofline_frac_of_memcpy"] for r in fr)
            rep = max(r["repair_roofline_frac_of_memcpy"] for r in fr)
            dec = max(r["decode_roofline_frac_of_memcpy"] for r in fr)
            return (f"roofline enc {enc:.1%} / repair {rep:.1%} / "
                    f"decode {dec:.1%} of memcpy")
        if stem == "BENCH_repair":
            r = rec["regeneration"][-1]
            return f"fused repair {r['roofline_frac_of_memcpy']:.1%} of memcpy"
    except (KeyError, IndexError, TypeError):
        pass
    return "—"


# Every trajectory file the fast sweep is expected to produce; a missing
# one gets an explicit skip row instead of silently vanishing from the
# table (a CI summary that shrinks should be loud about why).
EXPECTED_BENCH = ("BENCH_encode", "BENCH_checkpoint", "BENCH_repair",
                  "BENCH_cluster", "BENCH_pipeline", "BENCH_drills",
                  "BENCH_serve", "BENCH_shard", "BENCH_store",
                  "BENCH_codes")


def bench_table() -> str:
    """Markdown summary of every repo-root BENCH_*.json — the one table
    the CI bench-smoke job prints after the fast sweep.  Expected files
    that are absent get a skip-with-notice row; unexpected extras are
    still summarized."""
    out = ["| trajectory file | headline | overlap / roofline |",
           "|---|---|---|"]
    files = sorted(REPO_ROOT.glob("BENCH_*.json"))
    if not files:
        return "(no repo-root BENCH_*.json found — run benchmarks.run first)"
    present = {f.stem for f in files}
    for f in files:
        rec = json.loads(f.read_text())
        out.append(f"| `{f.name}` | {_bench_headline(f.stem, rec)} | "
                   f"{_bench_gap(f.stem, rec)} |")
    for stem in EXPECTED_BENCH:
        if stem not in present:
            out.append(f"| `{stem}.json` | (missing — run "
                       f"`PYTHONPATH=src python -m benchmarks.run --fast`) | "
                       f"— |")
    return "\n".join(out)


def refresh_dynamics():
    """Recompute every artifact's `dynamic` block from its stored .hlo.gz —
    lets analyzer improvements apply without recompiling 66 cells."""
    import gzip
    import sys as _sys
    _sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent.parent / "src"))
    from repro.launch import hlo_stats
    n = 0
    for f in sorted(RESULTS.glob("*.json")):
        hlo = f.with_suffix(".hlo.gz")
        if not hlo.exists():
            continue
        rec = json.loads(f.read_text())
        with gzip.open(hlo, "rt") as fh:
            dyn = hlo_stats.analyze(fh.read())
        rec["dynamic"] = {"flops": dyn["flops"], "hbm_bytes": dyn["hbm_bytes"],
                          "collectives": dyn["collectives"]}
        f.write_text(json.dumps(rec, indent=2))
        n += 1
    print(f"refreshed {n} artifacts")


def main():
    if "--refresh" in sys.argv:
        refresh_dynamics()
        return
    if "--bench" in sys.argv:
        print("### Benchmark trajectory (repo-root BENCH_*.json)\n")
        print(bench_table())
        return
    print("<!-- generated by benchmarks/report.py -->")
    print("\n### Dry-run ledger\n")
    print(dryrun_table("pod16x16"))
    print()
    print(dryrun_table("pod2x16x16"))
    print("\n### Roofline (single pod, 256 chips)\n")
    print(roofline_table("pod16x16"))
    print("\n### Roofline (multi-pod, 512 chips)\n")
    print(roofline_table("pod2x16x16"))


if __name__ == "__main__":
    main()
