"""Data pipeline, optimizer, serving engine, and e2e system behaviour."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest
from _hypothesis_compat import given, settings, st

from repro.configs import get_config
from repro.data import pipeline
from repro.models import Model
from repro.optim import adamw, compression
from repro.serve.engine import ServingEngine, Request
from repro.train.loop import TrainConfig, train


# ------------------------------------------------------------------- data
def test_data_deterministic_and_stateless():
    cfg = pipeline.DataConfig(vocab_size=128, seq_len=32, global_batch=4, seed=7)
    b1 = pipeline.batch_at(cfg, 5)
    b2 = pipeline.batch_at(cfg, 5)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    b3 = pipeline.batch_at(cfg, 6)
    assert not np.array_equal(b1["tokens"], b3["tokens"])
    # labels are next-token shifted
    np.testing.assert_array_equal(b1["tokens"][:, 1:], b1["labels"][:, :-1])


def test_data_host_sharding_partitions_global_batch():
    g = pipeline.DataConfig(vocab_size=64, seq_len=16, global_batch=8, seed=1)
    full = pipeline.batch_at(g, 0)["tokens"]
    parts = []
    for host in range(4):
        c = pipeline.DataConfig(vocab_size=64, seq_len=16, global_batch=8,
                                seed=1, n_hosts=4, host_id=host)
        parts.append(pipeline.batch_at(c, 0)["tokens"])
    np.testing.assert_array_equal(np.concatenate(parts), full)


@given(st.integers(0, 1000), st.integers(8, 64))
@settings(max_examples=10, deadline=None)
def test_data_tokens_in_vocab(step, seq):
    cfg = pipeline.DataConfig(vocab_size=97, seq_len=seq, global_batch=2)
    b = pipeline.batch_at(cfg, step)
    assert b["tokens"].min() >= 0 and b["tokens"].max() < 97


# -------------------------------------------------------------- optimizer
def test_adamw_decreases_quadratic():
    cfg = adamw.AdamWConfig(lr=0.1, weight_decay=0.0, warmup_steps=1,
                            total_steps=100)
    params = {"x": jnp.asarray([5.0, -3.0])}
    state = adamw.init(params, cfg)
    loss = lambda p: jnp.sum(p["x"] ** 2)
    for _ in range(60):
        g = jax.grad(loss)(params)
        params, state, _ = adamw.update(cfg, g, state, params)
    assert float(loss(params)) < 0.3


def test_adamw_grad_clip():
    cfg = adamw.AdamWConfig(lr=1e-3, grad_clip=1.0)
    params = {"x": jnp.ones(4)}
    state = adamw.init(params, cfg)
    g = {"x": jnp.full(4, 1e6)}
    _, _, metrics = adamw.update(cfg, g, state, params)
    assert float(metrics["grad_norm"]) > 1e5   # reported pre-clip


def test_adamw_moment_dtype():
    cfg = adamw.AdamWConfig(moment_dtype="bfloat16")
    params = {"x": jnp.ones(4)}
    st_ = adamw.init(params, cfg)
    assert st_.mu["x"].dtype == jnp.bfloat16
    g = {"x": jnp.ones(4)}
    _, st2, _ = adamw.update(cfg, g, st_, params)
    assert st2.mu["x"].dtype == jnp.bfloat16


def test_schedule_warmup_and_decay():
    cfg = adamw.AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100,
                            min_lr_ratio=0.1)
    lr0 = float(adamw.schedule(cfg, jnp.asarray(1)))
    lr_w = float(adamw.schedule(cfg, jnp.asarray(10)))
    lr_end = float(adamw.schedule(cfg, jnp.asarray(100)))
    assert lr0 == pytest.approx(0.1, rel=1e-3)
    assert lr_w == pytest.approx(1.0, rel=1e-3)
    assert lr_end == pytest.approx(0.1, rel=1e-2)


# ------------------------------------------------------------ compression
def test_error_feedback_unbiased_over_steps():
    """Error feedback: quantization error accumulates and is re-injected, so
    the SUM of emitted updates tracks the sum of true gradients."""
    rng = np.random.default_rng(0)
    err = jnp.zeros(256)
    total_emitted = np.zeros(256)
    total_true = np.zeros(256)
    for _ in range(50):
        g = jnp.asarray(rng.normal(size=256) * 1e-3, jnp.float32)
        q, s, err = compression.ef_compress(g, err)
        total_emitted += np.asarray(compression.dequantize(q, s))
        total_true += np.asarray(g)
    # residual bounded by one quantization step
    assert np.abs(total_emitted - total_true).max() <= float(np.abs(err).max()) + 1e-6


def test_quantize_roundtrip_small_error():
    x = jnp.linspace(-1, 1, 255)
    q, s = compression.quantize(x)
    err = np.abs(np.asarray(compression.dequantize(q, s)) - np.asarray(x))
    assert err.max() <= float(s) / 2 + 1e-7


# ---------------------------------------------------------------- serving
@pytest.fixture(scope="module")
def tiny_serving():
    cfg = get_config("qwen3-4b").reduced(n_layers=2, d_model=32, n_heads=2,
                                         n_kv_heads=2, head_dim=16, d_ff=64,
                                         vocab_size=128)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def test_generate_shapes_and_determinism(tiny_serving):
    cfg, model, params = tiny_serving
    eng = ServingEngine(model, params, batch_size=4, max_len=64)
    prompts = np.arange(12, dtype=np.int32).reshape(2, 6) % cfg.vocab_size
    out1 = eng.generate(prompts, 8)
    out2 = eng.generate(prompts, 8)
    assert out1.shape == (2, 8)
    np.testing.assert_array_equal(out1, out2)   # greedy => deterministic
    assert out1.min() >= 0 and out1.max() < cfg.vocab_size


def test_serve_queue_continuous_batching(tiny_serving):
    cfg, model, params = tiny_serving
    eng = ServingEngine(model, params, batch_size=2, max_len=64)
    reqs = [Request(uid=i, prompt=np.arange(4 + i, dtype=np.int32) % cfg.vocab_size,
                    max_new_tokens=3 + i % 3) for i in range(5)]
    done = eng.serve(reqs, prompt_len=8)
    assert len(done) == 5
    assert all(r.done and len(r.out_tokens) == r.max_new_tokens for r in done)


# ------------------------------------------------------------- e2e system
def test_training_reduces_loss():
    """A tiny LM on the structured synthetic stream must learn (paper-era
    sanity: the substrate is real, not a stub)."""
    cfg = get_config("paper-tiny-lm").reduced(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab_size=128, loss_chunk=32)
    tcfg = TrainConfig(n_steps=150, global_batch=8, seq_len=32, log_every=149, seed=0)
    opt = adamw.AdamWConfig(lr=1e-2, warmup_steps=10, total_steps=150)
    _, history = train(cfg, tcfg, opt)
    first, last = history[0]["loss"], history[-1]["loss"]
    assert last < first - 0.5, (first, last)
