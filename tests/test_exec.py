"""Execution-plan layer tests (DESIGN.md §11): bucket-ladder
correctness, padded bit-exactness, cache accounting, the steady-state
zero-recompile guarantee, and the unified pipeline engine."""
import numpy as np
import pytest

from repro.core.circulant import CodeSpec
from repro.core.msr import DoubleCirculantMSR
from repro.exec import plan as plan_mod
from repro.exec.pipeline import Pipeline
from repro.exec.plan import PlanCache, PlanResult, bucket_symbols
from repro.kernels import dispatch

P = 257
SPEC = CodeSpec.make(4, P)


def fresh_planner(bucket_min=32) -> PlanCache:
    """An UNSHARED plan cache (stats start at zero regardless of what
    other tests warmed in the process-wide registry)."""
    return PlanCache(dispatch.get("jnp-int32"), P, bucket_min=bucket_min)


# ------------------------------------------------------------ bucket ladder
class TestBucketLadder:
    def test_floor_and_growth(self):
        assert bucket_symbols(1, bucket_min=64) == 64
        assert bucket_symbols(64, bucket_min=64) == 64
        assert bucket_symbols(65, bucket_min=64) == 128
        assert bucket_symbols(129, bucket_min=64) == 256

    def test_ladder_membership_and_cover(self):
        for s in (1, 7, 100, 4095, 4096, 4097, 1 << 20, (1 << 20) + 1):
            b = bucket_symbols(s)
            assert b >= s
            # b is on the ladder: bucket_min * ratio^j
            j = 0
            x = plan_mod.BUCKET_MIN
            while x < b:
                x = int(x * plan_mod.BUCKET_RATIO)
                j += 1
            assert x == b
            # and it is the SMALLEST such bucket
            assert b == plan_mod.BUCKET_MIN or \
                int(b / plan_mod.BUCKET_RATIO) < s

    def test_log_many_buckets(self):
        # a 1000x size range maps to a handful of plans — the whole point
        buckets = {bucket_symbols(s) for s in range(1 << 10, 1 << 20, 997)}
        assert len(buckets) <= 11

    def test_invalid(self):
        with pytest.raises(ValueError):
            bucket_symbols(0)
        with pytest.raises(ValueError):
            bucket_symbols(10, ratio=1.0)


# ------------------------------------------------------ padded bit-exactness
class TestPlannedOpsBitExact:
    """Bucket padding must be invisible: planned results at odd stream
    extents equal the unpadded reference exactly."""

    rng = np.random.default_rng(7)

    @pytest.mark.parametrize("s", [1, 5, 31, 32, 33, 100])
    def test_matmul(self, s):
        pc = fresh_planner()
        mat = self.rng.integers(0, P, (6, 8)).astype(np.int32)
        blocks = self.rng.integers(0, P, (8, s)).astype(np.int32)
        ref = (mat.astype(np.int64) @ blocks) % P
        out = pc.matmul(mat, blocks).host()
        assert out.shape == ref.shape
        np.testing.assert_array_equal(out, ref)

    @pytest.mark.parametrize("s", [3, 32, 57])
    def test_circulant_encode(self, s):
        pc = fresh_planner()
        code = DoubleCirculantMSR(SPEC)
        data = self.rng.integers(0, P, (SPEC.n, s)).astype(np.int32)
        ref = np.asarray(code.encode(data))
        out = pc.circulant_encode(data, tuple(SPEC.c)).host()
        np.testing.assert_array_equal(out, ref)

    @pytest.mark.parametrize("s", [9, 40])
    def test_regenerate_and_batch(self, s):
        pc = fresh_planner()
        code = DoubleCirculantMSR(SPEC)
        data = self.rng.integers(0, P, (SPEC.n, s)).astype(np.int32)
        red = np.asarray(code.encode(data))
        nodes = [2, 5, 7]
        r_prevs = np.stack([red[code.repair_plan(i).prev_node - 1]
                            for i in nodes])
        helpers = np.stack([data[list(code.repair_plan(i).data_indices)]
                            for i in nodes])
        rmat = code.repair.repair_matrix()
        one = pc.regenerate(rmat, r_prevs[0], helpers[0]).host()
        a, r = code.regenerate(nodes[0], r_prevs[0], helpers[0])
        np.testing.assert_array_equal(one[0], np.asarray(a))
        np.testing.assert_array_equal(one[1], np.asarray(r))
        # batch: BOTH axes padded (F=3 -> batch bucket 4), trimmed back
        batch = pc.regenerate_batch(rmat, r_prevs, helpers).host()
        ref = np.asarray(code.regenerate_batch(nodes, r_prevs, helpers))
        assert batch.shape == ref.shape == (3, 2, s)
        np.testing.assert_array_equal(batch, ref)

    def test_disabled_fallback_bit_exact(self):
        pc = fresh_planner()
        mat = self.rng.integers(0, P, (4, 8)).astype(np.int32)
        blocks = self.rng.integers(0, P, (8, 21)).astype(np.int32)
        ref = (mat.astype(np.int64) @ blocks) % P
        with plan_mod.planning_disabled():
            out = pc.matmul(mat, blocks)
            assert isinstance(out, PlanResult)
            np.testing.assert_array_equal(out.host(), ref)
        assert pc.plan_stats().compiles == 0     # bypassed entirely


# -------------------------------------------------------- cache accounting
class TestPlanStats:
    def test_hits_misses_compiles(self):
        pc = fresh_planner(bucket_min=32)
        mat = np.eye(8, dtype=np.int32)
        for s, expect in ((10, (0, 1)), (20, (1, 1)), (32, (2, 1)),
                          (33, (2, 2)), (40, (3, 2)), (10, (4, 2))):
            pc.matmul(mat, np.ones((8, s), np.int32))
            st = pc.plan_stats()
            assert (st.hits, st.misses) == expect, s
            assert st.compiles == st.misses
        # a different op at the same bucket is its own plan
        pc.circulant_encode(np.ones((8, 10), np.int32), tuple(SPEC.c))
        assert pc.plan_stats().misses == 3
        pc.reset_stats()
        assert pc.plan_stats() == (0, 0, 0)

    def test_registry_aggregates_and_shares(self):
        be = dispatch.get("jnp-int32")
        a = plan_mod.get_planner(be, P)
        b = plan_mod.get_planner(be, P)
        assert a is b                      # one cache per (backend, p, ...)
        agg = plan_mod.plan_stats()
        assert agg.compiles >= a.plan_stats().compiles


# --------------------------------------------- steady-state recompile guard
class TestRecompileRegression:
    def test_store_and_checkpoint_steady_state(self, tmp_path):
        """A put/get/restore loop over varied sizes performs ZERO new
        compiles after its warm-up pass — the PR's acceptance bar."""
        from repro.store import CodedObjectStore
        from repro.checkpoint.msr_checkpoint import MSRCheckpointer

        rng = np.random.default_rng(0)
        store = CodedObjectStore(SPEC, n_nodes=SPEC.n + 2,
                                 stripe_symbols=256)
        ck = MSRCheckpointer(tmp_path, SPEC, keep_last=10)
        sizes = [300, 1700, 5000, 9000, 12000]

        def one_pass(tag):
            for i, size in enumerate(sizes):
                payload = bytes(rng.integers(0, 256, size,
                                             dtype=np.int64)
                                .astype(np.uint8))
                store.put(f"{tag}/{i}", payload)
                assert store.get(f"{tag}/{i}") == payload
                state = {"x": np.frombuffer(payload, np.uint8)
                         .astype(np.float32)}
                ck.save(i, state)
                got, _ = ck.restore(state, i, failed_nodes=[2],
                                    repair=False)
                np.testing.assert_array_equal(got["x"], state["x"])

        one_pass("warm")                       # compiles land here
        store.fail_node(1)
        one_pass("warm2")                      # degraded-read plans land
        warm = plan_mod.plan_stats()
        one_pass("steady")                     # same buckets, new sizes
        one_pass("steady2")
        steady = plan_mod.plan_stats()
        assert steady.compiles == warm.compiles, (
            f"steady-state recompiles: {steady.compiles - warm.compiles}")
        assert steady.hits > warm.hits         # the loop really ran planned


# ----------------------------------------------------------------- pipeline
class TestPipeline:
    @pytest.mark.parametrize("depth", [1, 2, 3])
    def test_stream_tiles_in_order_and_complete(self, depth):
        out = np.empty(103, np.int64)
        order = []
        with Pipeline(io_workers=2, depth=depth) as pipe:
            pipe.stream_tiles(
                103, 10,
                lambda sl: np.arange(sl.start, sl.stop),
                lambda sl, r: (order.append(sl.start),
                               out.__setitem__(sl, r)))
        np.testing.assert_array_equal(out, np.arange(103))
        assert order == sorted(order)          # consumed in stream order

    def test_map_with_read_prefetch(self):
        reads, consumed = [], []
        pipe = Pipeline(io_workers=2, depth=2)
        pipe.map(list(range(7)),
                 lambda i, d: d * 10,
                 lambda i, r: consumed.append(r),
                 read=lambda i: (reads.append(i), i + 1)[1])
        pipe.close()
        assert consumed == [10, 20, 30, 40, 50, 60, 70]
        assert sorted(reads) == list(range(7))

    def test_depth_one_is_serial(self):
        """depth=1: item t is fully consumed before t+1's compute —
        the benchmark's no-overlap baseline."""
        events = []
        with Pipeline(io_workers=1, depth=1) as pipe:
            pipe.map([0, 1, 2],
                     lambda i: events.append(("c", i)),
                     lambda i, r: events.append(("u", i)))
        assert events == [("c", 0), ("u", 0), ("c", 1), ("u", 1),
                          ("c", 2), ("u", 2)]

    def test_submit_error_surfaces_on_exit(self):
        def boom():
            raise OSError("disk on fire")
        with pytest.raises(OSError, match="disk on fire"):
            with Pipeline(io_workers=1) as pipe:
                pipe.submit(boom)

    def test_barrier_clears_and_reuse_after_close(self):
        pipe = Pipeline(io_workers=1)
        fut = pipe.submit(lambda: 42)
        pipe.barrier()
        assert fut.result() == 42
        pipe.close()
        assert pipe.submit(lambda: 1).result() == 1    # fresh pool spins up
        pipe.close()


# ------------------------------------------------------------- plan result
def test_plan_result_trims_stream_and_batch():
    raw = np.arange(4 * 2 * 8).reshape(4, 2, 8)
    res = PlanResult(raw, symbols=5, batch=3)
    out = res.host()
    assert out.shape == (3, 2, 5)
    np.testing.assert_array_equal(out, raw[:3, :, :5])
    np.testing.assert_array_equal(np.asarray(res), out)   # __array__


def test_store_close_releases_pool_and_store_stays_usable():
    from repro.store import CodedObjectStore
    with CodedObjectStore(SPEC, stripe_symbols=64) as store:
        store.put("x", b"abc")
        assert store.get("x") == b"abc"
    assert store.pipeline._ex is None          # pool released on exit
    store.put("y", b"def")                     # lazily respawns
    assert store.get("y") == b"def"
    store.close()


def test_planned_validation_errors():
    code = DoubleCirculantMSR(SPEC)
    with pytest.raises(ValueError, match="helper"):
        code.repair.regenerate_planned(1, np.ones(8, np.int32),
                                       np.ones((SPEC.k + 1, 8), np.int32))
    with pytest.raises(ValueError, match="blocks"):
        code.encode_planned(np.ones((SPEC.n - 1, 8), np.int32))
