"""Trip-count-aware HLO analyzer: validated against analytic FLOP counts of
known programs and a crafted HLO module."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch import hlo_stats


def compile_text(fn, *args):
    return jax.jit(fn).lower(*args).compile().as_text()


def test_scan_matmul_flops_trip_aware():
    """5-iteration scan of a 128^3 matmul: analytic = 5 * 2 * 128^3."""
    x = jax.ShapeDtypeStruct((128, 128), jnp.float32)

    def fn(a):
        def body(c, _):
            return c @ a, None
        out, _ = jax.lax.scan(body, a, None, length=5)
        return out

    txt = compile_text(fn, x)
    r = hlo_stats.analyze(txt)
    want = 5 * 2 * 128**3
    assert want * 0.8 <= r["flops"] <= want * 1.6, (r["flops"], want)


def test_nested_scan_multiplies_trip_counts():
    x = jax.ShapeDtypeStruct((64, 64), jnp.float32)

    def fn(a):
        def outer(c, _):
            def inner(ci, _):
                return ci @ a, None
            ci, _ = jax.lax.scan(inner, c, None, length=3)
            return ci, None
        out, _ = jax.lax.scan(outer, a, None, length=4)
        return out

    txt = compile_text(fn, x)
    r = hlo_stats.analyze(txt)
    want = 12 * 2 * 64**3
    assert want * 0.8 <= r["flops"] <= want * 1.8, (r["flops"], want)


def test_no_loop_matmul_counted_once():
    x = jax.ShapeDtypeStruct((256, 256), jnp.float32)
    txt = compile_text(lambda a: a @ a, x)
    r = hlo_stats.analyze(txt)
    want = 2 * 256**3
    assert want * 0.9 <= r["flops"] <= want * 1.3, (r["flops"], want)


def test_crafted_collectives_and_symbols():
    hlo = """HloModule test, entry_computation_layout={()->f32[]}

%cond (p: (s32[], f32[64])) -> pred[] {
  %p = (s32[], f32[64]) parameter(0)
  %gte = s32[] get-tuple-element(%p), index=0
  %c = s32[] constant(7)
  ROOT %cmp = pred[] compare(%gte, %c), direction=LT
}

%body (p2: (s32[], f32[64])) -> (s32[], f32[64]) {
  %p2 = (s32[], f32[64]) parameter(0)
  %g0 = s32[] get-tuple-element(%p2), index=0
  %g1 = f32[64]{0} get-tuple-element(%p2), index=1
  %ar = f32[64]{0} all-reduce(%g1), replica_groups={}, to_apply=%sum
  %one = s32[] constant(1)
  %next = s32[] add(%g0, %one)
  ROOT %t = (s32[], f32[64]) tuple(%next, %ar)
}

%sum (a: f32[], b: f32[]) -> f32[] {
  %a = f32[] parameter(0)
  %b = f32[] parameter(1)
  ROOT %s = f32[] add(%a, %b)
}

ENTRY %main (x: f32[64]) -> (s32[], f32[64]) {
  %x = f32[64]{0} parameter(0)
  %z = s32[] constant(0)
  %init = (s32[], f32[64]) tuple(%z, %x)
  ROOT %w = (s32[], f32[64]) while(%init), condition=%cond, body=%body
}
"""
    r = hlo_stats.analyze(hlo)
    assert r["entry"] == "main"
    # 7 loop iterations x one 64-float all-reduce
    ar = r["collectives"]["all-reduce"]
    assert ar["count"] == 7, ar
    assert ar["bytes"] == 7 * 64 * 4, ar


def test_parse_collectives_symbol_table():
    from repro.launch.dryrun import parse_collectives
    hlo = """HloModule m

ENTRY %main (x: f32[128]) -> f32[128] {
  %x = f32[128]{0} parameter(0)
  %y = f32[128]{0} add(%x, %x)
  %ag = f32[512]{0} all-gather(%y), dimensions={0}
  %rs = f32[128]{0} reduce-scatter(%ag), dimensions={0}, to_apply=%s
  ROOT %out = f32[128]{0} all-reduce(%rs), to_apply=%s
}
"""
    c = parse_collectives(hlo)
    assert c["all-gather"]["count"] == 1
    assert c["all-gather"]["bytes"] == 128 * 4        # operand
    assert c["all-gather"]["result_bytes"] == 512 * 4
    assert c["reduce-scatter"]["bytes"] == 512 * 4
    assert c["all-reduce"]["bytes"] == 128 * 4


def _check_dryrun_record(rec: dict, name: str) -> None:
    assert "error" not in rec, name
    assert rec["dynamic"]["flops"] >= rec["cost"]["flops"] * 0.5, name
    if rec["kind"] == "train":
        # trip-aware flops must exceed 6ND/chips (bwd+remat overhead)
        model = 6 * rec["n_active_params"] * rec["tokens_per_step"] / rec["n_devices"]
        assert rec["dynamic"]["flops"] > 0.5 * model, name


def test_dryrun_artifacts_consistency():
    """End-to-end dry-run smoke: lower a REDUCED train cell on a forced
    8-device (4 data x 2 model) mesh in a subprocess and assert the
    artifact invariants on the result — so the checks run in every CI
    pass instead of skipping when the 512-chip matrix hasn't been
    produced.  Real artifacts, when present, are held to the same bar.
    """
    import json
    import os
    import pathlib
    import subprocess
    import sys
    import textwrap
    code = textwrap.dedent("""
        import json, os, sys
        os.environ["REPRO_DRYRUN_DEVICES"] = "8"
        from repro.launch import dryrun          # sets XLA_FLAGS pre-jax
        import jax
        assert len(jax.devices()) == 8, jax.devices()
        from repro.configs import get_config
        from repro.configs.base import ShapeConfig
        cfg = get_config("qwen3-4b").reduced(
            n_layers=2, d_model=32, n_heads=4, n_kv_heads=2, head_dim=8,
            d_ff=64, vocab_size=256, loss_chunk=16)
        shape = ShapeConfig("train_smoke", 64, 8, "train")
        mesh = jax.make_mesh((4, 2), ("data", "model"))
        rec = dryrun.lower_cell("qwen3-4b", "train_smoke",
                                cfg=cfg, shape=shape, mesh=mesh)
        json.dump(rec, sys.stdout)
    """)
    env = dict(os.environ)
    src = str(pathlib.Path(__file__).resolve().parent.parent / "src")
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    env.pop("XLA_FLAGS", None)
    res = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=env, timeout=480)
    assert res.returncode == 0, f"stdout:\n{res.stdout}\nstderr:\n{res.stderr}"
    rec = json.loads(res.stdout)
    assert rec["n_devices"] == 8
    assert rec["mesh"] == "mesh4x2"
    assert rec["collectives"]["total_bytes"] > 0   # model axis => collectives
    _check_dryrun_record(rec, "train_smoke")
    # any committed full-scale artifacts must hold the same invariants
    res_dir = pathlib.Path(__file__).resolve().parent.parent / "benchmarks" / "dryrun_results"
    for f in res_dir.glob("*.json"):
        if f.name.endswith(".error.json"):
            continue
        _check_dryrun_record(json.loads(f.read_text()), f.name)
