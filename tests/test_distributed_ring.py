"""Multi-device tests for the ICI-ring MSR encode and int8 gradient sync.

These need >1 device, so they run in a SUBPROCESS with
XLA_FLAGS=--xla_force_host_platform_device_count=8 (the main test process
keeps the host's single device, per DESIGN.md §8).
"""
import os
import subprocess
import sys
import textwrap

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_subprocess(body: str):
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax
        import jax.numpy as jnp
        import numpy as np
        assert len(jax.devices()) == 8, jax.devices()
    """) + textwrap.dedent(body)
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    env.pop("XLA_FLAGS", None)
    res = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=env, timeout=480)
    assert res.returncode == 0, f"stdout:\n{res.stdout}\nstderr:\n{res.stderr}"
    return res.stdout


def _spec_literal(k, p):
    """Search coefficients in THIS process (memoized) and inline the result,
    so the subprocess skips the condition-(6) search it isn't testing."""
    from repro.core.circulant import CodeSpec
    spec = CodeSpec.make(k, p)
    return f"CodeSpec(k={spec.k}, p={spec.p}, c={spec.c!r})"


def test_ring_encode_matches_dense_oracle():
    run_subprocess(f"""
        from repro.core.circulant import CodeSpec
        from repro.core.ring import ring_encode, ring_encode_reference
        from repro.launch.mesh import make_storage_mesh
        spec = {_spec_literal(4, 257)}                   # n = 8 nodes
        mesh = make_storage_mesh(8)
        rng = np.random.default_rng(0)
        # full-range symbols: int32 wire
        data = rng.integers(0, 257, size=(8, 4096), dtype=np.int64).astype(np.int32)
        with mesh:
            got = np.asarray(ring_encode(jnp.asarray(data), spec, mesh))
        want = np.asarray(ring_encode_reference(jnp.asarray(data), spec))
        np.testing.assert_array_equal(got, want)
        # systematic byte blocks: uint8 wire (4x less traffic) must agree
        dbytes = rng.integers(0, 256, size=(8, 4096), dtype=np.int64).astype(np.int32)
        with mesh:
            got8 = np.asarray(ring_encode(jnp.asarray(dbytes), spec, mesh,
                                          byte_wire=True))
        want8 = np.asarray(ring_encode_reference(jnp.asarray(dbytes), spec))
        np.testing.assert_array_equal(got8, want8)
        print("ring encode OK")
    """)


def test_ring_encode_various_sizes():
    from repro.core.circulant import CodeSpec
    cases = []
    for k, p, s in [(4, 257, 128), (4, 257, 1000), (4, 5, 64)]:
        try:
            CodeSpec.make(k, p)
        except ValueError:
            continue
        cases.append(f"({_spec_literal(k, p)}, {s})")
    run_subprocess("""
        from repro.core.circulant import CodeSpec
        from repro.core.ring import ring_encode, ring_encode_reference
        from repro.launch.mesh import make_storage_mesh
        for spec, s in [%s]:
            k, p = spec.k, spec.p
            mesh = make_storage_mesh(2 * k)
            rng = np.random.default_rng(k + s)
            data = rng.integers(0, p, size=(2 * k, s), dtype=np.int64).astype(np.int32)
            with mesh:
                got = np.asarray(ring_encode(jnp.asarray(data), spec, mesh))
            want = np.asarray(ring_encode_reference(jnp.asarray(data), spec))
            np.testing.assert_array_equal(got, want, err_msg=f"k={k} p={p} s={s}")
        print("sizes OK")
    """ % ", ".join(cases))


def test_int8_ring_mean_close_to_true_mean():
    run_subprocess("""
        from repro.optim.compression import int8_ring_mean
        mesh = jax.make_mesh((8,), ("data",))
        rng = np.random.default_rng(1)
        x = rng.normal(size=(8, 4096)).astype(np.float32)
        got = np.asarray(int8_ring_mean(jnp.asarray(x), mesh, "data"))
        want = x.mean(0)
        for row in got:
            err = np.abs(row - want).max()
            scale = np.abs(x).max() / 127
            assert err < 10 * scale, (err, scale)   # a few re-quantized hops
        print("int8 ring mean OK")
    """)


def test_sharded_train_step_runs_on_host_mesh():
    """End-to-end: jit train_step with the sharding policy on an 8-device
    host mesh (data=4, model=2) — the same policy the dry-run uses."""
    run_subprocess("""
        from jax.sharding import PartitionSpec as P
        import jax
        mesh = jax.make_mesh((4, 2), ("data", "model"))
        from repro.configs import get_config
        from repro.models import Model
        from repro.optim import adamw
        from repro.launch.steps import make_train_step, input_specs
        from repro.sharding import policy, ctx as shctx
        from repro.configs.base import ShapeConfig

        cfg = get_config("qwen3-4b").reduced(
            n_layers=2, d_model=32, n_heads=4, n_kv_heads=2, head_dim=8,
            d_ff=64, vocab_size=256, loss_chunk=16)
        model = Model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        opt_cfg = adamw.AdamWConfig(lr=1e-3)
        state = {"params": params, "opt": adamw.init(params, opt_cfg)}
        pspecs = policy.param_specs(jax.eval_shape(lambda: params), mesh)
        state_sh = {"params": pspecs,
                    "opt": adamw.OptState(mu=pspecs, nu=pspecs, step=P())}
        rng = np.random.default_rng(0)
        batch = {"tokens": jnp.asarray(rng.integers(0, 256, (8, 32)), jnp.int32),
                 "labels": jnp.asarray(rng.integers(0, 256, (8, 32)), jnp.int32)}
        bspecs = policy.batch_spec(jax.eval_shape(lambda: batch), mesh, global_batch=8)
        rules = policy.activation_rules(cfg, mesh, "train")
        with mesh, shctx.rules(mesh, rules):
            fn = jax.jit(make_train_step(model, opt_cfg, 2),
                         in_shardings=(policy.named(state_sh, mesh),
                                       policy.named(bspecs, mesh)),
                         donate_argnums=(0,))
            state2, metrics = fn(state, batch)
        loss = float(metrics["loss"])
        assert np.isfinite(loss) and loss > 0, loss
        # compare against single-device reference
        params_ref = Model(cfg).init(jax.random.PRNGKey(0))
        state_ref = {"params": params_ref, "opt": adamw.init(params_ref, opt_cfg)}
        fn_ref = jax.jit(make_train_step(model, opt_cfg, 2), donate_argnums=(0,))
        _, m_ref = fn_ref(state_ref, batch)
        assert abs(loss - float(m_ref["loss"])) < 0.05, (loss, float(m_ref["loss"]))
        print("sharded train step OK", loss)
    """)
