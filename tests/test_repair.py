"""Fused batched repair engine (core/repair.py, DESIGN.md §4).

  * fused single-matmul regeneration is BIT-EXACT vs the unfused reference
    for every node, every registered backend, k in {2, 3, 4, 8};
  * batched (vmapped + stream-tiled) regeneration matches per-node calls;
  * the decode-inverse LRU serves repeated reconstructions from ONE
    ``gf.gauss_inverse`` per node subset, order-insensitively.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import gf
from repro.core.circulant import CodeSpec
from repro.core.msr import DoubleCirculantMSR
from repro.core.repair import build_repair_matrix

# native `pallas` needs a real TPU; interpret mode covers its semantics here
BACKENDS = ["jnp-int32", "jnp-f32", "pallas-interpret"]
if jax.default_backend() == "tpu":
    BACKENDS.append("pallas")

KS = (2, 3, 4, 8)


def random_blocks(n, s, p, seed=0):
    return jnp.asarray(np.random.default_rng(seed).integers(
        0, p, size=(n, s), dtype=np.int64), jnp.int32)


def helpers_for(code, data, red, i):
    plan = code.repair_plan(i)
    return red[plan.prev_node - 1], data[jnp.asarray(plan.data_indices)]


# ------------------------------------------------------------ fused parity
@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("k", KS)
def test_fused_regenerate_bit_exact_every_node(backend, k):
    spec = CodeSpec.make(k, 257)
    code = DoubleCirculantMSR(spec, backend=backend)
    n = spec.n
    data = random_blocks(n, 48, 257, seed=k)
    red = code.encode(data)
    for i in range(1, n + 1):
        r_prev, nxt = helpers_for(code, data, red, i)
        a_f, r_f = code.regenerate(i, r_prev, nxt)
        a_u, r_u = code.regenerate_reference(i, r_prev, nxt)
        np.testing.assert_array_equal(np.asarray(a_f), np.asarray(a_u),
                                      err_msg=f"{backend} k={k} node={i}")
        np.testing.assert_array_equal(np.asarray(r_f), np.asarray(r_u),
                                      err_msg=f"{backend} k={k} node={i}")
        # and both ARE the lost pair
        np.testing.assert_array_equal(np.asarray(a_f), np.asarray(data[i - 1]))
        np.testing.assert_array_equal(np.asarray(r_f), np.asarray(red[i - 1]))


def test_repair_matrix_node_invariant_and_small():
    spec = CodeSpec.make(4, 257)
    code = DoubleCirculantMSR(spec)
    r = build_repair_matrix(spec)
    assert r.shape == (2, spec.k + 1)
    assert r.dtype == np.int32
    assert int(r.min()) >= 0 and int(r.max()) < spec.p
    for i in (1, 3, spec.n):
        np.testing.assert_array_equal(code.repair.repair_matrix(i), r)
    with pytest.raises(ValueError):
        code.repair.repair_matrix(spec.n + 1)


def test_fused_regenerate_custom_matmul():
    """Custom injected matmuls keep every field op routed through the
    injected function — the fused path still applies (non-jitted)."""
    calls = []

    def mm(a, b, p):
        calls.append(np.asarray(a).shape)
        return gf.matmul(a, b, p)

    spec = CodeSpec.make(3, 257)
    code = DoubleCirculantMSR(spec, matmul=mm)
    data = random_blocks(spec.n, 32, 257, seed=1)
    red = code.encode(data)
    r_prev, nxt = helpers_for(code, data, red, 2)
    calls.clear()
    a_new, r_new = code.regenerate(2, r_prev, nxt)
    np.testing.assert_array_equal(np.asarray(a_new), np.asarray(data[1]))
    np.testing.assert_array_equal(np.asarray(r_new), np.asarray(red[1]))
    assert calls == [(2, spec.k + 1)]       # ONE fused matmul, nothing else


# ------------------------------------------------------------------ batched
@pytest.mark.parametrize("tile", [None, 7, 48])
def test_regenerate_batch_matches_single(tile):
    spec = CodeSpec.make(4, 257)
    code = DoubleCirculantMSR(spec)
    n = spec.n
    data = random_blocks(n, 48, 257, seed=9)
    red = code.encode(data)
    nodes = list(range(1, n + 1))
    r_prevs = jnp.stack([helpers_for(code, data, red, i)[0] for i in nodes])
    next_all = jnp.stack([helpers_for(code, data, red, i)[1] for i in nodes])
    out = code.regenerate_batch(nodes, r_prevs, next_all, tile_symbols=tile)
    assert out.shape == (n, 2, 48)
    np.testing.assert_array_equal(np.asarray(out[:, 0]), np.asarray(data))
    np.testing.assert_array_equal(np.asarray(out[:, 1]), np.asarray(red))


def test_regenerate_batch_subset_and_shape_validation():
    spec = CodeSpec.make(2, 257)
    code = DoubleCirculantMSR(spec)
    data = random_blocks(spec.n, 16, 257, seed=3)
    red = code.encode(data)
    nodes = [2, 4]
    r_prevs = jnp.stack([helpers_for(code, data, red, i)[0] for i in nodes])
    next_all = jnp.stack([helpers_for(code, data, red, i)[1] for i in nodes])
    out = code.regenerate_batch(nodes, r_prevs, next_all)
    for row, i in enumerate(nodes):
        np.testing.assert_array_equal(np.asarray(out[row, 0]),
                                      np.asarray(data[i - 1]))
    with pytest.raises(ValueError):
        code.regenerate_batch([2], r_prevs, next_all)   # F mismatch


# ------------------------------------------------------- decode-inverse LRU
def test_repeated_reconstruct_single_gauss_inverse(monkeypatch):
    """Acceptance: repeated `reconstruct` on the same node subset performs
    exactly one `gf.gauss_inverse` — order of the subset irrelevant."""
    calls = []
    real = gf.gauss_inverse
    monkeypatch.setattr(gf, "gauss_inverse",
                        lambda m, p: (calls.append(1), real(m, p))[1])
    spec = CodeSpec.make(4, 257)
    code = DoubleCirculantMSR(spec)
    n = spec.n
    data = random_blocks(n, 24, 257, seed=5)
    red = code.encode(data)

    def rec(ids):
        sel = jnp.asarray([i - 1 for i in ids])
        got = code.reconstruct(ids, data[sel], red[sel])
        np.testing.assert_array_equal(np.asarray(got), np.asarray(data))

    rec([1, 3, 5, 7])
    rec([1, 3, 5, 7])
    rec([7, 1, 5, 3])          # same subset, different order: still cached
    assert len(calls) == 1
    info = code.repair.decode_cache.cache_info()
    assert (info.hits, info.misses, info.size) == (2, 1, 1)
    rec([2, 4, 6, 8])          # new subset: one more solve
    assert len(calls) == 2


def test_decode_cache_lru_eviction():
    spec = CodeSpec.make(2, 257)
    code = DoubleCirculantMSR(spec, inverse_cache_size=2)
    cache = code.repair.decode_cache
    cache.inverse((1, 2))
    cache.inverse((1, 3))
    cache.inverse((1, 2))      # refresh 1,2 -> LRU victim is 1,3
    cache.inverse((1, 4))      # evicts 1,3
    assert cache.cache_info().size == 2
    misses = cache.cache_info().misses
    cache.inverse((1, 3))      # gone: recomputed
    assert cache.cache_info().misses == misses + 1
    with pytest.raises(ValueError):
        cache.inverse((2, 1))  # unsorted keys rejected (engine sorts)


# -------------------------------------------------- one-matmul multi-repair
@pytest.mark.parametrize("n_failed", [1, 2, 4])
def test_reconstruct_with_repair_lost_pairs(n_failed):
    spec = CodeSpec.make(4, 257)
    code = DoubleCirculantMSR(spec)
    n = spec.n
    data = random_blocks(n, 40, 257, seed=n_failed)
    red = code.encode(data)
    failed = list(range(1, n_failed + 1))
    use = [i for i in range(1, n + 1) if i not in failed][: spec.k]
    sel = jnp.asarray([i - 1 for i in use])
    got_data, got_red = code.reconstruct_with_repair(
        use, data[sel], red[sel], failed)
    np.testing.assert_array_equal(np.asarray(got_data), np.asarray(data))
    np.testing.assert_array_equal(
        np.asarray(got_red),
        np.asarray(red[jnp.asarray([f - 1 for f in failed])]))
