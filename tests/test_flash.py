"""Flash custom-VJP attention vs the materializing reference: forward AND
gradients, causal/window/cross variants, chunk-size sweep."""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import attention as attn
from repro.models.flash import flash_attention


def make_inputs(b=2, sq=64, sk=64, h=4, hd=16, seed=0, dtype=jnp.float32):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (b, sq, h, hd), dtype) * 0.5
    k = jax.random.normal(ks[1], (b, sk, h, hd), dtype) * 0.5
    v = jax.random.normal(ks[2], (b, sk, h, hd), dtype) * 0.5
    q_pos = jnp.broadcast_to(jnp.arange(sk - sq, sk, dtype=jnp.int32)[None], (b, sq))
    k_pos = jnp.broadcast_to(jnp.arange(sk, dtype=jnp.int32)[None], (b, sk))
    return q, k, v, q_pos, k_pos


def ref(q, k, v, q_pos, k_pos, causal, window):
    bias = attn._mask_bias(q_pos, k_pos, causal=causal, window=window)
    return attn._sdpa(q, k, v, bias)


@pytest.mark.parametrize("causal,window", [(True, None), (False, None),
                                           (True, 16)])
@pytest.mark.parametrize("kv_chunk", [16, 64])
def test_forward_matches_reference(causal, window, kv_chunk):
    q, k, v, qp, kp = make_inputs()
    got = flash_attention(q, k, v, qp, kp, causal, window, kv_chunk)
    want = ref(q, k, v, qp, kp, causal, window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("causal,window", [(True, None), (True, 24)])
def test_gradients_match_reference(causal, window):
    q, k, v, qp, kp = make_inputs(sq=48, sk=48)

    def loss_flash(q_, k_, v_):
        return jnp.sum(flash_attention(q_, k_, v_, qp, kp, causal, window, 16) ** 2)

    def loss_ref(q_, k_, v_):
        return jnp.sum(ref(q_, k_, v_, qp, kp, causal, window) ** 2)

    gf = jax.grad(loss_flash, argnums=(0, 1, 2))(q, k, v)
    gr = jax.grad(loss_ref, argnums=(0, 1, 2))(q, k, v)
    for a, b, name in zip(gf, gr, "qkv"):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=3e-4, atol=3e-4, err_msg=name)


def test_decode_like_one_query():
    q, k, v, qp, kp = make_inputs(sq=1, sk=96)
    got = flash_attention(q, k, v, qp, kp, True, None, 32)
    want = ref(q, k, v, qp, kp, True, None)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_uneven_last_window_fully_masked_chunk():
    """Sliding window: KV chunks entirely outside the window must contribute
    nothing (exp(-inf - lse) handling)."""
    q, k, v, qp, kp = make_inputs(sq=32, sk=128)
    got = flash_attention(q, k, v, qp, kp, True, 8, 32)
    want = ref(q, k, v, qp, kp, True, 8)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_bf16_inputs():
    q, k, v, qp, kp = make_inputs(dtype=jnp.bfloat16)
    got = flash_attention(q, k, v, qp, kp, True, None, 32)
    want = ref(q, k, v, qp, kp, True, None)
    np.testing.assert_allclose(np.asarray(got).astype(np.float32),
                               np.asarray(want).astype(np.float32),
                               rtol=3e-2, atol=3e-2)
