"""Device-count parity harness for the stream-axis mesh layer
(DESIGN.md §14).

The tentpole guarantee: sharding over a StreamMesh changes WHERE the
columns compute, never WHAT they are — every planned op must be
bit-exact across mesh sizes 1/2/4/8, for every backend, at stream
lengths both divisible and not divisible by the mesh, with zero
steady-state recompiles on the sharded plan path.

Multi-device cases run in SUBPROCESSES with
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (the main test
process keeps the host's single device, per DESIGN.md §8); the
construction/validation/fallback tests run in-process on one device.
"""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_subprocess(body: str, n_devices: int = 8) -> str:
    code = textwrap.dedent(f"""
        import os
        os.environ["XLA_FLAGS"] = \\
            "--xla_force_host_platform_device_count={n_devices}"
        import jax
        import numpy as np
        assert len(jax.devices()) == {n_devices}, jax.devices()
    """) + textwrap.dedent(body)
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    env.pop("XLA_FLAGS", None)
    env.pop("REPRO_GF_BACKEND", None)
    res = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=env, timeout=480)
    assert res.returncode == 0, f"stdout:\n{res.stdout}\nstderr:\n{res.stderr}"
    return res.stdout


def _spec_literal(k, p):
    """Search coefficients here (memoized) and inline the result so the
    subprocess skips the condition-(6) search it isn't testing."""
    from repro.core.circulant import CodeSpec
    spec = CodeSpec.make(k, p)
    return f"CodeSpec(k={spec.k}, p={spec.p}, c={spec.c!r})"


# ===================================================== mesh construction
class TestStreamMeshValidation:
    def test_bad_sizes_raise_typed(self):
        from repro.sharding.mesh import MeshConfigError, StreamMesh
        for bad in (0, -1, True, 2.5, "4"):
            with pytest.raises(MeshConfigError):
                StreamMesh(bad)

    def test_too_many_devices_names_the_fix(self):
        from repro.sharding.mesh import MeshConfigError, StreamMesh
        with pytest.raises(MeshConfigError) as ei:
            StreamMesh(999)
        msg = str(ei.value)
        assert "999" in msg and "xla_force_host_platform_device_count" in msg

    def test_mesh_config_error_is_value_error(self):
        from repro.sharding.mesh import MeshConfigError
        assert issubclass(MeshConfigError, ValueError)

    def test_default_uses_all_devices(self):
        import jax
        from repro.sharding.mesh import StreamMesh
        m = StreamMesh()
        assert m.size == len(jax.devices())

    def test_as_stream_mesh_coercion(self):
        from repro.sharding.mesh import (MeshConfigError, StreamMesh,
                                         as_stream_mesh)
        assert as_stream_mesh(None) is None
        m = StreamMesh(1)
        assert as_stream_mesh(m) is m
        assert isinstance(as_stream_mesh(1), StreamMesh)
        with pytest.raises(MeshConfigError):
            as_stream_mesh("stream")
        with pytest.raises(MeshConfigError):
            as_stream_mesh(True)

    def test_shard_extent(self):
        from repro.sharding.mesh import StreamMesh
        m = StreamMesh(1)
        assert m.shard_extent(7) == 7
        assert m.is_trivial


class TestLaunchMeshValidation:
    """Satellite: the launch/mesh.py scaffolding survives the refactor
    with typed construction errors."""

    def test_production_mesh_on_one_device_raises_typed(self):
        from repro.launch.mesh import make_production_mesh
        from repro.sharding.mesh import MeshConfigError
        with pytest.raises(MeshConfigError) as ei:
            make_production_mesh()
        assert "256" in str(ei.value)

    def test_storage_mesh_bad_sizes(self):
        from repro.launch.mesh import make_storage_mesh
        from repro.sharding.mesh import MeshConfigError
        for bad in (0, -3, True, 1.5):
            with pytest.raises(MeshConfigError):
                make_storage_mesh(bad)

    def test_checked_mesh_shape_name_mismatch(self):
        from repro.launch.mesh import checked_mesh
        from repro.sharding.mesh import MeshConfigError
        with pytest.raises(MeshConfigError):
            checked_mesh((1, 1), ("data",))
        with pytest.raises(MeshConfigError):
            checked_mesh((1, 1), ("data", "data"))

    def test_host_mesh_matches_device_count(self):
        import jax
        from repro.launch.mesh import make_host_mesh
        mesh = make_host_mesh()
        assert mesh.shape["data"] == len(jax.devices())


# ======================================================== rule registry
class TestRuleRegistry:
    def test_all_planned_ops_registered(self):
        from repro.sharding.mesh import known_rules
        assert set(known_rules()) >= {"matmul", "circulant_encode",
                                      "regenerate", "regenerate_batch"}

    def test_rule_arity_matches_op(self):
        from repro.sharding.mesh import get_rule
        for op, n_args in [("matmul", 2), ("circulant_encode", 1),
                           ("regenerate", 3), ("regenerate_batch", 3)]:
            assert len(get_rule(op).in_specs) == n_args, op

    def test_stream_axis_on_last_dim(self):
        from repro.sharding.mesh import STREAM_AXIS, get_rule, known_rules
        for op in known_rules():
            rule = get_rule(op)
            assert tuple(rule.out_specs)[-1] == STREAM_AXIS, op

    def test_unknown_op_lists_known(self):
        from repro.sharding.mesh import get_rule
        with pytest.raises(KeyError) as ei:
            get_rule("nope")
        assert "circulant_encode" in str(ei.value)

    def test_duplicate_registration_needs_override(self):
        from repro.sharding.mesh import ShardingRule, get_rule, register_rule
        from jax.sharding import PartitionSpec as P
        orig = get_rule("matmul")
        with pytest.raises(ValueError):
            register_rule(ShardingRule("matmul", (P(),), P()))
        register_rule(orig, override=True)        # idempotent restore
        assert get_rule("matmul") is orig


# ===================================== 1-device fallback (satellite fix)
class TestSingleDeviceFallback:
    """REPRO_GF_BACKEND x device-count interaction: a 1-device mesh must
    resolve to the SAME planner object as no mesh — identical results,
    zero spurious recompiles."""

    def test_trivial_mesh_normalizes_to_plain_planner(self):
        from repro.exec import plan
        from repro.kernels import dispatch
        from repro.sharding.mesh import StreamMesh
        be = dispatch.get("jnp-int32")
        assert plan.get_planner(be, 257) is plan.get_planner(be, 257, mesh=1)
        assert plan.get_planner(be, 257) is \
            plan.get_planner(be, 257, mesh=StreamMesh(1))

    @pytest.mark.parametrize("backend", ["jnp-int32", "jnp-f32"])
    def test_env_backend_with_trivial_mesh(self, backend, monkeypatch):
        from repro.core.circulant import CodeSpec
        from repro.core.msr import DoubleCirculantMSR
        from repro.sharding.mesh import use_mesh
        monkeypatch.setenv("REPRO_GF_BACKEND", backend)
        spec = CodeSpec.make(2, 257)
        plain = DoubleCirculantMSR(spec)
        with use_mesh(1):
            meshed = DoubleCirculantMSR(spec)
        assert plain.backend_name == meshed.backend_name == backend
        assert plain.planner is meshed.planner       # no second cache
        data = np.random.default_rng(0).integers(
            0, 257, size=(4, 5000)).astype(np.int32)
        ref = plain.encode_planned(data).host()
        meshed.planner.reset_stats()
        got = meshed.encode_planned(data).host()
        np.testing.assert_array_equal(ref, got)
        st = meshed.planner.plan_stats()
        assert st.compiles == 0 and st.misses == 0, st  # pure cache hit


class TestAmbientMesh:
    def test_use_mesh_scopes_and_none_override(self):
        from repro.sharding.mesh import StreamMesh, current_mesh, use_mesh
        assert current_mesh() is None
        m = StreamMesh(1)
        with use_mesh(m):
            assert current_mesh() is m
            with use_mesh(None):            # explicit disable
                assert current_mesh() is None
            assert current_mesh() is m
        assert current_mesh() is None

    def test_int_coercion_in_scope(self):
        from repro.sharding.mesh import current_mesh, use_mesh
        with use_mesh(1):
            assert current_mesh().size == 1


# ====================================== padding/sharding round trip (hyp)
@settings(max_examples=60, deadline=None)
@given(s=st.integers(min_value=1, max_value=5000),
       m=st.sampled_from([1, 2, 3, 4, 8]),
       bucket_min=st.sampled_from([4, 64, 4096]))
def test_pad_shard_roundtrip(s, m, bucket_min):
    """Per-shard bucketing invariants, pure host math: the padded global
    extent covers the true extent, splits evenly over the mesh, each
    shard is exactly the ladder bucket of ceil(s/m), and pad->split->
    concat->slice reproduces the input bit-exactly."""
    from repro.exec.plan import _pad_last, bucket_symbols
    shard = -(-s // m)
    b = bucket_symbols(shard, bucket_min=bucket_min)
    pad = b * m
    assert pad >= s and pad % m == 0
    assert b >= shard
    rng = np.random.default_rng(s * 31 + m)
    arr = rng.integers(0, 257, size=(3, s)).astype(np.int32)
    padded = _pad_last(arr, pad)
    shards = np.split(padded, m, axis=-1)
    assert all(sh.shape[-1] == b for sh in shards)
    back = np.concatenate(shards, axis=-1)[..., :s]
    np.testing.assert_array_equal(back, arr)
    # padding is zeros — the column-local ops' bit-exactness argument
    assert not padded[..., s:].any()


@settings(max_examples=30, deadline=None)
@given(s=st.integers(min_value=1, max_value=100_000),
       m=st.sampled_from([2, 4, 8]))
def test_shard_bucket_ladder_membership(s, m):
    """Per-shard buckets stay on the geometric ladder (executable count
    stays logarithmic even under sharding)."""
    from repro.exec.plan import BUCKET_MIN, BUCKET_RATIO, bucket_symbols
    b = bucket_symbols(-(-s // m))
    j = 0
    while BUCKET_MIN * BUCKET_RATIO ** j < b:
        j += 1
    assert int(BUCKET_MIN * BUCKET_RATIO ** j) == b


# ========================= policy scaffolding survival (satellite cover)
class FakeMesh:
    def __init__(self, shape):
        self.shape = shape


class TestPolicySpecFits:
    def test_shared_spec_fits(self):
        from jax.sharding import PartitionSpec as P
        from repro.sharding.policy import spec_fits
        mesh = FakeMesh({"data": 4, "model": 2})
        assert spec_fits(P(None, "model"), (3, 8), mesh)
        assert not spec_fits(P(None, "model"), (3, 7), mesh)
        assert spec_fits(P(("data", "model"),), (8,), mesh)
        assert not spec_fits(P(("data", "model"),), (12,), mesh)
        # unit axes pass by default, fail under require_multi
        unit = FakeMesh({"model": 1})
        assert spec_fits(P(None, "model"), (3, 7), unit)
        assert not spec_fits(P(None, "model"), (3, 8), unit,
                             require_multi=True)

    def test_ctx_constrain_noop_outside_rules(self):
        import jax.numpy as jnp
        from repro.sharding import ctx
        x = jnp.ones((4, 4))
        assert ctx.constrain(x, "residual") is x


# =============================================== multi-device parity (8)
def test_parity_across_mesh_sizes_all_ops():
    """THE parity matrix: every planned op x backend x odd/even stream
    length must be bit-exact across mesh sizes 1/2/4/8."""
    run_subprocess(f"""
        from repro.core.circulant import CodeSpec
        from repro.exec import plan
        from repro.kernels import dispatch
        spec = {_spec_literal(4, 257)}                    # n = 8 blocks
        c = tuple(int(x) for x in spec.c)
        rng = np.random.default_rng(1)
        for be_name in ("jnp-int32", "jnp-f32"):
            be = dispatch.get(be_name)
            ref = plan.get_planner(be, 257, bucket_min=64)
            for s in (513, 1024):                         # odd / even
                data = rng.integers(0, 257, size=(8, s)).astype(np.int32)
                mat = rng.integers(0, 257, size=(5, 8)).astype(np.int32)
                rmat = rng.integers(0, 257, size=(2, 5)).astype(np.int32)
                rp = rng.integers(0, 257, size=(s,)).astype(np.int32)
                nd = rng.integers(0, 257, size=(4, s)).astype(np.int32)
                rps = rng.integers(0, 257, size=(3, s)).astype(np.int32)
                nds = rng.integers(0, 257, size=(3, 4, s)).astype(np.int32)
                want = [ref.circulant_encode(data, c).host(),
                        ref.matmul(mat, data).host(),
                        ref.regenerate(rmat, rp, nd).host(),
                        ref.regenerate_batch(rmat, rps, nds).host()]
                for m in (1, 2, 4, 8):
                    pl = plan.get_planner(be, 257, bucket_min=64, mesh=m)
                    if m == 1:
                        assert pl is ref                  # fallback identity
                    got = [pl.circulant_encode(data, c).host(),
                           pl.matmul(mat, data).host(),
                           pl.regenerate(rmat, rp, nd).host(),
                           pl.regenerate_batch(rmat, rps, nds).host()]
                    for i, (w, g) in enumerate(zip(want, got)):
                        np.testing.assert_array_equal(
                            w, g, err_msg=f"{{be_name}} op{{i}} m={{m}} s={{s}}")
        print("parity matrix OK")
    """)


def test_sharded_plan_zero_steady_state_recompiles():
    """After warm-up, a mixed-size stream through a 4-device sharded
    planner performs ZERO new compiles — the §11 guarantee holds on the
    sharded path too."""
    run_subprocess(f"""
        from repro.core.circulant import CodeSpec
        from repro.exec import plan
        from repro.kernels import dispatch
        spec = {_spec_literal(4, 257)}
        c = tuple(int(x) for x in spec.c)
        rng = np.random.default_rng(2)
        pl = plan.get_planner(dispatch.get("jnp-int32"), 257,
                              bucket_min=64, mesh=4)
        assert pl.mesh is not None and pl.mesh.size == 4
        sizes = (100, 513, 777, 1024, 90, 1000)
        mat = rng.integers(0, 257, size=(8, 8)).astype(np.int32)
        rmat = rng.integers(0, 257, size=(2, 5)).astype(np.int32)
        def sweep():
            for s in sizes:
                d = rng.integers(0, 257, size=(8, s)).astype(np.int32)
                pl.circulant_encode(d, c).host()
                pl.matmul(mat, d).host()
                pl.regenerate_batch(
                    rmat,
                    rng.integers(0, 257, size=(2, s)).astype(np.int32),
                    rng.integers(0, 257, size=(2, 4, s)).astype(np.int32),
                ).host()
        sweep()                                  # warm-up compiles
        warm = pl.plan_stats().compiles
        assert warm > 0
        pl.reset_stats()
        for _ in range(3):
            sweep()
        st = pl.plan_stats()
        assert st.compiles == 0 and st.misses == 0, st
        assert st.hits == 3 * len(sizes) * 3
        print("steady-state compiles:", st.compiles, "warmup:", warm)
    """)


def test_store_parity_sharded_degraded_read_and_scrub():
    """Sharded put / get / degraded read / coalesced repair / scrub
    through the store: bit-exact vs the unsharded store, store-wide
    verify() green after a sharded repair drain."""
    run_subprocess(f"""
        from repro.core.circulant import CodeSpec
        from repro.sharding.mesh import use_mesh
        from repro.store import CodedObjectStore, RepairScheduler
        spec = {_spec_literal(2, 257)}
        rng = np.random.default_rng(3)
        payloads = {{f"obj{{i}}": rng.integers(0, 256, size=sz,
                    dtype=np.int64).astype(np.uint8).tobytes()
                    for i, sz in enumerate((100, 60_000, 200_001))}}
        with use_mesh(4):
            store = CodedObjectStore(spec, stripe_symbols=4096)
        assert store.code.mesh is not None and store.code.mesh.size == 4
        plain = CodedObjectStore(spec, stripe_symbols=4096)
        for key, data in payloads.items():
            store.put(key, data)
            plain.put(key, data)
            assert store.get(key) == plain.get(key) == data
        # degraded read: kill a node, both stores must still serve
        store.fail_node(1); plain.fail_node(1)
        for key, data in payloads.items():
            assert store.get(key) == data, key
            assert plain.get(key) == data, key
        # coalesced sharded repair drain, then integrity scrub
        store.replace_node(1)
        sched = RepairScheduler(store)
        sched.drain_all()
        assert store.verify()
        for node in range(1, store.n_nodes + 1):
            assert store.scrub_node(node) == []
        for key, data in payloads.items():
            assert store.get(key) == data, key
        print("store parity OK")
    """)


def test_checkpoint_restore_parity_sharded():
    """The checkpointer's stream-tile save/restore pipeline under a
    4-device mesh restores bit-exactly (and matches the unsharded
    checkpoint byte-for-byte on disk contents read back)."""
    run_subprocess(f"""
        import tempfile
        import jax.numpy as jnp
        from repro.checkpoint.msr_checkpoint import MSRCheckpointer
        from repro.core.circulant import CodeSpec
        spec = {_spec_literal(2, 257)}
        rng = np.random.default_rng(4)
        state = {{"w": rng.standard_normal((37, 113)).astype(np.float32),
                 "b": rng.standard_normal(41).astype(np.float32)}}
        outs = {{}}
        for label, mesh in (("plain", None), ("sharded", 4)):
            with tempfile.TemporaryDirectory() as d:
                ck = MSRCheckpointer(d, spec, mesh=mesh,
                                     save_tile_symbols=1 << 10)
                if mesh is not None:
                    assert ck.code.mesh is not None
                ck.save(0, state)
                outs[label], _rep = ck.restore(state, 0)
        for k in state:
            np.testing.assert_array_equal(
                np.asarray(outs["plain"][k]), np.asarray(state[k]), k)
            np.testing.assert_array_equal(
                np.asarray(outs["sharded"][k]), np.asarray(state[k]), k)
        print("checkpoint parity OK")
    """)
