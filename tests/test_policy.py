"""Sharding policy unit tests: spec selection, FSDP, layouts, divisibility."""
import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import SHAPES, get_config
from repro.models import Model
from repro.sharding import policy

# a light stand-in mesh: policy only reads mesh.shape
class FakeMesh:
    def __init__(self, **axes):
        self.shape = dict(axes)


MESH = FakeMesh(data=16, model=16)
POD = FakeMesh(pod=2, data=16, model=16)


def leaf(shape):
    return jax.ShapeDtypeStruct(tuple(shape), jax.numpy.float32)


def test_attention_head_sharding_when_divisible():
    # param_spec: TP rule only; param_specs adds FSDP on stack weights
    spec = policy.param_spec(["stack", "cycles", "attn", "wq"],
                             (36, 2560, 32, 128), MESH)
    assert spec == P(None, None, "model", None)
    ps = policy.param_specs(
        {"stack": {"cycles": ({"attn": {"wq": leaf((36, 2560, 32, 128))}},)}},
        MESH)
    assert ps["stack"]["cycles"][0]["attn"]["wq"] == P(None, "data", "model", None)


def test_attention_replicated_when_heads_dont_divide():
    spec = policy.param_spec(["stack", "cycles", "attn", "wq"],
                             (60, 7168, 56, 128), MESH)
    # heads 56 % 16 != 0 -> no model shard; FSDP puts data on the largest dim
    assert "model" not in str(spec)


def test_moe_expert_parallelism():
    spec = policy.param_spec(["stack", "cycles", "moe", "w_in"],
                             (35, 128, 7168, 4864), MESH)
    assert tuple(spec)[1] == "model"                # experts -> model


def test_embed_vocab_sharding_and_fallback():
    assert policy.param_spec(["embed"], (262144, 5376), MESH) == P("model", None)
    # 51865 doesn't divide 16 -> replicated
    assert policy.param_spec(["embed"], (51865, 1024), MESH) == P()


def test_norms_replicated():
    assert policy.param_spec(["stack", "cycles", "norm1", "scale"],
                             (36, 2560), MESH) == P(None)


def test_scan_resident_weights_never_fsdp():
    spec = policy.param_spec(["stack", "cycles", "slstm", "r_zifo"],
                             (6, 4, 4, 512, 512), MESH)
    ps = policy.param_specs(
        {"stack": {"cycles": ({"slstm": {"r_zifo": leaf((6, 4, 4, 512, 512))}},)}},
        MESH)
    got = ps["stack"]["cycles"][0]["slstm"]["r_zifo"]
    assert "data" not in str(got)


def test_choose_layout_per_arch():
    mesh = MESH
    train = SHAPES["train_4k"]
    dp = {a for a in ("qwen3-4b", "yi-34b", "starcoder2-7b", "xlstm-1.3b",
                      "recurrentgemma-2b", "granite-moe-1b-a400m",
                      "whisper-medium", "gemma3-27b")
          if policy.choose_layout(get_config(a), mesh, train) == "dp"}
    assert "qwen3-4b" in dp and "yi-34b" in dp
    assert policy.choose_layout(get_config("arctic-480b"), mesh, train) == "hybrid"
    assert policy.choose_layout(get_config("qwen2-vl-72b"), mesh, train) == "hybrid"
    # non-train shapes never use dp
    assert policy.choose_layout(get_config("qwen3-4b"), mesh,
                                SHAPES["decode_32k"]) == "hybrid"


def test_batch_spec_layouts():
    b = {"tokens": leaf((256, 4096))}
    hy = policy.batch_spec(b, MESH, global_batch=256)
    assert hy["tokens"] == P(("data",), None)
    dp = policy.batch_spec(b, MESH, global_batch=256, layout="dp")
    assert dp["tokens"] == P(("data", "model"), None)
    # batch=1 cannot shard
    one = policy.batch_spec({"tokens": leaf((1, 9))}, MESH, global_batch=1)
    assert one["tokens"] == P()


def test_cache_spec_kv_head_sharding():
    cache = {"k": leaf((128, 32768, 16, 128))}
    spec = policy.cache_spec(cache, MESH, batch=128)
    assert spec["k"] == P(("data",), None, "model", None)
    # kv heads not divisible -> head_dim
    cache = {"k": leaf((128, 32768, 8, 128))}
    spec = policy.cache_spec(cache, MESH, batch=128)
    assert spec["k"] == P(("data",), None, None, "model")
    # long-context: seq over data
    cache = {"k": leaf((1, 524288, 1, 256))}
    spec = policy.cache_spec(cache, MESH, batch=1, seq_shard=True)
    assert spec["k"] == P(None, "data", None, "model")


def test_activation_rules():
    cfg = get_config("yi-34b")
    r = policy.activation_rules(cfg, MESH, "train")
    assert "attn_q" in r and r["residual"] == P(("data",), None, None)
    r_dp = policy.activation_rules(cfg, MESH, "train", layout="dp")
    assert set(r_dp) == {"residual"}
    cfg2 = get_config("qwen3-4b")            # heads divide -> no attn hints
    assert set(policy.activation_rules(cfg2, MESH, "train")) == {"residual"}


def test_pod_axis_joins_batch():
    b = {"tokens": leaf((256, 4096))}
    spec = policy.batch_spec(b, POD, global_batch=256)
    assert spec["tokens"] == P(("pod", "data"), None)
