"""Double Circulant MSR code: the paper's guarantees as executable properties.

  * any-k data reconstruction (Prop. 2)          — exact file recovery
  * d = k+1 systematic regeneration (§III-C)     — bit-exact lost-node rebuild
  * MSR point: alpha = B/k, gamma = (k+1)B/(2k)  — eq. (7)
  * paper worked examples: [4,2] (Fig. 3) and [6,3] over F_5 (Fig. 4)
"""
import itertools

import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import gf
from repro.core.circulant import CodeSpec
from repro.core.msr import DoubleCirculantMSR, encode_file, reconstruct_file


def make_code(k, p=257, seed=0):
    return DoubleCirculantMSR(CodeSpec.make(k, p, seed=seed))


def random_blocks(n, s, p, seed=0):
    return jnp.asarray(np.random.default_rng(seed).integers(0, p, size=(n, s), dtype=np.int64), jnp.int32)


# ------------------------------------------------------------------ examples
def test_paper_example_42_figure3():
    """[4,2] with w = circ(0,0,1,1): r_1 = a1+a2, r_2 = a2+a3, r_3 = a3+a0... per Fig 3."""
    spec = CodeSpec.make(2, p=257, c=[1, 1])
    code = DoubleCirculantMSR(spec)
    a = jnp.arange(4, dtype=jnp.int32).reshape(4, 1) + 10  # a_i = 10+i, S=1
    r = np.asarray(code.encode(a))[:, 0]
    # r_i = c1 a_{(i-3) mod 4} + c2 a_{(i-4) mod 4} = a_{(i+1)%4} + a_{i%4... }
    want = [(10 + 1) + (10 + 2),   # r_1 = a1 + a2
            (10 + 2) + (10 + 3),   # r_2 = a2 + a3
            (10 + 3) + (10 + 0),   # r_3 = a3 + a0
            (10 + 0) + (10 + 1)]   # r_4 = a0 + a1
    assert r.tolist() == want


def test_paper_example_63_figure4():
    """[6,3] over F_5, w = circ(0,0,0,1,1,2).

    NOTE: the paper is internally inconsistent here.  The normative generator
    matrix A in §III-D has row a_0 = (1 0|0 0|0 0|0 c1|0 c2|0 c3), i.e. a_0
    contributes to r_4 with c1, r_5 with c2, r_6 with c3 — which our
    construction matches exactly (checked below).  Fig. 4's rendered node
    contents ("node 2: a2+a3+2a4") use the REVERSED coefficient order — an
    equivalent relabelled code (w reversed).  We follow matrix A and verify
    the Fig. 4 rendering under the reversed coefficients.
    """
    spec = CodeSpec.make(3, p=5, c=[1, 1, 2])
    code = DoubleCirculantMSR(spec)
    a = jnp.asarray(np.arange(6, dtype=np.int64).reshape(6, 1), jnp.int32)  # a_i = i
    r = np.asarray(code.encode(a))[:, 0]
    # closed form check against matrix-A semantics
    for i in range(1, 7):
        want = sum(spec.c[u - 1] * ((i - 3 - u) % 6) for u in range(1, 4)) % 5
        assert r[i - 1] == want
    # matrix-A row a_0: a_0 appears in r_4 (c1), r_5 (c2), r_6 (c3)
    m = spec.matrix_m()
    assert [int(x) for x in m[0]] == [0, 0, 0, 1, 1, 2]
    # Fig. 4's rendering corresponds to the reversed-coefficient twin code:
    spec_rev = CodeSpec.make(3, p=5, c=[2, 1, 1])
    r_rev = np.asarray(DoubleCirculantMSR(spec_rev).encode(a))[:, 0]
    assert r_rev[1] == (2 + 3 + 2 * 4) % 5   # node 2: a2 + a3 + 2 a4


@pytest.mark.parametrize("k,p,c", [(2, 257, [1, 1]), (3, 5, [1, 1, 2])])
def test_all_k_subsets_reconstruct_paper_codes(k, p, c):
    code = DoubleCirculantMSR(CodeSpec.make(k, p, c=c))
    n = 2 * k
    data = random_blocks(n, 7, p, seed=k)
    red = code.encode(data)
    for s in itertools.combinations(range(1, n + 1), k):
        got = code.reconstruct(list(s), data[jnp.asarray([i - 1 for i in s])], red[jnp.asarray([i - 1 for i in s])])
        np.testing.assert_array_equal(np.asarray(got), np.asarray(data), err_msg=str(s))


# ------------------------------------------------------------ reconstruction
@given(k=st.integers(2, 5), seed=st.integers(0, 20))
@settings(max_examples=20, deadline=None)
def test_any_k_reconstruction_random_subsets(k, seed):
    p = 257
    code = make_code(k, p)
    n = 2 * k
    rng = np.random.default_rng(seed)
    data = random_blocks(n, 16, p, seed)
    red = code.encode(data)
    s = sorted(rng.choice(n, size=k, replace=False) + 1)
    got = code.reconstruct([int(x) for x in s],
                           data[jnp.asarray([i - 1 for i in s])], red[jnp.asarray([i - 1 for i in s])])
    np.testing.assert_array_equal(np.asarray(got), np.asarray(data))


def test_reconstruct_rejects_duplicate_nodes():
    code = make_code(2)
    data = random_blocks(4, 4, 257)
    red = code.encode(data)
    with pytest.raises(ValueError):
        code.reconstruct([1, 1], data[:2], red[:2])


# -------------------------------------------------------------- regeneration
@given(k=st.integers(1, 6), node=st.integers(1, 12), seed=st.integers(0, 10))
@settings(max_examples=30, deadline=None)
def test_regeneration_bit_exact(k, node, seed):
    p = 257
    if node > 2 * k:
        node = (node - 1) % (2 * k) + 1
    code = make_code(k, p, seed=seed % 3)
    n = 2 * k
    data = random_blocks(n, 32, p, seed)
    red = code.encode(data)
    plan = code.repair_plan(node)
    assert plan.blocks_downloaded == k + 1 == code.spec.d
    r_prev = red[plan.prev_node - 1]
    next_data = data[jnp.asarray([j for j in plan.data_indices])]
    a_new, r_new = code.regenerate(node, r_prev, next_data)
    np.testing.assert_array_equal(np.asarray(a_new), np.asarray(data[node - 1]))
    np.testing.assert_array_equal(np.asarray(r_new), np.asarray(red[node - 1]))


def test_repair_plan_embedded_property():
    """Helpers are determined (embedded property): prev node + next k nodes."""
    code = make_code(3)
    plan = code.repair_plan(1)
    assert plan.prev_node == 6
    assert plan.next_nodes == (2, 3, 4)
    assert plan.data_indices == (1, 2, 3)
    plan = code.repair_plan(6)
    assert plan.prev_node == 5
    assert plan.next_nodes == (1, 2, 3)
    assert plan.data_indices == (0, 1, 2)


def test_regeneration_after_each_single_failure_all_nodes():
    k, p = 4, 257
    code = make_code(k, p)
    n = 2 * k
    data = random_blocks(n, 9, p, seed=3)
    red = code.encode(data)
    for node in range(1, n + 1):
        plan = code.repair_plan(node)
        a_new, r_new = code.regenerate(
            node, red[plan.prev_node - 1], data[jnp.asarray(plan.data_indices)])
        np.testing.assert_array_equal(np.asarray(a_new), np.asarray(data[node - 1]))
        np.testing.assert_array_equal(np.asarray(r_new), np.asarray(red[node - 1]))


# ------------------------------------------------------------------- metrics
def test_msr_point_accounting():
    """alpha = B/k and gamma = (k+1)B/(2k): eq. (1)/(7) at d = k+1."""
    for k in (2, 3, 8):
        code = make_code(k)
        s = 100                        # block symbols; B = n*s = 2k*s
        b = 2 * k * s
        assert code.alpha_symbols(s) == b // k
        assert code.gamma_regenerate_symbols(s) == (k + 1) * b // (2 * k)
        assert code.gamma_reconstruct_symbols(s) == b


def test_systematic_read_is_identity():
    code = make_code(2)
    data = random_blocks(4, 5, 257)
    np.testing.assert_array_equal(np.asarray(code.systematic_read(data)),
                                  np.asarray(data))


def test_verify_support():
    for k in (2, 3, 5):
        assert make_code(k).verify_support()


# ---------------------------------------------------------------- file level
@given(st.binary(min_size=1, max_size=2000), st.integers(2, 4), st.integers(0, 5))
@settings(max_examples=15, deadline=None)
def test_file_roundtrip_any_k(payload, k, seed):
    spec = CodeSpec.make(k, 257)
    enc = encode_file(payload, spec)
    rng = np.random.default_rng(seed)
    s = sorted(int(x) + 1 for x in rng.choice(2 * k, size=k, replace=False))
    assert reconstruct_file(enc, s) == payload
