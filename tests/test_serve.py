"""Robust serving front end (DESIGN.md §13): deadlines + hedged reads,
CRC quarantine + scrub re-admission, admission control with typed
shedding, and cross-request decode coalescing."""
import numpy as np
import pytest

from repro.core.circulant import CodeSpec
from repro.io import FaultInjector, fast_retry
from repro.io.retry import GiveUpError
from repro.serve import (FrontEndMetrics, NodeHealth, Overloaded,
                         ReadFrontEnd)
from repro.store import (CodedObjectStore, RepairScheduler,
                         UnknownKeyError)
from repro.train.fault_tolerance import HeartbeatMonitor

SPEC2 = CodeSpec.make(2, 257)


def make_store(n_nodes=6, stripe_symbols=64, **kw):
    return CodedObjectStore(SPEC2, n_nodes=n_nodes,
                            stripe_symbols=stripe_symbols, **kw)


def payload_bytes(size, seed=0):
    return np.random.default_rng(seed).integers(
        0, 256, size, dtype=np.uint8).tobytes()


class FakeClock:
    """Deterministic clock: advances a fixed step per call."""

    def __init__(self, step=0.0):
        self.t = 0.0
        self.step = step

    def __call__(self):
        self.t += self.step
        return self.t


# -------------------------------------------------------- ticket lifecycle
class TestTickets:
    def test_submit_pump_result(self):
        store = make_store()
        data = payload_bytes(300)
        store.put("a", data)
        with ReadFrontEnd(store) as fe:
            tk = fe.submit("a")
            with pytest.raises(RuntimeError, match="not.*served"):
                tk.result()
            fe.pump()
            assert tk.done and tk.result() == data
            r = tk.receipt
            assert r.key == "a" and r.deadline_met
            assert r.degraded_stripes == 0 and r.crc_rejected == 0
            assert fe.metrics.served == 1 and fe.metrics.failed == 0

    def test_read_convenience_and_coalescing_per_key(self):
        store = make_store()
        data = payload_bytes(500, seed=1)
        store.put("a", data)
        with ReadFrontEnd(store) as fe:
            t1, t2 = fe.submit("a"), fe.submit("a")
            fe.pump()
            assert t1.result() == data and t2.result() == data
            assert t1.receipt.coalesced == 2
            assert fe.metrics.coalesced_requests == 1
            assert fe.read("a") == data       # submit+pump+result in one

    def test_unknown_key_is_typed(self):
        store = make_store()
        with ReadFrontEnd(store) as fe:
            with pytest.raises(UnknownKeyError) as ei:
                fe.read("nope")
            assert ei.value.key == "nope"
            assert fe.metrics.failed == 1

    def test_deadline_miss_is_accounted(self):
        store = make_store()
        store.put("a", payload_bytes(128, seed=2))
        clock = FakeClock(step=0.05)          # every clock call costs 50ms
        with ReadFrontEnd(store, clock=clock) as fe:
            tk = fe.read_ext("a", deadline_s=0.01)
            assert tk.error is None           # late beats refused
            assert not tk.receipt.deadline_met
            assert fe.metrics.deadline_misses == 1

    def test_priority_order_within_pump(self):
        store = make_store()
        for key in ("lo", "hi"):
            store.put(key, payload_bytes(64, seed=3))
        with ReadFrontEnd(store) as fe:
            a = fe.submit("lo", priority=0)
            b = fe.submit("hi", priority=5)
            batch = fe.pump()
            assert [tk.key for tk in batch] == ["hi", "lo"]
            assert a.done and b.done


# -------------------------------------------------- deadline budget plumbing
class TestDeadlineBudget:
    def test_retry_budget_caps_wall_but_first_attempt_runs(self):
        calls = []

        def boom():
            calls.append(1)
            raise OSError("transient")

        policy = fast_retry(max_attempts=5)
        with pytest.raises(GiveUpError) as ei:
            policy.call(boom, op="x", budget_s=0.0)
        assert ei.value.attempts == 1 and len(calls) == 1

    def test_read_share_budget_zero_still_reads(self):
        store = make_store(faults=FaultInjector(seed=0), retry=fast_retry())
        store.put("a", payload_bytes(64, seed=4))
        pl = store.placement_of("a", 0)
        share = store.read_share(pl[0], "a", 0, budget_s=0.0)
        assert share[0] == 1                  # code node 1's share


# ------------------------------------------------------------ CRC integrity
class TestIntegrity:
    def test_storage_rot_decoded_around_dropped_and_enqueued(self):
        store = make_store()
        sched = RepairScheduler(store)
        store.subscribe(sched.on_event)
        data = payload_bytes(64, seed=5)
        store.put("obj", data)
        pl = store.placement_of("obj", 0)
        phys = pl[0]
        store._shares[phys - 1][("obj", 0)][1][0] ^= 0x55
        assert store.share_intact(phys, "obj", 0) is False
        with ReadFrontEnd(store, scheduler=sched) as fe:
            assert fe.read("obj") == data
            assert fe.metrics.crc_rejected == 1
            assert [e["what"] for e in fe.events] == ["crc_drop"]
            assert store.share_intact(phys, "obj", 0) is None   # dropped
            assert sched.pending() == 1
        sched.drain_all()
        assert store.share_intact(phys, "obj", 0) is True       # rebuilt

    def test_transient_read_flip_rereads_without_dropping(self):
        faults = FaultInjector(seed=0)
        faults.add(op="read", kind="corrupt", times=1)
        store = make_store(faults=faults, retry=fast_retry())
        data = payload_bytes(64, seed=6)
        store.put("obj", data)
        with ReadFrontEnd(store, hedge_after_s=None) as fe:
            assert fe.read("obj") == data
            assert fe.metrics.crc_rejected == 1
            assert [e["what"] for e in fe.events] == ["crc_transient"]
        # the stored copy was never touched: nothing dropped anywhere
        assert all(store.share_intact(p, "obj", 0) for p in
                   store.placement_of("obj", 0))

    def test_suspicion_weights_rank_crc_over_hedge(self):
        h = NodeHealth()
        fe = ReadFrontEnd(make_store())
        assert fe.crc_weight > fe.giveup_weight > fe.hedge_weight
        h.observe(0.010)
        h.observe(0.020)
        assert h.ewma_read_s == pytest.approx(0.013)
        fe.close()


# ------------------------------------------------- quarantine state machine
class TestQuarantine:
    def test_quarantine_dirty_scrub_then_clean_readmit(self):
        store = make_store()
        sched = RepairScheduler(store)
        store.subscribe(sched.on_event)
        k1, k2 = payload_bytes(64, seed=7), payload_bytes(64, seed=8)
        store.put("k1", k1)
        store.put("k2", k2)
        # two rotten shares on ONE node, but only k1 is read: the first
        # scrub must come back dirty (it finds k2's rot) and keep the
        # node out; only the second, clean scrub re-admits
        common = sorted(set(store.placement_of("k1", 0))
                        & set(store.placement_of("k2", 0)))
        assert common, "test setup: keys must share a node"
        phys = common[0]
        store._shares[phys - 1][("k1", 0)][1][0] ^= 0x55
        store._shares[phys - 1][("k2", 0)][1][1] ^= 0x55
        with ReadFrontEnd(store, scheduler=sched,
                          quarantine_threshold=2.0) as fe:
            assert fe.read("k1") == k1
            assert fe.quarantined_nodes() == [phys]
            out1 = fe.scrub_quarantined()
            assert out1 == [{"node": phys, "bad_shares": 1,
                             "readmitted": False}]
            sched.drain_all()
            out2 = fe.scrub_quarantined()
            assert out2 == [{"node": phys, "bad_shares": 0,
                             "readmitted": True}]
            assert fe.quarantined_nodes() == []
            kinds = [e["what"] for e in fe.events]
            assert kinds.index("quarantine") < kinds.index("scrub_dirty") \
                < kinds.index("readmit")
            assert fe.read("k2") == k2
            assert fe.metrics.quarantines == 1
            assert fe.metrics.readmissions == 1

    def test_quarantined_node_still_last_resort(self):
        # with every other helper dead, a quarantined node IS used —
        # graceful degradation beats refusal
        store = make_store()
        data = payload_bytes(64, seed=9)
        store.put("obj", data)
        pl = store.placement_of("obj", 0)
        with ReadFrontEnd(store) as fe:
            fe.health(pl[0]).quarantined = True
            fe.health(pl[1]).quarantined = True
            tk = fe.read_ext("obj")
            assert tk.result() == data
            assert set(tk.receipt.avoided_nodes) == {pl[0], pl[1]}


# ----------------------------------------------------- heartbeat avoidance
class TestHeartbeatAvoidance:
    def test_straggler_and_dead_demoted_before_hedge(self):
        store = make_store()
        data = payload_bytes(64, seed=10)
        store.put("obj", data)
        pl = store.placement_of("obj", 0)
        hb = HeartbeatMonitor(store.n_nodes, timeout_s=60.0,
                              straggler_s=5.0)
        now = 100.0
        for node in range(1, store.n_nodes + 1):
            hb.beat(node, step=10, now=now - 1.0)
        hb.beat(pl[0], step=10, now=now - 10.0)   # wall-clock straggler
        hb.declare_dead(pl[1])                    # control-plane dead
        with ReadFrontEnd(store, heartbeat=hb,
                          heartbeat_clock=lambda: now) as fe:
            reasons = fe._avoid_reasons()
            assert reasons[pl[0]] == "straggler"
            assert reasons[pl[1]] == "dead-heartbeat"
            tk = fe.read_ext("obj")
            assert tk.result() == data
            assert pl[0] in tk.receipt.avoided_nodes
            assert pl[1] in tk.receipt.avoided_nodes


# ------------------------------------------------------------------ hedging
class TestHedging:
    def test_hedged_read_abandons_straggler_and_learns(self):
        faults = FaultInjector(seed=0)
        store = make_store(faults=faults, retry=fast_retry())
        data = payload_bytes(64, seed=11)
        store.put("obj", data)
        phys = store.placement_of("obj", 0)[0]
        faults.add(op="read", kind="latency", match=f"node:{phys:02d}",
                   latency_s=0.2)
        with ReadFrontEnd(store, hedge_after_s=0.005) as fe:
            assert fe.read("obj") == data     # decoded around the laggard
            assert fe.metrics.hedged_fetches >= 1
            assert fe.health(phys).timeouts >= 1
            assert fe.metrics.degraded_stripes == 1

    def test_unhedged_baseline_waits_and_serves(self):
        faults = FaultInjector(seed=0)
        store = make_store(faults=faults, retry=fast_retry())
        data = payload_bytes(64, seed=12)
        store.put("obj", data)
        phys = store.placement_of("obj", 0)[0]
        faults.add(op="read", kind="latency", match=f"node:{phys:02d}",
                   latency_s=0.02)
        with ReadFrontEnd(store, hedge_after_s=None) as fe:
            assert fe.read("obj") == data
            assert fe.metrics.hedged_fetches == 0
            assert fe.metrics.degraded_stripes == 0


# --------------------------------------------------------- admission control
class TestOverload:
    def test_shed_is_typed_low_priority_first(self):
        store = make_store()
        for i in range(2):
            store.put(f"k{i}", payload_bytes(64, seed=13 + i))
        with ReadFrontEnd(store, max_queue=3) as fe:
            low = [fe.submit("k0", priority=0) for _ in range(3)]
            hi = fe.submit("k1", priority=2)      # bumps a queued low
            extra = fe.submit("k0", priority=0)   # loses to everything
            shed = [tk for tk in low + [hi, extra]
                    if isinstance(tk.error, Overloaded)]
            assert len(shed) == 2 and all(tk.priority == 0 for tk in shed)
            assert extra in shed and hi not in shed
            err = shed[0].error
            assert err.key == "k0" and err.priority == 0
            assert err.queue_depth == 3
            fe.pump()
            resolved = [tk for tk in low + [hi, extra] if tk.done]
            assert len(resolved) == 5             # nothing hangs
            assert fe.metrics.shed == 2
            assert fe.metrics.served + fe.metrics.shed == 5

    def test_equal_priority_newest_loses(self):
        store = make_store()
        store.put("k", payload_bytes(64, seed=15))
        with ReadFrontEnd(store, max_queue=1) as fe:
            first = fe.submit("k", priority=1)
            second = fe.submit("k", priority=1)
            assert isinstance(second.error, Overloaded)
            assert first.error is None and not first.done


# ----------------------------------------------- cross-request coalescing
class TestCoalescing:
    def test_one_decode_dispatch_per_pattern_across_keys(self):
        store = make_store()
        a, b = payload_bytes(64, seed=16), payload_bytes(64, seed=17)
        store.put("a", a)
        # same base stripe phase for both keys -> same placement ->
        # a shared failure pattern
        store._next_stripe = store.stat("a").meta["_base_stripe"]
        store.put("b", b)
        assert store.placement_of("a", 0) == store.placement_of("b", 0)
        store.fail_node(store.placement_of("a", 0)[0])
        with ReadFrontEnd(store) as fe:
            t1, t2 = fe.submit("a"), fe.submit("b")
            fe.pump()
            assert t1.result() == a and t2.result() == b
            assert fe.metrics.degraded_stripes == 2
            assert fe.metrics.decode_dispatches == 1   # pattern shared
            assert t1.receipt.decode_dispatches == 1

    def test_tick_interleaves_serving_scrub_and_repair(self):
        store = make_store()
        sched = RepairScheduler(store)
        store.subscribe(sched.on_event)
        data = payload_bytes(400, seed=18)
        store.put("obj", data)
        store.fail_node(1)
        assert sched.pending() > 0
        with ReadFrontEnd(store, scheduler=sched) as fe:
            fe.submit("obj")
            out = fe.tick(repair_budget_symbols=10_000_000)
            assert out["served"] == 1
            assert out["repair_remaining"] == 0
        assert store.get("obj") == data


# ------------------------------------------------------------------ metrics
class TestMetrics:
    def test_percentiles_and_summary_shape(self):
        m = FrontEndMetrics()
        assert m.latency_percentiles() == {"p50_s": 0.0, "p99_s": 0.0,
                                           "p999_s": 0.0, "max_s": 0.0}
        m.wall_latencies = [float(i) for i in range(1, 101)]
        lat = m.latency_percentiles()
        assert lat["p50_s"] == 50.0 and lat["p99_s"] == 99.0
        assert lat["p999_s"] == 100.0 and lat["max_s"] == 100.0
        s = m.summary()
        assert {"requests", "served", "failed", "shed",
                "latency"} <= set(s)
