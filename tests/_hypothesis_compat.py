"""Hypothesis shim: use the real library when installed, else a minimal
deterministic fallback so the suite collects and passes without it.

The fallback implements only what this suite uses — ``given``, ``settings``
and the ``integers`` / ``sampled_from`` / ``binary`` strategies — and runs
each property test on a fixed, seeded pseudo-random example set (seeded by
the test's qualified name, so failures reproduce exactly).
"""
from __future__ import annotations

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False
    import functools
    import inspect
    import random

    class _Strategy:
        def __init__(self, draw):
            self._draw = draw

        def example(self, rng: random.Random):
            return self._draw(rng)

    class _Strategies:
        @staticmethod
        def integers(min_value: int, max_value: int) -> _Strategy:
            return _Strategy(lambda rng: rng.randint(min_value, max_value))

        @staticmethod
        def sampled_from(elements) -> _Strategy:
            elements = list(elements)
            return _Strategy(lambda rng: elements[rng.randrange(len(elements))])

        @staticmethod
        def binary(min_size: int = 0, max_size: int = 64) -> _Strategy:
            def draw(rng):
                size = rng.randint(min_size, max_size)
                return bytes(rng.randrange(256) for _ in range(size))
            return _Strategy(draw)

    st = _Strategies()

    class settings:  # noqa: N801 — mirrors hypothesis.settings
        def __init__(self, max_examples: int = 20, deadline=None, **_ignored):
            self.max_examples = max_examples

        def __call__(self, fn):
            fn._max_examples = self.max_examples
            return fn

    def given(*arg_strategies, **kw_strategies):
        def decorate(fn):
            sig = inspect.signature(fn)
            params = list(sig.parameters.values())
            # strategy-provided params must not look like pytest fixtures:
            # positional strategies fill the LAST len(arg_strategies) slots
            # not named by keyword strategies (matching hypothesis, which
            # right-aligns positional strategies against the signature)
            kw_names = set(kw_strategies)
            free = [q.name for q in params if q.name not in kw_names]
            pos_names = free[len(free) - len(arg_strategies):]
            fixture_params = [q for q in params
                              if q.name not in kw_names
                              and q.name not in pos_names]

            @functools.wraps(fn)
            def wrapper(*args, **kwargs):
                n = getattr(wrapper, "_max_examples",
                            getattr(fn, "_max_examples", 20))
                rng = random.Random(f"{fn.__module__}.{fn.__qualname__}")
                for _ in range(n):
                    drawn = dict(zip(pos_names,
                                     (s.example(rng) for s in arg_strategies)))
                    drawn.update((k, s.example(rng))
                                 for k, s in kw_strategies.items())
                    fn(*args, **kwargs, **drawn)
            wrapper.__signature__ = sig.replace(parameters=fixture_params)
            return wrapper
        return decorate


__all__ = ["given", "settings", "st", "HAVE_HYPOTHESIS"]
