"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps, exact equality.

(assert_allclose with rtol=0 == exact integer match; GF arithmetic is exact.)
"""
import numpy as np
import jax.numpy as jnp
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import gf
from repro.core.circulant import CodeSpec
from repro.core.msr import DoubleCirculantMSR
from repro.kernels import ops
from repro.kernels.gf_matmul import _fold_depth


def rand(shape, p, seed):
    return np.random.default_rng(seed).integers(0, p, size=shape, dtype=np.int64).astype(np.int32)


# --------------------------------------------------------------- gf_matmul
@pytest.mark.parametrize("p", [5, 257])
@pytest.mark.parametrize("m,k,s", [
    (4, 4, 128), (8, 8, 512), (6, 6, 1000),       # unaligned stream
    (16, 16, 4096), (3, 300, 640),                # k > fold depth
    (1, 7, 130), (128, 128, 256),
])
def test_gf_matmul_matches_oracle(p, m, k, s):
    a = rand((m, k), p, seed=m * k + s)
    b = rand((k, s), p, seed=m + k + s)
    got = np.asarray(ops.gf_matmul(a, b, p))
    want = np.asarray(ops.gf_matmul_ref(jnp.asarray(a), jnp.asarray(b), p))
    np.testing.assert_allclose(got, want, rtol=0, atol=0)
    # and against int64 ground truth
    np.testing.assert_array_equal(got, (a.astype(np.int64) @ b.astype(np.int64)) % p)


@pytest.mark.parametrize("dtype", [np.int32, np.int64, np.uint8, np.int16])
def test_gf_matmul_input_dtypes(dtype):
    p = 257
    a = rand((4, 8), p, 0).astype(dtype)
    b = rand((8, 256), p, 1).astype(dtype)
    got = np.asarray(ops.gf_matmul(a, b, p))
    np.testing.assert_array_equal(got, (a.astype(np.int64) @ b.astype(np.int64)) % p)


def test_gf_matmul_worst_case_magnitudes():
    """All-(p-1) entries across a fold boundary must stay exact."""
    p = 257
    for k in (127, 128, 129, 255, 256, 300):
        a = np.full((2, k), p - 1, np.int32)
        b = np.full((k, 384), p - 1, np.int32)
        got = np.asarray(ops.gf_matmul(a, b, p))
        want = (a.astype(np.int64) @ b.astype(np.int64)) % p
        np.testing.assert_array_equal(got, want, err_msg=f"k={k}")


def test_fold_depth_envelope():
    assert _fold_depth(257) * 256 * 256 < 2**24
    assert _fold_depth(2) == 128
    with pytest.raises(ValueError):   # (p-1)^2 > 2^24-1: fp32 can't be exact
        _fold_depth(4099)


@given(st.integers(1, 64), st.integers(1, 200), st.integers(0, 100))
@settings(max_examples=20, deadline=None)
def test_gf_matmul_property(m, k, seed):
    p = 257
    a = rand((m, k), p, seed)
    b = rand((k, 320), p, seed + 1)
    got = np.asarray(ops.gf_matmul(a, b, p))
    np.testing.assert_array_equal(got, (a.astype(np.int64) @ b.astype(np.int64)) % p)


# --------------------------------------------------------- circulant_encode
@pytest.mark.parametrize("p", [5, 257])
@pytest.mark.parametrize("k,s", [(1, 128), (2, 512), (3, 1000), (8, 4096), (16, 384), (64, 256)])
def test_circulant_encode_matches_oracle(p, k, s):
    rng = np.random.default_rng(k + s)
    c = tuple(int(x) for x in rng.integers(1, p, size=k))
    data = rand((2 * k, s), p, seed=k * s)
    got = np.asarray(ops.circulant_encode(data, c, p))
    want = np.asarray(ops.circulant_encode_ref(jnp.asarray(data), c, p))
    np.testing.assert_allclose(got, want, rtol=0, atol=0)


def test_circulant_encode_matches_dense_matmul_encode():
    """Kernel (structure-exploiting) == dense M^T matmul == core encode."""
    for k, p in [(2, 257), (3, 5), (5, 257)]:
        spec = CodeSpec.make(k, p)
        code = DoubleCirculantMSR(spec)
        data = rand((2 * k, 700), p, seed=k)
        dense = np.asarray(code.encode(jnp.asarray(data)))
        kern = np.asarray(ops.circulant_encode(data, spec.c, p))
        np.testing.assert_array_equal(kern, dense)


def test_circulant_encode_rejects_zero_coefficients():
    with pytest.raises(ValueError):
        ops.circulant_encode(np.zeros((4, 128), np.int32), (1, 0), 257)


def test_circulant_encode_worst_case_fold():
    p = 257
    k = 130  # forces a fold inside the kernel accumulation
    c = tuple([p - 1] * k)
    data = np.full((2 * k, 256), p - 1, np.int32)
    got = np.asarray(ops.circulant_encode(data, c, p))
    want = np.asarray(ops.circulant_encode_ref(jnp.asarray(data), c, p))
    np.testing.assert_array_equal(got, want)


# ------------------------------------------------------ end-to-end via code
def test_msr_with_kernel_backend_roundtrip():
    """Full encode->regenerate->reconstruct using the Pallas backend."""
    spec = CodeSpec.make(4, 257)
    code = DoubleCirculantMSR(spec, matmul=ops.msr_matmul_backend(257))
    data = jnp.asarray(rand((8, 640), 257, seed=9))
    red = code.encode(data)
    # regenerate node 3
    plan = code.repair_plan(3)
    a_new, r_new = code.regenerate(3, red[plan.prev_node - 1],
                                   data[jnp.asarray(plan.data_indices)])
    np.testing.assert_array_equal(np.asarray(a_new), np.asarray(data[2]))
    np.testing.assert_array_equal(np.asarray(r_new), np.asarray(red[2]))
    # reconstruct from nodes {2,4,6,8}
    s = [2, 4, 6, 8]
    idx = jnp.asarray([i - 1 for i in s])
    got = code.reconstruct(s, data[idx], red[idx])
    np.testing.assert_array_equal(np.asarray(got), np.asarray(data))
