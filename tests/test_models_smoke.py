"""Per-architecture smoke tests on REDUCED configs (assignment requirement):
one forward/train step on CPU asserting output shapes + finite values, plus
gradient flow and prefill->decode consistency for every block family.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models import Model
from repro.models.frontend import mrope_positions, synth_embeddings

SEQ = 32
BATCH = 2


def make_batch(cfg, key, seq=SEQ, batch=BATCH, labels=True):
    ks = jax.random.split(key, 3)
    out = {}
    if cfg.embeds_as_input and not cfg.is_encoder_decoder:
        out["inputs_embeds"] = synth_embeddings(ks[0], (batch, seq, cfg.d_model))
    else:
        out["tokens"] = jax.random.randint(ks[0], (batch, seq), 0, cfg.vocab_size)
    if cfg.is_encoder_decoder:
        out["enc_embeds"] = synth_embeddings(ks[1], (batch, cfg.encoder_seq, cfg.d_model))
    if cfg.mrope_sections:
        out["positions"] = jnp.asarray(
            mrope_positions(batch, seq, image_tokens=8, grid_hw=(2, 4)))
    if labels:
        out["labels"] = jax.random.randint(ks[2], (batch, seq), 0, cfg.vocab_size)
    return out


def smoke_config(arch):
    """Reduced config with the layer pattern deduplicated to one layer per
    block TYPE (>= 2 layers so inter-layer plumbing is still exercised).
    XLA compile time scales with layer count, and smoke coverage only needs
    each block family once — this cuts e.g. xlstm from 16 to 2 layers."""
    base = get_config(arch)
    pat = tuple(dict.fromkeys(base.layer_pattern))
    return base.reduced(layer_pattern=pat, n_layers=max(2, len(pat)))


@pytest.fixture(scope="module")
def arch_setup():
    cache = {}

    def get(arch):
        if arch not in cache:
            cfg = smoke_config(arch)
            model = Model(cfg)
            params = model.init(jax.random.PRNGKey(0))
            cache[arch] = (cfg, model, params)
        return cache[arch]

    return get


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_and_loss(arch, arch_setup):
    cfg, model, params = arch_setup(arch)
    batch = make_batch(cfg, jax.random.PRNGKey(1))
    loss, metrics = jax.jit(model.loss)(params, batch)
    assert np.isfinite(float(loss)), arch
    assert float(loss) > 0
    h, _, aux = model.forward(params, batch, "train", remat=False)
    assert h.shape == (BATCH, SEQ, cfg.d_model)
    assert bool(jnp.isfinite(h.astype(jnp.float32)).all())
    if cfg.n_experts:
        assert float(metrics["aux"]) > 0  # router aux loss is live


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_grad_step_finite(arch, arch_setup):
    cfg, model, params = arch_setup(arch)
    batch = make_batch(cfg, jax.random.PRNGKey(2))
    grads = jax.jit(jax.grad(lambda p: model.loss(p, batch)[0]))(params)
    flat = jax.tree_util.tree_leaves(grads)
    assert all(bool(jnp.isfinite(g).all()) for g in flat), arch
    # at least the embedding (or input-proj) grads must be nonzero
    total = sum(float(jnp.abs(g).sum()) for g in flat)
    assert total > 0, arch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_prefill_decode_shapes(arch, arch_setup):
    cfg, model, params = arch_setup(arch)
    batch = make_batch(cfg, jax.random.PRNGKey(3), labels=False)
    max_len = SEQ + 8
    logits, cache = jax.jit(
        lambda p, b: model.prefill(p, b, max_len=max_len, q_chunk=16))(params, batch)
    assert logits.shape == (BATCH, 1, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all()), arch
    tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
    logits2, cache = jax.jit(
        lambda p, c, t, pos: model.decode_step(p, c, t, pos, max_len=max_len))(
        params, cache, tok, jnp.asarray(SEQ, jnp.int32))
    assert logits2.shape == (BATCH, 1, cfg.vocab_size)
    assert bool(jnp.isfinite(logits2).all()), arch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_prefill_decode_matches_full_forward(arch, arch_setup):
    """Teacher-forced consistency: decode_step(t_s at pos s) logits must match
    a fresh full forward over s+1 tokens at the last position."""
    cfg, model, params = arch_setup(arch)
    if cfg.n_experts:
        # Capacity dropping is chunk-context dependent (the s+1-token forward
        # chunks dispatch differently than the s-token prefill), so exact
        # cache consistency is only defined DROPLESS.  (At cf=1.25 the
        # reduced top-2-of-4 config drops ~half the tokens; verified exact at
        # cf=1e9.)  Production capacity semantics are covered by the aux-loss
        # and moe unit tests.
        cfg = dataclasses.replace(cfg, capacity_factor=1e9)
        model = Model(cfg)
    key = jax.random.PRNGKey(4)
    full = make_batch(cfg, key, seq=SEQ + 1, labels=False)
    max_len = SEQ + 1
    if "tokens" in full:
        prefix = {k: (v[..., :SEQ] if v.ndim == 2 else
                      (v[..., :SEQ] if k == "positions" else v))
                  for k, v in full.items()}
        if "positions" in full:
            prefix["positions"] = full["positions"][..., :SEQ]
        _, cache = model.prefill(params, prefix, max_len=max_len, q_chunk=16)
        tok = full["tokens"][:, SEQ:SEQ + 1]
        dec_logits, _ = model.decode_step(params, cache, tok,
                                          jnp.asarray(SEQ, jnp.int32),
                                          max_len=max_len)
    else:
        # embeds-input arch (VL frontend): decode_step takes token ids, so
        # feed the final-position EMBEDDING through forward() in decode mode
        # — same cache/mask path, same teacher-forced consistency claim.
        prefix = {"inputs_embeds": full["inputs_embeds"][:, :SEQ],
                  "positions": full["positions"][..., :SEQ]}
        _, cache = model.prefill(params, prefix, max_len=max_len, q_chunk=16)
        step = {"inputs_embeds": full["inputs_embeds"][:, SEQ:SEQ + 1],
                "positions": full["positions"][..., SEQ:SEQ + 1]}
        h1, _, _ = model.forward(params, step, "decode", cache,
                                 pos=jnp.asarray(SEQ, jnp.int32),
                                 max_len=max_len, remat=False)
        dec_logits = (h1 @ model.head(params).astype(h1.dtype)
                      ).astype(jnp.float32)
    h, _, _ = model.forward(params, full, "train", remat=False)
    head = model.head(params).astype(h.dtype)
    ref_logits = (h[:, -1:] @ head).astype(jnp.float32)
    dec, ref = np.asarray(dec_logits), np.asarray(ref_logits)
    # bf16 compute + different accumulation orders (chunkwise vs recurrent)
    # leave sub-1% of elements outside a tight tolerance; structural bugs
    # would disagree everywhere and flip the argmax.
    np.testing.assert_array_equal(dec.argmax(-1), ref.argmax(-1), err_msg=arch)
    close = np.isclose(dec, ref, rtol=0.15, atol=0.15)
    assert close.mean() > 0.98, (arch, float(close.mean()))
    assert np.abs(dec - ref).max() < 1.0, arch


def test_window_attention_masks_past():
    """A local-attn layer must not attend beyond its window: with ONE layer,
    perturbing a token > window positions in the past must not change the
    current position's output.  (With stacked layers the receptive field
    legitimately grows by window-1 per layer.)"""
    cfg = get_config("gemma3-27b").reduced(
        n_layers=1, layer_pattern=("la",), window_size=8)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    tok = jax.random.randint(jax.random.PRNGKey(1), (1, SEQ), 0, cfg.vocab_size)
    tok2 = tok.at[0, 2].set((tok[0, 2] + 7) % cfg.vocab_size)  # outside window of last pos
    out1, _, _ = model.forward(params, {"tokens": tok}, "train", remat=False)
    out2, _, _ = model.forward(params, {"tokens": tok2}, "train", remat=False)
    np.testing.assert_allclose(np.asarray(out1[:, -1]).astype(np.float32),
                               np.asarray(out2[:, -1]).astype(np.float32),
                               rtol=1e-5, atol=1e-5)
    assert not np.allclose(np.asarray(out1[:, 3]).astype(np.float32),
                           np.asarray(out2[:, 3]).astype(np.float32))


def test_causality():
    """Perturbing a future token must not change past logits (every family)."""
    for arch in ("qwen3-4b", "recurrentgemma-2b", "xlstm-1.3b", "granite-moe-1b-a400m"):
        cfg = smoke_config(arch)
        model = Model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        tok = jax.random.randint(jax.random.PRNGKey(1), (1, SEQ), 0, cfg.vocab_size)
        tok2 = tok.at[0, SEQ - 1].set((tok[0, SEQ - 1] + 3) % cfg.vocab_size)
        o1, _, _ = model.forward(params, {"tokens": tok}, "train", remat=False)
        o2, _, _ = model.forward(params, {"tokens": tok2}, "train", remat=False)
        np.testing.assert_allclose(
            np.asarray(o1[:, : SEQ - 1]).astype(np.float32),
            np.asarray(o2[:, : SEQ - 1]).astype(np.float32),
            rtol=1e-4, atol=1e-4, err_msg=arch)


def test_long_500k_eligibility_flags():
    eligible = {a for a in ARCH_IDS if get_config(a).is_subquadratic()}
    assert eligible == {"recurrentgemma-2b", "gemma3-27b", "xlstm-1.3b"}
