"""The fault-injection drill harness (DESIGN.md §12.5): every scripted
failure timeline must pass — bit-exact resume, bounded loss, zero
orphans."""
import pytest

from repro.cluster.drills import DRILLS, run_drills


@pytest.mark.parametrize("name", sorted(DRILLS))
def test_drill_passes(tmp_path, name):
    (res,) = run_drills(tmp_path, names=[name])
    assert res.passed, f"{name}: {res.detail}"
    assert res.bit_exact
    assert res.orphans == 0


def test_data_loss_bounded_by_cadence():
    results = {r.name: r for r in
               run_drills(names=["crash_mid_save", "kill_rack_write_behind"])}
    # crash_mid_save: 12 steps, cadence 5, the step-10 save dies -> the
    # crash costs exactly the steps past generation 5, never more
    assert results["crash_mid_save"].resumed_from == 5
    assert results["crash_mid_save"].data_loss_steps == 7
    assert results["kill_rack_write_behind"].resumed_from == 4


def test_unknown_drill_rejected(tmp_path):
    with pytest.raises(KeyError):
        run_drills(tmp_path, names=["meteor_strike"])


def test_deterministic_across_runs(tmp_path):
    a = run_drills(tmp_path / "a", names=["transient_fault_storm"], seed=3)
    b = run_drills(tmp_path / "b", names=["transient_fault_storm"], seed=3)
    assert a[0].passed and b[0].passed
