"""Online code conversion (DESIGN.md §15.3): round-trip equality in both
directions, systematic share reuse, degraded sources, atomicity under
injected crashes mid-convert, and the scheduler's convert queue.
"""
import numpy as np
import pytest

from repro.codes import CodeClass, FAMILY_PRODUCT_MATRIX
from repro.core.circulant import CodeSpec
from repro.io import FaultInjector, GiveUpError, fast_retry
from repro.store import CodedObjectStore, RepairScheduler

PM = CodeClass(FAMILY_PRODUCT_MATRIX, n=6, k=3, d=4)
PM_SMALL = CodeClass(FAMILY_PRODUCT_MATRIX, n=5, k=2, d=3)


def make_store(**kw):
    kw.setdefault("n_nodes", 8)
    kw.setdefault("stripe_symbols", 32)
    return CodedObjectStore(CodeSpec.make(2, 257), **kw)


def fill(store, n=2, nbytes=4096, seed=0):
    rng = np.random.default_rng(seed)
    objs = {}
    for i in range(n):
        key = f"o{i}"
        objs[key] = rng.integers(0, 256, nbytes, dtype=np.uint8).tobytes()
        store.put(key, objs[key])
    return objs


def test_convert_round_trip_both_directions():
    with make_store() as store:
        objs = fill(store)
        for key, ref in objs.items():
            r = store.convert(key, PM)
            assert r.converted and r.target == PM
            assert store.class_of(key) == PM
            assert store.get(key) == ref
            r2 = store.convert(key, store.default_class)
            assert r2.source == PM and r2.converted
            assert store.class_of(key) == store.default_class
            assert store.get(key) == ref
        assert store.verify()
        assert store.audit().clean


def test_convert_is_noop_on_same_class():
    with make_store() as store:
        objs = fill(store, n=1)
        key = next(iter(objs))
        r = store.convert(key, store.default_class)
        assert not r.converted and r.bytes_read == 0
        assert store.get(key) == objs[key]


def test_convert_preserves_meta_array_type_and_crc_ledger():
    with make_store() as store:
        arr = np.arange(300, dtype=np.int16).reshape(20, 15)
        store.put("arr", arr, meta={"tag": "v1"})
        store.convert("arr", PM)
        got = store.get("arr")
        np.testing.assert_array_equal(got, arr)
        assert got.dtype == arr.dtype
        stat = store.stat("arr")
        assert stat.meta["tag"] == "v1"
        # the ledger is rebuilt under the target family: every share of
        # every stripe must verify against its put-time CRC
        assert store.verify()
        assert not store.scrub_node(1)


def test_convert_serves_from_degraded_source():
    with make_store() as store:
        objs = fill(store, n=1, nbytes=8192)
        key = next(iter(objs))
        store.fail_node(1)
        r = store.convert(key, PM)
        assert store.class_of(key) == PM
        assert store.get(key) == objs[key]
        # at least one source stripe lost a share and needed a decode
        assert r.degraded_source_stripes >= 1


def test_convert_reuses_systematic_shares_when_healthy():
    with make_store() as store:
        objs = fill(store, n=1)
        key = next(iter(objs))
        r = store.convert(key, PM)
        assert r.degraded_source_stripes == 0
        # healthy read-out touches exactly the payload: k*q*S per stripe
        assert r.bytes_read == r.source_stripes * 2 * 2 * store.S
        assert store.get(key) == objs[key]


@pytest.mark.parametrize("victim", ["node:01", "node:04", "node:06"])
def test_crash_mid_convert_leaves_source_intact(victim):
    """A write crash partway through the conversion put must leave the
    OLD generation fully readable, the manifest unchanged, and nothing
    but garbage the audit counts as zero (staged shares are never
    installed).  Failing one node's writes persistently means SOME
    target shares were produced before the give-up — the torn-put
    shape the commit-last protocol must mask."""
    faults = FaultInjector(seed=0)
    with make_store(faults=faults, retry=fast_retry()) as store:
        objs = fill(store, n=1, nbytes=8192)
        key = next(iter(objs))
        old_class = store.class_of(key)
        faults.add(op="write", kind="transient", match=victim)
        with pytest.raises(GiveUpError):
            store.convert(key, PM)
        faults.clear()
        assert store.class_of(key) == old_class
        assert store.get(key) == objs[key]
        assert store.audit().clean
        assert store.gc_orphans() == 0
        assert store.verify()
        # the injector healed: the same conversion now lands atomically
        store.convert(key, PM)
        assert store.class_of(key) == PM
        assert store.get(key) == objs[key]
        assert store.audit().clean


def test_crash_converting_back_keeps_target_generation():
    """Symmetric crash on the PM -> default direction: the PM object
    stays live and bit-exact."""
    faults = FaultInjector(seed=1)
    with make_store(faults=faults, retry=fast_retry()) as store:
        objs = fill(store, n=1)
        key = next(iter(objs))
        store.convert(key, PM)
        faults.add(op="write", kind="transient")
        with pytest.raises(GiveUpError):
            store.convert(key, store.default_class)
        faults.clear()
        assert store.class_of(key) == PM
        assert store.get(key) == objs[key]
        assert store.audit().clean


def test_scheduler_runs_queued_conversions_after_repairs():
    """Protection first, re-encoding second: a drain with both repair
    tasks and queued conversions repairs every stripe AND converts,
    charging conversion read traffic to the same budget."""
    with make_store(n_nodes=10) as store:
        objs = fill(store, n=3, nbytes=4096)
        sched = RepairScheduler(store)
        store.subscribe(sched.on_event)
        for key in objs:
            sched.enqueue_convert(key, PM_SMALL)
        store.fail_node(2)
        rep = sched.drain_all(budget_symbols=4 * 2 * 2 * store.S)
        assert rep.converted_objects == len(objs)
        assert rep.convert_symbols > 0
        assert sched.pending_converts() == 0
        for key, ref in objs.items():
            assert store.class_of(key) == PM_SMALL
            assert store.get(key) == ref
        assert store.verify()


def test_scheduler_convert_skips_deleted_keys():
    with make_store() as store:
        objs = fill(store, n=2)
        sched = RepairScheduler(store)
        keys = list(objs)
        sched.enqueue_convert(keys[0], PM)
        sched.enqueue_convert(keys[1], PM)
        store.delete(keys[0])
        rep = sched.drain_all(budget_symbols=1 << 20)
        assert rep.converted_objects == 1
        assert store.class_of(keys[1]) == PM


def test_degraded_reads_under_target_family_after_convert():
    """put under family A -> convert -> kill nodes -> reads still come
    back bit-exact through the target family's decode paths."""
    with make_store() as store:
        objs = fill(store, n=2, nbytes=8192)
        for key in objs:
            store.convert(key, PM)
        store.fail_node(3)
        store.fail_node(5)
        degraded = 0
        for key, ref in objs.items():
            res = store.get_ext(key)
            assert res.obj == ref
            degraded += res.degraded_stripes
        assert degraded > 0
