"""Circulant machinery: paper's worked examples + structural properties."""
import itertools

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import circulant, gf


def test_circulant_vector_42():
    # paper [4,2]: w = circ(0, 0, c1, c2)
    w = circulant.circulant_vector([7, 9])
    np.testing.assert_array_equal(w, [0, 0, 7, 9])


def test_circulant_matrix_matches_paper_42():
    """Paper §III-B example: A=(I|M) for [4,2] gives
    r_1 = c2 a1 + c1 a2, r_2 = c2 a2 + c1 a3, r_3 = c1 a0 + c2 a3, r_4 = c2 a0 + c1 a1."""
    c1, c2 = 3, 4
    m = circulant.circulant_matrix([c1, c2], p=257)
    # column i-1 = coefficients of r_i over rows a_j
    want = np.zeros((4, 4), int)
    want[1, 0], want[2, 0] = c2, c1          # r_1
    want[2, 1], want[3, 1] = c2, c1          # r_2
    want[0, 2], want[3, 2] = c1, c2          # r_3
    want[0, 3], want[1, 3] = c2, c1          # r_4
    np.testing.assert_array_equal(m, want)


def test_condition6_42_polynomial():
    """Paper: condition (6) for [4,2] is -c1^8 c2^4 != 0, i.e. any nonzero c works,
    including over F_2 with c=(1,1)."""
    for p in (2, 3, 5, 257):
        assert circulant.check_condition6([1, 1], p)
    # and the polynomial identity itself on a sample of fields/coefficients
    for p in (5, 7, 257):
        for c1 in range(1, min(p, 6)):
            for c2 in range(1, min(p, 6)):
                prod = 1
                for s in itertools.combinations(range(1, 5), 2):
                    prod = (prod * circulant.submatrix_condition_det([c1, c2], s, p)) % p
                want = (-pow(c1, 8, p) * pow(c2, 4, p)) % p
                # determinant sign depends on the (unspecified) row ordering
                # convention; accept the identity up to global sign.
                assert prod in (want, (-want) % p), (p, c1, c2)


def test_condition6_63_paper_solution():
    """Paper §III-D: w = circ(0,0,0,1,1,2) is a valid [6,3] code over F_5."""
    assert circulant.check_condition6([1, 1, 2], p=5)


def test_condition6_63_polynomial_value():
    """Check the paper's [6,3] condition-(6) polynomial
    -c1^24 c2^12 (c2^2 c3 - c1 c3^2)^3 c3^3 (-c2^2 + c1 c3)^3 (c3^3 + c1^3)^2
    against the product of subset determinants, on random points over F_257."""
    p = 257
    rng = np.random.default_rng(0)
    for _ in range(5):
        c1, c2, c3 = (int(x) for x in rng.integers(1, p, size=3))
        prod = 1
        for s in itertools.combinations(range(1, 7), 3):
            prod = (prod * circulant.submatrix_condition_det([c1, c2, c3], s, p)) % p
        want = (-pow(c1, 24, p) * pow(c2, 12, p)
                * pow(c2 * c2 % p * c3 - c1 * c3 * c3, 3, p) * pow(c3, 3, p)
                * pow(c1 * c3 - c2 * c2, 3, p)
                * pow(pow(c3, 3, p) + pow(c1, 3, p), 2, p)) % p
        want %= p
        # sign convention of the subset determinants is row-order dependent
        assert prod in (want, (-want) % p), (c1, c2, c3)


def test_condition6_rejects_zero_coefficient():
    assert not circulant.check_condition6([0, 1], p=5)
    assert not circulant.check_condition6([1, 0, 1], p=5)


def test_find_coefficients_various_k():
    for k in (1, 2, 3, 4, 5):
        c = circulant.find_coefficients(k, p=257, seed=0)
        assert c.shape == (k,)
        assert circulant.check_condition6(c, 257)


def test_min_field_size_paper_claims():
    # [4,2] has a solution over any field (paper: F_2 suffices)
    assert circulant.min_field_size(2) == 2
    # [6,3]: paper exhibits a solution over F_5; check F_5 admits one and
    # that min over our prime list is <= 5
    assert circulant.min_field_size(3) <= 5


def test_generator_matrix_shape_and_identity():
    a = circulant.generator_matrix([1, 2, 3], p=7)
    assert a.shape == (6, 12)
    np.testing.assert_array_equal(a[:, :6], np.eye(6, dtype=np.int32))


def test_redundancy_support_matches_matrix():
    for k in (2, 3, 5):
        m = circulant.circulant_matrix(list(range(1, k + 1)), p=257)
        n = 2 * k
        for i in range(1, n + 1):
            nz = sorted(int(j) for j in np.nonzero(m[:, i - 1])[0])
            assert nz == sorted(circulant.redundancy_support(i, n))


def test_lemma1_every_row_nonzero():
    """Lemma 1: A^s has at least one nonzero coefficient in each row."""
    k = 3
    a = circulant.generator_matrix([1, 1, 2], p=5)
    n = 2 * k
    for s in itertools.combinations(range(1, n + 1), k):
        cols = [i - 1 for i in s] + [n + i - 1 for i in s]
        sub = a[:, cols]
        assert (sub != 0).any(axis=1).all()


@given(st.integers(2, 5), st.sampled_from([5, 7, 257]), st.integers(0, 10))
@settings(max_examples=10, deadline=None)
def test_codespec_make_validates(k, p, seed):
    try:
        spec = circulant.CodeSpec.make(k, p, seed=seed)
    except ValueError:
        return  # small fields may not admit a code for this k
    assert spec.n == 2 * k and spec.d == k + 1
    assert circulant.check_condition6(spec.c, p)
