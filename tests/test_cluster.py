"""Cluster failure simulator + degraded-read serving (DESIGN.md §9).

Acceptance battery: node loss over the full 1..n-k erasure budget,
latent corruption caught and repaired by scrub, rack-correlated failure,
straggler mitigation, rolling restarts — every recovery bit-exact, every
scenario's repair traffic ratioed against the RS re-download baseline.
"""
import numpy as np
import pytest

from repro.cluster import (ClusterSimulator, LinkModel, MetricsLog, events,
                           run_scenario)
from repro.core.baselines import rs_scenario_repair_symbols
from repro.core.circulant import CodeSpec
from repro.core.placement import RackLayout, rack_layout
from repro.serve.engine import CodedReadServer
from repro.train import fault_tolerance as ft

K, P, S = 4, 257, 256


@pytest.fixture(scope="module")
def spec():
    return CodeSpec.make(K, P)


@pytest.fixture(scope="module")
def data(spec):
    rng = np.random.default_rng(7)
    return rng.integers(0, P, (spec.n, S), dtype=np.int64).astype(np.int32)


def fresh_sim(spec, data, **kw):
    return ClusterSimulator(spec, data, **kw)


# ------------------------------------------------------------- node loss
@pytest.mark.parametrize("failures", range(1, 2 * K - K + 1))   # 1..n-k
def test_node_loss_bit_exact(spec, data, failures):
    sc = events.multi_node_loss(spec.n, spec.k, failures=failures)
    rep = run_scenario(spec, data, sc)
    assert rep.bit_exact
    m = rep.metrics["repair"]
    assert m["nodes_repaired"] == failures
    assert m["rs_baseline_symbols"] == rs_scenario_repair_symbols(
        spec.k, S, failures)
    if failures == 1:
        # embedded fused repair: gamma = (k+1) S of a 2kS baseline
        assert m["symbols_moved"] == (spec.k + 1) * S
        assert m["ratio_vs_rs"] == pytest.approx((K + 1) / (2 * K))
    else:
        # one-matmul multi-failure decode: one download set total
        assert m["symbols_moved"] == 2 * spec.k * S
        assert m["ratio_vs_rs"] == pytest.approx(1 / failures, rel=1e-3)


def test_single_loss_serves_degraded_reads(spec, data):
    rep = run_scenario(spec, data, events.single_node_loss(spec.n))
    assert rep.bit_exact
    assert rep.metrics["reads"]["degraded"] > 0
    assert rep.metrics["reads"]["failed"] == 0
    assert rep.metrics["availability"] == 1.0
    assert rep.unserved_events == 0


def test_beyond_budget_is_unrecoverable(spec, data):
    with pytest.raises(ValueError):
        events.multi_node_loss(spec.n, spec.k, failures=spec.n - spec.k + 1)
    sim = fresh_sim(spec, data)
    for v in range(1, spec.n - spec.k + 2):     # n-k+1 failures by hand
        sim.fail_node(v)
    assert sim.read_block(0) is None            # < k up: unservable
    assert sim.metrics.reads_failed == 1
    assert not sim.repair_now()


# ------------------------------------------------------- corruption + scrub
def test_corruption_scrub_repairs_bit_exact(spec, data):
    rep = run_scenario(spec, data, events.latent_corruption(spec.n))
    assert rep.bit_exact
    assert rep.metrics["scrub"]["passes"] == 1
    assert rep.metrics["scrub"]["nodes_flagged"] >= 1
    assert rep.metrics["scrub"]["symbols_read"] == 2 * spec.n * S


def test_scrub_flags_and_convicts_redundancy_corruption(spec, data):
    sim = fresh_sim(spec, data)
    sim.node_r[4, 3] = (sim.node_r[4, 3] + 1) % P       # node 5's r block
    flagged = sim.run_scrub()
    assert 5 in flagged
    assert np.array_equal(sim.node_r, sim._orig_r)      # repaired
    assert np.array_equal(sim.node_a, sim._orig_a)


def test_clean_scrub_flags_nothing(spec, data):
    sim = fresh_sim(spec, data)
    assert sim.run_scrub() == ()
    assert sim.metrics.repair_events == 0


def test_scrub_skipped_when_node_down(spec, data):
    sim = fresh_sim(spec, data)
    sim.state[0] = "down"
    assert sim.run_scrub() == ()
    assert sim.metrics.scrub_symbols == 0
    # a skipped pass must not masquerade as a clean one
    assert sim.metrics.scrub_passes == 0
    assert sim.metrics.scrub_skipped == 1


def test_corrupt_event_validates_target():
    with pytest.raises(ValueError):
        events.corrupt(1.0, 2, where="data")
    with pytest.raises(ValueError):
        events.Event(t=0.0, kind="bogus")


def test_node_targeted_events_validate_node(spec, data):
    with pytest.raises(ValueError):
        events.Event(t=0.0, kind="fail")        # node defaults to 0
    with pytest.raises(ValueError):
        events.fail(1.0, 0)
    sim = fresh_sim(spec, data)
    with pytest.raises(ValueError):
        sim.fail_node(0)                        # nodes are 1-indexed
    with pytest.raises(ValueError):
        sim.fail_node(spec.n + 1)
    with pytest.raises(ValueError):
        sim.run(events.Scenario("bad", (events.Event(
            t=0.0, kind="slow", node=spec.n + 3),)))


def test_read_all_unservable_bills_nothing(spec, data):
    sim = fresh_sim(spec, data)
    for v in range(1, spec.n - spec.k + 2):     # below k survivors
        sim.fail_node(v)
    assert sim.read_all() is None
    assert sim.metrics.reads_systematic == 0    # nothing claimed as served
    assert sim.metrics.reads_failed == spec.n
    assert sim.metrics.read_symbols == 0


# ------------------------------------------------------------ rack failure
def test_rack_layout_placement():
    lay = rack_layout(8, 4)
    assert lay.n_racks == 4 and lay.max_rack_size == 2
    assert lay.nodes_in(0) == (1, 5)
    assert lay.rack_of(5) == 0
    assert lay.survives_rack_loss(k=4)          # 2 <= n-k = 4
    tight = RackLayout(8, racks=(0, 0, 0, 0, 0, 1, 1, 1))
    assert not tight.survives_rack_loss(k=4)    # 5 > 4


def test_rack_correlated_failure_bit_exact(spec, data):
    lay = rack_layout(spec.n, 4)
    rep = run_scenario(spec, data, events.rack_failure(lay, spec.k, rack=1),
                       layout=lay)
    assert rep.bit_exact
    f = len(lay.nodes_in(1))
    assert rep.metrics["repair"]["nodes_repaired"] == f
    assert rep.metrics["repair"]["ratio_vs_rs"] == pytest.approx(1 / f)


def test_rack_failure_rejects_overfull_rack(spec):
    tight = RackLayout(8, racks=(0,) * 5 + (1,) * 3)
    with pytest.raises(ValueError):
        events.rack_failure(tight, spec.k, rack=0)


# -------------------------------------------------- stragglers + restarts
def test_straggler_mitigation_routes_around(spec, data):
    rep = run_scenario(spec, data, events.straggler(spec.n, factor=50.0))
    assert rep.bit_exact
    assert rep.metrics["reads"]["degraded"] > 0       # rerouted
    assert rep.metrics["repair"]["events"] == 0       # no repair traffic
    # without mitigation the slow node serves its own block
    rep2 = run_scenario(spec, data, events.straggler(spec.n, factor=50.0),
                        straggler_mitigation=False)
    assert rep2.metrics["reads"]["degraded"] == 0
    assert rep2.metrics["reads"]["latency"]["max_s"] > \
        rep.metrics["reads"]["latency"]["max_s"]


def test_rolling_restart_degrades_without_repair(spec, data):
    rep = run_scenario(spec, data, events.rolling_restart(spec.n))
    assert rep.bit_exact
    assert rep.metrics["reads"]["degraded"] >= spec.n   # one per dwell window
    assert rep.metrics["repair"]["symbols_moved"] == 0  # data was intact
    assert rep.metrics["availability"] == 1.0


# ------------------------------------------------------------ degraded reads
def test_degraded_read_bit_exact_and_single_solve(spec, data):
    sim = fresh_sim(spec, data)
    sim.fail_node(3)
    sim.code.repair.decode_cache.clear()
    for _ in range(5):
        out = sim.read_block(2)                 # the failed node's block
        np.testing.assert_array_equal(out, data[2])
    info = sim.code.repair.decode_cache.cache_info()
    assert info.misses == 1 and info.hits == 4  # one gauss_inverse total


def test_read_all_mixes_systematic_and_one_decode(spec, data):
    sim = fresh_sim(spec, data)
    sim.fail_node(1)
    sim.fail_node(6)
    out = sim.read_all()
    np.testing.assert_array_equal(out, data)
    assert sim.metrics.reads_systematic == spec.n - 2
    assert sim.metrics.reads_degraded == 2
    # the two degraded blocks share one download set
    assert sim.metrics.read_symbols == (spec.n - 2) * S + 2 * spec.k * S


# ----------------------------------------------------------- serving layer
def test_coded_read_server_pytree_roundtrip(spec):
    state = {"w": np.arange(600, dtype=np.float32).reshape(20, 30),
             "step": np.int32(41)}
    srv = CodedReadServer.for_pytree(state, spec)
    for victim in (2, 7):
        srv.sim.fail_node(victim)
    got = srv.read_state()
    np.testing.assert_array_equal(got["w"], state["w"])
    assert got["step"] == state["step"]
    assert srv.metrics.reads_degraded == 2
    assert srv.sim.repair_now()
    assert np.array_equal(srv.sim.node_a, srv.sim._orig_a)
    assert np.array_equal(srv.sim.node_r, srv.sim._orig_r)


def test_coded_read_server_requires_pytree_mode(spec, data):
    srv = CodedReadServer(fresh_sim(spec, data))
    with pytest.raises(RuntimeError):
        srv.read_state()
    np.testing.assert_array_equal(srv.read_block(4), data[4])


# ------------------------------------------------------- training wiring
def test_cluster_schedule_injector_maps_time_to_steps():
    sc = events.single_node_loss(8, node=5, at=3.0)
    inj = ft.ClusterScheduleInjector(8, sc, steps_per_time=2.0)
    assert inj.at(6) == [ft.FailureEvent(step=6, node=5)]
    assert inj.at(3) == []


def test_supervisor_records_repair_into_cluster_metrics(tmp_path):
    spec = CodeSpec.make(3, 257)
    ckpt_dir = tmp_path / "ckpt"
    from repro.checkpoint.msr_checkpoint import MSRCheckpointer
    ckpt = MSRCheckpointer(ckpt_dir, spec)
    metrics = MetricsLog()
    sc = events.single_node_loss(spec.n, node=2, at=3.0)
    inj = ft.ClusterScheduleInjector(spec.n, sc)
    sup = ft.Supervisor(ckpt, inj, ckpt_every=2, metrics=metrics)

    state = {"x": np.arange(128, dtype=np.float32)}

    def step_fn(s, batch):
        return {"x": s["x"] + 1.0}, {"loss": float(s["x"][0])}

    out = sup.run(state, step_fn, lambda step: None, n_steps=6)
    assert any(e["event"] == "repair" for e in sup.log)
    assert metrics.repair_events == 1
    assert metrics.repaired_nodes == 1
    assert 0 < metrics.repair_symbols
    assert metrics.rs_baseline_symbols > 0
    np.testing.assert_array_equal(out["x"], state["x"] + 6.0)


# ---------------------------------------------------------------- metrics
def test_metrics_summary_shapes():
    m = MetricsLog()
    m.record_read("systematic", 0.001, 256)
    m.record_read("degraded", 0.002, 2048, corrupt=True)
    m.record_read("failed", 0.0, 0)
    m.record_repair(2, 2048, 4096)
    s = m.summary()
    assert s["availability"] == pytest.approx(2 / 3, rel=1e-3)
    assert s["reads"]["served_corrupt"] == 1
    assert s["repair"]["ratio_vs_rs"] == 0.5
    assert s["reads"]["latency"]["max_s"] == pytest.approx(0.002)
    with pytest.raises(ValueError):
        m.record_read("bogus", 0.0, 0)


def test_link_model_latency_ordering():
    link = LinkModel(bandwidth_bps=1e6, request_overhead_s=1e-3)
    fast = link.fetch_s(1000)
    slow = link.fetch_s(1000, slow_factor=10.0)
    assert slow == pytest.approx(10 * fast)
    deg = link.degraded_read_s(2000, [1.0, 1.0, 4.0])
    assert deg > link.fetch_s(2000)             # slowest helper dominates


def test_standard_scenarios_all_bit_exact(spec, data):
    for sc in events.standard_scenarios(spec.n, spec.k):
        rep = run_scenario(spec, data, sc)
        assert rep.bit_exact, sc.name
        assert rep.metrics["availability"] == 1.0, sc.name
