"""GF(2^8) backend: field axioms, known AES values, matmul/inverse."""
import numpy as np
import jax.numpy as jnp
from _hypothesis_compat import given, settings, st

from repro.core import gf256


@given(st.integers(0, 255), st.integers(0, 255), st.integers(0, 255))
@settings(max_examples=80, deadline=None)
def test_field_axioms(a, b, c):
    m, add = gf256.mul, gf256.add
    assert int(add(a, b)) == a ^ b
    assert int(m(m(a, b), c)) == int(m(a, m(b, c)))
    assert int(m(a, add(b, c))) == int(add(m(a, b), m(a, c)))
    assert int(m(a, 1)) == a


def test_known_aes_products():
    # classic AES mix-columns facts over 0x11B
    assert int(gf256.mul(0x57, 0x83)) == 0xC1
    assert int(gf256.mul(0x02, 0x80)) == 0x1B
    assert int(gf256.mul(0x53, 0xCA)) == 0x01   # inverse pair


@given(st.integers(1, 255))
@settings(max_examples=60, deadline=None)
def test_inverse(a):
    assert int(gf256.mul(a, gf256.inv(a))) == 1


def test_matmul_against_scalar_reference():
    rng = np.random.default_rng(0)
    a = rng.integers(0, 256, (5, 7)).astype(np.int32)
    b = rng.integers(0, 256, (7, 9)).astype(np.int32)
    got = np.asarray(gf256.matmul(a, b))
    want = np.zeros((5, 9), np.int32)
    for i in range(5):
        for j in range(9):
            acc = 0
            for t in range(7):
                acc ^= int(gf256.mul(int(a[i, t]), int(b[t, j])))
            want[i, j] = acc
    np.testing.assert_array_equal(got, want)


def test_gauss_inverse_roundtrip():
    rng = np.random.default_rng(1)
    for _ in range(3):
        m = rng.integers(0, 256, (6, 6)).astype(np.int32)
        try:
            inv = gf256.gauss_inverse(m)
        except ValueError:
            continue
        eye = np.asarray(gf256.matmul(jnp.asarray(m), jnp.asarray(inv)))
        np.testing.assert_array_equal(eye, np.eye(6, dtype=np.int32))


def test_mds_code_over_gf256():
    """A Cauchy-style MDS sanity: random invertible generator rows recover
    data (the byte-native alternative to the GF(257) path)."""
    rng = np.random.default_rng(2)
    k, s = 4, 64
    data = rng.integers(0, 256, (k, s)).astype(np.int32)
    g = rng.integers(0, 256, (k, k)).astype(np.int32)
    while True:
        try:
            ginv = gf256.gauss_inverse(g)
            break
        except ValueError:
            g = rng.integers(0, 256, (k, k)).astype(np.int32)
    coded = np.asarray(gf256.matmul(jnp.asarray(g), jnp.asarray(data)))
    back = np.asarray(gf256.matmul(jnp.asarray(ginv), jnp.asarray(coded)))
    np.testing.assert_array_equal(back, data)
