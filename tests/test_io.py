"""The fault-injectable I/O substrate (DESIGN.md §12): blob backends,
retry policy, fault injection."""
import pathlib

import numpy as np
import pytest

from repro.io import (TRANSIENT_ERRORS, FaultInjector, FaultyBlob,
                      GiveUpError, LocalBlob, RetryPolicy, RetryStats,
                      count_tmp_orphans, fast_retry)


# ------------------------------------------------------------------ blob
class TestLocalBlob:
    def test_roundtrip_and_metadata(self, tmp_path):
        b = LocalBlob()
        p = tmp_path / "x.bin"
        b.write(p, b"payload")
        assert b.read(p) == b"payload"
        assert b.exists(p) and not b.isdir(p)
        assert b.listdir(tmp_path) == ["x.bin"]
        b.mkdir(tmp_path / "d")
        assert b.isdir(tmp_path / "d")
        b.rename(p, tmp_path / "d" / "y.bin")
        assert b.read(tmp_path / "d" / "y.bin") == b"payload"
        b.rmtree(tmp_path / "d")
        assert not b.exists(tmp_path / "d")

    def test_count_tmp_orphans(self, tmp_path):
        assert count_tmp_orphans(tmp_path) == 0
        (tmp_path / "step_000001.tmp").mkdir()
        (tmp_path / "step_000002").mkdir()
        (tmp_path / "step_000002" / "f.npy.tmp").write_bytes(b"x")
        (tmp_path / "step_000002" / "f.npy").write_bytes(b"x")
        assert count_tmp_orphans(tmp_path) == 2
        assert count_tmp_orphans(tmp_path / "missing") == 0


# ----------------------------------------------------------------- retry
class TestRetryPolicy:
    def test_success_first_try_no_sleep(self):
        calls = []
        pol = RetryPolicy(sleep=lambda s: calls.append(s))
        stats = RetryStats()
        assert pol.call(lambda: 42, op="x", stats=stats) == 42
        assert calls == [] and stats.summary() == {
            "ops": 1, "attempts": 1, "retries": 0, "giveups": 0,
            "amplification": 1.0}

    def test_transient_heals(self):
        state = {"n": 0}

        def flaky():
            state["n"] += 1
            if state["n"] < 3:
                raise OSError("flaky")
            return "ok"

        stats = RetryStats()
        assert fast_retry().call(flaky, op="x", stats=stats) == "ok"
        assert stats.attempts == 3 and stats.retries == 2
        assert stats.giveups == 0

    def test_typed_giveup_carries_cause(self):
        pol = fast_retry(max_attempts=3)
        stats = RetryStats()
        with pytest.raises(GiveUpError) as ei:
            pol.call(self._always_fail, op="w:node_01", stats=stats)
        assert ei.value.op == "w:node_01" and ei.value.attempts == 3
        assert isinstance(ei.value.__cause__, OSError)
        # a give-up is NOT retryable by an outer policy layer
        assert not isinstance(ei.value, TRANSIENT_ERRORS)
        assert stats.giveups == 1 and stats.amplification == 3.0

    @staticmethod
    def _always_fail():
        raise OSError("dead disk")

    def test_non_transient_propagates_immediately(self):
        def boom():
            raise ValueError("logic error")

        with pytest.raises(ValueError):
            fast_retry().call(boom, op="x")

    def test_deterministic_jitter(self):
        pol = RetryPolicy(base_delay_s=0.01, jitter=0.5)
        d1 = [pol.delay_s("op-a", a) for a in range(4)]
        assert d1 == [pol.delay_s("op-a", a) for a in range(4)]
        # jitter stays in [1-j, 1+j] of the raw exponential curve
        for a, d in enumerate(d1):
            raw = min(0.01 * 2.0 ** a, pol.max_delay_s)
            assert 0.5 * raw <= d <= 1.5 * raw
        # different op names take different (but fixed) backoff paths
        assert d1 != [pol.delay_s("op-b", a) for a in range(4)]

    def test_op_timeout_bounds_wall_clock(self):
        clock = {"t": 0.0}

        def tick():
            clock["t"] += 10.0
            raise OSError("slow fail")

        pol = RetryPolicy(max_attempts=100, op_timeout_s=25.0,
                          sleep=lambda s: None, clock=lambda: clock["t"])
        with pytest.raises(GiveUpError) as ei:
            pol.call(tick, op="x")
        assert ei.value.attempts < 100  # budget, not attempts, stopped it

    def test_validation(self):
        with pytest.raises(ValueError):
            RetryPolicy(max_attempts=0)
        with pytest.raises(ValueError):
            RetryPolicy(jitter=1.5)
        with pytest.raises(ValueError):
            RetryPolicy(op_timeout_s=0)


# ---------------------------------------------------------------- faults
class TestFaultInjector:
    def test_times_caps_firing(self):
        inj = FaultInjector()
        inj.add(op="write", kind="transient", times=2)
        fired = 0
        for _ in range(5):
            try:
                inj.apply("write", "p")
            except OSError:
                fired += 1
        assert fired == 2

    def test_op_and_match_filtering(self):
        inj = FaultInjector()
        inj.add(op="write", match="node_03", kind="transient")
        inj.apply("read", "node_03.a.npy")        # wrong op: no fire
        inj.apply("write", "node_01.a.npy")       # wrong ref: no fire
        with pytest.raises(OSError):
            inj.apply("write", "x/node_03.a.npy")

    def test_prob_deterministic_given_seed(self):
        def seq(seed):
            inj = FaultInjector(seed=seed)
            inj.add(kind="transient", prob=0.5)
            out = []
            for i in range(32):
                try:
                    inj.apply("write", f"p{i}")
                    out.append(0)
                except OSError:
                    out.append(1)
            return out

        a = seq(7)
        assert a == seq(7) and 0 < sum(a) < 32
        assert a != seq(8)

    def test_latency_sleeps_instead_of_raising(self):
        slept = []
        inj = FaultInjector(sleep=slept.append)
        inj.add(kind="latency", latency_s=0.25)
        inj.apply("read", "p")
        assert slept == [0.25]

    def test_unknown_kind_rejected(self):
        with pytest.raises(ValueError):
            FaultInjector().add(kind="meteor")


class TestFaultyBlob:
    def _blob(self, faults):
        return FaultyBlob(LocalBlob(fsync=False), faults)

    def test_transient_write_leaves_no_bytes(self, tmp_path):
        inj = FaultInjector()
        inj.add(op="write", kind="transient", times=1)
        fb = self._blob(inj)
        with pytest.raises(OSError):
            fb.write(tmp_path / "f", b"data")
        assert not fb.exists(tmp_path / "f")
        fb.write(tmp_path / "f", b"data")          # rule exhausted
        assert fb.read(tmp_path / "f") == b"data"

    def test_torn_write_leaves_prefix_then_raises(self, tmp_path):
        inj = FaultInjector()
        inj.add(op="write", kind="torn", torn_fraction=0.5, times=1)
        fb = self._blob(inj)
        with pytest.raises(OSError):
            fb.write(tmp_path / "f", b"0123456789")
        assert fb.read(tmp_path / "f") == b"01234"   # the torn prefix

    def test_torn_write_heals_under_retry(self, tmp_path):
        inj = FaultInjector()
        inj.add(op="write", kind="torn", times=1)
        fb = self._blob(inj)
        fast_retry().call(lambda: fb.write(tmp_path / "f", b"0123456789"),
                          op="w")
        assert fb.read(tmp_path / "f") == b"0123456789"

    def test_corrupt_flips_exactly_one_byte(self, tmp_path):
        inj = FaultInjector()
        inj.add(op="read", kind="corrupt", times=1)
        fb = self._blob(inj)
        fb.write(tmp_path / "f", bytes(64))
        bad = fb.read(tmp_path / "f")
        assert bad != bytes(64) and len(bad) == 64
        assert sum(a != b for a, b in zip(bad, bytes(64))) == 1
        assert fb.read(tmp_path / "f") == bytes(64)  # rule exhausted

    def test_torn_read_returns_prefix(self, tmp_path):
        inj = FaultInjector()
        inj.add(op="read", kind="torn", torn_fraction=0.25, times=1)
        fb = self._blob(inj)
        fb.write(tmp_path / "f", b"x" * 100)
        assert len(fb.read(tmp_path / "f")) == 25

    def test_rename_fault_kills_commit(self, tmp_path):
        inj = FaultInjector()
        inj.add(op="rename", match="final", kind="transient")
        fb = self._blob(inj)
        fb.write(tmp_path / "stage", b"x")
        with pytest.raises(OSError):
            fb.rename(tmp_path / "stage", tmp_path / "final")
        assert fb.exists(tmp_path / "stage")
        assert not fb.exists(tmp_path / "final")
