"""Fault-tolerance runtime: supervisor recovery, stragglers, elastic plans,
bit-exact resume after crash+repair."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.checkpoint.msr_checkpoint import MSRCheckpointer
from repro.configs import get_config
from repro.core.circulant import CodeSpec
from repro.train import fault_tolerance as ft
from repro.train.loop import TrainConfig, train, init_state
from repro.optim import adamw


def tiny_cfg():
    return get_config("qwen3-4b").reduced(n_layers=2, d_model=32, n_heads=2,
                                          n_kv_heads=2, head_dim=16, d_ff=64,
                                          vocab_size=128, loss_chunk=16)


def test_failure_injector_deterministic():
    inj = ft.FailureInjector(8, schedule=[ft.FailureEvent(5, 3),
                                          ft.FailureEvent(9, 1)])
    assert inj.at(5) == [ft.FailureEvent(5, 3)]
    assert inj.at(6) == []
    assert inj.at(9) == [ft.FailureEvent(9, 1)]


def test_heartbeat_straggler_and_death():
    mon = ft.HeartbeatMonitor(4, timeout_s=10, lag_threshold=2)
    for node in (1, 2, 3, 4):
        mon.beat(node, step=10, now=100.0)
    mon.beat(2, step=4, now=100.0)   # lagging progress
    assert mon.stragglers(now=101.0) == []   # progress keyed by max
    mon2 = ft.HeartbeatMonitor(4, timeout_s=10, lag_threshold=2)
    mon2.beat(1, 10, 100.0)
    mon2.beat(2, 3, 100.0)
    mon2.beat(3, 10, 100.0)
    mon2.beat(4, 10, 100.0)
    assert mon2.stragglers(101.0) == [2]
    assert mon2.dead(now=200.0) == [1, 2, 3, 4]
    mon2.beat(1, 11, 195.0)
    assert mon2.dead(now=200.0) == [2, 3, 4]


def test_elastic_plan():
    plan = ft.plan_elastic(16, dead=[3])
    assert plan.n_alive == 15
    assert plan.data_parallel == 8       # largest pow2 <= 15
    assert plan.microbatch_scale == 2.0  # global batch preserved
    assert plan.changed
    plan2 = ft.plan_elastic(16, dead=[])
    assert plan2.data_parallel == 16 and not plan2.changed
    with pytest.raises(RuntimeError):
        ft.plan_elastic(2, dead=[1, 2])


def test_supervised_training_with_crash_recovers(tmp_path):
    """Crash at step 7 -> repair from ckpt@5 -> final state must be BIT-EXACT
    equal to an uninterrupted run (stateless data + determinism)."""
    cfg = tiny_cfg()
    tcfg = TrainConfig(n_steps=12, global_batch=4, seq_len=16, ckpt_every=5,
                       seed=3)
    ckpt = MSRCheckpointer(tmp_path / "a", CodeSpec.make(3, 257))
    inj = ft.FailureInjector(6, schedule=[ft.FailureEvent(step=7, node=2)])
    state_f, log_f = train(cfg, tcfg, checkpointer=ckpt, injector=inj)
    events = [e["event"] for e in log_f]
    assert "repair" in events

    ckpt2 = MSRCheckpointer(tmp_path / "b", CodeSpec.make(3, 257))
    state_c, _ = train(cfg, tcfg, checkpointer=ckpt2)  # clean run

    la = jax.tree_util.tree_leaves(state_f)
    lb = jax.tree_util.tree_leaves(state_c)
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_repair_event_reads_less_than_full_restore(tmp_path):
    cfg = tiny_cfg()
    tcfg = TrainConfig(n_steps=8, global_batch=4, seq_len=16, ckpt_every=3, seed=0)
    ckpt = MSRCheckpointer(tmp_path, CodeSpec.make(4, 257))
    inj = ft.FailureInjector(8, schedule=[ft.FailureEvent(step=4, node=5)])
    _, log = train(cfg, tcfg, checkpointer=ckpt, injector=inj)
    rep = [e for e in log if e["event"] == "repair"][0]
    # gamma = (k+1)/(2k) of B: for k=4 that's 5/8 of the systematic read
    sys_read = [e for e in log if e["event"] == "ckpt"]
    assert rep["repair_bytes"] > 0


# ---------------------------------------- heartbeat rejoin + validation (§12)
def test_heartbeat_threshold_validation():
    with pytest.raises(ValueError):
        ft.HeartbeatMonitor(4, timeout_s=0)
    with pytest.raises(ValueError):
        ft.HeartbeatMonitor(4, timeout_s=-5.0)
    with pytest.raises(ValueError):
        ft.HeartbeatMonitor(4, lag_threshold=-1)
    with pytest.raises(ValueError, match="straggler_s"):
        ft.HeartbeatMonitor(4, timeout_s=10, straggler_s=10)  # >= timeout
    with pytest.raises(ValueError, match="straggler_s"):
        ft.HeartbeatMonitor(4, timeout_s=10, straggler_s=0)
    with pytest.raises(ValueError):
        ft.HeartbeatMonitor(0)


def test_heartbeat_declare_dead_and_rejoin():
    mon = ft.HeartbeatMonitor(3, timeout_s=10)
    for node in (1, 2, 3):
        mon.beat(node, step=1, now=0.0)
    mon.declare_dead(2)
    assert mon.dead(now=1.0) == [2]          # removed regardless of clock
    assert mon.rejoined() == []
    mon.beat(2, step=2, now=3.0)             # the restarted host re-admits
    assert mon.dead(now=3.5) == []
    assert mon.rejoined() == [2]
    with pytest.raises(ValueError):
        mon.declare_dead(9)
    with pytest.raises(ValueError):
        mon.beat(9, 1, 0.0)


def test_heartbeat_wall_clock_straggler():
    mon = ft.HeartbeatMonitor(3, timeout_s=100, lag_threshold=2,
                              straggler_s=10)
    for node in (1, 2, 3):
        mon.beat(node, step=5, now=0.0)
    mon.beat(1, 6, 50.0)
    mon.beat(2, 6, 50.0)
    # node 3's progress is within lag_threshold but its beat is stale:
    # hung-but-not-dead is flagged by the wall-clock criterion
    assert mon.stragglers(now=55.0) == [3]
    assert mon.dead(now=55.0) == []


# -------------------------------- write-behind supervisor (DESIGN.md §12.5)
def _int_step(state, batch):
    return {"w": state["w"] + batch["x"]}, {"loss": float(batch["x"][0])}


def _int_data(step):
    return {"x": np.full(256, step + 1, np.int64)}


def _int_ref(n_steps):
    state = {"w": np.zeros(256, np.int64)}
    for s in range(n_steps):
        state, _ = _int_step(state, _int_data(s))
    return state


def test_write_behind_bit_exact_vs_stop_world(tmp_path):
    outs = {}
    for mode in (False, True):
        ck = MSRCheckpointer(tmp_path / f"wb{mode}", CodeSpec.make(2, 257))
        sup = ft.Supervisor(ck, ckpt_every=3, write_behind=mode)
        out = sup.run({"w": np.zeros(256, np.int64)}, _int_step, _int_data, 10)
        ck.close()
        outs[mode] = out
        expected = "ckpt_async" if mode else "ckpt"
        assert any(e["event"] == expected for e in sup.log)
        # run returns only after the last save committed (final barrier)
        assert ck.steps()[-1] == 9
    np.testing.assert_array_equal(outs[False]["w"], outs[True]["w"])
    np.testing.assert_array_equal(outs[True]["w"], _int_ref(10)["w"])


def test_crash_mid_save_restores_previous_generation(tmp_path):
    """Satellite: the step-8 background save dies; a crash at step 9 must
    fence the failed save, fall back to generation 4, and resume
    BIT-EXACTLY from it — no orphan residue on disk."""
    from repro.io import (FaultInjector, FaultyBlob, LocalBlob,
                          count_tmp_orphans, fast_retry)
    faults = FaultInjector(seed=0)
    faults.add(op="write", match="step_000008", kind="transient")
    ck = MSRCheckpointer(tmp_path, CodeSpec.make(2, 257),
                         io_backend=FaultyBlob(LocalBlob(), faults),
                         retry=fast_retry())
    inj = ft.FailureInjector(4, schedule=[ft.FailureEvent(step=9, node=2)])
    sup = ft.Supervisor(ck, inj, ckpt_every=4, write_behind=True,
                        on_save_error="log")
    out = sup.run({"w": np.zeros(256, np.int64)}, _int_step, _int_data, 12)
    ck.close()
    events = [e["event"] for e in sup.log]
    assert "ckpt_failed" in events            # the fenced failure, logged
    repair = [e for e in sup.log if e["event"] == "repair"][0]
    assert repair["ckpt_step"] == 4           # previous generation, not 8
    np.testing.assert_array_equal(out["w"], _int_ref(12)["w"])  # bit-exact
    assert count_tmp_orphans(tmp_path) == 0


def test_write_behind_save_error_raise_mode(tmp_path):
    from repro.io import FaultInjector, FaultyBlob, GiveUpError, LocalBlob, fast_retry
    faults = FaultInjector(seed=0)
    faults.add(op="write", match="step_000004", kind="transient")
    ck = MSRCheckpointer(tmp_path, CodeSpec.make(2, 257),
                         io_backend=FaultyBlob(LocalBlob(), faults),
                         retry=fast_retry())
    sup = ft.Supervisor(ck, ckpt_every=4, write_behind=True)  # default: raise
    with pytest.raises(GiveUpError):
        sup.run({"w": np.zeros(256, np.int64)}, _int_step, _int_data, 8)
    ck.close()


def test_supervisor_config_validation(tmp_path):
    ck = MSRCheckpointer(tmp_path, CodeSpec.make(2, 257))
    with pytest.raises(ValueError, match="on_save_error"):
        ft.Supervisor(ck, on_save_error="ignore")
    with pytest.raises(ValueError, match="save_async"):
        ft.Supervisor(object(), write_behind=True)
