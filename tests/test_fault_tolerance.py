"""Fault-tolerance runtime: supervisor recovery, stragglers, elastic plans,
bit-exact resume after crash+repair."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.checkpoint.msr_checkpoint import MSRCheckpointer
from repro.configs import get_config
from repro.core.circulant import CodeSpec
from repro.train import fault_tolerance as ft
from repro.train.loop import TrainConfig, train, init_state
from repro.optim import adamw


def tiny_cfg():
    return get_config("qwen3-4b").reduced(n_layers=2, d_model=32, n_heads=2,
                                          n_kv_heads=2, head_dim=16, d_ff=64,
                                          vocab_size=128, loss_chunk=16)


def test_failure_injector_deterministic():
    inj = ft.FailureInjector(8, schedule=[ft.FailureEvent(5, 3),
                                          ft.FailureEvent(9, 1)])
    assert inj.at(5) == [ft.FailureEvent(5, 3)]
    assert inj.at(6) == []
    assert inj.at(9) == [ft.FailureEvent(9, 1)]


def test_heartbeat_straggler_and_death():
    mon = ft.HeartbeatMonitor(4, timeout_s=10, lag_threshold=2)
    for node in (1, 2, 3, 4):
        mon.beat(node, step=10, now=100.0)
    mon.beat(2, step=4, now=100.0)   # lagging progress
    assert mon.stragglers(now=101.0) == []   # progress keyed by max
    mon2 = ft.HeartbeatMonitor(4, timeout_s=10, lag_threshold=2)
    mon2.beat(1, 10, 100.0)
    mon2.beat(2, 3, 100.0)
    mon2.beat(3, 10, 100.0)
    mon2.beat(4, 10, 100.0)
    assert mon2.stragglers(101.0) == [2]
    assert mon2.dead(now=200.0) == [1, 2, 3, 4]
    mon2.beat(1, 11, 195.0)
    assert mon2.dead(now=200.0) == [2, 3, 4]


def test_elastic_plan():
    plan = ft.plan_elastic(16, dead=[3])
    assert plan.n_alive == 15
    assert plan.data_parallel == 8       # largest pow2 <= 15
    assert plan.microbatch_scale == 2.0  # global batch preserved
    assert plan.changed
    plan2 = ft.plan_elastic(16, dead=[])
    assert plan2.data_parallel == 16 and not plan2.changed
    with pytest.raises(RuntimeError):
        ft.plan_elastic(2, dead=[1, 2])


def test_supervised_training_with_crash_recovers(tmp_path):
    """Crash at step 7 -> repair from ckpt@5 -> final state must be BIT-EXACT
    equal to an uninterrupted run (stateless data + determinism)."""
    cfg = tiny_cfg()
    tcfg = TrainConfig(n_steps=12, global_batch=4, seq_len=16, ckpt_every=5,
                       seed=3)
    ckpt = MSRCheckpointer(tmp_path / "a", CodeSpec.make(3, 257))
    inj = ft.FailureInjector(6, schedule=[ft.FailureEvent(step=7, node=2)])
    state_f, log_f = train(cfg, tcfg, checkpointer=ckpt, injector=inj)
    events = [e["event"] for e in log_f]
    assert "repair" in events

    ckpt2 = MSRCheckpointer(tmp_path / "b", CodeSpec.make(3, 257))
    state_c, _ = train(cfg, tcfg, checkpointer=ckpt2)  # clean run

    la = jax.tree_util.tree_leaves(state_f)
    lb = jax.tree_util.tree_leaves(state_c)
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_repair_event_reads_less_than_full_restore(tmp_path):
    cfg = tiny_cfg()
    tcfg = TrainConfig(n_steps=8, global_batch=4, seq_len=16, ckpt_every=3, seed=0)
    ckpt = MSRCheckpointer(tmp_path, CodeSpec.make(4, 257))
    inj = ft.FailureInjector(8, schedule=[ft.FailureEvent(step=4, node=5)])
    _, log = train(cfg, tcfg, checkpointer=ckpt, injector=inj)
    rep = [e for e in log if e["event"] == "repair"][0]
    # gamma = (k+1)/(2k) of B: for k=4 that's 5/8 of the systematic read
    sys_read = [e for e in log if e["event"] == "ckpt"]
    assert rep["repair_bytes"] > 0
