"""GF backend dispatch layer: selection rules + bit-exact backend parity.

Every registered backend must agree with the pure oracles
(ref.gf_matmul_ref / ref.circulant_encode_ref / ref.gf_axpy_ref) across
fields, code dimensions, and odd stream sizes (padding edge).
"""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import gf
from repro.core.circulant import CodeSpec
from repro.core.msr import DoubleCirculantMSR
from repro.kernels import dispatch, ops, ref

PARITY_BACKENDS = ["jnp-int32", "jnp-f32", "pallas-interpret"]
# odd sizes exercise the Pallas padding path; 1 exercises the degenerate tile
STREAMS = [1, 37, 257, 640]


def rand(shape, p, seed):
    return np.random.default_rng(seed).integers(
        0, p, size=shape, dtype=np.int64).astype(np.int32)


# ----------------------------------------------------------------- parity
@pytest.mark.parametrize("backend", PARITY_BACKENDS)
@pytest.mark.parametrize("p", [2, 5, 257])
@pytest.mark.parametrize("k", [2, 8])
def test_matmul_parity(backend, p, k):
    be = dispatch.get(backend)
    for s in STREAMS:
        a = rand((2 * k, 2 * k), p, seed=k + s)
        b = rand((2 * k, s), p, seed=k * s + 1)
        got = np.asarray(be.matmul(a, b, p))
        want = np.asarray(ref.gf_matmul_ref(jnp.asarray(a), jnp.asarray(b), p))
        np.testing.assert_array_equal(got, want,
                                      err_msg=f"{backend} p={p} k={k} s={s}")


@pytest.mark.parametrize("backend", PARITY_BACKENDS)
@pytest.mark.parametrize("p", [2, 5, 257])
@pytest.mark.parametrize("k", [2, 8])
def test_circulant_parity(backend, p, k):
    be = dispatch.get(backend)
    rng = np.random.default_rng(p * k)
    c = tuple(int(x) for x in rng.integers(1, p, size=k))
    for s in STREAMS:
        data = rand((2 * k, s), p, seed=p + k + s)
        got = np.asarray(be.circulant_encode(data, c, p))
        want = np.asarray(ref.circulant_encode_ref(jnp.asarray(data), c, p))
        np.testing.assert_array_equal(got, want,
                                      err_msg=f"{backend} p={p} k={k} s={s}")


@pytest.mark.parametrize("backend", PARITY_BACKENDS)
def test_axpy_parity(backend):
    be = dispatch.get(backend)
    for p in (2, 5, 257):
        y, x = rand((199,), p, 0), rand((199,), p, 1)
        alpha = int(rand((), p, 2))
        got = np.asarray(be.axpy(y, alpha, x, p))
        want = np.asarray(ref.gf_axpy_ref(jnp.asarray(y), alpha,
                                          jnp.asarray(x), p))
        np.testing.assert_array_equal(got, want)


def test_lazy_fold_worst_case_magnitudes():
    """All-(p-1) inputs across chunk/fold boundaries stay exact on every
    backend — the lazy-folding envelope's edge (DESIGN.md §3.2)."""
    p = 257
    for backend in PARITY_BACKENDS:
        be = dispatch.get(backend)
        for k in (127, 128, 129, 255, 256, 300):
            a = np.full((2, k), p - 1, np.int32)
            b = np.full((k, 256), p - 1, np.int32)
            got = np.asarray(be.matmul(a, b, p))
            want = (a.astype(np.int64) @ b.astype(np.int64)) % p
            np.testing.assert_array_equal(got, want,
                                          err_msg=f"{backend} k={k}")


def test_interpret_kernel_folds_with_tiny_chunk():
    """A modulus with shallow fp32 chunks (depth 6) forces many in-kernel
    folds; worst-case magnitudes across those boundaries must stay exact."""
    p = 1621                             # (p-1)^2 * 6 < 2^24 < (p-1)^2 * 7
    a = np.full((3, 25), p - 1, np.int32)
    b = np.full((25, 130), p - 1, np.int32)
    got = np.asarray(dispatch.get("pallas-interpret").matmul(a, b, p))
    np.testing.assert_array_equal(got, (a.astype(np.int64) @ b.astype(np.int64)) % p)


def test_fp32_envelope_boundary_p_too_large():
    """(p-1)^2 > 2^24-1: a single product rounds in fp32, so the Pallas
    kernels must REJECT such p, jnp-f32 must fall back to exact integer
    lanes, and auto-selection must route to jnp-int32."""
    p = 4099
    # the exact pair that rounds in fp32: 4097*4097 is odd and > 2^24
    a, b = np.asarray([[4097]], np.int32), np.asarray([[4097]], np.int32)
    want = (4097 * 4097) % p
    got = np.asarray(dispatch.get("jnp-f32").matmul(a, b, p))
    assert int(got[0, 0]) == want, (int(got[0, 0]), want)
    with pytest.raises(ValueError):
        dispatch.get("pallas-interpret").matmul(a, b, p)
    with pytest.raises(ValueError):
        dispatch.fold_count("pallas", p, 8)
    assert dispatch.fold_count("jnp-f32", p, 8) == \
        dispatch.fold_count("jnp-int32", p, 8)
    assert dispatch.select(p, 8).name == "jnp-int32"


def test_int32_envelope_boundary_p_too_large():
    """p > 46341: a SINGLE product overflows int32, so no backend in this
    layer is exact — everything must reject loudly instead of silently
    returning wrapped results (e.g. GF(65537))."""
    from repro.kernels import envelope
    assert envelope.int32_lazy_terms(envelope.INT32_MAX_P) >= 1
    assert envelope.int32_lazy_terms(envelope.INT32_MAX_P + 1) < 1
    p = 65537
    a, b = rand((2, 4), p, 0), rand((4, 8), p, 1)
    for name in ("jnp-int32", "jnp-f32"):
        be = dispatch.get(name)
        with pytest.raises(ValueError):
            be.matmul(a, b, p)
        with pytest.raises(ValueError):
            be.axpy(a[0], 3, a[1], p)
    with pytest.raises(ValueError):
        dispatch.select(p, 2)
    with pytest.raises(ValueError):
        dispatch.fold_count("jnp-int32", p, 8)
    with pytest.raises(ValueError):
        gf.matmul(a, b, p)
    with pytest.raises(ValueError):
        ref.gf_matmul_ref(jnp.asarray(a), jnp.asarray(b), p)


# -------------------------------------------------------------- selection
def test_cpu_never_selects_interpret():
    """Automatic selection must never pick the validation-only backend."""
    for p in (2, 5, 257, 4099):
        for k in (None, 2, 8, 256):
            be = dispatch.select(p, k)
            assert be.name != "pallas-interpret", (p, k)
            assert be.selectable, (p, k)
    if jax.default_backend() != "tpu":
        assert dispatch.select(257, 8).name == "jnp-int32"


def test_env_override(monkeypatch):
    monkeypatch.setenv(dispatch.ENV_VAR, "jnp-f32")
    assert dispatch.select(257, 8).name == "jnp-f32"
    # an unknown env value is a config error: a clear ValueError naming
    # the valid backends, not a bare KeyError deep in selection
    monkeypatch.setenv(dispatch.ENV_VAR, "no-such-backend")
    with pytest.raises(ValueError, match="jnp-int32.*pallas"):
        dispatch.select(257, 8)


def test_set_default_backend_override():
    try:
        dispatch.set_default_backend("jnp-f32")
        assert dispatch.select(257, 8).name == "jnp-f32"
    finally:
        dispatch.set_default_backend(None)
    with pytest.raises(KeyError):
        dispatch.set_default_backend("bogus")


def test_fold_count_accounting():
    # fp32 chunks are 255 terms; int32 lanes fold every 32767 terms (the
    # post-fold residual < p costs one term of the 32767 headroom)
    assert dispatch.int32_headroom_terms(257) == 32767
    assert dispatch.f32_exact_terms(257) == 255
    assert dispatch.fold_count("jnp-int32", 257, 512) == 1
    assert dispatch.fold_count("jnp-f32", 257, 512) == 1
    assert dispatch.fold_count("jnp-int32", 257, 100_000) == 4
    # lazy int32 accumulation: 127 fp32 chunks per fold; jnp-f32 chunks are
    # 255 terms deep, the Pallas kernel clamps depth to the MXU-native 128
    assert dispatch.fold_count("jnp-f32", 257, 255 * 127) == 1
    assert dispatch.fold_count("jnp-f32", 257, 255 * 127 + 1) == 2
    assert dispatch.fold_count("pallas", 257, 128 * 127) == 1
    assert dispatch.fold_count("pallas", 257, 128 * 127 + 1) == 2


# ------------------------------------------------------------- integration
def test_msr_code_uses_dispatch_and_agrees():
    spec = CodeSpec.make(3, 257)
    auto = DoubleCirculantMSR(spec)
    assert auto.backend_name in dispatch.registered_backends()
    assert auto.backend_name != "pallas-interpret"
    pinned = DoubleCirculantMSR(spec, backend="jnp-f32")
    data = jnp.asarray(rand((6, 333), 257, seed=5))
    np.testing.assert_array_equal(np.asarray(auto.encode(data)),
                                  np.asarray(pinned.encode(data)))
    # custom matmul still honoured (and disables the circulant fast path)
    custom = DoubleCirculantMSR(spec, matmul=gf.matmul)
    assert custom.backend_name == "custom"
    np.testing.assert_array_equal(np.asarray(auto.encode(data)),
                                  np.asarray(custom.encode(data)))


def test_ops_backend_pinning():
    a, b = rand((4, 8), 257, 0), rand((8, 129), 257, 1)
    want = (a.astype(np.int64) @ b.astype(np.int64)) % 257
    for backend in PARITY_BACKENDS:
        np.testing.assert_array_equal(
            np.asarray(ops.gf_matmul(a, b, 257, backend=backend)), want)
